"""Fused batched NMS (Pallas kernel + XLA twin) vs the serial oracle:
bit-compatibility on random inputs plus the edge cases that break naive
implementations — zero survivors, fully-suppressed clusters, score ties,
degenerate zero-area (padding) boxes — and the vectorized mAP scorer vs
the seed's loop implementation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import batched_nms, nms, nms_serial

BOTH = pytest.mark.parametrize("use_pallas", [True, False],
                               ids=["pallas", "xla"])


def _rand_batch(rng, B, A, scale=1.0):
    tl = rng.uniform(0, 1, (B, A, 2))
    wh = rng.uniform(0.01, 0.35, (B, A, 2)) * scale
    boxes = jnp.asarray(np.concatenate([tl, tl + wh], -1), jnp.float32)
    scores = jnp.asarray(rng.random((B, A)), jnp.float32)
    return boxes, scores


# ------------------------------------------------------- bit-compat sweep
@BOTH
@pytest.mark.parametrize("B,A,max_out", [
    (1, 1, 8), (2, 3, 4), (4, 160, 32), (3, 97, 16), (8, 200, 64),
    (2, 33, 200),          # max_out > n boxes
])
def test_batched_nms_bit_compatible_with_ref(B, A, max_out, use_pallas):
    rng = np.random.default_rng(B * 1000 + A)
    boxes, scores = _rand_batch(rng, B, A)
    for iou_thr in (0.3, 0.5, 0.7):
        kr, vr = ref.batched_nms_ref(boxes, scores, iou_thr, max_out)
        kf, vf = batched_nms(boxes, scores, iou_thr=iou_thr,
                             max_out=max_out, use_pallas=use_pallas)
        assert np.array_equal(np.asarray(kr), np.asarray(kf))
        assert np.array_equal(np.asarray(vr), np.asarray(vf))


@BOTH
def test_single_frame_wrapper_matches_serial_path(use_pallas):
    rng = np.random.default_rng(7)
    boxes, scores = _rand_batch(rng, 1, 120)
    kf, vf = nms(boxes[0], scores[0], 0.5, 24, use_pallas=use_pallas)
    ks, vs = nms_serial(boxes[0], scores[0], 0.5, 24)
    assert np.array_equal(np.asarray(kf), np.asarray(ks))
    assert np.array_equal(np.asarray(vf), np.asarray(vs))


# ------------------------------------------------------------- edge cases
@BOTH
def test_zero_surviving_boxes(use_pallas):
    """All scores below the threshold with stop_at_zero: nothing valid."""
    rng = np.random.default_rng(0)
    boxes, scores = _rand_batch(rng, 2, 50)
    scores = scores * 0.2                       # all < 0.4
    keep, valid = batched_nms(boxes, scores, score_thr=0.4, max_out=16,
                              stop_at_zero=True, use_pallas=use_pallas)
    assert not bool(np.asarray(valid).any())


@BOTH
def test_all_suppressed_cluster_keeps_single_box(use_pallas):
    """Near-identical boxes collapse to exactly the top-scoring one."""
    base = np.array([10.0, 10.0, 30.0, 30.0])
    boxes = jnp.asarray(base[None, None] +
                        np.linspace(0, 0.5, 20)[None, :, None],
                        jnp.float32)            # (1, 20, 4) tight cluster
    scores = jnp.asarray(np.linspace(0.5, 0.9, 20)[None], jnp.float32)
    keep, valid = batched_nms(boxes, scores, iou_thr=0.5, max_out=8,
                              use_pallas=use_pallas)
    kept = np.asarray(keep)[np.asarray(valid)]
    assert kept.tolist() == [19]                # highest score wins
    # two well-separated clusters -> one survivor each
    far = jnp.concatenate([boxes, boxes + 100.0], axis=1)
    fscores = jnp.concatenate([scores, scores * 0.9], axis=1)
    keep, valid = batched_nms(far, fscores, iou_thr=0.5, max_out=8,
                              use_pallas=use_pallas)
    assert sorted(np.asarray(keep)[np.asarray(valid)].tolist()) == [19, 39]


@BOTH
def test_score_ties_break_by_index_like_ref(use_pallas):
    """Equal scores: stable order (lowest original index first), matching
    the oracle's stable argsort exactly."""
    rng = np.random.default_rng(3)
    boxes, _ = _rand_batch(rng, 2, 64)
    scores = jnp.asarray(
        rng.choice([0.3, 0.6, 0.9], size=(2, 64)), jnp.float32)
    kr, vr = ref.batched_nms_ref(boxes, scores, 0.5, 32)
    kf, vf = batched_nms(boxes, scores, iou_thr=0.5, max_out=32,
                         use_pallas=use_pallas)
    assert np.array_equal(np.asarray(kr), np.asarray(kf))
    assert np.array_equal(np.asarray(vr), np.asarray(vf))


@BOTH
def test_degenerate_zero_area_boxes_no_nan(use_pallas):
    """Zero-area boxes (the kernel's padding rows have the same shape)
    must produce IoU 0 — kept independently, never NaN."""
    boxes = jnp.asarray([[[5, 5, 5, 5], [5, 5, 5, 5], [0, 0, 10, 10],
                          [40, 40, 41, 41]]], jnp.float32)
    scores = jnp.asarray([[0.9, 0.8, 0.7, 0.6]], jnp.float32)
    kr, vr = ref.batched_nms_ref(boxes, scores, 0.5, 4)
    kf, vf = batched_nms(boxes, scores, iou_thr=0.5, max_out=4,
                         use_pallas=use_pallas)
    assert np.array_equal(np.asarray(kr), np.asarray(kf))
    assert np.array_equal(np.asarray(vr), np.asarray(vf))
    # both degenerate boxes survive (IoU(a, a) == 0 < thr) — like the ref
    assert np.asarray(vf).sum() == 4


@BOTH
def test_padded_rows_never_leak_into_output(use_pallas):
    """A tiny frame (far below one tile) still yields exactly its own
    indices: internal padding rows are never candidates."""
    boxes = jnp.asarray([[[0, 0, 10, 10], [100, 100, 110, 110]]],
                        jnp.float32)
    scores = jnp.asarray([[0.5, 0.9]], jnp.float32)
    keep, valid = batched_nms(boxes, scores, max_out=32,
                              use_pallas=use_pallas)
    kept = np.asarray(keep)[np.asarray(valid)]
    assert sorted(kept.tolist()) == [0, 1]
    assert np.asarray(valid).sum() == 2


# -------------------------------------------------- decode-path equivalence
def test_decode_detections_same_outputs_both_paths():
    """The detector's decode must give identical valid-masked outputs via
    the Pallas kernel and the XLA twin."""
    import jax
    from repro.detector import (SSDConfig, decode_detections, init_ssd,
                                make_anchors)
    cfg = SSDConfig()
    anchors = make_anchors(cfg)
    params = init_ssd(cfg, jax.random.PRNGKey(0))
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (3, 64, 64, 3))
    outs = {}
    for up in (True, False):
        outs[up] = decode_detections(params, cfg, imgs, anchors,
                                     score_thr=0.1, use_pallas=up)
    (b1, s1, c1, v1), (b2, s2, c2, v2) = outs[True], outs[False]
    assert np.array_equal(np.asarray(v1), np.asarray(v2))
    v = np.asarray(v1)
    assert np.array_equal(np.asarray(b1)[v], np.asarray(b2)[v])
    assert np.array_equal(np.asarray(s1)[v], np.asarray(s2)[v])
    assert np.array_equal(np.asarray(c1)[v], np.asarray(c2)[v])


# ------------------------------------------------------ vectorized mAP
@pytest.mark.parametrize("video,model,n", [
    ("ETH-Sunnyday", "yolov3", 2), ("ADL-Rundle-6", "ssd300", 3)])
def test_vectorized_map_equals_loop(video, model, n):
    from repro.core import (ParallelDetector, SequenceSynchronizer,
                            evaluate_map, evaluate_map_loop)
    from repro.core.simulator import simulate
    from repro.core.stream import FrameStream
    det = ParallelDetector(video, model, ["ncs2"] * n)
    result = simulate(FrameStream(det.video), det.scheduler)
    synced = SequenceSynchronizer().order(result)
    fast = evaluate_map(det.video, synced, det.detector)
    loop = evaluate_map_loop(det.video, synced, det.detector)
    assert fast == pytest.approx(loop, abs=1e-12)


def test_vectorized_map_heterogeneous_det_by_frame():
    from repro.core import (ParallelDetector, SequenceSynchronizer,
                            evaluate_map, evaluate_map_loop)
    from repro.core.simulator import simulate
    from repro.core.stream import FrameStream
    det = ParallelDetector("ETH-Sunnyday", ["yolov3", "ssd300"],
                           ["fast_cpu", "ncs2"])
    result = simulate(FrameStream(det.video), det.scheduler)
    synced = SequenceSynchronizer().order(result)
    dbf = {a.frame_idx: det.detectors[a.executor_idx]
           for a in result.assignments}
    fast = evaluate_map(det.video, synced, det.detector, det_by_frame=dbf)
    loop = evaluate_map_loop(det.video, synced, det.detector,
                             det_by_frame=dbf)
    assert fast == pytest.approx(loop, abs=1e-12)
