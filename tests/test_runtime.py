"""Incremental serving core (``repro.serving.runtime``), event pipeline
(``repro.serving.events``) and daemon (``repro.launch.daemon``).

The load-bearing bar is bit-identity: any chunking of ``ingest`` +
``advance`` must drain to byte-for-byte the one-shot batch ``serve``
report — on the plain engine AND the rebalancing sharded engine under a
seeded fault schedule.  On top of that: the unified ``reset`` semantic
(back-to-back serves independent on every engine), rolling per-epoch
reports that merge exactly (histograms summed bucket-wise, quantiles
recomputed — never averaged), the trace-derived event bus (every
recorded event routed, shard views included, audit-clean), and the
daemon (virtual clock, graceful stop, drained in-flight frames with
frame conservation)."""
import io
import json

import numpy as np
import pytest

from repro.core import proxy_detect_fn_streams
from repro.launch.daemon import ServingDaemon, VirtualClock, WallClock
from repro.obs import audit_recorder
from repro.obs.metrics import LatencyHistogram
from repro.serving import (DetectionEngine, EventBus, FaultSchedule,
                           JsonlSink, ServingRuntime,
                           ShardedDetectionEngine, make_nvr_streams,
                           topic_of)
from test_sharded_serving import assert_reports_identical

CHUNKS = (1, 3, 7, None)          # None = the whole trace in one chunk


def nvr_setup(n_streams=3, n_frames=10, rate=4.0):
    frames, frame_of, videos, dets = make_nvr_streams(
        n_streams, n_frames, rate)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    return sorted(frames, key=lambda f: f.t_arrival), oracle


def det_engine(oracle, **kw):
    return DetectionEngine(detect_fn=oracle, n_replicas=2,
                           service_time=0.3, track_and_interpolate=True,
                           **kw)


def feed_chunked(rt, frames, chunk):
    step = chunk or len(frames)
    for i in range(0, len(frames), step):
        rt.ingest(frames[i:i + step])
        rt.advance()              # watermark advance: nothing future


# ------------------------------------------- chunked == one-shot batch
@pytest.mark.parametrize("chunk", CHUNKS)
def test_chunked_ingest_matches_one_shot_detection(chunk):
    frames, oracle = nvr_setup()
    base = det_engine(oracle).serve(frames)
    rt = ServingRuntime(det_engine(oracle))
    feed_chunked(rt, frames, chunk)
    out = rt.drain()
    assert set(out) == set(base)
    assert_reports_identical(base, out)


@pytest.mark.parametrize("chunk", CHUNKS)
def test_chunked_ingest_matches_one_shot_sharded_faults(chunk):
    """The hard configuration: rebalancing epochs + seeded replica AND
    shard faults.  The pending-boundary restructure must reproduce the
    batch epoch loop's action sequence exactly."""
    frames, oracle = nvr_setup(n_streams=4, n_frames=12, rate=2.0)
    kw = dict(detect_fn=oracle, n_shards=2, n_replicas=2,
              service_time=0.3, track_and_interpolate=True,
              rebalance=True, epoch_s=2.0)

    def faults():
        return FaultSchedule.random(
            7, horizon_s=frames[-1].t_arrival, n_shards=2, n_replicas=2,
            n_replica_events=2, n_shard_events=1)

    base = ShardedDetectionEngine(faults=faults(), **kw).serve(frames)
    assert base["faults"]["frames_lost_shard"]   # the chaos actually bit
    rt = ServingRuntime(ShardedDetectionEngine(faults=faults(), **kw),
                        streams=range(4))
    feed_chunked(rt, frames, chunk)
    out = rt.drain()
    assert set(out) == set(base)
    assert_reports_identical(base, out)


@pytest.mark.parametrize("chunk", (1, 5))
def test_chunked_ingest_matches_one_shot_sharded_static(chunk):
    frames, oracle = nvr_setup(n_streams=4, n_frames=8, rate=2.0)
    kw = dict(detect_fn=oracle, n_shards=2, n_replicas=2,
              service_time=0.3, track_and_interpolate=True)
    base = ShardedDetectionEngine(**kw).serve(frames)
    rt = ServingRuntime(ShardedDetectionEngine(**kw), streams=range(4))
    feed_chunked(rt, frames, chunk)
    out = rt.drain()
    assert set(out) == set(base)
    assert_reports_identical(base, out)


# ------------------------------------------------- unified reset story
def test_unified_reset_back_to_back_detection():
    frames, oracle = nvr_setup()
    eng = det_engine(oracle)
    r1 = eng.serve(frames)
    r2 = eng.serve(frames)                 # serve() resets by default
    assert_reports_identical(r1, r2)
    eng.reset()                            # the documented explicit path
    r3 = eng.serve(frames, reset=False)
    assert_reports_identical(r1, r3)


def test_unified_reset_back_to_back_sharded():
    """``ShardedDetectionEngine.reset`` (new — the class had none) and
    ``ServingRuntime.reset`` both route through ``reset_engines`` and
    leave the engine exactly as serve()'s own reset would."""
    frames, oracle = nvr_setup(n_streams=4, n_frames=8, rate=2.0)
    seng = ShardedDetectionEngine(
        detect_fn=oracle, n_shards=2, n_replicas=2, service_time=0.3,
        track_and_interpolate=True, rebalance=True, epoch_s=2.0)
    r1 = seng.serve(frames)
    seng.reset()
    r2 = seng.serve(frames)
    assert_reports_identical(r1, r2)
    rt = ServingRuntime(seng, streams=range(4))
    rt.ingest(frames)
    out1 = rt.drain()
    rt.reset()                     # fresh watermark + segments + floors
    rt.ingest(frames)
    out2 = rt.drain()
    assert_reports_identical(out1, out2)
    assert_reports_identical(r1, out1)


# ------------------------------------------------ rolling epoch reports
def test_rolling_reports_merge_exactly_to_final():
    frames, oracle = nvr_setup(n_streams=3, n_frames=12, rate=4.0)
    rt = ServingRuntime(det_engine(oracle))
    step = len(frames) // 3
    epochs = []
    for i in range(0, len(frames), step):
        rt.ingest(frames[i:i + step])
        epochs.append(rt.epoch_boundary())
    assert len(rt.report(rolling=True)) == len(epochs)
    final = rt.drain()
    # every response lands in exactly one epoch window
    rids = sorted(r.rid for e in epochs for r in e["responses"])
    assert sorted(r.rid for r in final["responses"]) == rids
    assert sum(len(e["dropped"]) for e in epochs) == len(final["dropped"])
    # merge-never-average: histograms sum bucket-wise...
    merged = LatencyHistogram()
    for e in epochs:
        h = LatencyHistogram()
        h.counts = dict(e["latency_hist"]["counts"])
        h.n, h.max = e["latency_hist"]["n"], e["latency_hist"]["max"]
        merged.merge(h)
    assert final["latency_hist"]["counts"] == merged.counts
    assert final["latency_hist"]["n"] == merged.n
    # ...and quantiles recompute from the merged buckets
    assert final["p95_latency"] == merged.quantile(0.95)
    assert final["p99_latency"] == merged.quantile(0.99)
    # p50 is the exact median over the merged detections
    lat = [r.t_done - r.t_start for r in final["responses"]
           if not r.interpolated]
    assert final["p50_latency"] == pytest.approx(float(np.median(lat)))
    # per-stream frame totals conserve across the windows
    for sid in final["per_stream"]:
        assert final["per_stream"][sid]["frames"] == sum(
            e["per_stream"].get(sid, {"frames": 0})["frames"]
            for e in epochs)


def test_mid_serve_report_is_non_destructive():
    """A rolling peek must not perturb the final report: two identical
    runtimes, one peeked mid-serve, drain bit-identically."""
    frames, oracle = nvr_setup()
    ra = ServingRuntime(det_engine(oracle))
    rb = ServingRuntime(det_engine(oracle))
    half = len(frames) // 2
    for rt in (ra, rb):
        rt.ingest(frames[:half])
        rt.advance()
    peek = ra.report(rolling=False)
    assert peek["partial"] is True
    assert peek["responses"]             # something already completed
    for rt in (ra, rb):
        rt.ingest(frames[half:])
    assert_reports_identical(rb.drain(), ra.drain())


def test_sharded_rolling_rollups():
    frames, oracle = nvr_setup(n_streams=4, n_frames=12, rate=2.0)
    seng = ShardedDetectionEngine(
        detect_fn=oracle, n_shards=2, n_replicas=2, service_time=0.3,
        track_and_interpolate=True, rebalance=True, epoch_s=2.0)
    rt = ServingRuntime(seng, streams=range(4))
    feed_chunked(rt, frames, 3)
    final = rt.drain()
    per_epoch = rt.report(rolling=True)
    # the rolling rollups ARE the final report's per_epoch entries
    assert per_epoch == [final["per_epoch"][e]
                         for e in sorted(final["per_epoch"])]
    # fault-free + blocking mode: every frame ends up in some window
    assert sum(e["responses"] for e in per_epoch) == len(frames)
    assert sum(e["dropped"] for e in per_epoch) == 0


# ------------------------------------------------- contract violations
def test_watermark_violation_raises():
    frames, oracle = nvr_setup()
    rt = ServingRuntime(det_engine(oracle))
    rt.ingest(frames[5:])
    with pytest.raises(ValueError, match="watermark"):
        rt.ingest(frames[:5])


def test_incremental_sharded_requires_streams():
    frames, oracle = nvr_setup(n_streams=4, n_frames=6, rate=2.0)
    kw = dict(detect_fn=oracle, n_shards=2, n_replicas=2,
              service_time=0.3, track_and_interpolate=True)
    rt = ServingRuntime(ShardedDetectionEngine(**kw))   # no streams=
    rt.ingest(frames)
    with pytest.raises(RuntimeError, match="streams"):
        rt.epoch_boundary()
    base = ShardedDetectionEngine(**kw).serve(frames)
    out = rt.drain()                  # lazy batch replay is still exact
    assert_reports_identical(base, out)


def test_runtime_rejects_bad_engines_and_hooks():
    frames, oracle = nvr_setup(n_streams=2, n_frames=2, rate=2.0)
    seng = ShardedDetectionEngine(detect_fn=oracle, n_shards=2,
                                  n_replicas=2, service_time=0.3)
    with pytest.raises(ValueError, match="warm-start"):
        ServingRuntime(seng, stream_seq0={0: 1})
    with pytest.raises(TypeError):
        ServingRuntime(object())


# ------------------------------------------------------- event pipeline
def test_event_bus_taps_every_trace_event():
    frames, oracle = nvr_setup(n_streams=4, n_frames=8, rate=2.0)
    bus = EventBus()
    got = []
    h = bus.subscribe(lambda t, e: got.append((t, e["kind"])),
                      topics=("detection", "drop"))
    buf = io.StringIO()
    sink = JsonlSink(buf)
    bus.subscribe(sink)
    rec = bus.recorder()
    seng = ShardedDetectionEngine(
        detect_fn=oracle, n_shards=2, n_replicas=2, service_time=0.3,
        track_and_interpolate=True, recorder=rec)
    seng.serve(frames)
    # every recorded event was published exactly once (shard views
    # append to the parent log directly — the tap must cover them too)
    assert sum(bus.counts.values()) == len(rec.events) == sink.n_written
    assert any("shard" in e for e in rec.events)
    assert got and all(t in ("detection", "drop") for t, _ in got)
    lines = [json.loads(s) for s in buf.getvalue().splitlines()]
    assert len(lines) == len(rec.events)
    assert {ln["kind"] for ln in lines} == {e["kind"] for e in rec.events}
    assert all(ln["topic"] == topic_of(ln["kind"]) for ln in lines)
    assert audit_recorder(rec).ok     # the tapped log is still the log
    bus.unsubscribe(h)
    n = len(got)
    bus.publish({"kind": "complete", "t": 0.0})
    assert len(got) == n              # unsubscribed
    with pytest.raises(ValueError, match="unknown topics"):
        bus.subscribe(lambda *a: None, topics=("nope",))
    assert topic_of("some_future_kind") == "lifecycle"


# --------------------------------------------------------------- daemon
def test_daemon_virtual_clock_matches_batch_and_audits():
    frames, oracle = nvr_setup(n_streams=4, n_frames=8, rate=2.0)
    kw = dict(detect_fn=oracle, n_shards=2, n_replicas=2,
              service_time=0.3, track_and_interpolate=True)
    base = ShardedDetectionEngine(**kw).serve(frames)
    bus = EventBus()
    rec = bus.recorder()
    eng = ShardedDetectionEngine(recorder=rec, **kw)
    daemon = ServingDaemon(ServingRuntime(eng, streams=range(4)),
                           clock=VirtualClock(), chunk=3)
    out = daemon.run(frames)
    assert daemon.frames_ingested == len(frames)
    assert daemon.runtime.frames_pending == 0
    assert_reports_identical(base, out)
    res = audit_recorder(rec)         # frame conservation et al.
    assert res.ok, res.violations[:3]
    assert bus.counts.get("detection", 0) > 0


def test_daemon_graceful_stop_drains_ingested_frames():
    frames, oracle = nvr_setup(n_streams=3, n_frames=8, rate=4.0)
    rt = ServingRuntime(det_engine(oracle))
    daemon = ServingDaemon(rt, clock=VirtualClock(), chunk=2)

    def feed():
        for k, f in enumerate(frames):
            if k == 10:
                daemon.request_stop()
            yield f

    out = daemon.run(feed())
    n = daemon.frames_ingested
    assert 0 < n <= 10
    assert rt.frames_pending == 0     # in-flight frames were drained
    accounted = {r.rid for r in out["responses"]} | set(out["dropped"])
    assert accounted == {f.rid for f in frames[:n]}


def test_clocks():
    c = VirtualClock()
    assert c.now() == 0.0
    c.sleep_until(2.5)
    c.sleep_until(1.0)                # never goes backwards
    assert c.now() == 2.5
    w = WallClock()
    t0 = w.now()
    w.sleep_until(t0 - 1.0)           # already past: returns immediately
    assert w.now() >= t0
    with pytest.raises(ValueError):
        ServingDaemon(ServingRuntime(det_engine(nvr_setup()[1])),
                      chunk=0)


def test_daemon_cli_smoke(tmp_path, capsys):
    from repro.launch import daemon as dmod
    ev = tmp_path / "ev.jsonl"
    dmod.main(["--cameras", "3", "--frames", "6", "--shards", "2",
               "--clock", "virtual", "--events", str(ev), "--chunk", "2"])
    out = capsys.readouterr().out
    assert "audit=ok" in out and "pending=0" in out
    lines = [json.loads(s) for s in ev.read_text().splitlines()]
    assert lines and all("topic" in ln and "kind" in ln for ln in lines)
