"""Multi-camera (NVR) serving: interleaved-stream micro-batches keep
per-stream arrival order, the lockstep B>1 tracker is bit-identical to
B independent B=1 runs, per-stream accounting sums to the global
totals, and an 8-camera overloaded run keeps per-stream coverage 1.0
with one tracker launch per tick."""
import jax.numpy as jnp
import numpy as np

from repro.core import (SyntheticVideo, evaluate_streams,
                        proxy_detect_fn_streams)
from repro.core.stream import ETH_SUNNYDAY
from repro.serving import (DetectionEngine, FrameRequest,
                           make_nvr_streams)
from repro.tracking import TrackerConfig, coast, init_state, output, step

make_streams = make_nvr_streams     # shared workload builder (serving)


def engine_for(frames, frame_of, videos, dets, **kw):
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    return DetectionEngine(detect_fn=oracle, **kw)


# ----------------------------------------------- interleaved batching
def test_interleaved_micro_batches_keep_per_stream_order():
    """Frames from different cameras share micro-batches (at least one
    fused launch must mix streams), yet each camera's responses come
    back in that camera's arrival order with consecutive seq."""
    n_streams, n_frames = 3, 8
    frames, frame_of, videos, dets = make_streams(n_streams, n_frames,
                                                  rate=10.0)
    batch_streams = []
    orig = DetectionEngine._detect_batch

    def spy(self, images, rids=None):
        batch_streams.append({frame_of[r][0] for r in rids if r >= 0})
        return orig(self, images, rids)

    DetectionEngine._detect_batch = spy
    try:
        eng = engine_for(frames, frame_of, videos, dets, n_replicas=2,
                         service_time=0.5)
        out = eng.serve(frames)
    finally:
        DetectionEngine._detect_batch = orig
    assert any(len(s) > 1 for s in batch_streams)   # streams co-batched
    assert out["n_streams"] == n_streams
    assert len(out["responses"]) == n_streams * n_frames
    for s in range(n_streams):
        rs = out["streams"][s]
        assert [r.seq for r in rs] == list(range(n_frames))
        assert all(r.stream_id == s for r in rs)
        arrivals = [frame_of[r.rid][1] for r in rs]
        assert arrivals == sorted(arrivals)         # per-stream order
        emits = out["emit_t"][s]                    # per-camera release
        assert len(emits) == len(rs)                # clock: monotone,
        assert emits == sorted(emits)               # never decreasing


# -------------------------------------------------- lockstep tracker
def _rand_tick(rng, D, present_p=0.7):
    """One stream-tick of detections (or None for a drop)."""
    if rng.random() > present_p:
        return None
    n = int(rng.integers(1, D + 1))
    tl = rng.uniform(0, 300, (n, 2))
    wh = rng.uniform(15, 60, (n, 2))
    boxes = np.zeros((D, 4), np.float32)
    boxes[:n] = np.concatenate([tl, tl + wh], -1)
    scores = np.zeros(D, np.float32)
    scores[:n] = rng.uniform(0.5, 1.0, n)
    classes = np.zeros(D, np.int32)
    classes[:n] = rng.integers(0, 3, n)
    valid = np.zeros(D, bool)
    valid[:n] = True
    return boxes, scores, classes, valid


def test_lockstep_b_gt_1_matches_independent_b1_runs():
    """The acceptance bar for the batched NVR tracker: stepping B
    streams in lockstep — streams without a detection this tick ride
    the same launch with an all-invalid row — must be bit-for-bit
    identical to B independent B=1 step/coast runs."""
    cfg = TrackerConfig(capacity=12)
    B, D, n_ticks = 4, 6, 15
    rng = np.random.default_rng(7)
    seqs = [[_rand_tick(rng, D) for _ in range(n_ticks)]
            for _ in range(B)]

    # lockstep: one launch per tick over all B streams
    state = init_state(B, cfg)
    lock_tids = []
    for k in range(n_ticks):
        boxes = np.zeros((B, D, 4), np.float32)
        scores = np.zeros((B, D), np.float32)
        classes = np.zeros((B, D), np.int32)
        valid = np.zeros((B, D), bool)
        any_det = False
        for b in range(B):
            tick = seqs[b][k]
            if tick is not None:
                boxes[b], scores[b], classes[b], valid[b] = tick
                any_det = True
        if any_det:
            state, tid = step(state, jnp.asarray(boxes),
                              jnp.asarray(scores), jnp.asarray(classes),
                              jnp.asarray(valid), cfg)
            lock_tids.append(np.asarray(tid))
        else:
            state = coast(state, cfg)
            lock_tids.append(np.full((B, D), -1, np.int32))
    lock_out = [np.asarray(a) for a in output(state, cfg)]

    # B independent single-stream runs
    for b in range(B):
        st = init_state(1, cfg)
        for k in range(n_ticks):
            tick = seqs[b][k]
            if tick is None:
                st = coast(st, cfg)
                tid = np.full((1, D), -1, np.int32)
            else:
                st, tid = step(st, *(jnp.asarray(a[None])
                                     for a in tick), cfg)
            assert np.array_equal(np.asarray(tid)[0], lock_tids[k][b]), \
                (b, k)
        for name in st._fields:
            lv = np.asarray(getattr(state, name))[b]
            iv = np.asarray(getattr(st, name))[0]
            assert np.array_equal(lv, iv), (b, name)
        ind_out = [np.asarray(a) for a in output(st, cfg)]
        for lo, io in zip(lock_out, ind_out):
            assert np.array_equal(lo[b], io[0]), b


# ------------------------------------------------ per-stream accounting
def test_per_stream_accounting_sums_to_global():
    """Drop-mode NVR run: per-stream frames/drops/responses must sum to
    the global report's totals, and per-stream coverage must match each
    camera's own ratio."""
    n_streams, n_frames = 4, 20
    frames, frame_of, videos, dets = make_streams(n_streams, n_frames,
                                                  rate=5.0)
    eng = engine_for(frames, frame_of, videos, dets, n_replicas=1,
                     service_time=0.4, drop_when_busy=True)
    out = eng.serve(frames)
    ps = out["per_stream"]
    assert set(ps) == set(range(n_streams))
    assert sum(v["frames"] for v in ps.values()) == len(frames)
    assert sum(v["dropped"] for v in ps.values()) == len(out["dropped"])
    assert len(out["dropped"]) > 0                  # 4x overload drops
    n_resp = sum(len(out["streams"][s]) for s in ps)
    assert n_resp == len(out["responses"])
    for s, v in ps.items():
        assert v["coverage"] == len(out["streams"][s]) / v["frames"]
    global_cov = len(out["responses"]) / len(frames)
    assert out["coverage"] == global_cov


def test_eight_camera_tracked_run_full_coverage_one_launch_per_tick():
    """The PR acceptance row: an 8-camera overloaded run under
    track_and_interpolate completes with per-stream coverage 1.0,
    exactly one tracker launch per tick, per-stream arrival order, and
    a per-stream mAP win over the drop-frames baseline."""
    n_streams, n_frames = 8, 24
    frames, frame_of, videos, dets = make_streams(n_streams, n_frames,
                                                  rate=2.0)

    def run(**kw):
        eng = engine_for(frames, frame_of, videos, dets, n_replicas=2,
                         service_time=0.4, **kw)
        return eng.serve(frames)

    out_d = run(drop_when_busy=True)
    out_t = run(track_and_interpolate=True)
    assert out_t["coverage"] == 1.0
    assert out_t["n_streams"] == n_streams
    assert out_t["tracker_ticks"] == n_frames
    assert out_t["tracker_launches"] == n_frames    # one launch per tick
    for s in range(n_streams):
        v = out_t["per_stream"][s]
        assert v["coverage"] == 1.0
        assert v["frames"] == n_frames
        assert [r.seq for r in out_t["streams"][s]] == list(range(n_frames))
    # interpolated frames: tracker-tagged, replica -1, tracked ids
    n_interp = sum(r.interpolated for r in out_t["responses"])
    assert n_interp == out_t["interpolated"] == len(out_d["dropped"]) > 0
    for r in out_t["responses"]:
        if r.interpolated:
            assert r.replica == -1 and r.track_ids is not None
    # per-stream quality: shared compute, per-camera accuracy accounting
    q_t = evaluate_streams(videos, out_t["streams"], n_frames)
    q_d = evaluate_streams(videos, out_d["streams"], n_frames)
    assert set(q_t["per_stream"]) == set(range(n_streams))
    assert q_t["map_mean"] > q_d["map_mean"]
    assert q_t["coverage_mean"] > 0.7


def test_single_stream_results_invariant_to_stream_relabeling():
    """A lone camera must get bit-identical boxes whether it is called
    stream 0 (the implicit single-stream default) or stream 42."""
    n_frames = 16
    video = SyntheticVideo(ETH_SUNNYDAY)

    def run(sid):
        frames, frame_of, videos, dets = make_streams(1, n_frames,
                                                      rate=5.0, video=video)
        frames = [FrameRequest(f.rid, f.image, f.t_arrival, sid)
                  for f in frames]
        frame_of = {rid: (sid, k) for rid, (_, k) in frame_of.items()}
        eng = engine_for(frames, frame_of, {sid: video},
                         {sid: dets[0]}, n_replicas=1,
                         service_time=0.4, track_and_interpolate=True)
        return eng.serve(frames)

    a, b = run(0), run(42)
    assert a["coverage"] == b["coverage"] == 1.0
    assert list(a["per_stream"]) == [0] and list(b["per_stream"]) == [42]
    for ra, rb in zip(a["responses"], b["responses"]):
        assert ra.interpolated == rb.interpolated
        assert np.array_equal(ra.boxes, rb.boxes)
        assert np.array_equal(ra.valid, rb.valid)
        assert np.array_equal(np.asarray(ra.track_ids),
                              np.asarray(rb.track_ids))
