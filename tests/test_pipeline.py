"""Unit tests for the shared tick pipeline (``repro.serving.pipeline``).

Covers the stage-pipeline refactor's kernel-level contracts — the
engine-level (report) equivalences live in
``tests/test_serving_properties.py``:

* the chunking helpers the engines now share are equivalent to the
  historical per-engine copies (delegation, not drift);
* portable track rows round trip bit-identically (export -> rebuild,
  any subset/reordering), and an all-fresh rebuild == ``init_state``;
* the fused one-jit tick program is bit-identical to the staged
  ``step``/``output`` chain, tick by tick, on every ``TrackerState``
  field, the per-detection track-id assignment and the output tuple;
* a fused tick over an all-invalid detection row is bit-identical to
  ``coast`` (the invariant that lets fused mode run ONE program);
* a ``fused_window`` scan (one launch per K-tick window) matches the
  staged chain tick by tick — stacked det_tid, stacked outputs, final
  table — including a detection-free tick mid-window;
* the post-processor hook composes: identity hook changes nothing,
  a mutating hook's output reaches the report.
"""
import numpy as np
import pytest

import repro.tracking as trk
from repro.core import proxy_detect_fn_streams
from repro.serving import (DetectionEngine, TickPipeline, TickState,
                           make_nvr_streams)
from repro.serving.pipeline import (bucket, build_tracker_state,
                                    confirmed_ids, export_track_rows,
                                    sorted_chunk)
from repro.tracking import TrackerConfig

CFG = TrackerConfig(capacity=16)


def random_dets(rng, B, D):
    tl = rng.uniform(0, 400, (B, D, 2)).astype(np.float32)
    wh = rng.uniform(10, 60, (B, D, 2)).astype(np.float32)
    return (np.concatenate([tl, tl + wh], -1),
            rng.uniform(0.5, 1.0, (B, D)).astype(np.float32),
            rng.integers(0, 3, (B, D)).astype(np.int32),
            rng.random((B, D)) > 0.2)


def assert_states_equal(a, b):
    for f in type(a)._fields:
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f


# ------------------------------------------------------ chunking helpers
def test_bucket_matches_engine_delegate():
    for k in range(1, 40):
        assert bucket(k) == DetectionEngine._bucket(k)
        assert bucket(k) >= k and bucket(k) & (bucket(k) - 1) == 0


def test_sorted_chunk_single_and_stable():
    frames, _, _, _ = make_nvr_streams(2, 4, 5.0)
    one = sorted_chunk(frames[0])
    assert one == [frames[0]]
    shuffled = [frames[2], frames[0], frames[3], frames[1]]
    out = sorted_chunk(shuffled)
    assert [f.t_arrival for f in out] == sorted(f.t_arrival
                                                for f in frames[:4])
    # stable under arrival ties: equal keys keep input order
    frames[1].t_arrival = frames[0].t_arrival
    tied = sorted_chunk([frames[1], frames[0]])
    assert [f.rid for f in tied] == [frames[1].rid, frames[0].rid]


# --------------------------------------------------- portable track rows
def seeded_state(seed=0, B=3, D=5, ticks=4):
    rng = np.random.default_rng(seed)
    state = trk.init_state(B, CFG)
    for _ in range(ticks):
        state, _ = trk.step(state, *random_dets(rng, B, D), CFG)
    return state


def test_track_rows_round_trip_bit_identical():
    state = seeded_state()
    rows = trk.export_rows(state)
    assert_states_equal(trk.rows_to_state(rows, CFG), state)
    # keyed by stream id + rebuilt in a different order/subset
    sids = [7, 3, 9]
    by_sid = export_track_rows(state, sids)
    sub = build_tracker_state(by_sid, [9, 7], CFG)
    assert np.array_equal(np.asarray(sub.track_id[0]),
                          np.asarray(state.track_id[2]))
    assert np.array_equal(np.asarray(sub.track_id[1]),
                          np.asarray(state.track_id[0]))


def test_track_rows_fresh_equals_init_state():
    ref = trk.init_state(3, CFG)
    assert_states_equal(trk.rows_to_state([None] * 3, CFG), ref)
    assert_states_equal(build_tracker_state(None, [1, 2, 3], CFG), ref)
    assert_states_equal(build_tracker_state({}, [1, 2, 3], CFG), ref)
    # partial seed: carried row lands in ITS batch slot, others fresh
    state = seeded_state()
    rows = export_track_rows(state, [5, 6, 7])
    mixed = build_tracker_state({6: rows[6]}, [5, 6], CFG)
    assert np.array_equal(np.asarray(mixed.track_id[1]),
                          np.asarray(state.track_id[1]))
    assert np.array_equal(np.asarray(mixed.track_id[0]),
                          np.asarray(ref.track_id[0]))


def test_confirmed_ids_reads_the_emit_mask():
    state = seeded_state()
    rows = trk.export_rows(state)
    for b, row in enumerate(rows):
        emit = np.asarray(state.active[b]) & (
            np.asarray(state.hits[b]) >= CFG.min_hits)
        assert confirmed_ids(row, CFG) == sorted(
            int(t) for t in np.asarray(state.track_id[b])[emit])


# ------------------------------------------------------- fused tick program
@pytest.mark.parametrize("B,D", [(1, 4), (3, 5)])
def test_fused_tick_bit_identical_to_staged_chain(B, D):
    rng = np.random.default_rng(42)
    staged = TickPipeline(CFG)
    fused = TickPipeline(CFG, fused=True)
    s1 = staged.seed(list(range(B)))
    s2 = fused.seed(list(range(B)))
    for k in range(8):
        dets = random_dets(rng, B, D)
        if k == 5:            # a detection-free tick mid-sequence
            s1, o1 = staged.coast(s1, det_width=D)
            s2, o2 = fused.coast(s2, det_width=D)
            assert o1 is None and o2 is not None
        else:
            s1, tid1, o1 = staged.tick(s1, *dets)
            s2, tid2, o2 = fused.tick(s2, *dets)
            assert np.array_equal(tid1, tid2), k
            assert o1 is None and o2 is not None
        assert_states_equal(s1, s2)
        for a, b in zip(staged.output(s1), o2):
            assert np.array_equal(np.asarray(a), np.asarray(b)), k
    assert staged.launches == fused.launches == 8
    assert export_track_rows(s1, range(B)).keys() \
        == export_track_rows(s2, range(B)).keys()


def test_fused_window_bit_identical_to_staged_chain():
    from repro.serving.pipeline import fused_window
    rng = np.random.default_rng(7)
    B, D, K = 2, 5, 6
    ticks = [random_dets(rng, B, D) for _ in range(K)]
    ticks[3] = (np.zeros((B, D, 4), np.float32),
                np.zeros((B, D), np.float32),
                np.zeros((B, D), np.int32),
                np.zeros((B, D), bool))      # a detection-free tick
    s1 = trk.init_state(B, CFG)
    tids, outs = [], []
    for t in ticks:
        s1, tid = trk.step(s1, *t, CFG)
        tids.append(np.asarray(tid))
        outs.append([np.asarray(a) for a in trk.output(s1, CFG)])
    stacked = tuple(np.stack([t[i] for t in ticks]) for i in range(4))
    s2, wtid, wout = fused_window(trk.init_state(B, CFG), *stacked, CFG)
    assert_states_equal(s1, s2)
    for k in range(K):
        assert np.array_equal(np.asarray(wtid)[k], tids[k]), k
        for i, a in enumerate(wout):
            assert np.array_equal(np.asarray(a)[k], outs[k][i]), (k, i)


def test_fused_all_invalid_row_equals_coast():
    rng = np.random.default_rng(3)
    B, D = 2, 6
    pipe = TickPipeline(CFG, fused=True)
    state = pipe.seed([0, 1])
    for _ in range(3):
        state, _, _ = pipe.tick(state, *random_dets(rng, B, D))
    ref = trk.coast(trk.rows_to_state(trk.export_rows(state), CFG), CFG)
    state, out = pipe.coast(state, det_width=D)
    assert_states_equal(state, ref)
    for a, b in zip(out, trk.output(ref, CFG)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------ post-processor hook
def serve_nvr(post_process=None, seed=0):
    frames, frame_of, videos, dets = make_nvr_streams(2, 8, 4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    eng = DetectionEngine(detect_fn=oracle, n_replicas=2,
                          service_time=0.3, track_and_interpolate=True,
                          post_process=post_process)
    return eng.serve(frames)


def test_post_process_identity_hook_is_inert():
    from test_sharded_serving import assert_reports_identical
    assert_reports_identical(serve_nvr(), serve_nvr(lambda t: t))


def test_post_process_stage_rewrites_detections():
    thr = 0.9

    def gate(tick: TickState) -> TickState:
        keep = tick.valid & (np.asarray(tick.scores) >= thr)
        return tick._replace(valid=keep)

    out = serve_nvr(gate)
    for r in out["responses"]:
        if not r.interpolated:
            v = np.asarray(r.valid, bool)
            assert np.all(np.asarray(r.scores)[v] >= thr)
