"""Sharded multi-host NVR serving: the camera partition is
deterministic and balanced, a single-shard engine is bit-identical to
``DetectionEngine`` on the same trace, multi-shard reports merge back
to the global accounting, the SPMD mesh detect program matches the
plain jitted path bit-for-bit, and a forced-multi-device mesh run
(subprocess, ``xla_force_host_platform_device_count``) keeps full
per-stream coverage."""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import proxy_detect_fn_streams
from repro.serving import (DetectionEngine, FrameRequest,
                           ShardedDetectionEngine, make_nvr_streams,
                           make_spmd_detect, merge_shard_reports)
from repro.sharding import shard_streams, streams_of_shard

REPO = Path(__file__).resolve().parents[1]


def sharded_for(frames, frame_of, videos, dets, **kw):
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    return ShardedDetectionEngine(detect_fn=oracle, **kw)


# --------------------------------------------------- camera partition
def test_shard_streams_deterministic_and_balanced():
    sids = [9, 3, 5, 0, 7, 1, 4]
    for n in (1, 2, 3, 7, 12):
        part = shard_streams(sids, n)
        assert part == shard_streams(reversed(sids), n)   # order-free
        assert set(part) == set(sids)
        loads = [len(streams_of_shard(part, h)) for h in range(n)]
        assert max(loads) - min(loads) <= 1               # balanced
        assert sum(loads) == len(sids)
    with pytest.raises(ValueError):
        shard_streams(sids, 0)


# ------------------------------------------- single-shard regression
def assert_reports_identical(base, sharded):
    """Every DetectionEngine report key must match bit-for-bit; the
    sharded layer may only ADD keys."""
    assert set(base).issubset(set(sharded))
    for k, bv in base.items():
        sv = sharded[k]
        if k == "responses":
            assert len(bv) == len(sv)
            for ra, rb in zip(bv, sv):
                for f in ("rid", "replica", "t_start", "t_done",
                          "service_s", "interpolated", "stream_id",
                          "seq"):
                    assert getattr(ra, f) == getattr(rb, f), (ra.rid, f)
                for f in ("boxes", "scores", "classes", "valid"):
                    assert np.array_equal(getattr(ra, f),
                                          getattr(rb, f)), (ra.rid, f)
                ta, tb = ra.track_ids, rb.track_ids
                assert (ta is None) == (tb is None)
                if ta is not None:
                    assert np.array_equal(np.asarray(ta), np.asarray(tb))
        elif k == "streams":
            assert bv.keys() == sv.keys()
            for sid in bv:
                assert [r.rid for r in bv[sid]] == [r.rid
                                                    for r in sv[sid]]
        else:
            assert bv == sv, k


@pytest.mark.parametrize("mode", ["drop", "track"])
def test_single_shard_bit_identical_to_detection_engine(mode):
    """The PR acceptance bar: shards=1 on the oracle path produces a
    bit-identical report to ``DetectionEngine`` on the same request
    trace, in both drop and track-and-interpolate modes."""
    frames, frame_of, videos, dets = make_nvr_streams(3, 16, rate=2.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(n_replicas=2, service_time=0.4,
              **({"drop_when_busy": True} if mode == "drop"
                 else {"track_and_interpolate": True}))
    base = DetectionEngine(detect_fn=oracle, **kw).serve(frames)
    sh = ShardedDetectionEngine(n_shards=1, detect_fn=oracle,
                                **kw).serve(frames)
    assert_reports_identical(base, sh)
    assert sh["n_shards"] == 1
    assert sh["shard_of_stream"] == {0: 0, 1: 0, 2: 0}


# ------------------------------------------------- multi-shard merge
def test_multi_shard_partition_covers_every_frame_and_stream():
    """3 shards x 5 cameras: every camera lands on exactly one shard,
    per-stream accounting survives the merge, the tracked run keeps
    coverage 1.0, and replica ids are renumbered globally."""
    n_streams, n_frames, n_shards = 5, 12, 3
    frames, frame_of, videos, dets = make_nvr_streams(n_streams,
                                                      n_frames, rate=4.0)
    eng = sharded_for(frames, frame_of, videos, dets, n_shards=n_shards,
                      n_replicas=2, service_time=0.4,
                      track_and_interpolate=True)
    out = eng.serve(frames)
    assert out["n_shards"] == n_shards
    assert out["n_streams"] == n_streams
    # partition: disjoint, complete, matches the report's own map
    seen = [s for shard in out["per_shard"] for s in shard["streams"]]
    assert sorted(seen) == list(range(n_streams))
    for sid, h in out["shard_of_stream"].items():
        assert sid in out["per_shard"][h]["streams"]
    # every frame answered, in rid order, with per-stream seq intact
    assert out["coverage"] == 1.0
    assert [r.rid for r in out["responses"]] == sorted(
        r.rid for r in out["responses"])
    assert len(out["responses"]) == len(frames)
    for sid in range(n_streams):
        assert [r.seq for r in out["streams"][sid]] == list(range(n_frames))
        assert out["per_stream"][sid]["coverage"] == 1.0
        emits = out["emit_t"][sid]
        assert emits == sorted(emits)
    # per-shard totals sum to the global ones
    assert sum(s["frames"] for s in out["per_shard"]) == len(frames)
    assert sum(s["responses"] for s in out["per_shard"]) == len(frames)
    assert sum(s["tracker_launches"] for s in out["per_shard"]) \
        == out["tracker_launches"]
    # replica ids renumbered per shard pool: 3 shards x 2 replicas,
    # on the per_replica map AND on every response (so grouping
    # responses by replica stays consistent with the map)
    assert set(out["per_replica"]) == set(range(6))
    for r in out["responses"]:
        if r.interpolated:
            assert r.replica == -1
        else:
            h = out["shard_of_stream"][r.stream_id]
            assert 2 * h <= r.replica < 2 * (h + 1), (r.rid, r.replica)


def test_multi_shard_drop_accounting_merges_in_arrival_order():
    """Overloaded drop-mode run: merged ``dropped`` rids come back in
    global arrival order and per-stream drops sum to the global list."""
    frames, frame_of, videos, dets = make_nvr_streams(4, 20, rate=5.0)
    eng = sharded_for(frames, frame_of, videos, dets, n_shards=2,
                      n_replicas=1, service_time=0.4,
                      drop_when_busy=True)
    out = eng.serve(frames)
    assert len(out["dropped"]) > 0                   # 4x overload drops
    pos = {f.rid: i for i, f in
           enumerate(sorted(frames, key=lambda f: f.t_arrival))}
    order = [pos[r] for r in out["dropped"]]
    assert order == sorted(order)
    assert sum(v["dropped"] for v in out["per_stream"].values()) \
        == len(out["dropped"])
    assert out["coverage"] == len(out["responses"]) / len(frames)


def test_sharded_engine_empty_trace():
    """serve([]) mirrors DetectionEngine's empty report across shards."""
    frames, frame_of, videos, dets = make_nvr_streams(1, 1, rate=1.0)
    eng = sharded_for(frames, frame_of, videos, dets, n_shards=2,
                      n_replicas=1, service_time=0.1)
    out = eng.serve([])
    assert out["responses"] == [] and out["dropped"] == []
    assert out["coverage"] == 0.0 and out["n_streams"] == 0
    assert set(out["per_replica"]) == {0, 1}


def test_mesh_and_detect_fn_are_mutually_exclusive():
    with pytest.raises(ValueError):
        ShardedDetectionEngine(mesh=object(), detect_fn=lambda i, r: None)


# ------------------------------------------------------ SPMD detect
def test_spmd_detect_bit_identical_to_plain_jit_path():
    """``make_spmd_detect`` on a host mesh must return bit-identical
    detections to ``DetectionEngine``'s own jitted mini-SSD program —
    the sharding constraints change placement, never values."""
    from repro.launch.mesh import make_serving_mesh
    mesh = make_serving_mesh(1)
    rng = np.random.default_rng(3)
    frames = [FrameRequest(i, rng.random((64, 64, 3)).astype(np.float32),
                           i / 20.0, stream_id=i % 2) for i in range(8)]
    kw = dict(n_replicas=2, service_time=0.05, seed=0)
    sh = ShardedDetectionEngine(n_shards=1, mesh=mesh, **kw).serve(frames)
    base = DetectionEngine(**kw).serve(frames)
    assert_reports_identical(base, sh)


def test_multi_device_mesh_subprocess():
    """End-to-end on a REAL 4-device mesh (forced host devices in a
    subprocess — the parent jax is already initialized single-device):
    4 shards serve 4 cameras through one SPMD detect+NMS program with
    full coverage, and fresh-frame outputs match the meshless engine."""
    code = """
import numpy as np, jax
assert len(jax.devices()) == 4, jax.devices()
from repro.launch.mesh import make_serving_mesh
from repro.serving import DetectionEngine, FrameRequest, \
    ShardedDetectionEngine
rng = np.random.default_rng(0)
frames = [FrameRequest(i, rng.random((64, 64, 3)).astype(np.float32),
                       i / 40.0, stream_id=i % 4) for i in range(24)]
mesh = make_serving_mesh(4)
out = ShardedDetectionEngine(n_shards=4, mesh=mesh, n_replicas=1,
                             service_time=0.05, seed=0,
                             track_and_interpolate=True).serve(frames)
assert out["n_shards"] == 4
assert out["coverage"] == 1.0
assert [s["streams"] for s in out["per_shard"]] == [[0], [1], [2], [3]]
base = DetectionEngine(n_replicas=1, service_time=0.05, seed=0,
                       track_and_interpolate=True).serve(frames)
for ra, rb in zip(out["responses"], base["responses"]):
    if not (ra.interpolated or rb.interpolated):
        assert np.array_equal(ra.boxes, rb.boxes), ra.rid
print("MESH-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "MESH-OK" in r.stdout


# --------------------------------------------------- merge invariants
def test_merge_shard_reports_recomputes_global_scalars():
    """The merged scalars must follow DetectionEngine's own formulas
    over the union of responses, not an average of shard scalars."""
    frames, frame_of, videos, dets = make_nvr_streams(4, 10, rate=3.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    part = shard_streams(range(4), 2)
    subs = [[f for f in frames if part[f.stream_id] == h]
            for h in range(2)]
    engines = [DetectionEngine(detect_fn=oracle, n_replicas=1,
                               service_time=0.2, drop_when_busy=True)
               for _ in range(2)]
    reports = [e.serve(s) for e, s in zip(engines, subs)]
    merged = merge_shard_reports(frames, reports, [1, 1])
    assert merged["coverage"] == len(merged["responses"]) / len(frames)
    makespan = max(r.t_done for r in merged["responses"])
    assert merged["throughput_fps"] == \
        len(merged["responses"]) / max(makespan, 1e-9)
    assert merged["interpolated"] == sum(r["interpolated"]
                                         for r in reports)
    assert set(merged["per_replica"]) == {0, 1}
    # merging must not mutate the caller's shard reports (replica ids
    # are renumbered on copies), so merging twice is identical
    assert all(r.replica in (-1, 0) for rep in reports
               for r in rep["responses"])
    again = merge_shard_reports(frames, reports, [1, 1])
    assert [r.replica for r in again["responses"]] == \
        [r.replica for r in merged["responses"]]
    # the merged streams hold the SAME objects as merged responses
    # (the DetectionEngine contract), not the originals
    by_rid = {r.rid: r for r in merged["responses"]}
    for sid, rs in merged["streams"].items():
        assert all(r is by_rid[r.rid] for r in rs)
