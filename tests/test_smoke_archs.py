"""Per-architecture smoke tests: a REDUCED same-family variant (2 layers,
d_model<=512, <=4 experts) runs one forward/train step on CPU; output
shapes asserted, no NaNs.  Decode paths smoke-tested for non-encoder archs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import InputShape
from repro.models import init_cache, init_model, model_apply
from repro.models.layers import pad_vocab
from repro.optim import AdamWConfig, make_schedule
from repro.runtime import concrete_batch, make_train_step, train_state_init
from repro.runtime.steps import make_decode_step, make_prefill_step

TRAIN_SHAPE = InputShape("smoke_train", 64, 2, "train")
PREFILL_SHAPE = InputShape("smoke_prefill", 64, 2, "prefill")
DECODE_SHAPE = InputShape("smoke_decode", 128, 2, "decode")


def _smoke(arch):
    cfg = get_config(arch, preset="smoke")
    assert cfg.n_layers <= 2 or arch == "jamba-v0.1-52b"
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    return cfg


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = _smoke(arch)
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, TRAIN_SHAPE, seed=1)
    logits, _, aux = model_apply(params, cfg, batch, mode="train")
    B, S = TRAIN_SHAPE.global_batch, TRAIN_SHAPE.seq_len
    assert logits.shape == (B, S, pad_vocab(cfg.vocab_size))
    finite = logits[..., :cfg.vocab_size]
    assert bool(jnp.all(jnp.isfinite(finite))), "NaN/inf in logits"
    assert np.isfinite(float(aux["aux_loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = _smoke(arch)
    opt_cfg = AdamWConfig(peak_lr=1e-3)
    sched = make_schedule("cosine", 1e-3, 100, warmup_steps=5)
    state = train_state_init(cfg, jax.random.PRNGKey(0), opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, sched, remat=True))
    batch = concrete_batch(cfg, TRAIN_SHAPE, seed=2)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["total_loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    leaf = jax.tree.leaves(state["params"])[0]
    assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "hubert-xlarge"])
def test_prefill_then_decode(arch):
    cfg = _smoke(arch)
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    batch = concrete_batch(cfg, InputShape("p", S, B, "prefill"), seed=3)
    prefill = jax.jit(make_prefill_step(cfg, cache_len=128))
    logits, cache = prefill(params, batch)
    assert logits.shape == (B, pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits)))

    decode = jax.jit(make_decode_step(cfg))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step_in = {"tokens": tok, "cache": cache,
               "decode_pos": jnp.asarray(S, jnp.int32)}
    logits2, cache2 = decode(params, step_in)
    assert logits2.shape == (B, pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits2)))
    # a second decode step reuses the updated cache
    step_in = {"tokens": tok, "cache": cache2,
               "decode_pos": jnp.asarray(S + 1, jnp.int32)}
    logits3, _ = decode(params, step_in)
    assert bool(jnp.all(jnp.isfinite(logits3)))
