"""Substrate coverage: optimizer/schedules, data pipeline, mini-SSD
detector, RoPE variants, interface/energy models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.optim import AdamWConfig, adamw_init, adamw_update, make_schedule


# ------------------------------------------------------------- schedules
def test_wsd_schedule_shape():
    sched = make_schedule("wsd", 1.0, 1000, warmup_steps=100,
                          decay_frac=0.2, final_frac=0.1)
    assert float(sched(0)) == 0.0
    assert float(sched(50)) == pytest.approx(0.5)
    assert float(sched(100)) == pytest.approx(1.0)
    assert float(sched(700)) == pytest.approx(1.0)      # stable plateau
    assert float(sched(999)) == pytest.approx(0.1, rel=0.05)  # decay tail
    mid_decay = float(sched(900))
    assert 0.1 < mid_decay < 1.0


def test_cosine_schedule_monotone_after_warmup():
    sched = make_schedule("cosine", 1.0, 100, warmup_steps=10)
    vals = [float(sched(s)) for s in range(10, 100, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(peak_lr=0.1, weight_decay=0.0)
    params = {"x": jnp.array([3.0, -2.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state, _ = adamw_update(params, grads, state, cfg, 0.1)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_adamw_grad_clipping():
    cfg = AdamWConfig(peak_lr=0.1, grad_clip=1.0)
    params = {"x": jnp.zeros(4)}
    state = adamw_init(params, cfg)
    _, _, gnorm = adamw_update(params, {"x": jnp.full(4, 100.0)}, state,
                               cfg, 0.1)
    assert float(gnorm) == pytest.approx(200.0)


# ---------------------------------------------------------- data pipeline
def test_lm_pipeline_is_learnable():
    """The corpus is order-2 Markov: a trigram predictor beats chance."""
    from repro.data.pipeline import synthetic_corpus
    c = synthetic_corpus(256, 20000, seed=0)
    assert c.min() >= 0 and c.max() < 256
    from collections import Counter, defaultdict
    nxt = defaultdict(Counter)
    for a, b, d in zip(c[:-2], c[1:-1], c[2:]):
        nxt[(a, b)][d] += 1
    correct = sum(m.most_common(1)[0][1] for m in nxt.values())
    assert correct / (len(c) - 2) > 0.5     # >> uniform chance


def test_lm_batches_shapes():
    from repro.configs import get_config
    from repro.data import LMBatchIterator
    cfg = get_config("qwen3-4b", "smoke")
    it = iter(LMBatchIterator(cfg, 4, 32))
    b = next(it)
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    # labels are next-token shifted
    assert int(jnp.sum(b["tokens"][:, 1:] != b["labels"][:, :-1])) == 0


def test_modality_batches():
    from repro.configs import get_config
    from repro.data import make_modality_batch
    audio = get_config("hubert-xlarge", "smoke")
    b = make_modality_batch(audio, 2, 32)
    assert b["features"].shape == (2, 32, audio.frontend_dim)
    assert 0.1 < float(b["loss_mask"].mean()) < 0.6     # masked prediction
    vlm = get_config("pixtral-12b", "smoke")
    b = make_modality_batch(vlm, 2, 32)
    n_img = b["image_embeds"].shape[1]
    assert b["tokens"].shape[1] + n_img == 32
    assert float(b["loss_mask"][:, :n_img].sum()) == 0.0  # no loss on image


# ------------------------------------------------------------- detector
def test_ssd_detector_learns_and_decodes():
    from repro.core import SyntheticVideo
    from repro.core.stream import ETH_SUNNYDAY
    from repro.detector import (SSDConfig, decode_detections, detector_loss,
                                init_ssd, make_anchors)
    cfg = SSDConfig()
    anchors = make_anchors(cfg)
    assert anchors.shape[1] == 4 and len(anchors) == (8 * 8 + 4 * 4) * 2
    video = SyntheticVideo(ETH_SUNNYDAY)
    params = init_ssd(cfg, jax.random.PRNGKey(0))
    spec = video.spec

    def batch(i):
        imgs = np.stack([video.pixels(j, 64) for j in (i, i + 1)])
        boxes = np.stack([video.boxes_at(j) for j in (i, i + 1)])
        boxes = boxes / np.array([spec.width, spec.height] * 2)
        cls = np.tile(video.classes[None], (2, 1))
        return (jnp.asarray(imgs), jnp.asarray(boxes, jnp.float32),
                jnp.asarray(cls, jnp.int32),
                jnp.ones((2, spec.n_objects), jnp.float32))

    @jax.jit
    def step(p, *b):
        (l, _), g = jax.value_and_grad(
            lambda pp: detector_loss(pp, cfg, *b, anchors),
            has_aux=True)(p)
        return jax.tree.map(lambda x, gg: x - 5e-3 * gg, p, g), l

    losses = []
    for i in range(60):
        params, loss = step(params, *batch(i % 100))
        losses.append(float(loss))
    assert min(losses[-10:]) < 0.7 * losses[0], losses[::10]

    boxes, scores, classes, valid = decode_detections(
        params, cfg, jnp.asarray(video.pixels(0, 64)[None]), anchors,
        score_thr=0.1)
    assert boxes.shape[-1] == 4 and valid.dtype == bool


# ------------------------------------------------------------------ rope
def test_glm_rope_rotates_only_first_half():
    from repro.models.rope import apply_rope
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 64))
    pos = jnp.arange(4)[None]
    y = apply_rope(x, pos, 1e4, "glm")
    # second half of head_dim passes through untouched
    assert_allclose(np.asarray(y[..., 32:]), np.asarray(x[..., 32:]))
    assert float(jnp.max(jnp.abs(y[..., :32] - x[..., :32]))) > 1e-3


# ------------------------------------------------- interface/energy models
def test_usb2_goodput_predicts_paper_saturation():
    from repro.core.executor import (DEVICE_PROFILES, INTERFACE_GOODPUT,
                                     MODEL_PROFILES, DetectorExecutor)
    yolo = MODEL_PROFILES["yolov3"]
    cap = INTERFACE_GOODPUT["usb2"] / yolo.frame_bytes
    assert 7.5 <= cap <= 8.7                # paper: saturates at 8.1 FPS
    ex2 = DetectorExecutor(DEVICE_PROFILES["ncs2"], yolo, interface="usb2")
    ex3 = DetectorExecutor(DEVICE_PROFILES["ncs2"], yolo, interface="usb3")
    assert ex2.mu_effective == pytest.approx(1.9, rel=0.05)   # paper 1.9
    assert ex3.mu_effective == pytest.approx(2.44, rel=0.05)


def test_energy_ranking_matches_table_vi():
    from repro.core.executor import DEVICE_PROFILES
    eff = {n: d.mu("yolov3") / d.tdp_watts
           for n, d in DEVICE_PROFILES.items()}
    order = sorted(eff, key=eff.get, reverse=True)
    assert order == ["ncs2", "gpu_titanx", "fast_cpu", "slow_cpu"]
