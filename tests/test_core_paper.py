"""Behaviour tests for the paper's core system: λ/μ/σ math, n-selection,
scheduler semantics, sequence synchronization, and mAP degradation.

``hypothesis`` is an optional dev dependency: the property-based tests
skip without it (deterministic parametrized fallbacks below keep the
invariants covered either way)."""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional dep — see requirements-dev.txt
    given = None

from repro.core import (DEVICE_PROFILES, MODEL_PROFILES, DetectorExecutor,
                        FrameStream, ParallelDetector, SequenceSynchronizer,
                        SyntheticVideo, VideoSpec, choose_n, make_scheduler,
                        n_range, simulate)


def run(video="ETH-Sunnyday", model="yolov3", devices=("ncs2",),
        sched="fcfs", **kw):
    return ParallelDetector(video, model, list(devices), sched, **kw)


# --------------------------------------------------------------- §II math
def test_drop_math_single_stick():
    """Paper §II-B: λ=14, μ=2.5 -> ~5 random drops per processed frame."""
    r = run(devices=["ncs2"]).run(with_map=False)
    assert 4.0 <= r.drops_per_processed <= 5.5


def test_n_range_matches_paper_examples():
    assert n_range(14, 2.5) == (4, 6)          # §III-B worked example
    assert n_range(30, 2.3) == (5, 14)         # §IV-A SSD on ADL
    assert n_range(30, 2.5) == (4, 12)         # §IV-A YOLO on ADL
    assert choose_n(14, 2.5) == 4
    assert choose_n(14, 2.5, "conservative") == 6


def test_n_range_low_lambda_is_conservative():
    lo, hi = n_range(10, 2.5)                  # λ <= 12: single bound
    assert lo == hi == 4


# ------------------------------------------------------- linear scalability
@pytest.mark.parametrize("model", ["yolov3", "ssd300"])
def test_linear_scaling_with_n(model):
    mu = DEVICE_PROFILES["ncs2"].mu(model)
    for n in (1, 3, 5, 7):
        r = run(model=model, devices=["ncs2"] * n).run(with_map=False)
        assert r.sigma == pytest.approx(n * mu, rel=0.08)


def test_parallel_detection_closes_fps_gap():
    """The paper's headline: n in the recommended range delivers >=10 FPS
    near-real-time processing on a 14 FPS stream."""
    n = choose_n(14, 2.5)
    r = run(devices=["ncs2"] * n).run(with_map=False)
    assert r.sigma >= 9.4


# ------------------------------------------------------------- schedulers
def test_fcfs_beats_rr_on_heterogeneous():
    devs = ["fast_cpu"] + ["ncs2"] * 7
    rr = run(devices=devs, sched="rr").run(with_map=False)
    fcfs = run(devices=devs, sched="fcfs").run(with_map=False)
    assert fcfs.sigma > 1.3 * rr.sigma
    # Table VII shape: RR ~= 8 x min(mu), FCFS ~= sum(mu)
    assert rr.sigma == pytest.approx(8 * 2.5, rel=0.12)
    assert fcfs.sigma == pytest.approx(13.5 + 7 * 2.5, rel=0.12)


def test_fcfs_equals_rr_on_homogeneous():
    rr = run(devices=["ncs2"] * 4, sched="rr").run(with_map=False)
    fcfs = run(devices=["ncs2"] * 4, sched="fcfs").run(with_map=False)
    assert rr.sigma == pytest.approx(fcfs.sigma, rel=0.08)


def test_slow_device_drags_rr_but_not_fcfs():
    devs = ["slow_cpu"] + ["ncs2"] * 7
    rr = run(devices=devs, sched="rr").run(with_map=False)
    fcfs = run(devices=devs, sched="fcfs").run(with_map=False)
    assert rr.sigma < 4.0                       # paper: 3.4
    assert fcfs.sigma > 14.0                    # paper: 17.9


def test_weighted_rr_recovers_heterogeneous_throughput():
    devs = ["fast_cpu"] + ["ncs2"] * 3
    wrr = run(devices=devs, sched="wrr").run(with_map=False)
    rr = run(devices=devs, sched="rr").run(with_map=False)
    assert wrr.sigma > rr.sigma


def test_proportional_converges_to_weighted():
    devs = ["fast_cpu"] + ["ncs2"] * 3
    prop = run(devices=devs, sched="proportional").run(with_map=False)
    wrr = run(devices=devs, sched="wrr").run(with_map=False)
    assert prop.sigma == pytest.approx(wrr.sigma, rel=0.25)
    assert prop.sigma > 12.0


# ----------------------------------------------------------- synchronizer
def test_synchronizer_order_and_stale_fill():
    det = run(devices=["ncs2"] * 2)
    from repro.core.simulator import simulate as sim
    result = sim(FrameStream(det.video), det.scheduler)
    synced = SequenceSynchronizer().order(result)
    assert [s.index for s in synced] == list(range(result.n_frames))
    processed = set(result.processed_indices)
    for s in synced:
        if s.index in processed:
            assert not s.stale and s.source_index == s.index
        elif s.source_index >= 0:
            assert s.stale and s.source_index < s.index
            assert s.source_index in processed


def test_no_drops_when_capacity_exceeds_lambda():
    det = run(devices=["ncs2"] * 7)             # 17.5 FPS > 14 FPS
    from repro.core.simulator import simulate as sim
    result = sim(FrameStream(det.video), det.scheduler)
    assert result.drop_rate < 0.02


# ------------------------------------------------------------------ mAP
def test_map_recovers_with_parallelism():
    maps = []
    for n in (1, 3, 6):
        maps.append(run(devices=["ncs2"] * n).run().map_score)
    assert maps[0] < maps[1] <= maps[2] + 0.01
    off = run(devices=["ncs2"]).run(offline=True).map_score
    assert maps[2] == pytest.approx(off, abs=0.02)


def test_offline_reference_map_matches_paper_band():
    off = run(devices=["ncs2"]).run(offline=True).map_score
    assert 0.82 <= off <= 0.91                  # paper: 86.9% (YOLO, ETH)
    off_ssd = run(model="ssd300", devices=["ncs2"]).run(offline=True).map_score
    assert off_ssd < off                        # SSD below YOLO, as in paper


# ------------------------------------------------------- property tests
def _check_n_range_properties(lam, mu):
    lo, hi = n_range(lam, mu)
    assert 1 <= lo <= hi
    assert hi * mu >= lam                       # conservative end covers λ
    if lam > 12:
        assert lo * mu >= min(10.0, lam) - mu   # near-real-time end


def _check_simulation_invariants(n, sched, fps):
    video = SyntheticVideo(VideoSpec("t", fps, 120, 320, 240, False, 4, 1))
    execs = [DetectorExecutor(DEVICE_PROFILES["ncs2"],
                              MODEL_PROFILES["yolov3"]) for _ in range(n)]
    result = simulate(FrameStream(video), make_scheduler(sched, execs))
    # conservation: every frame either processed once or dropped once
    assert len(result.assignments) + len(result.dropped) == 120
    assert len(set(result.processed_indices) & set(result.dropped)) == 0
    # causality + no overlap per executor
    per_ex = {}
    for a in result.assignments:
        assert a.t_done > a.t_start >= 0
        assert a.t_start >= a.frame_idx / fps - 1e-9    # not before arrival
        per_ex.setdefault(a.executor_idx, []).append(a)
    for aas in per_ex.values():
        aas.sort(key=lambda a: a.t_start)
        for x, y in zip(aas, aas[1:]):
            assert y.t_start >= x.t_done - 1e-9


if given is not None:
    @settings(max_examples=25, deadline=None)
    @given(lam=st.floats(5.0, 60.0), mu=st.floats(0.3, 40.0))
    def test_n_range_properties(lam, mu):
        _check_n_range_properties(lam, mu)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(1, 6), sched=st.sampled_from(["rr", "fcfs", "wrr"]),
           fps=st.floats(5.0, 40.0))
    def test_simulation_invariants(n, sched, fps):
        _check_simulation_invariants(n, sched, fps)
else:
    @pytest.mark.parametrize("lam,mu", [
        (5.0, 0.3), (12.0, 2.5), (14.0, 2.5), (30.0, 2.3), (30.0, 40.0),
        (60.0, 0.5), (11.99, 12.01), (59.9, 39.9)])
    def test_n_range_properties(lam, mu):
        _check_n_range_properties(lam, mu)

    @pytest.mark.parametrize("n,sched,fps", [
        (1, "rr", 5.0), (3, "fcfs", 14.0), (6, "wrr", 40.0),
        (2, "wrr", 23.7), (4, "rr", 30.0), (5, "fcfs", 8.3)])
    def test_simulation_invariants(n, sched, fps):
        _check_simulation_invariants(n, sched, fps)


# --------------------------------------------------- smooth-WRR expansion
def test_wrr_expansion_interleaves_weight_one_executors():
    """Regression: the fractional-position expansion parked every
    weight-1 executor at the same mid-round key, emitting a consecutive
    weight-1 block ([0,0,1,2,3,4,0,0] for weights [4,1,1,1,1]) — the
    exact head-of-line pattern the smooth expansion exists to avoid.
    Expected order: the nginx current-weight sequence [0,1,0,2,0,3,0,4],
    rotated so the round opens with a lighter executor."""
    from repro.core.scheduler import WeightedRRScheduler
    execs = [DetectorExecutor(DEVICE_PROFILES["ncs2"],
                              MODEL_PROFILES["yolov3"]) for _ in range(5)]
    wrr = make_scheduler("wrr", execs, weights=[4, 1, 1, 1, 1])
    assert wrr._slots == [1, 0, 2, 0, 3, 0, 4, 0]
    # per-round quota is preserved for every weight vector
    for weights in ([2, 1], [1, 3], [3, 2, 1], [1, 1, 1]):
        wrr = make_scheduler("wrr", execs[:len(weights)], weights=weights)
        assert len(wrr._slots) == sum(weights)
        for j, w in enumerate(weights):
            assert wrr._slots.count(j) == w
        # no executor occupies two consecutive slots (cyclically) unless
        # its weight reaches half the round, where pigeonhole makes runs
        # unavoidable
        round2 = wrr._slots * 2
        for j, w in enumerate(weights):
            if 2 * w < sum(weights):
                assert all(not (a == j and b == j)
                           for a, b in zip(round2, round2[1:]))


def test_wrr_skips_backlogged_slot_instead_of_dropping():
    """Regression: ``assign`` returning None never advanced
    ``slot_idx``, so one backlogged executor at the head slot dropped
    EVERY subsequent arrival until its backlog cleared — even with the
    other executors idle.  A backlogged slot must forfeit its turn
    (skip to the next slot within the round), and a frame is dropped
    only when every slot is backlogged."""
    def fresh(n=2):
        execs = [DetectorExecutor(DEVICE_PROFILES["ncs2"],
                                  MODEL_PROFILES["yolov3"])
                 for _ in range(n)]
        return execs, make_scheduler("wrr", execs, weights=[1] * n)

    execs, wrr = fresh()
    head = wrr._slots[0]
    other = wrr._slots[1]
    execs[head].busy_until = 100.0       # deep backlog on the head slot
    for i in range(5):                   # paced at the healthy device's mu
        a = wrr.assign(i, t=0.4 * i)
        assert a is not None, f"frame {i} head-of-line dropped"
        assert a.executor_idx == other
    # every slot backlogged -> the frame really is dropped, and the
    # round position is left where it was
    execs, wrr = fresh()
    for e in execs:
        e.busy_until = 100.0
    idx_before = wrr.slot_idx
    assert wrr.assign(0, t=0.0) is None
    assert wrr.slot_idx == idx_before


def test_proportional_skips_backlogged_slot():
    """The same head-of-line fix must hold through the Proportional
    subclass (heterogeneous speeds: a slow device's backlog must not
    starve the fast ones)."""
    execs = [DetectorExecutor(DEVICE_PROFILES["slow_cpu"],
                              MODEL_PROFILES["yolov3"]),
             DetectorExecutor(DEVICE_PROFILES["fast_cpu"],
                              MODEL_PROFILES["yolov3"])]
    sched = make_scheduler("proportional", execs)
    slow_slot = 0
    execs[slow_slot].busy_until = 50.0
    got = [sched.assign(i, t=0.2 * i) for i in range(8)]
    assert all(a is not None for a in got)
    assert all(a.executor_idx == 1 for a in got)
    # rounds closed by skip-crossings still advance the reweighting
    # clock: with the backlogged device forfeiting every turn, the
    # EWMA-based weight refresh must still fire (it used to be keyed
    # off a slot_idx==0 condition such rounds could never satisfy)
    assert sched.rounds_completed >= sched.update_period
    assert sched._last_refresh >= sched.update_period


# ----------------------------------------- heterogeneous detection models
def test_heterogeneous_models_per_device():
    """Paper §III-A third design alternative: different detector models on
    different devices; FCFS exploits both, mAP scored per source model."""
    hetero = run(model=["yolov3"] + ["ssd300"] * 4,
                 devices=["fast_cpu"] + ["ncs2"] * 4).run()
    ssd_only = run(model="ssd300", devices=["ncs2"] * 4).run()
    assert hetero.model == "mixed"
    assert hetero.sigma > ssd_only.sigma + 5.0     # fast CPU adds ~13.5
    assert hetero.map_score > ssd_only.map_score   # YOLO share lifts mAP
