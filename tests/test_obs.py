"""Observability stack: trace recording semantics, streaming latency
histograms (merge == whole-run), the trace-replay invariant audit
(including its power to CATCH corrupted traces), and the Perfetto /
Chrome export.  Everything runs on the deterministic virtual clock, so
every recorded trace and every quantile replays bit-identically.
"""
import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import proxy_detect_fn_streams
from repro.obs import (LatencyHistogram, NullRecorder, TraceRecorder,
                       audit_events, audit_recorder,
                       detection_latency_keys, events_from_chrome,
                       merge_hist_dicts, quantile_of_dict,
                       to_chrome_trace)
from repro.serving import (DetectionEngine, FaultSchedule, FrameRequest,
                           Request, ServingEngine,
                           ShardedDetectionEngine, Watchdog,
                           make_nvr_streams)


def nvr(n_streams=4, n_frames=16, **kw):
    frames, frame_of, videos, dets = make_nvr_streams(n_streams,
                                                      n_frames, rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    base = dict(detect_fn=oracle, n_replicas=2, service_time=0.02,
                track_and_interpolate=True)
    base.update(kw)
    return frames, base


# ===================================================== recorder basics
def test_recorder_event_schema_and_code_order():
    rec = TraceRecorder()
    rec.record("arrive", 1.0, rid=0)
    rec.record("arrive", 0.5, rid=1)       # earlier t, later code order
    assert [e["i"] for e in rec.events] == [0, 1]
    assert all({"i", "kind", "t"} <= set(e) for e in rec.events)
    # sorted_events orders by virtual time; raw order is code order
    assert [e["rid"] for e in rec.sorted_events()] == [1, 0]


def test_shard_view_stamps_and_shares_counter():
    rec = TraceRecorder()
    v0, v1 = rec.shard_view(0), rec.shard_view(1)
    v1.record("drop", 1.0, rid=3)
    v0.record("drop", 2.0, rid=4)
    v1.record("dispatch", 3.0, rid=5, replica=0, shard=7)  # explicit wins
    assert [(e["i"], e["shard"]) for e in rec.events] == \
        [(0, 1), (1, 0), (2, 7)]


def test_null_recorder_is_inert():
    rec = NullRecorder()
    assert not rec.enabled
    rec.record("arrive", 0.0, rid=0)
    rec.sample("queue_depth", 0.0, 1)
    assert rec.shard_view(3) is rec
    assert rec.to_json() == {"events": [], "series": []} or \
        rec.to_json() == {"events": [], "series": {}}


# ============================================== latency histogram units
def test_histogram_quantile_bounds_and_max():
    h = LatencyHistogram()
    lat = [0.010, 0.020, 0.030, 0.100]
    for x in lat:
        h.add(x)
    for q in (0.5, 0.95, 0.99):
        v = h.quantile(q)
        # quantiles come from bucket upper edges, clamped at the true max
        assert v <= h.max
        assert v >= np.quantile(lat, q) / 2 ** 0.25
    assert h.quantile(0.99) == h.max


def test_histogram_merge_equals_whole():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(-3, 1, 200)
    whole = LatencyHistogram()
    parts = [LatencyHistogram() for _ in range(4)]
    for i, x in enumerate(xs):
        whole.add(float(x))
        parts[i % 4].add(float(x))
    merged = LatencyHistogram()
    for p in parts:
        merged.merge(p)
    assert merged == whole
    assert merged.quantile(0.95) == whole.quantile(0.95)
    d = merge_hist_dicts([p.to_dict() for p in parts])
    assert LatencyHistogram.from_dict(d) == whole
    assert quantile_of_dict(d, 0.99) == whole.quantile(0.99)


def test_histogram_dict_round_trips_json():
    h = LatencyHistogram()
    h.add(0.05), h.add(1.5)
    again = LatencyHistogram.from_dict(
        json.loads(json.dumps(h.to_dict())))   # str keys coerce back
    assert again == h


# ===================================== engine report latency satellites
def test_detection_report_has_latency_keys():
    frames, kw = nvr()
    rep = DetectionEngine(**kw).serve(frames)
    lat = sorted(r.t_done - r.t_start for r in rep["responses"]
                 if not r.interpolated)
    assert rep["p50_latency"] == float(np.median(lat))
    assert rep["p95_latency"] >= rep["p50_latency"]
    assert rep["p99_latency"] >= rep["p95_latency"]
    assert rep["p99_latency"] <= max(lat)
    assert sum(rep["latency_hist"]["counts"].values()) == len(lat)


def test_interpolated_frames_excluded_from_detection_histogram():
    frames, kw = nvr(n_streams=6, n_frames=12)
    kw["service_time"] = 0.2                  # force drops -> interp
    rep = DetectionEngine(**kw).serve(frames)
    n_interp = sum(r.interpolated for r in rep["responses"])
    assert n_interp > 0
    n_det = sum(not r.interpolated for r in rep["responses"])
    assert sum(rep["latency_hist"]["counts"].values()) == n_det
    assert sum(rep["interp_latency"]["counts"].values()) == n_interp


def test_serving_engine_p95_p99_and_empty_trace_keys():
    cfg = get_config("minicpm-2b", preset="smoke")
    eng = ServingEngine(cfg, n_replicas=2, scheduler="fcfs",
                        cache_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size - 1, 8)
                    .astype(np.int32), 4, i / 50.0) for i in range(6)]
    rep = eng.serve(reqs)
    empty = eng.serve([])
    for k in ("p50_latency", "p95_latency", "p99_latency",
              "latency_hist"):
        assert k in rep and k in empty
    assert rep["p50_latency"] <= rep["p95_latency"] <= rep["p99_latency"]
    assert empty["p95_latency"] == 0.0
    assert sum(empty["latency_hist"]["counts"].values()) == 0


# ==================================== histogram merge == whole-run serve
@pytest.mark.parametrize("n_shards", [1, 4])
def test_sharded_merge_hist_equals_whole_run(n_shards):
    frames, kw = nvr(n_streams=8, n_frames=12)
    rep = ShardedDetectionEngine(n_shards=n_shards, **kw).serve(frames)
    whole = LatencyHistogram()
    for r in rep["responses"]:
        if not r.interpolated:
            whole.add(r.t_done - r.t_start)
    assert LatencyHistogram.from_dict(rep["latency_hist"]) == whole
    lat = sorted(r.t_done - r.t_start for r in rep["responses"]
                 if not r.interpolated)
    assert rep["p50_latency"] == float(np.median(lat))
    assert rep["p95_latency"] == whole.quantile(0.95)
    # per-epoch rollup conserves the same histogram
    per_epoch = rep["per_epoch"]
    assert merge_hist_dicts(
        [e["latency_hist"] for e in per_epoch.values()]) == \
        rep["latency_hist"]


def test_shards1_report_matches_base_engine_bits():
    frames, kw = nvr(n_streams=4, n_frames=10)
    base = DetectionEngine(**kw).serve(frames)
    shard = ShardedDetectionEngine(n_shards=1, **kw).serve(frames)
    for k in ("p50_latency", "p95_latency", "p99_latency",
              "latency_hist", "interp_latency", "latency_by_stream"):
        assert base[k] == shard[k], k


# =============================== event ordering / out-of-order complete
def test_trace_under_out_of_order_completion():
    """A slow replica makes a later-dispatched request finish first;
    the trace must show the inversion, and the audit (including emit
    monotonicity) must still hold."""
    cfg = get_config("minicpm-2b", preset="smoke")
    rec = TraceRecorder()
    eng = ServingEngine(cfg, n_replicas=2, scheduler="rr", cache_len=32,
                        replica_speeds=[8.0, 1.0], recorder=rec)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size - 1, 8)
                    .astype(np.int32), 4, 0.0) for i in range(4)]
    eng.serve(reqs)
    comp = [e for e in rec.events if e["kind"] == "complete"]
    by_dispatch = sorted(comp, key=lambda e: e["t0"])
    done = [e["t"] for e in by_dispatch]
    assert done != sorted(done), "expected out-of-order completion"
    res = audit_events(rec.events)
    assert res.ok, res.violations
    emits = [e["t"] for e in rec.events if e["kind"] == "emit"]
    assert emits == sorted(emits)


def test_detection_trace_frame_conservation():
    frames, kw = nvr(n_streams=6, n_frames=12)
    kw["service_time"] = 0.2                  # force drops
    rec = TraceRecorder()
    rep = DetectionEngine(recorder=rec, **kw).serve(frames)
    res = audit_recorder(rec)
    assert res.ok, res.violations
    assert res.stats["arrive"] == len(frames)
    assert res.stats["emitted"] == len(rep["responses"])


# ======================================= audit catches corrupted traces
def clean_trace():
    frames, kw = nvr(n_streams=4, n_frames=10)
    rec = TraceRecorder()
    DetectionEngine(recorder=rec, **kw).serve(frames)
    assert audit_recorder(rec).ok
    return rec.events


def test_audit_catches_vanished_frame():
    events = [e for e in clean_trace()
              if not (e["kind"] == "emit" and e["rid"] == 0)]
    res = audit_events(events)
    assert not res.ok
    assert any(v["rule"] == "frame_conservation" for v in res.violations)


def test_audit_catches_double_emit():
    events = clean_trace()
    dup = dict(next(e for e in events if e["kind"] == "emit"))
    dup["i"] = len(events)
    res = audit_events(events + [dup])
    assert any(v["rule"] == "frame_conservation" and "terminal" in
               v.get("why", "") for v in res.violations)


def test_audit_catches_emit_time_regression():
    events = clean_trace()
    emits = [e for e in events if e["kind"] in ("emit", "interp_emit")]
    emits[-1]["t"] = emits[0]["t"] - 1.0     # time goes backwards
    res = audit_events(events)
    assert any(v["rule"] == "emit_monotonicity" for v in res.violations)


def test_audit_catches_dead_replica_dispatch():
    events = clean_trace()
    disp = next(e for e in events if e["kind"] == "dispatch")
    mark = {"i": -1, "kind": "health_mark", "t": 0.0,
            "replica": disp["replica"]}
    res = audit_events([mark] + events)
    assert any(v["rule"] == "dead_replica_dispatch"
               for v in res.violations)


def test_audit_catches_unreturned_and_non_lifo_loans():
    base = [{"i": 0, "kind": "loan", "t": 1.0, "lender": 1,
             "borrower": 0, "guest": 2},
            {"i": 1, "kind": "loan", "t": 2.0, "lender": 3,
             "borrower": 0, "guest": 3}]
    res = audit_events(base)                      # never returned
    assert sum(v["rule"] == "loan_lifo" for v in res.violations) == 2
    out_of_order = base + [
        {"i": 2, "kind": "loan_return", "t": 3.0, "lender": 1,
         "borrower": 0, "guest": 2},              # FIFO, not LIFO
        {"i": 3, "kind": "loan_return", "t": 3.0, "lender": 3,
         "borrower": 0, "guest": 3}]
    res = audit_events(out_of_order)
    assert any(v["rule"] == "loan_lifo" and "LIFO" in v["why"]
               for v in res.violations)


# ==================================================== Perfetto export
def test_chrome_export_one_span_per_completed_frame():
    frames, kw = nvr(n_streams=4, n_frames=12)
    rec = TraceRecorder()
    ShardedDetectionEngine(n_shards=2, recorder=rec, **kw).serve(frames)
    doc = to_chrome_trace(rec.events, rec.series)
    json.dumps(doc, default=float)                # valid JSON document
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    completes = [e for e in rec.events if e["kind"] == "complete"]
    assert len(spans) == len(completes) > 0
    # lanes: pid = shard, tid = replica, with metadata naming both
    assert {e["pid"] for e in spans} == \
        {e.get("shard", 0) for e in completes}
    assert any(e["ph"] == "M" for e in doc["traceEvents"])
    # counters exported from the sampled series
    assert any(e["ph"] == "C" for e in doc["traceEvents"])
    # and the raw events survive the round trip
    back = events_from_chrome(doc)
    assert len(back) == len(rec.events)
    assert audit_events(back).ok


# ============================================= chaos-marked audit runs
@pytest.mark.chaos
def test_audit_clean_across_seeded_chaos():
    frames, kw = nvr(n_streams=4, n_frames=16, n_shards=2,
                     rebalance=True, epoch_s=2.0)
    for seed in range(4):
        rec = TraceRecorder()
        sched = FaultSchedule.random(seed=seed, horizon_s=4.0,
                                     n_shards=2, n_replicas=2,
                                     n_shard_events=1)
        ShardedDetectionEngine(faults=sched, supervisor=Watchdog(),
                               recorder=rec, **kw).serve(frames)
        res = audit_recorder(rec)
        assert res.ok, (seed, res.violations)
        assert res.stats["arrive"] == len(frames)


@pytest.mark.chaos
def test_chaos_trace_is_deterministic():
    frames, kw = nvr(n_streams=4, n_frames=12, n_shards=2,
                     rebalance=True, epoch_s=2.0)
    sched = FaultSchedule.random(seed=7, horizon_s=3.0, n_shards=2,
                                 n_replicas=2, n_shard_events=1)

    def run():
        rec = TraceRecorder()
        ShardedDetectionEngine(faults=sched, supervisor=Watchdog(),
                               recorder=rec, **kw).serve(frames)
        return rec.events

    assert run() == run()


@pytest.mark.chaos
def test_lending_trace_loans_lifo():
    """The watchdog lending scenario records loan/loan_return pairs the
    audit accepts (LIFO + all returned)."""

    def stub(images, rids=None):
        b = len(images)
        return (np.zeros((b, 4, 4), np.float32),
                np.zeros((b, 4), np.float32),
                np.zeros((b, 4), np.int32), np.zeros((b, 4), bool))

    events = [(k / 30.0, 0, k) for k in range(120)]
    events += [(k + 0.5, 1, k) for k in range(4)]
    events.sort()
    frames = [FrameRequest(rid, np.zeros((4, 4, 3), np.float32), t,
                           stream_id=s)
              for rid, (t, s, k) in enumerate(events)]
    rec = TraceRecorder()
    rep = ShardedDetectionEngine(
        detect_fn=stub, n_replicas=2, service_time=0.1,
        drop_when_busy=True, micro_batch=1, max_micro_batch=1,
        n_shards=2, rebalance=True, epoch_s=2.0,
        supervisor=Watchdog(idle_backlog_s=0.5),
        recorder=rec).serve(frames)
    assert rep["faults"]["loans"]
    loans = [e for e in rec.events if e["kind"] == "loan"]
    returns = [e for e in rec.events if e["kind"] == "loan_return"]
    assert len(loans) == len(returns) > 0
    res = audit_recorder(rec)
    assert res.ok, res.violations
