"""Fault-injected serving: the deterministic fault model, the
schedulers' timeout detection / bounded failover / health accounting,
the engine-level drop accounting for an all-dead pool, and the sharded
layer's shard-kill recovery (watchdog restart + evacuation) and replica
lending.  Plus the pre-existing robustness bugs this PR fixes as
satellites: the WRR zero-weight round expansion, the Proportional
reweighting with a dead executor's stale EWMA, ``backlog`` edge cases,
and the blocking-dispatch fail-fast contract.

Everything here is a pure function of ``(trace, FaultSchedule)`` — the
chaos-marked tests replay bit-identically, which is what makes chaos
assertable."""
import numpy as np
import pytest

from repro.core import proxy_detect_fn_streams
from repro.core.executor import (DEVICE_PROFILES, MODEL_PROFILES,
                                 DetectorExecutor)
from repro.core.scheduler import NoHealthyExecutorError, make_scheduler
from repro.serving import (DetectionEngine, FaultEvent, FaultSchedule,
                           FrameRequest, ShardedDetectionEngine,
                           ShardFaultCursor, Watchdog, make_nvr_streams)

pytestmark = pytest.mark.chaos


def ncs2(n, **kw):
    return [DetectorExecutor(DEVICE_PROFILES["ncs2"],
                             MODEL_PROFILES["yolov3"], **kw)
            for _ in range(n)]


def attach(execs, sched: FaultSchedule, shard: int = 0):
    for i, e in enumerate(execs):
        e.faults = sched.view(shard, i)
    return execs


def stub_detect(images, rids=None):
    b = len(images)
    return (np.zeros((b, 4, 4), np.float32),
            np.zeros((b, 4), np.float32),
            np.zeros((b, 4), np.int32),
            np.zeros((b, 4), bool))


# ===================================================== fault model units
def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(1.0, "explode", replica=0)
    with pytest.raises(ValueError):
        FaultEvent(1.0, "kill")                      # replica required
    with pytest.raises(ValueError):
        FaultEvent(1.0, "shard_kill", replica=0)     # replica forbidden
    with pytest.raises(ValueError):
        FaultEvent(1.0, "slow", replica=0, factor=0.5)  # speedups aren't


def test_replica_view_fold():
    v = FaultSchedule([
        FaultEvent(1.0, "slow", replica=0, factor=4.0),
        FaultEvent(2.0, "kill", replica=0),
        FaultEvent(3.0, "revive", replica=0),
    ]).view(0, 0)
    assert v.alive(0.5) and v.factor(0.5) == 1.0
    assert v.alive(1.5) and v.factor(1.5) == 4.0
    assert not v.alive(2.5)
    assert v.alive(3.5) and v.factor(3.5) == 1.0     # revive comes back clean
    # an in-flight frame spanning the kill is lost even if revived after
    assert v.alive_through(0.5, 1.9)
    assert not v.alive_through(1.9, 2.1)
    assert not v.alive_through(2.5, 2.6)             # dead at dispatch
    assert v.alive_through(3.1, 9.0)


def test_schedule_sorted_falsy_and_composable():
    a = FaultSchedule.replica_kill(5.0, replica=1, revive_t=7.0)
    b = FaultSchedule.replica_slowdown(1.0, replica=0, factor=2.0)
    s = a + b
    assert [e.t for e in s] == [1.0, 5.0, 7.0]
    assert s.last_event_t == 7.0
    assert len(s) == 3 and bool(s)
    assert not FaultSchedule() and len(FaultSchedule()) == 0
    assert s.view(0, 1).events == tuple(a)
    assert s.view(1, 0).events == ()                 # other shard: clean


def test_random_schedule_deterministic():
    a = FaultSchedule.random(7, 10.0, n_shards=2, n_replicas=3,
                             n_replica_events=4, n_shard_events=1)
    b = FaultSchedule.random(7, 10.0, n_shards=2, n_replicas=3,
                             n_replica_events=4, n_shard_events=1)
    assert list(a) == list(b)
    assert a.has_shard_events
    c = FaultSchedule.random(8, 10.0, n_shards=2, n_replicas=3,
                             n_replica_events=4, n_shard_events=1)
    assert list(a) != list(c)


def test_shard_cursor_kill_revive_and_restart():
    sched = FaultSchedule.shard_kill(2.5, shard=0, revive_t=5.0)
    cur = ShardFaultCursor(sched, 2)
    # epoch [0,4): kill strikes mid-window -> cut at 2.5, shard down
    assert cur.begin_epoch(0, 0.0, 4.0) == 2.5
    assert cur.is_down(0) and not cur.is_down(1)
    assert cur.begin_epoch(1, 0.0, 4.0) is None
    # epoch [4,8): revive at 5.0 has NOT folded yet (boundary fold only
    # consumes t <= window_start), so the shard is down entering it...
    assert cur.begin_epoch(0, 4.0, 8.0) == 2.5
    # ...and up again from the next boundary on
    assert cur.begin_epoch(0, 8.0, 12.0) is None
    assert not cur.is_down(0)


def test_shard_cursor_watchdog_restart_and_permanent():
    sched = FaultSchedule.shard_kill(2.5, shard=0)
    cur = ShardFaultCursor(sched, 1)
    assert cur.begin_epoch(0, 0.0, 4.0) == 2.5
    assert cur.restart(0, 4.0) is True               # watchdog repairs it
    # the kill event (t=2.5 <= 4.0) folds at the next boundary but the
    # restart already reconciled it: the shard stays up
    assert cur.begin_epoch(0, 4.0, 8.0) is None
    perm = ShardFaultCursor(FaultSchedule.shard_kill(2.5, shard=0,
                                                     permanent=True), 1)
    assert perm.begin_epoch(0, 0.0, 4.0) == 2.5
    assert perm.restart(0, 4.0) is False             # refused
    assert perm.begin_epoch(0, 4.0, 8.0) == 2.5      # still down


# ============================================== scheduler failure handling
def test_timeout_detection_and_failover():
    sched = FaultSchedule.replica_kill(0.0, replica=0)
    execs = attach(ncs2(2), sched)
    s = make_scheduler("fcfs", execs)
    a = s.assign(0, 0.0)
    # replica 0 is dead: the dispatcher times out (holding the slot for
    # k x expected), marks it unhealthy, and rescues the frame on 1
    assert a is not None and a.executor_idx == 1
    assert s.healthy == [False, True]
    assert s.retries == {0: 1} and s.failovers == {0: 1}
    assert s.frames_lost == {}
    # and the timeout charged replica 0's slot
    assert execs[0].busy_until == pytest.approx(
        s.timeout_k / execs[0].mu_effective, rel=1e-6)


def test_bounded_retry_exhaustion_loses_frame():
    sched = (FaultSchedule.replica_kill(0.0, replica=0)
             + FaultSchedule.replica_kill(0.0, replica=1))
    s = make_scheduler("fcfs", attach(ncs2(2), sched))
    assert s.assign(0, 0.0) is None                  # both dead: lost
    assert s.healthy == [False, False]
    assert sum(s.frames_lost.values()) == 1
    assert s.fault_counts()["retries"] == {0: 1, 1: 1}


def test_probe_health_restores_revived_replica():
    sched = FaultSchedule.replica_kill(0.0, replica=0, revive_t=1.0)
    s = make_scheduler("fcfs", attach(ncs2(2), sched))
    s.assign(0, 0.0)
    assert s.healthy == [False, True]
    s.probe_health(0.5)
    assert s.healthy == [False, True]                # still dead at 0.5
    s.probe_health(1.5)
    assert s.healthy == [True, True]                 # revived


def test_slowdown_past_timeout_is_suspected():
    # a replica degraded by >= timeout_k cannot beat the timeout rule:
    # it is detected exactly like a death (and probe_health refuses to
    # restore it, avoiding suspect/restore thrash)
    sched = FaultSchedule.replica_slowdown(0.0, replica=0, factor=8.0)
    s = make_scheduler("fcfs", attach(ncs2(2), sched))
    a = s.assign(0, 0.0)
    assert a.executor_idx == 1 and s.healthy == [False, True]
    s.probe_health(10.0)
    assert s.healthy == [False, True]
    # a mild slowdown sails through (slower, but no suspicion)
    mild = FaultSchedule.replica_slowdown(0.0, replica=0, factor=2.0)
    s2 = make_scheduler("fcfs", attach(ncs2(1), mild))
    a2 = s2.assign(0, 0.0)
    assert a2 is not None and s2.healthy == [True]
    assert (a2.t_done - a2.t_start) == pytest.approx(
        2.0 / s2.executors[0].mu_effective * (1 + s2.sync_overhead))


def test_fault_free_scheduler_untouched():
    """No fault view -> the failure machinery never engages and the
    virtual timeline is bit-identical to the pre-fault scheduler."""
    for kind in ("fcfs", "rr", "wrr", "proportional"):
        s = make_scheduler(kind, ncs2(3))
        out = [s.assign(i, i * 0.05) for i in range(40)]
        s2 = make_scheduler(kind, ncs2(3))
        out2 = [s2.assign(i, i * 0.05) for i in range(40)]
        assert [(a.executor_idx, a.t_start, a.t_done)
                for a in out if a] == \
               [(a.executor_idx, a.t_start, a.t_done)
                for a in out2 if a]
        assert s.fault_counts() == {"retries": {}, "failovers": {},
                                    "frames_lost": {}}


def test_lockstep_rr_skips_dead_slot():
    sched = FaultSchedule.replica_kill(0.0, replica=1)
    s = make_scheduler("rr", attach(ncs2(3), sched))
    got = []
    t = 0.0
    for i in range(6):
        a = s.blocking_assign(i, t)
        assert a is not None
        got.append(a.executor_idx)
        t = a.t_start
    # slot 1 dies on its first dispatch (charged one retry), after which
    # the strict order renormalizes over {0, 2}
    assert 1 not in got[1:]
    assert set(got) <= {0, 2} or got[0] in (0, 1)
    assert s.retries.get(1, 0) >= 1


# ===================================== satellite: WRR zero-weight rounds
def test_wrr_zero_weight_expansion_regression():
    """Regression: ``_expand`` with any zero weight raised StopIteration
    (with [1, 0] no emitted slot had w[j] < wmax, so the head-rotation's
    ``next()`` found nothing).  A zero weight must simply contribute no
    slots."""
    s = make_scheduler("wrr", ncs2(2), weights=[1, 0])   # raised before
    assert s._slots == [0]
    a = s.assign(0, 0.0)
    assert a is not None and a.executor_idx == 0
    s3 = make_scheduler("wrr", ncs2(3), weights=[4, 0, 1])
    assert 1 not in s3._slots and sorted(set(s3._slots)) == [0, 2]
    assert len(s3._slots) == 5
    dead = make_scheduler("wrr", ncs2(2), weights=[0, 0])
    assert dead._slots == []
    assert dead.assign(0, 0.0) is None               # no slots -> drop
    with pytest.raises(NoHealthyExecutorError):
        dead.blocking_assign(0, 0.0)                 # ... not a hang


def test_proportional_reweight_ignores_dead_executor():
    """A suspected-dead executor's stale EWMA must not anchor the rate
    normalization (it would inflate every live weight), and its own
    weight must renormalize to zero slots."""
    execs = ncs2(3)
    execs[0].ewma_service = 0.01                     # blazing... and dead
    execs[1].ewma_service = 0.5
    execs[2].ewma_service = 0.5
    s = make_scheduler("proportional", execs)
    s.healthy[0] = False
    s._refresh_weights()
    assert s.weights[0] == 0
    # live weights normalize against the live min (equal -> both 1), not
    # against the dead executor's 100 fps ghost rate
    assert s.weights[1] == s.weights[2] == 1
    assert 0 not in s._slots


# ======================================= satellite: blocking fail-fast
def test_blocking_assign_empty_pool_fails_fast():
    s = make_scheduler("fcfs", [])
    with pytest.raises(NoHealthyExecutorError, match="empty"):
        s.blocking_assign(0, 0.0)


def test_blocking_assign_all_dead_fails_fast():
    sched = (FaultSchedule.replica_kill(0.0, replica=0)
             + FaultSchedule.replica_kill(0.0, replica=1))
    s = make_scheduler("fcfs", attach(ncs2(2), sched))
    # first call: the pool LOOKS healthy, dispatch discovers both dead
    # (bounded retry), the frame is lost — returns None, not a hang
    assert s.blocking_assign(0, 0.0) is None
    # second call: nothing left to wait for -> fail fast
    with pytest.raises(NoHealthyExecutorError, match="unhealthy"):
        s.blocking_assign(1, 0.0)
    for kind in ("rr", "wrr", "proportional"):
        s2 = make_scheduler(kind, attach(ncs2(2), sched))
        s2.healthy = [False, False]
        with pytest.raises(NoHealthyExecutorError):
            s2.blocking_assign(0, 0.0)


# ============================================ satellite: backlog edges
def test_backlog_empty_pool_and_pre_dispatch():
    assert make_scheduler("fcfs", []).backlog(0.0) == 0.0
    s = make_scheduler("fcfs", ncs2(4))
    # an untouched executor's busy_until of 0.0 is a clock origin, not a
    # commitment: probing before the first arrival must read zero, not
    # -n x t
    assert s.backlog(-5.0) == 0.0
    assert s.backlog(0.0) == 0.0
    assert s.backlog(100.0) == 0.0


def test_backlog_counts_only_inflight_residual():
    s = make_scheduler("fcfs", ncs2(2))
    a0 = s.assign(0, 0.0)
    a1 = s.assign(1, 0.0)
    t_mid = min(a0.t_done, a1.t_done) / 2
    expect = (a0.t_done - t_mid) + (a1.t_done - t_mid)
    assert s.backlog(t_mid) == pytest.approx(expect)
    # all work drained -> zero again; and an idle executor alongside an
    # in-flight one contributes nothing
    assert s.backlog(max(a0.t_done, a1.t_done)) == 0.0
    assert s.backlog(a0.t_start) == pytest.approx(
        (a0.t_done - a0.t_start) + (a1.t_done - a0.t_start))


# ================================================= engine-level chaos
def nvr_engine(sched=None, n=8, **kw):
    frames, frame_of, videos, dets = make_nvr_streams(2, n, rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    eng = DetectionEngine(detect_fn=oracle, n_replicas=2,
                          service_time=0.05, faults=sched, **kw)
    return eng, frames


def test_engine_no_fault_bit_identical():
    eng0, frames = nvr_engine(None)
    eng1, _ = nvr_engine(FaultSchedule())            # empty == inert
    r0, r1 = eng0.serve(frames), eng1.serve(frames)
    assert set(r0) == set(r1)
    assert r0["retries"] == r1["retries"] == {}
    assert [(r.rid, r.replica, r.t_start, r.t_done)
            for r in r0["responses"]] == \
           [(r.rid, r.replica, r.t_start, r.t_done)
            for r in r1["responses"]]
    assert r0["dropped"] == r1["dropped"]


def test_engine_replica_kill_reported_and_survives():
    sched = FaultSchedule.replica_kill(0.5, replica=1)
    eng, frames = nvr_engine(sched, n=16)
    rep = eng.serve(frames)
    assert rep["retries"].get(1, 0) >= 1
    assert rep["failovers"].get(1, 0) >= 1
    # blocking mode + a surviving replica: every frame still served
    assert rep["coverage"] == 1.0
    assert all(r.replica == 0 for r in rep["responses"]
               if r.t_start > 0.5 + eng.scheduler.timeout_k
               / eng.replicas[1].mu_effective)
    rep2 = eng.serve(frames)                         # replays identically
    assert rep["retries"] == rep2["retries"]
    assert [r.rid for r in rep["responses"]] == [r.rid
                                                 for r in rep2["responses"]]


def test_engine_all_dead_drops_instead_of_hanging():
    sched = (FaultSchedule.replica_kill(0.2, replica=0)
             + FaultSchedule.replica_kill(0.2, replica=1))
    eng, frames = nvr_engine(sched, n=16)
    rep = eng.serve(frames)                          # must terminate
    assert rep["coverage"] < 1.0
    # every frame is a response or a drop (a scheduler-lost frame is
    # dropped TOO — frames_lost attributes the loss to its executor)
    assert len(rep["dropped"]) + len(rep["responses"]) == len(frames)
    assert sum(rep["frames_lost"].values()) >= 1


def test_engine_track_mode_coasts_through_kill():
    sched = FaultSchedule.replica_kill(0.5, replica=1)
    eng, frames = nvr_engine(sched, n=16, track_and_interpolate=True)
    rep = eng.serve(frames)
    # tracker mode never leaves a gap: dropped arrivals are emitted with
    # coasted boxes, so per-stream coverage holds at 1.0 under the kill
    assert rep["coverage"] == 1.0
    assert all(v["coverage"] == 1.0 for v in rep["per_stream"].values())


def test_engine_rejects_empty_pool():
    with pytest.raises(ValueError):
        DetectionEngine(detect_fn=stub_detect, n_replicas=0)


# ================================================= sharded-layer chaos
def sharded_nvr(n_frames=24, **kw):
    frames, frame_of, videos, dets = make_nvr_streams(4, n_frames,
                                                      rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    eng = ShardedDetectionEngine(detect_fn=oracle, n_replicas=2,
                                 service_time=0.02, n_shards=2,
                                 rebalance=True, epoch_s=2.0,
                                 track_and_interpolate=True, **kw)
    return eng, frames


def test_shard_events_require_rebalance():
    sched = FaultSchedule.shard_kill(1.0, shard=0)
    with pytest.raises(ValueError, match="rebalance"):
        ShardedDetectionEngine(detect_fn=stub_detect, n_shards=2,
                               faults=sched)
    with pytest.raises(ValueError, match="watchdog|supervisor"):
        ShardedDetectionEngine(detect_fn=stub_detect, n_shards=2,
                               supervisor=Watchdog())
    # replica-level events need no epoch loop
    ShardedDetectionEngine(detect_fn=stub_detect, n_shards=2,
                           faults=FaultSchedule.replica_kill(1.0,
                                                             replica=0))


def test_sharded_no_fault_bit_identical():
    eng0, frames = sharded_nvr()
    eng1, _ = sharded_nvr(faults=FaultSchedule())
    r0, r1 = eng0.serve(frames), eng1.serve(frames)
    assert set(r0) == set(r1)
    assert [r.rid for r in r0["responses"]] == [r.rid
                                                for r in r1["responses"]]
    assert r0["dropped"] == r1["dropped"]
    assert r0["migrations"] == r1["migrations"]


def test_shard_kill_recovers_within_epoch():
    sched = FaultSchedule.shard_kill(2.5, shard=0)
    eng, frames = sharded_nvr(faults=sched, supervisor=Watchdog())
    rep = eng.serve(frames)
    fl = rep["faults"]
    assert fl["n_events"] == 1 and fl["frames_lost_shard"] > 0
    # the watchdog restarted the shard at the FIRST boundary after the
    # kill (within one epoch), and its streams were evacuated
    assert fl["restarts"] == [{"epoch": 1, "shard": 0, "ok": True,
                               "t": 4.0}]
    assert any(m["src"] == 0 for m in rep["migrations"])
    assert rep["recovered_coverage"] == 1.0
    # the lost frames are accounted as drops, stream by stream
    assert len(rep["dropped"]) >= fl["frames_lost_shard"]
    assert sum(v["dropped"] for v in rep["per_stream"].values()) \
        == len(rep["dropped"])
    # pools end at their constructed sizes
    assert all(len(e.replicas) == 2 for e in eng.engines)


def test_shard_kill_replay_deterministic():
    sched = FaultSchedule.shard_kill(2.5, shard=0)
    eng, frames = sharded_nvr(faults=sched, supervisor=Watchdog())
    r1, r2 = eng.serve(frames), eng.serve(frames)
    assert [r.rid for r in r1["responses"]] == [r.rid
                                                for r in r2["responses"]]
    assert r1["dropped"] == r2["dropped"]
    assert r1["faults"] == r2["faults"]
    assert r1["recovered_coverage"] == r2["recovered_coverage"]


def test_permanent_kill_recovers_by_evacuation_alone():
    sched = FaultSchedule.shard_kill(2.5, shard=0, permanent=True)
    eng, frames = sharded_nvr(faults=sched, supervisor=Watchdog())
    rep = eng.serve(frames)
    assert rep["faults"]["restarts"][0]["ok"] is False
    assert any(m["src"] == 0 for m in rep["migrations"])
    assert rep["recovered_coverage"] == 1.0          # evacuation carried it


def test_unsupervised_shard_kill_degrades():
    """Without a watchdog the kill still terminates cleanly (frames lost
    until the schedule's own revive), establishing the baseline the
    supervisor improves on."""
    killed = FaultSchedule.shard_kill(2.5, shard=0, revive_t=4.5)
    eng, frames = sharded_nvr(faults=killed)
    rep = eng.serve(frames)
    assert rep["faults"]["restarts"] == []
    assert rep["faults"]["frames_lost_shard"] > 0
    assert rep["recovered_coverage"] == 1.0          # schedule revive
    sup_eng, _ = sharded_nvr(faults=killed, supervisor=Watchdog())
    sup_rep = sup_eng.serve(frames)
    assert len(sup_rep["dropped"]) <= len(rep["dropped"])


def hot_stream_trace():
    """One 30 fps camera on shard 0, one 1 fps camera on shard 1 — the
    single-hot-stream overload ``rebalance_streams`` rule 3 refuses to
    migrate (moving the only stream just relocates the overload)."""
    events = [(k / 30.0, 0, k) for k in range(240)]
    events += [(k + 0.5, 1, k) for k in range(8)]
    events.sort()
    return [FrameRequest(rid, np.zeros((4, 4, 3), np.float32), t,
                         stream_id=s)
            for rid, (t, s, k) in enumerate(events)]


def lending_engine(**kw):
    return ShardedDetectionEngine(detect_fn=stub_detect, n_replicas=2,
                                  service_time=0.1, drop_when_busy=True,
                                  micro_batch=1, max_micro_batch=1,
                                  n_shards=2, rebalance=True,
                                  epoch_s=2.0, **kw)


def test_replica_lending_strictly_reduces_drops():
    frames = hot_stream_trace()
    rep_no = lending_engine().serve(frames)
    assert not rep_no["migrations"]                  # stealing refused
    eng = lending_engine(supervisor=Watchdog(idle_backlog_s=0.5))
    rep_ln = eng.serve(frames)
    loans = rep_ln["faults"]["loans"]
    assert loans and all(ln["lender"] == 1 and ln["borrower"] == 0
                         for ln in loans)
    assert all(ln["returned_epoch"] is not None for ln in loans)
    assert len(rep_ln["dropped"]) < len(rep_no["dropped"])
    assert all(len(e.replicas) == 2 for e in eng.engines)
    # renumbered guest-replica ids stay within the high-water id space
    assert max(rep_ln["per_replica"]) >= 4           # pool high-water = 3+2
    assert set(rep_ln["per_replica"]) == set(range(5))


def test_lending_disabled_watchdog_is_inert():
    frames = hot_stream_trace()
    rep_no = lending_engine().serve(frames)
    rep_off = lending_engine(
        supervisor=Watchdog(lend=False)).serve(frames)
    assert rep_off["faults"]["loans"] == []
    assert len(rep_off["dropped"]) == len(rep_no["dropped"])
    assert [r.rid for r in rep_off["responses"]] == \
           [r.rid for r in rep_no["responses"]]


def test_seeded_random_chaos_end_to_end():
    sched = FaultSchedule.random(3, 6.0, n_shards=2, n_replicas=2,
                                 n_replica_events=2, n_shard_events=1)
    eng, frames = sharded_nvr(faults=sched, supervisor=Watchdog())
    r1, r2 = eng.serve(frames), eng.serve(frames)
    assert r1["faults"] == r2["faults"]
    assert [r.rid for r in r1["responses"]] == [r.rid
                                                for r in r2["responses"]]
    assert r1["recovered_coverage"] == r2["recovered_coverage"]
    # conservation: every frame is a response, a drop, or scheduler-lost
    lost = sum(r1["frames_lost"].values())
    assert len(r1["responses"]) + len(r1["dropped"]) + lost \
        >= len(frames)
