"""Cross-shard work stealing for sharded NVR serving, plus the
scheduler / engine-state correctness satellites that ride along.

Tentpole invariants: the ``rebalance_streams`` policy is a pure
deterministic function of load observations (multi-host replicas must
agree without coordinating); on a skewed trace work stealing strictly
reduces total drops while never costing ANY stream coverage; a
migrated stream's per-stream ``seq``/ordering and emit monotonicity
survive the epoch-boundary handoff; and ``rebalance=False`` (and
``n_shards=1``) stay bit-identical to the pre-stealing engine.

Satellite regressions (failing before / passing after):
``WeightedRRScheduler.assign``'s drop path used to throw away the
round bookkeeping its scan accumulated, freezing the Proportional
reweighting clock under total backlog; ``x or fallback`` patterns
silently discarded legitimately-zero service times; and virtual-clock
state leaked across repeated ``serve()`` calls."""
import numpy as np
import pytest

from repro.core import proxy_detect_fn_streams
from repro.core.scheduler import make_scheduler
from repro.serving import (DetectionEngine, FrameRequest, ReplicaExecutor,
                           ShardedDetectionEngine, make_nvr_streams,
                           make_skewed_streams, merge_shard_reports)
from repro.sharding import rebalance_streams, shard_streams
from test_sharded_serving import assert_reports_identical

SKEW_KW = dict(n_frames=12, rate=1.0)     # smoke-sized skewed trace
ENGINE_KW = dict(n_replicas=2, service_time=0.36)


def skewed_setup(n_shards, mode="drop", **kw):
    n_streams = 3 * n_shards
    frames, frame_of, videos, dets = make_skewed_streams(
        n_streams, n_shards=n_shards, **SKEW_KW)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    mode_kw = ({"drop_when_busy": True} if mode == "drop"
               else {"track_and_interpolate": True})
    return frames, dict(detect_fn=oracle, n_shards=n_shards,
                        **ENGINE_KW, **mode_kw, **kw)


# ------------------------------------------------- rebalance policy unit
def test_rebalance_streams_pure_and_deterministic():
    """Same observations -> same migration, input never mutated: the
    property that lets replicated dispatchers agree without talking."""
    of = {0: 0, 2: 0, 4: 0, 1: 1, 3: 1, 5: 1}
    loads = [{"drops": 7, "backlog_s": 2.5,
              "frames": {0: 16, 2: 16, 4: 16}},
             {"drops": 0, "backlog_s": 0.0, "frames": {1: 8, 3: 8, 5: 8}}]
    before = dict(of)
    a = rebalance_streams(of, loads)
    b = rebalance_streams(dict(reversed(list(of.items()))), loads)
    assert of == before                       # pure: no mutation
    assert a[0] == b[0] and a[1] == b[1]      # insertion-order free
    new_of, moves = a
    assert moves == [(0, 0, 1)]               # heaviest stream, lowest id
    assert new_of[0] == 1
    # the move strictly shrank the max observed per-shard load
    load = lambda h, part: sum(16 if s % 2 == 0 else 8
                               for s, hh in part.items() if hh == h)
    assert max(load(h, new_of) for h in (0, 1)) \
        < max(load(h, of) for h in (0, 1))


def test_rebalance_streams_stable_when_balanced_or_futile():
    """No pressure gradient -> no churn; a donor whose every move would
    just relocate the overload keeps its streams."""
    balanced = [{"drops": 0, "backlog_s": 0.0, "frames": {0: 8, 2: 8}},
                {"drops": 0, "backlog_s": 0.0, "frames": {1: 8, 3: 8}}]
    of = {0: 0, 2: 0, 1: 1, 3: 1}
    assert rebalance_streams(of, balanced) == (of, [])
    # single hot stream: moving it would make the receiver the donor
    hot = [{"drops": 9, "backlog_s": 4.0, "frames": {0: 32}},
           {"drops": 0, "backlog_s": 0.0, "frames": {1: 8}}]
    assert rebalance_streams({0: 0, 1: 1}, hot) == ({0: 0, 1: 1}, [])


# ------------------------------------------- skewed-trace acceptance bar
@pytest.mark.parametrize("n_shards", [2, 4])
def test_stealing_reduces_drops_and_never_costs_coverage(n_shards):
    """The PR acceptance bar: on the 2x-rate skewed trace, work
    stealing strictly reduces total drops vs the static partition and
    every stream's coverage is >= its static coverage."""
    frames, kw = skewed_setup(n_shards)
    static = ShardedDetectionEngine(**kw).serve(frames)
    steal = ShardedDetectionEngine(rebalance=True, epoch_s=4.0,
                                   **kw).serve(frames)
    assert len(static["dropped"]) > 0          # the trace really skews
    assert len(steal["dropped"]) < len(static["dropped"])
    for sid, v in static["per_stream"].items():
        assert steal["per_stream"][sid]["coverage"] >= v["coverage"], sid
    assert steal["migrations"], "no migration on a skewed trace"
    m = steal["migrations"][0]
    assert m["src"] == 0                       # the overloaded shard
    assert steal["shard_of_stream"][m["stream"]] == m["dst"]
    # per-stream drop accounting still sums to the global list
    assert sum(v["dropped"] for v in steal["per_stream"].values()) \
        == len(steal["dropped"])
    assert sum(v["frames"] for v in steal["per_stream"].values()) \
        == len(frames)


def test_migration_determinism_across_engines():
    """Two engines fed the same trace (same observations) must choose
    the same migrations and produce identical reports."""
    frames, kw = skewed_setup(2)
    outs = [ShardedDetectionEngine(rebalance=True, epoch_s=4.0,
                                   **kw).serve(frames) for _ in range(2)]
    a, b = outs
    assert a["migrations"] == b["migrations"]
    assert a["shard_of_stream"] == b["shard_of_stream"]
    assert a["dropped"] == b["dropped"]
    assert [(r.rid, r.replica, r.t_done) for r in a["responses"]] \
        == [(r.rid, r.replica, r.t_done) for r in b["responses"]]


def test_epoch_indices_stay_in_fixed_window_coordinates():
    """An empty burst-gap window is skipped for serving but still
    counted: recorded migration epochs and ``n_epochs`` stay in fixed
    ``epoch_s``-window coordinates, so ``t0 + (epoch + 1) * epoch_s``
    is the virtual time a move took effect even across gaps."""
    frames, kw = skewed_setup(2)
    base = ShardedDetectionEngine(rebalance=True, epoch_s=4.0,
                                  **kw).serve(frames)
    # open a one-window arrival gap after the first epoch
    shifted = [FrameRequest(f.rid, f.image,
                            f.t_arrival + (4.0 if f.t_arrival >= 4.0
                                           else 0.0), f.stream_id)
               for f in frames]
    out = ShardedDetectionEngine(rebalance=True, epoch_s=4.0,
                                 **kw).serve(shifted)
    assert base["n_epochs"] == 3 and out["n_epochs"] == 4
    assert [m["epoch"] for m in base["migrations"]] == [0]
    assert [m["epoch"] for m in out["migrations"]] == [0]


# --------------------------------------- migration ordering / handoff
def test_seq_order_and_emit_monotone_across_migration():
    """A migrated stream keeps its global per-stream ``seq`` (contiguous
    from 0 across the epoch boundary) and monotone emit clocks; track
    mode keeps full coverage through the handoff."""
    frames, kw = skewed_setup(2, mode="track")
    out = ShardedDetectionEngine(rebalance=True, epoch_s=4.0,
                                 **kw).serve(frames)
    assert out["migrations"]
    moved = out["migrations"][0]["stream"]
    per_sid_total = {}
    for f in frames:
        per_sid_total[f.stream_id] = per_sid_total.get(f.stream_id, 0) + 1
    for sid, rs in out["streams"].items():
        assert [r.seq for r in rs] == list(range(per_sid_total[sid])), sid
        em = out["emit_t"][sid]
        assert em == sorted(em), sid
        assert out["per_stream"][sid]["coverage"] == 1.0, sid
    # the migrated stream's responses span both shards' replica pools
    pools = {h: set(range(2 * h, 2 * h + 2)) for h in range(2)}
    used = {r.replica for r in out["streams"][moved] if r.replica >= 0}
    assert used & pools[0] and used & pools[1], used
    # rid stays the join key: every response maps back to its frame
    by_rid = {f.rid: f for f in frames}
    for r in out["responses"]:
        assert by_rid[r.rid].stream_id == r.stream_id


def test_stream_relabel_invariance_under_migration():
    """Relabeling cameras with an order-preserving map must not change
    WHAT the policy does — same drop counts, same migration structure,
    same per-stream coverages under the relabel map."""
    def run(relabel):
        frames, frame_of, videos, dets = make_skewed_streams(
            6, n_shards=2, **SKEW_KW)
        frames = [FrameRequest(f.rid, f.image, f.t_arrival,
                               relabel(f.stream_id)) for f in frames]
        frame_of = {rid: (relabel(s), k)
                    for rid, (s, k) in frame_of.items()}
        videos = {relabel(s): v for s, v in videos.items()}
        dets = {relabel(s): d for s, d in dets.items()}
        oracle = proxy_detect_fn_streams(videos, dets, frame_of)
        eng = ShardedDetectionEngine(n_shards=2, detect_fn=oracle,
                                     rebalance=True, epoch_s=4.0,
                                     drop_when_busy=True, **ENGINE_KW)
        return eng.serve(frames)
    a, b = run(lambda s: s), run(lambda s: s + 17)
    assert a["dropped"] == b["dropped"]        # rids are label-free
    assert [(m["epoch"], m["stream"] + 17, m["src"], m["dst"])
            for m in a["migrations"]] == \
        [(m["epoch"], m["stream"], m["src"], m["dst"])
         for m in b["migrations"]]
    for sid, v in a["per_stream"].items():
        assert b["per_stream"][sid + 17]["coverage"] == v["coverage"]


# ------------------------------------------------- bit-identity bars
@pytest.mark.parametrize("mode", ["drop", "track"])
def test_rebalance_off_bit_identical_to_static_partition(mode):
    """``rebalance=False`` must reproduce the pre-stealing engine
    exactly: per-shard DetectionEngines under the static partition +
    ``merge_shard_reports``, key for key, bit for bit."""
    frames, frame_of, videos, dets = make_nvr_streams(4, 10, rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    mode_kw = ({"drop_when_busy": True} if mode == "drop"
               else {"track_and_interpolate": True})
    kw = dict(n_replicas=1, service_time=0.3, **mode_kw)
    sh = ShardedDetectionEngine(n_shards=2, detect_fn=oracle,
                                rebalance=False, **kw).serve(frames)
    part = shard_streams(range(4), 2)
    subs = [[f for f in frames if part[f.stream_id] == h]
            for h in range(2)]
    reports = [DetectionEngine(detect_fn=oracle, **kw).serve(s)
               for s in subs]
    manual = merge_shard_reports(frames, reports, [1, 1])
    assert_reports_identical(manual, sh)
    assert "migrations" not in sh              # static path adds no keys


def test_single_shard_ignores_rebalance_flag():
    """``n_shards=1`` has no peer to steal from: rebalance=True must
    fall back to the static path, bit-identical to DetectionEngine."""
    frames, frame_of, videos, dets = make_nvr_streams(3, 8, rate=3.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(detect_fn=oracle, n_replicas=2, service_time=0.2,
              drop_when_busy=True)
    base = DetectionEngine(**kw).serve(frames)
    sh = ShardedDetectionEngine(n_shards=1, rebalance=True,
                                epoch_s=1.0, **kw).serve(frames)
    assert_reports_identical(base, sh)
    assert "migrations" not in sh


# =================================================== satellite regressions
# ---- 1. WRR drop path must not discard round bookkeeping ---------------
def test_wrr_drop_path_advances_round_clock():
    """Every failed full scan (all slots backlogged -> frame dropped)
    closes exactly one round; the old code threw the scan's bookkeeping
    away, so ``rounds_completed`` froze under total backlog."""
    execs = [ReplicaExecutor(i) for i in range(3)]
    wrr = make_scheduler("wrr", execs, weights=[1, 1, 1])
    for e in execs:
        e.busy_until = 1e9
    before = wrr.rounds_completed
    for i in range(5):
        assert wrr.assign(i, t=0.1 * i) is None
    assert wrr.rounds_completed == before + 5
    assert wrr.slot_idx == 0                   # drops never advance slots


def test_proportional_refreshes_weights_under_total_backlog():
    """Sustained overload — every arrival dropped — must still trigger
    the EWMA weight refresh within ``update_period`` scan-crossed
    rounds: runtime adaptation under backlog is the condition the
    Proportional policy exists for."""
    execs = [ReplicaExecutor(0, 1.0), ReplicaExecutor(1, 4.0)]
    sched = make_scheduler("proportional", execs, update_period=3)
    for e in execs:
        e.busy_until = 1e9
        e.ewma_service = 0.5
    for i in range(sched.update_period + 1):
        assert sched.assign(i, t=0.05 * i) is None
    assert sched.rounds_completed >= sched.update_period
    assert sched._last_refresh >= sched.update_period


# ---- 2. falsy-zero service times ---------------------------------------
def test_zero_cost_oracle_service_time_is_honored():
    """A pinned ``service_time=0.0`` must pin the virtual clock to
    zero — the old ``service_time or wall`` fell back to the measured
    wall, so 'free' frames consumed fake capacity and were dropped."""
    def oracle(images, rids=None):
        B = len(images)
        return (np.zeros((B, 4, 4), np.float32),
                np.zeros((B, 4), np.float32), np.zeros((B, 4), np.int32),
                np.zeros((B, 4), bool))
    frames = [FrameRequest(i, np.zeros((4, 4, 3), np.float32), i / 50.0)
              for i in range(20)]
    eng = DetectionEngine(detect_fn=oracle, n_replicas=1,
                          service_time=0.0, drop_when_busy=True)
    out = eng.serve(frames)
    assert out["dropped"] == []                # zero cost -> zero backlog
    assert all(r.service_s == 0.0 for r in out["responses"])
    assert all(r.t_done == r.t_start for r in out["responses"])
    assert all(r._last_wall == 0.0 for r in eng.replicas)


def test_mu_effective_and_refresh_honor_zero_ewma():
    """An EWMA of exactly 0.0 is a measurement, not missing data: both
    ``mu_effective`` and the Proportional reweighting must use it
    instead of falling back to configured walls."""
    fast, slow = ReplicaExecutor(0, 1.0), ReplicaExecutor(1, 4.0)
    fast.ewma_service = slow.ewma_service = 0.0
    assert fast.mu_effective == slow.mu_effective == 1e6
    sched = make_scheduler("proportional", [fast, slow])
    sched._refresh_weights()
    assert sched.weights == [1, 1]             # equal zero-cost rates


# ---- 3. per-serve state reset ------------------------------------------
def test_back_to_back_serves_produce_identical_reports():
    """Virtual-clock state must not leak across ``serve()`` calls: a
    second identical call used to inherit the first call's
    ``busy_until`` horizon (mass drops at t=0) and cumulative
    ``per_replica`` counts."""
    frames, frame_of, videos, dets = make_nvr_streams(3, 10, rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(detect_fn=oracle, n_replicas=2, service_time=0.3,
              track_and_interpolate=True)
    eng = DetectionEngine(**kw)
    first, second = eng.serve(frames), eng.serve(frames)
    assert_reports_identical(first, second)
    sharded = ShardedDetectionEngine(n_shards=2, **kw)
    first, second = sharded.serve(frames), sharded.serve(frames)
    assert_reports_identical(first, second)
    assert first["shard_of_stream"] == second["shard_of_stream"]


def test_per_replica_counts_are_per_call():
    frames, frame_of, videos, dets = make_nvr_streams(2, 6, rate=10.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    eng = DetectionEngine(detect_fn=oracle, n_replicas=2,
                          service_time=0.05)
    a, b = eng.serve(frames), eng.serve(frames)
    assert sum(a["per_replica"].values()) == len(frames)
    assert a["per_replica"] == b["per_replica"]  # not cumulative


# ---- backlog snapshot API (tentpole's observation surface) -------------
def test_backlog_snapshot_reads_residual_virtual_work():
    frames, frame_of, videos, dets = make_nvr_streams(2, 8, rate=20.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    eng = DetectionEngine(detect_fn=oracle, n_replicas=2,
                          service_time=0.5)
    before = eng.serve(frames, reset=True)
    t_end = max(f.t_arrival for f in frames)
    snap = eng.backlog_snapshot(t_end)
    # blocking mode queued everything: committed work extends past t_end
    assert snap["backlog_s"] > 0.0
    assert snap["horizon_s"] == max(snap["busy_until"]) - t_end
    assert snap["backlog_s"] == pytest.approx(sum(
        max(0.0, b - t_end) for b in snap["busy_until"]))
    eng.reset()
    assert eng.backlog_snapshot(0.0)["backlog_s"] == 0.0
    assert before["coverage"] == 1.0
