"""Model-substrate correctness: decode/train equivalence, MoE dispatch vs
dense reference, ring-buffer positions, RoPE properties, sharding rules.
``hypothesis`` is optional: property tests fall back to fixed
parametrizations without it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional dep — see requirements-dev.txt
    given = None

from repro.configs import ARCH_IDS, get_config
from repro.models import init_cache, init_model, model_apply
from repro.models.attention import _ring_positions
from repro.models.config import ModelConfig, MoEConfig, dense_stages
from repro.models.rope import apply_rope

F32_ARCHS = [a for a in ARCH_IDS if a != "hubert-xlarge"]


# ---------------------------------------------- decode == full-forward
@pytest.mark.parametrize("arch", F32_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    """Prefill S tokens then decode token S must equal the full (S+1)-token
    forward's last-position logits (cache correctness across every mixer
    family: GQA, MLA, SWA, Mamba, RWKV6)."""
    cfg = get_config(arch, preset="smoke")
    if cfg.moe:
        # capacity-dropping is sequence-global (prefill-length dependent);
        # ample capacity isolates the cache-correctness property
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 48
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size - 1, (B, S + 1)),
                       jnp.int32)
    batch_in = {"tokens": toks}
    if cfg.modality == "vlm":
        n_img = 8
        batch_in = {"tokens": toks[:, n_img:],
                    "image_embeds": jnp.asarray(
                        rng.standard_normal((B, n_img, cfg.frontend_dim)),
                        jnp.float32)}
    # full forward over S+1 tokens
    logits_full, _, _ = model_apply(params, cfg, batch_in, mode="train")

    # prefill S, then decode one token
    pre_in = {"tokens": toks[:, :S]}
    if cfg.modality == "vlm":
        pre_in = {"tokens": batch_in["tokens"][:, :-1],
                  "image_embeds": batch_in["image_embeds"]}
    cache = init_cache(cfg, B, S + 8)
    _, cache, _ = model_apply(params, cfg, pre_in, mode="prefill",
                              cache=cache)
    logits_dec, _, _ = model_apply(
        params, cfg, {"tokens": toks[:, S:S + 1]}, mode="decode",
        cache=cache, decode_pos=jnp.asarray(S, jnp.int32))

    assert_allclose(np.asarray(logits_dec[:, 0]),
                    np.asarray(logits_full[:, -1]), rtol=2e-4, atol=2e-4)


def test_swa_decode_ring_buffer_matches_windowed_forward():
    """Decoding past the window with the ring buffer == full forward with a
    sliding-window mask (the long_500k mechanism)."""
    cfg = get_config("mistral-nemo-12b", preset="smoke").replace(
        decode_window=16)
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 1, 40
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size - 1, (B, S + 1)),
                       jnp.int32)
    # reference: full forward WITH window masks on every layer
    import dataclasses
    from repro.models.config import LayerSpec, Stage
    win_stages = tuple(
        Stage(tuple(dataclasses.replace(l, window=16) for l in s.pattern),
              s.repeats) for s in cfg.stages)
    cfg_win = cfg.replace(stages=win_stages)
    logits_full, _, _ = model_apply(params, cfg_win,
                                    {"tokens": toks}, mode="train")
    # ring-buffer path: prefill S then decode (cache length = window 16)
    cache = init_cache(cfg, B, S)
    _, cache, _ = model_apply(params, cfg, {"tokens": toks[:, :S]},
                              mode="prefill", cache=cache)
    # stacked cache layout: (repeats, batch, window, kv, head_dim)
    assert cache[0]["caches"][0]["mixer"]["k"].shape[2] == 16
    logits_dec, _, _ = model_apply(params, cfg, {"tokens": toks[:, S:]},
                                   mode="decode", cache=cache,
                                   decode_pos=jnp.asarray(S, jnp.int32))
    assert_allclose(np.asarray(logits_dec[:, 0]),
                    np.asarray(logits_full[:, -1]), rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- ring buffer
def _check_ring_positions(L, n):
    k_pos, valid = jax.jit(_ring_positions, static_argnums=0)(
        L, jnp.asarray(n))
    k_pos, valid = np.asarray(k_pos), np.asarray(valid)
    for s in range(L):
        # slot s holds the largest position p < n with p % L == s
        cands = [p for p in range(max(0, n - L), n) if p % L == s]
        if cands:
            assert valid[s] and k_pos[s] == cands[-1]
        else:
            assert not valid[s]


if given is not None:
    @settings(max_examples=50, deadline=None)
    @given(L=st.integers(1, 64), n=st.integers(1, 300))
    def test_ring_positions_properties(L, n):
        _check_ring_positions(L, n)
else:
    @pytest.mark.parametrize("L,n", [
        (1, 1), (1, 300), (64, 1), (64, 63), (64, 64), (64, 65),
        (16, 256), (7, 300), (33, 40)])
    def test_ring_positions_properties(L, n):
        _check_ring_positions(L, n)


# ------------------------------------------------------------------ MoE
def test_moe_matches_dense_per_token_reference():
    """Sort-based capacity dispatch == naive per-token top-k loop when
    capacity is ample."""
    from repro.models import moe as moe_mod
    cfg = ModelConfig(
        name="t", d_model=32, d_ff=64, vocab_size=64,
        stages=dense_stages(1, ffn="moe"), n_heads=2, n_kv_heads=2,
        head_dim=16, moe=MoEConfig(n_experts=4, top_k=2, d_ff=64,
                                   capacity_factor=8.0))
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out, aux = moe_mod.apply_moe(p, cfg, x)

    # naive reference
    xf = x.reshape(-1, 32)
    logits = xf @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(2):
            e = int(idx[t, j])
            we = p["experts"]
            h = jax.nn.silu(xf[t] @ we["w_gate"][e]) * (xf[t] @ we["w_up"][e])
            ref = ref.at[t].add(w[t, j] * (h @ we["w_down"][e]))
    assert_allclose(np.asarray(out.reshape(-1, 32)), np.asarray(ref),
                    rtol=2e-4, atol=2e-5)
    assert float(aux["load_balance"]) > 0.5   # ~1.0 for balanced routing


def test_moe_capacity_drops_overflow_tokens():
    from repro.models import moe as moe_mod
    cfg = ModelConfig(
        name="t", d_model=16, d_ff=32, vocab_size=64,
        stages=dense_stages(1, ffn="moe"), n_heads=2, n_kv_heads=2,
        head_dim=8, moe=MoEConfig(n_experts=2, top_k=1, d_ff=32,
                                  capacity_factor=0.25))
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    out, _ = moe_mod.apply_moe(p, cfg, x)
    # some tokens must be dropped (zero contribution)
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert int(jnp.sum(norms == 0.0)) > 0


# ------------------------------------------------------------------ RoPE
def _check_rope_relative(shift):
    """<rope(q,p1), rope(k,p2)> depends only on p1-p2 (full variant)."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 64))
    def dot_at(p1, p2):
        qr = apply_rope(q, jnp.array([[p1]]), 1e4, "full")
        kr = apply_rope(k, jnp.array([[p2]]), 1e4, "full")
        return float(jnp.sum(qr * kr))
    assert dot_at(5, 3) == pytest.approx(dot_at(5 + shift, 3 + shift),
                                         rel=1e-4, abs=1e-4)


if given is not None:
    @settings(max_examples=20, deadline=None)
    @given(shift=st.integers(0, 64))
    def test_rope_relative_property(shift):
        _check_rope_relative(shift)
else:
    @pytest.mark.parametrize("shift", [0, 1, 7, 31, 64])
    def test_rope_relative_property(shift):
        _check_rope_relative(shift)


# ------------------------------------------------------------- sharding
def test_param_sharding_rules_divisibility():
    """Every resolved spec must divide the dim it shards (all archs, both
    production meshes)."""
    from repro.sharding.rules import param_specs
    import jax.sharding as jsh
    devs = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    for axes in (("data", "model"), ("pod", "data", "model")):
        sizes = {"pod": 2, "data": 16, "model": 16}
        mesh_devs = np.empty([sizes[a] for a in axes], object)
        mesh = jsh.Mesh(
            np.tile(np.array(jax.devices()[:1]),
                    int(np.prod([sizes[a] for a in axes]))).reshape(
                [sizes[a] for a in axes]), axes)
        for arch in ARCH_IDS:
            cfg = get_config(arch, "full")
            struct = jax.eval_shape(
                lambda k, c=cfg: init_model(c, k),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            specs = param_specs(struct, mesh)
            flat = jax.tree_util.tree_flatten_with_path(
                (struct, specs))[0]
            leaves = jax.tree.leaves(struct)
            spec_leaves = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, jsh.PartitionSpec))
            assert len(leaves) == len(spec_leaves)
            for leaf, spec in zip(leaves, spec_leaves):
                for dim, entry in zip(leaf.shape, tuple(spec)):
                    if entry is None:
                        continue
                    axs = entry if isinstance(entry, tuple) else (entry,)
                    total = int(np.prod([mesh.shape[a] for a in axs]))
                    assert dim % total == 0, (arch, leaf.shape, spec)


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    from repro.optim import AdamWConfig
    from repro.runtime import train_state_init
    from repro.runtime.checkpoint import (checkpoint_step,
                                          restore_checkpoint,
                                          save_checkpoint)
    cfg = get_config("qwen3-4b", preset="smoke")
    state = train_state_init(cfg, jax.random.PRNGKey(0), AdamWConfig())
    path = tmp_path / "ckpt"
    save_checkpoint(path, state, step=7)
    assert checkpoint_step(path) == 7
    restored = restore_checkpoint(path, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert_allclose(np.asarray(a), np.asarray(b))


# ------------------------------------------- chunked == naive attention
@pytest.mark.parametrize("kv", [1, 2, 4, 8])
@pytest.mark.parametrize("window", [None, 1500])
def test_chunked_attention_matches_naive(kv, window):
    """The flash-style chunked online-softmax path (used for train/prefill
    at production lengths) must equal the naive masked softmax.  (The
    hypothesis strategy here only sampled from these same fixed choices,
    so a plain parametrization covers the full domain.)"""
    from repro.models.attention import _sdpa_chunked, make_mask, sdpa
    B, T, H, D = 1, 1024, 8, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, kv, D))
    v = jax.random.normal(ks[2], (B, T, kv, D))
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    import repro.models.attention as A
    oq, ok_ = A.Q_CHUNK, A.K_CHUNK
    A.Q_CHUNK, A.K_CHUNK = 256, 256
    try:
        got = _sdpa_chunked(q, k, v, pos, pos, True, window, D ** -0.5)
    finally:
        A.Q_CHUNK, A.K_CHUNK = oq, ok_
    want = sdpa(q, k, v, make_mask(pos, pos, True, window), D ** -0.5)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
