"""Unit tests for the trip-count-aware HLO analyzer (repro.hlo): the
machinery behind the roofline's FLOPs / bytes / collective terms."""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.hlo import HloAnalysis, _parse_instr, hlo_cost_from_text

HLO = textwrap.dedent("""\
    HloModule test

    %body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add.1
      ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i, %ar)
    }

    %cond.1 (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      ROOT %lt = pred[] constant(true)
    }

    %add.1 (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
      %arg = f32[8,16]{1,0} parameter(0)
      %c = s32[] constant(0)
      %tup = (s32[], f32[8,16]{1,0}) tuple(%c, %arg)
      %wh = (s32[], f32[8,16]{1,0}) while(%tup), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
    }
""")


def test_while_trip_count_multiplies_flops_and_collectives():
    t = HloAnalysis(HLO).totals()
    # dot: 2 * 8*16 * 16 = 4096 flops, x5 trips
    assert t["flops"] == 4096 * 5
    # all-reduce operand: 8*16*4 bytes = 512, x5
    assert t["by_kind"]["all-reduce"] == 512 * 5
    assert t["unknown_trip_counts"] == 0


def test_parse_instr_handles_tuple_types_with_comments():
    line = ("  %while.270 = (s32[], f32[16,36,256]{1,0,2}, "
            "/*index=5*/bf16[16,256,36,64]{3,2,0,1}) while(%tup), "
            "condition=%c, body=%b")
    name, typ, op = _parse_instr(line)
    assert name == "while.270" and op == "while"
    assert "bf16[16,256,36,64]" in typ


def test_analyzer_tracks_real_jax_matmul_flops():
    """End-to-end: analyzer flops on a compiled jax program matches the
    analytic matmul count."""
    @jax.jit
    def f(a, b):
        def body(c, _):
            return c @ b, None
        c, _ = jax.lax.scan(body, a, None, length=7)
        return c
    a = jnp.zeros((32, 64), jnp.float32)
    b = jnp.zeros((64, 64), jnp.float32)
    txt = jax.jit(f).lower(a, b).compile().as_text()
    got = hlo_cost_from_text(txt)
    expect = 2 * 32 * 64 * 64 * 7
    assert abs(got["flops"] - expect) / expect < 0.05, got
