"""Edge-case coverage for core/stream.py and core/synchronizer.py:
out-of-order completion, burst arrivals, 100%-drop intervals, leading
drops, and the batched ground-truth fetch."""
import numpy as np

from repro.core import (DEVICE_PROFILES, MODEL_PROFILES, DetectorExecutor,
                        FrameStream, SequenceSynchronizer, SyntheticVideo,
                        VideoSpec, make_scheduler, simulate)
from repro.core.scheduler import Assignment
from repro.core.simulator import SimResult
from repro.core.stream import ETH_SUNNYDAY


def _result(assignments, dropped, n):
    return SimResult("t", 10.0, assignments, dropped, n,
                     max((a.t_done for a in assignments), default=0.0))


# ------------------------------------------------------- synchronizer
def test_out_of_order_completion_reorders_and_monotonic_stream():
    """Executors finishing out of temporal order: frame 2 completes
    before frame 1; the synchronizer re-establishes index order and the
    streaming interface never emits with a decreasing clock."""
    a = [Assignment(0, 0, 0.0, 0.3),
         Assignment(1, 1, 0.1, 0.9),       # slow replica
         Assignment(2, 0, 0.3, 0.5),       # done before frame 1
         Assignment(3, 1, 0.9, 1.1)]
    r = _result(a, [], 4)
    synced = SequenceSynchronizer().order(r)
    assert [s.index for s in synced] == [0, 1, 2, 3]
    assert [s.t_ready for s in synced] == [0.3, 0.9, 0.5, 1.1]
    streamed = list(SequenceSynchronizer().stream(r))
    emits = [s.t_ready for s in streamed]
    assert emits == sorted(emits)          # reorder buffer: monotonic
    assert emits[2] == 0.9                 # frame 2 held behind frame 1


def test_total_drop_interval_reuses_last_processed():
    """A 100%-drop interval (every executor busy for a stretch): all
    frames in the gap are stale fills from the last processed frame."""
    a = [Assignment(i, 0, i * 0.1, i * 0.1 + 0.05) for i in range(3)]
    a += [Assignment(9, 0, 0.9, 0.95)]
    r = _result(a, list(range(3, 9)), 10)
    synced = SequenceSynchronizer().order(r)
    for s in synced[3:9]:
        assert s.stale and s.source_index == 2
        assert s.t_ready == synced[2].t_ready
    assert not synced[9].stale and synced[9].source_index == 9


def test_leading_drops_have_no_source():
    """Frames dropped before anything was processed have nothing to
    reuse: source_index -1.  order_tracked still tags them interpolated
    — the tracker emits its (empty) coasted table for them, never a
    replay."""
    a = [Assignment(3, 0, 0.3, 0.4), Assignment(4, 0, 0.4, 0.5)]
    r = _result(a, [0, 1, 2], 5)
    sync = SequenceSynchronizer()
    synced = sync.order(r)
    for s in synced[:3]:
        assert s.stale and s.source_index == -1 and s.t_ready == 0.0
    tagged = sync.order_tracked(r)
    assert [s.interpolated for s in tagged] == [True] * 3 + [False] * 2


def test_stream_preserves_interpolated_tagging():
    """Regression: ``stream`` re-yielded frames with the default
    ``interpolated=False`` (dropping the flag) — with ``tracked=True``
    it must emit the same tagging as ``order_tracked`` while keeping
    the monotonic emit clock."""
    a = [Assignment(0, 0, 0.0, 0.6), Assignment(2, 0, 0.6, 0.8)]
    r = _result(a, [1, 3], 4)
    sync = SequenceSynchronizer()
    tagged = sync.order_tracked(r)
    streamed = list(sync.stream(r, tracked=True))
    assert [s.interpolated for s in streamed] == \
        [s.interpolated for s in tagged] == [False, True, False, True]
    assert [s.stale for s in streamed] == [s.stale for s in tagged]
    emits = [s.t_ready for s in streamed]
    assert emits == sorted(emits)
    # the untracked path still reports no interpolation
    assert all(not s.interpolated for s in sync.stream(r))


def test_everything_dropped():
    r = _result([], list(range(5)), 5)
    sync = SequenceSynchronizer()
    synced = sync.order(r)
    assert all(s.source_index == -1 and s.stale for s in synced)
    assert sync.output_fps(r) == 0.0


def test_burst_arrivals_conserve_frames():
    """All frames arriving in one burst (arrival_rate >> mu): every
    frame is processed once or dropped once, causality holds, and the
    synchronizer still covers the full index range."""
    video = SyntheticVideo(VideoSpec("t", 10.0, 60, 320, 240, False, 4, 1))
    execs = [DetectorExecutor(DEVICE_PROFILES["ncs2"],
                              MODEL_PROFILES["yolov3"]) for _ in range(2)]
    r = simulate(FrameStream(video), make_scheduler("fcfs", execs),
                 arrival_rate=1e6)
    assert len(r.assignments) + len(r.dropped) == 60
    assert set(r.processed_indices).isdisjoint(r.dropped)
    assert len(r.dropped) > 40                 # burst overwhelms 2 sticks
    for a in r.assignments:
        assert a.t_done > a.t_start >= 0.0
    synced = SequenceSynchronizer().order(r)
    assert [s.index for s in synced] == list(range(60))


def test_output_fps_counts_fresh_frames_only():
    a = [Assignment(0, 0, 0.0, 0.5), Assignment(2, 0, 0.5, 1.0)]
    r = _result(a, [1, 3], 4)
    assert SequenceSynchronizer().output_fps(r) == 2 / 1.0


# ------------------------------------------------------------- stream
def test_boxes_at_many_matches_boxes_at():
    video = SyntheticVideo(ETH_SUNNYDAY)
    idx = np.array([0, 1, 7, 100, 353])
    batched = video.boxes_at_many(idx)
    for k, i in enumerate(idx):
        assert np.allclose(batched[k], video.boxes_at(int(i)))


def test_bounce_keeps_objects_in_frame():
    video = SyntheticVideo(ETH_SUNNYDAY)
    W, H = video.spec.width, video.spec.height
    for i in (0, 100, 1000, 5000):
        b = video.boxes_at(i)
        c = (b[:, :2] + b[:, 2:]) / 2
        assert (c[:, 0] >= 0).all() and (c[:, 0] <= W).all()
        assert (c[:, 1] >= 0).all() and (c[:, 1] <= H).all()


def test_frame_stream_arrival_clock():
    video = SyntheticVideo(ETH_SUNNYDAY)
    frames = list(FrameStream(video))
    assert len(frames) == video.spec.n_frames
    assert frames[14].t_arrival == 14 / video.spec.fps
    assert frames[0].boxes.shape == (video.spec.n_objects, 4)
