"""Guard the dry-run deliverable: every (arch x shape x mesh) artifact
exists, compiled without error (or is a documented skip), and feeds the
roofline.  (The artifacts are produced by `python -m repro.launch.dryrun
--arch all --shape all --mesh both`, which needs its own process because
it pins 512 host devices before jax init.)"""
import json
from pathlib import Path

import pytest

from repro.configs import ARCH_IDS, SHAPES

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

pytestmark = pytest.mark.skipif(
    not DRYRUN.exists() or not list(DRYRUN.glob("*.json")),
    reason="dry-run artifacts not generated (run repro.launch.dryrun)")


def _load(arch, shape, mesh):
    f = DRYRUN / f"{arch}__{shape}__{mesh}.json"
    assert f.exists(), f"missing artifact {f.name}"
    return json.loads(f.read_text())


@pytest.mark.parametrize("mesh", ["single", "multi"])
@pytest.mark.parametrize("shape", list(SHAPES))
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_pair_compiled_or_documented_skip(arch, shape, mesh):
    d = _load(arch, shape, mesh)
    assert "error" not in d, d.get("error")
    if d.get("skipped"):
        assert arch == "hubert-xlarge" and shape in ("decode_32k",
                                                     "long_500k")
        return
    assert d["memory"]["argument_size_in_bytes"] > 0
    assert d["hlo_cost"]["flops"] > 0
    assert d["collectives"]["unknown_trip_counts"] == 0
    mesh_size = 1
    for v in d["mesh"].values():
        mesh_size *= v
    assert mesh_size == (512 if mesh == "multi" else 256)


def test_roofline_covers_all_compiled_pairs():
    import sys
    sys.path.insert(0, str(DRYRUN.parents[1]))
    from benchmarks import roofline
    rows = roofline.table("single")
    # 10 archs x 4 shapes - 2 hubert decode skips = 38
    assert len(rows) == 38
    for r in rows:
        assert r["dominant"] in ("compute", "memory", "collective")
        assert r["compute_s"] >= 0 and r["memory_s"] > 0
        assert 0 <= r["useful_ratio"] < 50


def test_multi_pod_shards_the_pod_axis():
    """Multi-pod per-device argument bytes must be at most ~single-pod
    (the pod axis halves the per-device footprint for sharded inputs)."""
    for arch in ("minicpm-2b", "grok-1-314b"):
        s = _load(arch, "train_4k", "single")["memory"]
        m = _load(arch, "train_4k", "multi")["memory"]
        assert m["argument_size_in_bytes"] <= s["argument_size_in_bytes"] \
            * 0.75
