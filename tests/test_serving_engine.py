"""Serving-engine behaviour: real compute + the paper's scheduling
semantics over model replicas — token requests and micro-batched video
frames."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import (DetectionEngine, FrameRequest, Request,
                           ServingEngine)


def burst(cfg, n, rate, seed=0, new_tokens=3):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size - 1, 8)
                    .astype(np.int32), new_tokens, i / rate)
            for i in range(n)]


@pytest.fixture(scope="module")
def cfg():
    return get_config("minicpm-2b", preset="smoke")


def test_serving_responses_in_arrival_order(cfg):
    eng = ServingEngine(cfg, n_replicas=3, scheduler="fcfs", cache_len=32)
    out = eng.serve(burst(cfg, 9, rate=200.0))
    assert [r.rid for r in out["responses"]] == list(range(9))
    assert len(out["dropped"]) == 0
    assert all(len(r.tokens) == 3 for r in out["responses"])


def test_serving_deterministic_tokens_across_schedulers(cfg):
    """The scheduler decides placement/time, never the model output."""
    outs = {}
    for sched in ("fcfs", "rr"):
        eng = ServingEngine(cfg, n_replicas=2, scheduler=sched,
                            cache_len=32)
        outs[sched] = eng.serve(burst(cfg, 6, rate=100.0))
    for a, b in zip(outs["fcfs"]["responses"], outs["rr"]["responses"]):
        assert np.array_equal(a.tokens, b.tokens)


def test_replica_scaling_increases_throughput(cfg):
    rates = {}
    for n in (1, 4):
        eng = ServingEngine(cfg, n_replicas=n, scheduler="fcfs",
                            cache_len=32)
        rates[n] = eng.serve(burst(cfg, 12, rate=1e4))["throughput_rps"]
    assert rates[4] > 2.0 * rates[1]


def test_serve_empty_request_list(cfg):
    """Regression: ``serve([])`` used to crash in ``warmup`` on
    ``max()`` over an empty sequence; it must return an empty report,
    mirroring ``DetectionEngine``."""
    eng = ServingEngine(cfg, n_replicas=2, scheduler="fcfs", cache_len=32)
    out = eng.serve([])
    assert out["responses"] == [] and out["dropped"] == []
    assert out["throughput_rps"] == 0.0 and out["p50_latency"] == 0.0
    assert set(out["per_replica"]) == {0, 1}


def test_drop_when_busy_mode(cfg):
    eng = ServingEngine(cfg, n_replicas=1, scheduler="fcfs", cache_len=32,
                        drop_when_busy=True)
    out = eng.serve(burst(cfg, 12, rate=1e5))
    assert len(out["dropped"]) > 0
    assert len(out["dropped"]) + len(out["responses"]) == 12


# ---------------------------------------------- detection (frame) payloads
def frame_burst(n, rate, seed=0):
    from repro.core import SyntheticVideo
    from repro.core.stream import ETH_SUNNYDAY
    video = SyntheticVideo(ETH_SUNNYDAY)
    return [FrameRequest(i, video.pixels(i), i / rate) for i in range(n)]


def test_detection_engine_micro_batches_preserve_order():
    eng = DetectionEngine(n_replicas=2, micro_batch=4)
    out = eng.serve(frame_burst(10, rate=100.0))
    assert [r.rid for r in out["responses"]] == list(range(10))
    assert out["throughput_fps"] > 0
    for r in out["responses"]:
        assert r.boxes.shape[-1] == 4 and r.valid.dtype == bool
        assert r.scores.shape == r.valid.shape
    # every frame landed on a real replica
    assert sum(out["per_replica"].values()) == 10


def test_detection_engine_batching_matches_per_frame_results():
    """Micro-batch size must not change detections: the batched NMS is
    per-frame exact, so serving with mb=1 and mb=5 gives identical
    valid-masked outputs."""
    import jax
    from repro.detector import SSDConfig, init_ssd
    cfg = SSDConfig()
    params = init_ssd(cfg, jax.random.PRNGKey(0))
    frames = frame_burst(5, rate=50.0)
    outs = {}
    for mb in (1, 5):
        eng = DetectionEngine(cfg=cfg, params=params, n_replicas=2,
                              micro_batch=mb)
        outs[mb] = eng.serve(frames)["responses"]
    for a, b in zip(outs[1], outs[5]):
        assert np.array_equal(a.valid, b.valid)
        assert np.array_equal(a.boxes[a.valid], b.boxes[b.valid])
        assert np.array_equal(a.classes[a.valid], b.classes[b.valid])
