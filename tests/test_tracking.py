"""Tracking subsystem: association-kernel bit-compatibility, Kalman
behaviour, track lifecycle (birth/confirm/coast/kill), dropped-frame
interpolation quality, and the serving engine's track-and-interpolate
mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (ParallelDetector, ProxyDetector,
                        SequenceSynchronizer, SyntheticVideo,
                        evaluate_map, evaluate_map_dets, track_quality)
from repro.core.quality import proxy_detect_fn, responses_to_detections
from repro.core.simulator import simulate
from repro.core.stream import ETH_SUNNYDAY, FrameStream
from repro.kernels import ops, ref
from repro.tracking import (TrackerConfig, coast, fill_stream, init_state,
                            output, step)


# ------------------------------------------------- association kernel
def _rand_assoc(rng, B, T, D):
    def boxes(n):
        tl = rng.uniform(0, 400, (B, n, 2))
        wh = rng.uniform(10, 80, (B, n, 2))
        return jnp.asarray(np.concatenate([tl, tl + wh], -1), jnp.float32)
    return (boxes(T), boxes(D),
            jnp.asarray(rng.random((B, T)) > 0.3),
            jnp.asarray(rng.random((B, D)) > 0.3),
            jnp.asarray(rng.integers(0, 3, (B, T)), jnp.int32),
            jnp.asarray(rng.integers(0, 3, (B, D)), jnp.int32))


@pytest.mark.parametrize("B,T,D", [(3, 8, 5), (2, 5, 9), (4, 16, 16),
                                   (1, 1, 1), (2, 7, 3)])
def test_greedy_assign_bit_compat(B, T, D):
    """Pallas kernel and XLA twin must match the oracle exactly."""
    rng = np.random.default_rng(B * 100 + T * 10 + D)
    tb, db, tm, dm, tc, dc = _rand_assoc(rng, B, T, D)
    kw = dict(t_mask=tm, d_mask=dm, t_cls=tc, d_cls=dc, iou_thr=0.2)
    r = np.asarray(ref.greedy_assign_ref(tb, db, tm, dm, tc, dc, 0.2))
    x = np.asarray(ops.greedy_assign(tb, db, use_pallas=False, **kw))
    p = np.asarray(ops.greedy_assign(tb, db, use_pallas=True, **kw))
    assert np.array_equal(x, r)
    assert np.array_equal(p, r)


def test_greedy_assign_semantics():
    """Best pair wins first; class mismatch forbids a match; a retired
    column can't be claimed twice."""
    tb = jnp.asarray([[[0, 0, 10, 10], [20, 0, 30, 10]]], jnp.float32)
    # det 0 overlaps track 0 strongly and track 1 not at all; det 1
    # overlaps both weakly but clears the gate only for track 1
    db = jnp.asarray([[[1, 0, 11, 10], [21, 2, 31, 12]]], jnp.float32)
    m = np.asarray(ops.greedy_assign(tb, db, use_pallas=False))
    assert m.tolist() == [[0, 1]]
    # class gate: track 0 is class 1, detections class 0 -> only track 1
    m = np.asarray(ops.greedy_assign(
        tb, db, t_cls=jnp.asarray([[1, 0]]),
        d_cls=jnp.asarray([[0, 0]]), use_pallas=False))
    assert m.tolist() == [[-1, 1]]
    # dead track slot never matches
    m = np.asarray(ops.greedy_assign(
        tb, db, t_mask=jnp.asarray([[False, True]]), use_pallas=False))
    assert m.tolist() == [[-1, 1]]


# ------------------------------------------------------ tracker core
def _one_det(cx, cy, w=20.0, h=30.0, score=0.9, cls=1, cap=8):
    boxes = np.zeros((1, cap, 4), np.float32)
    scores = np.zeros((1, cap), np.float32)
    classes = np.zeros((1, cap), np.int32)
    valid = np.zeros((1, cap), bool)
    boxes[0, 0] = [cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2]
    scores[0, 0] = score
    classes[0, 0] = cls
    valid[0, 0] = True
    return (jnp.asarray(boxes), jnp.asarray(scores), jnp.asarray(classes),
            jnp.asarray(valid))


def test_kalman_learns_constant_velocity():
    """After a few noiseless updates the filter's coasted prediction
    follows the object's true constant-velocity path."""
    cfg = TrackerConfig(capacity=8)
    state = init_state(1, cfg)
    vx, vy = 3.0, -2.0
    for i in range(6):
        state, _ = step(state, *_one_det(100 + vx * i, 200 + vy * i), cfg)
    vel = np.asarray(state.vel)[0, 0]
    assert abs(vel[0] - vx) < 0.2 and abs(vel[1] - vy) < 0.2
    for k in range(1, 4):                       # coast 3 frames
        state = coast(state, cfg)
        b, _, _, _, emit = (np.asarray(a) for a in output(state, cfg))
        assert emit[0, 0]
        cx = (b[0, 0, 0] + b[0, 0, 2]) / 2
        cy = (b[0, 0, 1] + b[0, 0, 3]) / 2
        assert abs(cx - (100 + vx * (5 + k))) < 1.0
        assert abs(cy - (200 + vy * (5 + k))) < 1.0


def test_lifecycle_birth_confirm_coast_kill():
    cfg = TrackerConfig(capacity=4, min_hits=2, max_coast=3)
    state = init_state(1, cfg)
    # birth: first detection creates an unconfirmed (silent) track
    state, det_tid = step(state, *_one_det(50, 50), cfg)
    assert int(np.asarray(det_tid)[0, 0]) == 0
    assert int(state.active.sum()) == 1
    assert not bool(np.asarray(output(state, cfg)[-1]).any())
    # confirm: second match makes it emittable
    state, _ = step(state, *_one_det(52, 51), cfg)
    assert bool(np.asarray(output(state, cfg)[-1])[0, 0])
    # coast: emitted while within max_coast...
    for _ in range(cfg.max_coast):
        state = coast(state, cfg)
        assert bool(np.asarray(output(state, cfg)[-1])[0, 0])
    # ...then killed
    state = coast(state, cfg)
    assert int(state.active.sum()) == 0
    # the freed slot is reused with a fresh id
    state, det_tid = step(state, *_one_det(300, 300), cfg)
    assert int(state.active.sum()) == 1
    assert int(np.asarray(det_tid)[0, 0]) == 1


def test_unconfirmed_false_positive_stays_silent():
    """A one-off false positive births a track that never confirms and
    is never emitted."""
    cfg = TrackerConfig(capacity=4, min_hits=2, max_coast=2)
    state = init_state(1, cfg)
    state, _ = step(state, *_one_det(50, 50), cfg)
    for _ in range(3):
        state = coast(state, cfg)
        assert not bool(np.asarray(output(state, cfg)[-1]).any())
    assert int(state.active.sum()) == 0


def test_capacity_overflow_is_masked():
    """More unmatched detections than free slots: the extras are simply
    not born (masked update), nothing corrupts the table."""
    cfg = TrackerConfig(capacity=2)
    state = init_state(1, cfg)
    boxes = np.zeros((1, 4, 4), np.float32)
    for d in range(4):
        boxes[0, d] = [100 * d, 0, 100 * d + 20, 30]
    scores = np.full((1, 4), 0.9, np.float32)
    classes = np.zeros((1, 4), np.int32)
    valid = np.ones((1, 4), bool)
    state, det_tid = step(state, jnp.asarray(boxes), jnp.asarray(scores),
                          jnp.asarray(classes), jnp.asarray(valid), cfg)
    assert int(state.active.sum()) == 2
    assert (np.asarray(det_tid)[0] >= 0).sum() == 2


def test_full_table_evicts_lowest_score_coasting_track():
    """Regression: with zero free slots, new detections were silently
    dropped (det_tid -1, no birth).  Now the lowest-score COASTING
    track is evicted to make room, matched tracks are never touched,
    and the newborn claims the evicted slot."""
    cfg = TrackerConfig(capacity=3, iou_thr=0.3, min_hits=1)
    state = init_state(1, cfg)
    # fill the table: three tracks with distinct scores
    boxes = np.zeros((1, 3, 4), np.float32)
    for d, (x, s) in enumerate(zip((0, 200, 400), (0.9, 0.4, 0.7))):
        boxes[0, d] = [x, 0, x + 20, 30]
    scores = np.asarray([[0.9, 0.4, 0.7]], np.float32)
    classes = np.zeros((1, 3), np.int32)
    valid = np.ones((1, 3), bool)
    state, _ = step(state, jnp.asarray(boxes), jnp.asarray(scores),
                    jnp.asarray(classes), jnp.asarray(valid), cfg)
    assert int(state.active.sum()) == 3          # table full
    # next frame: tracks 0 and 2 re-match, track 1 (score 0.4) coasts,
    # and a brand-new detection arrives with nowhere to go
    boxes2 = np.zeros((1, 3, 4), np.float32)
    boxes2[0, 0] = [2, 0, 22, 30]
    boxes2[0, 1] = [402, 0, 422, 30]
    boxes2[0, 2] = [800, 0, 820, 30]             # the overflow birth
    scores2 = np.asarray([[0.9, 0.7, 0.95]], np.float32)
    state, det_tid = step(state, jnp.asarray(boxes2),
                          jnp.asarray(scores2), jnp.asarray(classes),
                          jnp.asarray(valid), cfg)
    tids = np.asarray(det_tid)[0]
    assert (tids >= 0).all()                     # nothing dropped
    assert tids[2] == 3                          # fresh id for the birth
    live = set(np.asarray(state.track_id)[0][np.asarray(state.active)[0]])
    assert live == {0, 2, 3}                     # score-0.4 coaster evicted
    # a full table of MATCHED tracks still never evicts (no coasters)
    state2 = init_state(1, cfg)
    state2, _ = step(state2, jnp.asarray(boxes), jnp.asarray(scores),
                     jnp.asarray(classes), jnp.asarray(valid), cfg)
    big = np.zeros((1, 4, 4), np.float32)
    big[0, :3] = boxes[0] + 1.0
    big[0, 3] = [800, 0, 820, 30]
    sc = np.asarray([[0.9, 0.4, 0.7, 0.95]], np.float32)
    state2, tid2 = step(state2, jnp.asarray(big), jnp.asarray(sc),
                        jnp.zeros((1, 4), jnp.int32),
                        jnp.ones((1, 4), bool), cfg)
    assert int(np.asarray(tid2)[0, 3]) == -1     # overflow, no coaster
    live2 = set(np.asarray(state2.track_id)[0]
                [np.asarray(state2.active)[0]])
    assert live2 == {0, 1, 2}


# -------------------------------------------- interpolation quality
def test_interpolated_map_beats_stale_reuse():
    """The acceptance bar: on the synthetic benchmark video, filling
    dropped frames with tracker-coasted boxes beats the paper's
    stale-reuse fill at every tested executor count."""
    for n in (1, 3):
        det = ParallelDetector("ETH-Sunnyday", "yolov3", ["ncs2"] * n)
        paced = simulate(FrameStream(det.video), det.scheduler)
        synced = SequenceSynchronizer().order(paced)
        stale = evaluate_map(det.video, synced, det.detector)
        tracked = fill_stream(det.video, paced, det.detector)
        assert len(tracked) == paced.n_frames          # full coverage
        assert [t.index for t in tracked] == list(range(paced.n_frames))
        tmap = evaluate_map_dets(det.video, tracked)
        assert tmap > stale, (n, tmap, stale)
        tq = track_quality(det.video, tracked)
        assert tq["coverage"] > 0.8
        assert tq["id_switches"] < 40


def test_report_track_columns():
    r = ParallelDetector("ETH-Sunnyday", "yolov3",
                         ["ncs2"] * 2).run(track=True)
    assert r.map_tracked > r.map_score
    assert 0.0 < r.track_coverage <= 1.0
    assert r.id_switches >= 0


def test_evaluate_map_dets_matches_evaluate_map_on_fresh_frames():
    """With zero drops the tracked stream is exactly the fresh
    detections, so both scorers must agree."""
    det = ParallelDetector("ETH-Sunnyday", "yolov3", ["ncs2"] * 7)
    paced = simulate(FrameStream(det.video), det.scheduler)
    if paced.dropped:                 # 7 sticks: no drops expected
        pytest.skip("unexpected drops")
    synced = SequenceSynchronizer().order(paced)
    m_sync = evaluate_map(det.video, synced, det.detector)
    dets = det.detector.detect_many(det.video, range(paced.n_frames))
    m_dets = evaluate_map_dets(det.video, dets)
    assert m_dets == pytest.approx(m_sync, abs=1e-12)


def test_synchronizer_tags_interpolated_frames():
    det = ParallelDetector("ETH-Sunnyday", "yolov3", ["ncs2"])
    paced = simulate(FrameStream(det.video), det.scheduler)
    sync = SequenceSynchronizer()
    tagged = sync.order_tracked(paced)
    assert [s.index for s in tagged] == list(range(paced.n_frames))
    processed = set(paced.processed_indices)
    for s in tagged:
        if s.index in processed:
            assert not s.interpolated and not s.stale
        else:
            assert s.interpolated and s.stale


# ------------------------------------------------- serving engine
def test_engine_track_and_interpolate_covers_stream_and_beats_drops():
    """Acceptance: stream rate 2x the single-replica detection rate —
    track-and-interpolate covers 100% of arrival frames and its
    full-stream mAP beats the drop-frames baseline."""
    from repro.serving import DetectionEngine, FrameRequest
    video = SyntheticVideo(ETH_SUNNYDAY)
    oracle = proxy_detect_fn(video, ProxyDetector("yolov3",
                                                  "ETH-Sunnyday"))
    mu, n = 2.5, 80
    frames = [FrameRequest(i, np.zeros((4, 4, 3), np.float32),
                           i / (2.0 * mu)) for i in range(n)]

    def run(**kw):
        eng = DetectionEngine(n_replicas=1, detect_fn=oracle,
                              service_time=1.0 / mu, **kw)
        out = eng.serve(frames)
        dets = responses_to_detections(out["responses"], n)
        return out, evaluate_map_dets(video, dets)

    out_d, map_d = run(drop_when_busy=True)
    assert out_d["coverage"] < 0.8                  # 2x overload drops
    out_t, map_t = run(track_and_interpolate=True)
    assert out_t["coverage"] == 1.0
    assert out_t["interpolated"] == len(out_d["dropped"]) > 0
    assert [r.rid for r in out_t["responses"]] == list(range(n))
    assert map_t > map_d
    for r in out_t["responses"]:
        if r.interpolated:
            assert r.replica == -1 and r.track_ids is not None


def test_engine_adaptive_micro_batching_matches_fixed():
    """Queue-depth-sized micro-batches must not change detections, and
    an overloaded stream must produce multi-frame batches."""
    from repro.serving import DetectionEngine, FrameRequest
    video = SyntheticVideo(ETH_SUNNYDAY)
    oracle = proxy_detect_fn(video, ProxyDetector("yolov3",
                                                  "ETH-Sunnyday"))
    frames = [FrameRequest(i, np.zeros((4, 4, 3), np.float32), i / 10.0)
              for i in range(24)]
    batch_sizes = []
    orig = DetectionEngine._detect_batch

    def spy(self, images, rids=None):
        batch_sizes.append(sum(1 for r in rids if r >= 0))
        return orig(self, images, rids)

    DetectionEngine._detect_batch = spy
    try:
        adaptive = DetectionEngine(n_replicas=2, detect_fn=oracle,
                                   service_time=0.4).serve(frames)
        fixed = DetectionEngine(n_replicas=2, detect_fn=oracle,
                                service_time=0.4,
                                micro_batch=1).serve(frames)
    finally:
        DetectionEngine._detect_batch = orig
    assert max(batch_sizes) > 1                     # depth-driven batching
    ra = sorted(adaptive["responses"], key=lambda r: r.rid)
    rf = sorted(fixed["responses"], key=lambda r: r.rid)
    assert [r.rid for r in ra] == [r.rid for r in rf]
    for a, b in zip(ra, rf):
        assert np.array_equal(a.valid, b.valid)
        assert np.array_equal(a.boxes[a.valid], b.boxes[b.valid])
