"""Documentation health, enforced by tier-1: no broken intra-repo
markdown links, the doctest-carrying modules pass their examples, and
every public export of ``repro.serving`` has a real docstring (the
NVR/sharded serving API contract lives there)."""
import importlib.util
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

_spec = importlib.util.spec_from_file_location(
    "check_docs", REPO / "tools" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
sys.modules.setdefault("check_docs", check_docs)
_spec.loader.exec_module(check_docs)


def test_no_broken_markdown_links():
    assert check_docs.broken_links() == []


def test_doctest_modules_pass():
    failed, attempted = check_docs.run_doctests()
    assert failed == 0
    assert attempted > 0          # the examples actually collected


def test_every_serving_export_has_a_docstring():
    import repro.serving as serving
    for name in serving.__all__:
        obj = getattr(serving, name)
        doc = obj.__doc__
        assert doc and doc.strip(), f"{name} has no docstring"
        # a dataclass's auto-generated doc is just its signature —
        # that does not count as documentation of the contract
        assert not doc.startswith(f"{name}("), \
            f"{name} only has the auto-generated dataclass docstring"
