"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp
oracle, swept over shapes and dtypes.  ``hypothesis`` is optional: the
property-based IoU sweep degrades to a fixed parametrization without it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional dep — see requirements-dev.txt
    given = None

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.iou import iou_matrix
from repro.kernels.ops import nms

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,T,S,D", [
    (1, 2, 128, 128, 64),
    (2, 4, 256, 256, 64),
    (1, 1, 128, 256, 128),     # cross: S > T (cached prefix)
    (2, 2, 256, 128, 32),      # T > S
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, H, T, S, D, dtype, causal):
    if causal and S < T:
        pytest.skip("causal with S<T is not a served configuration")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (rand(ks[0], (B, H, T, D), dtype),
               rand(ks[1], (B, H, S, D), dtype),
               rand(ks[2], (B, H, S, D), dtype))
    got = flash_attention(q, k, v, causal=causal, interpret=True,
                          block_q=128, block_k=128)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    assert_allclose(np.asarray(got, np.float32),
                    np.asarray(want, np.float32), **TOL[dtype])


def test_flash_attention_block_shape_sweep():
    B, H, T, D = 1, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (rand(ks[0], (B, H, T, D), jnp.float32),
               rand(ks[1], (B, H, T, D), jnp.float32),
               rand(ks[2], (B, H, T, D), jnp.float32))
    want = ref.flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(128, 128), (256, 128), (128, 256), (256, 256)]:
        got = flash_attention(q, k, v, causal=True, interpret=True,
                              block_q=bq, block_k=bk)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                        atol=2e-5)


# ------------------------------------------------------- decode attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 8, 2, 512, 64),
    (2, 16, 16, 1024, 64),     # MHA (KV == H)
    (2, 8, 1, 512, 128),       # MQA
    (4, 32, 8, 2048, 128),     # the decode_32k family shape
])
def test_decode_attention_matches_ref(B, H, KV, S, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (B, H, D), dtype)
    k = rand(ks[1], (B, S, KV, D), dtype)
    v = rand(ks[2], (B, S, KV, D), dtype)
    got = decode_attention(q, k, v, interpret=True, block_s=256)
    want = ref.decode_attention_ref(q, k, v)
    assert_allclose(np.asarray(got, np.float32),
                    np.asarray(want, np.float32), **TOL[dtype])


# --------------------------------------------------------------- IoU/NMS
def _check_iou_matrix_matches_ref(n, m, seed):
    rng = np.random.default_rng(seed)
    def boxes(k):
        tl = rng.uniform(0, 100, (k, 2))
        wh = rng.uniform(1, 50, (k, 2))
        return jnp.asarray(np.concatenate([tl, tl + wh], -1), jnp.float32)
    a, b = boxes(n), boxes(m)
    got = iou_matrix(a, b, interpret=True)
    want = ref.iou_matrix_ref(a, b)
    assert got.shape == (n, m)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
    assert float(jnp.max(got)) <= 1.0 + 1e-5
    assert float(jnp.min(got)) >= 0.0


if given is not None:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 300), m=st.integers(1, 300),
           seed=st.integers(0, 99))
    def test_iou_matrix_matches_ref(n, m, seed):
        _check_iou_matrix_matches_ref(n, m, seed)
else:
    @pytest.mark.parametrize("n,m,seed", [
        (1, 1, 0), (1, 300, 1), (300, 1, 2), (127, 129, 3), (128, 128, 4),
        (300, 300, 5), (17, 250, 6)])
    def test_iou_matrix_matches_ref(n, m, seed):
        _check_iou_matrix_matches_ref(n, m, seed)


def test_iou_diagonal_is_one():
    rng = np.random.default_rng(0)
    tl = rng.uniform(0, 100, (64, 2))
    wh = rng.uniform(1, 50, (64, 2))
    a = jnp.asarray(np.concatenate([tl, tl + wh], -1), jnp.float32)
    got = iou_matrix(a, a, interpret=True)
    assert_allclose(np.asarray(jnp.diag(got)), np.ones(64), rtol=1e-5)


def test_nms_suppresses_overlaps():
    boxes = jnp.asarray([[0, 0, 10, 10], [1, 1, 11, 11], [50, 50, 60, 60]],
                        jnp.float32)
    scores = jnp.asarray([0.9, 0.8, 0.7])
    keep, valid = nms(boxes, scores, iou_thr=0.5, max_out=3)
    kept = set(np.asarray(keep)[np.asarray(valid)].tolist())
    assert kept == {0, 2}
    # matches the oracle
    keep_r, valid_r = ref.nms_ref(boxes, scores, 0.5, 3)
    assert np.array_equal(np.asarray(keep)[np.asarray(valid)],
                          np.asarray(keep_r)[np.asarray(valid_r)])


# ------------------------------------------------------------ rwkv scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,T,hs,chunk", [
    (1, 2, 256, 32, 256),
    (2, 3, 512, 64, 256),      # multi-chunk: scratch persists across grid
    (1, 1, 1024, 64, 128),
])
def test_rwkv_scan_matches_ref(B, H, T, hs, chunk, dtype):
    from repro.kernels.rwkv_scan import rwkv_scan
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    r = rand(ks[0], (B, H, T, hs), dtype)
    k = rand(ks[1], (B, H, T, hs), dtype)
    v = rand(ks[2], (B, H, T, hs), dtype)
    w = (jax.nn.sigmoid(rand(ks[3], (B, H, T, hs), jnp.float32)) * 0.5
         + 0.45).astype(dtype)
    u = rand(ks[4], (H, hs), jnp.float32)
    s0 = jax.random.normal(ks[5], (B, H, hs, hs), jnp.float32) * 0.1
    got_o, got_s = rwkv_scan(r, k, v, w, u, s0, interpret=True,
                             chunk_t=chunk)
    want_o, want_s = ref.rwkv_scan_ref(r, k, v, w, u, s0)
    tol = TOL[dtype]
    assert_allclose(np.asarray(got_o, np.float32),
                    np.asarray(want_o, np.float32), **tol)
    assert_allclose(np.asarray(got_s), np.asarray(want_s),
                    rtol=tol["rtol"] * 5, atol=tol["atol"] * 5)
