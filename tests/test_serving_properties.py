"""Property-based test layer for the serving stack.

Every invariant here is phrased over RANDOMIZED traces — stream
counts, arrival rates, chunk boundaries, fault schedules — rather than
the fixed fixtures the unit tests use:

* frame conservation: every arrival reaches exactly one terminal state
  (the ``obs.audit`` rule), for any trace shape and drop mode;
* per-stream emit monotonicity: sequence numbers strictly increase and
  emit times never decrease, per camera;
* chunked ``ingest``/``advance`` drains byte-for-byte equal to the
  one-shot batch ``serve``, for ANY chunking;
* histogram merge never averages: the merged latency quantile is
  recomputed from summed buckets and must equal the quantile of the
  pooled samples' histogram exactly;
* the fused one-jit tick program produces reports byte-identical to
  the staged tracker chain, for any trace shape;
* track identities survive shard migration: a track id born before a
  ``rebalance_streams`` move re-appears on the destination shard, the
  ``track_import`` reproduces the source's ``track_export``, and the
  audit's continuity rule passes — while ``carry_tracks=False``
  (the old re-seed behaviour) makes the same rule fail;
* randomized (seeded) fault schedules keep all of the above.

``hypothesis`` is an optional dev dependency: the ``@given`` variants
skip without it, and deterministic seed-parametrized fallbacks keep
every property covered either way.
"""
import numpy as np
import pytest

from repro.core import proxy_detect_fn_streams
from repro.obs import TraceRecorder, audit_recorder
from repro.obs.metrics import (LatencyHistogram, merge_hist_dicts,
                               quantile_of_dict)
from repro.serving import (DetectionEngine, FaultSchedule, FrameRequest,
                           ServingRuntime, ShardedDetectionEngine,
                           make_nvr_streams, make_skewed_streams)
from test_sharded_serving import assert_reports_identical

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional dep — see requirements-dev.txt
    given = None

SEEDS = list(range(6))           # deterministic fallback space


def random_trace(seed: int):
    """A randomized NVR trace: random camera count, length, pacing and
    per-frame jitter (always sorted by arrival; rids globally unique)."""
    rng = np.random.default_rng(seed)
    n_streams = int(rng.integers(1, 5))
    n_frames = int(rng.integers(2, 12))
    rate = float(rng.uniform(1.0, 8.0))
    frames, frame_of, videos, dets = make_nvr_streams(
        n_streams, n_frames, rate)
    # jitter arrivals so micro-batch composition varies with the seed
    for f in frames:
        f.t_arrival = max(0.0, f.t_arrival +
                          float(rng.uniform(-0.05, 0.05)))
    frames.sort(key=lambda f: (f.t_arrival, f.rid))
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    return frames, oracle, n_streams, n_frames


def engine_for(oracle, seed: int, recorder=None, faults=None):
    rng = np.random.default_rng(1000 + seed)
    mode = ({"drop_when_busy": True} if rng.integers(2)
            else {"track_and_interpolate": True})
    return DetectionEngine(detect_fn=oracle,
                           n_replicas=int(rng.integers(1, 4)),
                           service_time=float(rng.uniform(0.1, 0.6)),
                           recorder=recorder, faults=faults, **mode)


def check_conservation_and_monotonicity(seed: int, faults=None):
    frames, oracle, n_streams, _ = random_trace(seed)
    rec = TraceRecorder()
    out = engine_for(oracle, seed, recorder=rec, faults=faults) \
        .serve(frames)
    res = audit_recorder(rec)
    assert res.ok, res.violations[:3]
    assert res.stats["arrive"] == len(frames)
    if faults is None:
        # terminal accounting closes exactly: emitted + finally-dropped
        # (under faults the audit's conservation rule — part of
        # ``res.ok`` above — is the authority; lost frames included)
        assert (res.stats["emitted"]
                + res.stats["dropped_final"]) == len(frames)
    # direct monotonicity re-check from the report (not just the audit)
    for sid, resp in out["streams"].items():
        seqs = [r.seq for r in resp]
        assert seqs == sorted(set(seqs)), sid


# ---------------------------------------------- frame conservation
@pytest.mark.parametrize("seed", SEEDS)
def test_frame_conservation_randomized_traces(seed):
    check_conservation_and_monotonicity(seed)


if given is not None:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_frame_conservation_property(seed):
        check_conservation_and_monotonicity(seed)


# ------------------------------------------------ chunked == one-shot
def check_chunked_equals_one_shot(seed: int, cuts):
    frames, oracle, _, _ = random_trace(seed)
    base = engine_for(oracle, seed).serve(frames)
    rt = ServingRuntime(engine_for(oracle, seed))
    bounds = sorted({min(c, len(frames)) for c in cuts} | {len(frames)})
    prev = 0
    for b in bounds:
        rt.ingest(frames[prev:b])
        rt.advance()
        prev = b
    out = rt.drain()
    assert_reports_identical(base, out)


@pytest.mark.parametrize("seed,cuts", [
    (0, (1,)), (1, (2, 5)), (2, (3, 4, 9)), (3, (1, 2, 3, 4, 5)),
    (4, (7,)), (5, (2, 2, 6)),
])
def test_chunked_ingest_matches_one_shot_randomized(seed, cuts):
    check_chunked_equals_one_shot(seed, cuts)


if given is not None:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000),
           cuts=st.lists(st.integers(1, 40), max_size=6))
    def test_chunked_ingest_matches_one_shot_property(seed, cuts):
        check_chunked_equals_one_shot(seed, cuts)


# ----------------------------------------- merge never averages
def check_merge_never_average(latencies, n_shards: int):
    pooled = LatencyHistogram()
    shards = [LatencyHistogram() for _ in range(n_shards)]
    for i, x in enumerate(latencies):
        pooled.add(x)
        shards[i % n_shards].add(x)
    merged = merge_hist_dicts([h.to_dict() for h in shards])
    for q in (0.5, 0.9, 0.95, 0.99):
        # bucket-sum + recompute == pooled quantile, exactly
        assert quantile_of_dict(merged, q) == pooled.quantile(q), q
        # and the recomputed quantile is NOT the per-shard average
        # (a strictly weaker statement, but the one that catches the
        # classic mean-of-p99s bug on skewed shards)
        per = [h.quantile(q) for h in shards if h.n]
        if per:
            assert min(per) <= quantile_of_dict(merged, q) <= max(per)


@pytest.mark.parametrize("seed", SEEDS)
def test_latency_merge_never_averages_randomized(seed):
    rng = np.random.default_rng(seed)
    lat = rng.lognormal(-2.0, 1.0, size=int(rng.integers(1, 200)))
    check_merge_never_average([float(x) for x in lat],
                              n_shards=int(rng.integers(1, 5)))


if given is not None:
    @settings(max_examples=30, deadline=None)
    @given(lat=st.lists(st.floats(1e-4, 10.0), min_size=1, max_size=80),
           n_shards=st.integers(1, 5))
    def test_latency_merge_never_averages_property(lat, n_shards):
        check_merge_never_average(lat, n_shards)


# -------------------------------------------- fused tick == staged
def check_fused_matches_staged(seed: int):
    """The one-jit donated-buffer tick program must be report-identical
    to the staged ``trk.step``/``trk.coast`` chain on any trace."""
    frames, oracle, _, _ = random_trace(seed)
    rng = np.random.default_rng(2000 + seed)
    kw = dict(n_replicas=int(rng.integers(1, 4)),
              service_time=float(rng.uniform(0.1, 0.6)),
              track_and_interpolate=True,
              drop_when_busy=bool(rng.integers(2)))
    staged = DetectionEngine(detect_fn=oracle, **kw).serve(frames)
    frames2, oracle2, _, _ = random_trace(seed)
    fused = DetectionEngine(detect_fn=oracle2, fused_tick=True,
                            **kw).serve(frames2)
    assert_reports_identical(staged, fused)


@pytest.mark.parametrize("seed", SEEDS)
def test_fused_tick_matches_staged_randomized(seed):
    check_fused_matches_staged(seed)


if given is not None:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_fused_tick_matches_staged_property(seed):
        check_fused_matches_staged(seed)


# --------------------------------- track identity across migration
def migration_run(carry_tracks: bool):
    """A skewed 2-shard rebalancing trace with tracker interpolation:
    guaranteed to migrate stream(s) off the hot shard at epoch 0."""
    frames, frame_of, videos, dets = make_skewed_streams(
        6, n_shards=2, n_frames=12, rate=1.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    rec = TraceRecorder()
    rep = ShardedDetectionEngine(
        detect_fn=oracle, n_shards=2, n_replicas=2, service_time=0.36,
        track_and_interpolate=True, carry_tracks=carry_tracks,
        rebalance=True, epoch_s=4.0, recorder=rec).serve(frames)
    return rep, rec


def test_track_identity_survives_migration():
    rep, rec = migration_run(carry_tracks=True)
    moves = rep["migrations"]
    assert moves, "trace must actually migrate"
    res = audit_recorder(rec)
    assert res.ok, res.violations[:3]
    assert res.stats["track_export"] > 0
    assert res.stats["track_import"] > 0
    evs = rec.events
    for m in (e for e in evs if e["kind"] == "migrate"):
        sid = m["stream"]
        exps = [e for e in evs if e["kind"] == "track_export"
                and e["stream"] == sid and e["i"] < m["i"]]
        imps = [e for e in evs if e["kind"] == "track_import"
                and e["stream"] == sid and e["i"] > m["i"]]
        assert exps and imps, sid
        exp, imp = exps[-1], imps[0]
        # the destination shard imports the source's exact table ...
        assert imp["next_id"] == exp["next_id"]
        assert imp["tids"] == exp["tids"]
        assert imp["shard"] == m["dst"] != exp["shard"] == m["src"]
        # ... and a track id born BEFORE the boundary shows up again in
        # responses the DESTINATION shard emitted after the move
        surviving = set(exp["tids"])
        assert surviving
        post_rids = {e["rid"] for e in evs
                     if e["kind"] in ("emit", "interp_emit")
                     and e["stream"] == sid and e["i"] > m["i"]}
        emitted_after = set()
        for r in rep["streams"][sid]:
            if r.rid in post_rids and r.track_ids is not None:
                emitted_after |= {int(t) for t in np.asarray(r.track_ids)
                                  if t >= 0}
        assert surviving & emitted_after, (sid, surviving, emitted_after)


def test_reseed_without_carry_fails_continuity_audit():
    """The pre-refactor behaviour (re-seed at epoch boundaries),
    reproduced via ``carry_tracks=False``, must TRIP the new audit
    rule — the invariant genuinely distinguishes the two."""
    rep, rec = migration_run(carry_tracks=False)
    assert rep["migrations"]
    res = audit_recorder(rec)
    assert any(v["rule"] == "track_continuity" for v in res.violations), \
        res.violations


# ------------------------------------------- randomized fault chaos
@pytest.mark.chaos
@pytest.mark.parametrize("seed", SEEDS)
def test_conservation_under_randomized_faults(seed):
    sched = FaultSchedule.random(seed, 6.0, n_replicas=3,
                                 n_replica_events=2)
    check_conservation_and_monotonicity(seed, faults=sched)


@pytest.mark.chaos
@pytest.mark.parametrize("seed", [0, 3, 7])
def test_sharded_chaos_replay_is_deterministic(seed):
    """Same (trace, FaultSchedule) seed => byte-identical reports —
    randomized chaos stays assertable."""
    sched = FaultSchedule.random(seed, 8.0, n_shards=2, n_replicas=2,
                                 n_replica_events=2)

    def run():
        frames, frame_of, videos, dets = make_nvr_streams(3, 8, 3.0)
        oracle = proxy_detect_fn_streams(videos, dets, frame_of)
        return ShardedDetectionEngine(
            detect_fn=oracle, n_shards=2, n_replicas=2,
            service_time=0.3, track_and_interpolate=True,
            faults=sched).serve(frames)

    assert_reports_identical(run(), run())


if given is not None:
    @pytest.mark.chaos
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_conservation_under_faults_property(seed):
        sched = FaultSchedule.random(seed, 6.0, n_replicas=3,
                                     n_replica_events=2)
        check_conservation_and_monotonicity(seed, faults=sched)
