"""Transprecise multi-model cascade serving: model catalogs, the
virtual-time ``ModelSelector`` state machine, the ROI crop/uncrop
kernel pair (three-tier: Pallas / XLA twin / numpy oracle), the
engine-level cascade + hierarchical second pass, and the bit-identity
bar — a single-entry catalog must leave every gated serving path
(detection, sharded static/rebalance, faults) byte-for-byte identical
to an engine built without one."""
import numpy as np
import pytest

from repro.core import evaluate_streams, proxy_detect_fn_streams
from repro.core.quality import evaluate_map_dets, track_quality
from repro.core.stream import SyntheticVideo, VideoSpec
from repro.kernels import ops
from repro.kernels.ref import crop_resize_ref, uncrop_boxes_ref
from repro.kernels.roi import (crop_resize_pallas, crop_resize_xla,
                               uncrop_boxes_pallas, uncrop_boxes_xla)
from repro.obs import TraceRecorder, audit_recorder
from repro.serving import (DetectionEngine, FaultSchedule, FrameRequest,
                           ModelCatalog, ModelProfile, ModelSelector,
                           ShardedDetectionEngine, Watchdog,
                           make_cascade_detect_fn, make_nvr_streams,
                           make_skewed_streams, paper_catalog)
from repro.serving.cascade import roi_pixels, rois_from_boxes
from repro.serving.models import as_catalog, cascade_report_keys
from test_sharded_serving import assert_reports_identical

SERVICE = 0.4          # the literal shared by both sides of identity

#: per-model bookkeeping keys — present on every report now, and the
#: ONLY keys allowed to differ between a plain engine and a
#: single-entry-catalog engine (the plain side reports them empty)
CASCADE_KEYS = set(cascade_report_keys(
    {}, {}, {}, 0, {"full": 0.0, "roi": 0.0, "passes": 0}, 0))


def assert_identical_modulo_cascade_keys(base, cas):
    assert_reports_identical(
        {k: v for k, v in base.items() if k not in CASCADE_KEYS}, cas)


def single_catalog(service_s=SERVICE):
    return ModelCatalog([ModelProfile("only", 0.8, band="yolov3",
                                      service_s=service_s)])


# ------------------------------------------------------------ catalog
def test_model_profile_derives_mu_and_validates():
    p = ModelProfile("m", 0.5, service_s=0.25)
    assert p.mu == pytest.approx(4.0)
    with pytest.raises(ValueError):
        ModelProfile("m", 0.5)                   # no rate at all
    with pytest.raises(ValueError):
        ModelProfile("m", 0.5, service_s=-1.0)


def test_catalog_ordering_lookup_and_uniqueness():
    cat = paper_catalog(0.4)
    assert [p.name for p in cat.by_quality()] == ["heavy", "medium",
                                                  "fast"]
    assert cat.heaviest.name == "heavy"
    assert cat.lightest.name == "fast"
    assert cat["fast"].mu == pytest.approx(10.0)   # 0.4 / 4
    assert "medium" in cat and "nope" not in cat
    assert set(cat.map_est_by_name()) == {"fast", "medium", "heavy"}
    with pytest.raises(ValueError):
        ModelCatalog([cat["fast"], cat["fast"]])   # duplicate name
    with pytest.raises(ValueError):
        ModelCatalog([])


def test_as_catalog_coercion():
    cat = single_catalog()
    assert as_catalog(None) is None
    assert as_catalog(cat) is cat
    assert as_catalog(list(cat)).names == cat.names


# ----------------------------------------------------- model selector
def caps_for(cat, n_replicas=1):
    return {p.name: n_replicas * p.mu for p in cat}


def test_selector_single_entry_never_switches():
    sel = ModelSelector(single_catalog())
    caps = caps_for(single_catalog())
    for k in range(20):
        name, switched = sel.decide(float(k), 5, 10.0, caps)
        assert name == "only" and not switched
    assert sel.switches == 0


def test_selector_degrades_immediately_under_pressure():
    cat = paper_catalog(0.5)            # caps: heavy 2, medium 4, fast 8
    sel = ModelSelector(cat)
    caps = caps_for(cat)
    sel.decide(0.0, 1, 0.0, caps)       # prime the rate estimator
    # 12 fps instantaneous: even fast (8) is infeasible -> stays lightest
    name, _ = sel.decide(1.0, 12, 0.0, caps)
    assert name == "fast"
    # deep backlog forces the extra degrade step even when feasible
    sel2 = ModelSelector(cat)
    sel2._cur = 0                       # pin at heavy
    name, switched = sel2.decide(0.0, 0, 10.0, caps)   # 20 frames of lag
    assert switched and name == "medium"


def test_selector_upgrade_needs_hold_and_headroom():
    cat = paper_catalog(0.5)
    sel = ModelSelector(cat, hold=3)
    caps = caps_for(cat)                # heavy 2, medium 4, fast 8
    sel.decide(0.0, 1, 0.0, caps)       # prime (counts one slack tick)
    # 1 fps << heavy cap * headroom (1.4): slack, but only after `hold`
    # consecutive slack decisions does the selector step up one tier
    seen = [sel.decide(1.0 + k, 1, 0.0, caps)[0] for k in range(8)]
    assert seen[0] == "fast"            # still holding
    assert "medium" in seen and seen[-1] == "heavy"
    i_med, i_heavy = seen.index("medium"), seen.index("heavy")
    assert i_heavy - i_med >= 3         # one tier per hold, no jumps


def test_selector_hysteresis_band_blocks_upgrade():
    cat = paper_catalog(0.5)
    sel = ModelSelector(cat, hold=2)
    caps = caps_for(cat)
    sel.decide(0.0, 1, 0.0, caps)
    # 3.5 fps: feasible for medium (cap 4) but NOT with 0.7 headroom
    # (2.8), so the selector must sit at fast forever — no flapping
    for k in range(6):
        sel.decide(2.0 * (k + 1), 7, 0.0, caps)   # 7 arrivals / 2 s
    assert sel.current == "fast"
    assert sel.switches == 0


def test_selector_zero_capacity_stays_lightest():
    cat = paper_catalog(0.5)
    sel = ModelSelector(cat)
    dead = {p.name: 0.0 for p in cat}
    sel.decide(0.0, 1, 0.0, dead)
    name, _ = sel.decide(1.0, 4, 0.0, dead)
    assert name == "fast"


# ----------------------------------------------- ROI window selection
def test_rois_from_boxes_topk_pad_clamp():
    boxes = np.array([[10, 10, 30, 30], [100, 100, 200, 200],
                      [0, 0, 5, 5], [600, 440, 700, 520]], np.float32)
    scores = np.array([0.9, 0.5, 0.99, 0.7], np.float32)
    valid = np.array([True, True, False, True])
    rois, n = rois_from_boxes(boxes, scores, valid, bounds=(640, 480),
                              roi_max=2, pad=0.1)
    assert rois.shape == (2, 4) and n == 2
    # top-2 valid by score: box 0 (0.9) then box 3 (0.7); box 2 invalid
    assert rois[0] == pytest.approx([8, 8, 32, 32])    # 10% pad
    assert rois[1][2] == 640.0 and rois[1][3] == 480.0  # clamped
    # degenerate inputs
    r0, n0 = rois_from_boxes(boxes, scores, np.zeros(4, bool),
                             bounds=(640, 480), roi_max=2)
    assert n0 == 0 and r0.shape == (2, 4)


def test_roi_pixels_clamped_to_full_frame():
    rois = np.array([[0, 0, 640, 480], [0, 0, 640, 480]], np.float32)
    assert roi_pixels(rois, 2, (640, 480)) == 640 * 480   # never exceeds
    assert roi_pixels(rois, 0, (640, 480)) == 0.0


# ------------------------------------------- crop/uncrop kernel tiers
def _roi_fixture(b=3, h=24, w=32, r=2, seed=0):
    rng = np.random.default_rng(seed)
    images = rng.random((b, h, w, 3)).astype(np.float32)
    # normalized [x0, y0, x1, y1] windows, well-formed
    lo = rng.uniform(0.0, 0.5, (b, r, 2)).astype(np.float32)
    hi = lo + rng.uniform(0.2, 0.5, (b, r, 2)).astype(np.float32)
    rois = np.concatenate([lo, np.minimum(hi, 1.0)], -1)
    return images, rois


def test_crop_resize_three_tiers_bit_compatible():
    images, rois = _roi_fixture()
    ref = np.asarray(crop_resize_ref(images, rois, out_size=8))
    xla = np.asarray(crop_resize_xla(images, rois, out_size=8))
    pal = np.asarray(crop_resize_pallas(images, rois, out_size=8))
    # index quantization (floor/clip) absorbs the FMA contraction:
    # all three tiers agree exactly
    assert np.array_equal(ref, xla)
    assert np.array_equal(xla, pal)
    assert pal.shape == (3, 2, 8, 8, 3)


def test_uncrop_boxes_pallas_matches_xla_exactly():
    rng = np.random.default_rng(1)
    boxes = rng.uniform(0, 16, (3, 2, 5, 4)).astype(np.float32)
    _, rois = _roi_fixture()
    kw = dict(bounds=(640, 480), crop_size=16)
    xla = np.asarray(uncrop_boxes_xla(boxes, rois[:, :, None, :], **kw))
    pal = np.asarray(uncrop_boxes_pallas(boxes, rois[:, :, None, :],
                                         **kw))
    ref = uncrop_boxes_ref(boxes, rois[:, :, None, :], **kw)
    # both jitted tiers see the same FMA contraction: exact match;
    # the numpy oracle differs by at most ~1 ULP of the frame scale
    assert np.array_equal(xla, pal)
    np.testing.assert_allclose(pal, ref, atol=1e-3)
    assert pal.shape == boxes.shape


def test_ops_dispatchers_follow_nms_convention():
    images, rois = _roi_fixture(seed=2)
    a = np.asarray(ops.crop_resize(images, rois, out_size=8,
                                   use_pallas=True))
    b = np.asarray(ops.crop_resize(images, rois, out_size=8,
                                   use_pallas=False))
    assert np.array_equal(a, b)
    boxes = np.random.default_rng(3).uniform(
        0, 8, (3, 2, 4, 4)).astype(np.float32)
    ua = np.asarray(ops.uncrop_boxes(boxes, rois[:, :, None, :],
                                     bounds=(64, 48), crop_size=8,
                                     use_pallas=True))
    ub = np.asarray(ops.uncrop_boxes(boxes, rois[:, :, None, :],
                                     bounds=(64, 48), crop_size=8,
                                     use_pallas=False))
    assert np.array_equal(ua, ub)


def test_uncrop_inverts_crop_window_corners():
    # a box spanning the whole crop must map back to the ROI window
    rois = np.array([[[0.25, 0.25, 0.75, 0.75]]], np.float32)
    boxes = np.array([[[[0.0, 0.0, 16.0, 16.0]]]], np.float32)
    out = np.asarray(uncrop_boxes_xla(boxes, rois[:, :, None, :],
                                      bounds=(640, 480), crop_size=16))
    np.testing.assert_allclose(out[0, 0, 0],
                               [160.0, 120.0, 480.0, 360.0], atol=1e-3)


# ----------------------------------------- engine-level cascade + ROI
def fast_videos(n_streams=2, n_frames=64, obj_speed=0.02,
                cam_speed=0.004):
    return {s: SyntheticVideo(VideoSpec("NVR-cascade", 14.0, n_frames,
                                        640, 480, moving_camera=True,
                                        n_objects=8, seed=3 + s,
                                        obj_speed=obj_speed,
                                        cam_speed=cam_speed))
            for s in range(n_streams)}


def trace_for(n, n_streams=2, rate=6.0):
    img = np.zeros((4, 4, 3), np.float32)
    frames, frame_of, seqs = [], {}, [0] * n_streams
    for k in range(n):
        s = k % n_streams
        frames.append(FrameRequest(k, img, k / rate, stream_id=s))
        frame_of[k] = (s, seqs[s])
        seqs[s] += 1
    return frames, frame_of


def test_cascade_report_keys_and_audit_clean():
    videos = fast_videos()
    frames, frame_of = trace_for(48, rate=10.0)
    cat = paper_catalog(0.5)
    rec = TraceRecorder()
    eng = DetectionEngine(detect_fn=make_cascade_detect_fn(
                              videos, frame_of, cat),
                          catalog=cat, n_replicas=2, drop_when_busy=True,
                          track_and_interpolate=True, roi=True,
                          roi_bounds=(640, 480), recorder=rec)
    out = eng.serve(frames)
    for k in ("models", "model_of_frame", "model_map_est",
              "model_switches", "map_estimate", "roi_pixels",
              "roi_pixel_reduction"):
        assert k in out, k
    assert sum(out["models"].values()) == len(out["model_of_frame"])
    assert 0.0 <= out["map_estimate"] <= 1.0
    # overloaded (10 fps vs heavy cap 4): the selector must sit below
    # the heaviest model, so the ROI second pass fires
    assert out["roi_pixels"]["passes"] > 0
    assert 0.0 < out["roi_pixel_reduction"] <= 1.0
    res = audit_recorder(rec)
    assert res.ok, res.violations[:3]
    assert res.stats["roi_pass"] == out["roi_pixels"]["passes"]
    # every served frame is attributed to exactly one model
    for rid, m in out["model_of_frame"].items():
        assert m in cat


def test_model_switch_only_at_batch_boundaries():
    videos = fast_videos()
    # lull -> burst -> lull so the selector actually moves
    img = np.zeros((4, 4, 3), np.float32)
    frames, frame_of, t = [], {}, 0.0
    seqs = [0, 0]
    for k in range(60):
        rate = 12.0 if 20 <= k < 40 else 2.0
        s = k % 2
        frames.append(FrameRequest(k, img, t, stream_id=s))
        frame_of[k] = (s, seqs[s])
        seqs[s] += 1
        t += 1.0 / rate
    cat = paper_catalog(0.5)
    rec = TraceRecorder()
    eng = DetectionEngine(detect_fn=make_cascade_detect_fn(
                              videos, frame_of, cat),
                          catalog=cat, n_replicas=2, drop_when_busy=True,
                          recorder=rec)
    out = eng.serve(frames)
    assert out["model_switches"] > 0
    switches = [e for e in rec.events if e["kind"] == "model_switch"]
    assert len(switches) == out["model_switches"]
    res = audit_recorder(rec)
    assert res.ok, res.violations[:3]
    # corrupting a switch to name an already-started batch must trip
    # the boundary rule
    enq = next(e for e in rec.events if e["kind"] == "enqueue")
    bad = dict(switches[0], batch=enq["batch"])
    bad["i"] = rec.events[-1]["i"] + 1
    res2 = audit_recorder(rec)
    assert res2.ok
    broken = audit_recorder(type("R", (), {"events":
                                           rec.events + [bad]})())
    assert not broken.ok
    assert any(v["rule"] == "model_switch_boundary"
               for v in broken.violations)


def test_roi_detections_contained_and_reduction_counted():
    videos = fast_videos()
    frames, frame_of = trace_for(32, rate=12.0)
    cat = ModelCatalog([paper_catalog(0.5)["fast"],
                        paper_catalog(0.5)["heavy"]])
    rec = TraceRecorder()
    eng = DetectionEngine(detect_fn=make_cascade_detect_fn(
                              videos, frame_of, cat),
                          catalog=cat, n_replicas=2, drop_when_busy=True,
                          roi=True, roi_bounds=(640, 480), recorder=rec)
    out = eng.serve(frames)
    passes = [e for e in rec.events if e["kind"] == "roi_pass"]
    assert passes and out["roi_pixels"]["passes"] == len(passes)
    for e in passes:
        W, H = e["bounds"]
        assert e["px_roi"] <= e["px_full"]
        for r in e["rois"]:
            assert -1e-3 <= r[0] <= r[2] <= W + 1e-3
            assert -1e-3 <= r[1] <= r[3] <= H + 1e-3
    assert audit_recorder(rec).ok
    # second-pass boxes in the report stay inside the frame too
    for r in out["responses"]:
        v = np.asarray(r.valid, bool)
        if v.any():
            bx = np.asarray(r.boxes)[v]
            assert bx[:, [0, 2]].max() <= 640 + 1e-3
            assert bx[:, [1, 3]].max() <= 480 + 1e-3


# --------------------------------------------- single-entry identity
def identity_pair(mode_kw, sharded=False, **extra):
    """(plain, single-entry-catalog) reports over the same trace; both
    sides use the SAME oracle so any divergence is the cascade's."""
    frames, frame_of, videos, dets = make_nvr_streams(3, 16, rate=2.0)
    cat = single_catalog()
    fn = make_cascade_detect_fn(videos, frame_of, cat)
    cls = ShardedDetectionEngine if sharded else DetectionEngine
    base = cls(detect_fn=fn, n_replicas=2, service_time=SERVICE,
               **mode_kw, **extra).serve(frames)
    frames2, _, _, _ = make_nvr_streams(3, 16, rate=2.0)
    cas = cls(detect_fn=fn, n_replicas=2, catalog=cat, roi=True,
              roi_bounds=(videos[0].spec.width, videos[0].spec.height),
              **mode_kw, **extra).serve(frames2)
    return base, cas


@pytest.mark.parametrize("mode_kw", [{"drop_when_busy": True},
                                     {"track_and_interpolate": True}])
def test_single_entry_catalog_bit_identical_detection(mode_kw):
    base, cas = identity_pair(mode_kw)
    assert_identical_modulo_cascade_keys(base, cas)
    assert cas["model_switches"] == 0
    assert cas["roi_pixels"]["passes"] == 0     # heaviest == only model


def test_single_entry_catalog_bit_identical_sharded_static():
    base, cas = identity_pair({"track_and_interpolate": True},
                              sharded=True, n_shards=2)
    assert_identical_modulo_cascade_keys(base, cas)
    assert cas["model_switches"] == 0


def test_single_entry_catalog_bit_identical_rebalance():
    frames, frame_of, videos, dets = make_skewed_streams(4, 12, 3.0,
                                                         n_shards=2)
    cat = single_catalog()
    fn = make_cascade_detect_fn(videos, frame_of, cat)
    kw = dict(n_shards=2, n_replicas=2, track_and_interpolate=True,
              epoch_s=2.0, rebalance=True)
    base = ShardedDetectionEngine(detect_fn=fn, service_time=SERVICE,
                                  **kw).serve(frames)
    cas = ShardedDetectionEngine(detect_fn=fn, catalog=cat,
                                 **kw).serve(frames)
    assert_identical_modulo_cascade_keys(base, cas)


@pytest.mark.chaos
def test_single_entry_catalog_bit_identical_under_faults():
    sched = FaultSchedule.replica_kill(1.0, replica=0, revive_t=3.0)
    base, cas = identity_pair({"track_and_interpolate": True},
                              faults=sched)
    assert_identical_modulo_cascade_keys(base, cas)


# --------------------------------------- empty inputs / empty traces
def test_evaluate_map_dets_empty_inputs():
    video = SyntheticVideo(VideoSpec("t", 10.0, 8, 64, 48, False,
                                     n_objects=2))
    assert evaluate_map_dets(video, []) == 0.0
    assert evaluate_map_dets(video, [None, None]) == 0.0


def test_track_quality_empty_input_schema():
    video = SyntheticVideo(VideoSpec("t", 10.0, 8, 64, 48, False,
                                     n_objects=2))
    tq = track_quality(video, [])
    assert tq == {"id_switches": 0.0, "coverage": 0.0, "fragments": 0.0}


def test_cascade_report_keys_zero_frames_schema():
    empty = cascade_report_keys({}, {}, {}, 0,
                                {"full": 0.0, "roi": 0.0, "passes": 0}, 0)
    populated = cascade_report_keys({"m": 2}, {0: "m", 1: "m"},
                                    {"m": 0.5}, 1,
                                    {"full": 10.0, "roi": 5.0,
                                     "passes": 2}, 2)
    assert set(empty) == set(populated)
    assert empty["map_estimate"] == 0.0
    assert populated["map_estimate"] == pytest.approx(0.5)
    assert populated["roi_pixel_reduction"] == pytest.approx(0.5)


@pytest.mark.parametrize("sharded", [False, True])
def test_empty_trace_report_schema_matches_populated(sharded):
    videos = fast_videos()
    frames, frame_of = trace_for(8)
    cat = paper_catalog(0.5)
    fn = make_cascade_detect_fn(videos, frame_of, cat)
    kw = dict(detect_fn=fn, catalog=cat, n_replicas=2,
              track_and_interpolate=True)
    cls = ShardedDetectionEngine if sharded else DetectionEngine
    if sharded:
        kw["n_shards"] = 2
    populated = cls(**kw).serve(frames)
    empty = cls(**kw).serve([])
    missing = set(populated) - set(empty)
    assert not missing, missing
    assert empty["models"] == {}
    assert empty["map_estimate"] == 0.0
    assert empty["roi_pixel_reduction"] == 0.0


# ----------------------------------------------- faults x catalog
def test_lent_guest_replica_carries_its_catalog():
    """Replica lending moves the executor OBJECT between shard pools:
    its loaded-model catalog must travel with it and come home intact."""
    cat_a = single_catalog(0.3)
    cat_b = paper_catalog(0.5)
    frames, frame_of, videos, dets = make_nvr_streams(2, 4, 4.0)
    fn = proxy_detect_fn_streams(videos, dets, frame_of)
    lender = DetectionEngine(detect_fn=fn, n_replicas=2,
                             service_time=0.3, catalog=cat_a)
    borrower = DetectionEngine(detect_fn=fn, n_replicas=2,
                               service_time=0.3, catalog=cat_b)
    assert all(r.catalog is cat_a for r in lender.replicas)
    wd = Watchdog()
    wd.begin([lender, borrower])
    wd._lend([lender, borrower], 0, 1, epoch=0)
    guest = borrower.replicas[-1]
    assert guest.catalog is cat_a          # home catalog travels along
    assert all(r.catalog is cat_b for r in borrower.replicas[:-1])
    wd._return([lender, borrower], wd._loans[-1], epoch=1)
    assert lender.replicas[-1].catalog is cat_a


@pytest.mark.chaos
def test_probe_health_restore_keeps_selector_hysteresis():
    """A replica revival (``probe_health`` restore) is a scheduler
    event — it must not reset the engine-owned selector's hysteresis
    state (streak, current tier, switch count)."""
    cat = paper_catalog(0.5)
    videos = fast_videos()
    frames, frame_of = trace_for(40, rate=6.0)
    sched = FaultSchedule.replica_kill(1.0, replica=0, revive_t=2.5)
    eng = DetectionEngine(detect_fn=make_cascade_detect_fn(
                              videos, frame_of, cat),
                          catalog=cat, n_replicas=2, drop_when_busy=True,
                          faults=sched)
    sel = eng.cascade
    assert sel is not None
    out = eng.serve(frames)
    assert eng.cascade is sel              # never rebuilt mid-run
    assert sel.switches == out["model_switches"]
    # direct restore probe: selector state is untouched by the scheduler
    sel._streak, sel._cur = 1, 0
    before = (sel._streak, sel._cur, sel.switches)
    eng.scheduler.probe_health(99.0)
    assert (sel._streak, sel._cur, sel.switches) == before


@pytest.mark.chaos
def test_dead_replica_capacity_leaves_cascade_feasibility():
    """A killed replica's catalog capacity drops out of the selector's
    feasible-rate budget: under the same load the degraded pool must
    select a model no heavier than the healthy pool's."""
    cat = paper_catalog(0.5)
    videos = fast_videos()
    frames, frame_of = trace_for(40, rate=7.0)
    fn = make_cascade_detect_fn(videos, frame_of, cat)
    order = [p.name for p in cat.by_quality()]

    def heaviness(report):
        return min(order.index(m) for m in report["models"])

    healthy = DetectionEngine(detect_fn=fn, catalog=cat, n_replicas=2,
                              drop_when_busy=True).serve(frames)
    frames2, _ = trace_for(40, rate=7.0)
    degraded = DetectionEngine(detect_fn=fn, catalog=cat, n_replicas=2,
                               drop_when_busy=True,
                               faults=FaultSchedule.replica_kill(
                                   0.0, replica=0)).serve(frames2)
    assert heaviness(degraded) >= heaviness(healthy)


# ------------------------------------------------- overload behavior
def test_cascade_beats_fixed_models_at_overload():
    """The tentpole's quality claim in miniature (the full gate runs in
    benchmarks/cascade_bench.py): under a lull/overload cycle the
    cascade's tracked mAP beats every fixed-model baseline."""
    import math
    # fast motion: coasted (interpolated) boxes decay across bounces,
    # so a baseline that survives overload by dropping + coasting pays
    videos = fast_videos(n_frames=200, obj_speed=0.035, cam_speed=0.006)
    cat = paper_catalog(0.5)
    img = np.zeros((4, 4, 3), np.float32)

    def sinus_trace(n=320, lo=2.0, hi=20.0, period=96):
        frames, frame_of, t = [], {}, 0.0
        seqs = [0, 0]
        for k in range(n):
            rate = lo + (hi - lo) * 0.5 * (
                1 - math.cos(2 * math.pi * k / period))
            s = k % 2
            frames.append(FrameRequest(k, img, t, stream_id=s))
            frame_of[k] = (s, seqs[s])
            seqs[s] += 1
            t += 1.0 / rate
        return frames, frame_of, seqs[0]

    def run(c):
        frames, frame_of, per_stream = sinus_trace()
        eng = DetectionEngine(detect_fn=make_cascade_detect_fn(
                                  videos, frame_of, c),
                              catalog=c, n_replicas=2,
                              drop_when_busy=True,
                              track_and_interpolate=True)
        out = eng.serve(frames)
        q = evaluate_streams(videos, out["streams"], per_stream)
        return out, q["map_mean"]

    out, cas_map = run(cat)
    assert out["model_switches"] > 0
    assert len(out["models"]) >= 2          # actually transprecise
    for name in cat.names:
        _, fixed_map = run(ModelCatalog([cat[name]]))
        assert cas_map > fixed_map, (name, cas_map, fixed_map)
