from .pipeline import (LMBatchIterator, make_lm_batches, make_modality_batch,
                       synthetic_corpus)

__all__ = ["LMBatchIterator", "make_lm_batches", "make_modality_batch",
           "synthetic_corpus"]
