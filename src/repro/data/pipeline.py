"""Synthetic data pipeline: a deterministic, learnable token stream (a
k-th order Markov chain over a Zipf vocabulary — models with capacity can
drive loss well below the unigram entropy, so train demos show real
learning), plus modality batches (audio frames / vision patches) for the
stub-frontend architectures.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

import jax.numpy as jnp

from ..models.config import ModelConfig


def synthetic_corpus(vocab: int, n_tokens: int, seed: int = 0,
                     order: int = 2) -> np.ndarray:
    """Markov chain: next token = f(prev tokens) with learnable structure
    (deterministic transitions 85% of the time, Zipf noise otherwise)."""
    rng = np.random.default_rng(seed)
    # deterministic transition table over the last `order` tokens, so a
    # model with >= order context can drive loss toward the 15% noise floor
    table = rng.integers(0, vocab, size=4096)
    zipf = rng.zipf(1.4, size=n_tokens).clip(1, vocab - 1)
    out = np.empty(n_tokens, np.int32)
    ctx = [1] * order
    for i in range(n_tokens):
        if rng.random() < 0.85:
            h = 0
            for t in ctx:
                h = h * 8191 + t
            out[i] = table[h % 4096]
        else:
            out[i] = zipf[i]
        ctx = ctx[1:] + [int(out[i])]
    return out


class LMBatchIterator:
    """Yields {tokens, labels, loss_mask} batches for causal LM training."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0, n_tokens: int = 0):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        need = n_tokens or (batch * (seq + 1) * 64)
        self.corpus = synthetic_corpus(min(cfg.vocab_size, 32768), need,
                                       seed=seed)
        self.rng = np.random.default_rng(seed + 1)

    def __iter__(self) -> Iterator[Dict]:
        while True:
            starts = self.rng.integers(
                0, len(self.corpus) - self.seq - 1, size=self.batch)
            tok = np.stack([self.corpus[s:s + self.seq] for s in starts])
            lab = np.stack([self.corpus[s + 1:s + self.seq + 1]
                            for s in starts])
            yield {
                "tokens": jnp.asarray(tok, jnp.int32),
                "labels": jnp.asarray(lab, jnp.int32),
                "loss_mask": jnp.ones((self.batch, self.seq), jnp.float32),
            }


def make_lm_batches(cfg, batch, seq, n, seed=0):
    it = iter(LMBatchIterator(cfg, batch, seq, seed))
    return [next(it) for _ in range(n)]


def make_modality_batch(cfg: ModelConfig, batch: int, seq: int,
                        seed: int = 0) -> Dict:
    """Train batch for audio (frame features) / vlm (patch embeddings)."""
    rng = np.random.default_rng(seed)
    act = jnp.dtype(cfg.dtype)
    out: Dict = {}
    if cfg.modality == "audio":
        out["features"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.frontend_dim)), act)
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
        mask = rng.random((batch, seq)) < 0.35        # masked-unit targets
        out["loss_mask"] = jnp.asarray(mask, jnp.float32)
        return out
    if cfg.modality == "vlm":
        n_img = min(cfg.n_frontend_tokens, seq // 2)
        out["image_embeds"] = jnp.asarray(
            rng.standard_normal((batch, n_img, cfg.frontend_dim)), act)
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq - n_img)), jnp.int32)
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
        mask = np.zeros((batch, seq), np.float32)
        mask[:, n_img:] = 1.0                         # loss on text only
        out["loss_mask"] = jnp.asarray(mask)
        return out
    raise ValueError(cfg.modality)
