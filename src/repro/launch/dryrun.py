import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (the two lines above MUST run before any other import — jax locks the
# device count at first init)
import argparse            # noqa: E402
import json                # noqa: E402
import re                  # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402
from pathlib import Path   # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config, supported_shapes  # noqa: E402
from repro.launch.mesh import make_production_mesh                        # noqa: E402
from repro.models import init_model                                       # noqa: E402
from repro.optim import AdamWConfig, adamw_init, make_schedule            # noqa: E402
from repro.runtime import input_specs, make_decode_step, make_prefill_step, make_train_step  # noqa: E402
from repro.sharding import input_shardings, mesh_context, param_shardings  # noqa: E402
from repro.hlo import collective_bytes_from_hlo, hlo_cost_from_text       # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# long_500k needs sub-quadratic attention: SSM/hybrid run natively; the
# full-attention archs run the sliding-window variant.
NATIVE_LONG = {"rwkv6-3b", "jamba-v0.1-52b"}


def variant_for(arch: str, shape: str):
    if shape == "long_500k" and arch not in NATIVE_LONG:
        return "swa"
    return None


def opt_config(n_params: int) -> AdamWConfig:
    # >50B params: bf16 moments so FSDP-sharded AdamW fits 16GB/chip HBM
    moment = "bfloat16" if n_params > 5e10 else "float32"
    return AdamWConfig(moment_dtype=moment)


def count_params(params_struct) -> int:
    import math
    return sum(math.prod(l.shape) for l in jax.tree.leaves(params_struct))


def build_lowered(arch: str, shape_name: str, mesh):
    """Lower one (arch x shape) pair on `mesh`. Returns (lowered, meta)."""
    variant = variant_for(arch, shape_name)
    cfg = get_config(arch, "full", variant)
    if shape_name not in supported_shapes(cfg, variant):
        return None, {"skipped": True,
                      "reason": ("encoder-only: no decode step"
                                 if cfg.encoder_only else
                                 "full attention at 524k: needs swa variant")}
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    rng_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_struct = jax.eval_shape(lambda k: init_model(cfg, k), rng_struct)
    n_params = count_params(params_struct)

    with mesh_context(mesh):
        if shape.kind == "train":
            ocfg = opt_config(n_params)
            sched = make_schedule("wsd" if arch == "minicpm-2b" else "cosine",
                                  3e-4, 10000)
            step = make_train_step(cfg, ocfg, sched, remat=True)
            state_struct = {
                "params": params_struct,
                "opt": jax.eval_shape(lambda p: adamw_init(p, ocfg),
                                      params_struct),
            }
            state_sh = param_shardings(state_struct, mesh)
            batch_sh = input_shardings(specs, mesh)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_struct, specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            p_sh = param_shardings(params_struct, mesh)
            b_sh = input_shardings(specs, mesh)
            out_struct = jax.eval_shape(step, params_struct, specs)
            cache_sh = input_shardings(out_struct[1], mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=(None, cache_sh))
            lowered = jitted.lower(params_struct, specs)
        else:
            step = make_decode_step(cfg)
            p_sh = param_shardings(params_struct, mesh)
            b_sh = input_shardings(specs, mesh)
            # out cache sharding == in cache sharding => donation aliases the
            # ring buffer in place (no 2x cache copy)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=(None, b_sh["cache"]),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_struct, specs)

    meta = {"arch": arch, "shape": shape_name, "variant": variant,
            "n_params": n_params, "kind": shape.kind,
            "mesh": dict(zip(mesh.axis_names,
                             [int(s) for s in mesh.devices.shape]))}
    return lowered, meta


def run_pair(arch: str, shape_name: str, multi_pod: bool, save=True):
    mesh_name = "multi" if multi_pod else "single"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    try:
        lowered, meta = build_lowered(arch, shape_name, mesh)
        if lowered is None:
            result.update(meta)
            print(f"[dryrun] SKIP {arch} x {shape_name} ({meta['reason']})")
        else:
            result.update(meta)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            result["memory"] = {
                k: int(getattr(mem, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
            result["cost"] = {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))}
            hlo_text = compiled.as_text()
            result["collectives"] = collective_bytes_from_hlo(hlo_text)
            result["hlo_cost"] = hlo_cost_from_text(hlo_text)
            result["timing"] = {"lower_s": t_lower - t0,
                                "compile_s": t_compile - t_lower}
            print(f"[dryrun] OK   {arch} x {shape_name} x {mesh_name} "
                  f"(lower {t_lower-t0:.1f}s compile {t_compile-t_lower:.1f}s"
                  f", {result['n_params']/1e9:.1f}B params)")
            print(f"         memory: {result['memory']}")
            flops = result['cost'].get('flops', 0.0)
            print(f"         flops={flops:.3e} "
                  f"coll_bytes={result['collectives']['total_bytes']:.3e}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] FAIL {arch} x {shape_name} x {mesh_name}: "
              f"{result['error']}")
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        out = OUT_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
        out.write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all", help="input shape or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                out = OUT_DIR / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_existing and out.exists():
                    prev = json.loads(out.read_text())
                    if "error" not in prev:
                        continue
                res = run_pair(arch, shape, mp)
                n_fail += 1 if "error" in res else 0
    print(f"[dryrun] done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
