"""Always-on NVR serving daemon: drive ``ServingRuntime`` from a clock.

The batch launchers (``launch/serve.py``) hand a full frame trace to
``eng.serve(frames)`` and wait.  The daemon is the long-lived shape of
the same computation: frames are ingested as they *arrive* on a
pluggable clock, the runtime advances its virtual time to the clock,
per-epoch rolling reports stay available mid-run, and every trace
event streams to subscribers (JSONL on disk, counters, …) the moment
it is recorded.  On shutdown the runtime drains in-flight frames and
the final report is bit-identical to what a one-shot batch
``serve(frames)`` would have produced on the same trace.

Two clocks:

* ``VirtualClock`` — ``sleep_until`` jumps instantly.  Tests and CI
  replay a whole trace in milliseconds, deterministically.
* ``WallClock`` — ``sleep_until`` really sleeps, anchored at daemon
  start.  Real runs pace ingest at the trace's arrival rate.

The serving *simulation* itself always runs on the virtual timeline
(``t_arrival`` seconds); the clock only decides how fast the daemon
walks that timeline.

Smoke run (finishes instantly, writes one JSON object per event)::

  PYTHONPATH=src python -m repro.launch.daemon --cameras 4 --frames 16 \\
      --shards 2 --clock virtual --events events.jsonl

Graceful shutdown: SIGINT/SIGTERM (wall runs) stop ingest after the
current chunk; frames already ingested are drained, audited, and
reported — never dropped on the floor.
"""
from __future__ import annotations

import argparse
import signal
import time


class VirtualClock:
    """A clock whose ``sleep_until`` jumps: ``now()`` is simply the
    largest time ever slept to.  Deterministic; replays any trace at
    CPU speed.  This is the clock for tests and CI."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def sleep_until(self, t: float):
        if t > self._now:
            self._now = float(t)


class WallClock:
    """Real time, anchored at construction: ``now()`` is seconds since
    the daemon started, ``sleep_until(t)`` blocks until that many
    seconds have really elapsed.  Paces ingest at the trace's own
    arrival rate for live runs."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def sleep_until(self, t: float):
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


class ServingDaemon:
    """Long-lived driver: ingest frames as the clock reaches their
    arrival times, advance the runtime behind the clock, drain on
    shutdown.

    ``runtime`` is a constructed ``ServingRuntime`` (any engine);
    ``clock`` anything with ``now()`` / ``sleep_until(t)``.  ``run``
    consumes an iterable of ``FrameRequest`` in arrival order, ingests
    them in chunks of ``chunk`` frames (frames whose arrival times tie
    always travel in one chunk — the runtime's watermark contract),
    and returns the final drained report.  ``request_stop()`` (also
    wired to SIGINT/SIGTERM by the CLI) makes ``run`` stop ingesting
    after the current chunk and fall through to ``shutdown()``.
    """

    def __init__(self, runtime, clock=None, chunk: int = 1):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.runtime = runtime
        self.clock = clock if clock is not None else VirtualClock()
        self.chunk = chunk
        self.frames_ingested = 0
        self._stop = False

    def request_stop(self):
        """Ask ``run`` to stop ingesting after the current chunk; the
        frames already ingested still drain.  Safe from a signal
        handler."""
        self._stop = True

    def run(self, frames) -> dict:
        """Pace ``frames`` (arrival order) through the runtime and
        return the drained final report."""
        pending = []
        for f in frames:
            if self._stop:
                break
            if pending and (len(pending) >= self.chunk
                            and f.t_arrival != pending[-1].t_arrival):
                self._flush(pending)
                pending = []
            pending.append(f)
        if pending and not self._stop:
            self._flush(pending)
        return self.shutdown()

    def _flush(self, chunk):
        self.clock.sleep_until(chunk[-1].t_arrival)
        self.runtime.ingest(chunk)
        self.runtime.advance(self.clock.now())
        self.frames_ingested += len(chunk)

    def shutdown(self) -> dict:
        """Drain in-flight frames and return the final report (bit-
        identical to a one-shot batch ``serve`` of everything
        ingested)."""
        return self.runtime.drain()


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="always-on NVR detection daemon (incremental "
                    "serving core + event pipeline)")
    ap.add_argument("--cameras", type=int, default=4)
    ap.add_argument("--frames", type=int, default=24,
                    help="frames per camera in the synthetic trace")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--n-replicas", type=int, default=2)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="per-camera arrival FPS")
    ap.add_argument("--clock", default="virtual",
                    choices=["virtual", "wall"],
                    help="virtual: replay instantly (tests/CI); wall: "
                         "pace ingest in real time")
    ap.add_argument("--chunk", type=int, default=1,
                    help="frames ingested per runtime call")
    ap.add_argument("--events", default=None, metavar="OUT.jsonl",
                    help="stream every trace event as one JSON line")
    ap.add_argument("--rebalance", action="store_true",
                    help="epoch-boundary rebalancing (shards >= 2)")
    ap.add_argument("--epoch-s", type=float, default=4.0)
    ap.add_argument("--watchdog", action="store_true",
                    help="supervise epoch boundaries with the PR 6 "
                         "Watchdog (implies --rebalance)")
    args = ap.parse_args(argv)

    from repro.core import proxy_detect_fn_streams
    from repro.obs import audit_recorder
    from repro.serving import (EventBus, JsonlSink, ServingRuntime,
                               ShardedDetectionEngine, Watchdog,
                               make_nvr_streams)
    from repro.serving.runtime import _sorted_chunk  # arrival order

    if args.watchdog:
        args.rebalance = True

    frames, frame_of, videos, dets = make_nvr_streams(
        args.cameras, args.frames, args.rate)
    frames = _sorted_chunk(frames)

    bus = EventBus()
    sink = None
    if args.events:
        sink = JsonlSink(args.events)
        bus.subscribe(sink)
    recorder = bus.recorder()

    eng = ShardedDetectionEngine(
        n_shards=args.shards,
        detect_fn=proxy_detect_fn_streams(videos, dets, frame_of),
        service_time=0.4, n_replicas=args.n_replicas,
        track_and_interpolate=True, rebalance=args.rebalance,
        epoch_s=args.epoch_s,
        supervisor=Watchdog() if args.watchdog else None,
        recorder=recorder)
    rt = ServingRuntime(eng, streams=range(args.cameras))
    clock = VirtualClock() if args.clock == "virtual" else WallClock()
    daemon = ServingDaemon(rt, clock=clock, chunk=args.chunk)

    if args.clock == "wall":
        for sig in (signal.SIGINT, signal.SIGTERM):
            signal.signal(sig, lambda *_: daemon.request_stop())

    out = daemon.run(frames)
    if sink is not None:
        sink.close()

    print(f"daemon clock={args.clock} cameras={args.cameras} "
          f"shards={out['n_shards']} ingested={daemon.frames_ingested} "
          f"pending={rt.frames_pending}")
    print(f"coverage={out['coverage']:.3f} dropped={len(out['dropped'])} "
          f"throughput={out['throughput_fps']:.2f} fps "
          f"p95_latency={out['p95_latency']*1e3:.1f} ms")
    print("events: " + "  ".join(
        f"{topic}={bus.counts.get(topic, 0)}"
        for topic in sorted(bus.counts)))
    if sink is not None:
        print(f"events -> {args.events} ({sink.n_written} lines)")

    res = audit_recorder(recorder)
    print(f"audit={'ok' if res.ok else 'FAIL'} "
          f"({len(recorder.events)} events)")
    if not res.ok:
        for v in res.violations[:5]:
            print(f"  audit violation: {v}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
