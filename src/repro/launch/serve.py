"""Serving launcher: run the parallel-replica serving engine on any
assigned architecture (smoke preset on CPU; the full configs are exercised
via dryrun.py).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \\
      --n-replicas 4 --scheduler fcfs --requests 24
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--n-replicas", type=int, default=4)
    ap.add_argument("--scheduler", default="fcfs",
                    choices=["fcfs", "rr", "wrr", "proportional"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="request arrival rate (req/s)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--heterogeneous", action="store_true",
                    help="replica 0 is 5x faster (the paper's fast-CPU+"
                         "NCS2 mix)")
    args = ap.parse_args()

    cfg = get_config(args.arch, preset=args.preset)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving "
                         f"(see DESIGN.md §Arch-applicability)")
    speeds = None
    if args.heterogeneous:
        speeds = [0.2] + [1.0] * (args.n_replicas - 1)
    engine = ServingEngine(cfg, n_replicas=args.n_replicas,
                           scheduler=args.scheduler, cache_len=256,
                           replica_speeds=speeds)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size - 1, args.prompt_len)
                    .astype(np.int32), args.new_tokens, i / args.rate)
            for i in range(args.requests)]
    out = engine.serve(reqs)
    print(f"arch={args.arch} n={args.n_replicas} sched={args.scheduler}")
    print(f"throughput={out['throughput_rps']:.2f} req/s  "
          f"p50_latency={out['p50_latency']*1e3:.1f} ms  "
          f"dropped={len(out['dropped'])}")
    print(f"per-replica counts: {out['per_replica']}")
    first = out["responses"][0]
    print(f"first response tokens: {first.tokens.tolist()}")


if __name__ == "__main__":
    main()
