"""Serving launcher: run the parallel-replica serving engine on any
assigned architecture (smoke preset on CPU; the full configs are exercised
via dryrun.py).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \\
      --n-replicas 4 --scheduler fcfs --requests 24

``--payload frames`` switches to the detection/NVR path: a synthetic
multi-camera trace served by ``ShardedDetectionEngine`` on a
``make_serving_mesh`` host mesh (``--shards`` > available devices falls
back to the meshless Python partition with a warning; force devices
with XLA_FLAGS=--xla_force_host_platform_device_count=N).

  PYTHONPATH=src python -m repro.launch.serve --payload frames \\
      --shards 2 --cameras 8 --frames 24
"""
from __future__ import annotations

import argparse

import numpy as np


def serve_frames(args):
    """Serve-mode mesh entry point for sharded NVR detection."""
    import jax

    from repro.core import evaluate_streams, proxy_detect_fn_streams
    from repro.launch.mesh import make_serving_mesh
    from repro.serving import ShardedDetectionEngine, make_nvr_streams

    recorder = None
    if args.trace:
        from repro.obs import TraceRecorder
        recorder = TraceRecorder()
    frames, frame_of, videos, dets = make_nvr_streams(
        args.cameras, args.frames, args.rate)
    mesh = None
    if args.spmd:
        if args.shards <= len(jax.devices()):
            mesh = make_serving_mesh(args.shards)
        else:
            print(f"# {args.shards} shards > {len(jax.devices())} devices: "
                  "meshless fallback (set XLA_FLAGS=--xla_force_host_"
                  "platform_device_count to get a real mesh)")
    kw = dict(n_shards=args.shards, n_replicas=args.n_replicas,
              scheduler=args.scheduler, track_and_interpolate=True,
              recorder=recorder)
    catalog = None
    if args.models:
        from repro.serving import ModelCatalog, paper_catalog
        full = paper_catalog()
        names = [m.strip() for m in args.models.split(",") if m.strip()]
        catalog = ModelCatalog([full[m] for m in names])
        spec = videos[0].spec
        kw.update(catalog=catalog, roi=args.roi,
                  roi_bounds=(spec.width, spec.height))
    if mesh is not None:
        eng = ShardedDetectionEngine(mesh=mesh, **kw)
        # the SPMD path runs the real mini-SSD: give it real-sized
        # images (the oracle trace carries 4x4 placeholders)
        size = eng.cfg.image_size
        rng = np.random.default_rng(0)
        for f in frames:
            f.image = rng.random((size, size, 3)).astype(np.float32)
    elif catalog is not None:   # transprecise oracle: per-band detectors
        from repro.serving import make_cascade_detect_fn
        eng = ShardedDetectionEngine(
            detect_fn=make_cascade_detect_fn(videos, frame_of, catalog),
            **kw)
    else:                      # oracle fallback: per-camera proxy detectors
        eng = ShardedDetectionEngine(
            detect_fn=proxy_detect_fn_streams(videos, dets, frame_of),
            service_time=0.4, **kw)
    out = eng.serve(frames)
    q = evaluate_streams(videos, out["streams"], args.frames) \
        if mesh is None else None
    print(f"payload=frames shards={out['n_shards']} "
          f"cameras={out['n_streams']} spmd={mesh is not None}")
    print(f"coverage={out['coverage']:.3f} "
          f"interpolated={out['interpolated']} "
          f"throughput={out['throughput_fps']:.2f} fps")
    for h, shard in enumerate(out["per_shard"]):
        print(f"  shard {h}: cameras={shard['streams']} "
              f"frames={shard['frames']} dropped={shard['dropped']} "
              f"tracker_launches={shard['tracker_launches']}")
    if args.models:
        red = out["roi_pixel_reduction"]
        print(f"cascade models={out['models']} "
              f"switches={out['model_switches']} "
              f"map_estimate={out['map_estimate']:.3f} "
              f"roi_passes={out['roi_pixels']['passes']} "
              f"roi_pixel_reduction={red:.3f}")
    if q is not None:
        print(f"tracked mAP mean={q['map_mean']*100:.1f}% "
              f"min={q['map_min']*100:.1f}%")
    print(f"p95_latency={out['p95_latency']*1e3:.1f} ms "
          f"p99_latency={out['p99_latency']*1e3:.1f} ms")
    if recorder is not None:
        _write_trace(args.trace, recorder)


def _write_trace(path: str, recorder):
    """Export the recorded trace (Perfetto-viewable Chrome JSON) and
    audit it before writing — a trace that breaks the serving
    invariants should fail loudly at the source, not at inspection."""
    from repro.obs import audit_recorder, write_chrome_trace
    res = audit_recorder(recorder)
    write_chrome_trace(path, recorder)
    print(f"trace: {len(recorder.events)} events -> {path} "
          f"(open at https://ui.perfetto.dev)  audit="
          f"{'ok' if res.ok else 'FAIL'}")
    if not res.ok:
        for v in res.violations[:5]:
            print(f"  audit violation: {v}")
        raise SystemExit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--payload", default="tokens",
                    choices=["tokens", "frames"],
                    help="tokens: LLM serving; frames: sharded NVR "
                         "detection on the serving mesh")
    ap.add_argument("--shards", type=int, default=1,
                    help="frames payload: mesh shards for the camera set")
    ap.add_argument("--cameras", type=int, default=4)
    ap.add_argument("--frames", type=int, default=24,
                    help="frames payload: frames per camera")
    ap.add_argument("--spmd", action="store_true",
                    help="frames payload: use the mesh SPMD detect path "
                         "(mini-SSD) instead of the proxy oracle")
    ap.add_argument("--models", default=None, metavar="fast,heavy",
                    help="frames payload: comma subset of "
                         "fast/medium/heavy -> transprecise cascade "
                         "(per-micro-batch model selection over the "
                         "paper_catalog profiles)")
    ap.add_argument("--roi", action="store_true",
                    help="frames payload: hierarchical ROI second pass "
                         "(cheap first-pass boxes re-detected by the "
                         "heaviest catalog model; needs --models)")
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--n-replicas", type=int, default=4)
    ap.add_argument("--scheduler", default="fcfs",
                    choices=["fcfs", "rr", "wrr", "proportional"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=None,
                    help="arrival rate: req/s for tokens (default 20), "
                         "per-camera FPS for frames (default 2)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--heterogeneous", action="store_true",
                    help="replica 0 is 5x faster (the paper's fast-CPU+"
                         "NCS2 mix)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record the frame-lifecycle trace and export "
                         "it as Chrome-trace-event JSON (open at "
                         "https://ui.perfetto.dev); the trace is "
                         "audited before writing")
    args = ap.parse_args()

    if args.rate is None:
        args.rate = 2.0 if args.payload == "frames" else 20.0

    if args.payload == "frames":
        serve_frames(args)
        return

    from repro.configs import ARCH_IDS, get_config
    from repro.serving import Request, ServingEngine

    if args.arch not in ARCH_IDS:
        raise SystemExit(f"unknown --arch {args.arch}; one of {ARCH_IDS}")
    cfg = get_config(args.arch, preset=args.preset)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    speeds = None
    if args.heterogeneous:
        speeds = [0.2] + [1.0] * (args.n_replicas - 1)
    recorder = None
    if args.trace:
        from repro.obs import TraceRecorder
        recorder = TraceRecorder()
    engine = ServingEngine(cfg, n_replicas=args.n_replicas,
                           scheduler=args.scheduler, cache_len=256,
                           replica_speeds=speeds, recorder=recorder)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size - 1, args.prompt_len)
                    .astype(np.int32), args.new_tokens, i / args.rate)
            for i in range(args.requests)]
    out = engine.serve(reqs)
    print(f"arch={args.arch} n={args.n_replicas} sched={args.scheduler}")
    print(f"throughput={out['throughput_rps']:.2f} req/s  "
          f"p50_latency={out['p50_latency']*1e3:.1f} ms  "
          f"dropped={len(out['dropped'])}")
    print(f"p95_latency={out['p95_latency']*1e3:.1f} ms  "
          f"p99_latency={out['p99_latency']*1e3:.1f} ms")
    print(f"per-replica counts: {out['per_replica']}")
    first = out["responses"][0]
    print(f"first response tokens: {first.tokens.tolist()}")
    if recorder is not None:
        _write_trace(args.trace, recorder)


if __name__ == "__main__":
    main()
