"""Training launcher: real training steps on a reduced config (CPU), or
mesh-sharded lowering for the full configs via --dry-run (see dryrun.py
for the full sweep).

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data import LMBatchIterator, make_modality_batch
from repro.optim import AdamWConfig, make_schedule
from repro.runtime import make_train_step, train_state_init
from repro.runtime.checkpoint import restore_checkpoint, save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b", choices=ARCH_IDS)
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default=None,
                    help="cosine|wsd (default: wsd for minicpm, else cosine)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch, preset=args.preset)
    sched_kind = args.schedule or ("wsd" if "minicpm" in args.arch
                                   else "cosine")
    opt_cfg = AdamWConfig(peak_lr=args.lr)
    schedule = make_schedule(sched_kind, args.lr, args.steps,
                             warmup_steps=max(2, args.steps // 10))
    state = train_state_init(cfg, jax.random.PRNGKey(0), opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, schedule, remat=False),
                      donate_argnums=(0,))

    if cfg.modality == "text":
        batches = iter(LMBatchIterator(cfg, args.batch, args.seq))
        next_batch = lambda i: next(batches)
    else:
        next_batch = lambda i: make_modality_batch(cfg, args.batch,
                                                   args.seq, seed=i)

    t0 = time.time()
    for i in range(args.steps):
        state, metrics = step_fn(state, next_batch(i))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(metrics['total_loss']):.4f} "
                  f"ce={float(metrics['ce_loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f}")
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")

    if args.checkpoint:
        save_checkpoint(args.checkpoint, state, step=args.steps)
        restored = restore_checkpoint(args.checkpoint, state)
        print(f"checkpoint round-trip OK -> {args.checkpoint}")


if __name__ == "__main__":
    main()
