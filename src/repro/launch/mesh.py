"""Production mesh definitions (TPU v5e-class pods).

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first
jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_replica_mesh(n_replicas: int, chips_per_replica: int = 1):
    """Paper-mode mesh: n parallel detection-model replicas over the
    ``replica`` axis (the paper's n NCS2 sticks), each replica spanning
    ``chips_per_replica`` model-parallel chips."""
    return jax.make_mesh((n_replicas, chips_per_replica),
                         ("data", "model"))


def make_host_mesh():
    """Single-host CPU mesh for smoke runs."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
