"""Production mesh definitions (TPU v5e-class pods).

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first
jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_replica_mesh(n_replicas: int, chips_per_replica: int = 1):
    """Paper-mode mesh: n parallel detection-model replicas over the
    ``replica`` axis (the paper's n NCS2 sticks), each replica spanning
    ``chips_per_replica`` model-parallel chips."""
    return jax.make_mesh((n_replicas, chips_per_replica),
                         ("data", "model"))


def make_host_mesh():
    """Single-host CPU mesh for smoke runs."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_serving_mesh(n_shards: int | None = None):
    """Serve-mode mesh for sharded NVR detection: ``n_shards`` entries
    on the ``data`` axis (the ``replica`` logical axis the serving
    sharding rules target), one model-parallel column each.

    Defaults to one shard per visible device.  Raises if the host has
    fewer devices than shards — on a CPU smoke host, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before*
    the first jax import to fake an N-device mesh (what
    ``benchmarks/sharded_bench.py`` does)."""
    n = n_shards if n_shards is not None else len(jax.devices())
    avail = len(jax.devices())
    if n > avail:
        raise ValueError(
            f"make_serving_mesh({n}) needs {n} devices but only {avail} "
            "are visible; set XLA_FLAGS=--xla_force_host_platform_"
            "device_count before the first jax import for CPU smoke "
            "meshes")
    return jax.make_mesh((n, 1), ("data", "model"))
