"""Synthetic multi-camera (NVR) workload builder for the serving
engine's multi-stream path — shared by the NVR tests, benchmark and
example so the arrival-phase formula and detector seeding exist in
exactly one place.  Lives in ``serving`` (not ``core``) because it
constructs ``FrameRequest``s: serving depends on core, never the
reverse.
"""
from __future__ import annotations

import numpy as np

from ..core.quality import ProxyDetector
from ..core.stream import ETH_SUNNYDAY, SyntheticVideo
from .engine import FrameRequest


def make_nvr_streams(n_streams: int, n_frames: int, rate: float,
                     video: SyntheticVideo | None = None,
                     model: str = "yolov3"):
    """``n_streams`` cameras each pacing ``n_frames`` at ``rate`` FPS
    with phase-staggered arrivals so the streams interleave, plus
    per-camera proxy detectors (distinct seeds) over the same
    benchmark scene.  Returns ``(frames, frame_of, videos,
    detectors)`` where ``frame_of`` maps the globally-unique rid back
    to ``(stream_id, per-stream frame index)`` — the tuple
    ``core.quality.proxy_detect_fn_streams`` consumes."""
    video = video if video is not None else SyntheticVideo(ETH_SUNNYDAY)
    name = video.spec.name
    frames, frame_of = [], {}
    rid = 0
    for k in range(n_frames):
        for s in range(n_streams):
            frames.append(FrameRequest(
                rid, np.zeros((4, 4, 3), np.float32),
                (k + s / n_streams) / rate, stream_id=s))
            frame_of[rid] = (s, k)
            rid += 1
    videos = {s: video for s in range(n_streams)}
    detectors = {s: ProxyDetector(model, name, seed=s)
                 for s in range(n_streams)}
    return frames, frame_of, videos, detectors


def make_skewed_streams(n_streams: int, n_frames: int, rate: float,
                        n_shards: int, skew: float = 2.0,
                        video: SyntheticVideo | None = None,
                        model: str = "yolov3"):
    """Skewed NVR trace for the work-stealing benchmark: the cameras the
    static round-robin partition (``shard_streams``) assigns to shard 0
    run at ``skew x rate`` — with ``skew x n_frames`` frames, so every
    camera spans the SAME ``n_frames / rate`` time horizon — while the
    rest pace ``n_frames`` at ``rate``.  This concentrates the paper's
    §III rate mismatch on one shard: under the static partition, shard
    0 drops frames while its neighbors idle; a work-stealing dispatcher
    should migrate one of shard 0's hot cameras away.

    Frame rids are assigned in global arrival order (ties broken by
    stream id), so they are globally unique and deterministic.  Returns
    the same ``(frames, frame_of, videos, detectors)`` tuple as
    ``make_nvr_streams``."""
    from ..sharding.serving_rules import shard_streams
    video = video if video is not None else SyntheticVideo(ETH_SUNNYDAY)
    name = video.spec.name
    shard_of = shard_streams(range(n_streams), n_shards)
    events = []
    for s in range(n_streams):
        factor = skew if shard_of[s] == 0 else 1.0
        r_s = rate * factor
        for k in range(int(round(n_frames * factor))):
            events.append(((k + s / n_streams) / r_s, s, k))
    events.sort()
    frames, frame_of = [], {}
    for rid, (t, s, k) in enumerate(events):
        frames.append(FrameRequest(rid, np.zeros((4, 4, 3), np.float32),
                                   t, stream_id=s))
        frame_of[rid] = (s, k)
    videos = {s: video for s in range(n_streams)}
    detectors = {s: ProxyDetector(model, name, seed=s)
                 for s in range(n_streams)}
    return frames, frame_of, videos, detectors
