"""Synthetic multi-camera (NVR) workload builder for the serving
engine's multi-stream path — shared by the NVR tests, benchmark and
example so the arrival-phase formula and detector seeding exist in
exactly one place.  Lives in ``serving`` (not ``core``) because it
constructs ``FrameRequest``s: serving depends on core, never the
reverse.
"""
from __future__ import annotations

import numpy as np

from ..core.quality import ProxyDetector
from ..core.stream import ETH_SUNNYDAY, SyntheticVideo
from .engine import FrameRequest


def make_nvr_streams(n_streams: int, n_frames: int, rate: float,
                     video: SyntheticVideo | None = None,
                     model: str = "yolov3"):
    """``n_streams`` cameras each pacing ``n_frames`` at ``rate`` FPS
    with phase-staggered arrivals so the streams interleave, plus
    per-camera proxy detectors (distinct seeds) over the same
    benchmark scene.  Returns ``(frames, frame_of, videos,
    detectors)`` where ``frame_of`` maps the globally-unique rid back
    to ``(stream_id, per-stream frame index)`` — the tuple
    ``core.quality.proxy_detect_fn_streams`` consumes."""
    video = video if video is not None else SyntheticVideo(ETH_SUNNYDAY)
    name = video.spec.name
    frames, frame_of = [], {}
    rid = 0
    for k in range(n_frames):
        for s in range(n_streams):
            frames.append(FrameRequest(
                rid, np.zeros((4, 4, 3), np.float32),
                (k + s / n_streams) / rate, stream_id=s))
            frame_of[rid] = (s, k)
            rid += 1
    videos = {s: video for s in range(n_streams)}
    detectors = {s: ProxyDetector(model, name, seed=s)
                 for s in range(n_streams)}
    return frames, frame_of, videos, detectors
