"""Sharded multi-host NVR serving on the replica mesh.

``DetectionEngine`` multiplexes every camera of an NVR deployment onto
one host's replica pool.  This layer carries the same serving contract
across a *device mesh*: the camera set is partitioned over mesh shards
(``sharding.serving_rules.shard_streams`` — deterministic, so every
host agrees without communicating), each shard runs its own
``DetectionEngine`` — its own scheduler, interleaved micro-batches and
lockstep ``B = cameras-per-shard`` tracker — and the per-shard reports
are merged into ONE global engine report with the exact key set
``DetectionEngine.serve`` produces (so ``core.quality.evaluate_streams``
consumes it unchanged).

Two detection paths
-------------------
* **SPMD fast path** (``mesh=`` given): the batched detect+NMS launch
  is ONE ``jax.jit`` program whose micro-batch dim carries the
  ``replica`` logical axis (``constrain_frames`` /
  ``constrain_detections``), compiled once and shared by every shard —
  the mesh, not a Python loop, spreads frames over devices.  This is
  the paper's "n parallel detection models" as a single compiled
  program spanning the mesh.
* **Scheduler fallback** (``mesh=None``): each shard's engine keeps its
  own per-host jitted program (or the caller's ``detect_fn`` oracle) —
  the path for heterogeneous device pools, which one SPMD program
  cannot model, and for numpy oracles, which cannot be jitted.

Single-shard regression bar: ``ShardedDetectionEngine(n_shards=1,
**kw).serve(trace)`` is bit-identical to
``DetectionEngine(**kw).serve(trace)`` — the sharded layer adds keys
(``n_shards``, ``per_shard``, ``shard_of_stream``) but never changes
the base report.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.synchronizer import SequenceSynchronizer
from ..obs.metrics import merge_hist_dicts, quantile_of_dict
from ..obs.trace import NULL_RECORDER
from ..sharding.context import mesh_context
from ..sharding.serving_rules import constrain_detections, constrain_frames
from .engine import DetectionEngine, FrameRequest
from .models import cascade_report_keys


def make_spmd_detect(cfg, params, mesh, *, score_thr: float = 0.4,
                     iou_thr: float = 0.5, max_out: int = 32,
                     use_pallas: bool = False):
    """ONE jitted detect+NMS program spanning every replica of ``mesh``.

    Wraps the unchanged ``detector.decode_detections`` with replica-axis
    sharding constraints on its input images and output detections, so
    a micro-batch of B frames is computed by the mesh's ``data`` axis
    shards in a single compiled program — the SPMD replacement for the
    Python-side per-replica executor loop.  On a 1-device mesh the
    constraints are no-ops and the outputs are bit-identical to
    ``DetectionEngine``'s own jitted path.

    Returns a ``(images, rids=None) -> (boxes, scores, classes, valid)``
    callable matching the ``DetectionEngine.detect_fn`` interface
    (blocking, so the engine's wall-time measurement brackets real
    device work)."""
    from ..detector import decode_detections, make_anchors
    anchors = jnp.asarray(make_anchors(cfg))

    def infer(imgs):
        imgs = constrain_frames(imgs)
        out = decode_detections(params, cfg, imgs, anchors,
                                score_thr=score_thr, iou_thr=iou_thr,
                                max_out=max_out, use_pallas=use_pallas)
        return constrain_detections(*out)

    jitted = jax.jit(infer)

    def detect(images, rids=None):
        with mesh_context(mesh):
            return jax.block_until_ready(jitted(jnp.asarray(images)))

    return detect


def _renumber_and_collect(frames: Sequence[FrameRequest],
                          reports: Sequence[Dict],
                          report_shard: Sequence[int],
                          pool_sizes: Sequence[int]):
    """Shared merge scaffolding for ``merge_shard_reports`` (one report
    per shard) and ``merge_epoch_shard_reports`` (one per epoch x
    shard): renumber replica ids by the owning shard's pool offset (on
    COPIES — never the caller's responses; offset 0 reuses the original
    objects so single-shard reports stay bit-identical), collect
    responses in rid order and dropped rids in global arrival order
    (stable on ties, like the engine's own sort), sum the per-call
    ``per_replica`` counts into the globally-renumbered map, and
    rebuild the per-stream view from the merged responses with the
    engine's own reorder helper — so ``streams`` holds the SAME objects
    as ``responses``, the DetectionEngine contract.

    Returns ``(responses, dropped, makespan, per_replica, streams,
    emit_t)``."""
    n_shards = len(pool_sizes)
    offsets = [0] * n_shards
    for h in range(1, n_shards):
        offsets[h] = offsets[h - 1] + pool_sizes[h - 1]
    per_replica: Dict[int, int] = {
        offsets[h] + i: 0 for h in range(n_shards)
        for i in range(pool_sizes[h])}
    responses = []
    for rep, h in zip(reports, report_shard):
        off = offsets[h]
        for idx, count in rep["per_replica"].items():
            per_replica[off + idx] += count
        for r in rep["responses"]:
            if off and r.replica >= 0:
                r = replace(r, replica=r.replica + off)
            responses.append(r)
    responses.sort(key=lambda r: r.rid)
    pos = {f.rid: i for i, f in
           enumerate(sorted(frames, key=lambda f: f.t_arrival))}
    dropped = sorted((rid for rep in reports for rid in rep["dropped"]),
                     key=pos.__getitem__)
    makespan = max((r.t_done for r in responses), default=0.0)
    ordered = SequenceSynchronizer.order_per_stream(responses)
    streams = {sid: rs for sid, (rs, _) in ordered.items()}
    emit_t = {sid: em for sid, (_, em) in ordered.items()}
    return responses, dropped, makespan, per_replica, streams, emit_t


def _merged_fault_counts(reports: Sequence[Dict],
                         report_shard: Sequence[int],
                         pool_sizes: Sequence[int]) -> Dict[str, Dict]:
    """Sum the per-replica failure counters (``retries`` / ``failovers``
    / ``frames_lost``) across shard reports, renumbering replica ids by
    the owning shard's pool offset exactly like ``per_replica``.  The
    keys stay sparse (all-empty on the fault-free path), mirroring the
    single-engine report."""
    offsets = [0] * len(pool_sizes)
    for h in range(1, len(pool_sizes)):
        offsets[h] = offsets[h - 1] + pool_sizes[h - 1]
    out: Dict[str, Dict] = {"retries": {}, "failovers": {},
                            "frames_lost": {}}
    for rep, h in zip(reports, report_shard):
        for key, agg in out.items():
            for idx, c in rep.get(key, {}).items():
                g = offsets[h] + idx
                agg[g] = agg.get(g, 0) + c
    return out


def _merged_latency_keys(responses, reports: Sequence[Dict],
                         report_shard: Sequence[int],
                         pool_sizes: Sequence[int]) -> Dict:
    """Rebuild the latency block of a merged report (``repro.obs``
    contract): histograms SUM bucket-wise across shard reports and the
    quantiles are recomputed from the merged buckets — never averaged
    (an average of per-shard p99s is not a p99).  ``p50_latency`` is
    recomputed exactly (median over the merged detection latencies,
    the same formula the engine uses), so a single-shard merge is
    bit-identical to the shard's own report.  ``latency_by_replica``
    keys renumber by the owning shard's pool offset like
    ``per_replica``."""
    det = merge_hist_dicts(rep.get("latency_hist") for rep in reports)
    interp = merge_hist_dicts(rep.get("interp_latency")
                              for rep in reports)
    by_stream: Dict[int, List] = {}
    by_replica: Dict[int, List] = {}
    offsets = [0] * len(pool_sizes)
    for h in range(1, len(pool_sizes)):
        offsets[h] = offsets[h - 1] + pool_sizes[h - 1]
    for rep, h in zip(reports, report_shard):
        for sid, d in rep.get("latency_by_stream", {}).items():
            by_stream.setdefault(sid, []).append(d)
        for idx, d in rep.get("latency_by_replica", {}).items():
            by_replica.setdefault(offsets[h] + idx, []).append(d)
    lat = [r.t_done - r.t_start for r in responses if not r.interpolated]
    return {
        "p50_latency": float(np.median(lat)) if lat else 0.0,
        "p95_latency": quantile_of_dict(det, 0.95),
        "p99_latency": quantile_of_dict(det, 0.99),
        "latency_hist": det,
        "interp_latency": interp,
        "latency_by_stream": {sid: merge_hist_dicts(ds)
                              for sid, ds in sorted(by_stream.items())},
        "latency_by_replica": {g: merge_hist_dicts(ds)
                               for g, ds in sorted(by_replica.items())},
    }


def _epoch_rollup(reports: Sequence[Dict]) -> Dict:
    """One epoch's latency/volume rollup for the ``per_epoch`` key."""
    det = merge_hist_dicts(rep.get("latency_hist") for rep in reports)
    return {
        "responses": sum(len(rep["responses"]) for rep in reports),
        "dropped": sum(len(rep["dropped"]) for rep in reports),
        "interpolated": sum(rep["interpolated"] for rep in reports),
        "latency_hist": det,
        "p95_latency": quantile_of_dict(det, 0.95),
        "p99_latency": quantile_of_dict(det, 0.99),
    }


def _merged_cascade_keys(reports: Sequence[Dict], n_frames: int) -> Dict:
    """Merge the transprecise-cascade block: raw counters sum (model
    counts, switches, roi pixels) or union (``model_of_frame`` /
    ``model_map_est`` — rids are globally unique, catalogs agree on
    names), then the derived scalars (``map_estimate``,
    ``roi_pixel_reduction``) are RECOMPUTED by the same
    ``cascade_report_keys`` the engines use — never averaged — so a
    single-shard merge is bit-identical to the shard's own report."""
    counts: Dict[str, int] = {}
    model_of: Dict[int, str] = {}
    map_est: Dict[str, float] = {}
    switches = 0
    roi_px = {"full": 0.0, "roi": 0.0, "passes": 0}
    for rep in reports:
        for m, c in rep.get("models", {}).items():
            counts[m] = counts.get(m, 0) + c
        model_of.update(rep.get("model_of_frame", {}))
        map_est.update(rep.get("model_map_est", {}))
        switches += rep.get("model_switches", 0)
        rp = rep.get("roi_pixels", {})
        roi_px["full"] += rp.get("full", 0.0)
        roi_px["roi"] += rp.get("roi", 0.0)
        roi_px["passes"] += rp.get("passes", 0)
    return cascade_report_keys(counts, model_of, map_est, switches,
                               roi_px, n_frames)


def merge_shard_reports(frames: Sequence[FrameRequest],
                        reports: Sequence[Dict],
                        pool_sizes: Sequence[int]) -> Dict:
    """Merge per-shard ``DetectionEngine.serve`` reports into one global
    engine report.

    Streams are disjoint across shards, so the per-stream maps
    (``streams`` / ``emit_t`` / ``per_stream``) merge by union; global
    scalars (``coverage``, ``throughput_fps``) are recomputed from the
    merged responses with the same formulas ``DetectionEngine`` uses;
    replica ids are renumbered globally (shard ``h``'s replica ``i``
    becomes ``offset(h) + i`` with ``offset = cumsum(pool_sizes)``) —
    both the ``per_replica`` map and every ``DetectionResponse.replica``
    field (the ``-1`` tracker-interpolated sentinel excepted), so
    grouping responses by replica stays consistent with the map.  With
    a single shard every merged key is bit-identical to the shard's own
    report.

    Adds the shard-level view on top: ``n_shards`` and ``per_shard``
    (per-shard frame/response/drop/tracker counts).  The caller attaches
    ``shard_of_stream``.

    Tracker accounting across shards: each shard runs its OWN lockstep
    tracker, so the merged ``tracker_launches`` SUMS over shards while
    ``tracker_ticks`` is the MAX (the shards tick in parallel, not in
    series).  The single-engine invariant "one launch per tick" thus
    reads globally as ``launches == n_shards x ticks`` — exact when
    every shard saw the same tick count (balanced frames-per-stream),
    an upper bound on ``ticks`` otherwise."""
    # renumber replica ids on COPIES (never mutate the caller's shard
    # reports), keeping the -1 tracker-interpolated sentinel; offset 0
    # (first shard / single shard) reuses the original objects so the
    # shards=1 report stays bit-identical
    responses, dropped, makespan, per_replica, streams, emit_t = \
        _renumber_and_collect(frames, reports, range(len(reports)),
                              pool_sizes)
    per_stream: Dict[int, Dict] = {}
    for rep in reports:
        per_stream.update(rep["per_stream"])
        for sid in rep["streams"]:
            streams.setdefault(sid, [])      # streams with 0 responses
            emit_t.setdefault(sid, [])
    return {
        "responses": responses,
        "dropped": dropped,
        "coverage": len(responses) / max(len(frames), 1),
        "interpolated": sum(rep["interpolated"] for rep in reports),
        "throughput_fps": len(responses) / max(makespan, 1e-9),
        "per_replica": per_replica,
        "n_streams": sum(rep["n_streams"] for rep in reports),
        "streams": streams,
        "emit_t": emit_t,
        "per_stream": per_stream,
        "tracker_launches": sum(rep["tracker_launches"]
                                for rep in reports),
        "tracker_ticks": max((rep["tracker_ticks"] for rep in reports),
                             default=0),
        **_merged_fault_counts(reports, range(len(reports)), pool_sizes),
        **_merged_latency_keys(responses, reports, range(len(reports)),
                               pool_sizes),
        **_merged_cascade_keys(reports, len(frames)),
        "per_epoch": {0: _epoch_rollup(reports)},
        "n_shards": len(reports),
        "per_shard": [{
            "streams": sorted(rep["per_stream"]),
            "frames": sum(v["frames"] for v in rep["per_stream"].values()),
            "responses": len(rep["responses"]),
            "dropped": len(rep["dropped"]),
            "interpolated": rep["interpolated"],
            "tracker_launches": rep["tracker_launches"],
            "tracker_ticks": rep["tracker_ticks"],
            "latency_hist": merge_hist_dicts([rep.get("latency_hist")]),
        } for rep in reports],
    }


def merge_epoch_shard_reports(frames: Sequence[FrameRequest],
                              reports: Sequence[Dict],
                              report_shard: Sequence[int],
                              pool_sizes: Sequence[int],
                              report_epoch: Optional[Sequence[int]] = None,
                              ) -> Dict:
    """Merge per-(epoch, shard) ``DetectionEngine.serve`` reports into
    one global engine report — the epoch-loop generalization of
    ``merge_shard_reports``.

    Unlike the single-epoch merge, a stream may appear in SEVERAL
    reports (later epochs, and — after a migration — a different
    shard), so per-stream stats are SUMMED across reports instead of
    unioned, and the per-stream response order / emit clocks are
    rebuilt globally from the merged responses (``rid`` stays globally
    unique and ``seq`` is the global per-stream arrival index thanks to
    the engines' warm-start floors, so the rebuild is exact).  Replica
    ids renumber by shard exactly as in ``merge_shard_reports``; per-
    call ``per_replica`` counts sum across epochs.  ``per_shard``
    aggregates each shard over its epochs (its ``streams`` list names
    every stream the shard served at least one frame for — a migrated
    stream legitimately shows up on two shards).  Global
    ``tracker_launches`` sums over shards AND epochs; global
    ``tracker_ticks`` is the max over shards of each shard's summed
    epoch ticks (shards tick in parallel, epochs in series).  The
    caller attaches ``shard_of_stream`` / ``migrations`` /
    ``n_epochs``.

    Latency merging (``repro.obs.metrics``): histograms sum bucket-wise
    across every (epoch, shard) report, quantiles are recomputed from
    the merged buckets (never averaged), and ``p50_latency`` is the
    exact median over the merged responses.  ``report_epoch`` (the raw
    epoch index of each report, parallel to ``report_shard``) buckets
    the ``per_epoch`` rollup; when omitted every report lands in epoch
    0."""
    n_shards = len(pool_sizes)
    epochs_of = (list(report_epoch) if report_epoch is not None
                 else [0] * len(reports))
    responses, dropped, makespan, per_replica, streams, emit_t = \
        _renumber_and_collect(frames, reports, report_shard, pool_sizes)
    per_stream: Dict[int, Dict] = {}
    per_shard = [{"streams": set(), "frames": 0, "responses": 0,
                  "dropped": 0, "interpolated": 0, "tracker_launches": 0,
                  "tracker_ticks": 0, "_hists": []}
                 for _ in range(n_shards)]
    for rep, h in zip(reports, report_shard):
        for sid, v in rep["per_stream"].items():
            agg = per_stream.setdefault(
                sid, {"frames": 0, "dropped": 0, "interpolated": 0})
            agg["frames"] += v["frames"]
            agg["dropped"] += v["dropped"]
            agg["interpolated"] += v["interpolated"]
            if v["frames"]:
                per_shard[h]["streams"].add(sid)
            per_shard[h]["frames"] += v["frames"]
        per_shard[h]["responses"] += len(rep["responses"])
        per_shard[h]["dropped"] += len(rep["dropped"])
        per_shard[h]["interpolated"] += rep["interpolated"]
        per_shard[h]["tracker_launches"] += rep["tracker_launches"]
        per_shard[h]["tracker_ticks"] += rep["tracker_ticks"]
        per_shard[h]["_hists"].append(rep.get("latency_hist"))
    for sh in per_shard:
        sh["streams"] = sorted(sh["streams"])
        sh["latency_hist"] = merge_hist_dicts(sh.pop("_hists"))
    for sid, agg in per_stream.items():
        rs = streams.setdefault(sid, [])
        em = emit_t.setdefault(sid, [])
        agg["coverage"] = len(rs) / max(agg["frames"], 1)
        agg["throughput_fps"] = len(rs) / max(em[-1] if em else 0.0, 1e-9)
    return {
        "responses": responses,
        "dropped": dropped,
        "coverage": len(responses) / max(len(frames), 1),
        "interpolated": sum(rep["interpolated"] for rep in reports),
        "throughput_fps": len(responses) / max(makespan, 1e-9),
        "per_replica": per_replica,
        "n_streams": len(per_stream),
        "streams": streams,
        "emit_t": emit_t,
        "per_stream": per_stream,
        "tracker_launches": sum(rep["tracker_launches"]
                                for rep in reports),
        "tracker_ticks": max((sh["tracker_ticks"] for sh in per_shard),
                             default=0),
        **_merged_fault_counts(reports, report_shard, pool_sizes),
        **_merged_latency_keys(responses, reports, report_shard,
                               pool_sizes),
        **_merged_cascade_keys(reports, len(frames)),
        "per_epoch": {
            e: _epoch_rollup([rep for rep, re_ in zip(reports, epochs_of)
                              if re_ == e])
            for e in sorted(set(epochs_of))},
        "n_shards": n_shards,
        "per_shard": per_shard,
    }


class ShardedDetectionEngine:
    """NVR detection serving partitioned over mesh shards.

    ``n_shards`` Python-level shards each own a full ``DetectionEngine``
    (replica pool, scheduler, micro-batching, lockstep tracker with
    ``B = cameras assigned to the shard``); the camera set is split by
    the deterministic ``shard_streams`` partition and the per-shard
    reports merge into one global report (``merge_shard_reports``).
    Every ``DetectionEngine`` keyword is accepted and forwarded
    verbatim to the shard engines, so ``n_shards=1`` is a transparent
    wrapper: same trace in, bit-identical report out (plus the
    ``n_shards`` / ``per_shard`` / ``shard_of_stream`` extras).

    ``mesh`` switches the detection compute to the SPMD fast path: one
    ``make_spmd_detect`` program shared by all shards, its micro-batch
    dim constrained to the mesh's replica (``data``) axis.  Requires
    the built-in mini-SSD path (a numpy ``detect_fn`` oracle cannot be
    jitted — passing both is an error); heterogeneous
    ``replica_speeds`` keep working because speeds scale the *virtual*
    service clock, not the compiled program.  Off-mesh (``mesh=None``)
    the engines keep today's per-host scheduler path.

    Cross-shard work stealing (``rebalance=True``): the static
    ``shard_streams`` partition drops frames on a shard whose cameras
    go bursty while a neighboring shard idles — the paper's §III rate
    mismatch, recreated between shards.  With rebalancing on, ``serve``
    splits the trace into ``epoch_s``-second virtual-time epochs; after
    each epoch every shard's backlog/drop pressure is observed
    (``DetectionEngine.backlog_snapshot`` + the epoch report) and
    ``sharding.serving_rules.rebalance_streams`` — a pure deterministic
    function of those observations, so replicated dispatchers agree
    without coordinating — migrates up to ``max_moves_per_epoch`` whole
    camera streams from the most pressured shard to the least pressured
    one.  Migration happens ONLY at epoch boundaries: within an epoch
    no tracker state moves; at the boundary every stream's portable
    track rows (``tracking.export_rows``, handed between shards through
    the engines' ``stream_tracks`` warm start) and its per-stream
    ``seq`` and emit clock all carry to its new shard alongside the
    ``stream_seq0`` / ``stream_emit0`` floors — so track identities,
    per-stream ordering and emit monotonicity survive migration, and
    nothing is silently reset mid-epoch.  ``rebalance=False`` (the default) and
    ``n_shards=1`` (no peer to steal from) keep the static single-pass
    path, bit-identical to the pre-stealing engine.

    Example::

        mesh = make_serving_mesh(4)            # 4-shard host mesh
        eng = ShardedDetectionEngine(n_shards=4, mesh=mesh,
                                     n_replicas=2,
                                     track_and_interpolate=True)
        report = eng.serve(frames)             # same keys as the
                                               # single-host engine
    """

    def __init__(self, n_shards: int = 1, mesh=None, cfg=None, params=None,
                 seed: int = 0, detect_fn=None, use_pallas: bool = False,
                 score_thr: float = 0.4, iou_thr: float = 0.5,
                 max_out: int = 32, rebalance: bool = False,
                 epoch_s: float = 4.0, max_moves_per_epoch: int = 1,
                 faults=None, supervisor=None, recorder=None,
                 **engine_kwargs):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if epoch_s <= 0:
            raise ValueError(f"epoch_s must be > 0, got {epoch_s}")
        self.rebalance = rebalance
        self.epoch_s = epoch_s
        self.max_moves_per_epoch = max_moves_per_epoch
        # fault injection + supervision: an empty schedule normalizes to
        # None so the fault-free paths stay bit-identical
        self.faults = faults if faults else None
        self.supervisor = supervisor
        if self.faults is not None and self.faults.has_shard_events and (
                not rebalance or n_shards < 2):
            raise ValueError(
                "shard-level fault events are folded into the epoch "
                "loop: they require rebalance=True and n_shards >= 2 "
                "(replica-level events work on any configuration)")
        if supervisor is not None and (not rebalance or n_shards < 2):
            raise ValueError(
                "the watchdog supervises epoch boundaries: supervisor= "
                "requires rebalance=True and n_shards >= 2")
        if mesh is not None and detect_fn is not None:
            raise ValueError(
                "mesh= (SPMD detect) and detect_fn= (host-side oracle) "
                "are mutually exclusive: an arbitrary Python callable "
                "cannot be compiled across mesh shards — drop mesh= to "
                "use the scheduler fallback path")
        self.n_shards = n_shards
        self.mesh = mesh
        self._shared_detect = None
        self._spmd_warm = False
        if mesh is not None:
            from ..detector import SSDConfig, init_ssd
            cfg = cfg or SSDConfig()
            if params is None:
                params = init_ssd(cfg, jax.random.PRNGKey(seed))
            self._shared_detect = make_spmd_detect(
                cfg, params, mesh, score_thr=score_thr, iou_thr=iou_thr,
                max_out=max_out, use_pallas=use_pallas)
            self.cfg = cfg
            shard_detect_kw = dict(detect_fn=self._shared_detect, cfg=cfg)
        else:
            if detect_fn is None:
                # meshless mini-SSD: init the params ONCE — the shards
                # are replicas of the same model, not n different ones
                from ..detector import SSDConfig, init_ssd
                cfg = cfg or SSDConfig()
                if params is None:
                    params = init_ssd(cfg, jax.random.PRNGKey(seed))
            shard_detect_kw = dict(detect_fn=detect_fn, cfg=cfg,
                                   params=params, seed=seed,
                                   use_pallas=use_pallas,
                                   score_thr=score_thr, iou_thr=iou_thr,
                                   max_out=max_out)
            self.cfg = cfg
        # observability: each shard engine gets a shard_view(h) of the
        # one recorder, so its frame/replica events carry their failure
        # domain; the watchdog shares the recorder for loan/restart
        # events.  None -> the no-op recorder (bit-identical default).
        self.recorder = recorder if recorder is not None else NULL_RECORDER
        if supervisor is not None:
            supervisor.recorder = self.recorder
        self.engines = [DetectionEngine(**shard_detect_kw, **engine_kwargs,
                                        faults=self.faults, fault_shard=h,
                                        recorder=self.recorder.shard_view(h))
                        for h in range(n_shards)]
        if mesh is None and detect_fn is None:
            # one jitted program for all shards (identical closures
            # would otherwise re-trace/compile per shard)
            for eng in self.engines[1:]:
                eng._infer = self.engines[0]._infer

    # ------------------------------------------------------------- warmup
    def warmup(self):
        """Warm every shard engine, plus — on the SPMD path — compile the
        shared mesh program at every power-of-two micro-batch bucket
        the engines can emit, so no served batch's measured wall time
        (which drives the schedulers' service estimates) includes XLA
        compilation."""
        for eng in self.engines:
            if not eng._warm:
                eng.warmup()
        if self._shared_detect is not None and not self._spmd_warm:
            size = self.cfg.image_size
            eng = self.engines[0]
            if eng.micro_batch is not None:
                # fixed mode pads every batch to exactly micro_batch
                shapes = [eng.micro_batch]
            else:
                # adaptive mode buckets to powers of two, up to the
                # bucket that COVERS max_micro_batch (e.g. max 6 -> 8)
                shapes, b = [], 1
                while b < DetectionEngine._bucket(eng.max_micro_batch):
                    shapes.append(b)
                    b <<= 1
                shapes.append(b)
            for b in shapes:
                self._shared_detect(
                    np.zeros((b, size, size, 3), np.float32))
            self._spmd_warm = True

    # ------------------------------------------------------------- serving
    def serve(self, frames: Sequence[FrameRequest]) -> Dict:
        """Partition the trace's cameras over the shards, serve each
        shard's sub-trace through its own engine, and merge the
        per-shard reports into one global report (same keys as
        ``DetectionEngine.serve`` plus ``n_shards`` / ``per_shard`` /
        ``shard_of_stream``).

        ``rid`` stays globally unique and ``seq`` is per-stream, so
        responses and quality accounting are unaffected by WHICH shard
        served a camera; only drop/latency behaviour depends on the
        per-shard pools.

        With ``rebalance=True`` (and more than one shard) the trace is
        served in ``epoch_s`` virtual-second epochs with cross-shard
        work stealing between them (see the class docstring); the
        report gains ``migrations`` (one ``{"epoch", "stream", "src",
        "dst"}`` record per executed move) and ``n_epochs``, and
        ``shard_of_stream`` reflects the FINAL partition.

        With ``faults=`` (or ``supervisor=``) active, the report also
        gains ``faults`` (``{"n_events", "frames_lost_shard",
        "restarts", "loans"}`` — the injected schedule's size and the
        recovery actions taken) and ``recovered_coverage`` (the minimum
        per-stream coverage over frames arriving after the last fault /
        recovery action took effect — 1.0 means every stream fully
        recovered).

        The merged report carries the engine's latency block
        (``p50_latency`` / ``p95_latency`` / ``p99_latency`` /
        ``latency_hist`` / ``interp_latency`` / ``latency_by_stream``
        / ``latency_by_replica`` — histograms summed across shards,
        quantiles recomputed from the merged buckets) plus
        ``per_epoch`` ({raw epoch index: responses / dropped /
        latency rollup}; a single ``0`` entry on the static path) and
        a ``latency_hist`` per ``per_shard`` entry.  With a
        ``recorder=`` attached, every shard engine traces through a
        ``shard_view`` of it and the epoch loop adds
        epoch/migrate/shard_down/shard_lost control events (the
        watchdog adds loan/restart events) — see ``repro.obs``."""
        from .runtime import ServingRuntime
        rt = ServingRuntime(self)
        rt.ingest(frames)
        return rt.drain()

    def reset(self):
        """Clear per-serve virtual-clock state on EVERY shard engine
        (replica ``busy_until`` / counts / EWMAs and each shard
        scheduler's round bookkeeping) so repeated ``serve()`` calls
        are independent.  Delegates to
        ``ServingRuntime.reset_engines`` — the ONE reset semantic every
        engine shares (warm service estimates and compiled programs
        survive, like ``DetectionEngine.reset``)."""
        from .runtime import ServingRuntime
        ServingRuntime.reset_engines(self)

    # -------------------------------------------------------- fault report
    def _attach_fault_keys(self, out: Dict, frames, lost, restarts,
                           loans, t_rec):
        """Attach the fault-scenario keys: ``faults`` (what happened and
        what the supervision did about it) and ``recovered_coverage``
        (did every stream come back after the dust settled)."""
        out["faults"] = {
            "n_events": len(self.faults) if self.faults is not None else 0,
            "frames_lost_shard": len(lost),
            "restarts": restarts,
            "loans": loans,
        }
        out["recovered_coverage"] = self._recovered_coverage(
            out, frames, t_rec)

    @staticmethod
    def _recovered_coverage(out: Dict, frames, t_rec) -> float:
        """Minimum per-stream coverage over frames arriving at or after
        ``t_rec`` (the first epoch boundary after the last fault or
        recovery action).  1.0 = every stream fully served once the
        system settled; 0.0 = some stream never came back.  ``None``
        (no fault ever fired) reads 1.0 by definition."""
        if t_rec is None:
            return 1.0
        total: Dict[int, int] = {}
        by_rid: Dict[int, FrameRequest] = {}
        for f in frames:
            by_rid[f.rid] = f
            if f.t_arrival >= t_rec:
                total[f.stream_id] = total.get(f.stream_id, 0) + 1
        if not total:
            return 1.0            # the trace ended before recovery did
        got: Dict[int, int] = {}
        for r in out["responses"]:
            f = by_rid.get(r.rid)
            if f is not None and f.t_arrival >= t_rec:
                got[f.stream_id] = got.get(f.stream_id, 0) + 1
        return min(got.get(sid, 0) / n for sid, n in sorted(total.items()))
