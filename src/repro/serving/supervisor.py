"""Epoch-boundary watchdog for ``ShardedDetectionEngine``: shard
restart + camera re-homing + replica lending.

The sharded epoch loop is the supervision point the serving stack
already has — every shard reports once per epoch (its serve report +
``backlog_snapshot``), so the watchdog runs where the observations
land: at epoch boundaries, on pure per-epoch data, with no extra
channel.  Everything here is a deterministic function of those
observations; re-running the same (trace, FaultSchedule) replays the
same restarts and loans bit-for-bit.

Detection
---------
A shard is *dead* when it had frames to serve this epoch but missed its
heartbeat (the epoch loop stamps a heartbeat only for shards that are
up at the window's end — a host that died mid-epoch never stamps).  A
shard is *straggling* (lending-hot, below) when its epoch observation
shows drops, or residual backlog at the epoch's last arrival beyond
``straggler_backlog_s`` — the two pressure signals
``rebalance_streams`` already ranks shards by.

Dead-shard recovery
-------------------
On detection the watchdog (1) restarts the shard — ``engine.reset()``
plus clearing the fault cursor, refused for ``permanent`` kills — and
(2) evacuates every camera the dead shard owned through
``sharding.serving_rules.rebalance_streams(evacuate=[shard])``: each
stream re-homes to the least-loaded live shard, and the next epoch
serves it there with its ``seq``/emit floors warm-started through the
engines' ``serve(stream_seq0=, stream_emit0=)`` hooks (the same
machinery a stolen stream migrates by).  Evacuation runs even when the
restart succeeds: the restarted shard is an empty host that re-earns
streams through the normal stealing policy, which is simpler to reason
about than guessing which cameras survived the outage.

Replica lending
---------------
Stream migration cannot help a shard whose load is ONE hot camera
(``rebalance_streams`` rule 3 refuses moves that merely relocate the
overload).  Lending is the dual: move capacity to the load instead.
When no migration happened at a boundary and the pressure gradient
persists, the most idle shard (zero drops, backlog under
``idle_backlog_s``, pool larger than ``min_donor_pool - 1``) lends the
TAIL replica of its pool to the hottest shard (drops >= ``hot_drops``
or backlog >= ``straggler_backlog_s``):

    lender pool  [r0 r1]  --pop-->  r1
    borrower pool [r0 r1] --append--> [r0 r1 g2]   (guest idx = 2)

Tail-only pop/append keeps every executor's list position equal to its
``idx``, which is what the engines' per-replica accounting keys on;
``scheduler.sync_pool()`` renormalizes the health mask and any WRR
weights on both sides.  A loan returns (LIFO, same tail discipline) at
a later boundary once the borrower stops dropping or the lender itself
comes under pressure, and unconditionally when the serve ends — pools
always end the serve at their constructed sizes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from ..obs.trace import NULL_RECORDER


@dataclass
class _Loan:
    lender: int
    borrower: int
    ex: object                    # the ReplicaExecutor on loan
    home_idx: int                 # its idx in the lender's pool
    record: Dict                  # the log entry (gains "returned_epoch")


class Watchdog:
    """Epoch-boundary supervisor (see module docstring).  One instance
    is bound to one ``ShardedDetectionEngine`` via ``supervisor=``; its
    per-serve state (loans, logs, pool high-water marks) resets on
    ``begin`` so repeated serves replay identically."""

    def __init__(self, lend: bool = True, max_loans: int = 1,
                 min_donor_pool: int = 2, hot_drops: int = 1,
                 idle_backlog_s: float = 1e-9,
                 straggler_backlog_s: Optional[float] = None):
        self.lend = lend
        self.max_loans = max_loans
        self.min_donor_pool = min_donor_pool
        self.hot_drops = hot_drops
        self.idle_backlog_s = idle_backlog_s
        self.straggler_backlog_s = straggler_backlog_s
        self.restart_log: List[Dict] = []
        self.loan_log: List[Dict] = []
        self._loans: List[_Loan] = []
        self._max_pool: List[int] = []
        # observability: the owning ShardedDetectionEngine swaps in its
        # TraceRecorder so restarts and loans land on the shared trace
        self.recorder = NULL_RECORDER

    # ------------------------------------------------------------ lifecycle
    def begin(self, engines: Sequence):
        """Reset per-serve state; called by the epoch loop on entry."""
        self.restart_log = []
        self.loan_log = []
        self._loans = []
        self._max_pool = [len(e.replicas) for e in engines]

    def finish(self, engines: Sequence, epoch: int,
               t: Optional[float] = None):
        """Return every outstanding loan (LIFO) so pools end the serve
        at their constructed sizes.  ``t`` (optional, additive) is the
        virtual boundary time the returns are recorded at."""
        while self._loans:
            self._return(engines, self._loans[-1], epoch, t=t)

    def pool_sizes(self, engines: Sequence) -> List[int]:
        """Per-shard replica-id space for the report merge: the HIGH
        WATER mark each pool reached, so a guest replica's renumbered
        id never collides with a neighbor shard's offset range."""
        return list(self._max_pool)

    # ------------------------------------------------------------ dead shards
    def detect_dead(self, heartbeat: Dict[int, int], epoch: int,
                    had_frames: Sequence[bool]) -> List[int]:
        """Shards that had frames this epoch but missed the heartbeat."""
        return [h for h, hb in sorted(heartbeat.items())
                if had_frames[h] and hb < epoch]

    def handle_dead(self, engines: Sequence, h: int, cursor, epoch: int,
                    t_boundary: float) -> bool:
        """Restart a dead shard: reset its engine (virtual clock, round
        state, health mask) and clear the fault cursor.  Returns the
        restart outcome (``False`` for permanent kills — the shard
        stays down and evacuation carries the recovery alone)."""
        ok = cursor.restart(h, t_boundary)
        engines[h].reset()
        self.restart_log.append({"epoch": epoch, "shard": h, "ok": ok,
                                 "t": t_boundary})
        if self.recorder.enabled:
            self.recorder.record("shard_restart", t_boundary, shard=h,
                                 epoch=epoch, ok=ok)
        return ok

    # ------------------------------------------------------------ lending
    def _pressure(self, observations: Sequence[Dict], epoch_s: float):
        thresh = (self.straggler_backlog_s if self.straggler_backlog_s
                  is not None else epoch_s)
        pres = [(int(o["drops"]), float(o["backlog_s"]))
                for o in observations]
        hot = [h for h, (d, b) in enumerate(pres)
               if d >= self.hot_drops or b >= thresh]
        idle = [h for h, (d, b) in enumerate(pres)
                if d == 0 and b <= self.idle_backlog_s]
        return pres, hot, idle

    def rebalance_loans(self, engines: Sequence,
                        observations: Sequence[Dict], moved: bool,
                        down: Sequence[int], epoch: int,
                        epoch_s: float,
                        t: Optional[float] = None) -> List[Dict]:
        """One boundary's lending decisions: first return loans whose
        reason expired, then — only if stream migration did NOT act
        this boundary (migration is the cheaper fix: no pool churn) —
        open at most one new loan along the steepest pressure
        gradient.  Down shards neither lend nor borrow.  ``t``
        (optional, additive) is the virtual boundary time loan events
        are recorded at."""
        if not self.lend:
            return []
        actions: List[Dict] = []
        pres, hot, idle = self._pressure(observations, epoch_s)
        for loan in list(reversed(self._loans)):     # LIFO returns
            borrower_cool = pres[loan.borrower][0] == 0
            lender_hot = loan.lender in hot or loan.lender in down
            if borrower_cool or lender_hot or loan.borrower in down:
                self._return(engines, loan, epoch, t=t)
                actions.append(loan.record)
        if moved or len(self._loans) >= self.max_loans:
            return actions
        lenders = {ln.lender for ln in self._loans}
        borrowers = {ln.borrower for ln in self._loans}
        cand_hot = [h for h in hot if h not in down and h not in lenders]
        cand_idle = [h for h in idle
                     if h not in down and h not in borrowers
                     and len(engines[h].replicas) >= self.min_donor_pool]
        if not cand_hot or not cand_idle:
            return actions
        borrower = max(cand_hot, key=lambda h: (pres[h], -h))
        lender = min(cand_idle,
                     key=lambda h: (pres[h], -len(engines[h].replicas), h))
        if borrower == lender or pres[borrower] <= pres[lender]:
            return actions
        actions.append(self._lend(engines, lender, borrower, epoch, t=t))
        return actions

    def _lend(self, engines: Sequence, lender: int, borrower: int,
              epoch: int, t: Optional[float] = None) -> Dict:
        ex = engines[lender].replicas.pop()          # tail only: every
        home_idx = ex.idx                            # survivor keeps its
        ex.idx = len(engines[borrower].replicas)     # idx == position
        engines[borrower].replicas.append(ex)
        engines[lender].scheduler.sync_pool()
        engines[borrower].scheduler.sync_pool()
        record = {"epoch": epoch, "lender": lender, "borrower": borrower,
                  "returned_epoch": None}
        self._loans.append(_Loan(lender, borrower, ex, home_idx, record))
        self.loan_log.append(record)
        self._max_pool[borrower] = max(self._max_pool[borrower],
                                       len(engines[borrower].replicas))
        if self.recorder.enabled:
            self.recorder.record("loan", 0.0 if t is None else t,
                                 lender=lender, borrower=borrower,
                                 guest=ex.idx, epoch=epoch)
        return record

    def _return(self, engines: Sequence, loan: _Loan, epoch: int,
                t: Optional[float] = None):
        ex = engines[loan.borrower].replicas.pop()
        assert ex is loan.ex, "loan return must be LIFO (tail discipline)"
        if self.recorder.enabled:
            # guest = the lane the borrower just retired: the audit uses
            # it to close any open health mark on that (shard, lane)
            self.recorder.record("loan_return", 0.0 if t is None else t,
                                 lender=loan.lender,
                                 borrower=loan.borrower, guest=ex.idx,
                                 epoch=epoch)
        ex.idx = loan.home_idx
        # the guest's virtual clock may run ahead of its home pool (it
        # was absorbing a hot shard's backlog); busy_until rides along —
        # the lender simply cannot use it until its borrowed work drains
        engines[loan.lender].replicas.append(ex)
        engines[loan.borrower].scheduler.sync_pool()
        engines[loan.lender].scheduler.sync_pool()
        loan.record["returned_epoch"] = epoch
        self._loans.remove(loan)
