"""Incremental serving core: the engines' virtual-time loops as a
long-lived runtime.

``DetectionEngine.serve`` and the sharded epoch loop used to be
monolithic whole-trace functions: a finished frame list in, one report
out.  ``ServingRuntime`` is the same machinery restructured around
*arrival*: frames are ``ingest``-ed in any chunking (one at a time,
bursts, or the whole trace), ``advance(to_t)`` runs every micro-batch
whose membership can no longer change, ``epoch_boundary()`` closes a
reporting window mid-serve, and ``drain()`` flushes the pipeline and
returns the final report.  Both engines' ``serve()`` are now thin
trace-replay drivers over this core — one-shot ingest + drain — and
stay bit-identical to the pre-refactor batch reports.

Watermark contract
------------------
The incremental loop is deterministic because ingest order is
constrained: across ``ingest`` calls the earliest arrival of each chunk
must be >= the latest arrival already ingested (ties allowed — within a
chunk frames are sorted stably, exactly like the batch path's stable
sort).  ``advance(to_t)`` is the caller's promise that every frame with
``t_arrival < to_t`` has been ingested; the core then *seals* and runs
precisely the micro-batches the one-shot path would have formed:

* adaptive mode seals the head batch when ``t_now = max(head arrival,
  min replica busy_until) < to_t`` — every frame that could join the
  batch (arrival <= t_now) is already present, so membership is final;
* fixed ``micro_batch`` mode seals when ``micro_batch`` frames are
  queued and the last one arrived strictly before ``to_t``;
* ``drain()`` / ``advance(float("inf"))`` seals everything, including
  the partial tail batch.

Deferring an unsealed batch never changes its membership, which is the
invariant behind the chunked == one-shot bit-identity guarantee.

Sharded serving
---------------
For a ``ShardedDetectionEngine`` the runtime picks the matching core:
the static partition (``rebalance=False`` or one shard) fans ingest out
to one per-shard core, the rebalancing configuration replays the epoch
loop — serving each ``epoch_s`` window as soon as the watermark passes
its end, with the *pending-boundary* restructure: the migration /
watchdog boundary actions of window ``e`` run immediately before the
next non-empty window is served (the identical action sequence the
batch loop produced with its look-ahead ``i < len(epochs) - 1`` test,
expressed without knowing the future).  The deterministic
``shard_streams`` partition needs the full camera universe, so
*incremental* sharded ingest requires the stream set declared up front
(``ServingRuntime(engine, streams=...)``); without it the core buffers
and resolves everything at ``drain()``, replaying the batch path
exactly.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.synchronizer import SequenceSynchronizer
from ..obs.metrics import detection_latency_keys
from ..obs.trace import NULL_RECORDER
from ..sharding.serving_rules import rebalance_streams, shard_streams
from .engine import (DetectionEngine, DetectionResponse, FrameRequest,
                     _per_replica_counts)
from .faults import ShardFaultCursor
from .models import cascade_report_keys
from .pipeline import TickState, roi_second_pass
from .pipeline import sorted_chunk as _sorted_chunk

_INF = float("inf")


class _DetectionCore:
    """Incremental micro-batch loop of ONE ``DetectionEngine``.

    Holds the open *segment*: the frames since the last epoch boundary,
    the responses/drops produced so far, and the per-stream seq / emit
    floors that carry across segments (the same ``stream_seq0`` /
    ``stream_emit0`` warm-start semantics the sharded epoch loop always
    used between its per-epoch ``serve`` calls)."""

    def __init__(self, eng: DetectionEngine, *, reset: bool = True,
                 stream_seq0: Optional[Dict[int, int]] = None,
                 stream_emit0: Optional[Dict[int, float]] = None,
                 stream_tracks: Optional[Dict[int, dict]] = None):
        self.eng = eng
        if not eng._warm:
            eng.warmup()
        if reset:
            eng.reset()
        self._watermark = -_INF
        self._seq_next: Dict[int, int] = dict(stream_seq0 or {})
        self._emit0: Dict[int, float] = dict(stream_emit0 or {})
        # portable track rows carried across segments (and, via the
        # epoch core, across shard migration): stream_id -> row dict
        # from ``tracking.export_rows``.  Seeds the interpolation
        # tracker of every NEXT segment so track identities persist
        # instead of re-seeding at epoch boundaries.
        self._tracks0: Dict[int, dict] = dict(stream_tracks or {})
        self._seq_of: Dict[int, int] = {}
        self._epoch_reports: List[Dict] = []
        self._all_frames: List[FrameRequest] = []
        # micro-batch numbering is monotone across SEGMENTS (not reset
        # at epoch boundaries): the audit's switch-at-batch-boundary
        # rule keys model_switch events on (shard, batch), which must
        # never repeat within one trace
        self._batch_no = 0
        self._new_segment()

    def _new_segment(self):
        self._queue: List[FrameRequest] = []
        self._qi = 0
        self._responses: List[DetectionResponse] = []
        self._dropped: List[FrameRequest] = []
        # warm-start stream set of THIS segment: every stream with a seq
        # floor appears in the segment report even with zero frames
        self._seg_warm = set(self._seq_next)
        self._fc0 = self.eng.scheduler.fault_counts()
        # per-segment transprecise-cascade counters (summed back
        # together by the shard/epoch merges via cascade_report_keys)
        self._model_counts: Dict[str, int] = {}
        self._model_of: Dict[int, str] = {}
        self._switches = 0
        self._roi_px = {"full": 0.0, "roi": 0.0, "passes": 0}

    # ------------------------------------------------------------ ingest
    def ingest(self, frames):
        chunk = _sorted_chunk(frames)
        if not chunk:
            return
        if chunk[0].t_arrival < self._watermark:
            raise ValueError(
                f"ingest violates the watermark: frame rid={chunk[0].rid} "
                f"arrives at {chunk[0].t_arrival} < watermark "
                f"{self._watermark} — chunks must be non-decreasing in "
                "t_arrival across ingest calls")
        self._watermark = chunk[-1].t_arrival
        rec = self.eng.recorder
        for f in chunk:
            s = self._seq_next.get(f.stream_id, 0)
            self._seq_of[f.rid] = s
            self._seq_next[f.stream_id] = s + 1
            if rec.enabled:
                rec.record("arrive", f.t_arrival, rid=f.rid,
                           stream=f.stream_id, seq=s)
        self._queue.extend(chunk)

    # ----------------------------------------------------------- advance
    def _sealed(self, to_t: float) -> bool:
        q, i, eng = self._queue, self._qi, self.eng
        if i >= len(q):
            return False
        if to_t == _INF:
            return True
        if eng.micro_batch is not None:
            j = i + eng.micro_batch - 1
            return j < len(q) and q[j].t_arrival < to_t
        t_now = max(q[i].t_arrival,
                    min(r.busy_until for r in eng.replicas))
        return t_now < to_t

    def advance(self, to_t: float):
        while self._sealed(to_t):
            self._process_next_batch()

    def _process_next_batch(self):
        eng = self.eng
        frames = self._queue
        i = self._qi
        rec = eng.recorder
        seq_of = self._seq_of
        chunk = frames[i:i + eng._chunk_size(frames, i)]
        self._qi += len(chunk)
        model = None
        if eng.cascade is not None:
            # transprecise model selection at the batch boundary — the
            # ONLY point a switch may happen (audited).  The decision is
            # a pure function of virtual-clock signals (batch formation
            # time, batch size, committed backlog, healthy-pool caps),
            # so it replays bit-identically.
            t_sel = max(chunk[0].t_arrival,
                        min(r.busy_until for r in eng.replicas))
            model, switched = eng.cascade.decide(
                t_sel, len(chunk), eng.scheduler.backlog(t_sel),
                eng._model_caps())
            if switched:
                self._switches += 1
                if rec.enabled:
                    rec.record("model_switch", t_sel, batch=self._batch_no,
                               model=model)
            # pin service estimates BEFORE the drop-assign loop: drop
            # decisions must price frames at the selected model's rate
            eng._apply_model(model)
        if rec.enabled:
            if self._batch_no % 4 == 0:
                # queue depth + residual backlog sampled at the moment a
                # micro-batch forms (the dispatch decision point),
                # decimated 4:1 — the series is a load signal, not a
                # ledger, and the backlog scan is the costliest
                # per-batch probe on the traced path
                t_q = max(chunk[0].t_arrival,
                          min(r.busy_until for r in eng.replicas))
                rec.sample("queue_depth", t_q, len(chunk))
                rec.sample("backlog_s", t_q, eng.scheduler.backlog(t_q))
            rec_enq = rec.record
            for f in chunk:
                rec_enq("enqueue", f.t_arrival, rid=f.rid,
                        stream=f.stream_id, batch=self._batch_no)
        bno = self._batch_no
        self._batch_no += 1
        kept, assigns = [], []
        if eng.drop_when_busy:
            # the drop decision happens at arrival time, before this
            # batch's wall time exists — it uses the service estimate
            # from the previous batch (a real system can do no better).
            # A fault-lost frame (assign detects a failure and the
            # bounded retry dies too) lands in the same dropped list:
            # under track_and_interpolate the tracker coasts it, so an
            # outage degrades to interpolation, never to a gap.
            for f in chunk:
                a = eng.scheduler.assign(f.rid, f.t_arrival)
                if a is None:
                    self._dropped.append(f)
                    if rec.enabled:
                        rec.record("drop", f.t_arrival, rid=f.rid,
                                   stream=f.stream_id, seq=seq_of[f.rid])
                    continue
                kept.append(f)
                assigns.append(a)
        else:
            kept = chunk
        if not kept:
            return
        images = np.stack([f.image for f in kept])
        b = eng.micro_batch or eng._bucket(len(kept))
        if len(kept) < b:                     # pad: static jit shapes
            pad = np.zeros((b - len(kept),) + images.shape[1:],
                           images.dtype)
            images = np.concatenate([images, pad], 0)
        # no catalog => no `model=` kwarg: the plain-engine call keeps
        # the pre-cascade `_detect_batch` signature contract
        mkw = {} if model is None else {"model": model}
        (boxes, scores, classes, valid), wall = eng._detect_batch(
            images, rids=[f.rid for f in kept] + [-1] * (b - len(kept)),
            **mkw)
        if rec.enabled:
            # deterministic stage event + wall timing as a sampled
            # series (events must stay bit-identical across replays)
            rec.record("stage", chunk[0].t_arrival, stage="detect",
                       batch=bno, frames=len(kept))
            rec.sample("stage_ms_detect", chunk[0].t_arrival,
                       wall * 1e3)
        # from here the batch travels as a TickState through the shared
        # stage pipeline: [ROI second pass] -> post-processor hook
        tick = TickState(boxes=boxes, scores=scores, classes=classes,
                         valid=valid, images=images, model=model)
        roi_frac = 0.0
        if (model is not None and eng.roi
                and model != eng.cascade.heaviest):
            # hierarchical second pass: the light model's boxes become
            # ROI windows batched through the heavy model
            tick, roi_frac, roi_wall, px = roi_second_pass(
                eng, tick, kept, b, rec)
            self._roi_px["full"] += px["full"]
            self._roi_px["roi"] += px["roi"]
            self._roi_px["passes"] += px["passes"]
            wall += roi_wall
        if eng.post_process is not None:
            tick = eng.post_process(tick)
        boxes, scores, classes, valid = (tick.boxes, tick.scores,
                                         tick.classes, tick.valid)
        per_frame = (wall / len(kept) if eng.service_time is None
                     else eng.service_time)
        roi_cost = 0.0
        if model is not None:
            prof = eng.catalog.get(model)
            if prof is not None and prof.service_s is not None:
                # virtual cost: the selected model's pinned service plus
                # the second pass priced at the pixel fraction actually
                # read of the heavy model's full-frame service
                heavy_s = eng.catalog[eng.cascade.heaviest].service_s
                roi_cost = roi_frac * (heavy_s or 0.0)
                per_frame = prof.service_s + roi_cost
        for r in eng.replicas:
            r._last_wall = per_frame
        if model is not None:
            # re-pin from each replica's own catalog (heterogeneous
            # per-replica profiles override the pool-wide estimate)
            eng._apply_model(model, roi_cost)
        if not eng.drop_when_busy:
            # blocking mode assigns after the measurement, so this
            # batch's own wall time drives its virtual-clock slots.
            # During a total outage (no healthy replica) blocking would
            # hang forever — those frames take the drop-accounted path
            # instead of raising, so a transient all-dead window
            # degrades coverage rather than the call
            assigns = []
            for f in kept:
                if not eng.scheduler.any_healthy():
                    eng.scheduler.probe_health(f.t_arrival)
                if eng.scheduler.any_healthy():
                    assigns.append(eng.scheduler.blocking_assign(
                        f.rid, f.t_arrival))
                else:
                    assigns.append(None)
        for j, (f, a) in enumerate(zip(kept, assigns)):
            if a is None:            # fault-lost (retry exhausted or
                self._dropped.append(f)   # no healthy replica):
                if rec.enabled:      # accounted as a drop, never a gap
                    rec.record("drop", f.t_arrival, rid=f.rid,
                               stream=f.stream_id, seq=seq_of[f.rid])
                continue
            self._responses.append(DetectionResponse(
                f.rid, boxes[j], scores[j], classes[j], valid[j],
                a.executor_idx, a.t_start, a.t_done, per_frame,
                stream_id=f.stream_id, seq=seq_of[f.rid]))
            if model is not None:
                self._model_of[f.rid] = model
                self._model_counts[model] = \
                    self._model_counts.get(model, 0) + 1

    # ---------------------------------------------------------- finalize
    def _finalize_segment(self, *, record: bool = True) -> Dict:
        """The tail of the batch ``serve``: tracker interpolation,
        rid-order sort, per-stream reorder + emit events, per-stream
        stats, fault-count deltas and the latency block — over the
        PROCESSED prefix of the open segment.  ``record=False`` is the
        non-destructive peek ``report()`` uses: it works on copies,
        records nothing, and leaves the segment open."""
        eng = self.eng
        frames = self._queue[:self._qi]
        seq_of = self._seq_of
        dropped = self._dropped
        responses = self._responses if record else list(self._responses)
        rec = eng.recorder if record else NULL_RECORDER
        n_frames_stream: Dict[int, int] = {
            sid: 0 for sid in self._seg_warm}
        for f in frames:
            n_frames_stream[f.stream_id] = \
                n_frames_stream.get(f.stream_id, 0) + 1
        interpolated = 0
        eng._tracker_launches = eng._tracker_ticks = 0
        # clear stale exports up front: a segment that never runs the
        # tracker (no frames processed) must not re-offer the PREVIOUS
        # segment's table at the next boundary — the epoch core's
        # _tracks0 already holds it
        eng._exported_tracks = {}
        if eng.track_and_interpolate and (dropped or responses):
            responses = eng._interpolate(frames, responses, seq_of,
                                         self._emit0,
                                         tracks0=self._tracks0, rec=rec)
            interpolated = sum(r.interpolated for r in responses)
        responses.sort(key=lambda r: r.rid)   # sequence synchronizer
        makespan = max((r.t_done for r in responses), default=0.0)
        # per-stream reorder + drop accounting (the per-camera view of
        # the same responses; one entry per stream_id seen this segment)
        ordered = SequenceSynchronizer.order_per_stream(responses)
        streams, emit_t = {}, {}
        for sid, (rs, emits) in ordered.items():
            streams[sid], emit_t[sid] = rs, emits
        if rec.enabled:
            # trace emits carry the warm-start emit floor forward (a
            # migrated / segment-continued stream's emits stay monotone
            # ACROSS segments — exactly the global clock the
            # shard-report merge rebuilds).  emit_t stays per-segment.
            rec_emit = rec.record
            for sid in sorted(streams):
                clk = self._emit0.get(sid, 0.0)
                for r, e in zip(streams[sid], emit_t[sid]):
                    clk = max(clk, e)
                    rec_emit("interp_emit" if r.interpolated else "emit",
                             clk, rid=r.rid, stream=sid, seq=r.seq)
        drop_stream: Dict[int, int] = {}
        for f in dropped:
            drop_stream[f.stream_id] = drop_stream.get(f.stream_id, 0) + 1
        per_stream = {}
        for sid, n in n_frames_stream.items():
            rs = streams.setdefault(sid, [])
            emits = emit_t.setdefault(sid, [])
            mk = emits[-1] if emits else 0.0   # per-stream emit makespan
            per_stream[sid] = {
                "frames": n,
                "dropped": drop_stream.get(sid, 0),
                "interpolated": sum(r.interpolated for r in rs),
                "coverage": len(rs) / max(n, 1),
                "throughput_fps": len(rs) / max(mk, 1e-9),
            }
        # this segment's failure-detection deltas, sparse per replica
        fc0, fc1 = self._fc0, eng.scheduler.fault_counts()
        fault_counts = {
            key: {i: fc1[key].get(i, 0) - fc0[key].get(i, 0)
                  for i in set(fc1[key]) | set(fc0[key])
                  if fc1[key].get(i, 0) - fc0[key].get(i, 0)}
            for key in ("retries", "failovers", "frames_lost")}
        return {
            "responses": responses,
            "dropped": [f.rid for f in dropped],
            "coverage": len(responses) / max(len(frames), 1),
            "interpolated": interpolated,
            "throughput_fps": len(responses) / max(makespan, 1e-9),
            "per_replica": _per_replica_counts(eng.replicas, responses),
            "n_streams": len(n_frames_stream),
            "streams": streams,
            "emit_t": emit_t,    # per-stream monotonic release clocks
            "per_stream": per_stream,
            "tracker_launches": eng._tracker_launches,
            "tracker_ticks": eng._tracker_ticks,
            "retries": fault_counts["retries"],
            "failovers": fault_counts["failovers"],
            "frames_lost": fault_counts["frames_lost"],
            # transprecise-cascade block (serving.models): raw counters
            # through the SAME derivation the shard merges recompute
            # with, so single-shard merges stay bit-identical.  All
            # keys present (empty) without a catalog.
            **cascade_report_keys(
                self._model_counts, self._model_of,
                (eng.catalog.map_est_by_name()
                 if eng.catalog is not None else {}),
                self._switches, self._roi_px, len(frames)),
            # latency distribution block (repro.obs.metrics): exact p50
            # plus histogram-derived p95/p99 and mergeable rollups
            **detection_latency_keys(
                responses, {f.rid: f.t_arrival for f in frames}),
        }

    # -------------------------------------------------------- boundaries
    def epoch_boundary(self) -> Dict:
        """Flush the open segment, close it into a per-epoch report, and
        start a new segment with the seq / emit floors carried (the
        virtual clock is NOT reset — exactly the warm-started epoch
        calls the sharded loop always made)."""
        self.advance(_INF)
        rep = self._finalize_segment(record=True)
        self._epoch_reports.append(rep)
        self._all_frames.extend(self._queue)
        for sid, em in rep["emit_t"].items():
            if em:
                self._emit0[sid] = max(self._emit0.get(sid, 0.0), em[-1])
        if self.eng.carry_tracks:
            # track identities persist across the boundary: the closed
            # segment's exported rows seed the next segment's tracker
            self._tracks0.update(self.eng._exported_tracks)
        self._new_segment()
        return rep

    def finalize_segments(self) -> List[Dict]:
        """Flush + close the open segment (if it has frames, or if it is
        the only one) and return every closed segment report, in epoch
        order.  After this the core is drained."""
        self.advance(_INF)
        if self._queue or not self._epoch_reports:
            self.epoch_boundary()
        return list(self._epoch_reports)

    def drain(self) -> Dict:
        """Flush everything and return the final report: with no epoch
        boundaries this is byte-for-byte the batch ``serve`` report;
        with boundaries the per-epoch segments merge through
        ``merge_epoch_shard_reports`` (histograms summed, quantiles
        recomputed — never averaged)."""
        segs = self.finalize_segments()
        if len(segs) == 1:
            return segs[0]
        from .sharded import merge_epoch_shard_reports
        return merge_epoch_shard_reports(
            self._all_frames, segs, [0] * len(segs),
            [len(self.eng.replicas)],
            report_epoch=list(range(len(segs))))

    def report(self, rolling: bool = True):
        """Rolling view mid-serve.  ``rolling=True``: the closed
        per-epoch reports plus (when the open segment has frames) a
        non-destructive peek of it, tagged ``partial``.  ``rolling=
        False``: one cumulative report merged over the same pieces."""
        reps = list(self._epoch_reports)
        if self._queue or not reps:
            peek = self._finalize_segment(record=False)
            peek["partial"] = True
            reps.append(peek)
        if rolling:
            return reps
        if len(reps) == 1:
            return reps[0]
        from .sharded import merge_epoch_shard_reports
        return merge_epoch_shard_reports(
            self._all_frames + self._queue, reps, [0] * len(reps),
            [len(self.eng.replicas)],
            report_epoch=list(range(len(reps))))

    @property
    def frames_pending(self) -> int:
        return len(self._queue) - self._qi


class _ShardedStaticCore:
    """Incremental front for the static-partition sharded path
    (``rebalance=False`` or one shard): one ``_DetectionCore`` per
    shard under the fixed ``shard_streams`` partition.

    With ``streams`` declared the partition is known up front and
    ingest fans out immediately; without it every frame buffers and
    ``drain()`` replays the batch path shard-by-shard — bit-identical
    to ``_serve_static`` before the refactor."""

    def __init__(self, seng, streams=None):
        self._seng = seng
        if seng._shared_detect is not None:
            seng.warmup()
        self._frames: List[FrameRequest] = []
        self._watermark = -_INF
        self._cores: Optional[List[_DetectionCore]] = None
        self._shard_of: Optional[Dict[int, int]] = None
        self._n_boundaries = 0
        if streams is not None:
            self._shard_of = shard_streams(streams, seng.n_shards)
            self._cores = [_DetectionCore(eng) for eng in seng.engines]

    def ingest(self, frames):
        chunk = _sorted_chunk(frames)
        if not chunk:
            return
        if chunk[0].t_arrival < self._watermark:
            raise ValueError("ingest violates the watermark (chunks must "
                             "be non-decreasing in t_arrival)")
        self._watermark = chunk[-1].t_arrival
        self._frames.extend(chunk)
        if self._cores is not None:
            subs: List[List[FrameRequest]] = [
                [] for _ in range(self._seng.n_shards)]
            for f in chunk:
                subs[self._shard_of[f.stream_id]].append(f)
            for core, sub in zip(self._cores, subs):
                if sub:
                    core.ingest(sub)

    def advance(self, to_t: float):
        if self._cores is not None:
            for core in self._cores:
                core.advance(to_t)

    def epoch_boundary(self):
        if self._cores is None:
            raise RuntimeError(
                "incremental sharded serving needs the stream universe "
                "declared up front: ServingRuntime(engine, streams=...) "
                "(the deterministic shard_streams partition is a "
                "function of the full camera set)")
        reps = [core.epoch_boundary() for core in self._cores]
        self._n_boundaries += 1
        from .sharded import _epoch_rollup
        return _epoch_rollup(reps)

    def drain(self) -> Dict:
        from .sharded import merge_epoch_shard_reports, merge_shard_reports
        seng = self._seng
        frames = self._frames
        if self._cores is None:
            # lazy batch replay: partition now, then serve each shard to
            # completion in shard order — the exact event + compute
            # sequence of the pre-refactor static path
            shard_of = shard_streams((f.stream_id for f in frames),
                                     seng.n_shards)
            self._shard_of = shard_of
            subs: List[List[FrameRequest]] = [
                [] for _ in range(seng.n_shards)]
            for f in frames:
                subs[shard_of[f.stream_id]].append(f)
            reports = []
            for eng, sub in zip(seng.engines, subs):
                core = _DetectionCore(eng)
                core.ingest(sub)
                reports.append(core.drain())
            out = merge_shard_reports(
                frames, reports, [len(eng.replicas)
                                  for eng in seng.engines])
        else:
            pool_sizes = [len(eng.replicas) for eng in seng.engines]
            per_shard_segs = [core.finalize_segments()
                              for core in self._cores]
            if self._n_boundaries == 0:
                out = merge_shard_reports(
                    frames, [segs[0] for segs in per_shard_segs],
                    pool_sizes)
            else:
                reports, report_shard, report_epoch = [], [], []
                for h, segs in enumerate(per_shard_segs):
                    for e, rep in enumerate(segs):
                        reports.append(rep)
                        report_shard.append(h)
                        report_epoch.append(e)
                out = merge_epoch_shard_reports(
                    frames, reports, report_shard, pool_sizes,
                    report_epoch=report_epoch)
        out["shard_of_stream"] = self._shard_of
        if seng.faults is not None:
            seng._attach_fault_keys(
                out, frames, lost=[], restarts=[], loans=[],
                t_rec=seng.faults.last_event_t if frames else None)
        return out

    def report(self, rolling: bool = True):
        from .sharded import _epoch_rollup
        if self._cores is None:
            raise RuntimeError(
                "report() mid-serve needs streams= declared up front; "
                "without it the static sharded core resolves at drain()")
        per_shard = [core.report(rolling=True) for core in self._cores]
        if rolling:
            n = max(len(reps) for reps in per_shard)
            return [_epoch_rollup([reps[e] for reps in per_shard
                                   if e < len(reps)])
                    for e in range(n)]
        return _epoch_rollup([rep for reps in per_shard for rep in reps])

    @property
    def frames_pending(self) -> int:
        if self._cores is None:
            return len(self._frames)
        return sum(core.frames_pending for core in self._cores)


class _ShardedEpochCore:
    """Incremental replay of the rebalancing epoch loop (``rebalance=
    True`` and >= 2 shards): fixed ``epoch_s`` virtual-time windows
    anchored at the first arrival, served as soon as the watermark
    passes their end.

    The batch loop ran a window's boundary actions (watchdog
    dead-shard handling, ``rebalance_streams`` migration, replica
    lending) only when a LATER non-empty window existed (``i <
    len(epochs) - 1``) — a look-ahead an incremental loop cannot make.
    Here the boundary of window ``e`` is *pending* until the next
    non-empty window is about to be served, then runs first: the same
    action sequence, no knowledge of the future required.  The final
    pending boundary is discarded at ``drain()``, exactly like batch.
    """

    def __init__(self, seng, streams=None):
        self._seng = seng
        if seng._shared_detect is not None:
            seng.warmup()
        self._frames: List[FrameRequest] = []
        self._watermark = -_INF
        self._t0: Optional[float] = None
        self._windows: List[List[FrameRequest]] = []
        self._next_raw = 0
        self._shard_of = (shard_streams(streams, seng.n_shards)
                          if streams is not None else None)
        self._seq0: Dict[int, int] = {}
        self._emit0: Dict[int, float] = {}
        # portable track rows by stream_id, updated after every shard
        # serve: migration hands a stream's row to its NEW shard, so
        # track identities survive rebalancing and evacuation
        self._tracks0: Dict[int, dict] = {}
        self._reports: List[Dict] = []
        self._report_shard: List[int] = []
        self._report_epoch: List[int] = []
        self._migrations: List[Dict] = []
        self._lost: List[FrameRequest] = []
        self._heartbeat = {h: -1 for h in range(seng.n_shards)}
        self._cursor = (ShardFaultCursor(seng.faults, seng.n_shards)
                        if seng.faults is not None
                        and seng.faults.has_shard_events else None)
        self._sup = seng.supervisor
        self._sup_begun = False
        self._first_served = False
        self._pending = None       # boundary context of the last window
        self._last_raw: Optional[int] = None

    def ingest(self, frames):
        chunk = _sorted_chunk(frames)
        if not chunk:
            return
        if chunk[0].t_arrival < self._watermark:
            raise ValueError("ingest violates the watermark (chunks must "
                             "be non-decreasing in t_arrival)")
        self._watermark = chunk[-1].t_arrival
        if self._t0 is None:
            self._t0 = chunk[0].t_arrival
        eps = self._seng.epoch_s
        for f in chunk:
            e = int((f.t_arrival - self._t0) // eps)
            while len(self._windows) <= e:
                self._windows.append([])
            self._windows[e].append(f)
        self._frames.extend(chunk)

    def advance(self, to_t: float):
        """Serve every materialized window whose end lies at or before
        ``to_t`` (the caller's promise that no frame below ``to_t`` is
        still outstanding makes such a window final).  No-op until the
        stream universe is known (``streams=`` declared, or resolved at
        ``drain()``)."""
        if self._t0 is None or self._shard_of is None:
            return
        eps = self._seng.epoch_s
        while self._next_raw < len(self._windows):
            w_end = self._t0 + (self._next_raw + 1) * eps
            if w_end > to_t:
                break
            ef = self._windows[self._next_raw]
            if ef:
                self._serve_window(self._next_raw, ef)
            self._next_raw += 1

    def _serve_window(self, raw_e: int, ef: List[FrameRequest]):
        """One non-empty epoch window, verbatim from the batch loop:
        run the previous window's pending boundary, split the window
        over the current partition, apply shard-fault cuts, serve each
        shard warm-started, collect observations and advance the seq /
        emit floors."""
        if self._pending is not None:
            self._run_boundary(self._pending)
            self._pending = None
        seng = self._seng
        sup, cursor = self._sup, self._cursor
        if sup is not None and not self._sup_begun:
            sup.begin(seng.engines)
            self._sup_begun = True
        rec = seng.recorder
        seq0, emit0, shard_of = self._seq0, self._emit0, self._shard_of
        subs: List[List[FrameRequest]] = [[] for _ in range(seng.n_shards)]
        for f in ef:
            subs[shard_of[f.stream_id]].append(f)
        t_end = ef[-1].t_arrival
        w_start = self._t0 + raw_e * seng.epoch_s
        w_end = self._t0 + (raw_e + 1) * seng.epoch_s
        if rec.enabled:
            rec.record("epoch", w_start, epoch=raw_e)
        observations = []
        down: List[int] = []
        for h, (eng, sub) in enumerate(zip(seng.engines, subs)):
            lost_h: List[FrameRequest] = []
            if cursor is not None:
                cut = cursor.begin_epoch(h, w_start, w_end)
                if cut is not None:
                    lost_h = [f for f in sub if f.t_arrival >= cut]
                    sub = [f for f in sub if f.t_arrival < cut]
                if cursor.is_down(h):
                    down.append(h)          # no heartbeat this epoch
                    if rec.enabled:
                        rec.record("shard_down", w_start, shard=h,
                                   epoch=raw_e)
                else:
                    self._heartbeat[h] = raw_e
            else:
                self._heartbeat[h] = raw_e
            warm = {sid: seq0.get(sid, 0)
                    for sid, hh in shard_of.items() if hh == h}
            rep = eng.serve(sub, reset=not self._first_served,
                            stream_seq0=warm,
                            stream_emit0={sid: emit0[sid]
                                          for sid in warm
                                          if sid in emit0},
                            stream_tracks={sid: self._tracks0[sid]
                                           for sid in warm
                                           if sid in self._tracks0})
            self._reports.append(rep)
            self._report_shard.append(h)
            self._report_epoch.append(raw_e)
            obs_frames = {sid: v["frames"]
                          for sid, v in rep["per_stream"].items()}
            for f in lost_h:   # the policy sees true arrival rates
                obs_frames[f.stream_id] = \
                    obs_frames.get(f.stream_id, 0) + 1
            observations.append({
                # shard-lost frames are drops for the pressure signal:
                # a dead shard reads maximally pressured
                "drops": len(rep["dropped"]) + len(lost_h),
                "backlog_s": eng.backlog_snapshot(t_end)["backlog_s"],
                "frames": obs_frames,
            })
            for sid, v in rep["per_stream"].items():
                seq0[sid] = seq0.get(sid, 0) + v["frames"]
            for f in lost_h:
                # lost frames still advance the seq floor: later
                # epochs' frames must map to their true per-stream
                # arrival indices or quality accounting corrupts
                if rec.enabled:
                    # lost frames never reach an engine, so their
                    # arrive + terminal events record here (frame
                    # conservation holds over the whole trace)
                    rec.record("arrive", f.t_arrival, rid=f.rid,
                               stream=f.stream_id,
                               seq=seq0.get(f.stream_id, 0), shard=h)
                    rec.record("shard_lost", f.t_arrival, rid=f.rid,
                               stream=f.stream_id, shard=h)
                seq0[f.stream_id] = seq0.get(f.stream_id, 0) + 1
            for sid, em in rep["emit_t"].items():
                if em:
                    emit0[sid] = max(emit0.get(sid, 0.0), em[-1])
            if eng.carry_tracks:
                # pull the served streams' track rows back into the
                # epoch-level map — the rows a migrated stream carries
                # to its destination shard next window
                self._tracks0.update(eng._exported_tracks)
            self._lost += lost_h
        self._first_served = True
        self._last_raw = raw_e
        self._pending = {"raw_e": raw_e, "down": down,
                         "observations": observations, "w_end": w_end,
                         "had_frames": [bool(s) for s in subs]}

    def _run_boundary(self, p: Dict):
        """The batch loop's inter-epoch block: watchdog dead-shard
        detection + restart/evacuation, deterministic stream migration,
        then replica lending — acting on the window recorded in ``p``,
        exactly when the batch loop would have (before the next
        non-empty window serves)."""
        seng, sup, cursor = self._seng, self._sup, self._cursor
        rec = seng.recorder
        raw_e, down = p["raw_e"], p["down"]
        evac: List[int] = []
        if sup is not None and cursor is not None:
            dead = sup.detect_dead(self._heartbeat, raw_e,
                                   p["had_frames"])
            for h in dead:
                sup.handle_dead(seng.engines, h, cursor, raw_e,
                                p["w_end"])
            # every currently-down shard is excluded from the stealing
            # phase (and drained of streams), detected or not — a dead
            # host must never RECEIVE streams
            evac = sorted(set(down))
        self._shard_of, moves = rebalance_streams(
            self._shard_of, p["observations"],
            max_moves=seng.max_moves_per_epoch,
            evacuate=tuple(evac))
        self._migrations += [{"epoch": raw_e, "stream": sid,
                              "src": src, "dst": dst}
                             for sid, src, dst in moves]
        if rec.enabled:
            for sid, src, dst in moves:
                rec.record("migrate", p["w_end"], stream=sid,
                           src=src, dst=dst, epoch=raw_e)
        if sup is not None:
            stole = any(src not in set(evac) for _, src, _ in moves)
            sup.rebalance_loans(seng.engines, p["observations"],
                                moved=stole, down=down, epoch=raw_e,
                                epoch_s=seng.epoch_s, t=p["w_end"])

    def epoch_boundary(self):
        """Epoch windows are intrinsic here (the ``epoch_s`` grid), so
        this only returns the latest served window's rollup (or None
        before any window completed) — it cannot cut a window early."""
        if self._last_raw is None:
            return None
        from .sharded import _epoch_rollup
        return _epoch_rollup(
            [rep for rep, e in zip(self._reports, self._report_epoch)
             if e == self._last_raw])

    def drain(self) -> Dict:
        from .sharded import merge_epoch_shard_reports
        seng = self._seng
        frames = self._frames
        if not frames:
            # batch dispatch served an empty trace on the static path
            return _ShardedStaticCore(seng).drain()
        if self._shard_of is None:
            self._shard_of = shard_streams(
                (f.stream_id for f in frames), seng.n_shards)
        self.advance(_INF)
        # the last window's pending boundary is discarded: batch never
        # rebalanced after the final non-empty epoch
        self._pending = None
        sup = self._sup
        pool_sizes = [len(eng.replicas) for eng in seng.engines]
        if sup is not None:
            sup.finish(seng.engines, self._last_raw,
                       t=self._t0 + (self._last_raw + 1) * seng.epoch_s)
            pool_sizes = sup.pool_sizes(seng.engines)
        out = merge_epoch_shard_reports(frames, self._reports,
                                        self._report_shard, pool_sizes,
                                        report_epoch=self._report_epoch)
        out["shard_of_stream"] = self._shard_of
        out["migrations"] = self._migrations
        out["n_epochs"] = len(self._windows)
        lost = self._lost
        if lost:
            # fold the shard-lost frames into the drop accounting: they
            # never reached an engine, so no report counted them
            pos = {f.rid: k for k, f in enumerate(frames)}
            out["dropped"] = sorted(out["dropped"]
                                    + [f.rid for f in lost],
                                    key=pos.__getitem__)
            for f in lost:
                agg = out["per_stream"].setdefault(
                    f.stream_id, {"frames": 0, "dropped": 0,
                                  "interpolated": 0, "coverage": 0.0,
                                  "throughput_fps": 0.0})
                agg["frames"] += 1
                agg["dropped"] += 1
            for sid in sorted({f.stream_id for f in lost}):
                rs = out["streams"].setdefault(sid, [])
                out["emit_t"].setdefault(sid, [])
                agg = out["per_stream"][sid]
                agg["coverage"] = len(rs) / max(agg["frames"], 1)
            out["n_streams"] = len(out["per_stream"])
        if seng.faults is not None or sup is not None:
            restarts = list(sup.restart_log) if sup is not None else []
            loans = list(sup.loan_log) if sup is not None else []
            t_cands = []
            if seng.faults is not None:
                t_cands.append(seng.faults.last_event_t)
            t_cands += [r["t"] for r in restarts]
            for ln in loans:
                t_cands.append(
                    self._t0 + (ln["epoch"] + 1) * seng.epoch_s)
                if ln["returned_epoch"] is not None:
                    t_cands.append(
                        self._t0 + (ln["returned_epoch"] + 1)
                        * seng.epoch_s)
            t_rec = None
            if t_cands:
                # recovery acts at epoch boundaries: quantize the last
                # fault/action up to the next boundary
                k = int(np.ceil(max(max(t_cands) - self._t0, 0.0)
                                / seng.epoch_s - 1e-12))
                t_rec = self._t0 + k * seng.epoch_s
            seng._attach_fault_keys(out, frames, lost, restarts, loans,
                                    t_rec)
        return out

    def report(self, rolling: bool = True):
        from .sharded import _epoch_rollup
        by_epoch: Dict[int, List[Dict]] = {}
        for rep, e in zip(self._reports, self._report_epoch):
            by_epoch.setdefault(e, []).append(rep)
        if rolling:
            return [_epoch_rollup(by_epoch[e])
                    for e in sorted(by_epoch)]
        return _epoch_rollup(self._reports)

    @property
    def frames_pending(self) -> int:
        return sum(len(w) for w in self._windows[self._next_raw:])


class ServingRuntime:
    """Always-on incremental serving core over a ``DetectionEngine`` or
    ``ShardedDetectionEngine``.

    The batch ``serve(frames)`` entry points are now one-shot drivers
    over this class::

        rt = ServingRuntime(engine)
        rt.ingest(frames)        # any chunking: per-frame, bursts, all
        rt.advance(t)            # run work that can no longer change
        rt.report()              # rolling per-epoch reports, mid-serve
        rt.epoch_boundary()      # close a reporting window explicitly
        report = rt.drain()      # flush + final report

    **Bit-identity:** one-shot ingest + drain reproduces the batch
    report byte for byte, and — under the watermark contract (chunks
    non-decreasing in ``t_arrival``; ``advance(to_t)`` only after every
    frame below ``to_t`` is ingested) — so does ANY chunking.

    **Sharded engines:** the deterministic ``shard_streams`` partition
    is a function of the full camera set, so incremental processing
    needs the stream universe declared up front (``streams=``); without
    it ingest buffers and ``drain()`` replays the batch path.  The
    warm-start hooks (``reset=False`` / ``stream_seq0`` /
    ``stream_emit0`` / ``stream_tracks``) are single-engine
    trace-slicing plumbing and are rejected on sharded engines — the
    sharded cores manage their own epoch floors and carry each
    stream's portable track rows across windows (and migrations)
    themselves.

    **Reset semantics:** :meth:`reset_engines` is THE one definition of
    per-serve state reset (replica virtual clocks + scheduler round
    bookkeeping, shard-recursive); ``ServingEngine.reset``,
    ``DetectionEngine.reset`` and ``ShardedDetectionEngine.reset`` all
    delegate to it, and every fresh runtime (``reset=True``, the
    default) starts from it — so back-to-back serves are independent by
    construction."""

    def __init__(self, engine, *, reset: bool = True,
                 stream_seq0: Optional[Dict[int, int]] = None,
                 stream_emit0: Optional[Dict[int, float]] = None,
                 stream_tracks: Optional[Dict[int, dict]] = None,
                 streams: Optional[Sequence[int]] = None):
        self.engine = engine
        if isinstance(engine, DetectionEngine):
            if streams is not None and stream_seq0 is None:
                # declare the expected camera set: it pre-seeds the
                # per-stream accounting so idle declared cameras still
                # appear (with zero frames) in every report
                stream_seq0 = {sid: 0 for sid in streams}
            self._core = _DetectionCore(engine, reset=reset,
                                        stream_seq0=stream_seq0,
                                        stream_emit0=stream_emit0,
                                        stream_tracks=stream_tracks)
        elif hasattr(engine, "engines"):     # ShardedDetectionEngine
            if not reset or stream_seq0 or stream_emit0 or stream_tracks:
                raise ValueError(
                    "warm-start hooks (reset=False / stream_seq0 / "
                    "stream_emit0 / stream_tracks) are single-engine "
                    "trace-slicing plumbing; the sharded cores manage "
                    "their own epoch floors and track rows")
            if engine.rebalance and engine.n_shards > 1:
                self._core = _ShardedEpochCore(engine, streams=streams)
            else:
                self._core = _ShardedStaticCore(engine, streams=streams)
        else:
            raise TypeError(
                f"ServingRuntime drives frame-payload engines "
                f"(DetectionEngine / ShardedDetectionEngine), got "
                f"{type(engine).__name__}")

    # ------------------------------------------------------------- intake
    def ingest(self, frames):
        """Feed one ``FrameRequest`` or a sequence of them.  Chunks must
        be non-decreasing in ``t_arrival`` across calls (ties allowed);
        within a chunk frames are sorted stably, like the batch path."""
        self._core.ingest(frames)

    def advance(self, to_t: Optional[float] = None):
        """Run every micro-batch / epoch window that is *sealed* below
        ``to_t`` — the caller's promise that all frames with
        ``t_arrival < to_t`` have been ingested.  ``None`` uses the
        ingest watermark (process everything that can no longer
        change)."""
        if to_t is None:
            to_t = self._core._watermark
        self._core.advance(to_t)

    # ------------------------------------------------------------ windows
    def epoch_boundary(self):
        """Close the current reporting window: flush pending work, emit
        the window's report, carry seq/emit floors into the next one.
        On the rebalancing sharded core windows are intrinsic (the
        ``epoch_s`` grid) and this returns the latest window's rollup
        instead of cutting one."""
        return self._core.epoch_boundary()

    def report(self, rolling: bool = True):
        """Non-destructive mid-serve view.  ``rolling=True`` returns the
        per-epoch report list (full engine reports on a single-engine
        runtime — the open window peeked and tagged ``partial`` —
        volume/latency rollups on sharded runtimes); ``rolling=False``
        returns one cumulative report/rollup merged under the
        merge-never-average rule."""
        return self._core.report(rolling=rolling)

    def drain(self) -> Dict:
        """Flush all in-flight frames (seal everything, including the
        partial tail micro-batch) and return the final report — the
        graceful-shutdown path.  Bit-identical to batch ``serve()``
        when no mid-serve boundaries were cut."""
        return self._core.drain()

    # -------------------------------------------------------------- state
    @property
    def frames_pending(self) -> int:
        """Ingested frames not yet processed (in-flight on shutdown)."""
        return self._core.frames_pending

    @property
    def watermark(self) -> float:
        """Latest ingested ``t_arrival`` (``-inf`` before any frame)."""
        return self._core._watermark

    def reset(self):
        """Reset the engine's per-serve state (through
        :meth:`reset_engines`) and restart this runtime's incremental
        state from scratch: queues, segments, floors and reports are
        cleared.  Warm service estimates and compiled programs survive,
        exactly like the engines' own documented ``reset``."""
        ServingRuntime.reset_engines(self.engine)
        kw = {}
        core = self._core
        if isinstance(core, (_ShardedStaticCore, _ShardedEpochCore)):
            streams = (sorted(core._shard_of) if core._shard_of is not None
                       else None)
            self._core = type(core)(self.engine, streams=streams)
        else:
            self._core = _DetectionCore(self.engine, reset=False, **kw)

    @staticmethod
    def reset_engines(engine):
        """THE per-serve reset semantic, shared by every engine: clear
        replica virtual-clock state (``busy_until`` / processed counts /
        EWMAs — warm ``_last_wall`` estimates survive) and the
        scheduler's round bookkeeping; recurse over a sharded engine's
        shard engines.  ``ServingEngine.reset`` /
        ``DetectionEngine.reset`` / ``ShardedDetectionEngine.reset``
        all route here."""
        subs = getattr(engine, "engines", None)
        if subs is not None:                 # sharded: recurse per shard
            for eng in subs:
                ServingRuntime.reset_engines(eng)
            return
        for r in engine.replicas:
            r.reset()
        engine.scheduler.reset()
