"""Subscriber event bus over the serving trace — one source of truth.

The engines already record every frame-lifecycle and control-plane
event into ``obs.TraceRecorder`` (deterministic on the virtual clock,
audited by ``obs.audit``).  This module derives the *push* side from
that same log instead of inventing a second event schema:
``EventBus.recorder()`` returns a ``TapRecorder`` — a drop-in
``TraceRecorder`` that publishes every event it records to the bus's
subscribers, grouped into coarse topics:

=============  =====================================================
topic          trace kinds (``repro.obs.trace``)
=============  =====================================================
``detection``  ``complete``, ``emit``, ``interp_emit``
``drop``       ``drop``, ``shard_lost``, ``lost``
``migration``  ``migrate``
``fault``      ``retry``, ``failover``, ``health_mark``,
               ``health_restore``, ``shard_down``, ``shard_restart``
``loan``       ``loan``, ``loan_return``
``epoch``      ``epoch``
``lifecycle``  ``arrive``, ``enqueue``, ``dispatch`` (and any
               future kind not mapped above)
=============  =====================================================

Because the tap IS the trace recorder, subscribers see exactly the
events the audit replays and the Perfetto export draws — same dicts,
same code order — and an engine built with a plain ``TraceRecorder``
(or none) is untouched: the bus is opt-in per engine construction.

``JsonlSink`` is the daemon's streaming subscriber: one JSON object
per line, ``topic`` added to the raw event fields.
"""
from __future__ import annotations

import json
from typing import Callable, Dict, List, Optional, Sequence

from ..obs.trace import TraceRecorder, _ShardView

#: the seven event topics, in the order the daemon summarizes them
TOPICS = ("detection", "drop", "migration", "fault", "loan", "epoch",
          "lifecycle")

_TOPIC_OF_KIND = {
    "complete": "detection", "emit": "detection",
    "interp_emit": "detection",
    "drop": "drop", "shard_lost": "drop", "lost": "drop",
    "migrate": "migration",
    "track_export": "migration", "track_import": "migration",
    "retry": "fault", "failover": "fault", "health_mark": "fault",
    "health_restore": "fault", "shard_down": "fault",
    "shard_restart": "fault",
    "loan": "loan", "loan_return": "loan",
    "epoch": "epoch",
    "arrive": "lifecycle", "enqueue": "lifecycle",
    "dispatch": "lifecycle",
}


def topic_of(kind: str) -> str:
    """Map a trace event ``kind`` to its bus topic.  Unmapped kinds
    (future additions) land in ``lifecycle`` so no event is ever
    silently unroutable.

    >>> topic_of("interp_emit"), topic_of("shard_down"), topic_of("x")
    ('detection', 'fault', 'lifecycle')
    """
    return _TOPIC_OF_KIND.get(kind, "lifecycle")


class EventBus:
    """Topic-routed fan-out of serving trace events to subscribers.

    ``subscribe(cb, topics=...)`` registers ``cb(topic, event)`` for a
    topic subset (``None`` or ``"*"`` = every topic) and returns a
    handle for ``unsubscribe``.  ``publish`` routes one raw trace-event
    dict by ``topic_of(event["kind"])`` and counts it in ``counts``
    (per topic, subscribers or not).  Subscriber errors propagate: the
    bus runs on the deterministic serve path, where a silently dropped
    event would be a debugging trap.

    Wire it to an engine by constructing the engine with
    ``recorder=bus.recorder()``::

        bus = EventBus()
        bus.subscribe(lambda topic, e: print(topic, e["kind"]),
                      topics=("drop", "fault"))
        eng = DetectionEngine(recorder=bus.recorder(), ...)
    """

    def __init__(self):
        self._subs: List[Optional[tuple]] = []   # (topics|None, cb)
        self.counts: Dict[str, int] = {}

    def subscribe(self, callback: Callable[[str, dict], None],
                  topics: Optional[Sequence[str]] = None) -> int:
        """Register ``callback(topic, event)``; returns an unsubscribe
        handle.  ``topics=None`` (or ``"*"``) delivers every topic."""
        if topics is None or topics == "*":
            tset = None
        else:
            tset = frozenset([topics] if isinstance(topics, str)
                             else topics)
            unknown = tset - frozenset(TOPICS)
            if unknown:
                raise ValueError(f"unknown topics {sorted(unknown)}; "
                                 f"valid: {TOPICS}")
        self._subs.append((tset, callback))
        return len(self._subs) - 1

    def unsubscribe(self, handle: int):
        """Remove the subscription returned by ``subscribe``."""
        self._subs[handle] = None

    def publish(self, event: dict):
        """Route one raw trace-event dict to the matching subscribers
        (called by ``TapRecorder`` for every recorded event)."""
        topic = topic_of(event["kind"])
        self.counts[topic] = self.counts.get(topic, 0) + 1
        for sub in self._subs:
            if sub is not None and (sub[0] is None or topic in sub[0]):
                sub[1](topic, event)

    def recorder(self) -> "TapRecorder":
        """A ``TraceRecorder`` wired to this bus: hand it to an engine
        as ``recorder=`` and every recorded event is also published."""
        return TapRecorder(self)


class TapRecorder(TraceRecorder):
    """A ``TraceRecorder`` that additionally publishes every event to
    an ``EventBus`` — the log stays the source of truth (audit/export
    replay it unchanged); the bus is a live view of the same dicts.

    ``shard_view`` must be overridden here: the base ``_ShardView``
    appends to the parent's event list *directly* (hot-path
    optimization), which would silently bypass the tap for every
    shard-engine event."""

    def __init__(self, bus: EventBus):
        super().__init__()
        self.bus = bus

    def record(self, kind: str, t: float, **fields):
        super().record(kind, t, **fields)
        self.bus.publish(self.events[-1])

    def shard_view(self, shard: int) -> "_TapShardView":
        return _TapShardView(self, shard)


class _TapShardView(_ShardView):
    """Shard-stamping proxy that keeps the tap: records through the
    base proxy (direct append, same dict layout) then publishes."""

    def record(self, kind: str, t: float, **fields):
        super().record(kind, t, **fields)
        self._parent.bus.publish(self._parent.events[-1])

    def shard_view(self, shard: int) -> "_TapShardView":
        return _TapShardView(self._parent, shard)


class JsonlSink:
    """Streaming JSONL subscriber: one line per event, the raw trace
    fields plus ``topic``.  Subscribe it to a bus (usually to ``"*"``)
    and close it on shutdown; usable as a context manager.

    >>> import io
    >>> bus = EventBus()
    >>> sink = JsonlSink(io.StringIO())
    >>> _ = bus.subscribe(sink)
    >>> bus.publish({"kind": "drop", "t": 1.0, "i": 0, "rid": 7})
    >>> sink.n_written
    1
    """

    def __init__(self, path_or_file):
        if hasattr(path_or_file, "write"):
            self._fh = path_or_file
            self._own = False
        else:
            self._fh = open(path_or_file, "w")
            self._own = True
        self.n_written = 0

    def __call__(self, topic: str, event: dict):
        self._fh.write(json.dumps({"topic": topic, **event},
                                  default=float) + "\n")
        self.n_written += 1

    def close(self):
        self._fh.flush()
        if self._own:
            self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
