"""Loadable model profiles for transprecise cascade serving.

The paper's deployment is heterogeneous *models* (SSD300 + YOLOv3) on
heterogeneous devices; the serving stack modelled only heterogeneous
replica *speeds*.  This module adds the missing axis: a
``ModelProfile`` is one loadable detector with a sustained service rate
``mu`` and a calibrated quality estimate ``map_est``; a ``ModelCatalog``
is the set of profiles every ``ReplicaExecutor`` can switch between
(TOD, arXiv 2105.08668, switches model precision/size per frame from
the latency budget; EdgeNet, arXiv 1911.06091, maps the same
accuracy-vs-performance space offline).

``paper_catalog`` builds the fast/medium/heavy triple calibrated from
the existing ``ProxyDetector`` paper bands (``core.quality.NOISE``):
YOLOv3 is the heavy high-recall model, SSD300 the medium one, and the
tiny-YOLO band the fast low-recall one — so switching models changes
*real scored detections*, not just the virtual clock.

``make_cascade_detect_fn`` is the multi-model oracle: the engine passes
``model=`` to select the band per micro-batch, and ``rois=`` on the
hierarchical second pass (the heavy model answers only inside the
first pass's ROI windows, detections clipped to their covering ROI —
SNIPPETS.md §3's ``inference-region=roi-list``).

``cascade_report_keys`` is the ONE place the cascade block of a serve
report is derived from raw counters; the engine and both shard merges
share it, so a single-shard merge recomputes bit-identical values.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.quality import ProxyDetector


@dataclass(frozen=True)
class ModelProfile:
    """One loadable detector model.

    * ``map_est`` — calibrated quality estimate (orders the catalog:
      heaviest = highest ``map_est``) and the weight behind the
      report's ``map_estimate``.
    * ``band`` — the ``core.quality.NOISE`` band the proxy oracle
      detects with when this model is selected.
    * ``service_s`` — pinned virtual per-frame service seconds on a
      speed-1.0 replica (like the engine's ``service_time``); ``None``
      leaves the measured-wall service estimate in charge.
    * ``mu`` — sustained frames/s on a speed-1.0 replica; defaults to
      ``1 / service_s``.  The selector's feasibility test compares the
      pool's summed ``mu`` against the arrival-rate estimate.
    """
    name: str
    map_est: float
    band: str = "yolov3"
    service_s: Optional[float] = None
    mu: Optional[float] = None

    def __post_init__(self):
        if self.mu is None:
            if self.service_s is None or self.service_s <= 0:
                raise ValueError(
                    f"profile {self.name!r} needs mu= or a positive "
                    f"service_s= to derive it (got {self.service_s})")
            object.__setattr__(self, "mu", 1.0 / self.service_s)


class ModelCatalog:
    """Ordered, immutable set of ``ModelProfile``s with unique names.

    The catalog object itself rides on every ``ReplicaExecutor``
    (``r.catalog``), so replica lending moves it with the executor and
    a dead replica's catalog leaves the capacity pool with it."""

    def __init__(self, profiles: Sequence[ModelProfile]):
        profiles = tuple(profiles)
        if not profiles:
            raise ValueError("a ModelCatalog needs at least one profile "
                             "(pass catalog=None for no cascade at all)")
        names = [p.name for p in profiles]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate profile names in catalog: {names}")
        self.profiles = profiles
        self._by_name = {p.name: p for p in profiles}

    def get(self, name: str) -> Optional[ModelProfile]:
        return self._by_name.get(name)

    def __getitem__(self, name: str) -> ModelProfile:
        return self._by_name[name]

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def __len__(self) -> int:
        return len(self.profiles)

    def __iter__(self):
        return iter(self.profiles)

    @property
    def names(self):
        return tuple(p.name for p in self.profiles)

    def by_quality(self) -> List[ModelProfile]:
        """Profiles sorted heaviest (highest ``map_est``) first; ties
        keep catalog order (stable sort)."""
        return sorted(self.profiles, key=lambda p: -p.map_est)

    @property
    def heaviest(self) -> ModelProfile:
        return self.by_quality()[0]

    @property
    def lightest(self) -> ModelProfile:
        return self.by_quality()[-1]

    def map_est_by_name(self) -> Dict[str, float]:
        return {p.name: p.map_est for p in self.profiles}

    def __repr__(self):
        return f"ModelCatalog({list(self.names)})"


def as_catalog(catalog) -> Optional[ModelCatalog]:
    """Normalize an engine's ``catalog=`` argument: ``None`` / empty ->
    ``None`` (no cascade layer at all — the bit-identical default),
    a sequence of profiles -> a ``ModelCatalog``."""
    if not catalog:
        return None
    if isinstance(catalog, ModelCatalog):
        return catalog
    return ModelCatalog(catalog)


def paper_catalog(heavy_service_s: float = 0.4) -> ModelCatalog:
    """The fast/medium/heavy triple calibrated from the paper bands:
    YOLOv3 (heavy, high recall), SSD300 (medium), tiny-YOLO (fast,
    4x the heavy model's rate at roughly half its quality).  The
    ``map_est`` values are the proxy bands' tracked-mAP plateaus on the
    ETH-Sunnyday scene; relative ORDER is what the selector needs."""
    return ModelCatalog([
        ModelProfile("heavy", map_est=0.88, band="yolov3",
                     service_s=heavy_service_s),
        ModelProfile("medium", map_est=0.62, band="ssd300",
                     service_s=heavy_service_s / 2),
        ModelProfile("fast", map_est=0.45, band="yolov3_tiny",
                     service_s=heavy_service_s / 4),
    ])


def make_cascade_detect_fn(videos: Dict, frame_of, catalog,
                           max_out: int = 24):
    """Multi-model proxy oracle for ``DetectionEngine.detect_fn``.

    Same ``(images, rids) -> (boxes, scores, classes, valid)`` contract
    as ``core.quality.proxy_detect_fn_streams``, plus two keyword
    hooks the engine probes for:

    * ``model=`` — the catalog profile name whose noise band answers
      this micro-batch (default: the heaviest profile, so an engine
      WITHOUT a catalog scores exactly like a fixed heavy-model run);
    * ``rois=`` — ``{rid: (R, 4) xyxy windows}`` for the hierarchical
      second pass: only detections whose center lies inside a window
      survive, clipped to their covering window (a second-pass box can
      never escape the region the first pass proposed — the audit's
      roi-containment invariant holds by construction).

    Detectors are memoized per (stream, band): a band's detections are
    a pure function of (band, stream seed, frame), so a fixed-model
    baseline and the cascade score identically wherever they pick the
    same model."""
    catalog = as_catalog(catalog)
    default = catalog.heaviest.name
    band_of = {p.name: p.band for p in catalog}
    detectors: Dict[tuple, ProxyDetector] = {}

    def det_for(sid: int, band: str) -> ProxyDetector:
        key = (sid, band)
        if key not in detectors:
            detectors[key] = ProxyDetector(band, videos[sid].spec.name,
                                           seed=sid)
        return detectors[key]

    def detect(images, rids, model=None, rois=None):
        band = band_of[model if model is not None else default]
        B = len(images)
        per_det: Dict[int, List[int]] = {}
        for rid in rids:
            if rid < 0:
                continue
            sid, k = frame_of[rid]
            per_det.setdefault(sid, []).append(k)
        for sid, ks in per_det.items():
            det_for(sid, band).detect_many(videos[sid], ks)
        boxes = np.zeros((B, max_out, 4), np.float32)
        scores = np.zeros((B, max_out), np.float32)
        classes = np.zeros((B, max_out), np.int32)
        valid = np.zeros((B, max_out), bool)
        for i, rid in enumerate(rids):
            if rid < 0:                     # batch padding row
                continue
            sid, k = frame_of[rid]
            d = det_for(sid, band).detect(videos[sid], k)
            db, ds, dc = d.boxes, d.scores, d.classes
            if rois is not None:
                rw = np.asarray(rois.get(rid, ()), float).reshape(-1, 4)
                if len(rw) == 0 or len(db) == 0:
                    db, ds, dc = db[:0], ds[:0], dc[:0]
                else:
                    cx = (db[:, 0] + db[:, 2]) / 2
                    cy = (db[:, 1] + db[:, 3]) / 2
                    inside = ((rw[None, :, 0] <= cx[:, None])
                              & (cx[:, None] <= rw[None, :, 2])
                              & (rw[None, :, 1] <= cy[:, None])
                              & (cy[:, None] <= rw[None, :, 3]))
                    hit = inside.any(-1)
                    cover = rw[inside.argmax(-1)[hit]]
                    db, ds, dc = db[hit], ds[hit], dc[hit]
                    db = np.stack([np.maximum(db[:, 0], cover[:, 0]),
                                   np.maximum(db[:, 1], cover[:, 1]),
                                   np.minimum(db[:, 2], cover[:, 2]),
                                   np.minimum(db[:, 3], cover[:, 3])], -1)
            n = min(len(db), max_out)
            boxes[i, :n] = db[:n]
            scores[i, :n] = ds[:n]
            classes[i, :n] = dc[:n]
            valid[i, :n] = True
        return boxes, scores, classes, valid

    return detect


def cascade_report_keys(model_counts: Dict[str, int],
                        model_of_frame: Dict[int, str],
                        model_map_est: Dict[str, float],
                        model_switches: int,
                        roi_pixels: Dict[str, float],
                        n_frames: int) -> Dict:
    """The cascade block of a serve report, derived from raw counters.

    Both the engine's ``_finalize_segment`` and the shard merges call
    THIS function (merges after summing/unioning the raw counters
    across reports), so derived scalars are recomputed — never averaged
    — and a single-shard merge is bit-identical to the shard's own
    report:

    * ``models`` — frames detected per model (drops/interpolations
      excluded);
    * ``model_of_frame`` — ``{rid: model name}`` for every detected
      frame;
    * ``model_map_est`` — the catalog's quality estimates;
    * ``model_switches`` — selector transitions this report covers;
    * ``map_estimate`` — expected quality over ARRIVAL frames:
      ``sum(count_m * map_est_m) / n_frames`` (a dropped frame counts
      0, so shedding load shows up as lost expected quality);
    * ``roi_pixels`` / ``roi_pixel_reduction`` — hierarchical
      second-pass accounting: full-frame vs ROI pixels the heavy model
      would have read, and the fraction saved.

    Every key is present (empty/0.0) on a catalog-less engine, so
    report schemas match with and without a cascade."""
    est = 0.0
    for m in sorted(model_counts):
        est += model_counts[m] * model_map_est.get(m, 0.0)
    full = float(roi_pixels.get("full", 0.0))
    roi = float(roi_pixels.get("roi", 0.0))
    return {
        "models": dict(model_counts),
        "model_of_frame": dict(model_of_frame),
        "model_map_est": dict(model_map_est),
        "model_switches": int(model_switches),
        "map_estimate": est / n_frames if n_frames else 0.0,
        "roi_pixels": {"full": full, "roi": roi,
                       "passes": int(roi_pixels.get("passes", 0))},
        "roi_pixel_reduction": 1.0 - roi / full if full > 0 else 0.0,
    }
