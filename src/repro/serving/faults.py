"""Deterministic fault injection for the serving stack.

The serving layers (``DetectionEngine`` replicas, the sharded epoch
loop) run on a *virtual* clock, so faults are virtual-time events too:
a ``FaultSchedule`` is a sorted, immutable list of ``FaultEvent``s that
the engines fold into the clock exactly like arrivals.  Nothing here is
random at injection time — a schedule (optionally generated from a seed
by ``FaultSchedule.random``) replays bit-identically on every serve, so
every recovery behaviour is a regression-testable function of
``(trace, schedule)``.

Failure domains (matching the serving stack's layers):

* **replica** — one executor of one shard's pool.  ``slow`` degrades
  its service rate by ``factor`` (the paper's mu degradation: a stick
  on a throttled USB hub), ``kill`` makes it stop completing work
  (service time becomes infinite), ``revive`` brings it back clean
  (factor reset to 1).  Injected into ``ReplicaExecutor.service_time``
  via a per-replica ``ReplicaFaultView``; *detected* by the scheduler's
  timeout rule (``core.scheduler``), because a real dispatcher never
  observes "dead", only "did not come back in k x the expected time".
* **shard** — a whole host of ``ShardedDetectionEngine``.
  ``shard_kill`` makes the shard lose every frame arriving while it is
  down (and stop heartbeating); ``shard_revive`` is the schedule-driven
  self-recovery, and the watchdog's ``restart`` is the supervised one.
  Folded into the epoch loop by ``ShardFaultCursor``.

Boundary quantization
---------------------
Shard recovery (revive or watchdog restart) takes effect only at epoch
boundaries, while kills take effect immediately.  That asymmetry is
deliberate: within one epoch a shard is up for a *prefix* of the window
and down for the *suffix*, so the frames a stream loses are a
contiguous suffix of its epoch arrivals — which is exactly the property
that lets the epoch loop advance the per-stream ``seq`` floors past
lost frames without corrupting the arrival-index bookkeeping
``core.quality.evaluate_streams`` keys on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

REPLICA_KINDS = ("slow", "kill", "revive")
SHARD_KINDS = ("shard_kill", "shard_revive")
KINDS = REPLICA_KINDS + SHARD_KINDS


@dataclass(frozen=True)
class FaultEvent:
    """One virtual-time fault.

    ``t`` is virtual seconds on the serving clock.  Replica-level kinds
    (``slow``/``kill``/``revive``) require ``replica``; shard-level
    kinds (``shard_kill``/``shard_revive``) forbid it.  ``factor`` is
    the service-time multiplier of a ``slow`` event (>= 1: a factor of
    4 quarters the replica's effective mu).  ``permanent`` marks a
    ``shard_kill`` the watchdog cannot repair (restart returns failure
    and the shard stays down) — the evacuation path must carry the
    recovery alone."""
    t: float
    kind: str
    shard: int = 0
    replica: Optional[int] = None
    factor: float = 1.0
    permanent: bool = False

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.kind in REPLICA_KINDS and self.replica is None:
            raise ValueError(f"{self.kind!r} is a replica-level fault: "
                             "it requires replica=")
        if self.kind in SHARD_KINDS and self.replica is not None:
            raise ValueError(f"{self.kind!r} is a shard-level fault: "
                             "replica= must be None")
        if self.kind == "slow" and self.factor < 1.0:
            raise ValueError("slow events degrade service: factor must "
                             f"be >= 1.0, got {self.factor}")


@dataclass(frozen=True)
class ReplicaFaultView:
    """One replica's slice of a ``FaultSchedule`` — the object
    ``ReplicaExecutor.faults`` holds.  Pure fold over the (sorted)
    events, so reading it never mutates anything and two replicas of
    the same schedule always agree."""
    events: Tuple[FaultEvent, ...] = ()

    def alive(self, t: float) -> bool:
        """Is the replica up at virtual time ``t``? (kill/revive fold)"""
        up = True
        for e in self.events:
            if e.t > t:
                break
            if e.kind == "kill":
                up = False
            elif e.kind == "revive":
                up = True
        return up

    def alive_through(self, t0: float, t1: float) -> bool:
        """Does an in-flight frame dispatched over ``[t0, t1]`` survive?
        Requires the replica up at ``t0`` and no kill striking inside
        ``(t0, t1]`` — a kill+revive blip inside the window still loses
        the frame that was on the device."""
        if not self.alive(t0):
            return False
        return not any(e.kind == "kill" and t0 < e.t <= t1
                       for e in self.events)

    def factor(self, t: float) -> float:
        """Service-time multiplier at ``t``: the latest ``slow`` factor,
        reset to 1.0 by ``revive`` (a revived replica comes back
        clean)."""
        f = 1.0
        for e in self.events:
            if e.t > t:
                break
            if e.kind == "slow":
                f = e.factor
            elif e.kind == "revive":
                f = 1.0
        return f


class FaultSchedule:
    """Immutable, sorted collection of ``FaultEvent``s.

    Falsy when empty — every injection site in the serving stack gates
    on truthiness, so ``FaultSchedule()`` (or ``faults=None``) keeps the
    fault-free paths bit-identical to the pre-fault engine (the
    ``no_fault_bit_identical`` acceptance bar).

    >>> s = FaultSchedule.replica_kill(1.0, replica=1, revive_t=3.0)
    >>> [e.kind for e in s]
    ['kill', 'revive']
    >>> bool(FaultSchedule())
    False
    """

    def __init__(self, events: Iterable[FaultEvent] = ()):
        evs = list(events)
        # total order (time, shard, replica, kind rank): schedules built
        # from the same event set compare and replay identically no
        # matter the construction order
        evs.sort(key=lambda e: (e.t, e.shard,
                                -1 if e.replica is None else e.replica,
                                KINDS.index(e.kind)))
        self.events: Tuple[FaultEvent, ...] = tuple(evs)

    def __bool__(self) -> bool:
        return bool(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    @property
    def has_shard_events(self) -> bool:
        return any(e.kind in SHARD_KINDS for e in self.events)

    @property
    def last_event_t(self) -> float:
        """Virtual time of the last scheduled event (0.0 when empty) —
        the anchor the sharded report's ``recovered_coverage`` window
        starts after."""
        return self.events[-1].t if self.events else 0.0

    def replica_events(self, shard: int, replica: int) -> List[FaultEvent]:
        return [e for e in self.events if e.kind in REPLICA_KINDS
                and e.shard == shard and e.replica == replica]

    def shard_events(self, shard: int) -> List[FaultEvent]:
        return [e for e in self.events if e.kind in SHARD_KINDS
                and e.shard == shard]

    def view(self, shard: int, replica: int) -> ReplicaFaultView:
        """The per-replica fold ``ReplicaExecutor.faults`` consumes."""
        return ReplicaFaultView(tuple(self.replica_events(shard, replica)))

    # -------------------------------------------------- convenience ctors
    @classmethod
    def replica_kill(cls, t: float, replica: int, shard: int = 0,
                     revive_t: Optional[float] = None) -> "FaultSchedule":
        evs = [FaultEvent(t, "kill", shard=shard, replica=replica)]
        if revive_t is not None:
            evs.append(FaultEvent(revive_t, "revive", shard=shard,
                                  replica=replica))
        return cls(evs)

    @classmethod
    def replica_slowdown(cls, t: float, replica: int, factor: float,
                         shard: int = 0,
                         until: Optional[float] = None) -> "FaultSchedule":
        evs = [FaultEvent(t, "slow", shard=shard, replica=replica,
                          factor=factor)]
        if until is not None:
            evs.append(FaultEvent(until, "slow", shard=shard,
                                  replica=replica, factor=1.0))
        return cls(evs)

    @classmethod
    def shard_kill(cls, t: float, shard: int,
                   revive_t: Optional[float] = None,
                   permanent: bool = False) -> "FaultSchedule":
        evs = [FaultEvent(t, "shard_kill", shard=shard,
                          permanent=permanent)]
        if revive_t is not None:
            evs.append(FaultEvent(revive_t, "shard_revive", shard=shard))
        return cls(evs)

    def __add__(self, other: "FaultSchedule") -> "FaultSchedule":
        return FaultSchedule(self.events + tuple(other))

    @classmethod
    def random(cls, seed: int, horizon_s: float, n_shards: int = 1,
               n_replicas: int = 4, n_replica_events: int = 3,
               n_shard_events: int = 0,
               max_factor: float = 8.0) -> "FaultSchedule":
        """Seeded chaos generator: ``n_replica_events`` slow/kill events
        on random replicas (each kill paired with a revive half way to
        the horizon) plus ``n_shard_events`` shard kills (each paired
        with a revive).  Same seed => same schedule => bit-identical
        serve, which is what makes chaos tests assertable."""
        rng = np.random.default_rng(seed)
        evs: List[FaultEvent] = []
        for _ in range(n_replica_events):
            t = float(rng.uniform(0.05, 0.75) * horizon_s)
            shard = int(rng.integers(n_shards))
            replica = int(rng.integers(n_replicas))
            if rng.random() < 0.5:
                evs.append(FaultEvent(t, "slow", shard=shard,
                                      replica=replica,
                                      factor=float(rng.uniform(
                                          2.0, max_factor))))
            else:
                evs.append(FaultEvent(t, "kill", shard=shard,
                                      replica=replica))
                evs.append(FaultEvent(
                    t + 0.5 * (horizon_s - t), "revive", shard=shard,
                    replica=replica))
        for _ in range(n_shard_events):
            t = float(rng.uniform(0.05, 0.6) * horizon_s)
            shard = int(rng.integers(n_shards))
            evs.append(FaultEvent(t, "shard_kill", shard=shard))
            evs.append(FaultEvent(t + 0.5 * (horizon_s - t),
                                  "shard_revive", shard=shard))
        return cls(evs)


class ShardFaultCursor:
    """Stateful fold of a schedule's shard-level events over the epoch
    loop, one instance per ``serve`` call (so repeated serves replay
    identically).

    ``begin_epoch(h, ws, we)`` is called once per (epoch, shard) in
    epoch order: it first consumes every event with ``t <= ws`` (the
    boundary fold — this is where revives and watchdog restarts take
    effect), then *peeks* for the first mid-window kill without
    consuming it, so the next boundary fold still sees the kill and can
    reconcile it against any restart the watchdog issued in between.
    Returns the virtual time the shard goes (or already is) down within
    the window, or ``None`` if it is up throughout.

    Kills are immediate; recovery is boundary-quantized (see the module
    docstring for why that keeps seq floors exact).
    """

    def __init__(self, schedule: FaultSchedule, n_shards: int):
        self._events: Dict[int, List[FaultEvent]] = {
            h: schedule.shard_events(h) for h in range(n_shards)}
        self._ptr = {h: 0 for h in range(n_shards)}
        self._down_since: Dict[int, Optional[float]] = {
            h: None for h in range(n_shards)}
        self._permanent = {h: False for h in range(n_shards)}
        self._restarts: Dict[int, List[float]] = {
            h: [] for h in range(n_shards)}

    def begin_epoch(self, h: int, window_start: float,
                    window_end: float) -> Optional[float]:
        evs, p = self._events[h], self._ptr[h]
        while p < len(evs) and evs[p].t <= window_start:
            e = evs[p]
            if e.kind == "shard_kill":
                if e.permanent:
                    self._down_since[h] = e.t
                    self._permanent[h] = True
                elif not any(r >= e.t for r in self._restarts[h]):
                    # no watchdog restart repaired this kill yet
                    self._down_since[h] = e.t
            else:                            # shard_revive
                if not self._permanent[h]:
                    self._down_since[h] = None
            p += 1
        self._ptr[h] = p
        if self._down_since[h] is not None:
            return self._down_since[h]       # down entering the window
        for e in evs[p:]:                    # peek, do not consume
            if e.t >= window_end:
                break
            if e.kind == "shard_kill":
                self._down_since[h] = e.t
                self._permanent[h] = self._permanent[h] or e.permanent
                return e.t
            # a mid-window revive is deferred to the next boundary fold
        return None

    def is_down(self, h: int) -> bool:
        return self._down_since[h] is not None

    def restart(self, h: int, t_boundary: float) -> bool:
        """Watchdog repair at an epoch boundary.  Returns ``False`` when
        the shard's kill was permanent (the restart is refused and the
        shard stays down — evacuation must carry the recovery)."""
        self._restarts[h].append(t_boundary)
        if self._permanent[h]:
            return False
        self._down_since[h] = None
        return True
