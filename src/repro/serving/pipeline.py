"""Composable per-tick stage pipeline: ONE implementation of the
serving data plane shared by every engine.

The per-tick chain — detect -> decode -> NMS -> [ROI second pass] ->
associate -> Kalman — used to be duplicated across
``serving/engine.py`` (``_detect_batch`` / ``_interpolate``),
``serving/runtime.py`` (``_DetectionCore._process_next_batch`` /
``_roi_pass``) and the sharded cores.  This module makes each stage a
function of one typed ``TickState`` pytree, and the engines thin
drivers over it:

* ``TickState``      — the value threaded through the stages: the
  micro-batch ``images``, the decoded/suppressed detections
  (``boxes``/``scores``/``classes``/``valid`` — the detect+NMS stages
  already run as ONE fused jit launch, ``DetectionEngine._infer``),
  the cascade ``model`` that produced them, the lockstep
  ``tracker`` table and the per-detection ``det_tid`` assignment.
* ``roi_second_pass`` — the cascade's hierarchical ROI stage as a pure
  function of ``TickState`` (previously a bespoke ``_roi_pass`` method
  buried in the incremental core).
* ``TickPipeline``   — the tracker tick driver: staged mode launches
  ``trk.step``/``trk.coast`` exactly like the pre-refactor engines
  (bit-identical, and monkeypatch-observable per launch); fused mode
  compiles associate -> Kalman -> output as ONE ``jax.jit`` program
  with the track-table buffers donated, so a serve tick is a single
  launch instead of a kernel chain.
* ``export_track_rows`` / ``build_tracker_state`` — the portable
  track-state contract: the (B, T) table splits into per-stream rows
  keyed by ``stream_id`` and rebuilds with any stream subset/order, so
  track identities survive segment boundaries, ``rebalance_streams``
  migration and watchdog evacuation.
* ``sorted_chunk`` / ``chunk_size`` / ``bucket`` / ``dispatch_time`` —
  the chunking/ordering helpers that were copied between the batch
  engine and the incremental core.

Fusion/donation rules
---------------------
The fused tick program traces the SAME jitted ``trk.step`` and
``trk.output`` the staged chain launches, so the op sequence is
identical and the outputs are bit-identical (validated by
``tests/test_pipeline.py`` / ``benchmarks/tick_bench.py``); only the
launch count changes.  The incoming ``TrackerState`` is donated
(``donate_argnums=(0,)``): callers must thread the returned state and
never reuse the argument.  On backends without donation support
(XLA-CPU) the donation is a no-op — JAX keeps the input buffers valid
and would warn per call; that warning is filtered here.  A tick with an
all-invalid detection row is bit-identical to ``trk.coast`` (every
lifecycle write is masked by match/birth bits an invalid row can never
set), which is what lets fused mode run ONE uniform program every tick.

``fused_window`` takes the fusion one step further where the tick
schedule is known before the tracker runs (the engines' interpolation
replay: micro-batch detection results are all collected first): a
``lax.scan`` of the same tick body turns a K-tick window — 2K launches
staged — into ONE launch, amortizing the whole dispatch chain.  Same
trace, same bits; only the launch count changes.
"""
from __future__ import annotations

import functools
import warnings
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

# donation is best-effort: XLA-CPU cannot honor donated buffers and
# would warn once per fused launch; the program is correct either way
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


# --------------------------------------------------------------- chunking
def sorted_chunk(frames) -> List:
    """Normalize an ingest argument to a list of ``FrameRequest``
    sorted stably by arrival (a single frame passes through as
    ``[frame]``) — the shared front door of every ingest path."""
    from .engine import FrameRequest   # lazy: avoids import cycles
    if isinstance(frames, FrameRequest):
        return [frames]
    return sorted(frames, key=lambda f: f.t_arrival)


def dispatch_time(frames, i: int, replicas) -> float:
    """Virtual 'now' when the micro-batch headed by ``frames[i]``
    forms: the later of the head frame's arrival and the earliest
    replica free-up — the clock every dispatch-point decision (batch
    sizing, cascade model selection, load sampling) is evaluated at."""
    return max(frames[i].t_arrival,
               min(r.busy_until for r in replicas))


def chunk_size(frames, i: int, *, micro_batch: Optional[int],
               max_micro_batch: int, replicas) -> int:
    """Queue depth at dispatch time: how many frames have arrived by
    the moment the earliest replica frees up (at least one — the head
    frame defines 'now' when the pipeline is idle).  A fixed
    ``micro_batch`` short-circuits the adaptive rule."""
    if micro_batch is not None:
        return micro_batch
    t_now = dispatch_time(frames, i, replicas)
    q = 1
    while (i + q < len(frames) and q < max_micro_batch
           and frames[i + q].t_arrival <= t_now):
        q += 1
    return q


def bucket(k: int) -> int:
    """Pad adaptive batches to power-of-two buckets: O(log mb) jit
    traces instead of one per distinct queue depth.

    >>> [bucket(k) for k in (1, 2, 3, 5, 8)]
    [1, 2, 4, 8, 8]
    """
    b = 1
    while b < k:
        b <<= 1
    return b


# -------------------------------------------------------------- TickState
class TickState(NamedTuple):
    """The value threaded through the per-tick stage chain.

    Detection-side fields hold one micro-batch (leading axis = frames
    in the batch); tracker-side fields hold the lockstep table (leading
    axis = streams).  Every stage is a function ``TickState ->
    TickState`` that fills or rewrites the fields it owns and leaves
    the rest untouched, so stages compose in any gated combination:

    * ``images``  — the stacked (padded) micro-batch input frames.
    * ``boxes`` / ``scores`` / ``classes`` / ``valid`` — the decoded,
      NMS-suppressed detections (fixed ``max_out`` rows, ``valid``
      masking the real ones).
    * ``model``   — the cascade model name that produced them (None on
      catalog-less engines); the post-processor hook composes on it.
    * ``tracker`` — the ``tracking.TrackerState`` (B, T) table.
    * ``det_tid`` — per-detection track-id assignment from the last
      associate/Kalman stage ((B, D) int32, -1 for unused rows).
    """
    boxes: Optional[np.ndarray] = None
    scores: Optional[np.ndarray] = None
    classes: Optional[np.ndarray] = None
    valid: Optional[np.ndarray] = None
    images: Optional[np.ndarray] = None
    model: Optional[str] = None
    tracker: Optional[object] = None
    det_tid: Optional[np.ndarray] = None


# ---------------------------------------------------- portable track rows
def export_track_rows(state, sids) -> Dict[int, dict]:
    """Split the (B, T) track table into per-stream portable rows keyed
    by ``stream_id`` (batch row ``b`` belongs to ``sids[b]``).  Rows
    are plain numpy dicts — serializable, shard-agnostic — and round
    trip bit-identically through ``build_tracker_state``."""
    from ..tracking import export_rows    # lazy: avoids import cycles
    rows = export_rows(state)
    return {s: rows[b] for b, s in enumerate(sids)}


def build_tracker_state(rows0: Optional[Dict[int, dict]], sids, cfg):
    """Tracker table for streams ``sids`` (batch row ``b`` =
    ``sids[b]``), seeding each stream from its carried row in ``rows0``
    when present and a fresh row otherwise.  With no carried rows the
    result is bit-identical to ``tracking.init_state`` — the
    pre-portability behavior."""
    from ..tracking import init_state, rows_to_state
    if not rows0:
        return init_state(len(sids), cfg)
    return rows_to_state([rows0.get(s) for s in sids], cfg)


def confirmed_ids(row: dict, cfg) -> List[int]:
    """Sorted ids of the confirmed, alive tracks in one portable row —
    the identity set the continuity audit compares across an
    export/import (migration) boundary."""
    m = np.asarray(row["active"]) & (np.asarray(row["hits"])
                                     >= cfg.min_hits)
    return sorted(int(t) for t in np.asarray(row["track_id"])[m])


# ------------------------------------------------------- fused tick program
@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas"),
                   donate_argnums=(0,))
def _fused_tick(state, boxes, scores, classes, valid, cfg, use_pallas):
    """ONE launch per tick: associate -> Kalman update/birth -> output,
    with the incoming track table donated.  Traces the same jitted
    ``trk.step`` / ``trk.output`` the staged chain calls (nested jits
    inline), so the op graph — and the bits — match the two-launch
    chain exactly."""
    from .. import tracking as trk       # lazy: avoids import cycles
    state, det_tid = trk.step(state, boxes, scores, classes, valid,
                              cfg, use_pallas)
    return state, det_tid, trk.output(state, cfg)


def make_fused_tick(cfg, use_pallas: bool = False):
    """The one-jit tick program as a plain callable
    ``(state, boxes, scores, classes, valid) -> (state, det_tid,
    (boxes, scores, classes, track_ids, emit))`` with ``cfg`` /
    ``use_pallas`` closed over (compiled once per (B, D) shape).  The
    input ``state`` is donated — thread the returned one."""
    return lambda state, b, s, c, v: _fused_tick(state, b, s, c, v,
                                                 cfg, use_pallas)


@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas"),
                   donate_argnums=(0,))
def _fused_window(state, boxes, scores, classes, valid, cfg, use_pallas):
    """ONE launch per K-tick WINDOW: ``lax.scan`` of the fused tick
    body over stacked detection rows (leading axis = ticks).  The
    interpolation replay knows every tick's detections before the
    tracker runs (micro-batch results are collected first), so the
    whole dispatch chain — 2K launches staged, K fused — collapses to
    a single program.  The scan body is the same ``trk.step`` /
    ``trk.output`` trace as ``_fused_tick``, so the stacked outputs
    and the final table are bit-identical to the per-tick chain;
    detection-free ticks ride along as all-invalid rows."""
    from .. import tracking as trk       # lazy: avoids import cycles

    def body(s, tick):
        b, sc, c, v = tick
        s, det_tid = trk.step(s, b, sc, c, v, cfg, use_pallas)
        return s, (det_tid, trk.output(s, cfg))

    state, (det_tid, out) = jax.lax.scan(
        body, state, (boxes, scores, classes, valid))
    return state, det_tid, out


def fused_window(state, boxes, scores, classes, valid, cfg,
                 use_pallas: bool = False):
    """Run a K-tick window as ONE launch.  ``boxes`` (K, B, D, 4),
    ``scores``/``classes``/``valid`` (K, B, D) are the window's stacked
    detection rows (all-invalid rows for detection-free ticks); returns
    ``(state, det_tid (K, B, D), out)`` with every output stacked along
    the tick axis.  The input ``state`` is donated — thread the
    returned one.  Compiled once per (K, B, D) shape: callers with
    variable-length windows should bucket K."""
    return _fused_window(state, jnp.asarray(boxes), jnp.asarray(scores),
                         jnp.asarray(classes), jnp.asarray(valid),
                         cfg, use_pallas)


class TickPipeline:
    """Driver for the tracker end of the tick chain.

    ``fused=False`` (the default) launches the staged chain —
    ``trk.step`` / ``trk.coast`` per tick, ``trk.output`` on demand —
    through the ``tracking`` module attributes, exactly like the
    pre-refactor engines (the launch spies in ``benchmarks/nvr_bench``
    keep working).  ``fused=True`` runs the one-jit donated-buffer
    program every tick, detections or not (an all-invalid row is
    bit-identical to coasting), and returns the tick's outputs for
    free.  ``launches`` counts tracker launches either way — one per
    tick."""

    def __init__(self, cfg, *, use_pallas: bool = False,
                 fused: bool = False):
        self.cfg = cfg
        self.use_pallas = use_pallas
        self.fused = fused
        self.launches = 0

    def seed(self, sids, rows0: Optional[Dict[int, dict]] = None):
        """Initial table for streams ``sids``: carried rows when given,
        fresh (== ``init_state``, bit-identical) otherwise."""
        return build_tracker_state(rows0, sids, self.cfg)

    def tick(self, state, boxes, scores, classes, valid):
        """One detection tick.  Returns ``(state, det_tid, out)`` where
        ``out`` is the tick's confirmed-track output tuple in fused
        mode and None in staged mode (ask ``output`` lazily)."""
        from .. import tracking as trk   # module attr: spy-patchable
        self.launches += 1
        args = (jnp.asarray(boxes), jnp.asarray(scores),
                jnp.asarray(classes), jnp.asarray(valid))
        if self.fused:
            state, det_tid, out = _fused_tick(
                state, *args, self.cfg, self.use_pallas)
            return state, np.asarray(det_tid), out
        state, det_tid = trk.step(state, *args, self.cfg,
                                  self.use_pallas)
        return state, np.asarray(det_tid), None

    def coast(self, state, det_width: int = 1):
        """One detection-free tick.  Staged mode launches
        ``trk.coast``; fused mode feeds the one program an all-invalid
        (B, det_width) row — bit-identical state, uniform launch —
        and returns the output tuple.  ``det_width`` should match the
        segment's detection width so ONE compiled program covers every
        tick."""
        from .. import tracking as trk   # module attr: spy-patchable
        self.launches += 1
        if self.fused:
            B = state.active.shape[0]
            D = det_width
            state, _, out = _fused_tick(
                state, jnp.zeros((B, D, 4), jnp.float32),
                jnp.zeros((B, D), jnp.float32),
                jnp.zeros((B, D), jnp.int32),
                jnp.zeros((B, D), bool), self.cfg, self.use_pallas)
            return state, out
        return trk.coast(state, self.cfg), None

    def output(self, state):
        """Confirmed-track output of the current table (staged mode's
        lazy path — fused mode already returned it from the tick)."""
        from .. import tracking as trk
        return trk.output(state, self.cfg)

    def export(self, state, sids) -> Dict[int, dict]:
        """Portable per-stream rows of the final table (see
        ``export_track_rows``)."""
        return export_track_rows(state, sids)


# ------------------------------------------------------------- ROI stage
def roi_second_pass(eng, tick: TickState, kept, pad_b: int, rec):
    """Hierarchical second pass over one micro-batch as a pipeline
    stage: the selected light model's detections (``tick.boxes``...)
    become ROI windows (top ``roi_max`` by score, padded, clamped),
    the heavy model answers only inside them, and its detections —
    clipped to their covering window — REPLACE the first pass's fields
    in the returned ``TickState``.  Also returns the fraction of
    full-frame pixels the second pass read, its measured wall seconds,
    and the pixel tallies ``{"full", "roi", "passes"}`` for the
    caller's accounting (the stage itself mutates nothing).

    The crop always runs through the ``kernels.roi`` pair (Pallas /
    XLA twin per the engine's ``use_pallas``), so the serving hot
    path exercises the kernel tier; with a built-in SSD the crops
    are detected directly, with a cascade oracle the ROI windows
    are forwarded for the oracle's containment filter."""
    import time as _time
    from ..kernels import ops as _kops
    from .cascade import roi_pixels, rois_from_boxes
    images = tick.images
    boxes, scores = tick.boxes, tick.scores
    classes, valid = tick.classes, tick.valid
    heavy = eng.cascade.heaviest
    n = len(kept)
    R = eng.roi_max
    if eng.roi_bounds is not None:
        W, H = eng.roi_bounds
    else:
        W, H = images.shape[2], images.shape[1]
    rois = np.zeros((n, R, 4), np.float32)
    n_rois = np.zeros(n, np.int64)
    px = np.zeros(n)
    for j in range(n):
        rois[j], n_rois[j] = rois_from_boxes(
            boxes[j], scores[j], valid[j], bounds=(W, H),
            roi_max=R, pad=eng.roi_pad)
        px[j] = roi_pixels(rois[j], int(n_rois[j]), (W, H))
    px_full = float(n) * W * H
    px_roi = float(px.sum())
    t0 = _time.perf_counter()
    C = eng.roi_crop or images.shape[1]
    norm = rois / np.array([W, H, W, H], np.float32)
    crops = _kops.crop_resize(images[:n], norm, out_size=C,
                              use_pallas=eng._use_pallas)
    if eng._detect_fn is not None:
        roi_arg = {f.rid: rois[j][:n_rois[j]]
                   for j, f in enumerate(kept)}
        out2, _ = eng._detect_batch(
            images, rids=[f.rid for f in kept] + [-1] * (pad_b - n),
            model=heavy, rois=roi_arg)
        boxes, scores, classes, valid = out2
    else:
        # built-in SSD: detect the crop tiles, map boxes back into
        # the parent frame, keep the top detections per frame
        flat = np.asarray(crops).reshape((n * R,) + crops.shape[2:])
        bb = bucket(n * R)
        if len(flat) < bb:
            flat = np.concatenate(
                [flat, np.zeros((bb - len(flat),) + flat.shape[1:],
                                flat.dtype)], 0)
        out2, _ = eng._detect_batch(flat)
        cb, cs, cc, cv = out2
        M = cb.shape[1]
        cb = np.asarray(_kops.uncrop_boxes(
            cb[:n * R].reshape(n, R, M, 4), norm[:, :, None, :],
            bounds=(W, H), crop_size=C,
            use_pallas=eng._use_pallas))
        cs = cs[:n * R].reshape(n, R, M)
        cc = cc[:n * R].reshape(n, R, M)
        cv = (cv[:n * R].reshape(n, R, M)
              & (np.arange(R)[None, :, None] < n_rois[:, None, None]))
        K = boxes.shape[1]
        # jitted outputs can be read-only views — replace in copies
        boxes, scores = boxes.copy(), scores.copy()
        classes, valid = classes.copy(), valid.copy()
        for j in range(n):
            fb = cb[j].reshape(-1, 4)
            fs = np.where(cv[j].reshape(-1), cs[j].reshape(-1),
                          -np.inf)
            top = np.argsort(-fs, kind="stable")[:K]
            keep = top[np.isfinite(fs[top])]
            boxes[j] = 0.0
            scores[j] = 0.0
            classes[j] = 0
            valid[j] = False
            boxes[j, :len(keep)] = fb[keep]
            scores[j, :len(keep)] = fs[keep]
            classes[j, :len(keep)] = cc[j].reshape(-1)[keep]
            valid[j, :len(keep)] = True
    roi_wall = _time.perf_counter() - t0
    if rec.enabled:
        for j, f in enumerate(kept):
            v = np.asarray(valid[j], bool)
            fb = np.asarray(boxes[j])[v]
            ext = ([float(fb[:, 0].min()), float(fb[:, 1].min()),
                    float(fb[:, 2].max()), float(fb[:, 3].max())]
                   if len(fb) else None)
            rec.record(
                "roi_pass", f.t_arrival, rid=f.rid,
                stream=f.stream_id, model=heavy,
                n_rois=int(n_rois[j]), px_full=float(W) * float(H),
                px_roi=float(px[j]),
                rois=[[float(x) for x in row]
                      for row in rois[j][:n_rois[j]]],
                bounds=[float(W), float(H)], det_extent=ext)
        # the stage EVENT carries only virtual-clock-deterministic
        # fields (trace bit-determinism contract); the measured wall ms
        # goes to the sampled series, exported as a Perfetto counter
        rec.record("stage", kept[0].t_arrival, stage="roi", frames=n)
        rec.sample("stage_ms_roi", kept[0].t_arrival, roi_wall * 1e3)
    new_tick = tick._replace(boxes=boxes, scores=scores,
                             classes=classes, valid=valid, model=heavy)
    return new_tick, (px_roi / px_full if px_full else 0.0), roi_wall, \
        {"full": px_full, "roi": px_roi, "passes": n}
