"""Mesh-runtime serving engine: the paper's multi-model parallelism as a
first-class feature of an LLM/encoder serving stack.

The paper's "n detection models on n accelerator sticks" becomes n model
replicas (replica groups of the mesh; on this CPU host, n logical replicas
sharing the device).  Requests stream in, the paper's schedulers (FCFS /
RR / weighted / proportional) pick a replica, real jitted prefill+decode
runs, measured wall times drive the same virtual timeline as the edge
simulator, and the sequence synchronizer returns responses in arrival
order.  One engine, two payload kinds: token requests (LLM serving) and
video frames (detection serving).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.scheduler import make_scheduler
from ..models import init_model
from ..models.config import ModelConfig
from ..runtime.steps import make_decode_step, make_prefill_step


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (prompt_len,)
    max_new_tokens: int = 8
    t_arrival: float = 0.0


@dataclass
class FrameRequest:
    rid: int
    image: np.ndarray             # (S, S, 3) float32
    t_arrival: float = 0.0


@dataclass
class DetectionResponse:
    rid: int
    boxes: np.ndarray             # (max_out, 4)
    scores: np.ndarray            # (max_out,)
    classes: np.ndarray           # (max_out,)
    valid: np.ndarray             # (max_out,) bool
    replica: int
    t_start: float
    t_done: float
    service_s: float


@dataclass
class Response:
    rid: int
    tokens: np.ndarray            # generated ids
    replica: int
    t_start: float
    t_done: float
    service_s: float


class ReplicaExecutor:
    """Scheduler-compatible executor backed by a real jitted model call."""

    def __init__(self, idx: int, speed: float = 1.0):
        self.idx = idx
        self.speed = speed            # heterogeneity: service multiplier
        self.busy_until = 0.0
        self.n_processed = 0
        self.ewma_service = None
        self._last_wall = 0.1

    @property
    def mu_effective(self) -> float:
        t = self.ewma_service or self._last_wall * self.speed
        return 1.0 / max(t, 1e-6)

    def service_time(self, frame=None) -> float:
        return self._last_wall * self.speed

    def record(self, t_service: float):
        self.n_processed += 1
        a = 0.3
        self.ewma_service = (t_service if self.ewma_service is None
                             else (1 - a) * self.ewma_service + a * t_service)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params=None, n_replicas: int = 4,
                 scheduler: str = "fcfs", cache_len: int = 128,
                 replica_speeds: Optional[Sequence[float]] = None,
                 drop_when_busy: bool = False, seed: int = 0):
        self.cfg = cfg
        self.params = params if params is not None else init_model(
            cfg, jax.random.PRNGKey(seed))
        self.cache_len = cache_len
        self.prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
        self.decode = jax.jit(make_decode_step(cfg))
        speeds = list(replica_speeds or [1.0] * n_replicas)
        self.replicas = [ReplicaExecutor(i, s) for i, s in enumerate(speeds)]
        self.scheduler = make_scheduler(scheduler, self.replicas,
                                        host_overhead=1e-4)
        self.drop_when_busy = drop_when_busy
        self._warm = False

    # ------------------------------------------------------------- compute
    def _generate(self, req: Request) -> tuple[np.ndarray, float]:
        t0 = time.perf_counter()
        toks = jnp.asarray(req.tokens, jnp.int32)[None]
        logits, cache = self.prefill(self.params, {"tokens": toks})
        out = []
        pos = toks.shape[1]
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(req.max_new_tokens):
            out.append(int(nxt[0, 0]))
            logits, cache = self.decode(self.params, {
                "tokens": nxt, "cache": cache,
                "decode_pos": jnp.asarray(pos, jnp.int32)})
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            pos += 1
        jax.block_until_ready(logits)
        return np.array(out, np.int32), time.perf_counter() - t0

    def warmup(self, prompt_len: int = 16):
        req = Request(-1, np.zeros(prompt_len, np.int32), 2)
        _, wall = self._generate(req)
        for r in self.replicas:
            r._last_wall = wall
        self._warm = True

    # ------------------------------------------------------------- serving
    def serve(self, requests: Sequence[Request]) -> Dict:
        """Run a batch of requests through the parallel-replica pipeline.
        Returns responses (arrival order), dropped ids, and FPS metrics."""
        if not self._warm:
            self.warmup(max(len(r.tokens) for r in requests))
        responses: List[Response] = []
        dropped: List[int] = []
        for req in sorted(requests, key=lambda r: r.t_arrival):
            gen, wall = self._generate(req)       # real compute, measured
            for r in self.replicas:               # this request would cost
                r._last_wall = wall               # wall x speed on replica r
            if self.drop_when_busy:
                a = self.scheduler.assign(req.rid, req.t_arrival)
                if a is None:
                    dropped.append(req.rid)
                    continue
            else:
                a = self.scheduler.blocking_assign(req.rid, req.t_arrival)
            responses.append(Response(req.rid, gen, a.executor_idx,
                                      a.t_start, a.t_done, wall))
        responses.sort(key=lambda r: r.rid)       # sequence synchronizer
        makespan = max((r.t_done for r in responses), default=0.0)
        return {
            "responses": responses,
            "dropped": dropped,
            "throughput_rps": len(responses) / max(makespan, 1e-9),
            "p50_latency": float(np.median(
                [r.t_done - r.t_start for r in responses])) if responses
            else 0.0,
            "per_replica": {r.idx: r.n_processed for r in self.replicas},
        }


class DetectionEngine:
    """Video-frame payload path: the paper's "n detection models" served
    from the same scheduler/replica machinery as the token path, with
    frames routed through the detector in micro-batches so the whole
    batch is decoded and suppressed by ONE fused batched-NMS launch
    (repro.kernels.nms) instead of a per-frame kernel + serial loop."""

    def __init__(self, cfg=None, params=None, n_replicas: int = 4,
                 scheduler: str = "fcfs", micro_batch: int = 8,
                 replica_speeds: Optional[Sequence[float]] = None,
                 use_pallas: bool = False, score_thr: float = 0.4,
                 iou_thr: float = 0.5, max_out: int = 32, seed: int = 0):
        from ..detector import SSDConfig, decode_detections, init_ssd, \
            make_anchors
        self.cfg = cfg or SSDConfig()
        self.params = params if params is not None else init_ssd(
            self.cfg, jax.random.PRNGKey(seed))
        self.anchors = jnp.asarray(make_anchors(self.cfg))
        self.micro_batch = micro_batch
        self._infer = jax.jit(lambda imgs: decode_detections(
            self.params, self.cfg, imgs, self.anchors, score_thr=score_thr,
            iou_thr=iou_thr, max_out=max_out, use_pallas=use_pallas))
        speeds = list(replica_speeds or [1.0] * n_replicas)
        self.replicas = [ReplicaExecutor(i, s) for i, s in enumerate(speeds)]
        self.scheduler = make_scheduler(scheduler, self.replicas,
                                        host_overhead=1e-4)
        self._warm = False

    def _detect_batch(self, images: np.ndarray):
        """One fused launch for a full micro-batch; returns numpy
        results + measured wall seconds."""
        t0 = time.perf_counter()
        out = self._infer(jnp.asarray(images))
        out = jax.block_until_ready(out)
        return tuple(np.asarray(o) for o in out), time.perf_counter() - t0

    def warmup(self):
        size = self.cfg.image_size
        imgs = np.zeros((self.micro_batch, size, size, 3), np.float32)
        _, wall = self._detect_batch(imgs)
        for r in self.replicas:
            r._last_wall = wall / self.micro_batch
        self._warm = True

    def serve(self, frames: Sequence[FrameRequest]) -> Dict:
        """Micro-batched detection serving: frames are grouped in arrival
        order into micro-batches, each batch runs through the batched
        fast path once, and the per-frame share of the measured wall time
        drives the virtual-clock scheduler."""
        if not self._warm:
            self.warmup()
        frames = sorted(frames, key=lambda f: f.t_arrival)
        responses: List[DetectionResponse] = []
        mb = self.micro_batch
        for lo in range(0, len(frames), mb):
            chunk = frames[lo:lo + mb]
            images = np.stack([f.image for f in chunk])
            if len(chunk) < mb:                   # pad: static jit shapes
                pad = np.zeros((mb - len(chunk),) + images.shape[1:],
                               images.dtype)
                images = np.concatenate([images, pad], 0)
            (boxes, scores, classes, valid), wall = \
                self._detect_batch(images)
            per_frame = wall / len(chunk)
            for r in self.replicas:
                r._last_wall = per_frame
            for i, f in enumerate(chunk):
                a = self.scheduler.blocking_assign(f.rid, f.t_arrival)
                responses.append(DetectionResponse(
                    f.rid, boxes[i], scores[i], classes[i], valid[i],
                    a.executor_idx, a.t_start, a.t_done, per_frame))
        responses.sort(key=lambda r: r.rid)       # sequence synchronizer
        makespan = max((r.t_done for r in responses), default=0.0)
        return {
            "responses": responses,
            "throughput_fps": len(responses) / max(makespan, 1e-9),
            "per_replica": {r.idx: r.n_processed for r in self.replicas},
        }
