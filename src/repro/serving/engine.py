"""Mesh-runtime serving engine: the paper's multi-model parallelism as a
first-class feature of an LLM/encoder serving stack.

The paper's "n detection models on n accelerator sticks" becomes n model
replicas (replica groups of the mesh; on this CPU host, n logical replicas
sharing the device).  Requests stream in, the paper's schedulers (FCFS /
RR / weighted / proportional) pick a replica, real jitted prefill+decode
runs, measured wall times drive the same virtual timeline as the edge
simulator, and the sequence synchronizer returns responses in arrival
order.  One engine, two payload kinds: token requests (LLM serving) and
video frames (detection serving).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.scheduler import make_scheduler
from ..models import init_model
from ..models.config import ModelConfig
from ..runtime.steps import make_decode_step, make_prefill_step


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (prompt_len,)
    max_new_tokens: int = 8
    t_arrival: float = 0.0


@dataclass
class Response:
    rid: int
    tokens: np.ndarray            # generated ids
    replica: int
    t_start: float
    t_done: float
    service_s: float


class ReplicaExecutor:
    """Scheduler-compatible executor backed by a real jitted model call."""

    def __init__(self, idx: int, speed: float = 1.0):
        self.idx = idx
        self.speed = speed            # heterogeneity: service multiplier
        self.busy_until = 0.0
        self.n_processed = 0
        self.ewma_service = None
        self._last_wall = 0.1

    @property
    def mu_effective(self) -> float:
        t = self.ewma_service or self._last_wall * self.speed
        return 1.0 / max(t, 1e-6)

    def service_time(self, frame=None) -> float:
        return self._last_wall * self.speed

    def record(self, t_service: float):
        self.n_processed += 1
        a = 0.3
        self.ewma_service = (t_service if self.ewma_service is None
                             else (1 - a) * self.ewma_service + a * t_service)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params=None, n_replicas: int = 4,
                 scheduler: str = "fcfs", cache_len: int = 128,
                 replica_speeds: Optional[Sequence[float]] = None,
                 drop_when_busy: bool = False, seed: int = 0):
        self.cfg = cfg
        self.params = params if params is not None else init_model(
            cfg, jax.random.PRNGKey(seed))
        self.cache_len = cache_len
        self.prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
        self.decode = jax.jit(make_decode_step(cfg))
        speeds = list(replica_speeds or [1.0] * n_replicas)
        self.replicas = [ReplicaExecutor(i, s) for i, s in enumerate(speeds)]
        self.scheduler = make_scheduler(scheduler, self.replicas,
                                        host_overhead=1e-4)
        self.drop_when_busy = drop_when_busy
        self._warm = False

    # ------------------------------------------------------------- compute
    def _generate(self, req: Request) -> tuple[np.ndarray, float]:
        t0 = time.perf_counter()
        toks = jnp.asarray(req.tokens, jnp.int32)[None]
        logits, cache = self.prefill(self.params, {"tokens": toks})
        out = []
        pos = toks.shape[1]
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(req.max_new_tokens):
            out.append(int(nxt[0, 0]))
            logits, cache = self.decode(self.params, {
                "tokens": nxt, "cache": cache,
                "decode_pos": jnp.asarray(pos, jnp.int32)})
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            pos += 1
        jax.block_until_ready(logits)
        return np.array(out, np.int32), time.perf_counter() - t0

    def warmup(self, prompt_len: int = 16):
        req = Request(-1, np.zeros(prompt_len, np.int32), 2)
        _, wall = self._generate(req)
        for r in self.replicas:
            r._last_wall = wall
        self._warm = True

    # ------------------------------------------------------------- serving
    def serve(self, requests: Sequence[Request]) -> Dict:
        """Run a batch of requests through the parallel-replica pipeline.
        Returns responses (arrival order), dropped ids, and FPS metrics."""
        if not self._warm:
            self.warmup(max(len(r.tokens) for r in requests))
        responses: List[Response] = []
        dropped: List[int] = []
        for req in sorted(requests, key=lambda r: r.t_arrival):
            gen, wall = self._generate(req)       # real compute, measured
            for r in self.replicas:               # this request would cost
                r._last_wall = wall               # wall x speed on replica r
            if self.drop_when_busy:
                a = self.scheduler.assign(req.rid, req.t_arrival)
                if a is None:
                    dropped.append(req.rid)
                    continue
            else:
                a = self.scheduler.blocking_assign(req.rid, req.t_arrival)
            responses.append(Response(req.rid, gen, a.executor_idx,
                                      a.t_start, a.t_done, wall))
        responses.sort(key=lambda r: r.rid)       # sequence synchronizer
        makespan = max((r.t_done for r in responses), default=0.0)
        return {
            "responses": responses,
            "dropped": dropped,
            "throughput_rps": len(responses) / max(makespan, 1e-9),
            "p50_latency": float(np.median(
                [r.t_done - r.t_start for r in responses])) if responses
            else 0.0,
            "per_replica": {r.idx: r.n_processed for r in self.replicas},
        }
