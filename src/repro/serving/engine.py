"""NVR detection serving engines: the paper's multi-model parallelism
as parallel replica executors behind one scheduler.

The paper's "n detection models on n accelerator sticks" becomes n model
replicas (replica groups of the mesh; on this CPU host, n logical replicas
sharing the device).  Frames stream in, the paper's schedulers (FCFS /
RR / weighted / proportional) pick a replica, the real jitted detect+NMS
fast path runs in micro-batches, measured wall times drive the same
virtual timeline as the edge simulator, and the sequence synchronizer
returns responses in arrival order.  ``DetectionEngine`` is the primary
(video-frame) payload path; ``ServingEngine`` carries the same replica
machinery for token (LLM prefill+decode) payloads.  Both engines'
``serve()`` are thin one-shot drivers over the incremental core in
``repro.serving.runtime`` — ``ServingRuntime`` accepts the same trace
frame-by-frame for always-on serving, bit-identical to the batch call.

Multi-camera (NVR) contract
---------------------------
``FrameRequest.stream_id`` tags which camera a frame belongs to
(default 0 — the single-stream case).  ``rid`` stays globally unique
across streams; a frame's position WITHIN its camera's stream (its
per-stream arrival index) is derived by the engine and returned as
``DetectionResponse.seq``.  All cameras share the same replicas,
micro-batches and — under ``track_and_interpolate`` — ONE batched
tracker with batch dim B = number of streams: frames from different
cameras are interleaved into shared micro-batches (one fused detect +
one fused NMS launch covers frames from several cameras), and the
track table advances all streams in lockstep, one launch per tick.
Ordering, drop accounting, coverage and FPS are all reported both
globally (unchanged keys) and per stream (``per_stream`` /
``streams``); per-stream emit clocks guarantee a camera's frames are
released in that camera's arrival order, independent of the other
cameras.  With a single stream the engine's outputs are bit-identical
to the scalar-stream implementation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.scheduler import make_scheduler
from ..models import init_model
from ..models.config import ModelConfig
from ..obs.metrics import detection_latency_keys
from ..obs.trace import NULL_RECORDER
from ..runtime.steps import make_decode_step, make_prefill_step
from .pipeline import TickPipeline, bucket, chunk_size, confirmed_ids


@dataclass
class Request:
    """Token-payload request for ``ServingEngine``: a prompt of
    ``tokens`` (``(prompt_len,)`` int32) arriving at virtual time
    ``t_arrival``, asking for ``max_new_tokens`` of greedy decode.
    ``rid`` is the caller-assigned unique request id that responses
    are matched and ordered by."""
    rid: int
    tokens: np.ndarray            # (prompt_len,)
    max_new_tokens: int = 8
    t_arrival: float = 0.0


@dataclass
class FrameRequest:
    """Video-frame request for ``DetectionEngine``: one camera frame
    (``image``: ``(S, S, 3)`` float32) arriving at virtual time
    ``t_arrival``.

    ``stream_id`` names the camera the frame belongs to (default 0,
    the single-stream case); ``rid`` must stay globally unique ACROSS
    cameras — the engine derives the frame's position within its own
    camera's stream and returns it as ``DetectionResponse.seq``."""
    rid: int
    image: np.ndarray             # (S, S, 3) float32
    t_arrival: float = 0.0
    stream_id: int = 0            # which camera this frame belongs to


@dataclass
class DetectionResponse:
    """Per-frame detection result from ``DetectionEngine.serve``.

    ``boxes``/``scores``/``classes`` are fixed-width ``max_out`` rows
    with ``valid`` masking the real detections.  ``replica`` is the
    executor that processed the frame, or ``-1`` for a frame the
    scheduler dropped and the tracker re-emitted (``interpolated=True``
    — boxes are the tracker's coasted prediction, ``track_ids`` carries
    the persistent track identities).  ``t_start``/``t_done`` are
    virtual-clock processing bounds and ``service_s`` the per-frame
    service share of the micro-batch.  ``stream_id``/``seq`` locate the
    frame in its camera's stream: ``seq`` is the per-stream arrival
    index the per-camera reorder/quality accounting keys on."""
    rid: int
    boxes: np.ndarray             # (max_out, 4)
    scores: np.ndarray            # (max_out,)
    classes: np.ndarray           # (max_out,)
    valid: np.ndarray             # (max_out,) bool
    replica: int                  # -1 for tracker-interpolated frames
    t_start: float
    t_done: float
    service_s: float
    interpolated: bool = False    # True: boxes coasted by the tracker
    track_ids: Optional[np.ndarray] = None
    stream_id: int = 0            # camera this frame belongs to
    seq: int = -1                 # per-stream arrival index of the frame


@dataclass
class Response:
    """Token-payload response from ``ServingEngine.serve``: the greedy
    decode ``tokens`` for request ``rid``, the ``replica`` that served
    it, its virtual-clock ``t_start``/``t_done`` window and the
    measured wall ``service_s``."""
    rid: int
    tokens: np.ndarray            # generated ids
    replica: int
    t_start: float
    t_done: float
    service_s: float


class ReplicaExecutor:
    """Scheduler-compatible executor backed by a real jitted model call."""

    def __init__(self, idx: int, speed: float = 1.0):
        self.idx = idx
        self.speed = speed            # heterogeneity: service multiplier
        self.busy_until = 0.0
        self.n_processed = 0
        self.ewma_service = None
        self._last_wall = 0.1
        self.faults = None            # optional faults.ReplicaFaultView
        # loadable-model catalog (serving.models.ModelCatalog) — attached
        # by the owning engine.  It travels WITH the executor: replica
        # lending moves the object into the borrower's pool, so a guest
        # keeps its home catalog, and a dead replica's catalog leaves the
        # capacity pool with it.
        self.catalog = None

    @property
    def mu_effective(self) -> float:
        # explicit None check: a measured EWMA of exactly 0.0 (zero-cost
        # oracle detectors in tests) is data, not absence of data — the
        # old `ewma or fallback` silently fell back to the wall estimate
        t = (self._last_wall * self.speed if self.ewma_service is None
             else self.ewma_service)
        return 1.0 / max(t, 1e-6)

    def service_time(self, frame=None, t=None) -> float:
        """Virtual service seconds for one frame.  ``t`` is the virtual
        dispatch time the scheduler evaluates the work at; it only
        matters when a fault view is attached — an injected slowdown
        multiplies the base estimate and a dead replica reports
        infinity, which the scheduler's timeout rule turns into a
        suspect + retry (``core.scheduler``)."""
        s = self._last_wall * self.speed
        if self.faults is not None and t is not None:
            if not self.faults.alive(t):
                return float("inf")
            s *= self.faults.factor(t)
        return s

    def record(self, t_service: float):
        self.n_processed += 1
        a = 0.3
        self.ewma_service = (t_service if self.ewma_service is None
                             else (1 - a) * self.ewma_service + a * t_service)

    def reset(self):
        """Clear per-serve virtual-clock state.  ``_last_wall`` (the warm
        service estimate from warmup / the last measured batch) survives,
        so a reset replica starts a new serve exactly like a
        freshly-warmed one."""
        self.busy_until = 0.0
        self.n_processed = 0
        self.ewma_service = None


def _per_replica_counts(replicas, responses) -> Dict[int, int]:
    """Per-CALL placement counts (``replica == -1`` tracker-interpolated
    frames excluded): identical to the executors' cumulative
    ``n_processed`` on a fresh or reset engine, but stays per-call when
    virtual-clock state is carried across calls (the sharded epoch
    loop), so report merges can sum counts without double counting."""
    counts = {r.idx: 0 for r in replicas}
    for resp in responses:
        if resp.replica >= 0:
            counts[resp.replica] += 1
    return counts


class ServingEngine:
    """Token-payload serving: the paper's parallel-replica scheduling
    applied to an LLM decode loop.

    ``n_replicas`` logical replicas share one set of jitted
    prefill/decode programs; each request's REAL measured wall time,
    scaled by the replica's ``replica_speeds`` multiplier
    (heterogeneous pools), drives the same virtual-clock schedulers as
    the edge simulator (``scheduler`` in fcfs/rr/wrr/proportional).
    ``drop_when_busy=True`` reproduces the paper's load shedding: a
    request arriving with every replica busy is dropped instead of
    queued.  ``serve`` returns responses in arrival order plus
    throughput/latency/per-replica accounting."""

    def __init__(self, cfg: ModelConfig, params=None, n_replicas: int = 4,
                 scheduler: str = "fcfs", cache_len: int = 128,
                 replica_speeds: Optional[Sequence[float]] = None,
                 drop_when_busy: bool = False, seed: int = 0,
                 recorder=None):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}: "
                             "an empty replica pool can never serve")
        self.cfg = cfg
        self.params = params if params is not None else init_model(
            cfg, jax.random.PRNGKey(seed))
        self.cache_len = cache_len
        self.prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
        self.decode = jax.jit(make_decode_step(cfg))
        speeds = list(replica_speeds or [1.0] * n_replicas)
        self.replicas = [ReplicaExecutor(i, s) for i, s in enumerate(speeds)]
        self.scheduler = make_scheduler(scheduler, self.replicas,
                                        host_overhead=1e-4)
        # observability (repro.obs): None -> the shared no-op recorder,
        # so the untraced engine stays bit-identical to the pre-tracing
        # one; the scheduler shares the same recorder for dispatch events
        self.recorder = recorder if recorder is not None else NULL_RECORDER
        self.scheduler.recorder = self.recorder
        self.drop_when_busy = drop_when_busy
        self._warm = False

    # ------------------------------------------------------------- compute
    def _generate(self, req: Request) -> tuple[np.ndarray, float]:
        t0 = time.perf_counter()
        toks = jnp.asarray(req.tokens, jnp.int32)[None]
        logits, cache = self.prefill(self.params, {"tokens": toks})
        out = []
        pos = toks.shape[1]
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(req.max_new_tokens):
            out.append(int(nxt[0, 0]))
            logits, cache = self.decode(self.params, {
                "tokens": nxt, "cache": cache,
                "decode_pos": jnp.asarray(pos, jnp.int32)})
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            pos += 1
        jax.block_until_ready(logits)
        return np.array(out, np.int32), time.perf_counter() - t0

    def warmup(self, prompt_len: int = 16):
        req = Request(-1, np.zeros(prompt_len, np.int32), 2)
        _, wall = self._generate(req)
        for r in self.replicas:
            r._last_wall = wall
        self._warm = True

    def reset(self):
        """Clear per-serve virtual-clock state (replica ``busy_until`` /
        processed counts / EWMAs and the scheduler's round bookkeeping)
        so repeated ``serve()`` calls are independent: the second call
        sees idle replicas at t=0, exactly like the first.  Delegates to
        ``ServingRuntime.reset_engines`` — the ONE reset semantic every
        engine shares."""
        from .runtime import ServingRuntime
        ServingRuntime.reset_engines(self)

    # ------------------------------------------------------------- serving
    def serve(self, requests: Sequence[Request]) -> Dict:
        """Run a batch of requests through the parallel-replica pipeline.
        Returns responses (arrival order), dropped ids, and FPS metrics.

        Each call is independent: per-serve virtual-clock state is reset
        on entry, and ``per_replica`` counts THIS call's placements (not
        a lifetime cumulative), so two identical back-to-back calls
        return identical reports.

        Latency keys (same names as ``DetectionEngine.serve``, present
        in the empty-trace early return too): ``p50_latency`` (exact
        median of ``t_done - t_start``), ``p95_latency`` /
        ``p99_latency`` (quantiles of the log-bucketed
        ``latency_hist`` — see ``repro.obs.metrics``)."""
        if not requests:                  # empty report, like DetectionEngine
            empty = detection_latency_keys([])
            return {"responses": [], "dropped": [], "throughput_rps": 0.0,
                    "p50_latency": 0.0, "p95_latency": 0.0,
                    "p99_latency": 0.0, "latency_hist": empty["latency_hist"],
                    "per_replica": {r.idx: 0 for r in self.replicas}}
        if not self._warm:
            self.warmup(max(len(r.tokens) for r in requests))
        self.reset()
        rec = self.recorder
        responses: List[Response] = []
        dropped: List[int] = []
        for req in sorted(requests, key=lambda r: r.t_arrival):
            if rec.enabled:
                rec.record("arrive", req.t_arrival, rid=req.rid,
                           stream=0, seq=req.rid)
            gen, wall = self._generate(req)       # real compute, measured
            for r in self.replicas:               # this request would cost
                r._last_wall = wall               # wall x speed on replica r
            if self.drop_when_busy:
                a = self.scheduler.assign(req.rid, req.t_arrival)
                if a is None:
                    dropped.append(req.rid)
                    if rec.enabled:
                        rec.record("drop", req.t_arrival, rid=req.rid,
                                   stream=0, seq=req.rid)
                    continue
            else:
                # raises NoHealthyExecutorError when nothing can ever
                # take the request (fail fast, never spin); returns None
                # only when a fault kills the bounded retry chain
                a = self.scheduler.blocking_assign(req.rid, req.t_arrival)
                if a is None:
                    dropped.append(req.rid)
                    if rec.enabled:
                        rec.record("drop", req.t_arrival, rid=req.rid,
                                   stream=0, seq=req.rid)
                    continue
            responses.append(Response(req.rid, gen, a.executor_idx,
                                      a.t_start, a.t_done, wall))
        responses.sort(key=lambda r: r.rid)       # sequence synchronizer
        if rec.enabled:
            clk = 0.0                   # rid-order release clock (one lane)
            for r in responses:
                clk = max(clk, r.t_done)
                rec.record("emit", clk, rid=r.rid, stream=0, seq=r.rid)
        makespan = max((r.t_done for r in responses), default=0.0)
        lk = detection_latency_keys(responses)
        return {
            "responses": responses,
            "dropped": dropped,
            "throughput_rps": len(responses) / max(makespan, 1e-9),
            "p50_latency": lk["p50_latency"],
            "p95_latency": lk["p95_latency"],
            "p99_latency": lk["p99_latency"],
            "latency_hist": lk["latency_hist"],
            "per_replica": _per_replica_counts(self.replicas, responses),
        }


class DetectionEngine:
    """Video-frame payload path: the paper's "n detection models" served
    from the same scheduler/replica machinery as the token path, with
    frames routed through the detector in micro-batches so the whole
    batch is decoded and suppressed by ONE fused batched-NMS launch
    (repro.kernels.nms) instead of a per-frame kernel + serial loop.

    * ``micro_batch=None`` (the default) sizes each micro-batch by the
      queue depth at dispatch time — the frames that arrived while the
      replicas were busy — capped at ``max_micro_batch``; an explicit
      int keeps the fixed-size behaviour.
    * ``drop_when_busy=True`` reproduces the paper's frame dropping on
      this path: a frame arriving with every replica slot taken gets no
      detection.
    * ``track_and_interpolate=True`` closes that gap with the batched
      tracker (``repro.tracking``): dropped frames are emitted in
      arrival order with tracker-coasted boxes, tagged
      ``interpolated`` — the sequence synchronizer's stale-reuse fill
      upgraded to motion-compensated prediction.
    * ``detect_fn`` swaps the mini-SSD for any ``(images, rids) ->
      (boxes, scores, classes, valid)`` callable (oracle detectors in
      tests/benchmarks); ``service_time`` pins the virtual per-frame
      service time so paced runs are deterministic.
    * Multi-camera (NVR): tag requests with ``stream_id`` and the SAME
      engine multiplexes every camera onto the shared replicas —
      interleaved micro-batches, one batched tracker with B = number
      of streams stepping all cameras in lockstep, and per-stream
      coverage/FPS/drop accounting in the report (``per_stream``,
      ``streams``).  B=1 results are bit-identical to the
      single-stream engine.
    * ``faults=`` takes a ``serving.faults.FaultSchedule`` of
      virtual-time replica slowdowns/deaths/revivals (``fault_shard``
      picks which shard's events apply — 0 standalone).  The scheduler
      detects failures by timeout (``timeout_k`` x expected service),
      retries the in-flight frame up to ``max_retries`` times on a
      healthy replica, and the report's ``retries`` / ``failovers`` /
      ``frames_lost`` keys count the outcomes per replica.  An empty
      schedule (or ``None``) leaves every path bit-identical to the
      pre-fault engine.
    * ``catalog=`` gives every replica a ``serving.models.ModelCatalog``
      of loadable model profiles and turns on per-micro-batch model
      selection (``serving.cascade.ModelSelector``): the heaviest model
      whose pooled ``mu`` sustains the arrival-rate estimate, degrade
      under backlog pressure, hysteretic upgrade when slack returns.
      ``roi=True`` additionally runs the hierarchical second pass
      whenever a lighter model was selected: the first pass's boxes
      become ROI windows (``roi_max`` top-scored, padded ``roi_pad``,
      clamped to ``roi_bounds``) batched through the heavy model, with
      per-frame pixel-reduction accounting.  A single-entry catalog
      never switches and never triggers ROI — bit-identical to pinning
      ``service_time`` to that profile.  Reports gain ``models`` /
      ``model_of_frame`` / ``model_map_est`` / ``model_switches`` /
      ``map_estimate`` / ``roi_pixels`` / ``roi_pixel_reduction``
      (present, empty, without a catalog).
    * Tick pipeline (``serving.pipeline``): the per-tick data plane —
      detect -> decode -> NMS -> [ROI second pass] -> associate ->
      Kalman — is composed from shared stages over a ``TickState``
      pytree.  ``fused_tick=True`` runs the tracker tick as ONE jitted
      program with donated track-table buffers (bit-identical to the
      staged chain); ``post_process=`` installs a pure ``TickState ->
      TickState`` stage between NMS/ROI and the tracker (composes with
      cascade model selection — the state carries the batch's model);
      ``carry_tracks=False`` opts out of seeding the tracker from
      carried portable rows (``serve(stream_tracks=...)``), restoring
      the re-seed-per-segment behaviour.
    """

    def __init__(self, cfg=None, params=None, n_replicas: int = 4,
                 scheduler: str = "fcfs", micro_batch: Optional[int] = None,
                 max_micro_batch: int = 8,
                 replica_speeds: Optional[Sequence[float]] = None,
                 use_pallas: bool = False, score_thr: float = 0.4,
                 iou_thr: float = 0.5, max_out: int = 32, seed: int = 0,
                 drop_when_busy: bool = False,
                 track_and_interpolate: bool = False,
                 tracker_cfg=None, detect_fn=None,
                 service_time: Optional[float] = None,
                 faults=None, fault_shard: int = 0,
                 timeout_k: float = 4.0, max_retries: int = 1,
                 recorder=None, catalog=None, selector_kw=None,
                 roi: bool = False, roi_bounds=None, roi_max: int = 4,
                 roi_pad: float = 0.1, roi_crop: Optional[int] = None,
                 fused_tick: bool = False, post_process=None,
                 carry_tracks: bool = True):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}: "
                             "an empty replica pool can never serve")
        self.micro_batch = micro_batch
        self.max_micro_batch = micro_batch or max_micro_batch
        self.drop_when_busy = drop_when_busy or track_and_interpolate
        self.track_and_interpolate = track_and_interpolate
        self.service_time = service_time
        self._detect_fn = detect_fn
        if track_and_interpolate:
            from ..tracking import TrackerConfig   # lazy: avoids cycles
            self.tracker_cfg = tracker_cfg or TrackerConfig()
        if detect_fn is None:
            from ..detector import SSDConfig, decode_detections, \
                init_ssd, make_anchors
            self.cfg = cfg or SSDConfig()
            self.params = params if params is not None else init_ssd(
                self.cfg, jax.random.PRNGKey(seed))
            self.anchors = jnp.asarray(make_anchors(self.cfg))
            self._infer = jax.jit(lambda imgs: decode_detections(
                self.params, self.cfg, imgs, self.anchors,
                score_thr=score_thr, iou_thr=iou_thr, max_out=max_out,
                use_pallas=use_pallas))
        else:
            self.cfg = cfg
        speeds = list(replica_speeds or [1.0] * n_replicas)
        self.replicas = [ReplicaExecutor(i, s) for i, s in enumerate(speeds)]
        # fault injection: an EMPTY schedule normalizes to None, so the
        # no-fault path attaches no views and stays bit-identical to the
        # pre-fault engine (the no_fault_bit_identical regression bar)
        self.faults = faults if faults else None
        if self.faults is not None:
            for r in self.replicas:
                r.faults = self.faults.view(fault_shard, r.idx)
        self.scheduler = make_scheduler(scheduler, self.replicas,
                                        host_overhead=1e-4,
                                        timeout_k=timeout_k,
                                        max_retries=max_retries)
        # observability (repro.obs): None -> the shared no-op recorder —
        # the disabled path skips every event and stays bit-identical.
        # The sharded engine passes each shard a recorder.shard_view(h)
        # so this engine's events carry their failure domain.
        self.recorder = recorder if recorder is not None else NULL_RECORDER
        self.scheduler.recorder = self.recorder
        # transprecise cascade (serving.models / serving.cascade): a
        # missing or empty catalog normalizes to None and leaves every
        # existing path untouched.  The selector lives on the ENGINE so
        # scheduler health probes / pool resizes never reset its
        # hysteresis state; each replica carries the catalog object so
        # lending and deaths move per-model capacity with the executor.
        from .models import as_catalog
        self.catalog = as_catalog(catalog)
        self.cascade = None
        if self.catalog is not None:
            from .cascade import ModelSelector
            self.cascade = ModelSelector(self.catalog,
                                         **(selector_kw or {}))
        for r in self.replicas:
            r.catalog = self.catalog
        self.roi = bool(roi)
        self.roi_bounds = tuple(roi_bounds) if roi_bounds is not None else None
        self.roi_max = roi_max
        self.roi_pad = roi_pad
        self.roi_crop = roi_crop
        # tick-pipeline knobs (serving.pipeline): ``fused_tick`` runs
        # the tracker tick as ONE jitted program with donated
        # track-table buffers (bit-identical to the staged chain);
        # ``post_process`` is a pure ``TickState -> TickState`` stage
        # applied after detect/NMS/ROI, before responses and the
        # tracker (None = identity, bit-identical); ``carry_tracks``
        # seeds each segment's tracker from the previous segment's
        # exported rows so identities survive epoch boundaries and
        # stream migration (False restores the old re-seed behavior).
        self.fused_tick = bool(fused_tick)
        self.post_process = post_process
        self.carry_tracks = bool(carry_tracks)
        self._exported_tracks: Dict[int, dict] = {}
        self._use_pallas = use_pallas
        # capability probe: does a custom detect_fn accept the cascade's
        # model= / rois= keywords?  A plain oracle keeps its exact
        # 2-argument call, so the no-catalog path is bit-identical.
        self._fn_takes_model = self._fn_takes_rois = False
        if detect_fn is not None:
            try:
                import inspect
                ps = inspect.signature(detect_fn).parameters
                self._fn_takes_model = "model" in ps
                self._fn_takes_rois = "rois" in ps
            except (TypeError, ValueError):
                pass
        self._warm = False

    def _detect_batch(self, images: np.ndarray, rids=None, model=None,
                      rois=None):
        """One fused launch for a full micro-batch; returns numpy
        results + measured wall seconds.  ``model``/``rois`` are the
        cascade hooks, forwarded only to detect_fns that declare them."""
        t0 = time.perf_counter()
        if self._detect_fn is not None:
            kw = {}
            if model is not None and self._fn_takes_model:
                kw["model"] = model
            if rois is not None and self._fn_takes_rois:
                kw["rois"] = rois
            out = self._detect_fn(images, rids, **kw)
        else:
            out = jax.block_until_ready(self._infer(jnp.asarray(images)))
        return tuple(np.asarray(o) for o in out), time.perf_counter() - t0

    def _model_caps(self) -> Dict[str, float]:
        """Summed healthy-pool service rate (frames/s) per model name —
        the feasibility signal ``ModelSelector.decide`` consumes.  Each
        replica contributes from ITS OWN catalog (a lent guest carries
        its home catalog; a model a guest cannot load adds nothing), and
        unhealthy replicas contribute nothing at all, so a death
        removes its catalog's capacity the moment the scheduler marks
        it."""
        caps: Dict[str, float] = {}
        for r, ok in zip(self.replicas, self.scheduler.healthy):
            if not ok:
                continue
            cat = r.catalog if r.catalog is not None else self.catalog
            if cat is None:
                continue
            for p in cat:
                caps[p.name] = caps.get(p.name, 0.0) + p.mu / r.speed
        return caps

    def _apply_model(self, model: str, extra_s: float = 0.0):
        """Pin each replica's service estimate to the selected model's
        profile (plus the ROI second-pass surcharge).  Replicas whose
        own catalog pins a different ``service_s`` for the same model
        name use theirs (heterogeneous pools); profiles without
        ``service_s`` leave the measured-wall estimate in charge."""
        for r in self.replicas:
            cat = r.catalog if r.catalog is not None else self.catalog
            prof = cat.get(model) if cat is not None else None
            if prof is not None and prof.service_s is not None:
                r._last_wall = prof.service_s + extra_s

    def warmup(self):
        mb = self.max_micro_batch
        if self._detect_fn is None:
            size = self.cfg.image_size
            imgs = np.zeros((mb, size, size, 3), np.float32)
            _, wall = self._detect_batch(imgs, rids=[-1] * mb)
            per_frame = wall / mb
        else:
            per_frame = 1e-3
        # explicit None check: a pinned ``service_time=0.0`` (zero-cost
        # oracle) must pin the virtual clock to zero, not fall back to
        # the measured wall the way `service_time or wall` did
        if self.service_time is not None:
            per_frame = self.service_time
        for r in self.replicas:
            r._last_wall = per_frame
        self._warm = True

    def reset(self):
        """Clear per-serve virtual-clock state: replica ``busy_until`` /
        processed counts / EWMAs and the scheduler's round bookkeeping.
        Warm service estimates (``_last_wall``) and compiled programs
        survive, so a reset engine starts the next ``serve`` exactly
        like a freshly-warmed one.  Delegates to
        ``ServingRuntime.reset_engines`` — the ONE reset semantic every
        engine shares."""
        from .runtime import ServingRuntime
        ServingRuntime.reset_engines(self)

    def backlog_snapshot(self, t: float) -> Dict:
        """Virtual-clock load observation at time ``t``, the signal the
        sharded serving layer's work-stealing policy consumes:
        ``busy_until`` per replica, ``backlog_s`` (summed committed
        service extending past ``t`` — ``scheduler.backlog``) and
        ``horizon_s`` (how far the busiest replica's commitment reaches
        beyond ``t``).  Pure observation: reading it never perturbs the
        clock."""
        busy = [r.busy_until for r in self.replicas]
        return {"t": t,
                "busy_until": busy,
                "horizon_s": max(max(busy, default=0.0) - t, 0.0),
                "backlog_s": self.scheduler.backlog(t)}

    def _chunk_size(self, frames, i: int) -> int:
        """Queue depth at dispatch time: how many frames have arrived by
        the moment the earliest replica frees up (at least one — the
        head frame defines 'now' when the pipeline is idle).  Shared
        implementation: ``pipeline.chunk_size``."""
        return chunk_size(frames, i, micro_batch=self.micro_batch,
                          max_micro_batch=self.max_micro_batch,
                          replicas=self.replicas)

    @staticmethod
    def _bucket(k: int) -> int:
        """Pad adaptive batches to power-of-two buckets: O(log mb) jit
        traces instead of one per distinct queue depth.  Shared
        implementation: ``pipeline.bucket``.

        >>> [DetectionEngine._bucket(k) for k in (1, 2, 3, 5, 8)]
        [1, 2, 4, 8, 8]
        """
        return bucket(k)

    def serve(self, frames: Sequence[FrameRequest], *, reset: bool = True,
              stream_seq0: Optional[Dict[int, int]] = None,
              stream_emit0: Optional[Dict[int, float]] = None,
              stream_tracks: Optional[Dict[int, dict]] = None) -> Dict:
        """Micro-batched detection serving: frames are grouped in arrival
        order into micro-batches (queue-depth-sized unless a fixed
        ``micro_batch`` was given), each batch runs through the batched
        fast path once, and the per-frame share of the measured wall time
        drives the virtual-clock scheduler.  With ``drop_when_busy``,
        frames arriving into a full pipeline are dropped — and, with
        ``track_and_interpolate``, re-emitted with tracker-predicted
        boxes so the output stream covers every arrival frame.

        Frames from several cameras (distinct ``stream_id``) interleave
        into the SAME micro-batches and replicas; the report carries
        per-stream coverage/FPS/drop accounting next to the global keys
        (see the module docstring for the multi-camera contract).

        Each call is independent by default: per-serve virtual-clock
        state (replica ``busy_until`` / counts / EWMAs, scheduler round
        bookkeeping) is reset on entry and ``per_replica`` counts THIS
        call's placements, so two identical back-to-back calls return
        identical reports.  The keyword-only warm-start hooks exist for
        callers that slice ONE logical trace into several calls (the
        sharded epoch loop):

        * ``reset=False`` carries the virtual clock and scheduler state
          from the previous call instead of clearing them;
        * ``stream_seq0`` maps ``stream_id -> first per-stream arrival
          index of this call`` — its key set is the warm-start stream
          set: every key appears in the report's per-stream maps even
          with zero frames this call, and ``seq`` continues from the
          given floor instead of restarting at 0;
        * ``stream_emit0`` maps ``stream_id -> emit-clock floor``:
          tracker-interpolated frames of that stream are never released
          before it (per-stream emit monotonicity across calls);
        * ``stream_tracks`` maps ``stream_id -> portable track row``
          (``tracking.export_rows``; the engine's own exports land in
          ``_exported_tracks`` after each serve): the lockstep tracker
          seeds those streams from their carried rows instead of fresh
          tables, so track identities survive the call boundary —
          including a ``rebalance_streams`` migration to a different
          shard's engine.  Ignored when ``carry_tracks=False``.

        Report keys: ``responses`` (rid order), ``dropped`` (rids, in
        arrival order), ``coverage`` = responses/frames,
        ``interpolated`` (count of tracker-filled frames),
        ``throughput_fps``, ``per_replica`` (frames per executor, this
        call), ``n_streams``, ``streams`` ({stream_id: responses in
        per-stream ``seq`` order}), ``emit_t`` ({stream_id: monotonic
        release clocks, same length as the stream's responses}),
        ``per_stream`` ({stream_id: frames / dropped / interpolated /
        coverage / throughput_fps}), ``tracker_launches`` /
        ``tracker_ticks`` (lockstep-tracker accounting; 0 unless
        ``track_and_interpolate``), and ``retries`` / ``failovers`` /
        ``frames_lost`` (this call's failure-detection counts, sparse
        per replica — all empty on the fault-free path).

        Latency keys (``repro.obs.metrics``): ``p50_latency`` (exact
        median of detection ``t_done - t_start``), ``p95_latency`` /
        ``p99_latency`` (quantiles of the log-bucketed
        ``latency_hist`` — mergeable: shard merges sum buckets and
        recompute, never average), ``interp_latency`` (re-emission
        delay of tracker-interpolated frames, kept OUT of the
        detection histogram), and ``latency_by_stream`` /
        ``latency_by_replica`` histogram rollups.  With a
        ``recorder=`` attached, the engine additionally records the
        full frame lifecycle (arrive/enqueue/dispatch/complete/drop/
        emit events — see ``repro.obs.trace``) and samples queue depth
        and scheduler backlog at each micro-batch dispatch; the
        default no-op recorder keeps this path bit-identical."""
        from .runtime import ServingRuntime
        rt = ServingRuntime(self, reset=reset, stream_seq0=stream_seq0,
                            stream_emit0=stream_emit0,
                            stream_tracks=stream_tracks)
        rt.ingest(frames)
        return rt.drain()

    def _interpolate(self, frames, responses, seq_of, emit0,
                     tracks0: Optional[Dict[int, dict]] = None,
                     rec=None) -> List[DetectionResponse]:
        """ONE batched tracker over every camera stream, advanced in
        lockstep by the shared tick pipeline (``serving.pipeline``):
        tick k covers each stream's k-th arrival frame, and the whole
        (B, T) track table moves with a single tracker launch per tick
        (the staged ``trk.step``/``trk.coast`` chain by default; the
        one-jit donated-buffer program under ``fused_tick`` —
        bit-identical).  Streams whose tick-k frame was processed feed
        the associate/update/birth path; streams whose frame was
        dropped — or that have no frame left — are passed an
        all-invalid detection row, which is bit-identical to coasting
        (every lifecycle write is masked by match/birth bits that an
        invalid row can never set).  Dropped frames are re-emitted with
        the coasted prediction, tagged ``interpolated``, ready no
        earlier than the newest detection of the SAME stream they
        extrapolate from (per-stream emit clocks: one slow camera never
        delays another's output).

        ``tracks0`` seeds streams from carried portable rows (see
        ``serve``'s ``stream_tracks``); the final table is exported per
        stream into ``self._exported_tracks`` either way.  With a
        ``rec`` attached, seeding records a ``track_import`` per
        carried stream, the export records a ``track_export`` per
        stream (both carrying ``next_id`` + confirmed ``tids`` — the
        identity-continuity audit's evidence), and one ``stage`` timing
        event covers the whole tracker chain."""
        rec = NULL_RECORDER if rec is None else rec
        cfg = self.tracker_cfg
        per: Dict[int, List[FrameRequest]] = {}
        for f in frames:                    # frames sorted by arrival
            per.setdefault(f.stream_id, []).append(f)
        sids = sorted(per)
        row = {s: b for b, s in enumerate(sids)}
        B = len(sids)
        pipe = TickPipeline(cfg, fused=self.fused_tick)
        rows0 = dict(tracks0) if (self.carry_tracks and tracks0) else {}
        state = pipe.seed(sids, rows0)
        if rec.enabled:
            for s in sids:
                r0 = rows0.get(s)
                if r0 is not None:
                    rec.record("track_import", per[s][0].t_arrival,
                               stream=s, next_id=int(r0["next_id"]),
                               tids=confirmed_ids(r0, cfg))
        by_rid = {r.rid: r for r in responses}
        D = responses[0].boxes.shape[0] if responses else 1
        # warm-start emit floor: when this call continues a sliced trace
        # (epoch loop), a stream's interpolated frames are never released
        # before anything the PREVIOUS call already emitted for it
        emit_t = {s: emit0.get(s, 0.0) for s in sids}
        ticks = max(len(v) for v in per.values())
        wall0 = time.perf_counter()
        out: List[DetectionResponse] = []
        for k in range(ticks):
            tick = [(s, per[s][k] if k < len(per[s]) else None)
                    for s in sids]
            resp = {s: by_rid.get(f.rid) if f is not None else None
                    for s, f in tick}
            det_tid = None
            if any(r is not None for r in resp.values()):
                boxes = np.zeros((B, D, 4), np.float32)
                scores = np.zeros((B, D), np.float32)
                classes = np.zeros((B, D), np.int32)
                valid = np.zeros((B, D), bool)
                for s, r in resp.items():
                    if r is not None:
                        b = row[s]
                        boxes[b], scores[b] = r.boxes, r.scores
                        classes[b], valid[b] = r.classes, r.valid
                state, det_tid, fout = pipe.tick(state, boxes, scores,
                                                 classes, valid)
            else:                           # no stream saw a detection
                state, fout = pipe.coast(state, det_width=D)
            # fused mode returns the tick's output for free; the staged
            # chain materializes it lazily, only if a drop needs it
            coasted = (tuple(np.asarray(a) for a in fout)
                       if fout is not None else None)
            for s, f in tick:
                if f is None:
                    continue
                r, b = resp[s], row[s]
                if r is not None:
                    r.track_ids = det_tid[b]
                    emit_t[s] = max(emit_t[s], r.t_done)
                    out.append(r)
                else:
                    if coasted is None:
                        coasted = tuple(np.asarray(a) for a in
                                        pipe.output(state))
                    tb, ts, tc, tid, emit = coasted
                    t_ready = max(emit_t[s], f.t_arrival)
                    out.append(DetectionResponse(
                        f.rid, tb[b], ts[b], tc[b], emit[b], -1, t_ready,
                        t_ready, 0.0, interpolated=True,
                        track_ids=tid[b], stream_id=s, seq=seq_of[f.rid]))
        self._tracker_launches = pipe.launches
        self._tracker_ticks = ticks
        self._exported_tracks = pipe.export(state, sids)
        if rec.enabled:
            for s in sids:
                rowd = self._exported_tracks[s]
                rec.record("track_export", per[s][-1].t_arrival,
                           stream=s, next_id=int(rowd["next_id"]),
                           tids=confirmed_ids(rowd, cfg))
            rec.record("stage", frames[-1].t_arrival, stage="track",
                       launches=pipe.launches, ticks=ticks)
            rec.sample("stage_ms_track", frames[-1].t_arrival,
                       (time.perf_counter() - wall0) * 1e3)
        return out
