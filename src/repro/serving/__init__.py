"""Serving package: token (LLM) and video-frame (detection) payloads on
the same parallel-replica scheduler machinery.

``stream_id`` contract (multi-camera / NVR serving): every
``FrameRequest`` carries a ``stream_id`` naming its camera (default 0);
``rid`` stays globally unique across cameras.  ``DetectionEngine``
interleaves all streams into shared micro-batches and — under
``track_and_interpolate`` — one batched tracker (B = n_streams,
lockstep, one launch per tick), returning per-stream order, coverage,
FPS and drop accounting alongside the unchanged global report keys.
See ``repro.serving.engine`` for the full contract.

Sharded serving (``repro.serving.sharded``): ``ShardedDetectionEngine``
carries the same contract across a device mesh — the camera set is
partitioned over shards (each shard a full ``DetectionEngine`` with its
own lockstep tracker), the batched detect+NMS launch optionally runs as
ONE ``jax.jit`` program spanning the mesh's replica axis
(``make_spmd_detect``), and per-shard reports merge into one global
report (``merge_shard_reports``) that ``core.quality.evaluate_streams``
consumes unchanged.

Fault injection + supervision (``repro.serving.faults`` /
``repro.serving.supervisor``): a ``FaultSchedule`` of virtual-time
replica/shard failure events drives deterministic chaos through the
same serving paths (schedulers detect failures by service timeout and
fail over; the sharded epoch loop loses a killed shard's frames and a
``Watchdog`` restarts it, evacuates its cameras, and lends replicas
along the pressure gradient).  An empty schedule is inert: the
fault-free report is bit-identical to an engine built without one.

Transprecise cascade (``repro.serving.models`` /
``repro.serving.cascade``): a ``ModelCatalog`` of loadable model
profiles (per-model service rate + accuracy proxy, ``paper_catalog``
for the ProxyDetector fast/medium/heavy triple) attached to every
replica, a deterministic virtual-time ``ModelSelector`` that re-picks
the serving model at micro-batch boundaries from backlog + arrival
rate (degrade under pressure, hysteretic upgrade), and a hierarchical
ROI second pass (cheap first-pass boxes -> ``kernels.roi`` crops ->
heavy model).  A single-entry catalog is bit-identical to the plain
engine.

Tick pipeline (``repro.serving.pipeline``): the per-tick data plane —
detect -> decode -> NMS -> [ROI second pass] -> associate -> Kalman —
as composable stages over one typed ``TickState`` pytree, shared by
every engine: the chunking helpers, the ROI second pass as a pure
stage, the portable track-row contract that carries identities across
epoch boundaries and shard migration, and ``TickPipeline`` — the
tracker tick driver whose fused mode runs the whole tick as ONE jitted
program with donated track-table buffers, bit-identical to the staged
chain.

Incremental core (``repro.serving.runtime``): both batch ``serve()``
entry points are thin trace-replay drivers over ``ServingRuntime`` —
an always-on core with ``ingest`` / ``advance`` / ``epoch_boundary`` /
``drain`` that accepts frames in any chunking, serves rolling
per-epoch reports mid-run, and drains to a report bit-identical to the
one-shot batch path.  ``repro.serving.events`` derives a push-side
event pipeline from the same ``obs.TraceRecorder`` log (``EventBus`` /
``TapRecorder`` / ``JsonlSink``); ``repro.launch.daemon`` is the
long-lived entry point driving both from a pluggable clock.
"""
from .cascade import ModelSelector
from .engine import (DetectionEngine, DetectionResponse, FrameRequest,
                     ReplicaExecutor, Request, Response, ServingEngine)
from .events import EventBus, JsonlSink, TapRecorder, topic_of
from .faults import (FaultEvent, FaultSchedule, ReplicaFaultView,
                     ShardFaultCursor)
from .models import (ModelCatalog, ModelProfile, make_cascade_detect_fn,
                     paper_catalog)
from .nvr import make_nvr_streams, make_skewed_streams
from .pipeline import TickPipeline, TickState, roi_second_pass
from .runtime import ServingRuntime
from .sharded import (ShardedDetectionEngine, make_spmd_detect,
                      merge_epoch_shard_reports, merge_shard_reports)
from .supervisor import Watchdog

__all__ = ["DetectionEngine", "DetectionResponse", "EventBus",
           "FaultEvent", "FaultSchedule", "FrameRequest", "JsonlSink",
           "ModelCatalog", "ModelProfile", "ModelSelector",
           "ReplicaFaultView", "Request", "Response", "ReplicaExecutor",
           "ServingEngine", "ServingRuntime", "ShardFaultCursor",
           "ShardedDetectionEngine", "TapRecorder", "TickPipeline",
           "TickState", "Watchdog", "make_cascade_detect_fn",
           "make_nvr_streams", "make_skewed_streams", "make_spmd_detect",
           "merge_epoch_shard_reports", "merge_shard_reports",
           "paper_catalog", "roi_second_pass", "topic_of"]
