from .engine import (DetectionEngine, DetectionResponse, FrameRequest,
                     ReplicaExecutor, Request, Response, ServingEngine)

__all__ = ["DetectionEngine", "DetectionResponse", "FrameRequest",
           "Request", "Response", "ReplicaExecutor", "ServingEngine"]
