"""Serving package: token (LLM) and video-frame (detection) payloads on
the same parallel-replica scheduler machinery.

``stream_id`` contract (multi-camera / NVR serving): every
``FrameRequest`` carries a ``stream_id`` naming its camera (default 0);
``rid`` stays globally unique across cameras.  ``DetectionEngine``
interleaves all streams into shared micro-batches and — under
``track_and_interpolate`` — one batched tracker (B = n_streams,
lockstep, one launch per tick), returning per-stream order, coverage,
FPS and drop accounting alongside the unchanged global report keys.
See ``repro.serving.engine`` for the full contract.
"""
from .engine import (DetectionEngine, DetectionResponse, FrameRequest,
                     ReplicaExecutor, Request, Response, ServingEngine)
from .nvr import make_nvr_streams

__all__ = ["DetectionEngine", "DetectionResponse", "FrameRequest",
           "Request", "Response", "ReplicaExecutor", "ServingEngine",
           "make_nvr_streams"]
