from .engine import Request, Response, ReplicaExecutor, ServingEngine

__all__ = ["Request", "Response", "ReplicaExecutor", "ServingEngine"]
