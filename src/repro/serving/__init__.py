"""Serving package: token (LLM) and video-frame (detection) payloads on
the same parallel-replica scheduler machinery.

``stream_id`` contract (multi-camera / NVR serving): every
``FrameRequest`` carries a ``stream_id`` naming its camera (default 0);
``rid`` stays globally unique across cameras.  ``DetectionEngine``
interleaves all streams into shared micro-batches and — under
``track_and_interpolate`` — one batched tracker (B = n_streams,
lockstep, one launch per tick), returning per-stream order, coverage,
FPS and drop accounting alongside the unchanged global report keys.
See ``repro.serving.engine`` for the full contract.

Sharded serving (``repro.serving.sharded``): ``ShardedDetectionEngine``
carries the same contract across a device mesh — the camera set is
partitioned over shards (each shard a full ``DetectionEngine`` with its
own lockstep tracker), the batched detect+NMS launch optionally runs as
ONE ``jax.jit`` program spanning the mesh's replica axis
(``make_spmd_detect``), and per-shard reports merge into one global
report (``merge_shard_reports``) that ``core.quality.evaluate_streams``
consumes unchanged.
"""
from .engine import (DetectionEngine, DetectionResponse, FrameRequest,
                     ReplicaExecutor, Request, Response, ServingEngine)
from .nvr import make_nvr_streams, make_skewed_streams
from .sharded import (ShardedDetectionEngine, make_spmd_detect,
                      merge_epoch_shard_reports, merge_shard_reports)

__all__ = ["DetectionEngine", "DetectionResponse", "FrameRequest",
           "Request", "Response", "ReplicaExecutor", "ServingEngine",
           "ShardedDetectionEngine", "make_nvr_streams",
           "make_skewed_streams", "make_spmd_detect",
           "merge_epoch_shard_reports", "merge_shard_reports"]
