"""Deterministic virtual-time model selection for cascade serving.

``ModelSelector`` picks the model per micro-batch to maximize expected
quality subject to the incoming-FPS constraint (TOD, arXiv 2105.08668:
pick size/precision from the latency budget).  All inputs are virtual-
clock quantities the scheduler already exposes — the batch formation
time, the batch size, ``scheduler.backlog(t)`` and the per-model
healthy-pool capacities — so selection is a pure function of the trace
and replays bit-identically.

Selection state machine (heaviest-first order over the catalog)::

            rate > cap(cur)            rate > cap(cur)
        ┌────────────────────┐     ┌────────────────────┐
        │                    ▼     │                    ▼
    [heavy]              [medium]              [fast/lightest]
        ▲                    │     ▲                    │
        └────────────────────┘     └────────────────────┘
          hold consecutive slack decisions AND
          cap(next) * headroom >= rate AND backlog small

    plus, from any state: backlog above the degrade bar -> one step
    lighter (early warning before the rate EWMA catches a burst).

* **degrade** is immediate and can jump several tiers at once — the
  moment the arrival-rate estimate exceeds the healthy pool's summed
  ``mu`` for the current model, drop to the heaviest *feasible* model;
* **upgrade** is damped (hysteresis): the next-heavier model must look
  feasible with ``upgrade_headroom`` to spare, the backlog must be
  small, and both must hold for ``hold`` consecutive decisions.  The
  band between ``headroom * cap`` and ``cap`` is sticky in both
  directions, so selection cannot flap on a rate sitting near a
  capacity boundary.

The selector starts at the LIGHTEST model: the first few decisions ramp
up as slack is proven, which keeps cascade drops bounded by the
fast-model baseline even when the trace opens with a burst.

Selector state lives on the ENGINE (``engine.cascade``), not on the
scheduler — ``probe_health`` restores and pool resizes must not reset
hysteresis.

``rois_from_boxes`` is the geometry half of the hierarchical second
pass (SNIPPETS.md §3): the first pass's top-scored boxes, padded and
clamped to the frame, become the ROI windows the heavy model reads.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from .models import ModelCatalog


class ModelSelector:
    """Hysteretic heaviest-feasible-model policy over a catalog.

    ``decide`` is called once per micro-batch; it maintains an EWMA
    arrival-rate estimate from the batch sizes and virtual formation
    times, and returns ``(model_name, switched)``.

    Thresholds are expressed in frames of the relevant model's
    reference service time (``k / mu``), so one set of defaults works
    across catalogs with different absolute speeds:

    * degrade when ``backlog_s > degrade_backlog_frames / mu(cur)``;
    * upgrade only while ``backlog_s <= upgrade_backlog_frames /
      mu(next_heavier)``.
    """

    def __init__(self, catalog: ModelCatalog, *,
                 upgrade_headroom: float = 0.7,
                 hold: int = 2,
                 rate_alpha: float = 0.5,
                 degrade_backlog_frames: float = 6.0,
                 upgrade_backlog_frames: float = 2.0):
        self.catalog = catalog
        self._order = catalog.by_quality()       # heaviest first
        self.upgrade_headroom = float(upgrade_headroom)
        self.hold = int(hold)
        self.rate_alpha = float(rate_alpha)
        self.degrade_backlog_frames = float(degrade_backlog_frames)
        self.upgrade_backlog_frames = float(upgrade_backlog_frames)
        self._cur = len(self._order) - 1         # start lightest
        self._streak = 0                         # consecutive slack decisions
        self._rate: Optional[float] = None       # EWMA arrivals/s
        self._last_t: Optional[float] = None
        self.switches = 0

    @property
    def current(self) -> str:
        return self._order[self._cur].name

    @property
    def heaviest(self) -> str:
        return self._order[0].name

    def rate_estimate(self) -> float:
        return self._rate if self._rate is not None else 0.0

    def decide(self, t: float, n_arrived: int, backlog_s: float,
               caps: Dict[str, float]) -> Tuple[str, bool]:
        """Pick the model for the micro-batch forming at virtual time
        ``t`` with ``n_arrived`` frames, given the scheduler's committed
        backlog (seconds of residual service) and ``caps`` = summed
        healthy-pool ``mu`` per model name (frames/s)."""
        order = self._order
        if self._last_t is not None and t > self._last_t:
            inst = n_arrived / (t - self._last_t)
            a = self.rate_alpha
            self._rate = (inst if self._rate is None
                          else (1.0 - a) * self._rate + a * inst)
        self._last_t = t
        rate = self._rate if self._rate is not None else 0.0
        prev = self._cur
        last = len(order) - 1

        def cap(i: int) -> float:
            return caps.get(order[i].name, 0.0)

        def feasible(i: int, margin: float = 1.0) -> bool:
            c = cap(i)
            return c > 0.0 and c * margin >= rate

        # Degrade: jump straight to the heaviest feasible model at or
        # below the current one — a burst can overrun several tiers in
        # one decision, and stopping halfway just defers drops.
        while self._cur < last and not feasible(self._cur):
            self._cur += 1
        # Backlog pressure: one extra step lighter per decision.  The
        # committed work drains at pool speed, so a single step is the
        # stable early-warning response while the EWMA catches up.
        if (self._cur < last and backlog_s * order[self._cur].mu
                > self.degrade_backlog_frames):
            self._cur += 1

        if self._cur != prev:
            self._streak = 0
        elif (self._cur > 0
              and feasible(self._cur - 1, self.upgrade_headroom)
              and backlog_s * order[self._cur - 1].mu
              <= self.upgrade_backlog_frames):
            self._streak += 1
            if self._streak >= self.hold:
                self._cur -= 1
                self._streak = 0
        else:
            self._streak = 0

        switched = self._cur != prev
        if switched:
            self.switches += 1
        return order[self._cur].name, switched


def rois_from_boxes(boxes: np.ndarray, scores: np.ndarray,
                    valid: np.ndarray, *, bounds: Tuple[float, float],
                    roi_max: int = 4, pad: float = 0.1):
    """First-pass detections -> padded, clamped ROI windows.

    ``boxes``/``scores``/``valid`` are one frame's rows from the
    detection output (xyxy, absolute coordinates in ``bounds`` =
    ``(W, H)`` space).  Returns ``(rois, n)`` where ``rois`` is a
    dense ``(roi_max, 4)`` float32 array whose first ``n`` rows are the
    top-``roi_max`` highest-scoring valid boxes grown by ``pad`` on
    each side and clamped to the frame; remaining rows are zero
    (degenerate windows with zero area).
    """
    W, H = float(bounds[0]), float(bounds[1])
    rois = np.zeros((roi_max, 4), np.float32)
    v = np.asarray(valid, bool)
    b = np.asarray(boxes, np.float64)[v]
    s = np.asarray(scores, np.float64)[v]
    if len(b) == 0:
        return rois, 0
    top = np.argsort(-s, kind="stable")[:roi_max]
    sel = b[top]
    pw = (sel[:, 2] - sel[:, 0]) * pad
    ph = (sel[:, 3] - sel[:, 1]) * pad
    out = np.stack([np.clip(sel[:, 0] - pw, 0.0, W),
                    np.clip(sel[:, 1] - ph, 0.0, H),
                    np.clip(sel[:, 2] + pw, 0.0, W),
                    np.clip(sel[:, 3] + ph, 0.0, H)], axis=-1)
    n = len(out)
    rois[:n] = out.astype(np.float32)
    return rois, n


def roi_pixels(rois: np.ndarray, n: int,
               bounds: Tuple[float, float]) -> float:
    """Pixels the second pass reads for one frame: the summed window
    areas, capped at the full frame (overlapping windows cannot cost
    more than reading the whole frame once)."""
    W, H = float(bounds[0]), float(bounds[1])
    r = np.asarray(rois[:n], np.float64)
    if len(r) == 0:
        return 0.0
    areas = (np.clip(r[:, 2] - r[:, 0], 0.0, None)
             * np.clip(r[:, 3] - r[:, 1], 0.0, None))
    return float(min(areas.sum(), W * H))
