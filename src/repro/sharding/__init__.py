from .context import (active_mesh, constrain, mesh_context, logical_to_mesh,
                      resolve_spec)
from .rules import param_specs, param_shardings, batch_spec, input_shardings

__all__ = [
    "active_mesh", "constrain", "mesh_context", "logical_to_mesh",
    "resolve_spec", "param_specs", "param_shardings", "batch_spec",
    "input_shardings",
]
