"""Mesh-free sharding hooks: logical axis names (``context``),
path-based parameter/input rules for the model surface (``rules``),
and frame/detection specs + the NVR camera partition for the serving
surface (``serving_rules``)."""
from .context import (active_mesh, constrain, mesh_context, logical_to_mesh,
                      resolve_spec)
from .rules import param_specs, param_shardings, batch_spec, input_shardings
from .serving_rules import (constrain_detections, constrain_frames,
                            rebalance_streams, shard_streams,
                            streams_of_shard)

__all__ = [
    "active_mesh", "constrain", "mesh_context", "logical_to_mesh",
    "resolve_spec", "param_specs", "param_shardings", "batch_spec",
    "input_shardings", "constrain_detections", "constrain_frames",
    "rebalance_streams", "shard_streams", "streams_of_shard",
]
