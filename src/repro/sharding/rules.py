"""Path-based parameter / input sharding rules (MaxText-style logical axes).

Every rule maps a parameter path suffix to an ordered list of *candidate*
logical specs; ``resolve_spec`` applies divisibility fallbacks per mesh, and
we pick the candidate that keeps the most dims sharded.  This single table
covers all ten assigned architectures (dense / MoE / MLA / Mamba / RWKV) on
both the single-pod (data, model) and multi-pod (pod, data, model) meshes.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import tree_map_with_path, keystr

from .context import resolve_spec

Spec = Tuple[Optional[str], ...]

# (regex on /-joined path, [candidate logical specs for the unstacked rank])
PARAM_RULES = [
    (r"(^|/)embed/table$", [("tensor", "fsdp"), (None, "fsdp")]),
    (r"(^|/)frontend/w$", [("fsdp", "tensor")]),
    (r"(^|/)unembed/w$", [("fsdp", "tensor")]),
    (r"(^|/)(wq|wk|wv|wq_b|wk_b|wv_b|wi_gate|wi_up|in_proj|wr6|wk6|wv6|wg6)$",
     [("fsdp", "tensor")]),
    (r"(^|/)(wq_a|wkv_a)$", [("fsdp", "tensor"), ("fsdp", None)]),
    (r"(^|/)(wo|out_proj|wo6)$", [("tensor", "fsdp")]),
    (r"(^|/)router/w$", [(None, None)]),
    (r"(^|/)experts/(w_gate|w_up)$",
     [("expert", "fsdp", None), (None, "fsdp", "tensor")]),
    (r"(^|/)experts/w_down$",
     [("expert", None, "fsdp"), (None, "tensor", "fsdp")]),
    # mamba
    (r"(^|/)conv_w$", [(None, "tensor")]),
    (r"(^|/)(conv_b|dt_b|Dskip)$", [("tensor",)]),
    (r"(^|/)x_proj$", [("tensor", None)]),
    (r"(^|/)dt_w$", [(None, "tensor")]),
    (r"(^|/)A_log$", [("tensor", None)]),
    # rwkv loras / mixes
    (r"(^|/)lora_w1$", [("fsdp", None)]),
    (r"(^|/)lora_w2$", [(None, "tensor")]),
    (r"(^|/)(w0|u|mu_.*)$", [(None,) * 8]),  # trimmed to rank below
    (r"scale$", [(None,)]),
]

INPUT_RULES = [
    (r"(^|/)(tokens|labels|loss_mask|frame_labels|frame_mask|positions)$",
     [("batch", None)]),
    (r"(^|/)(features|image_embeds)$", [("batch", None, None)]),
    (r"(^|/)(k|v)$", [("batch", "kv_len", "tensor", None)]),
    (r"(^|/)(ckv|kpe)$", [("batch", "kv_len", None)]),
    (r"(^|/)conv$", [("batch", None, "tensor")]),
    (r"(^|/)ssm$", [("batch", "tensor", None)]),
    (r"(^|/)wkv$", [("batch", None, None, None)]),
    (r"(^|/)(tm_shift|cm_shift)$", [("batch", None)]),
]


def _match(path: str, rules) -> Optional[list]:
    for pat, cands in rules:
        if re.search(pat, path):
            return cands
    return None


def _pick(cands, shape, mesh: Mesh, stacked: bool) -> P:
    best, best_n = P(*([None] * len(shape))), -1
    for cand in cands:
        cand = tuple(cand)[: len(shape) - (1 if stacked else 0)]
        if stacked:
            cand = (None,) + cand
        cand = cand + (None,) * (len(shape) - len(cand))
        spec = resolve_spec(cand, shape, mesh)
        n = sum(e is not None for e in spec)
        if n > best_n:
            best, best_n = spec, n
    return best


def _spec_for(path_str: str, leaf, mesh: Mesh, rules) -> P:
    shape = leaf.shape
    cands = _match(path_str, rules)
    # scanned stacks carry a leading `repeats` dim
    stacked = bool(re.search(r"(^|/)(layers|caches)/", path_str))
    if cands is None:
        return P(*([None] * len(shape)))
    return _pick(cands, shape, mesh, stacked)


def _path_str(kp) -> str:
    try:
        return keystr(kp, simple=True, separator="/")
    except TypeError:  # older jax: render and strip the [''] decorations
        return keystr(kp).replace("']['", "/").strip("[']").replace("[", "/") \
            .replace("]", "")


def param_specs(params, mesh: Mesh):
    return tree_map_with_path(
        lambda kp, x: _spec_for(_path_str(kp), x, mesh, PARAM_RULES), params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


def batch_spec(inputs, mesh: Mesh):
    def leaf(kp, x):
        if not hasattr(x, "shape") or x.ndim == 0:
            return P()
        path = _path_str(kp)
        cands = _match(path, INPUT_RULES)
        stacked = bool(re.search(r"(^|/)(layers|caches)/", path))
        if cands is None:
            # default: shard the leading (batch) dim
            cand = ("batch",) + (None,) * (x.ndim - 1)
            return _pick([cand], x.shape, mesh, False)
        return _pick(cands, x.shape, mesh, stacked)
    return tree_map_with_path(leaf, inputs)


def input_shardings(inputs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        batch_spec(inputs, mesh))


def constrain_like_params(tree):
    """Pin a pytree (e.g. grads) to the parameter sharding rules inside the
    active mesh context — forces reduce-scatter instead of all-reduce on
    the backward pass so grads never materialize replicated."""
    from .context import active_mesh
    mesh = active_mesh()
    if mesh is None:
        return tree
    specs = param_specs(tree, mesh)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)), tree, specs)
