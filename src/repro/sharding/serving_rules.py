"""Sharding rules for the detection-serving surface.

The model-side tables in ``rules.py`` map *parameter paths* to logical
specs; serving needs the complement: logical specs for the frame /
detection tensors that flow through the batched detect+NMS program, and
a deterministic partition of the NVR camera set over mesh shards.

Logical layout
--------------
Every serving tensor is batch-major with the micro-batch (frame) dim
first, and that dim carries the ``replica`` logical axis — the paper's
"n parallel detection models", resolved to the mesh's ``data`` axis by
``context.LOGICAL_AXES`` (with the usual divisibility fallback: a
micro-batch that does not divide the axis stays replicated rather than
failing).  All trailing dims (pixels, anchor slots, box coords) stay
unsharded: detection is embarrassingly parallel across frames.

* images  ``(B, S, S, 3)``  -> ``("replica", None, None, None)``
* boxes   ``(B, D, 4)``     -> ``("replica", None, None)``
* scores / classes / valid ``(B, D)`` -> ``("replica", None)``

``constrain_frames`` / ``constrain_detections`` apply those specs via
``context.constrain`` — identity outside a ``mesh_context``, a
``with_sharding_constraint`` inside one — so
``serving.sharded.make_spmd_detect`` can wrap the unchanged
``detector.decode_detections`` in ONE jitted program that spans every
replica of the mesh.

Camera partition
----------------
``shard_streams`` is the Python-side complement: the static assignment
of camera ids to mesh shards that ``ShardedDetectionEngine`` uses to
split the NVR request trace.  It is deterministic (sorted round-robin)
so two hosts computing the partition independently agree on it.

``rebalance_streams`` is the runtime correction to that static split —
the cross-shard work-stealing rule.  It consumes only *observations*
(per-shard drop counts, backlog horizons, per-stream frame counts from
one served epoch) and is a pure deterministic function of them, so
every host replaying the same epoch report computes the same
migration without coordinating.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

from .context import constrain

# logical per-dim axes of the serving tensors (batch dim = paper replicas)
FRAME_AXES = ("replica", None, None, None)      # (B, S, S, 3) images
BOX_AXES = ("replica", None, None)              # (B, D, 4) boxes
ROW_AXES = ("replica", None)                    # (B, D) scores/classes/valid


def constrain_frames(images):
    """Pin a micro-batch of images ``(B, S, S, 3)`` to the replica axis.

    Identity outside a mesh context; inside one, the batch dim is split
    into contiguous blocks of ``B / n_shards`` frames, one block per
    mesh shard (jax's NamedSharding block layout — NOT round-robin),
    when ``B`` divides the axis; otherwise the divisibility fallback
    keeps the batch replicated."""
    return constrain(images, *FRAME_AXES)


def constrain_detections(boxes, scores, classes, valid):
    """Pin a batched detection tuple ``(boxes (B,D,4), scores (B,D),
    classes (B,D), valid (B,D))`` to the replica axis, mirroring
    ``constrain_frames`` on the output side of the fused detect+NMS
    program."""
    return (constrain(boxes, *BOX_AXES),
            constrain(scores, *ROW_AXES),
            constrain(classes, *ROW_AXES),
            constrain(valid, *ROW_AXES))


def shard_streams(stream_ids: Iterable[int],
                  n_shards: int) -> Dict[int, int]:
    """Deterministic partition of camera ids over ``n_shards`` shards.

    Sorted round-robin: camera ranks are assigned modulo the shard
    count, so shard loads differ by at most one camera and the mapping
    depends only on the *set* of ids (any two hosts agree on it
    without communicating).

    >>> shard_streams([3, 0, 2, 1], 2)
    {0: 0, 1: 1, 2: 0, 3: 1}
    >>> shard_streams([7], 4)
    {7: 0}
    >>> shard_streams([], 2)
    {}
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    sids = sorted(set(int(s) for s in stream_ids))
    return {sid: i % n_shards for i, sid in enumerate(sids)}


def streams_of_shard(shard_of: Dict[int, int], shard: int) -> List[int]:
    """The sorted camera ids assigned to ``shard`` by ``shard_streams``.

    >>> streams_of_shard({0: 0, 1: 1, 2: 0, 3: 1}, 0)
    [0, 2]
    """
    return sorted(s for s, h in shard_of.items() if h == shard)


def rebalance_streams(shard_of: Dict[int, int], loads: Sequence[Dict],
                      max_moves: int = 1, evacuate: Sequence[int] = ()
                      ) -> Tuple[Dict[int, int], List[Tuple[int, int, int]]]:
    """Cross-shard work stealing: migrate whole camera streams from the
    most pressured shard to the least pressured one, based on one served
    epoch's observations.

    ``loads[h]`` is shard ``h``'s observation for the epoch:

    * ``drops``     — frames shard ``h`` dropped (the primary pressure
      signal: the paper's rate-mismatch pathology made visible);
    * ``backlog_s`` — residual committed service at the epoch's end
      (``DetectionEngine.backlog_snapshot``: pressure that has not yet
      turned into drops — the early-warning signal);
    * ``frames``    — ``{stream_id: frames observed this epoch}``, the
      per-stream arrival-rate estimate migrations are sized by.

    Policy (rationale):

    1. *Donor* = lexicographically max ``(drops, backlog_s)`` shard,
       *receiver* = min; a move requires donor pressure STRICTLY above
       receiver pressure, so a balanced system never churns.
    2. Candidate streams are the donor's, heaviest observed first (the
       fastest camera is the one whose departure relieves the most
       rate mismatch), ties broken by lowest stream id.
    3. A candidate only moves if ``receiver_load + stream <
       donor_load`` in observed frames — the move must strictly shrink
       the maximum per-shard load, which rules out ping-ponging a hot
       stream between shards and refuses "moves" that just relocate
       the overload (e.g. a donor with a single hot stream).
    4. At most ``max_moves`` migrations per call (whole streams only —
       a stream's frames never split across shards inside an epoch, so
       per-stream ordering survives migration untouched).

    Forced evacuation (``evacuate=``): the watchdog's re-homing path.
    Shards listed in ``evacuate`` are treated as DEAD — every stream
    they own is re-homed before the stealing phase runs, heaviest
    observed first, each to the live shard with the least observed
    load at that point (ties by lowest shard id).  Unlike stealing,
    evacuation is unconditional: rule 3's strict-improvement gate does
    not apply (there is no "keeping it where it is" when the shard is
    down), evacuation moves do not count against ``max_moves``, and
    evacuated shards are excluded from the stealing phase entirely
    (their epoch observations describe a dead host — neither a
    credible donor nor a restart-fresh receiver this boundary).

    Deterministic: every choice is totally ordered (ties fall back to
    shard/stream ids), and only the observation values matter — not
    dict insertion order — so replicas that saw the same epoch report
    agree on the migration without communicating.

    Returns ``(new_shard_of, moves)`` with ``moves`` a list of
    ``(stream_id, src_shard, dst_shard)`` (evacuation moves first);
    the input mapping is not mutated.

    >>> of = {0: 0, 2: 0, 4: 0, 1: 1, 3: 1, 5: 1}
    >>> loads = [{"drops": 9, "backlog_s": 3.0,
    ...           "frames": {0: 16, 2: 16, 4: 16}},
    ...          {"drops": 0, "backlog_s": 0.0,
    ...           "frames": {1: 8, 3: 8, 5: 8}}]
    >>> rebalance_streams(of, loads)
    ({0: 1, 2: 0, 4: 0, 1: 1, 3: 1, 5: 1}, [(0, 0, 1)])
    >>> balanced = [{"drops": 0, "backlog_s": 0.0, "frames": {0: 8}},
    ...             {"drops": 0, "backlog_s": 0.0, "frames": {1: 8}}]
    >>> rebalance_streams({0: 0, 1: 1}, balanced)
    ({0: 0, 1: 1}, [])
    """
    n = len(loads)
    shard_of = dict(shard_of)
    moves: List[Tuple[int, int, int]] = []
    # per-stream observed frames (each stream served by exactly one
    # shard per epoch; the count rides along when the stream moves)
    stream_frames: Dict[int, int] = {}
    for load in loads:
        for sid, c in load["frames"].items():
            stream_frames[sid] = stream_frames.get(sid, 0) + int(c)
    pressure = [(int(load["drops"]), float(load["backlog_s"]))
                for load in loads]
    dead = set(int(h) for h in evacuate)
    live = [h for h in range(n) if h not in dead]
    if dead and not live:
        raise ValueError("cannot evacuate every shard: no live shard "
                         "left to re-home the streams onto")
    # -- phase 0: forced evacuation of dead shards (watchdog re-homing)
    for h in sorted(dead):
        doomed = sorted((sid for sid, hh in shard_of.items() if hh == h),
                        key=lambda sid: (-stream_frames.get(sid, 0), sid))
        for sid in doomed:
            shard_load = {r: sum(stream_frames.get(s, 0)
                                 for s, x in shard_of.items() if x == r)
                          for r in live}
            recv = min(live, key=lambda r: (shard_load[r], r))
            shard_of[sid] = recv
            moves.append((sid, h, recv))
    # -- stealing phase (live shards only)
    for _ in range(max_moves):
        shard_load = [sum(stream_frames.get(sid, 0)
                          for sid, h in shard_of.items() if h == hh)
                      for hh in range(n)]
        donor = max(live, key=lambda h: (pressure[h], shard_load[h],
                                         -h))
        recv = min(live, key=lambda h: (pressure[h], shard_load[h],
                                        h))
        if donor == recv or pressure[donor] <= pressure[recv]:
            break                        # no pressure gradient -> stable
        cands = sorted((sid for sid, h in shard_of.items()
                        if h == donor and stream_frames.get(sid, 0) > 0),
                       key=lambda sid: (-stream_frames[sid], sid))
        moved = None
        for sid in cands:
            if shard_load[recv] + stream_frames[sid] < shard_load[donor]:
                moved = sid
                break
        if moved is None:
            break                        # every move would just relocate it
        shard_of[moved] = recv
        moves.append((moved, donor, recv))
    return shard_of, moves
