"""Sharding rules for the detection-serving surface.

The model-side tables in ``rules.py`` map *parameter paths* to logical
specs; serving needs the complement: logical specs for the frame /
detection tensors that flow through the batched detect+NMS program, and
a deterministic partition of the NVR camera set over mesh shards.

Logical layout
--------------
Every serving tensor is batch-major with the micro-batch (frame) dim
first, and that dim carries the ``replica`` logical axis — the paper's
"n parallel detection models", resolved to the mesh's ``data`` axis by
``context.LOGICAL_AXES`` (with the usual divisibility fallback: a
micro-batch that does not divide the axis stays replicated rather than
failing).  All trailing dims (pixels, anchor slots, box coords) stay
unsharded: detection is embarrassingly parallel across frames.

* images  ``(B, S, S, 3)``  -> ``("replica", None, None, None)``
* boxes   ``(B, D, 4)``     -> ``("replica", None, None)``
* scores / classes / valid ``(B, D)`` -> ``("replica", None)``

``constrain_frames`` / ``constrain_detections`` apply those specs via
``context.constrain`` — identity outside a ``mesh_context``, a
``with_sharding_constraint`` inside one — so
``serving.sharded.make_spmd_detect`` can wrap the unchanged
``detector.decode_detections`` in ONE jitted program that spans every
replica of the mesh.

Camera partition
----------------
``shard_streams`` is the Python-side complement: the static assignment
of camera ids to mesh shards that ``ShardedDetectionEngine`` uses to
split the NVR request trace.  It is deterministic (sorted round-robin)
so two hosts computing the partition independently agree on it.
"""
from __future__ import annotations

from typing import Dict, Iterable, List

from .context import constrain

# logical per-dim axes of the serving tensors (batch dim = paper replicas)
FRAME_AXES = ("replica", None, None, None)      # (B, S, S, 3) images
BOX_AXES = ("replica", None, None)              # (B, D, 4) boxes
ROW_AXES = ("replica", None)                    # (B, D) scores/classes/valid


def constrain_frames(images):
    """Pin a micro-batch of images ``(B, S, S, 3)`` to the replica axis.

    Identity outside a mesh context; inside one, the batch dim is split
    into contiguous blocks of ``B / n_shards`` frames, one block per
    mesh shard (jax's NamedSharding block layout — NOT round-robin),
    when ``B`` divides the axis; otherwise the divisibility fallback
    keeps the batch replicated."""
    return constrain(images, *FRAME_AXES)


def constrain_detections(boxes, scores, classes, valid):
    """Pin a batched detection tuple ``(boxes (B,D,4), scores (B,D),
    classes (B,D), valid (B,D))`` to the replica axis, mirroring
    ``constrain_frames`` on the output side of the fused detect+NMS
    program."""
    return (constrain(boxes, *BOX_AXES),
            constrain(scores, *ROW_AXES),
            constrain(classes, *ROW_AXES),
            constrain(valid, *ROW_AXES))


def shard_streams(stream_ids: Iterable[int],
                  n_shards: int) -> Dict[int, int]:
    """Deterministic partition of camera ids over ``n_shards`` shards.

    Sorted round-robin: camera ranks are assigned modulo the shard
    count, so shard loads differ by at most one camera and the mapping
    depends only on the *set* of ids (any two hosts agree on it
    without communicating).

    >>> shard_streams([3, 0, 2, 1], 2)
    {0: 0, 1: 1, 2: 0, 3: 1}
    >>> shard_streams([7], 4)
    {7: 0}
    >>> shard_streams([], 2)
    {}
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    sids = sorted(set(int(s) for s in stream_ids))
    return {sid: i % n_shards for i, sid in enumerate(sids)}


def streams_of_shard(shard_of: Dict[int, int], shard: int) -> List[int]:
    """The sorted camera ids assigned to ``shard`` by ``shard_streams``.

    >>> streams_of_shard({0: 0, 1: 1, 2: 0, 3: 1}, 0)
    [0, 2]
    """
    return sorted(s for s, h in shard_of.items() if h == shard)
