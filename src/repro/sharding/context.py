"""Mesh-free sharding hooks.

Model code annotates activations with *logical* axis names via
``constrain(x, "batch", None, "tensor")``.  Outside a mesh context this is
an identity; inside ``mesh_context(mesh)`` the names resolve to mesh axes
(with divisibility fallbacks) and become
``jax.lax.with_sharding_constraint`` calls.  This keeps every model file
independent of the production mesh while letting the dry-run/launchers pin
the distribution the paper's replica-parallel serving requires.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# logical axis -> preferred mesh axes (in order; filtered by mesh presence)
LOGICAL_AXES = {
    "batch": ("pod", "data"),        # global batch / token parallelism
    "fsdp": ("pod", "data"),         # parameter (ZeRO-3 style) sharding
    "tensor": ("model",),            # head / ff / vocab tensor parallelism
    "expert": ("model",),            # expert parallelism
    "kv_len": ("data", "model"),     # KV-cache length sharding (decode)
    "seq": ("model",),               # sequence-parallel activations (train)
    "replica": ("data",),            # paper's n parallel detection models
}


def active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = prev


def logical_to_mesh(name: Optional[str], mesh: Mesh) -> Tuple[str, ...]:
    if name is None:
        return ()
    return tuple(a for a in LOGICAL_AXES[name] if a in mesh.axis_names)


def resolve_spec(logical: Sequence[Optional[str]], shape, mesh: Mesh) -> P:
    """Logical per-dim names -> PartitionSpec with divisibility fallback."""
    entries = []
    used = set()
    for dim, name in zip(shape, logical):
        axes = tuple(a for a in logical_to_mesh(name, mesh) if a not in used)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and size > 1 and dim % size == 0:
            entries.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            # per-axis partial fallback: try the single largest dividing axis
            picked = None
            for a in axes:
                if dim % mesh.shape[a] == 0 and mesh.shape[a] > 1:
                    picked = a
                    break
            if picked is not None:
                entries.append(picked)
                used.add(picked)
            else:
                entries.append(None)
    return P(*entries)


def constrain(x, *logical: Optional[str]):
    """Annotate activation ``x`` with logical axes; identity off-mesh."""
    mesh = active_mesh()
    if mesh is None:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank mismatch {x.shape} vs {logical}")
    spec = resolve_spec(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
