"""Input specs: ShapeDtypeStruct stand-ins (dry-run) and concrete batches
(smoke tests / examples) for every (architecture × input shape) pair."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import SHAPES, InputShape
from ..models import init_cache
from ..models.config import ModelConfig


def make_positions(B, S):
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))


def _train_tree(cfg: ModelConfig, B, S, make):
    act = jnp.dtype(cfg.dtype)
    tree: Dict = {}
    if cfg.modality == "audio":
        tree["features"] = make((B, S, cfg.frontend_dim), act)
        tree["labels"] = make((B, S), jnp.int32)
        tree["loss_mask"] = make((B, S), jnp.float32)
        return tree
    if cfg.modality == "vlm":
        n_img = cfg.n_frontend_tokens
        tree["tokens"] = make((B, S - n_img), jnp.int32)
        tree["image_embeds"] = make((B, n_img, cfg.frontend_dim), act)
    else:
        tree["tokens"] = make((B, S), jnp.int32)
    tree["labels"] = make((B, S), jnp.int32)
    tree["loss_mask"] = make((B, S), jnp.float32)
    return tree


def _prefill_tree(cfg: ModelConfig, B, S, make):
    act = jnp.dtype(cfg.dtype)
    tree: Dict = {}
    if cfg.modality == "audio":
        tree["features"] = make((B, S, cfg.frontend_dim), act)
    elif cfg.modality == "vlm":
        n_img = cfg.n_frontend_tokens
        tree["tokens"] = make((B, S - n_img), jnp.int32)
        tree["image_embeds"] = make((B, n_img, cfg.frontend_dim), act)
    else:
        tree["tokens"] = make((B, S), jnp.int32)
    return tree


def input_specs(cfg: ModelConfig, shape: str | InputShape):
    """ShapeDtypeStruct pytree for the lowered step (no allocation)."""
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    B, S = sh.global_batch, sh.seq_len
    make = lambda s, d: jax.ShapeDtypeStruct(s, d)
    if sh.kind == "train":
        return _train_tree(cfg, B, S, make)
    if sh.kind == "prefill":
        return _prefill_tree(cfg, B, S, make)
    # decode: one new token against a cache of S positions
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return {
        "tokens": make((B, 1), jnp.int32),
        "cache": cache,
        "decode_pos": make((), jnp.int32),
    }


def concrete_batch(cfg: ModelConfig, shape: str | InputShape, seed=0):
    """Small concrete batch for smoke tests and CPU examples."""
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    B, S = sh.global_batch, sh.seq_len
    rng = np.random.default_rng(seed)
    act = jnp.dtype(cfg.dtype)

    def make(s, d):
        if jnp.issubdtype(d, jnp.integer):
            hi = max(2, cfg.vocab_size - 1)
            return jnp.asarray(rng.integers(0, hi, size=s), d)
        if s and s[-1] == 1 and len(s) == 2:
            pass
        arr = rng.standard_normal(size=s).astype(np.float32)
        return jnp.asarray(arr, d)

    if sh.kind == "train":
        tree = _train_tree(cfg, B, S, make)
        tree["loss_mask"] = jnp.ones((B, S), jnp.float32)
        return tree
    if sh.kind == "prefill":
        return _prefill_tree(cfg, B, S, make)
    return {
        "tokens": make((B, 1), jnp.int32),
        "cache": init_cache(cfg, B, S),
        "decode_pos": jnp.asarray(S, jnp.int32),
    }
