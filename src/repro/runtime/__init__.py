from .specs import concrete_batch, input_specs, make_positions
from .steps import (TrainState, loss_fn, make_decode_step, make_prefill_step,
                    make_train_step, train_state_init)

__all__ = [
    "TrainState", "concrete_batch", "input_specs", "loss_fn",
    "make_decode_step", "make_positions", "make_prefill_step",
    "make_train_step", "train_state_init",
]
