"""Minimal dependency-free checkpointing (orbax is not available offline).

Saves a pytree as one .npz per top-level key plus a JSON manifest with the
tree structure; restores onto host then (optionally) re-shards via
device_put with the caller's shardings.  Atomic via tmp-dir rename.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    # jax.tree_util spelling: jax.tree.flatten_with_path only exists in
    # newer jax releases than this container ships
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(kp): np.asarray(x) for kp, x in flat}, \
        jax.tree.structure(tree)


def save_checkpoint(path: str | Path, tree: Any, step: int = 0):
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _flatten(tree)
    np.savez(tmp / "arrays.npz", **leaves)
    manifest = {"step": step, "keys": sorted(leaves)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)
    return path


def restore_checkpoint(path: str | Path, like: Any,
                       shardings: Optional[Any] = None):
    """Restore into the structure of ``like``; arrays placed with
    ``shardings`` when given (mesh-sharded restore)."""
    path = Path(path)
    data = np.load(path / "arrays.npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for kp, ref in flat:
        key = jax.tree_util.keystr(kp)
        arr = data[key]
        assert arr.shape == tuple(ref.shape), (key, arr.shape, ref.shape)
        out.append(arr.astype(ref.dtype))
    tree = jax.tree.unflatten(jax.tree.structure(like), out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def checkpoint_step(path: str | Path) -> int:
    return json.loads((Path(path) / "manifest.json").read_text())["step"]
