"""Train / prefill / decode step builders shared by smoke tests, examples,
the serving runtime, and the multi-pod dry-run."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..models import init_cache, init_model, model_apply
from ..models.config import ModelConfig
from ..models.layers import cross_entropy
from ..optim import AdamWConfig, adamw_init, adamw_update
from ..sharding.rules import constrain_like_params


@dataclass
class TrainState:
    params: Any
    opt_state: Any


def train_state_init(cfg: ModelConfig, rng, opt_cfg: AdamWConfig):
    params = init_model(cfg, rng)
    return {"params": params, "opt": adamw_init(params, opt_cfg)}


def loss_fn(params, cfg: ModelConfig, batch: Dict, remat=False):
    logits, _, aux = model_apply(params, cfg, batch, mode="train",
                                 remat=remat)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    loss = cross_entropy(logits, labels, mask)
    metrics = {"ce_loss": loss, "aux_loss": aux["aux_loss"],
               "load_balance": aux["load_balance"]}
    total = loss + aux["aux_loss"]
    if cfg.mtp and "mtp_logits" in aux:
        mtp_labels = jnp.roll(labels, -1, axis=1)
        mtp_mask = (mask if mask is not None
                    else jnp.ones(labels.shape, jnp.float32))
        mtp_mask = mtp_mask.at[:, -2:].set(0.0)
        mtp_loss = cross_entropy(aux["mtp_logits"], mtp_labels, mtp_mask)
        total = total + cfg.mtp_loss_weight * mtp_loss
        metrics["mtp_loss"] = mtp_loss
    metrics["total_loss"] = total
    return total, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, schedule,
                    remat: bool = True):
    def train_step(state, batch):
        (_, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=remat), has_aux=True
        )(state["params"])
        grads = constrain_like_params(grads)
        lr = schedule(state["opt"]["step"])
        params, opt, gnorm = adamw_update(state["params"], grads,
                                          state["opt"], opt_cfg, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return {"params": params, "opt": opt}, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int | None = None):
    def prefill(params, batch):
        B = (batch["features"] if cfg.modality == "audio"
             else batch["tokens"]).shape[0]
        S = _seq_len(cfg, batch)
        cache = init_cache(cfg, B, cache_len or S)
        logits, cache, _ = model_apply(params, cfg, batch, mode="prefill",
                                       cache=cache)
        return logits[:, -1], cache
    return prefill


def make_decode_step(cfg: ModelConfig):
    def decode(params, batch):
        logits, cache, _ = model_apply(
            params, cfg, {"tokens": batch["tokens"]}, mode="decode",
            cache=batch["cache"], decode_pos=batch["decode_pos"])
        return logits[:, -1], cache
    return decode


def _seq_len(cfg: ModelConfig, batch):
    if cfg.modality == "audio":
        return batch["features"].shape[1]
    S = batch["tokens"].shape[1]
    if cfg.modality == "vlm" and "image_embeds" in batch:
        S += batch["image_embeds"].shape[1]
    return S
