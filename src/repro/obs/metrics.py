"""Streaming latency metrics: log-bucketed histograms with mergeable
quantiles.

The paper's diagnosis method is rate *measurement* (incoming FPS vs
processing FPS vs display FPS); a single end-of-serve median hides
exactly the tail behaviour that exposes an edge bottleneck.  This
module gives the serving reports a latency distribution that

* streams — O(1) per observation, no latency list kept around,
* merges exactly — two histograms sum bucket-wise, so a sharded
  report's distribution equals the whole-run distribution (quantiles
  are recomputed from the merged buckets, NEVER averaged: an average
  of per-shard p99s is not a p99), and
* serializes — the dict form is JSON-ready and round-trips.

Bucket layout: quarter-octave log buckets anchored at ``LO`` = 1 µs.
Bucket 0 holds every latency ``<= LO``; bucket ``k >= 1`` holds
``(LO * 2^((k-1)/4), LO * 2^(k/4)]`` — ~19 %-wide buckets, so a
reported quantile (a bucket's upper edge, capped at the observed max)
is within 19 % of the exact order statistic at any scale from
microseconds to hours.  1 second lands in bucket 80:

>>> LatencyHistogram.bucket_of(1.0)
80
>>> LatencyHistogram.bucket_of(0.0)
0
>>> h = LatencyHistogram()
>>> for x in (0.010, 0.011, 0.012, 0.5):
...     h.add(x)
>>> h.n, round(h.max, 3)
(4, 0.5)
>>> round(h.quantile(0.5), 6) <= round(h.quantile(0.99), 6) == 0.5
True
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional

import numpy as np

_LOG2 = math.log(2.0)


class LatencyHistogram:
    """Log-bucketed streaming histogram (see module docstring for the
    bucket layout).  ``merge`` sums bucket counts; ``quantile``
    recomputes from the (merged) buckets.  Equality compares counts,
    n and max — the mergeable state — so a merged histogram compares
    equal to the whole-run histogram of the same observations."""

    LO = 1e-6                 # seconds: bucket-0 upper edge
    PER_OCTAVE = 4            # buckets per doubling (quarter-octave)

    def __init__(self):
        self.counts: Dict[int, int] = {}
        self.n = 0
        self.max = 0.0

    @classmethod
    def bucket_of(cls, x: float) -> int:
        if x <= cls.LO:
            return 0
        return 1 + int(math.floor(
            math.log(x / cls.LO) / _LOG2 * cls.PER_OCTAVE))

    @classmethod
    def upper_edge(cls, k: int) -> float:
        """Upper edge of bucket ``k`` in seconds."""
        return cls.LO if k <= 0 else cls.LO * 2.0 ** (k / cls.PER_OCTAVE)

    def add(self, x: float):
        k = self.bucket_of(x)
        self.counts[k] = self.counts.get(k, 0) + 1
        self.n += 1
        if x > self.max:
            self.max = float(x)

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        for k, c in other.counts.items():
            self.counts[k] = self.counts.get(k, 0) + c
        self.n += other.n
        self.max = max(self.max, other.max)
        return self

    def quantile(self, q: float) -> float:
        """The smallest bucket upper edge covering rank ``ceil(q * n)``,
        capped at the observed max (so ``quantile(1.0) == max`` and a
        top-bucket quantile never over-reports past the data).  0.0 on
        an empty histogram."""
        if self.n == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.n))
        cum = 0
        for k in sorted(self.counts):
            cum += self.counts[k]
            if cum >= rank:
                return min(self.upper_edge(k), self.max)
        return self.max

    # ------------------------------------------------------ serialization
    def to_dict(self) -> dict:
        return {"lo": self.LO, "per_octave": self.PER_OCTAVE,
                "counts": dict(self.counts), "n": self.n, "max": self.max}

    @classmethod
    def from_dict(cls, d: Optional[dict]) -> "LatencyHistogram":
        h = cls()
        if d:
            h.counts = {int(k): int(c) for k, c in d["counts"].items()}
            h.n = int(d["n"])
            h.max = float(d["max"])
        return h

    def __eq__(self, other) -> bool:
        if not isinstance(other, LatencyHistogram):
            return NotImplemented
        return (self.counts == other.counts and self.n == other.n
                and self.max == other.max)

    def __repr__(self):
        return (f"LatencyHistogram(n={self.n}, max={self.max:.6f}, "
                f"buckets={len(self.counts)})")


def merge_hist_dicts(dicts: Iterable[Optional[dict]]) -> dict:
    """Sum serialized histograms bucket-wise (the shard-report merge)."""
    out = LatencyHistogram()
    for d in dicts:
        out.merge(LatencyHistogram.from_dict(d))
    return out.to_dict()


def quantile_of_dict(d: Optional[dict], q: float) -> float:
    return LatencyHistogram.from_dict(d).quantile(q)


def detection_latency_keys(responses, arrival_of=None) -> dict:
    """The latency block of a serve report, computed from final
    responses (pure post-processing: never touches the virtual clock).

    Detection latency is ``t_done - t_start`` — the frame's service
    window on its replica.  Tracker-coasted re-emissions
    (``interpolated`` / ``replica == -1``) are NOT detections and must
    not pollute the detection distribution (their service window is
    zero by construction); they land in the separate ``interp_latency``
    series instead, measured as re-emission delay ``t_done -
    t_arrival`` when ``arrival_of`` (rid -> arrival time) is given.

    Keys: ``p50_latency`` (exact median — backward-compatible with the
    pre-histogram reports), ``p95_latency`` / ``p99_latency``
    (histogram quantiles, so merged reports can recompute them exactly
    from summed buckets), ``latency_hist`` / ``interp_latency``
    (serialized histograms) and ``latency_by_stream`` /
    ``latency_by_replica`` rollups."""
    det = LatencyHistogram()
    interp = LatencyHistogram()
    by_stream: Dict[int, LatencyHistogram] = {}
    by_replica: Dict[int, LatencyHistogram] = {}
    lat: List[float] = []
    for r in responses:
        if getattr(r, "interpolated", False):
            if arrival_of is not None and r.rid in arrival_of:
                interp.add(r.t_done - arrival_of[r.rid])
            continue
        x = r.t_done - r.t_start
        lat.append(x)
        det.add(x)
        sid = getattr(r, "stream_id", 0)
        by_stream.setdefault(sid, LatencyHistogram()).add(x)
        if r.replica >= 0:
            by_replica.setdefault(r.replica, LatencyHistogram()).add(x)
    return {
        "p50_latency": float(np.median(lat)) if lat else 0.0,
        "p95_latency": det.quantile(0.95),
        "p99_latency": det.quantile(0.99),
        "latency_hist": det.to_dict(),
        "interp_latency": interp.to_dict(),
        "latency_by_stream": {s: h.to_dict()
                              for s, h in sorted(by_stream.items())},
        "latency_by_replica": {i: h.to_dict()
                               for i, h in sorted(by_replica.items())},
    }
