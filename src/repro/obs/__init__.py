"""Deterministic observability for the serving stack: frame-lifecycle
tracing (``trace``), streaming latency histograms (``metrics``),
Perfetto/Chrome timeline export (``export``), and trace-replay
invariant auditing (``audit``).  See ``docs/OBSERVABILITY.md``."""
from repro.obs.audit import AuditResult, audit_events, audit_recorder
from repro.obs.export import (events_from_chrome, to_chrome_trace,
                              write_chrome_trace)
from repro.obs.metrics import (LatencyHistogram, detection_latency_keys,
                               merge_hist_dicts, quantile_of_dict)
from repro.obs.trace import NULL_RECORDER, NullRecorder, TraceRecorder

__all__ = [
    "TraceRecorder", "NullRecorder", "NULL_RECORDER",
    "LatencyHistogram", "detection_latency_keys", "merge_hist_dicts",
    "quantile_of_dict",
    "to_chrome_trace", "events_from_chrome", "write_chrome_trace",
    "AuditResult", "audit_events", "audit_recorder",
]
