"""Trace-replay invariant checking.

A serving trace is a deterministic artifact (virtual clock), so the
invariants the stack is *supposed* to uphold can be re-checked from the
event log alone — no engine state, no re-run.  ``audit_events`` replays
a recorded event list and verifies:

1. **Frame conservation** — every ``arrive`` reaches exactly one
   terminal state: ``emit`` (detected), ``interp_emit``
   (tracker-coasted re-emission of a drop), ``drop`` with no
   re-emission, or ``shard_lost`` (a down shard swallowed it).  No
   frame vanishes; no frame is emitted twice.
2. **Per-stream emit monotonicity** — within each stream the emitted
   sequence numbers strictly increase and emit times never decrease
   (the reorder buffer's contract, including across epoch migrations
   where the emit clock is carried as a floor).
3. **No dispatch to a dead replica** — between a ``health_mark`` and
   the matching ``health_restore`` for a ``(shard, replica)`` lane,
   the scheduler must not ``dispatch`` to that lane.  A
   ``shard_restart`` closes every open mark on its shard (the watchdog
   resets the whole scheduler health mask), and a ``loan_return``
   closes the borrower's retired guest lane.  Checked in *code order*
   (the event sequence number ``i``), the order decisions were
   actually made in — virtual timestamps of a retry's detection and
   the rescuing dispatch can legitimately interleave.
4. **Loans are LIFO-returned** — ``loan_return`` events per borrower
   must pop the most recent outstanding ``loan`` (the tail-replica
   lending discipline), and every loan must be returned by trace end.
5. **Model switches only at micro-batch boundaries** — a
   ``model_switch`` names the micro-batch it takes effect for
   (``batch``); it must be recorded BEFORE any frame is enqueued to
   that ``(shard, batch)``.  A switch after the batch started filling
   would mean frames priced/detected under two different models in one
   batch.  (Batch numbers are monotone across epoch segments, so the
   pair never repeats within a trace.)
6. **ROI containment** — every ``roi_pass`` window must lie inside its
   parent frame ``bounds``, the pixels read must not exceed the full
   frame, and the second pass's detections (``det_extent``) must land
   inside the frame — a cropped re-detection can never escape the
   image it came from.
7. **Track-identity continuity** — track identities must survive
   segment boundaries and shard migration.  Every tracker segment
   records a ``track_export`` per stream (its ``next_id`` counter +
   confirmed track-id set) and, when seeded from carried rows, a
   matching ``track_import``.  An import must reproduce the stream's
   latest prior export exactly (same ``next_id``, same ``tids`` — a
   fresh table restarting ids at 0 can never fake it), and a stream
   that keeps emitting after a ``migrate`` without importing its
   exported table was re-seeded: a violation.  Traces from engines
   that never ran a tracker carry no export events and pass vacuously.

``audit_events`` returns an ``AuditResult`` whose ``violations`` list
is empty on a clean trace; each violation is a dict with a ``rule``
key naming the broken invariant.  ``tools/check_trace.py`` is the CLI
over saved trace files.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple


class AuditResult:
    """Outcome of a trace audit: per-rule violation dicts + tallies."""

    def __init__(self, violations: List[dict], stats: dict):
        self.violations = violations
        self.stats = stats

    @property
    def ok(self) -> bool:
        return not self.violations

    def __repr__(self):
        return (f"AuditResult(ok={self.ok}, "
                f"violations={len(self.violations)}, stats={self.stats})")


def _lane(ev: dict) -> Tuple[int, int]:
    return (ev.get("shard", 0), ev["replica"])


def audit_events(events: List[dict],
                 max_violations: int = 50) -> AuditResult:
    """Replay ``events`` (raw recorder order) and check the seven
    invariants in the module docstring.  Events may be passed in any
    order; they are re-sorted by code order ``i`` first."""
    evs = sorted(events, key=lambda e: e["i"])
    violations: List[dict] = []

    def flag(rule: str, ev: Optional[dict] = None, **detail):
        if len(violations) < max_violations:
            v = {"rule": rule}
            if ev is not None:
                v["event"] = ev
            v.update(detail)
            violations.append(v)

    # -- per-frame terminal-state machine ------------------------------
    # rid -> one of None (arrived, pending), "emit", "interp_emit",
    # "drop", "shard_lost"
    state: Dict[int, Optional[str]] = {}
    # -- per-stream emit clock -----------------------------------------
    last_emit: Dict[int, Tuple[int, float]] = {}   # stream -> (seq, t)
    # -- replica health (code-order intervals) -------------------------
    dead: Dict[Tuple[int, int], dict] = {}          # lane -> mark event
    # -- loan stacks ---------------------------------------------------
    loans: Dict[int, List[dict]] = {}               # borrower -> stack
    # -- micro-batches already filling (model switches must precede) ---
    started: set = set()                            # (shard, batch)
    # -- track-identity continuity -------------------------------------
    last_export: Dict[int, dict] = {}   # stream -> latest export event
    # migrated streams whose exported table has not been imported yet:
    # an emit for one of them means the destination re-seeded
    pending_migrate: Dict[int, dict] = {}

    n = {"arrive": 0, "emit": 0, "interp_emit": 0, "drop": 0,
         "shard_lost": 0, "dispatch": 0, "loan": 0, "model_switch": 0,
         "roi_pass": 0, "track_export": 0, "track_import": 0}

    for ev in evs:
        kind = ev["kind"]
        if kind == "arrive":
            n["arrive"] += 1
            rid = ev["rid"]
            if rid in state:
                flag("frame_conservation", ev, why="duplicate arrive")
            state.setdefault(rid, None)
        elif kind in ("emit", "interp_emit"):
            n[kind] += 1
            rid = ev["rid"]
            if rid not in state:
                flag("frame_conservation", ev, why="emit without arrive")
            elif state[rid] == "drop" and kind == "interp_emit":
                pass   # a dropped frame MAY be coasted back by the tracker
            elif state[rid] is not None:
                flag("frame_conservation", ev,
                     why=f"{kind} after terminal {state[rid]}")
            state[rid] = kind
            s, seq, t = ev["stream"], ev["seq"], ev["t"]
            if s in last_emit:
                pseq, pt = last_emit[s]
                if seq <= pseq:
                    flag("emit_monotonicity", ev, prev_seq=pseq,
                         why="sequence not increasing")
                if t < pt:
                    flag("emit_monotonicity", ev, prev_t=pt,
                         why="emit time decreased")
            last_emit[s] = (seq, t)
            if s in pending_migrate:
                flag("track_continuity", pending_migrate.pop(s),
                     why="stream served after migration without "
                         "importing its exported track table")
        elif kind == "drop":
            n["drop"] += 1
            rid = ev["rid"]
            if state.get(rid) is not None:
                flag("frame_conservation", ev,
                     why=f"drop after terminal {state[rid]}")
            state[rid] = "drop"
        elif kind == "shard_lost":
            n["shard_lost"] += 1
            rid = ev["rid"]
            if state.get(rid) is not None:
                flag("frame_conservation", ev,
                     why=f"lost after terminal {state[rid]}")
            state[rid] = "shard_lost"
        elif kind == "enqueue":
            started.add((ev.get("shard", 0), ev.get("batch")))
        elif kind == "model_switch":
            n["model_switch"] += 1
            key = (ev.get("shard", 0), ev.get("batch"))
            if key in started:
                flag("model_switch_boundary", ev,
                     why="switch after the micro-batch started filling")
        elif kind == "roi_pass":
            n["roi_pass"] += 1
            W, H = ev.get("bounds", (float("inf"), float("inf")))
            eps = 1e-6 * max(W, H, 1.0)
            for r in ev.get("rois", ()):
                if (r[0] < -eps or r[1] < -eps
                        or r[2] > W + eps or r[3] > H + eps
                        or r[2] < r[0] or r[3] < r[1]):
                    flag("roi_containment", ev, roi=list(r),
                         why="ROI window escapes the parent frame")
            if ev.get("px_roi", 0.0) > ev.get("px_full", 0.0) + eps:
                flag("roi_containment", ev,
                     why="ROI pixels exceed the full frame")
            ext = ev.get("det_extent")
            if ext is not None and (ext[0] < -eps or ext[1] < -eps
                                    or ext[2] > W + eps
                                    or ext[3] > H + eps):
                flag("roi_containment", ev, det_extent=list(ext),
                     why="second-pass detection outside the parent frame")
        elif kind == "dispatch":
            n["dispatch"] += 1
            lane = _lane(ev)
            if lane in dead:
                flag("dead_replica_dispatch", ev,
                     marked_at=dead[lane]["t"])
        elif kind == "health_mark":
            dead[_lane(ev)] = ev
        elif kind == "health_restore":
            dead.pop(_lane(ev), None)
        elif kind == "shard_restart":
            # the watchdog restart resets the shard's whole scheduler
            # health mask: every open mark on that shard closes
            for lane in [ln for ln in dead if ln[0] == ev.get("shard")]:
                dead.pop(lane)
        elif kind == "track_export":
            n["track_export"] += 1
            last_export[ev["stream"]] = ev
        elif kind == "track_import":
            n["track_import"] += 1
            s = ev["stream"]
            prev = last_export.get(s)
            if prev is not None and (
                    ev.get("next_id") != prev.get("next_id")
                    or list(ev.get("tids", ())) != list(
                        prev.get("tids", ()))):
                flag("track_continuity", ev,
                     exported={"next_id": prev.get("next_id"),
                               "tids": list(prev.get("tids", ()))},
                     why="imported table does not match the stream's "
                         "latest export")
            pending_migrate.pop(s, None)
        elif kind == "migrate":
            s = ev["stream"]
            if s in last_export:
                # the stream owes its next segment an import of this
                # table; emitting again without one is a re-seed
                pending_migrate[s] = ev
        elif kind == "loan":
            n["loan"] += 1
            loans.setdefault(ev["borrower"], []).append(ev)
        elif kind == "loan_return":
            stack = loans.get(ev["borrower"], [])
            if not stack:
                flag("loan_lifo", ev, why="return without loan")
            elif stack[-1]["lender"] != ev["lender"]:
                flag("loan_lifo", ev, expected=stack[-1]["lender"],
                     why="not the most recent loan (LIFO broken)")
                stack.pop()
            else:
                stack.pop()
            # the returned guest lane is retired; close any open death
            # mark on it so a FUTURE loan creating a fresh guest at the
            # same index isn't falsely flagged
            dead.pop((ev["borrower"], ev["guest"]), None)

    for rid, st in state.items():
        if st is None:
            flag("frame_conservation", None, rid=rid,
                 why="arrived but never emitted/dropped/lost")
    for borrower, stack in loans.items():
        for ev in stack:
            flag("loan_lifo", ev, why="loan never returned")

    emitted = n["emit"] + n["interp_emit"]
    # drops that were later coasted back count as interp_emit terminals,
    # so conservation is over terminal states, not raw counters
    terminal = sum(1 for st in state.values() if st is not None)
    if terminal != n["arrive"] and not violations:
        flag("frame_conservation", None, arrived=n["arrive"],
             terminal=terminal, why="terminal-state count mismatch")

    stats = dict(n)
    stats["emitted"] = emitted
    stats["dropped_final"] = sum(1 for st in state.values()
                                 if st == "drop")
    return AuditResult(violations, stats)


def audit_recorder(recorder) -> AuditResult:
    """Convenience: audit a live ``TraceRecorder``."""
    return audit_events(recorder.events)
