"""Chrome-trace-event export: open a serving trace in Perfetto.

``to_chrome_trace`` converts a recorded event list (plus optional time
series) into the Chrome Trace Event JSON format — load the file at
https://ui.perfetto.dev (or ``chrome://tracing``) to get a zoomable
timeline of the whole serve run:

* one *process* lane per shard (``pid`` = shard index, named
  ``shard<h>``) and one *thread* lane per replica (``tid`` = replica
  index within the shard, named ``replica<r>``; lane 0 of each shard
  doubles as the control/stream lane for instants with no replica),
* a complete-event span (``"ph": "X"``) per completed frame covering
  its service window ``[t0, t0 + service]`` — exactly one span per
  ``complete`` event,
* instant markers (``"ph": "i"``) for drops, retries, failovers, lost
  frames, migrations, loans, health marks and shard kills/restarts,
* counter tracks (``"ph": "C"``) from the recorder's sampled series
  (queue depth, scheduler backlog).

Virtual-time seconds map to microseconds (``ts = t * 1e6``) — the
trace-event format's native unit.  Every emitted traceEvent embeds the
raw recorder event under ``args`` untouched, so a Chrome-format file
round-trips back to an auditable event list via ``events_from_chrome``
(``tools/check_trace.py`` accepts either format).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

#: event kinds rendered as instant markers, and the lane they pin to
_INSTANT_KINDS = ("arrive", "enqueue", "drop", "emit", "interp_emit",
                  "retry", "failover", "lost", "epoch", "migrate",
                  "loan", "loan_return", "health_mark", "health_restore",
                  "shard_down", "shard_restart", "shard_lost")


def _us(t: float) -> float:
    return t * 1e6


def _lane(ev: dict) -> Tuple[int, int]:
    """(pid, tid) for an event: shard lane + replica lane (0 when the
    event has no replica — control-plane / stream events)."""
    pid = ev.get("shard", ev.get("borrower", 0))
    tid = ev.get("replica", ev.get("guest", 0))
    return pid, max(0, tid)


def to_chrome_trace(events: List[dict],
                    series: Optional[Dict[str, list]] = None) -> dict:
    """Build the ``{"traceEvents": [...]}`` document (JSON-ready)."""
    out: List[dict] = []
    lanes = set()

    for ev in sorted(events, key=lambda e: (e["t"], e["i"])):
        kind = ev["kind"]
        pid, tid = _lane(ev)
        lanes.add((pid, tid))
        if kind == "complete":
            t0 = ev.get("t0", ev["t"])
            dur = ev.get("service", max(0.0, ev["t"] - t0))
            out.append({"name": f"frame {ev.get('rid', '?')}",
                        "cat": "service", "ph": "X",
                        "ts": _us(t0), "dur": _us(dur),
                        "pid": pid, "tid": tid, "args": ev})
        elif kind == "dispatch":
            # dispatch marks the span's start; the span itself comes
            # from the matching complete event — keep dispatch as a
            # thin instant so faulted dispatch-less retries stand out
            out.append({"name": "dispatch", "cat": "sched", "ph": "i",
                        "s": "t", "ts": _us(ev["t"]),
                        "pid": pid, "tid": tid, "args": ev})
        elif kind in _INSTANT_KINDS:
            scope = "p" if kind in ("epoch", "shard_down",
                                    "shard_restart") else "t"
            out.append({"name": kind, "cat": "lifecycle", "ph": "i",
                        "s": scope, "ts": _us(ev["t"]),
                        "pid": pid, "tid": tid, "args": ev})
        else:   # unknown kinds still export (forward compatibility)
            out.append({"name": kind, "cat": "other", "ph": "i",
                        "s": "t", "ts": _us(ev["t"]),
                        "pid": pid, "tid": tid, "args": ev})

    for name, pts in (series or {}).items():
        base, _, shard = name.rpartition("/")
        pid = int(shard) if base else 0
        cname = base or name
        for t, v in pts:
            out.append({"name": cname, "cat": "series", "ph": "C",
                        "ts": _us(t), "pid": pid,
                        "args": {cname: v}})

    meta: List[dict] = []
    for pid in sorted({p for p, _ in lanes}):
        meta.append({"name": "process_name", "ph": "M", "pid": pid,
                     "args": {"name": f"shard{pid}"}})
    for pid, tid in sorted(lanes):
        meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                     "tid": tid, "args": {"name": f"replica{tid}"}})

    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def events_from_chrome(doc: dict) -> List[dict]:
    """Recover the raw recorder events embedded in a Chrome-format
    document's ``args`` (inverse of ``to_chrome_trace`` for auditing)."""
    evs = []
    for te in doc.get("traceEvents", []):
        args = te.get("args")
        if isinstance(args, dict) and "kind" in args and "i" in args:
            evs.append(args)
    return evs


def write_chrome_trace(path: str, recorder) -> dict:
    """Export a live recorder to ``path``; returns the document."""
    doc = to_chrome_trace(recorder.events, recorder.series)
    with open(path, "w") as f:
        # event fields are stored unconverted on the hot path; numpy
        # scalars (if a caller's clocks carry them) coerce here instead
        json.dump(doc, f, default=float)
    return doc
