"""Frame-lifecycle trace recording for the serving stack.

The serving engines run on a deterministic *virtual* clock, so a trace
is a deterministic artifact too: re-running the same ``(trace,
FaultSchedule)`` records the same events in the same order, which is
what makes traces regression-assertable (``repro.obs.audit``) and
diffable across PRs.

``TraceRecorder`` is an append-only event log plus named time series.
Every event is a plain dict — cheap to record on the hot path, trivially
JSON-serializable — with at least ``{"i", "kind", "t"}`` where ``i`` is
a monotonically increasing sequence number (the *code-order* tiebreak:
events recorded at equal virtual times sort stably) and ``t`` is virtual
seconds on the serving clock.  The full schema is documented in
``docs/OBSERVABILITY.md``; the kinds are:

frame lifecycle (recorded by ``DetectionEngine`` / ``ServingEngine``
and the schedulers):

* ``arrive``     — frame entered the serve trace (``rid``, ``stream``,
  ``seq``)
* ``enqueue``    — frame admitted to micro-batch ``batch``
* ``dispatch``   — scheduler committed the frame to ``replica`` at
  ``t_start`` (successful assignments only — a faulted attempt records
  ``retry`` instead)
* ``complete``   — service finished (``t0``/``service`` carry the span)
* ``retry`` / ``failover`` / ``lost`` — the scheduler's timeout
  detection outcomes (``core.scheduler``)
* ``drop``       — the engine dropped the frame at arrival
* ``emit`` / ``interp_emit`` — the per-stream reorder buffer released
  the frame (``interp_emit``: a tracker-coasted re-emission)
* ``model_switch`` — the transprecise cascade changed model at a
  micro-batch boundary (``batch``, ``model``); audited: the switch
  must precede every ``enqueue`` of its batch
* ``roi_pass``   — hierarchical second pass over one frame (``rid``,
  ``model``, ``n_rois``, ``px_full``/``px_roi``, the absolute ``rois``
  and ``bounds``, plus the final detections' ``det_extent``); audited
  for containment

control plane (recorded by ``ShardedDetectionEngine`` and ``Watchdog``):

* ``epoch``      — epoch-window boundary (``epoch``)
* ``migrate``    — stream migration (``stream``, ``src``, ``dst``)
* ``loan`` / ``loan_return`` — replica lending (``lender``,
  ``borrower``, ``guest`` = the guest's lane in the borrower's pool)
* ``health_mark`` / ``health_restore`` — a replica suspected dead by
  the timeout rule / restored by ``probe_health``
* ``shard_down`` / ``shard_restart`` — shard-level fault + watchdog
  repair; ``shard_lost`` accounts each frame a down shard lost

The DEFAULT recorder everywhere is ``NULL_RECORDER`` — a no-op whose
``enabled`` flag lets hot paths skip event construction entirely, so an
engine built without a recorder is bit-identical (same virtual clocks,
same report) to one that predates tracing.
"""
from __future__ import annotations

from typing import Dict, List, Tuple


class TraceRecorder:
    """Append-only deterministic event log + named time series.

    ``record`` appends one event dict; ``sample`` appends one ``(t,
    value)`` point to a named series (the engines sample queue depth and
    scheduler backlog at every micro-batch dispatch).  ``shard_view``
    returns a lightweight proxy that stamps ``shard=h`` on everything it
    forwards — the sharded engine hands one view to each shard engine so
    replica/frame events carry their failure domain.

    >>> rec = TraceRecorder()
    >>> rec.record("arrive", 0.5, rid=7, stream=1)
    >>> rec.shard_view(2).record("drop", 1.0, rid=8)
    >>> [(e["kind"], e.get("shard", 0)) for e in rec.events]
    [('arrive', 0), ('drop', 2)]
    """

    enabled = True

    def __init__(self):
        self.events: List[dict] = []
        self.series: Dict[str, List[Tuple[float, float]]] = {}
        self._i = 0

    def record(self, kind: str, t: float, **fields):
        # the kwargs dict is already a fresh allocation — annotate it in
        # place instead of merging into a second dict (this runs once
        # per lifecycle event on the serve hot path)
        fields["kind"] = kind
        fields["t"] = t
        fields["i"] = self._i
        self._i += 1
        self.events.append(fields)

    def sample(self, name: str, t: float, value: float, shard: int = 0):
        """Append one point to the per-shard series ``name`` (stored
        under ``"name/shard"`` so shards never interleave samples)."""
        key = f"{name}/{shard}"
        s = self.series.get(key)
        if s is None:
            s = self.series[key] = []
        s.append((t, value))

    def shard_view(self, shard: int) -> "_ShardView":
        return _ShardView(self, shard)

    def sorted_events(self) -> List[dict]:
        """Events in virtual-time order (code order ``i`` breaks ties),
        the canonical order export and human inspection use.  The audit
        checker uses raw code order — the order decisions were made in."""
        return sorted(self.events, key=lambda e: (e["t"], e["i"]))

    def to_json(self) -> dict:
        """The raw-trace serialization ``tools/check_trace.py`` accepts
        (the Chrome export in ``repro.obs.export`` is the other one)."""
        return {"events": list(self.events),
                "series": {k: [list(p) for p in v]
                           for k, v in self.series.items()}}


class _ShardView:
    """Forwarding proxy that stamps ``shard=h`` on records and samples.
    Shares the parent's log, counter and ``enabled`` flag, so events
    from every shard interleave into one totally-ordered trace."""

    def __init__(self, parent: TraceRecorder, shard: int):
        self._parent = parent
        self.shard = shard

    @property
    def enabled(self) -> bool:
        return self._parent.enabled

    def record(self, kind: str, t: float, **fields):
        # stamp + annotate in place (one kwargs dict per event, no
        # re-expansion through the parent's signature)
        fields.setdefault("shard", self.shard)
        fields["kind"] = kind
        fields["t"] = t
        p = self._parent
        fields["i"] = p._i
        p._i += 1
        p.events.append(fields)

    def sample(self, name: str, t: float, value: float, shard=None):
        self._parent.sample(name, t, value,
                            self.shard if shard is None else shard)

    def shard_view(self, shard: int) -> "_ShardView":
        return _ShardView(self._parent, shard)


class NullRecorder:
    """The default no-op recorder: ``enabled`` is False so every hot
    path skips event construction, keeping the untraced engine
    bit-identical to the pre-tracing one (and paying ~one attribute
    read per would-be event)."""

    enabled = False

    def record(self, kind: str, t: float, **fields):
        pass

    def sample(self, name: str, t: float, value: float, shard: int = 0):
        pass

    def shard_view(self, shard: int) -> "NullRecorder":
        return self

    def sorted_events(self):
        return []

    def to_json(self) -> dict:
        return {"events": [], "series": {}}


#: process-wide default; engines use it whenever ``recorder=None``
NULL_RECORDER = NullRecorder()
