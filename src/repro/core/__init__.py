from .stream import (BENCHMARK_VIDEOS, ADL_RUNDLE_6, ETH_SUNNYDAY,
                     Frame, FrameStream, SyntheticVideo, VideoSpec)
from .executor import (DEVICE_PROFILES, MODEL_PROFILES, DetectorExecutor,
                       DeviceProfile, ModelProfile)
from .scheduler import (FCFSScheduler, LockstepRRScheduler,
                        ProportionalScheduler, WeightedRRScheduler,
                        make_scheduler)
from .simulator import SimResult, simulate
from .synchronizer import SequenceSynchronizer, SyncedFrame
from .parallel import ParallelDetector, choose_n, n_range
from .quality import (ProxyDetector, evaluate_map, evaluate_map_dets,
                      evaluate_map_loop, evaluate_streams,
                      proxy_detect_fn_streams, track_quality)

__all__ = [
    "BENCHMARK_VIDEOS", "ADL_RUNDLE_6", "ETH_SUNNYDAY", "Frame",
    "FrameStream", "SyntheticVideo", "VideoSpec", "DEVICE_PROFILES",
    "MODEL_PROFILES", "DetectorExecutor", "DeviceProfile", "ModelProfile",
    "FCFSScheduler", "LockstepRRScheduler", "ProportionalScheduler",
    "WeightedRRScheduler", "make_scheduler", "SimResult", "simulate",
    "SequenceSynchronizer", "SyncedFrame", "ParallelDetector", "choose_n",
    "n_range", "ProxyDetector", "evaluate_map", "evaluate_map_dets",
    "evaluate_map_loop", "evaluate_streams", "proxy_detect_fn_streams",
    "track_quality",
]
