"""Model-parallel sequence synchronizer (paper §III-A/III-C).

Parallel executors complete frames out of temporal order; the synchronizer
is a reorder buffer that (a) re-establishes the original stream order on
the detection-processed frames, and (b) fills every randomly-dropped frame
with the detection output of the latest processed frame before it (the
paper's stale-reuse semantics — the mechanism behind the mAP drop under
frame dropping).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from .simulator import SimResult


@dataclass
class SyncedFrame:
    index: int
    source_index: int        # which processed frame supplied the detection
    stale: bool              # True if filled from an earlier frame
    t_ready: float           # when the detection became available
    interpolated: bool = False   # True if a tracker synthesized the fill


class SequenceSynchronizer:
    """Offline-friendly implementation over a SimResult; the streaming
    variant (used by examples/video_analytics.py) exposes push/pop with a
    bounded reorder window."""

    def __init__(self, window: int = 64):
        self.window = window

    def order(self, result: SimResult) -> List[SyncedFrame]:
        done_at: Dict[int, float] = {a.frame_idx: a.t_done
                                     for a in result.assignments}
        out: List[SyncedFrame] = []
        last_processed: Optional[int] = None
        last_t = 0.0
        for i in range(result.n_frames):
            if i in done_at:
                last_processed, last_t = i, done_at[i]
                out.append(SyncedFrame(i, i, False, done_at[i]))
            elif last_processed is not None:
                out.append(SyncedFrame(i, last_processed, True, last_t))
            else:
                out.append(SyncedFrame(i, -1, True, 0.0))
        return out

    # ---- streaming interface ------------------------------------------
    def stream(self, result: SimResult, tracked: bool = False):
        """Yield SyncedFrames in order as their detections become ready,
        respecting a bounded reorder window (emits a stale fill if a frame
        hasn't completed by the time the window slides past it).

        ``tracked=True`` streams the ``order_tracked`` tagging (dropped
        frames marked ``interpolated``); either way the flag is carried
        through on the re-yielded frames instead of being reset."""
        ordered = self.order_tracked(result) if tracked else self.order(result)
        emit_t = 0.0
        for sf in ordered:
            emit_t = max(emit_t, sf.t_ready)
            yield SyncedFrame(sf.index, sf.source_index, sf.stale, emit_t,
                              interpolated=sf.interpolated)

    def order_tracked(self, result: SimResult) -> List[SyncedFrame]:
        """Arrival-order output for the track-and-interpolate mode:
        processed frames are emitted as usual; every dropped frame is
        tagged ``interpolated`` — its boxes come from the tracker's
        coasted prediction instead of replaying ``source_index``
        (which is kept as the last frame that fed the tracker, i.e.
        the prediction's information horizon; -1 before the first
        processed frame, where the coasted table is still empty)."""
        return [SyncedFrame(sf.index, sf.source_index, sf.stale,
                            sf.t_ready, interpolated=sf.stale)
                for sf in self.order(result)]

    # ---- multi-camera (NVR) interface ---------------------------------
    @staticmethod
    def order_per_stream(responses):
        """Per-stream arrival-order emit for multi-camera serving: group
        engine responses by ``stream_id``, re-establish each camera's
        arrival order (``seq``), and attach a monotonic per-stream emit
        clock (a frame is never released before an earlier frame of the
        SAME stream — the reorder buffer is per camera, so one slow
        camera never holds back another).

        Returns ``{stream_id: (ordered_responses, emit_times)}``.
        """
        by_stream: Dict[int, List] = {}
        for r in responses:
            by_stream.setdefault(getattr(r, "stream_id", 0), []).append(r)
        out = {}
        for sid, rs in by_stream.items():
            rs.sort(key=lambda r: (getattr(r, "seq", -1), r.rid))
            emit_t, emits = 0.0, []
            for r in rs:
                emit_t = max(emit_t, r.t_done)
                emits.append(emit_t)
            out[sid] = (rs, emits)
        return out

    def output_fps(self, result: SimResult) -> float:
        frames = self.order(result)
        if not frames:
            return 0.0
        t_last = max(f.t_ready for f in frames)
        return len([f for f in frames if not f.stale]) / max(t_last, 1e-9)
