"""Parallel detection controller: n-selection (paper §III-B) + the
end-to-end pipeline facade (stream -> scheduler -> executors ->
synchronizer -> quality/FPS report).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from .executor import (DEVICE_PROFILES, MODEL_PROFILES, DetectorExecutor,
                       DeviceProfile)
from .quality import (ProxyDetector, evaluate_map, evaluate_map_dets,
                      track_quality)
from .scheduler import make_scheduler
from .simulator import SimResult, simulate
from .stream import BENCHMARK_VIDEOS, FrameStream, SyntheticVideo, VideoSpec
from .synchronizer import SequenceSynchronizer

HUMAN_COMFORT_FPS = 10.0   # paper: 10-30 FPS comfortable for street view


def n_range(lam: float, mu: float) -> tuple[int, int]:
    """Paper §III-B: n ∈ [⌈10/μ⌉, ⌈λ/μ⌉] when λ > 12 FPS (else the
    conservative single bound ⌈λ/μ⌉)."""
    hi = math.ceil(lam / mu)
    if lam > 12.0:
        lo = min(math.ceil(HUMAN_COMFORT_FPS / mu), hi)
    else:
        lo = hi
    return lo, hi


def choose_n(lam: float, mu: float,
             mode: str = "near_real_time") -> int:
    lo, hi = n_range(lam, mu)
    return lo if mode == "near_real_time" else hi


@dataclass
class Report:
    video: str
    model: str
    scheduler: str
    n: int
    sigma: float           # achieved detection processing FPS (σ_P)
    map_score: float
    drop_rate: float
    drops_per_processed: float
    offline: bool = False
    # track-and-interpolate mode (run(track=True)): mAP of the tracked
    # output stream, fraction of object-frames a track covered, and the
    # tracker's identity-switch count
    map_tracked: float = float("nan")
    track_coverage: float = float("nan")
    id_switches: float = float("nan")

    def row(self):
        return (f"{self.video},{self.model},{self.scheduler},{self.n},"
                f"{self.sigma:.2f},{self.map_score*100:.1f},"
                f"{self.drop_rate*100:.1f}")


class ParallelDetector:
    """The paper's EVA pipeline with calibrated device profiles.

    ``model`` may be a single detector name or one per device — the
    heterogeneous-models deployment the paper sketches as its third design
    alternative (§III-A) and "ongoing work" (§V): e.g. YOLOv3 on the fast
    CPU and SSD300 on the NCS2 sticks.  mAP is then scored per frame with
    the noise profile of the model that actually processed it."""

    def __init__(self, video: VideoSpec | str,
                 model: str | Sequence[str] = "yolov3",
                 devices: Sequence[str] = ("ncs2",),
                 scheduler: str = "fcfs", interface: str = "usb3",
                 host_overhead: float = 0.002, jitter: float = 0.0,
                 seed: int = 0):
        spec = BENCHMARK_VIDEOS[video] if isinstance(video, str) else video
        self.spec = spec
        self.video = SyntheticVideo(spec)
        models = ([model] * len(devices) if isinstance(model, str)
                  else list(model))
        assert len(models) == len(devices), (models, devices)
        self.model = models[0] if len(set(models)) == 1 else "mixed"
        self.scheduler_kind = scheduler
        self.executors = [
            DetectorExecutor(DEVICE_PROFILES[d], MODEL_PROFILES[m],
                             interface=interface, jitter=jitter,
                             seed=seed + i)
            for i, (d, m) in enumerate(zip(devices, models))]
        self.scheduler = make_scheduler(scheduler, self.executors,
                                        host_overhead=host_overhead)
        self.sync = SequenceSynchronizer()
        self.detector = ProxyDetector(models[0], spec.name, seed=seed)
        self.detectors = [ProxyDetector(m, spec.name, seed=seed)
                          for m in models]

    def _fresh_scheduler(self):
        for e in self.executors:
            e.busy_until = 0.0
            e.n_processed = 0
            e.ewma_service = None
        return make_scheduler(self.scheduler_kind, self.executors,
                              host_overhead=self.scheduler.host_overhead)

    def run(self, offline: bool = False, with_map: bool = True,
            track: bool = False) -> Report:
        """σ_P ("Detection FPS" in the paper's tables) is the saturated
        processing capacity — the paper feeds the stored test video and
        measures processing rate, so n=7 can exceed λ.  Drop rate and mAP
        come from the λ-paced online run.

        ``track=True`` additionally runs the batched tracker over the
        paced run (``repro.tracking.fill_stream``): dropped frames get
        tracker-coasted boxes instead of stale reuse, and the report
        gains the tracked stream's mAP plus ID-switch / coverage
        counters — the offline-reference comparison extended to the
        tracked stream."""
        if offline:
            result = simulate(FrameStream(self.video), self.scheduler,
                              offline=True)
            synced = self.sync.order(result)
            m = evaluate_map(self.video, synced, self.detector) if with_map \
                else float("nan")
            return Report(self.spec.name, self.model, self.scheduler_kind,
                          len(self.executors), result.sigma, m,
                          result.drop_rate, result.drops_per_processed,
                          offline=True)
        # capacity: the paper measures Detection FPS on the stored video,
        # i.e. frames are always buffered and ready -> blocking dispatch
        # through the scheduler's own policy
        cap = simulate(FrameStream(self.video), self._fresh_scheduler(),
                       offline=True)
        paced = simulate(FrameStream(self.video), self._fresh_scheduler())
        synced = self.sync.order(paced)
        det_by_frame = {a.frame_idx: self.detectors[a.executor_idx]
                        for a in paced.assignments}
        m = evaluate_map(self.video, synced, self.detector,
                         det_by_frame=det_by_frame) if with_map \
            else float("nan")
        report = Report(self.spec.name, self.model, self.scheduler_kind,
                        len(self.executors), cap.sigma, m,
                        paced.drop_rate, paced.drops_per_processed)
        if track:
            from ..tracking import fill_stream   # lazy: avoids cycles
            tracked = fill_stream(self.video, paced, self.detector,
                                  det_by_frame=det_by_frame)
            tq = track_quality(self.video, tracked)
            report.map_tracked = evaluate_map_dets(self.video, tracked)
            report.track_coverage = tq["coverage"]
            report.id_switches = tq["id_switches"]
        return report
