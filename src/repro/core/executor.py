"""Detection-model executors and device/interface profiles.

Device service rates (μ, FPS) and TDP come straight from the paper's
Tables IV–IX (measured on real hardware by the authors); the executor can
alternatively *measure* service time by running a real JAX model on this
host.  Interface goodput is calibrated from Table IX: the per-frame USB-2.0
penalty the paper measured (1/1.9 − 1/2.5 ≈ 126 ms for YOLOv3-class
inputs) implies ≈ 8.4 MB/s effective NCS2 goodput on USB 2.0; USB 3.0 is
effectively unconstrained at these frame sizes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import numpy as np


@dataclass(frozen=True)
class ModelProfile:
    """A pre-trained detector (paper Table II)."""
    name: str
    input_size: int          # square input resolution
    channels: int = 3
    bytes_per_px: int = 2    # FP16 deployment on NCS2
    model_size_mb: float = 0.0
    base_map: float = 0.0    # zero-drop reference mAP (paper Tables IV/V)

    @property
    def frame_bytes(self) -> int:
        return self.input_size * self.input_size * self.channels \
            * self.bytes_per_px


MODEL_PROFILES = {
    "ssd300": ModelProfile("ssd300", 300, model_size_mb=51, base_map=0.745),
    "yolov3": ModelProfile("yolov3", 416, model_size_mb=119, base_map=0.869),
}


@dataclass(frozen=True)
class DeviceProfile:
    """An edge AI device (paper Tables III & VI)."""
    name: str
    tdp_watts: float
    # per-model zero-drop service rate μ (FPS), from the paper's tables
    fps: dict = field(default_factory=dict)

    def mu(self, model: str) -> float:
        return self.fps[model]


DEVICE_PROFILES = {
    "ncs2": DeviceProfile("ncs2", 2.0, {"ssd300": 2.3, "yolov3": 2.5}),
    "fast_cpu": DeviceProfile("fast_cpu", 125.0,
                              {"ssd300": 12.0, "yolov3": 13.5}),
    "slow_cpu": DeviceProfile("slow_cpu", 15.0,
                              {"ssd300": 0.5, "yolov3": 0.4}),
    "gpu_titanx": DeviceProfile("gpu_titanx", 250.0,
                                {"ssd300": 46.0, "yolov3": 35.0}),
}

# effective host->accelerator goodput in bytes/s (calibration in docstring)
INTERFACE_GOODPUT = {
    "usb2": 8.4e6,
    "usb3": 8.4e6 * (5.0 / 0.48),     # scales with the 5 Gbps/480 Mbps ratio
    "pcie": 1e12,                      # host-local (CPU/GPU): no penalty
}


@dataclass(eq=False)
class DetectorExecutor:
    """One parallel detection model instance bound to one device.

    Service time = compute (1/μ) + interface transfer (frame_bytes/goodput),
    with optional lognormal jitter; or measured from a real `infer_fn`.
    """
    device: DeviceProfile
    model: ModelProfile
    interface: str = "usb3"
    jitter: float = 0.0            # relative stddev of service time
    infer_fn: Optional[Callable] = None   # real JAX inference (measured)
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.busy_until = 0.0
        self.n_processed = 0
        self.ewma_service = None   # fed back to the proportional scheduler
        self.faults = None         # optional serving.faults.ReplicaFaultView

    @property
    def mu_effective(self) -> float:
        t = 1.0 / self.device.mu(self.model.name)
        t += self.model.frame_bytes / INTERFACE_GOODPUT[self.interface]
        return 1.0 / t

    def service_time(self, frame=None, t=None) -> float:
        """Virtual service seconds for one frame; ``t`` (the virtual
        dispatch time, passed by the scheduler) only matters when a
        fault view is attached — injected slowdowns multiply the base
        time and a dead replica reports infinity, which the scheduler's
        timeout rule detects."""
        if self.infer_fn is not None and frame is not None:
            t0 = time.perf_counter()
            self.infer_fn(frame)
            return time.perf_counter() - t0
        s = 1.0 / self.mu_effective
        if self.jitter > 0:
            sigma = self.jitter
            s *= float(self._rng.lognormal(-0.5 * sigma ** 2, sigma))
        if self.faults is not None and t is not None:
            if not self.faults.alive(t):
                return float("inf")
            s *= self.faults.factor(t)
        return s

    def record(self, t_service: float):
        self.n_processed += 1
        a = 0.2
        self.ewma_service = (t_service if self.ewma_service is None
                             else (1 - a) * self.ewma_service + a * t_service)
