"""Video stream model: frames arriving at λ FPS, plus a synthetic benchmark
video generator with moving-object ground truth (stands in for the MOT-15
clips, which are not available offline).

The two benchmark specs mirror the paper's Table I:
  ADL-Rundle-6 : 30 FPS, 525 frames, 1920x1080, static camera
  ETH-Sunnyday : 14 FPS, 354 frames,  640x480, moving camera
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class VideoSpec:
    name: str
    fps: float              # λ — incoming video stream rate
    n_frames: int
    width: int
    height: int
    moving_camera: bool
    n_objects: int = 8
    seed: int = 0
    # object / camera speed as a fraction of frame width per frame
    obj_speed: float = 0.002
    cam_speed: float = 0.0025


ADL_RUNDLE_6 = VideoSpec("ADL-Rundle-6", 30.0, 525, 1920, 1080,
                         moving_camera=False, n_objects=10, seed=6,
                         obj_speed=0.002, cam_speed=0.0)
ETH_SUNNYDAY = VideoSpec("ETH-Sunnyday", 14.0, 354, 640, 480,
                         moving_camera=True, n_objects=8, seed=3,
                         obj_speed=0.0025, cam_speed=0.002)
BENCHMARK_VIDEOS = {v.name: v for v in (ADL_RUNDLE_6, ETH_SUNNYDAY)}


@dataclass
class Frame:
    index: int
    t_arrival: float         # seconds since stream start (= index / fps)
    boxes: np.ndarray        # ground-truth (K, 4) xyxy, pixel coords
    classes: np.ndarray      # (K,) int class ids


class SyntheticVideo:
    """Objects move with constant velocity + camera pan (moving cameras get
    a global drift, which makes stale-reused detections decay faster —
    exactly the effect the paper shows on ETH-Sunnyday)."""

    N_CLASSES = 3  # person / bicycle / car — the classes the paper shows

    def __init__(self, spec: VideoSpec):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        W, H, K = spec.width, spec.height, spec.n_objects
        self.sizes = np.stack([rng.uniform(0.04, 0.12, K) * W,
                               rng.uniform(0.10, 0.25, K) * H], -1)
        self.pos0 = np.stack([rng.uniform(0.1, 0.9, K) * W,
                              rng.uniform(0.2, 0.8, K) * H], -1)
        # pedestrian-ish speeds: a few px/frame at the video's native fps
        speed = spec.obj_speed * W
        ang = rng.uniform(0, 2 * np.pi, K)
        self.vel = np.stack([np.cos(ang), np.sin(ang)], -1) * \
            rng.uniform(0.5, 1.5, (K, 1)) * speed
        self.cam_vel = np.array([spec.cam_speed * W, 0.0])
        self.classes = rng.integers(0, self.N_CLASSES, K)

    def boxes_at(self, frame_idx: int) -> np.ndarray:
        W, H = self.spec.width, self.spec.height
        centers = self.pos0 + frame_idx * (self.vel + self.cam_vel)
        # bounce off frame edges (keeps objects in view)
        span = np.array([W, H], float)
        centers = np.abs(np.mod(centers, 2 * span) - span)
        half = self.sizes / 2
        return np.concatenate([centers - half, centers + half], -1)

    def boxes_at_many(self, frame_idx: np.ndarray) -> np.ndarray:
        """Ground truth for many frames at once: (F,) indices ->
        (F, K, 4) xyxy.  Same math as ``boxes_at`` with the frame axis
        broadcast, so quality evaluation fetches all its GT in one call."""
        idx = np.asarray(frame_idx, float)[:, None, None]
        centers = self.pos0[None] + idx * (self.vel + self.cam_vel)[None]
        span = np.array([self.spec.width, self.spec.height], float)
        centers = np.abs(np.mod(centers, 2 * span) - span)
        half = (self.sizes / 2)[None]
        return np.concatenate([centers - half, centers + half], -1)

    def frame(self, i: int) -> Frame:
        return Frame(i, i / self.spec.fps, self.boxes_at(i), self.classes)

    def pixels(self, i: int, size: int = 64) -> np.ndarray:
        """Render a small frame tensor (for real-inference executors)."""
        img = np.zeros((size, size, 3), np.float32)
        boxes = self.boxes_at(i)
        sx, sy = size / self.spec.width, size / self.spec.height
        for b, c in zip(boxes, self.classes):
            x0, y0 = int(b[0] * sx), int(b[1] * sy)
            x1, y1 = max(int(b[2] * sx), x0 + 1), max(int(b[3] * sy), y0 + 1)
            img[max(y0, 0):y1, max(x0, 0):x1, c % 3] = 1.0
        return img


class FrameStream:
    """The live stream: frames with arrival timestamps at λ FPS."""

    def __init__(self, video: SyntheticVideo):
        self.video = video
        self.fps = video.spec.fps

    def __iter__(self):
        for i in range(self.video.spec.n_frames):
            yield self.video.frame(i)

    def __len__(self):
        return self.video.spec.n_frames
