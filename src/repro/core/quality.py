"""Detection quality model + real mAP evaluation.

MOT-15 videos and pretrained SSD/YOLO weights are not available
offline, so detection outputs come from a *proxy detector*: a
well-trained detector is modelled as ground truth + localization jitter +
misses + false positives, with noise levels per model class (SSD300 is
noisier than YOLOv3, matching the paper's mAP ordering).  The mAP math
(greedy IoU matching + all-point-interpolated AP) is real — and the
paper's central quality effect is mechanical: dropped frames reuse stale
detections, object motion decays their IoU against the current frame, and
mAP falls exactly as in Tables IV/V.

``evaluate_map`` is the vectorized scorer (batched GT fetch, per-source
class partitioning, argmax-based greedy matcher); ``evaluate_map_loop``
keeps the seed's Python-loop implementation as the equality oracle.
``evaluate_map_dets`` scores a stream whose per-frame detections are
given explicitly (the tracked/interpolated stream), and
``track_quality`` adds the tracker-identity counters (ID switches,
object coverage, fragmentation).

Noise synthesis is a batched counter-based sampler (splitmix64-style
hashing -> uniforms -> Box-Muller normals / inverse-CDF Poisson): every
frame's detections are a pure function of (model, seed, frame) — batch
composition and evaluation order can't change them — and a whole run's
noise is drawn in a handful of vectorized calls instead of per-frame
PCG streams.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence
from zlib import crc32

import numpy as np

from .stream import SyntheticVideo
from .synchronizer import SyncedFrame

# (center jitter, size jitter, miss rate, false positives per frame)
NOISE = {
    # max_miss_diff caps how much scene difficulty compounds the miss rate
    # (SSD's recall is already low; the paper's ADL/ETH gap is mostly
    # localization+precision for SSD, recall for YOLO)
    "yolov3": dict(c=0.05, s=0.055, miss=0.13, fp=0.5, max_miss_diff=99.0),
    "ssd300": dict(c=0.06, s=0.07, miss=0.28, fp=1.3, max_miss_diff=1.5),
    # tiny-YOLO band for the transprecise cascade's fast first pass:
    # clearly worse than both paper models (high miss, noisy fps) so
    # the fast/medium/heavy quality ordering is strict
    "yolov3_tiny": dict(c=0.08, s=0.09, miss=0.38, fp=2.0,
                        max_miss_diff=1.3),
}
# per-video difficulty multiplier (ADL-Rundle-6 is the harder scene in the
# paper: 1080p static camera, more/smaller objects)
DIFFICULTY = {"ADL-Rundle-6": 2.8, "ETH-Sunnyday": 1.0}


@dataclass
class Detections:
    boxes: np.ndarray      # (K, 4) xyxy
    classes: np.ndarray    # (K,)
    scores: np.ndarray     # (K,)


# ------------------------------------------------ counter-based sampler
# splitmix64-style finalizer over uint64 arrays: every random draw is
# keyed by (frame key, stream id, element index), so the sampler is a
# pure function of the frame — batchable to any width with zero state.
_G = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)
_M3 = np.uint64(0xD6E8FEB86659FD93)


def _mix64(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> np.uint64(30))) * _M1
    x = (x ^ (x >> np.uint64(27))) * _M2
    return x ^ (x >> np.uint64(31))


def _uniform(keys: np.ndarray, stream: int, n: int) -> np.ndarray:
    """keys (F,) uint64 -> (F, n) uniforms in [0, 1)."""
    e = np.arange(1, n + 1, dtype=np.uint64)
    with np.errstate(over="ignore"):     # uint64 wraparound is the point
        h = _mix64(keys[:, None] + _G * np.uint64(stream)
                   + _M3 * e[None, :])
    return (h >> np.uint64(11)) * (1.0 / (1 << 53))


def _normal(keys: np.ndarray, stream: int, n: int) -> np.ndarray:
    """Box-Muller over two uniform streams -> (F, n) standard normals."""
    u1 = _uniform(keys, stream, n)
    u2 = _uniform(keys, stream + 1, n)
    return np.sqrt(-2.0 * np.log1p(-u1)) * np.cos(2.0 * np.pi * u2)


def _poisson(keys: np.ndarray, stream: int, lam: float,
             kmax: int = 16) -> np.ndarray:
    """Inverse-CDF Poisson(lam) -> (F,) ints in [0, kmax]."""
    u = _uniform(keys, stream, 1)[:, 0]
    k = np.arange(kmax + 1, dtype=float)
    pmf = np.exp(-lam) * np.cumprod(np.concatenate(
        [[1.0], lam / k[1:]]))
    cdf = np.cumsum(pmf)
    return np.minimum((u[:, None] >= cdf[None, :]).sum(-1), kmax)


_FP_MAX = 16   # Poisson tail cap (P(N>16) < 1e-7 at the rates in NOISE)


class ProxyDetector:
    def __init__(self, model: str, video_name: str, seed: int = 0):
        self.noise = NOISE[model]
        self.diff = DIFFICULTY.get(video_name, 1.0)
        self.model = model
        self.seed = seed
        # crc32, not hash(): string hashing is randomized per process
        # (PYTHONHASHSEED), which made mAP values — and the paper-band
        # tests — flap from run to run
        self._base = (crc32(f"{model}/{seed}".encode()) & 0xFFFF) * 100003
        self._memo: Dict[int, Detections] = {}
        self._memo_video: SyntheticVideo | None = None

    def detect(self, video: SyntheticVideo, frame_idx: int) -> Detections:
        return self.detect_many(video, [frame_idx])[0]

    def detect_many(self, video: SyntheticVideo,
                    frame_idxs) -> List[Detections]:
        """Detections for many frames at once: the whole batch's noise is
        synthesized in one vectorized pass.  Detection is a pure function
        of (model, seed, video, frame): results are memoized so repeated
        evaluations (offline + paced runs, benchmark sweeps) pay the
        synthesis once per frame; the cache resets when a different video
        object comes through."""
        if video is not self._memo_video:
            self._memo = {}
            self._memo_video = video
        missing = sorted({int(i) for i in frame_idxs} - self._memo.keys())
        if missing:
            self._synthesize(video, np.asarray(missing, np.int64))
        return [self._memo[int(i)] for i in frame_idxs]

    def _synthesize(self, video: SyntheticVideo, idx: np.ndarray):
        n = self.noise
        F, K = len(idx), len(video.classes)
        keys = _mix64(np.uint64(self._base) + idx.astype(np.uint64))
        gt = video.boxes_at_many(idx)                    # (F, K, 4)
        # difficulty scales misses/false-positives strongly but jitter only
        # mildly, so harder scenes lower the mAP plateau without putting
        # every match at the IoU-threshold cliff
        jit = 1.0 + 0.3 * (self.diff - 1.0)
        miss_diff = min(self.diff, n["max_miss_diff"])
        keep = _uniform(keys, 0, K) >= min(n["miss"] * miss_diff, 0.9)
        wh = gt[..., 2:] - gt[..., :2]
        center = (gt[..., :2] + gt[..., 2:]) / 2
        center = center + _normal(keys, 1, K * 2).reshape(F, K, 2) \
            * (n["c"] * jit) * wh
        wh = wh * np.exp(_normal(keys, 3, K * 2).reshape(F, K, 2)
                         * (n["s"] * jit))
        boxes = np.concatenate([center - wh / 2, center + wh / 2], -1)
        scores = 0.55 + _uniform(keys, 5, K) * (0.99 - 0.55)
        # false positives
        n_fp = _poisson(keys, 6, n["fp"] * self.diff, _FP_MAX)
        W, H = video.spec.width, video.spec.height
        fp_wh = np.stack(
            [(0.03 + _uniform(keys, 7, _FP_MAX) * 0.12) * W,
             (0.06 + _uniform(keys, 8, _FP_MAX) * 0.24) * H], -1)
        fp_c = np.stack([_uniform(keys, 9, _FP_MAX) * W,
                         _uniform(keys, 10, _FP_MAX) * H], -1)
        fp_boxes = np.concatenate([fp_c - fp_wh / 2, fp_c + fp_wh / 2], -1)
        fp_cls = (_uniform(keys, 11, _FP_MAX)
                  * video.N_CLASSES).astype(np.int64)
        fp_sc = 0.1 + _uniform(keys, 12, _FP_MAX) * (0.65 - 0.1)
        for f, i in enumerate(idx):
            k, m = keep[f], int(n_fp[f])
            self._memo[int(i)] = Detections(
                np.concatenate([boxes[f][k], fp_boxes[f][:m]], 0),
                np.concatenate([video.classes[k], fp_cls[f][:m]]),
                np.concatenate([scores[f][k], fp_sc[f][:m]]))


class _IdentityFrameOf:
    """rid -> (stream 0, frame rid): the single-stream ``frame_of``
    mapping without materializing a dict."""

    def __getitem__(self, rid):
        return (0, int(rid))


def proxy_detect_fn(video: SyntheticVideo, detector: ProxyDetector,
                    max_out: int = 24):
    """Bridge a ProxyDetector into ``serving.DetectionEngine``'s
    ``detect_fn`` interface: an ``(images, rids) -> (boxes, scores,
    classes, valid)`` callable that looks detections up by frame id
    (rid) instead of running the mini-SSD — the oracle detector the
    engine tests and ``benchmarks/tracking_bench.py`` share.  The
    single-stream special case of ``proxy_detect_fn_streams`` (rid ==
    frame index, one camera)."""
    return proxy_detect_fn_streams({0: video}, {0: detector},
                                   _IdentityFrameOf(), max_out)


def proxy_detect_fn_streams(videos: Dict[int, SyntheticVideo],
                            detectors: Dict[int, ProxyDetector],
                            frame_of: Dict[int, tuple],
                            max_out: int = 24):
    """Multi-camera oracle for ``DetectionEngine.detect_fn``: ``rid`` is
    globally unique across cameras, so ``frame_of`` maps it back to
    ``(stream_id, per-stream frame index)`` and each camera's proxy
    detector answers for its own video.  Batches are grouped per
    detector so every model still pays one vectorized noise-synthesis
    call per micro-batch."""
    def detect(images, rids):
        B = len(images)
        per_det: Dict[int, List[int]] = {}
        for rid in rids:
            if rid < 0:
                continue
            sid, k = frame_of[rid]
            per_det.setdefault(sid, []).append(k)
        for sid, ks in per_det.items():
            detectors[sid].detect_many(videos[sid], ks)
        boxes = np.zeros((B, max_out, 4), np.float32)
        scores = np.zeros((B, max_out), np.float32)
        classes = np.zeros((B, max_out), np.int32)
        valid = np.zeros((B, max_out), bool)
        for i, rid in enumerate(rids):
            if rid < 0:                     # batch padding row
                continue
            sid, k = frame_of[rid]
            d = detectors[sid].detect(videos[sid], k)
            n = min(len(d.boxes), max_out)
            boxes[i, :n] = d.boxes[:n]
            scores[i, :n] = d.scores[:n]
            classes[i, :n] = d.classes[:n]
            valid[i, :n] = True
        return boxes, scores, classes, valid
    return detect


@dataclass
class _TrackedView:
    """Minimal per-frame view for ``track_quality`` over engine
    responses (index/boxes/track_ids triple)."""
    index: int
    boxes: np.ndarray
    track_ids: np.ndarray


def evaluate_streams(videos, streams: Dict[int, Sequence],
                     n_frames: int, iou_thr: float = 0.5) -> Dict:
    """Per-stream quality aggregation for multi-camera serving: each
    camera's responses (the engine report's ``streams`` entry, ordered
    by per-stream ``seq``) are scored independently against that
    camera's video — mAP over the camera's arrival-frame sequence
    (``evaluate_map_dets``; frames with no response still count in the
    recall denominator) and tracker-identity counters
    (``track_quality``) — plus cross-stream aggregates.

    ``videos`` is either one ``SyntheticVideo`` shared by every camera
    or a ``{stream_id: video}`` dict; EdgeNet-style accounting: compute
    is shared, accuracy stays per-stream.

    Sharded serving needs no variant of this function: streams are
    disjoint across shards, so the ``streams`` key of a merged
    ``ShardedDetectionEngine`` report scores identically to the
    per-shard reports scored separately — per-stream quality is
    invariant to WHICH shard served a camera."""
    per: Dict[int, Dict[str, float]] = {}
    for sid, resp in streams.items():
        video = videos[sid] if isinstance(videos, dict) else videos
        dets: List = [None] * n_frames
        tracked: List[_TrackedView] = []
        for r in resp:
            if not 0 <= r.seq < n_frames:
                raise ValueError(
                    f"stream {sid}: response rid={r.rid} has "
                    f"seq={r.seq} outside [0, {n_frames}) — only "
                    "engine-produced streams (DetectionEngine sets "
                    "seq) or responses with seq set explicitly can "
                    "be scored")
            v = np.asarray(r.valid, bool)
            d = Detections(np.asarray(r.boxes)[v],
                           np.asarray(r.classes)[v],
                           np.asarray(r.scores)[v])
            dets[r.seq] = d
            tids = (np.asarray(r.track_ids)[v]
                    if r.track_ids is not None
                    else np.full(int(v.sum()), -1, np.int64))
            tracked.append(_TrackedView(r.seq, d.boxes, tids))
        tq = track_quality(video, tracked, iou_thr)
        per[sid] = {"map": evaluate_map_dets(video, dets, iou_thr), **tq}
    maps = [v["map"] for v in per.values()]
    covs = [v["coverage"] for v in per.values()]
    return {
        "per_stream": per,
        "map_mean": float(np.mean(maps)) if maps else 0.0,
        "map_min": float(np.min(maps)) if maps else 0.0,
        "coverage_mean": float(np.mean(covs)) if covs else 0.0,
        "id_switches_total": float(sum(v["id_switches"]
                                       for v in per.values())),
    }


def responses_to_detections(responses, n_frames: int) -> List:
    """Engine responses -> the per-arrival-frame ``Detections`` list
    ``evaluate_map_dets`` scores (None for frames with no response)."""
    per: List = [None] * n_frames
    for r in responses:
        v = np.asarray(r.valid, bool)
        per[r.rid] = Detections(np.asarray(r.boxes)[v],
                                np.asarray(r.classes)[v],
                                np.asarray(r.scores)[v])
    return per


def iou_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(N,4) x (M,4) xyxy -> (N,M) IoU.  (The Pallas kernel in
    repro/kernels/iou.py implements this tiled for TPU.)"""
    if len(a) == 0 or len(b) == 0:
        return np.zeros((len(a), len(b)))
    tl = np.maximum(a[:, None, :2], b[None, :, :2])
    br = np.minimum(a[:, None, 2:], b[None, :, 2:])
    inter = np.prod(np.clip(br - tl, 0, None), -1)
    area_a = np.prod(a[:, 2:] - a[:, :2], -1)
    area_b = np.prod(b[:, 2:] - b[:, :2], -1)
    return inter / np.maximum(area_a[:, None] + area_b[None] - inter, 1e-9)


def average_precision(tp: np.ndarray, scores: np.ndarray,
                      n_gt: int) -> float:
    if n_gt == 0 or len(tp) == 0:
        return 0.0
    order = np.argsort(-scores)
    tp = tp[order]
    cum_tp = np.cumsum(tp)
    recall = cum_tp / n_gt
    precision = cum_tp / (np.arange(len(tp)) + 1)
    # all-point interpolation (running max from the right, vectorized)
    mrec = np.concatenate([[0.0], recall, [1.0]])
    mpre = np.concatenate([[1.0], precision, [0.0]])
    mpre = np.maximum.accumulate(mpre[::-1])[::-1]
    idx = np.where(mrec[1:] != mrec[:-1])[0]
    return float(np.sum((mrec[idx + 1] - mrec[idx]) * mpre[idx + 1]))


def _batched_iou(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """a (F, D, 4) x b (F, K, 4) -> (F, D, K) IoU."""
    tl = np.maximum(a[:, :, None, :2], b[:, None, :, :2])
    br = np.minimum(a[:, :, None, 2:], b[:, None, :, 2:])
    inter = np.prod(np.clip(br - tl, 0, None), -1)
    aa = np.prod(a[:, :, 2:] - a[:, :, :2], -1)
    ab = np.prod(b[:, :, 2:] - b[:, :, :2], -1)
    return inter / np.maximum(aa[:, :, None] + ab[:, None, :] - inter, 1e-9)


def _batched_greedy_tp(fb: np.ndarray, fs: np.ndarray, gt: np.ndarray,
                       iou_thr: float):
    """Batched greedy matcher: fb (F, Dmax, 4) score-sorted padded
    detection boxes, fs (F, Dmax) scores (-inf padding), gt (F, K, 4)
    -> (tp (F, Dmax) float, real (F, Dmax) bool).

    The seed walked detections in score order and matched each against
    the *single* best-IoU ground-truth box (a second-best box never
    rescues a detection whose best box is taken), so the match rule is
    separable: a detection is TP iff its best-IoU box clears the
    threshold AND no earlier (higher-score) detection in the same frame
    claimed the same box — one argmax plus a triangular first-claim
    mask, batched over frames."""
    d_max = fb.shape[1]
    real = np.isfinite(fs)
    ious = _batched_iou(fb, gt)                        # (F, Dmax, K)
    jb = np.argmax(ious, -1)                           # best gt per det
    best = np.take_along_axis(ious, jb[..., None], -1)[..., 0]
    ok = (best >= iou_thr) & real
    # first claim wins: det i is blocked if an earlier (higher-score)
    # qualified det j < i targets the same gt box
    same = jb[:, :, None] == jb[:, None, :]            # (F, i, j)
    earlier = np.tril(np.ones((d_max, d_max), bool), -1)
    blocked = np.any(same & ok[:, None, :] & earlier[None], -1)
    tp = (ok & ~blocked).astype(float)
    return tp, real


def evaluate_map(video: SyntheticVideo, synced: Sequence[SyncedFrame],
                 detector: ProxyDetector, iou_thr: float = 0.5,
                 det_by_frame: Dict[int, ProxyDetector] | None = None
                 ) -> float:
    """Vectorized mAP over all frames of the output stream (identical
    result to ``evaluate_map_loop``, the seed implementation kept below
    as the oracle): processed frames score their own detections; dropped
    frames score the stale reused detections against the *current*
    frame's ground truth.  ``det_by_frame`` scores each processed frame
    with the model that ran it (heterogeneous-model deployments).

    Vectorization: detections per unique source frame are synthesized and
    class-partitioned once (one batched sampler call per detector);
    ground truth for every output frame comes from one batched
    ``boxes_at_many`` call; and the per-frame/per-class Python greedy-
    matching loops collapse into ONE batched matcher per class over all
    frames at once (``_batched_greedy_tp``).
    """
    C = video.N_CLASSES
    gt_cls = video.classes
    cls_masks = [gt_cls == c for c in range(C)]
    n_gt = {c: len(synced) * int(np.sum(m))
            for c, m in enumerate(cls_masks)}

    # detections per unique source frame, class-partitioned + score-sorted
    # once (the same (D, 4) arrays serve every output frame that reuses
    # this source, stale or fresh); sources are batched per detector so
    # each model pays one vectorized noise-synthesis call
    scored = [sf for sf in synced if sf.source_index >= 0]
    by_det: Dict[int, tuple] = {}
    for sf in scored:
        det = (det_by_frame or {}).get(sf.source_index, detector)
        by_det.setdefault(id(det), (det, set()))[1].add(sf.source_index)
    for det, idxs in by_det.values():
        det.detect_many(video, sorted(idxs))

    det_cache: Dict[int, List[tuple]] = {}
    sources = []
    for sf in scored:
        if sf.source_index in det_cache:
            continue
        det = (det_by_frame or {}).get(sf.source_index, detector)
        d = det.detect(video, sf.source_index)
        by_class = []
        for c in range(C):
            db = d.boxes[d.classes == c]
            ds = d.scores[d.classes == c]
            order = np.argsort(-ds)
            by_class.append((db[order], ds[order]))
        det_cache[sf.source_index] = by_class
        sources.append(sf.source_index)
    src_row = {s: i for i, s in enumerate(sources)}
    frame_src = np.array([src_row[sf.source_index] for sf in scored])

    all_gt = video.boxes_at_many(np.array([sf.index for sf in scored],
                                          np.int64))   # (F, K, 4)

    aps = []
    for c in range(C):
        if n_gt[c] == 0:
            continue
        K = int(np.sum(cls_masks[c]))
        per_src = [det_cache[s][c] for s in sources]
        d_max = max((len(db) for db, _ in per_src), default=0)
        if d_max == 0 or K == 0:
            aps.append(average_precision(np.zeros(0), np.zeros(0),
                                         n_gt[c]))
            continue
        # pad per-source detections to (S, Dmax)
        S = len(per_src)
        sb = np.zeros((S, d_max, 4))
        ss = np.full((S, d_max), -np.inf)
        for i, (db, ds) in enumerate(per_src):
            sb[i, :len(db)] = db
            ss[i, :len(ds)] = ds
        fb = sb[frame_src]                     # (F, Dmax, 4)
        fs = ss[frame_src]                     # (F, Dmax)
        tp, real = _batched_greedy_tp(fb, fs, all_gt[:, cls_masks[c]],
                                      iou_thr)
        aps.append(average_precision(tp[real], fs[real], n_gt[c]))
    return float(np.mean(aps)) if aps else 0.0


def evaluate_map_dets(video: SyntheticVideo, dets: Sequence,
                      iou_thr: float = 0.5) -> float:
    """mAP over an output stream whose per-frame detections are given
    explicitly — the tracked stream (fresh detections on processed
    frames, tracker-predicted boxes on interpolated ones).

    ``dets[f]`` covers arrival frame f: any object with ``boxes`` /
    ``classes`` / ``scores`` attributes (``Detections``,
    ``tracking.TrackedFrame``) or None for a frame with no output
    (which still contributes its ground truth to the recall
    denominator, exactly like ``evaluate_map``).

    Empty inputs are explicit, not incidental: a zero-frame ``dets``
    returns 0.0 (there is nothing to score — previously this raised
    ``ValueError`` from ``max()`` over an empty per-frame partition),
    and an all-``None``/all-empty stream scores 0.0 through the normal
    zero-detection AP path."""
    C = video.N_CLASSES
    F = len(dets)
    if F == 0:
        return 0.0
    cls_masks = [video.classes == c for c in range(C)]
    n_gt = {c: F * int(np.sum(m)) for c, m in enumerate(cls_masks)}
    all_gt = video.boxes_at_many(np.arange(F, dtype=np.int64))

    # partition each frame once (score-sorted per class), not per class
    empty = (np.zeros((0, 4)), np.zeros(0))
    by_class = [[empty] * F for _ in range(C)]
    for f, d in enumerate(dets):
        if d is None or len(d.boxes) == 0:
            continue
        db = np.asarray(d.boxes)
        ds = np.asarray(d.scores)
        dc = np.asarray(d.classes)
        order = np.argsort(-ds)
        db, ds, dc = db[order], ds[order], dc[order]
        for c in range(C):
            m = dc == c
            if m.any():
                by_class[c][f] = (db[m], ds[m])

    aps = []
    for c in range(C):
        if n_gt[c] == 0:
            continue
        per_frame = by_class[c]
        d_max = max(len(db) for db, _ in per_frame)
        if d_max == 0:
            aps.append(average_precision(np.zeros(0), np.zeros(0),
                                         n_gt[c]))
            continue
        fb = np.zeros((F, d_max, 4))
        fs = np.full((F, d_max), -np.inf)
        for i, (db, ds) in enumerate(per_frame):
            fb[i, :len(db)] = db
            fs[i, :len(ds)] = ds
        tp, real = _batched_greedy_tp(fb, fs, all_gt[:, cls_masks[c]],
                                      iou_thr)
        aps.append(average_precision(tp[real], fs[real], n_gt[c]))
    return float(np.mean(aps)) if aps else 0.0


def track_quality(video: SyntheticVideo, tracked: Sequence,
                  iou_thr: float = 0.5) -> Dict[str, float]:
    """Tracker-identity counters over a tracked output stream
    (``tracking.fill_stream`` output, or anything with per-frame
    ``index`` / ``boxes`` / ``track_ids``):

    * ``id_switches``  — times a ground-truth object's matched track id
      changed (both ids real; standard MOTA-style accounting against
      the last known id).
    * ``coverage``     — fraction of object-frames covered by an
      emitted box at ``iou_thr``.
    * ``fragments``    — covered -> uncovered transitions while the
      object remains in frame (track continuity).

    An empty ``tracked`` stream returns the explicit all-zero schema
    (coverage 0.0, no switches, no fragments) so zero-frame reports
    carry the same keys as populated ones.
    """
    if not len(tracked):
        return {"id_switches": 0.0, "coverage": 0.0, "fragments": 0.0}
    last_id: Dict[int, int] = {}
    prev_cov: Dict[int, bool] = {}
    switches = frags = covered = total = 0
    for tf in tracked:
        gt = video.boxes_at(tf.index)
        total += len(gt)
        boxes = np.asarray(tf.boxes, float).reshape(-1, 4)
        tids = np.asarray(tf.track_ids, np.int64).reshape(-1)
        matched_obj: Dict[int, int] = {}
        if len(boxes):
            iou = iou_matrix(gt, boxes)
            order = np.argsort(-iou, axis=None)
            used_t = set()
            for flat in order:
                o, t = divmod(int(flat), len(boxes))
                if iou[o, t] < iou_thr:
                    break
                if o in matched_obj or t in used_t:
                    continue
                matched_obj[o] = int(tids[t])
                used_t.add(t)
        for o in range(len(gt)):
            cov = o in matched_obj
            covered += cov
            if cov:
                tid = matched_obj[o]
                if tid >= 0:
                    if o in last_id and last_id[o] != tid:
                        switches += 1
                    last_id[o] = tid
            elif prev_cov.get(o, False):
                frags += 1
            prev_cov[o] = cov
    return {"id_switches": float(switches),
            "coverage": covered / max(total, 1),
            "fragments": float(frags)}


def evaluate_map_loop(video: SyntheticVideo, synced: Sequence[SyncedFrame],
                      detector: ProxyDetector, iou_thr: float = 0.5,
                      det_by_frame: Dict[int, ProxyDetector] | None = None
                      ) -> float:
    """The seed's per-frame/per-class/per-detection Python-loop mAP —
    kept verbatim as the oracle for ``evaluate_map`` (tests assert
    equality; ``benchmarks/nms_bench.py`` times the two against each
    other)."""
    det_cache: Dict[int, Detections] = {}
    per_class_tp: Dict[int, List[float]] = {c: [] for c in
                                            range(video.N_CLASSES)}
    per_class_scores: Dict[int, List[float]] = {c: [] for c in
                                                range(video.N_CLASSES)}
    n_gt = {c: 0 for c in range(video.N_CLASSES)}

    for sf in synced:
        gt_boxes = video.boxes_at(sf.index)
        gt_cls = video.classes
        for c in range(video.N_CLASSES):
            n_gt[c] += int(np.sum(gt_cls == c))
        if sf.source_index < 0:
            continue
        if sf.source_index not in det_cache:
            det = (det_by_frame or {}).get(sf.source_index, detector)
            det_cache[sf.source_index] = det.detect(video, sf.source_index)
        det = det_cache[sf.source_index]
        for c in range(video.N_CLASSES):
            db = det.boxes[det.classes == c]
            ds = det.scores[det.classes == c]
            gb = gt_boxes[gt_cls == c]
            if len(db) == 0:
                continue
            order = np.argsort(-ds)
            ious = iou_matrix(db[order], gb)
            matched = np.zeros(len(gb), bool)
            for i in range(len(db)):
                j = int(np.argmax(ious[i])) if len(gb) else -1
                if j >= 0 and ious[i, j] >= iou_thr and not matched[j]:
                    matched[j] = True
                    per_class_tp[c].append(1.0)
                else:
                    per_class_tp[c].append(0.0)
                per_class_scores[c].append(float(ds[order][i]))

    aps = []
    for c in range(video.N_CLASSES):
        if n_gt[c] == 0:
            continue
        aps.append(average_precision(np.array(per_class_tp[c]),
                                     np.array(per_class_scores[c]),
                                     n_gt[c]))
    return float(np.mean(aps)) if aps else 0.0
