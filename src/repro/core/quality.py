"""Detection quality model + real mAP evaluation.

MOT-15 videos and pretrained SSD/YOLO weights are not available offline
(DESIGN.md §7), so detection outputs come from a *proxy detector*: a
well-trained detector is modelled as ground truth + localization jitter +
misses + false positives, with noise levels per model class (SSD300 is
noisier than YOLOv3, matching the paper's mAP ordering).  The mAP math
(greedy IoU matching + all-point-interpolated AP) is real — and the
paper's central quality effect is mechanical: dropped frames reuse stale
detections, object motion decays their IoU against the current frame, and
mAP falls exactly as in Tables IV/V.

``evaluate_map`` is the vectorized scorer (batched GT fetch, per-source
class partitioning, argmax-based greedy matcher); ``evaluate_map_loop``
keeps the seed's Python-loop implementation as the equality oracle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence
from zlib import crc32

import numpy as np

from .stream import SyntheticVideo
from .synchronizer import SyncedFrame

# (center jitter, size jitter, miss rate, false positives per frame)
NOISE = {
    # max_miss_diff caps how much scene difficulty compounds the miss rate
    # (SSD's recall is already low; the paper's ADL/ETH gap is mostly
    # localization+precision for SSD, recall for YOLO)
    "yolov3": dict(c=0.05, s=0.055, miss=0.13, fp=0.5, max_miss_diff=99.0),
    "ssd300": dict(c=0.06, s=0.07, miss=0.28, fp=1.3, max_miss_diff=1.5),
}
# per-video difficulty multiplier (ADL-Rundle-6 is the harder scene in the
# paper: 1080p static camera, more/smaller objects)
DIFFICULTY = {"ADL-Rundle-6": 2.8, "ETH-Sunnyday": 1.0}


@dataclass
class Detections:
    boxes: np.ndarray      # (K, 4) xyxy
    classes: np.ndarray    # (K,)
    scores: np.ndarray     # (K,)


class ProxyDetector:
    def __init__(self, model: str, video_name: str, seed: int = 0):
        self.noise = NOISE[model]
        self.diff = DIFFICULTY.get(video_name, 1.0)
        self.model = model
        self.seed = seed
        self._memo: Dict[int, Detections] = {}
        self._memo_video: SyntheticVideo | None = None

    def detect(self, video: SyntheticVideo, frame_idx: int) -> Detections:
        # detection is a pure function of (model, seed, video, frame):
        # memoize so repeated evaluations (offline + paced runs,
        # benchmark sweeps) pay the noise synthesis once per frame; the
        # cache resets when a different video object comes through
        if video is not self._memo_video:
            self._memo = {}
            self._memo_video = video
        hit = self._memo.get(frame_idx)
        if hit is not None:
            return hit
        # crc32, not hash(): string hashing is randomized per process
        # (PYTHONHASHSEED), which made mAP values — and the paper-band
        # tests — flap from run to run
        rng = np.random.default_rng(
            (crc32(f"{self.model}/{self.seed}".encode()) & 0xFFFF)
            * 100003 + frame_idx)
        gt = video.boxes_at(frame_idx)
        classes = video.classes
        n = self.noise
        # difficulty scales misses/false-positives strongly but jitter only
        # mildly, so harder scenes lower the mAP plateau without putting
        # every match at the IoU-threshold cliff
        jit = 1.0 + 0.3 * (self.diff - 1.0)
        miss_diff = min(self.diff, n["max_miss_diff"])
        keep = rng.random(len(gt)) >= min(n["miss"] * miss_diff, 0.9)
        boxes, cls = gt[keep].copy(), classes[keep].copy()
        wh = np.stack([boxes[:, 2] - boxes[:, 0],
                       boxes[:, 3] - boxes[:, 1]], -1)
        center = (boxes[:, :2] + boxes[:, 2:]) / 2
        center += rng.normal(0, n["c"] * jit, center.shape) * wh
        wh = wh * np.exp(rng.normal(0, n["s"] * jit, wh.shape))
        boxes = np.concatenate([center - wh / 2, center + wh / 2], -1)
        scores = rng.uniform(0.55, 0.99, len(boxes))
        # false positives
        n_fp = rng.poisson(n["fp"] * self.diff)
        W, H = video.spec.width, video.spec.height
        fp_wh = np.stack([rng.uniform(0.03, 0.15, n_fp) * W,
                          rng.uniform(0.06, 0.3, n_fp) * H], -1)
        fp_c = np.stack([rng.uniform(0, W, n_fp),
                         rng.uniform(0, H, n_fp)], -1)
        fp_boxes = np.concatenate([fp_c - fp_wh / 2, fp_c + fp_wh / 2], -1)
        boxes = np.concatenate([boxes, fp_boxes], 0)
        cls = np.concatenate([cls, rng.integers(0, video.N_CLASSES, n_fp)])
        scores = np.concatenate([scores, rng.uniform(0.1, 0.65, n_fp)])
        det = Detections(boxes, cls, scores)
        self._memo[frame_idx] = det
        return det


def iou_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(N,4) x (M,4) xyxy -> (N,M) IoU.  (The Pallas kernel in
    repro/kernels/iou.py implements this tiled for TPU.)"""
    if len(a) == 0 or len(b) == 0:
        return np.zeros((len(a), len(b)))
    tl = np.maximum(a[:, None, :2], b[None, :, :2])
    br = np.minimum(a[:, None, 2:], b[None, :, 2:])
    inter = np.prod(np.clip(br - tl, 0, None), -1)
    area_a = np.prod(a[:, 2:] - a[:, :2], -1)
    area_b = np.prod(b[:, 2:] - b[:, :2], -1)
    return inter / np.maximum(area_a[:, None] + area_b[None] - inter, 1e-9)


def average_precision(tp: np.ndarray, scores: np.ndarray,
                      n_gt: int) -> float:
    if n_gt == 0 or len(tp) == 0:
        return 0.0
    order = np.argsort(-scores)
    tp = tp[order]
    cum_tp = np.cumsum(tp)
    recall = cum_tp / n_gt
    precision = cum_tp / (np.arange(len(tp)) + 1)
    # all-point interpolation (running max from the right, vectorized)
    mrec = np.concatenate([[0.0], recall, [1.0]])
    mpre = np.concatenate([[1.0], precision, [0.0]])
    mpre = np.maximum.accumulate(mpre[::-1])[::-1]
    idx = np.where(mrec[1:] != mrec[:-1])[0]
    return float(np.sum((mrec[idx + 1] - mrec[idx]) * mpre[idx + 1]))


def _batched_iou(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """a (F, D, 4) x b (F, K, 4) -> (F, D, K) IoU."""
    tl = np.maximum(a[:, :, None, :2], b[:, None, :, :2])
    br = np.minimum(a[:, :, None, 2:], b[:, None, :, 2:])
    inter = np.prod(np.clip(br - tl, 0, None), -1)
    aa = np.prod(a[:, :, 2:] - a[:, :, :2], -1)
    ab = np.prod(b[:, :, 2:] - b[:, :, :2], -1)
    return inter / np.maximum(aa[:, :, None] + ab[:, None, :] - inter, 1e-9)


def evaluate_map(video: SyntheticVideo, synced: Sequence[SyncedFrame],
                 detector: ProxyDetector, iou_thr: float = 0.5,
                 det_by_frame: Dict[int, ProxyDetector] | None = None
                 ) -> float:
    """Vectorized mAP over all frames of the output stream (identical
    result to ``evaluate_map_loop``, the seed implementation kept below
    as the oracle): processed frames score their own detections; dropped
    frames score the stale reused detections against the *current*
    frame's ground truth.  ``det_by_frame`` scores each processed frame
    with the model that ran it (heterogeneous-model deployments).

    Vectorization: detections per unique source frame are synthesized and
    class-partitioned once; ground truth for every output frame comes
    from one batched ``boxes_at_many`` call; and the per-frame/per-class
    Python greedy-matching loops collapse into ONE batched matcher per
    class over all frames at once.  The seed walked detections in score
    order and matched each against the *single* best-IoU ground-truth box
    (a second-best box never rescues a detection whose best box is
    taken), so the match rule is separable: a detection is TP iff its
    best-IoU box clears the threshold AND no earlier (higher-score)
    detection in the same frame claimed the same box — one argmax plus a
    triangular first-claim mask, batched over frames.
    """
    C = video.N_CLASSES
    gt_cls = video.classes
    cls_masks = [gt_cls == c for c in range(C)]
    n_gt = {c: len(synced) * int(np.sum(m))
            for c, m in enumerate(cls_masks)}

    # detections per unique source frame, class-partitioned + score-sorted
    # once (the same (D, 4) arrays serve every output frame that reuses
    # this source, stale or fresh)
    det_cache: Dict[int, List[tuple]] = {}
    scored = [sf for sf in synced if sf.source_index >= 0]
    sources = []
    for sf in scored:
        if sf.source_index in det_cache:
            continue
        det = (det_by_frame or {}).get(sf.source_index, detector)
        d = det.detect(video, sf.source_index)
        by_class = []
        for c in range(C):
            db = d.boxes[d.classes == c]
            ds = d.scores[d.classes == c]
            order = np.argsort(-ds)
            by_class.append((db[order], ds[order]))
        det_cache[sf.source_index] = by_class
        sources.append(sf.source_index)
    src_row = {s: i for i, s in enumerate(sources)}
    frame_src = np.array([src_row[sf.source_index] for sf in scored])

    all_gt = video.boxes_at_many(np.array([sf.index for sf in scored],
                                          np.int64))   # (F, K, 4)

    aps = []
    for c in range(C):
        if n_gt[c] == 0:
            continue
        K = int(np.sum(cls_masks[c]))
        per_src = [det_cache[s][c] for s in sources]
        d_max = max((len(db) for db, _ in per_src), default=0)
        if d_max == 0 or K == 0:
            aps.append(average_precision(np.zeros(0), np.zeros(0),
                                         n_gt[c]))
            continue
        # pad per-source detections to (S, Dmax)
        S = len(per_src)
        sb = np.zeros((S, d_max, 4))
        ss = np.full((S, d_max), -np.inf)
        for i, (db, ds) in enumerate(per_src):
            sb[i, :len(db)] = db
            ss[i, :len(ds)] = ds
        fb = sb[frame_src]                     # (F, Dmax, 4)
        fs = ss[frame_src]                     # (F, Dmax)
        real = np.isfinite(fs)
        ious = _batched_iou(fb, all_gt[:, cls_masks[c]])   # (F, Dmax, K)
        jb = np.argmax(ious, -1)               # best gt per detection
        best = np.take_along_axis(ious, jb[..., None], -1)[..., 0]
        ok = (best >= iou_thr) & real
        # first claim wins: det i is blocked if an earlier (higher-score)
        # qualified det j < i targets the same gt box
        same = jb[:, :, None] == jb[:, None, :]            # (F, i, j)
        earlier = np.tril(np.ones((d_max, d_max), bool), -1)
        blocked = np.any(same & ok[:, None, :] & earlier[None], -1)
        tp = (ok & ~blocked).astype(float)
        aps.append(average_precision(tp[real], fs[real], n_gt[c]))
    return float(np.mean(aps)) if aps else 0.0


def evaluate_map_loop(video: SyntheticVideo, synced: Sequence[SyncedFrame],
                      detector: ProxyDetector, iou_thr: float = 0.5,
                      det_by_frame: Dict[int, ProxyDetector] | None = None
                      ) -> float:
    """The seed's per-frame/per-class/per-detection Python-loop mAP —
    kept verbatim as the oracle for ``evaluate_map`` (tests assert
    equality; ``benchmarks/nms_bench.py`` times the two against each
    other)."""
    det_cache: Dict[int, Detections] = {}
    per_class_tp: Dict[int, List[float]] = {c: [] for c in
                                            range(video.N_CLASSES)}
    per_class_scores: Dict[int, List[float]] = {c: [] for c in
                                                range(video.N_CLASSES)}
    n_gt = {c: 0 for c in range(video.N_CLASSES)}

    for sf in synced:
        gt_boxes = video.boxes_at(sf.index)
        gt_cls = video.classes
        for c in range(video.N_CLASSES):
            n_gt[c] += int(np.sum(gt_cls == c))
        if sf.source_index < 0:
            continue
        if sf.source_index not in det_cache:
            det = (det_by_frame or {}).get(sf.source_index, detector)
            det_cache[sf.source_index] = det.detect(video, sf.source_index)
        det = det_cache[sf.source_index]
        for c in range(video.N_CLASSES):
            db = det.boxes[det.classes == c]
            ds = det.scores[det.classes == c]
            gb = gt_boxes[gt_cls == c]
            if len(db) == 0:
                continue
            order = np.argsort(-ds)
            ious = iou_matrix(db[order], gb)
            matched = np.zeros(len(gb), bool)
            for i in range(len(db)):
                j = int(np.argmax(ious[i])) if len(gb) else -1
                if j >= 0 and ious[i, j] >= iou_thr and not matched[j]:
                    matched[j] = True
                    per_class_tp[c].append(1.0)
                else:
                    per_class_tp[c].append(0.0)
                per_class_scores[c].append(float(ds[order][i]))

    aps = []
    for c in range(video.N_CLASSES):
        if n_gt[c] == 0:
            continue
        aps.append(average_precision(np.array(per_class_tp[c]),
                                     np.array(per_class_scores[c]),
                                     n_gt[c]))
    return float(np.mean(aps)) if aps else 0.0
