"""Detection quality model + real mAP evaluation.

MOT-15 videos and pretrained SSD/YOLO weights are not available offline
(DESIGN.md §7), so detection outputs come from a *proxy detector*: a
well-trained detector is modelled as ground truth + localization jitter +
misses + false positives, with noise levels per model class (SSD300 is
noisier than YOLOv3, matching the paper's mAP ordering).  The mAP math
(greedy IoU matching + all-point-interpolated AP) is real — and the
paper's central quality effect is mechanical: dropped frames reuse stale
detections, object motion decays their IoU against the current frame, and
mAP falls exactly as in Tables IV/V.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np

from .stream import SyntheticVideo
from .synchronizer import SyncedFrame

# (center jitter, size jitter, miss rate, false positives per frame)
NOISE = {
    # max_miss_diff caps how much scene difficulty compounds the miss rate
    # (SSD's recall is already low; the paper's ADL/ETH gap is mostly
    # localization+precision for SSD, recall for YOLO)
    "yolov3": dict(c=0.05, s=0.055, miss=0.13, fp=0.5, max_miss_diff=99.0),
    "ssd300": dict(c=0.06, s=0.07, miss=0.28, fp=1.3, max_miss_diff=1.5),
}
# per-video difficulty multiplier (ADL-Rundle-6 is the harder scene in the
# paper: 1080p static camera, more/smaller objects)
DIFFICULTY = {"ADL-Rundle-6": 2.8, "ETH-Sunnyday": 1.0}


@dataclass
class Detections:
    boxes: np.ndarray      # (K, 4) xyxy
    classes: np.ndarray    # (K,)
    scores: np.ndarray     # (K,)


class ProxyDetector:
    def __init__(self, model: str, video_name: str, seed: int = 0):
        self.noise = NOISE[model]
        self.diff = DIFFICULTY.get(video_name, 1.0)
        self.model = model
        self.seed = seed

    def detect(self, video: SyntheticVideo, frame_idx: int) -> Detections:
        rng = np.random.default_rng(
            (hash((self.model, self.seed)) & 0xFFFF) * 100003 + frame_idx)
        gt = video.boxes_at(frame_idx)
        classes = video.classes
        n = self.noise
        # difficulty scales misses/false-positives strongly but jitter only
        # mildly, so harder scenes lower the mAP plateau without putting
        # every match at the IoU-threshold cliff
        jit = 1.0 + 0.3 * (self.diff - 1.0)
        miss_diff = min(self.diff, n["max_miss_diff"])
        keep = rng.random(len(gt)) >= min(n["miss"] * miss_diff, 0.9)
        boxes, cls = gt[keep].copy(), classes[keep].copy()
        wh = np.stack([boxes[:, 2] - boxes[:, 0],
                       boxes[:, 3] - boxes[:, 1]], -1)
        center = (boxes[:, :2] + boxes[:, 2:]) / 2
        center += rng.normal(0, n["c"] * jit, center.shape) * wh
        wh = wh * np.exp(rng.normal(0, n["s"] * jit, wh.shape))
        boxes = np.concatenate([center - wh / 2, center + wh / 2], -1)
        scores = rng.uniform(0.55, 0.99, len(boxes))
        # false positives
        n_fp = rng.poisson(n["fp"] * self.diff)
        W, H = video.spec.width, video.spec.height
        fp_wh = np.stack([rng.uniform(0.03, 0.15, n_fp) * W,
                          rng.uniform(0.06, 0.3, n_fp) * H], -1)
        fp_c = np.stack([rng.uniform(0, W, n_fp),
                         rng.uniform(0, H, n_fp)], -1)
        fp_boxes = np.concatenate([fp_c - fp_wh / 2, fp_c + fp_wh / 2], -1)
        boxes = np.concatenate([boxes, fp_boxes], 0)
        cls = np.concatenate([cls, rng.integers(0, video.N_CLASSES, n_fp)])
        scores = np.concatenate([scores, rng.uniform(0.1, 0.65, n_fp)])
        return Detections(boxes, cls, scores)


def iou_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(N,4) x (M,4) xyxy -> (N,M) IoU.  (The Pallas kernel in
    repro/kernels/iou.py implements this tiled for TPU.)"""
    if len(a) == 0 or len(b) == 0:
        return np.zeros((len(a), len(b)))
    tl = np.maximum(a[:, None, :2], b[None, :, :2])
    br = np.minimum(a[:, None, 2:], b[None, :, 2:])
    inter = np.prod(np.clip(br - tl, 0, None), -1)
    area_a = np.prod(a[:, 2:] - a[:, :2], -1)
    area_b = np.prod(b[:, 2:] - b[:, :2], -1)
    return inter / np.maximum(area_a[:, None] + area_b[None] - inter, 1e-9)


def average_precision(tp: np.ndarray, scores: np.ndarray,
                      n_gt: int) -> float:
    if n_gt == 0 or len(tp) == 0:
        return 0.0
    order = np.argsort(-scores)
    tp = tp[order]
    cum_tp = np.cumsum(tp)
    recall = cum_tp / n_gt
    precision = cum_tp / (np.arange(len(tp)) + 1)
    # all-point interpolation
    mrec = np.concatenate([[0.0], recall, [1.0]])
    mpre = np.concatenate([[1.0], precision, [0.0]])
    for i in range(len(mpre) - 2, -1, -1):
        mpre[i] = max(mpre[i], mpre[i + 1])
    idx = np.where(mrec[1:] != mrec[:-1])[0]
    return float(np.sum((mrec[idx + 1] - mrec[idx]) * mpre[idx + 1]))


def evaluate_map(video: SyntheticVideo, synced: Sequence[SyncedFrame],
                 detector: ProxyDetector, iou_thr: float = 0.5,
                 det_by_frame: Dict[int, ProxyDetector] | None = None
                 ) -> float:
    """mAP over all frames of the output stream: processed frames score
    their own detections; dropped frames score the stale reused detections
    against the *current* frame's ground truth.  ``det_by_frame`` scores
    each processed frame with the model that ran it (heterogeneous-model
    deployments)."""
    det_cache: Dict[int, Detections] = {}
    per_class_tp: Dict[int, List[float]] = {c: [] for c in
                                            range(video.N_CLASSES)}
    per_class_scores: Dict[int, List[float]] = {c: [] for c in
                                                range(video.N_CLASSES)}
    n_gt = {c: 0 for c in range(video.N_CLASSES)}

    for sf in synced:
        gt_boxes = video.boxes_at(sf.index)
        gt_cls = video.classes
        for c in range(video.N_CLASSES):
            n_gt[c] += int(np.sum(gt_cls == c))
        if sf.source_index < 0:
            continue
        if sf.source_index not in det_cache:
            det = (det_by_frame or {}).get(sf.source_index, detector)
            det_cache[sf.source_index] = det.detect(video, sf.source_index)
        det = det_cache[sf.source_index]
        for c in range(video.N_CLASSES):
            db = det.boxes[det.classes == c]
            ds = det.scores[det.classes == c]
            gb = gt_boxes[gt_cls == c]
            if len(db) == 0:
                continue
            order = np.argsort(-ds)
            ious = iou_matrix(db[order], gb)
            matched = np.zeros(len(gb), bool)
            for i in range(len(db)):
                j = int(np.argmax(ious[i])) if len(gb) else -1
                if j >= 0 and ious[i, j] >= iou_thr and not matched[j]:
                    matched[j] = True
                    per_class_tp[c].append(1.0)
                else:
                    per_class_tp[c].append(0.0)
                per_class_scores[c].append(float(ds[order][i]))

    aps = []
    for c in range(video.N_CLASSES):
        if n_gt[c] == 0:
            continue
        aps.append(average_precision(np.array(per_class_tp[c]),
                                     np.array(per_class_scores[c]),
                                     n_gt[c]))
    return float(np.mean(aps)) if aps else 0.0
