"""Parallel detection scheduling algorithms (paper §III-C).

All schedulers operate on a deterministic virtual clock (the simulator in
``simulator.py`` drives them with arrival events).  Semantics calibrated to
the paper's measurements:

* LockstepRR — the paper's Round-Robin: the thread pool dispatches one
  frame per model per round and joins the round before starting the next
  (this is what makes heterogeneous RR degrade to n x min(mu): Table VII
  shows 8 x 0.4 ≈ 3.4 FPS for slow-CPU + 7 NCS2).  Frames arriving while
  all round slots are taken are dropped.
* WeightedRR — static weights ∝ configured device rates (compile-time).
* FCFS — work-conserving: a frame goes to the first available executor
  (each executor holds at most one queued frame, i.e. the frame currently
  being transferred); throughput approaches Σ mu_i (Table VII: 29 FPS for
  fast-CPU + 7 NCS2 vs 20.1 for RR).
* Proportional — performance-aware: WeightedRR whose weights are
  re-derived every ``update_period`` rounds from EWMA-measured service
  times (handles runtime drift the static WRR cannot).

A host-dispatch serialization term models the paper's Table X language
study: Python's GIL serializes pre/post-processing (h ≈ 102 ms/frame caps
the pipeline at ~9.8 FPS no matter how many sticks); the C++ thread pool
has h ≈ 2 ms and scales.

Failure detection (``serving.faults`` integration)
--------------------------------------------------
Executors may carry a ``faults`` attribute (a
``serving.faults.ReplicaFaultView``); when present, ``_dispatch``
applies the timeout rule a real dispatcher uses — a dispatch whose
completion would exceed ``timeout_k x 1/mu_effective`` (or whose
executor dies before finishing) marks the executor *suspect*: its
``healthy`` flag drops, the in-flight frame is retried once (bounded by
``max_retries``) on the least-busy healthy executor at the detection
time, and the ``retries`` / ``failovers`` / ``frames_lost`` counters
record the outcome per executor.  Assign paths skip unhealthy
executors; ``probe_health`` restores one whose fault view says it came
back.  Executors WITHOUT a fault view (the default everywhere) never
enter any of this machinery, so the fault-free virtual timeline is
bit-identical to the pre-fault scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from .executor import DetectorExecutor
from ..obs.trace import NULL_RECORDER


@dataclass
class Assignment:
    frame_idx: int
    executor_idx: int
    t_start: float
    t_done: float


class NoHealthyExecutorError(RuntimeError):
    """Raised by ``blocking_assign`` when no executor can EVER accept the
    frame — an empty pool, or every member marked unhealthy with no
    fault view promising a comeback.  Blocking dispatch means "wait
    until the policy can take it"; with nothing to wait FOR, failing
    fast beats committing the frame to a replica that will never run
    it (the all-replicas-dead hang)."""


class _Base:
    def __init__(self, executors: List[DetectorExecutor],
                 host_overhead: float = 0.001, sync_overhead: float = 0.005,
                 timeout_k: float = 4.0, max_retries: int = 1):
        self.executors = executors
        self.host_overhead = host_overhead
        self.sync_overhead = sync_overhead
        self.host_free_at = 0.0
        # failure-detection state (inert unless an executor carries a
        # ``faults`` view — see the module docstring)
        self.timeout_k = timeout_k
        self.max_retries = max_retries
        self.healthy = [True] * len(executors)
        self.retries: dict = {}       # executor idx -> suspected dispatches
        self.failovers: dict = {}     # executor idx -> frames rescued
        self.frames_lost: dict = {}   # executor idx -> frames not rescued
        # observability: the owning engine swaps in its TraceRecorder
        # (or shard view); the no-op default adds one attribute read per
        # dispatch and keeps the virtual timeline untouched
        self.recorder = NULL_RECORDER

    @property
    def n(self):
        return len(self.executors)

    # ------------------------------------------------------------- health
    def any_healthy(self) -> bool:
        return any(self.healthy)

    def fault_counts(self) -> dict:
        """Snapshot of the cumulative failure counters (copies, so the
        engine can diff per-serve deltas across warm-started calls)."""
        return {"retries": dict(self.retries),
                "failovers": dict(self.failovers),
                "frames_lost": dict(self.frames_lost)}

    def probe_health(self, t: float):
        """Restore suspects whose fault view says they came back: alive
        at ``t`` and not degraded past the timeout rule (a replica
        slowed by >= timeout_k would be re-suspected on its first
        dispatch, so leaving it out keeps the pool from thrashing)."""
        for j, ex in enumerate(self.executors):
            if not self.healthy[j]:
                view = getattr(ex, "faults", None)
                if view is not None and view.alive(t) \
                        and view.factor(t) < self.timeout_k:
                    self.healthy[j] = True
                    if self.recorder.enabled:
                        self.recorder.record("health_restore", t, replica=j)
                    self._pool_changed()

    def sync_pool(self):
        """Re-size health/round state after the caller changed pool
        MEMBERSHIP (the supervisor's replica lending appends/pops at
        the tail of ``executors``).  New members start healthy."""
        n = len(self.executors)
        if len(self.healthy) < n:
            self.healthy += [True] * (n - len(self.healthy))
        else:
            del self.healthy[n:]
        self._pool_changed()

    def _pool_changed(self):
        """Hook for round-based subclasses to rebuild their slot state
        when pool membership or health changes."""

    def _dispatch(self, ex_idx: int, frame_idx: int, t: float,
                  _attempt: int = 0) -> Optional[Assignment]:
        # executor identified by index — callers pick executors by index,
        # so dispatch is O(1) instead of an O(n) ``executors.index`` scan
        ex = self.executors[ex_idx]
        # host dispatch is serialized (GIL / thread-pool handoff)
        t = max(t, self.host_free_at)
        self.host_free_at = t + self.host_overhead
        t_start = max(t, ex.busy_until)
        # service evaluated at t_start so injected faults (slowdowns /
        # deaths) see the time the work actually runs, not arrival time
        service = ex.service_time(t=t_start) * (1 + self.sync_overhead)
        view = getattr(ex, "faults", None)
        if view is not None:
            # timeout detection: the dispatcher cannot see "dead" — it
            # sees a completion that never arrives within k x the
            # expected service.  An infinite service (killed replica), a
            # completion beyond the timeout (degraded mu), or a kill
            # striking mid-service all fire the same detector.
            expected = self.timeout_k / ex.mu_effective
            failed = (not np.isfinite(service) or service > expected
                      or not view.alive_through(t_start, t_start + service))
            if failed:
                t_detect = t_start + expected
                ex.busy_until = t_detect    # the slot is held until the
                self.healthy[ex_idx] = False  # timeout fires
                self.retries[ex_idx] = self.retries.get(ex_idx, 0) + 1
                if self.recorder.enabled:
                    self.recorder.record("retry", t_detect, rid=frame_idx,
                                         replica=ex_idx, attempt=_attempt)
                    self.recorder.record("health_mark", t_detect,
                                         replica=ex_idx)
                self._pool_changed()
                live = [i for i in range(self.n) if self.healthy[i]]
                if _attempt >= self.max_retries or not live:
                    self.frames_lost[ex_idx] = \
                        self.frames_lost.get(ex_idx, 0) + 1
                    if self.recorder.enabled:
                        self.recorder.record("lost", t_detect,
                                             rid=frame_idx, replica=ex_idx)
                    return None
                j = min(live, key=lambda i: self.executors[i].busy_until)
                a = self._dispatch(j, frame_idx, t_detect,
                                   _attempt=_attempt + 1)
                if a is not None:
                    # a dead retry chain is already charged to the LAST
                    # failing executor, so only rescues count here
                    self.failovers[ex_idx] = \
                        self.failovers.get(ex_idx, 0) + 1
                    if self.recorder.enabled:
                        self.recorder.record("failover", t_detect,
                                             rid=frame_idx, replica=ex_idx,
                                             to=a.executor_idx)
                return a
        t_done = t_start + service
        ex.busy_until = t_done
        ex.record(service)
        if self.recorder.enabled:
            self.recorder.record("dispatch", t_start, rid=frame_idx,
                                 replica=ex_idx)
            self.recorder.record("complete", t_done, rid=frame_idx,
                                 replica=ex_idx, t0=t_start,
                                 service=service)
        return Assignment(frame_idx, ex_idx, t_start, t_done)

    def assign(self, frame_idx: int, t: float) -> Optional[Assignment]:
        raise NotImplementedError

    def reset(self):
        """Clear per-serve dispatch state (the executors are owned by the
        caller and reset separately).  Subclasses extend this with their
        round bookkeeping so repeated ``serve()`` calls start from the
        same virtual-clock origin."""
        self.host_free_at = 0.0
        self.healthy = [True] * len(self.executors)
        self.retries = {}
        self.failovers = {}
        self.frames_lost = {}

    def backlog(self, t: float) -> float:
        """Residual committed work at virtual time ``t``: the summed
        seconds of already-dispatched service that extend past ``t``
        across all executors.  This is the load signal the sharded
        serving layer's work-stealing policy and the watchdog consume —
        0.0 means every executor would be idle at ``t``.

        Only executors that have DISPATCHED something count: an
        untouched executor's ``busy_until`` of 0.0 is a clock origin,
        not a commitment, so probing with ``t < 0`` (or before the
        first arrival) must read zero backlog rather than ``-n x t``."""
        return float(sum(max(0.0, e.busy_until - t)
                         for e in self.executors if e.n_processed > 0))

    def blocking_assign(self, frame_idx: int,
                        t: float = 0.0) -> Optional[Assignment]:
        """Zero-drop dispatch: the frame waits (buffered) until this
        scheduler's policy can take it (no earlier than arrival ``t``).
        FCFS default: first healthy executor to free up.  Raises
        ``NoHealthyExecutorError`` when nothing can ever take the frame
        (empty pool / every member dead); returns ``None`` only when a
        fault strikes mid-dispatch and the bounded retry is exhausted."""
        self.probe_health(t)
        self._require_healthy()
        live = [i for i in range(self.n) if self.healthy[i]]
        j = min(live, key=lambda i: self.executors[i].busy_until)
        return self._dispatch(j, frame_idx,
                              max(self.executors[j].busy_until, t))

    def _require_healthy(self):
        if not self.executors:
            raise NoHealthyExecutorError(
                "blocking_assign on an empty executor pool: there is "
                "nothing to wait for — construct the scheduler with at "
                "least one executor")
        if not self.any_healthy():
            raise NoHealthyExecutorError(
                f"all {self.n} executors are marked unhealthy and none "
                "is scheduled to come back: a blocking dispatch would "
                "hang forever (use drop mode for degraded operation, or "
                "revive a replica in the FaultSchedule)")


class FCFSScheduler(_Base):
    """First-come-first-serve: first available executor; one in-flight +
    one queued frame per executor; drop if every slot is full."""

    def assign(self, frame_idx, t):
        # first available executor; while all are busy, any executor with a
        # free single queued-frame slot (the frame being transferred while
        # the previous one computes) keeps the pipeline work-conserving.
        # Unhealthy (suspected-dead) executors are invisible to both scans.
        self.probe_health(t)
        free = [i for i, e in enumerate(self.executors)
                if self.healthy[i] and e.busy_until <= t]
        if free:
            return self._dispatch(
                min(free, key=lambda i: self.executors[i].busy_until),
                frame_idx, t)
        open_q = [i for i, e in enumerate(self.executors)
                  if self.healthy[i]
                  and e.busy_until - t <= 1.0 / e.mu_effective]
        if open_q:
            return self._dispatch(
                min(open_q, key=lambda i: self.executors[i].busy_until),
                frame_idx, t)
        return None


class LockstepRRScheduler(_Base):
    """Paper's RR: strict order, one frame per model per round, round
    barrier = all models done."""

    def __init__(self, executors, **kw):
        super().__init__(executors, **kw)
        self.rr_idx = 0
        self.round_barrier = 0.0

    def reset(self):
        super().reset()
        self.rr_idx = 0
        self.round_barrier = 0.0

    def _skip_unhealthy(self):
        """Advance ``rr_idx`` past suspected-dead slots (at most one lap)
        so one dead device does not sentence the whole strict-order
        stream; returns False when no healthy slot exists."""
        for _ in range(self.n):
            if self.healthy[self.rr_idx]:
                return True
            self.rr_idx = (self.rr_idx + 1) % self.n
            if self.rr_idx == 0:
                self.round_barrier = max(e.busy_until
                                         for e in self.executors)
        return False

    def assign(self, frame_idx, t):
        self.probe_health(t)
        if not self._skip_unhealthy():
            return None                      # every slot dead -> drop
        ex = self.executors[self.rr_idx]
        # the frame for this slot must wait for the round barrier
        t_eff = max(t, self.round_barrier)
        if ex.busy_until > t:
            return None                      # slot still busy -> drop
        a = self._dispatch(self.rr_idx, frame_idx, t_eff)
        self.rr_idx = (self.rr_idx + 1) % self.n
        if self.rr_idx == 0:                 # round complete: set barrier
            self.round_barrier = max(e.busy_until for e in self.executors)
        return a

    def blocking_assign(self, frame_idx, t: float = 0.0):
        self.probe_health(t)
        self._require_healthy()
        self._skip_unhealthy()
        ex = self.executors[self.rr_idx]
        a = self._dispatch(self.rr_idx, frame_idx, max(self.round_barrier,
                                                       ex.busy_until, t))
        self.rr_idx = (self.rr_idx + 1) % self.n
        if self.rr_idx == 0:
            self.round_barrier = max(e.busy_until for e in self.executors)
        return a

    def _pool_changed(self):
        if self.n:
            self.rr_idx %= self.n


class WeightedRRScheduler(_Base):
    """Static weighted RR: executor j takes w_j consecutive slots per
    round, w ∝ configured device rate."""

    def __init__(self, executors, weights=None, **kw):
        super().__init__(executors, **kw)
        self.weights = weights or self._default_weights()
        self._init_weights = list(self.weights)
        self._slots = self._expand()
        self.slot_idx = 0
        self.round_barrier = 0.0
        self._round_done = 0.0           # latest t_done in the open round
        self.rounds_completed = 0        # counts skip-crossings too

    def reset(self):
        super().reset()
        self.weights = list(self._init_weights)
        self._slots = self._expand()
        self.slot_idx = 0
        self.round_barrier = 0.0
        self._round_done = 0.0
        self.rounds_completed = 0

    def _default_weights(self):
        mus = np.array([e.mu_effective for e in self.executors])
        return np.maximum(1, np.round(mus / mus.min())).astype(int).tolist()

    def _expand(self):
        # smooth (interleaved) weighted round-robin: spreading each
        # executor's slots avoids head-of-line blocking in the strict-order
        # dispatcher (a run of consecutive slots on a busy device would
        # stall dispatch for every executor behind it).  Executor j's k-th
        # slot sits at fractional round position (k + phase_j) / w_j;
        # same-weight executors get distinct sub-phases, which fixes the
        # old expansion's weight-1 clump (every weight-1 executor landed on
        # the same 0.5 key, so [4,1,1,1,1] expanded to the head-of-line
        # block [0,0,1,2,3,4,0,0] instead of [0,1,0,2,0,3,0,4]).
        # A weight of 0 (dead or lent-away replica) simply contributes no
        # slots: the round renormalizes over the live executors.  The old
        # expansion let a zero weight poison the whole round — with
        # weights like [1, 0], min(w)=0 < wmax=1 but NO emitted slot had
        # w[j] < wmax, so the rotation's next() raised StopIteration.
        w = [int(x) for x in self.weights]
        live = [j for j, x in enumerate(w) if x > 0]
        if not live:
            return []
        group = {wj: [j for j in live if w[j] == wj]
                 for wj in set(w[j] for j in live)}
        keyed = []
        for j in live:
            wj = w[j]
            phase = (group[wj].index(j) + 0.5) / len(group[wj])
            keyed += [((k + phase) / wj, j) for k in range(wj)]
        slots = [j for _, j in sorted(keyed, key=lambda x: x[0])]
        # rotate the (cyclic, rotation-invariant) sequence so the round
        # opens with a lighter executor: the blocking dispatcher waits for
        # each slot's device in strict order, so lighter (slower) devices
        # dispatched first overlap their long service with the heavy
        # device's burst instead of queueing behind it
        wmax = max(w[j] for j in live)
        if min(w[j] for j in live) < wmax:
            start = next(i for i, j in enumerate(slots) if w[j] < wmax)
            slots = slots[start:] + slots[:start]
        return slots

    def assign(self, frame_idx, t):
        # a backlogged slot is SKIPPED (it forfeits this turn), not a
        # drop sentence for the whole stream: the old code returned None
        # without advancing slot_idx, so one backlogged executor at the
        # head slot dropped every subsequent arrival until its backlog
        # cleared, no matter how idle the other devices were.  The frame
        # is only dropped when every slot in the round is backlogged.
        # The round barrier is the latest t_done dispatched WITHIN the
        # round (equal to the old max-busy_until rule when nothing is
        # skipped, but immune to a skipped executor's stale backlog).
        self.probe_health(t)
        nslots = len(self._slots)
        barrier, round_done = self.round_barrier, self._round_done
        rounds = 0                       # edges crossed, incl. by skips
        for k in range(nslots):
            idx = (self.slot_idx + k) % nslots
            if idx == 0 and k > 0:       # the scan crossed a round edge
                barrier, round_done, rounds = round_done, 0.0, rounds + 1
            j = self._slots[idx]
            ex = self.executors[j]
            if not self.healthy[j]:
                continue                 # suspected dead -> skip its slot
            if ex.busy_until > t + 1.0 / ex.mu_effective:
                continue                 # slot backlog -> try next slot
            a = self._dispatch(j, frame_idx, max(t, barrier))
            if a is not None:
                round_done = max(round_done, a.t_done)
            self.slot_idx = (idx + 1) % nslots
            if self.slot_idx == 0:
                barrier, round_done, rounds = round_done, 0.0, rounds + 1
            self.round_barrier, self._round_done = barrier, round_done
            self.rounds_completed += rounds
            return a
        # every slot backlogged -> drop.  The scan still visited one full
        # round of slots, so the bookkeeping it accumulated is NOT thrown
        # away (the old code did, so ``rounds_completed`` undercounted and
        # ``ProportionalScheduler`` froze its reweighting clock under
        # exactly the total-backlog condition it exists to fix).  When the
        # scan started at slot 0 the wrap edge sits at its end and was
        # never crossed mid-scan; count it here so a failed full scan
        # always closes exactly one round.
        if self.slot_idx == 0:
            barrier, round_done, rounds = round_done, 0.0, rounds + 1
        self.round_barrier, self._round_done = barrier, round_done
        self.rounds_completed += rounds
        return None

    def blocking_assign(self, frame_idx, t: float = 0.0):
        self.probe_health(t)
        self._require_healthy()
        if not self._slots:
            raise NoHealthyExecutorError(
                "every WRR weight is zero: the round has no slots to "
                "wait on (renormalize the weights or revive a replica)")
        nslots = len(self._slots)
        # scan from the round cursor for the first healthy slot — a dead
        # slot forfeits its turn exactly like the drop-mode scan, and the
        # round edges crossed by skipping still close their rounds
        for k in range(nslots):
            idx = (self.slot_idx + k) % nslots
            if idx == 0 and k > 0:
                self.round_barrier, self._round_done = self._round_done, 0.0
                self.rounds_completed += 1
            j = self._slots[idx]
            if not self.healthy[j]:
                continue
            ex = self.executors[j]
            a = self._dispatch(j, frame_idx, max(self.round_barrier,
                                                 ex.busy_until, t))
            if a is not None:
                self._round_done = max(self._round_done, a.t_done)
            self.slot_idx = (idx + 1) % nslots
            if self.slot_idx == 0:
                self.round_barrier, self._round_done = self._round_done, 0.0
                self.rounds_completed += 1
            return a
        raise NoHealthyExecutorError(
            "every executor with a nonzero WRR weight is unhealthy: "
            "nothing in the round can ever take the frame")

    def _pool_changed(self):
        # pool membership changed (replica lending): renormalize the
        # weight vector to the new length (guests join at weight 1) and
        # rebuild the round.  Health-only changes leave the round state
        # alone — unhealthy executors are skipped by the scans instead.
        if len(self.weights) != self.n:
            ext = [1] * max(0, self.n - len(self.weights))
            self.weights = [int(x) for x in self.weights[:self.n]] + ext
            self._init_weights = list(self._init_weights[:self.n]) + ext
            self._slots = self._expand()
            self.slot_idx = 0


class ProportionalScheduler(WeightedRRScheduler):
    """Performance-aware proportional: re-derive weights from measured EWMA
    service times every ``update_period`` completed rounds."""

    def __init__(self, executors, update_period: int = 4, **kw):
        super().__init__(executors, weights=[1] * len(executors), **kw)
        self.update_period = update_period
        self._last_refresh = 0           # rounds_completed at last refresh

    def reset(self):
        super().reset()
        self._last_refresh = 0

    def _maybe_refresh(self):
        # keyed off rounds_completed (which also counts rounds closed by
        # skip-crossings) rather than slot_idx == 0: a round that ends
        # because the scan skipped past the wrap point — exactly the
        # backlogged-device case this policy exists for — still advances
        # the reweighting clock
        if self.rounds_completed - self._last_refresh >= self.update_period:
            self._last_refresh = self.rounds_completed
            self._refresh_weights()

    def assign(self, frame_idx, t):
        # refresh even when the frame is dropped: a failed scan closes a
        # round too (see WeightedRRScheduler.assign), and the reweighting
        # clock must keep ticking under sustained total backlog — that is
        # the drift condition the policy exists to correct
        a = super().assign(frame_idx, t)
        self._maybe_refresh()
        return a

    def blocking_assign(self, frame_idx, t: float = 0.0):
        a = super().blocking_assign(frame_idx, t)
        self._maybe_refresh()
        return a

    def _refresh_weights(self):
        # explicit None check: an EWMA of 0.0 (zero-cost oracle executor)
        # is a real measurement, not "no data" — `ewma or fallback` used
        # to silently fall back to the configured mu here
        ts = np.array([1.0 / e.mu_effective if e.ewma_service is None
                       else e.ewma_service for e in self.executors])
        rates = 1.0 / np.maximum(ts, 1e-9)
        # an unhealthy (suspected-dead) executor gets weight 0 and the
        # round renormalizes over the live rates — its stale EWMA must
        # not anchor rates.min() either, or every live weight inflates
        alive = np.array(self.healthy[:len(rates)], bool)
        if alive.any():
            w = np.zeros(len(rates), int)
            w[alive] = np.maximum(
                1, np.round(rates[alive] / rates[alive].min())).astype(int)
            self.weights = w.tolist()
        else:
            self.weights = np.maximum(1, np.round(rates / rates.min())) \
                .astype(int).tolist()
        self._slots = self._expand()
        self.slot_idx = 0


def make_scheduler(kind: str, executors, **kw):
    return {
        "rr": LockstepRRScheduler,
        "wrr": WeightedRRScheduler,
        "fcfs": FCFSScheduler,
        "proportional": ProportionalScheduler,
    }[kind](executors, **kw)
