"""Parallel detection scheduling algorithms (paper §III-C).

All schedulers operate on a deterministic virtual clock (the simulator in
``simulator.py`` drives them with arrival events).  Semantics calibrated to
the paper's measurements:

* LockstepRR — the paper's Round-Robin: the thread pool dispatches one
  frame per model per round and joins the round before starting the next
  (this is what makes heterogeneous RR degrade to n x min(mu): Table VII
  shows 8 x 0.4 ≈ 3.4 FPS for slow-CPU + 7 NCS2).  Frames arriving while
  all round slots are taken are dropped.
* WeightedRR — static weights ∝ configured device rates (compile-time).
* FCFS — work-conserving: a frame goes to the first available executor
  (each executor holds at most one queued frame, i.e. the frame currently
  being transferred); throughput approaches Σ mu_i (Table VII: 29 FPS for
  fast-CPU + 7 NCS2 vs 20.1 for RR).
* Proportional — performance-aware: WeightedRR whose weights are
  re-derived every ``update_period`` rounds from EWMA-measured service
  times (handles runtime drift the static WRR cannot).

A host-dispatch serialization term models the paper's Table X language
study: Python's GIL serializes pre/post-processing (h ≈ 102 ms/frame caps
the pipeline at ~9.8 FPS no matter how many sticks); the C++ thread pool
has h ≈ 2 ms and scales.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from .executor import DetectorExecutor


@dataclass
class Assignment:
    frame_idx: int
    executor_idx: int
    t_start: float
    t_done: float


class _Base:
    def __init__(self, executors: List[DetectorExecutor],
                 host_overhead: float = 0.001, sync_overhead: float = 0.005):
        self.executors = executors
        self.host_overhead = host_overhead
        self.sync_overhead = sync_overhead
        self.host_free_at = 0.0

    @property
    def n(self):
        return len(self.executors)

    def _dispatch(self, ex_idx: int, frame_idx: int,
                  t: float) -> Assignment:
        # executor identified by index — callers pick executors by index,
        # so dispatch is O(1) instead of an O(n) ``executors.index`` scan
        ex = self.executors[ex_idx]
        # host dispatch is serialized (GIL / thread-pool handoff)
        t = max(t, self.host_free_at)
        self.host_free_at = t + self.host_overhead
        service = ex.service_time() * (1 + self.sync_overhead)
        t_start = max(t, ex.busy_until)
        t_done = t_start + service
        ex.busy_until = t_done
        ex.record(service)
        return Assignment(frame_idx, ex_idx, t_start, t_done)

    def assign(self, frame_idx: int, t: float) -> Optional[Assignment]:
        raise NotImplementedError

    def reset(self):
        """Clear per-serve dispatch state (the executors are owned by the
        caller and reset separately).  Subclasses extend this with their
        round bookkeeping so repeated ``serve()`` calls start from the
        same virtual-clock origin."""
        self.host_free_at = 0.0

    def backlog(self, t: float) -> float:
        """Residual committed work at virtual time ``t``: the summed
        seconds of already-dispatched service that extend past ``t``
        across all executors.  This is the load signal the sharded
        serving layer's work-stealing policy consumes — 0.0 means every
        executor would be idle at ``t``."""
        return float(sum(max(0.0, e.busy_until - t)
                         for e in self.executors))

    def blocking_assign(self, frame_idx: int, t: float = 0.0) -> Assignment:
        """Zero-drop dispatch: the frame waits (buffered) until this
        scheduler's policy can take it (no earlier than arrival ``t``).
        FCFS default: first executor to free up."""
        j = min(range(self.n), key=lambda i: self.executors[i].busy_until)
        return self._dispatch(j, frame_idx,
                              max(self.executors[j].busy_until, t))


class FCFSScheduler(_Base):
    """First-come-first-serve: first available executor; one in-flight +
    one queued frame per executor; drop if every slot is full."""

    def assign(self, frame_idx, t):
        # first available executor; while all are busy, any executor with a
        # free single queued-frame slot (the frame being transferred while
        # the previous one computes) keeps the pipeline work-conserving
        free = [i for i, e in enumerate(self.executors) if e.busy_until <= t]
        if free:
            return self._dispatch(
                min(free, key=lambda i: self.executors[i].busy_until),
                frame_idx, t)
        open_q = [i for i, e in enumerate(self.executors)
                  if e.busy_until - t <= 1.0 / e.mu_effective]
        if open_q:
            return self._dispatch(
                min(open_q, key=lambda i: self.executors[i].busy_until),
                frame_idx, t)
        return None


class LockstepRRScheduler(_Base):
    """Paper's RR: strict order, one frame per model per round, round
    barrier = all models done."""

    def __init__(self, executors, **kw):
        super().__init__(executors, **kw)
        self.rr_idx = 0
        self.round_barrier = 0.0

    def reset(self):
        super().reset()
        self.rr_idx = 0
        self.round_barrier = 0.0

    def assign(self, frame_idx, t):
        ex = self.executors[self.rr_idx]
        # the frame for this slot must wait for the round barrier
        t_eff = max(t, self.round_barrier)
        if ex.busy_until > t:
            return None                      # slot still busy -> drop
        a = self._dispatch(self.rr_idx, frame_idx, t_eff)
        self.rr_idx = (self.rr_idx + 1) % self.n
        if self.rr_idx == 0:                 # round complete: set barrier
            self.round_barrier = max(e.busy_until for e in self.executors)
        return a

    def blocking_assign(self, frame_idx, t: float = 0.0):
        ex = self.executors[self.rr_idx]
        a = self._dispatch(self.rr_idx, frame_idx, max(self.round_barrier,
                                                       ex.busy_until, t))
        self.rr_idx = (self.rr_idx + 1) % self.n
        if self.rr_idx == 0:
            self.round_barrier = max(e.busy_until for e in self.executors)
        return a


class WeightedRRScheduler(_Base):
    """Static weighted RR: executor j takes w_j consecutive slots per
    round, w ∝ configured device rate."""

    def __init__(self, executors, weights=None, **kw):
        super().__init__(executors, **kw)
        self.weights = weights or self._default_weights()
        self._init_weights = list(self.weights)
        self._slots = self._expand()
        self.slot_idx = 0
        self.round_barrier = 0.0
        self._round_done = 0.0           # latest t_done in the open round
        self.rounds_completed = 0        # counts skip-crossings too

    def reset(self):
        super().reset()
        self.weights = list(self._init_weights)
        self._slots = self._expand()
        self.slot_idx = 0
        self.round_barrier = 0.0
        self._round_done = 0.0
        self.rounds_completed = 0

    def _default_weights(self):
        mus = np.array([e.mu_effective for e in self.executors])
        return np.maximum(1, np.round(mus / mus.min())).astype(int).tolist()

    def _expand(self):
        # smooth (interleaved) weighted round-robin: spreading each
        # executor's slots avoids head-of-line blocking in the strict-order
        # dispatcher (a run of consecutive slots on a busy device would
        # stall dispatch for every executor behind it).  Executor j's k-th
        # slot sits at fractional round position (k + phase_j) / w_j;
        # same-weight executors get distinct sub-phases, which fixes the
        # old expansion's weight-1 clump (every weight-1 executor landed on
        # the same 0.5 key, so [4,1,1,1,1] expanded to the head-of-line
        # block [0,0,1,2,3,4,0,0] instead of [0,1,0,2,0,3,0,4]).
        w = [int(x) for x in self.weights]
        group = {wj: [j for j, x in enumerate(w) if x == wj]
                 for wj in set(w)}
        keyed = []
        for j, wj in enumerate(w):
            phase = (group[wj].index(j) + 0.5) / len(group[wj])
            keyed += [((k + phase) / wj, j) for k in range(wj)]
        slots = [j for _, j in sorted(keyed, key=lambda x: x[0])]
        # rotate the (cyclic, rotation-invariant) sequence so the round
        # opens with a lighter executor: the blocking dispatcher waits for
        # each slot's device in strict order, so lighter (slower) devices
        # dispatched first overlap their long service with the heavy
        # device's burst instead of queueing behind it
        wmax = max(w)
        if min(w) < wmax:
            start = next(i for i, j in enumerate(slots) if w[j] < wmax)
            slots = slots[start:] + slots[:start]
        return slots

    def assign(self, frame_idx, t):
        # a backlogged slot is SKIPPED (it forfeits this turn), not a
        # drop sentence for the whole stream: the old code returned None
        # without advancing slot_idx, so one backlogged executor at the
        # head slot dropped every subsequent arrival until its backlog
        # cleared, no matter how idle the other devices were.  The frame
        # is only dropped when every slot in the round is backlogged.
        # The round barrier is the latest t_done dispatched WITHIN the
        # round (equal to the old max-busy_until rule when nothing is
        # skipped, but immune to a skipped executor's stale backlog).
        nslots = len(self._slots)
        barrier, round_done = self.round_barrier, self._round_done
        rounds = 0                       # edges crossed, incl. by skips
        for k in range(nslots):
            idx = (self.slot_idx + k) % nslots
            if idx == 0 and k > 0:       # the scan crossed a round edge
                barrier, round_done, rounds = round_done, 0.0, rounds + 1
            j = self._slots[idx]
            ex = self.executors[j]
            if ex.busy_until > t + 1.0 / ex.mu_effective:
                continue                 # slot backlog -> try next slot
            a = self._dispatch(j, frame_idx, max(t, barrier))
            round_done = max(round_done, a.t_done)
            self.slot_idx = (idx + 1) % nslots
            if self.slot_idx == 0:
                barrier, round_done, rounds = round_done, 0.0, rounds + 1
            self.round_barrier, self._round_done = barrier, round_done
            self.rounds_completed += rounds
            return a
        # every slot backlogged -> drop.  The scan still visited one full
        # round of slots, so the bookkeeping it accumulated is NOT thrown
        # away (the old code did, so ``rounds_completed`` undercounted and
        # ``ProportionalScheduler`` froze its reweighting clock under
        # exactly the total-backlog condition it exists to fix).  When the
        # scan started at slot 0 the wrap edge sits at its end and was
        # never crossed mid-scan; count it here so a failed full scan
        # always closes exactly one round.
        if self.slot_idx == 0:
            barrier, round_done, rounds = round_done, 0.0, rounds + 1
        self.round_barrier, self._round_done = barrier, round_done
        self.rounds_completed += rounds
        return None

    def blocking_assign(self, frame_idx, t: float = 0.0):
        j = self._slots[self.slot_idx]
        ex = self.executors[j]
        a = self._dispatch(j, frame_idx, max(self.round_barrier,
                                             ex.busy_until, t))
        self._round_done = max(self._round_done, a.t_done)
        self.slot_idx = (self.slot_idx + 1) % len(self._slots)
        if self.slot_idx == 0:
            self.round_barrier, self._round_done = self._round_done, 0.0
            self.rounds_completed += 1
        return a


class ProportionalScheduler(WeightedRRScheduler):
    """Performance-aware proportional: re-derive weights from measured EWMA
    service times every ``update_period`` completed rounds."""

    def __init__(self, executors, update_period: int = 4, **kw):
        super().__init__(executors, weights=[1] * len(executors), **kw)
        self.update_period = update_period
        self._last_refresh = 0           # rounds_completed at last refresh

    def reset(self):
        super().reset()
        self._last_refresh = 0

    def _maybe_refresh(self):
        # keyed off rounds_completed (which also counts rounds closed by
        # skip-crossings) rather than slot_idx == 0: a round that ends
        # because the scan skipped past the wrap point — exactly the
        # backlogged-device case this policy exists for — still advances
        # the reweighting clock
        if self.rounds_completed - self._last_refresh >= self.update_period:
            self._last_refresh = self.rounds_completed
            self._refresh_weights()

    def assign(self, frame_idx, t):
        # refresh even when the frame is dropped: a failed scan closes a
        # round too (see WeightedRRScheduler.assign), and the reweighting
        # clock must keep ticking under sustained total backlog — that is
        # the drift condition the policy exists to correct
        a = super().assign(frame_idx, t)
        self._maybe_refresh()
        return a

    def blocking_assign(self, frame_idx, t: float = 0.0):
        a = super().blocking_assign(frame_idx, t)
        self._maybe_refresh()
        return a

    def _refresh_weights(self):
        # explicit None check: an EWMA of 0.0 (zero-cost oracle executor)
        # is a real measurement, not "no data" — `ewma or fallback` used
        # to silently fall back to the configured mu here
        ts = np.array([1.0 / e.mu_effective if e.ewma_service is None
                       else e.ewma_service for e in self.executors])
        rates = 1.0 / np.maximum(ts, 1e-9)
        self.weights = np.maximum(1, np.round(rates / rates.min())) \
            .astype(int).tolist()
        self._slots = self._expand()
        self.slot_idx = 0


def make_scheduler(kind: str, executors, **kw):
    return {
        "rr": LockstepRRScheduler,
        "wrr": WeightedRRScheduler,
        "fcfs": FCFSScheduler,
        "proportional": ProportionalScheduler,
    }[kind](executors, **kw)
