"""Deterministic virtual-clock simulation of the online detection pipeline.

Drives a scheduler with frame arrivals at λ FPS and records, per frame,
whether it was detection-processed (and when) or randomly dropped — the
quantity the paper's entire analysis (σ, drop rate, mAP degradation)
hangs off.  Service times are calibrated device profiles or real measured
JAX inference (executor.infer_fn); either way the clock is virtual so a
7-accelerator edge rig can be simulated exactly on this CPU-only host.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .scheduler import Assignment, _Base
from .stream import FrameStream


@dataclass
class SimResult:
    video: str
    lambda_fps: float
    assignments: List[Assignment]
    dropped: List[int]
    n_frames: int
    makespan: float

    @property
    def processed_indices(self):
        return [a.frame_idx for a in self.assignments]

    @property
    def sigma(self) -> float:
        """Achieved detection processing rate σ_P (FPS)."""
        if not self.assignments:
            return 0.0
        return len(self.assignments) / max(self.makespan, 1e-9)

    @property
    def drop_rate(self) -> float:
        return len(self.dropped) / max(self.n_frames, 1)

    @property
    def drops_per_processed(self) -> float:
        return len(self.dropped) / max(len(self.assignments), 1)

    def per_executor_counts(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for a in self.assignments:
            out[a.executor_idx] = out.get(a.executor_idx, 0) + 1
        return out


def simulate(stream: FrameStream, scheduler: _Base, offline: bool = False,
             arrival_rate: Optional[float] = None) -> SimResult:
    """offline=True reproduces the paper's zero-frame-drop reference: every
    frame waits for a free executor (unbounded buffer), σ == μ aggregate.
    ``arrival_rate`` overrides the video's λ (e.g. saturated feeding to
    measure a scheduler's processing capacity, the paper's Detection FPS)."""
    assignments, dropped = [], []
    t_next_free = 0.0
    for frame in stream:
        t = (frame.index / arrival_rate if arrival_rate is not None
             else frame.t_arrival)
        if offline:
            # blocking dispatch through the scheduler's own policy
            assignments.append(scheduler.blocking_assign(frame.index))
            continue
        a = scheduler.assign(frame.index, t)
        if a is None:
            dropped.append(frame.index)
        else:
            assignments.append(a)
    makespan = max((a.t_done for a in assignments), default=0.0)
    return SimResult(stream.video.spec.name, stream.fps, assignments,
                     dropped, len(stream), makespan)
