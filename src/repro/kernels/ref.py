"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """q:(B,H,T,D) k/v:(B,H,S,D) -> (B,H,T,D)  (full softmax attention)."""
    B, H, T, D = q.shape
    S = k.shape[2]
    scale = D ** -0.5 if scale is None else scale
    s = jnp.einsum("bhtd,bhsd->bhts", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.arange(S)[None, :] <= jnp.arange(T)[:, None] + (S - T)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p.astype(q.dtype), v)


def decode_attention_ref(q, k, v, *, scale: float | None = None):
    """GQA flash-decode oracle.
    q:(B,H,D) one token; k/v:(B,S,KV,D) full cache -> (B,H,D)."""
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    scale = D ** -0.5 if scale is None else scale
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v)
    return out.reshape(B, H, D)


def iou_matrix_ref(a, b):
    """a:(N,4) b:(M,4) xyxy -> (N,M) IoU in f32."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    tl = jnp.maximum(a[:, None, :2], b[None, :, :2])
    br = jnp.minimum(a[:, None, 2:], b[None, :, 2:])
    inter = jnp.prod(jnp.clip(br - tl, 0.0), -1)
    area_a = jnp.prod(a[:, 2:] - a[:, :2], -1)
    area_b = jnp.prod(b[:, 2:] - b[:, :2], -1)
    union = area_a[:, None] + area_b[None, :] - inter
    return inter / jnp.maximum(union, 1e-9)


def nms_ref(boxes, scores, iou_thr: float = 0.5, max_out: int = 64):
    """Greedy NMS oracle. Returns (keep_idx (max_out,), valid mask)."""
    n = boxes.shape[0]
    iou = iou_matrix_ref(boxes, boxes)
    order = jnp.argsort(-scores)

    def body(i, state):
        keep, kcount, alive = state
        idx = order[i]
        ok = alive[idx]
        keep = keep.at[kcount].set(jnp.where(ok, idx, keep[kcount]))
        kcount = kcount + ok.astype(jnp.int32)
        # suppress everything overlapping idx
        sup = (iou[idx] >= iou_thr) & ok
        alive = alive & ~sup
        return keep, kcount, alive

    keep0 = jnp.zeros((max_out,), jnp.int32)
    alive0 = jnp.ones((n,), bool)
    keep, kcount, _ = jax.lax.fori_loop(0, n, body, (keep0, 0, alive0))
    valid = jnp.arange(max_out) < kcount
    return keep, valid


def batched_nms_ref(boxes, scores, iou_thr: float = 0.5,
                    max_out: int = 64, score_thr: float | None = None):
    """Batched greedy-NMS oracle: ``nms_ref`` vmapped over the leading
    frame axis, with the detector's score-threshold semantics (scores
    below ``score_thr`` are zeroed but still iterated, exactly like the
    seed decode path).  boxes (B, A, 4), scores (B, A)."""
    if score_thr is not None:
        scores = jnp.where(scores >= score_thr, scores, 0.0)
    return jax.vmap(
        lambda b, s: nms_ref(b, s, iou_thr, max_out))(boxes, scores)


def greedy_assign_ref(t_boxes, d_boxes, t_mask, d_mask, t_cls=None,
                      d_cls=None, iou_thr: float = 0.3):
    """Greedy IoU-association oracle for the tracking subsystem.

    t_boxes (B, T, 4) xyxy predicted track boxes, d_boxes (B, D, 4)
    detections, boolean slot masks, optional int class ids (class
    mismatch forbids a pair) -> match (B, T) int32: detection index per
    track slot or -1.  Per step the globally best remaining pair is
    committed (row-major tie break) and its row+column retired, until
    the best pair falls below ``iou_thr``.
    """
    import numpy as np
    t_boxes = jnp.asarray(t_boxes)
    d_boxes = jnp.asarray(d_boxes)
    B, T = t_boxes.shape[0], t_boxes.shape[1]
    D = d_boxes.shape[1]
    match = np.full((B, T), -1, np.int32)
    for b in range(B):
        ok = (np.asarray(t_mask[b], bool)[:, None] &
              np.asarray(d_mask[b], bool)[None, :])
        if t_cls is not None:
            ok &= (np.asarray(t_cls[b])[:, None] ==
                   np.asarray(d_cls[b])[None, :])
        cost = np.where(ok, np.asarray(iou_matrix_ref(t_boxes[b],
                                                      d_boxes[b])), -1.0)
        for _ in range(min(T, D)):
            flat = int(np.argmax(cost))
            i, j = divmod(flat, D)
            if cost[i, j] < iou_thr:
                break
            match[b, i] = j
            cost[i, :] = -1.0
            cost[:, j] = -1.0
    return jnp.asarray(match)


def crop_resize_ref(images, rois, *, out_size: int):
    """Nearest-neighbor ROI crop oracle (numpy loops, float32 index
    math — the bit-compatibility reference for ``roi.py``).

    images (B, H, W, ch), rois (B, R, 4) normalized xyxy ->
    crops (B, R, C, C, ch) float32."""
    import numpy as np
    images = np.asarray(images)
    rois = np.asarray(rois, np.float32)
    B, H, W, ch = images.shape
    R = rois.shape[1]
    C = out_size
    f = (np.arange(C, dtype=np.float32) + np.float32(0.5)) / np.float32(C)
    out = np.zeros((B, R, C, C, ch), np.float32)
    for b in range(B):
        for r in range(R):
            x0, y0, x1, y1 = rois[b, r]
            ys = np.clip(np.floor((y0 + f * (y1 - y0)) * np.float32(H)),
                         0, H - 1).astype(np.int64)
            xs = np.clip(np.floor((x0 + f * (x1 - x0)) * np.float32(W)),
                         0, W - 1).astype(np.int64)
            out[b, r] = images[b].astype(np.float32)[ys][:, xs]
    return jnp.asarray(out)


def uncrop_boxes_ref(boxes, rois, *, bounds, crop_size: int):
    """Crop-space -> parent-frame box mapping oracle for ``roi.py``.

    boxes (..., 4) xyxy in [0, crop_size] pixels, rois (..., 4)
    normalized parent windows (broadcast), bounds = (W, H)."""
    import numpy as np
    W, H = np.float32(bounds[0]), np.float32(bounds[1])
    b = np.asarray(boxes, np.float32)
    r = np.broadcast_to(np.asarray(rois, np.float32), b.shape)
    C = np.float32(crop_size)
    out = np.stack([
        (r[..., 0] + b[..., 0] / C * (r[..., 2] - r[..., 0])) * W,
        (r[..., 1] + b[..., 1] / C * (r[..., 3] - r[..., 1])) * H,
        (r[..., 0] + b[..., 2] / C * (r[..., 2] - r[..., 0])) * W,
        (r[..., 1] + b[..., 3] / C * (r[..., 3] - r[..., 1])) * H,
    ], axis=-1)
    return jnp.asarray(out)


def rwkv_scan_ref(r, k, v, w, u, s0):
    """Stepwise oracle for the RWKV-6 recurrence kernel.
    r/k/v/w: (B,H,T,hs); u: (H,hs); s0: (B,H,hs,hs)."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                       # (B,H,hs)
        kv = k_t[..., :, None] * v_t[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, out
    xs = tuple(a.transpose(2, 0, 1, 3) for a in (r, k, v, w))
    S, outs = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return outs.transpose(1, 2, 0, 3).astype(r.dtype), S
