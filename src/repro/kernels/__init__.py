from . import ops, ref
from .decode_attention import decode_attention
from .flash_attention import flash_attention
from .iou import iou_matrix

__all__ = ["ops", "ref", "decode_attention", "flash_attention",
           "iou_matrix"]
