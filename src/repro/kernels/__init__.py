"""Pallas kernels for the detection fast path and the LLM substrate.

Fast path
---------
The detection hot path is ``nms.batched_nms_pallas``: fused batched
greedy NMS with a leading batch grid dimension (one program per frame,
one launch per micro-batch).  Layout and tiling choices:

* Boxes are carried transposed as (4, A) coordinate planes per frame —
  the candidate index lands on the 128-wide lane dimension (the natural
  (A, 4) layout would waste 124/128 lanes per vector op), mirroring
  ``iou.py``.
* Candidates are sorted by (thresholded) score once in the wrapper,
  then suppressed in tiles of 32: each tile computes its IoU strip
  against all later candidates on the fly in VMEM, so the full (A, A)
  IoU matrix never exists in HBM.
* Within a tile, greedy NMS is solved by a suppression *fixpoint*
  (3-5 vectorized sweeps) instead of a serial per-box loop; the tile
  loop exits early once ``max_out`` survivors exist.
* Survivor -> output-slot assignment is an O(A) exclusive cumsum over
  the alive mask — never a dense (A, A) triangular product, which
  would put the quadratic operand back into VMEM.

``nms.batched_nms_xla`` is the same algorithm as batched XLA ops and is
the production path on hosts where Pallas runs interpreted;
``ops.batched_nms`` dispatches between the two, and ``ref.nms_ref`` /
``ref.batched_nms_ref`` remain the bit-compatibility oracles.

``association.greedy_assign_pallas`` follows the same three-tier
pattern for the tracking subsystem's data-association step (IoU cost
matrix + greedy assignment fused into one launch per frame batch, XLA
twin ``greedy_assign_xla``, oracle ``ref.greedy_assign_ref``,
dispatch ``ops.greedy_assign``).

``roi.crop_resize_pallas`` / ``roi.uncrop_boxes_pallas`` carry the
cascade's hierarchical second pass (cheap first-pass boxes -> ROI crops
batched into the heavy model -> detections mapped back to the parent
frame), again with XLA twins and ``ref`` oracles; the nearest-neighbor
gather is expressed as two one-hot matmuls so it runs on the MXU.
"""
from . import ops, ref
from .association import greedy_assign_pallas, greedy_assign_xla
from .decode_attention import decode_attention
from .flash_attention import flash_attention
from .iou import iou_matrix
from .nms import batched_nms_pallas, batched_nms_xla
from .roi import (crop_resize_pallas, crop_resize_xla,
                  uncrop_boxes_pallas, uncrop_boxes_xla)

__all__ = ["ops", "ref", "decode_attention", "flash_attention",
           "iou_matrix", "batched_nms_pallas", "batched_nms_xla",
           "greedy_assign_pallas", "greedy_assign_xla",
           "crop_resize_pallas", "crop_resize_xla",
           "uncrop_boxes_pallas", "uncrop_boxes_xla"]
