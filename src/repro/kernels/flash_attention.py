"""Blocked causal flash attention (prefill) — Pallas TPU kernel.

Tiling: grid (B, H, T/BLOCK_Q).  Each program holds one (BLOCK_Q, D) query
tile in VMEM and streams (BLOCK_K, D) key/value tiles with an online
softmax (running max / sum), so VMEM holds O(BLOCK_Q x BLOCK_K) scores
instead of the O(T x S) full matrix.  Block sizes are multiples of 128 so
the QK^T and PV matmuls land on MXU-aligned shapes; accumulation is f32.

Validated on CPU with interpret=True against ref.flash_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, seq_k,
                  block_k, offset):
    iq = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (BQ, D)
    bq, d = q.shape
    # `offset` = S - T aligns query positions when a cached prefix makes
    # the key sequence longer than the query block range
    q_pos = iq * bq + jax.lax.iota(jnp.int32, bq) + offset

    k_all = k_ref[0, 0]                                  # (S, D) in VMEM
    v_all = v_ref[0, 0]

    def kv_step(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(
            k_all, j * block_k, block_k, 0).astype(jnp.float32)  # (BK, D)
        v = jax.lax.dynamic_slice_in_dim(
            v_all, j * block_k, block_k, 0).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        if causal:
            mask = k_pos[None, :] <= q_pos[:, None]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, d), jnp.float32)
    n_k = seq_k // block_k
    if causal:
        # only blocks at or left of the diagonal contribute
        n_k_eff = jnp.minimum(
            n_k, ((iq + 1) * bq + offset + block_k - 1) // block_k)
    else:
        n_k_eff = n_k
    m, l, acc = jax.lax.fori_loop(0, n_k_eff, kv_step, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "interpret",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    interpret: bool = True, block_q: int = BLOCK_Q,
                    block_k: int = BLOCK_K):
    """q:(B,H,T,D) k/v:(B,H,S,D) -> (B,H,T,D)."""
    B, H, T, D = q.shape
    S = k.shape[2]
    assert T % block_q == 0 and S % block_k == 0, (T, S)
    scale = D ** -0.5 if scale is None else scale
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               seq_k=S, block_k=block_k, offset=S - T)
    return pl.pallas_call(
        kernel,
        grid=(B, H, T // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
