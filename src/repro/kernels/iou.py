"""Pairwise-IoU matrix — Pallas TPU kernel for the paper's NMS
post-processing hot-spot.

Layout adaptation for TPU: boxes are carried TRANSPOSED as (4, N) planes
(x0, y0, x1, y1) so the box index lands on the 128-wide lane dimension —
the natural (N, 4) layout would waste 124/128 lanes per vector op.
Tiling: grid (N/BN, M/BM); each program computes a (BN, BM) IoU tile from
one (4, BN) and one (4, BM) strip held in VMEM.

Validated on CPU with interpret=True against ref.iou_matrix_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128
BLOCK_M = 128


def _iou_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)      # (4, BN)
    b = b_ref[...].astype(jnp.float32)      # (4, BM)
    ax0, ay0, ax1, ay1 = a[0], a[1], a[2], a[3]
    bx0, by0, bx1, by1 = b[0], b[1], b[2], b[3]
    ix0 = jnp.maximum(ax0[:, None], bx0[None, :])
    iy0 = jnp.maximum(ay0[:, None], by0[None, :])
    ix1 = jnp.minimum(ax1[:, None], bx1[None, :])
    iy1 = jnp.minimum(ay1[:, None], by1[None, :])
    inter = jnp.clip(ix1 - ix0, 0.0) * jnp.clip(iy1 - iy0, 0.0)
    area_a = (ax1 - ax0) * (ay1 - ay0)
    area_b = (bx1 - bx0) * (by1 - by0)
    union = area_a[:, None] + area_b[None, :] - inter
    o_ref[...] = (inter / jnp.maximum(union, 1e-9)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n",
                                             "block_m"))
def iou_matrix(a, b, *, interpret: bool = True, block_n: int = BLOCK_N,
               block_m: int = BLOCK_M):
    """a:(N,4) b:(M,4) xyxy -> (N,M) f32 IoU (N, M padded internally)."""
    N, M = a.shape[0], b.shape[0]
    n_pad = -N % block_n
    m_pad = -M % block_m
    at = jnp.pad(a, ((0, n_pad), (0, 0))).T          # (4, Np)
    bt = jnp.pad(b, ((0, m_pad), (0, 0))).T          # (4, Mp)
    Np, Mp = at.shape[1], bt.shape[1]
    out = pl.pallas_call(
        _iou_kernel,
        grid=(Np // block_n, Mp // block_m),
        in_specs=[
            pl.BlockSpec((4, block_n), lambda i, j: (0, i)),
            pl.BlockSpec((4, block_m), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Mp), jnp.float32),
        interpret=interpret,
    )(at, bt)
    return out[:N, :M]
