"""RWKV-6 recurrence — Pallas TPU kernel (beyond-paper §Perf hillclimb #1).

The jnp scan reads+writes the (B, H, hs, hs) wkv state from HBM every
step; this kernel keeps the state in a VMEM scratch across the whole
sequence, so HBM traffic drops to one read of r/k/v/w + one write of the
output (+ state in/out once per sequence).

Tiling: grid (B, H, T/CHUNK_T) with the last grid dim sequential — the
scratch persists across T-chunks (standard TPU accumulation pattern; the
chunk bounds VMEM at CHUNK_T x hs per input).  hs = 64 keeps the per-head
state (64x64 f32 = 16 KB) resident.

Validated on CPU with interpret=True against ref-equivalent jnp scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK_T = 256


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sf_ref,
                 S_ref, *, chunk, n_chunks):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        S_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                   # (hs,)

    def step(t, _):
        r = r_ref[0, 0, t].astype(jnp.float32)         # (hs,)
        k = k_ref[0, 0, t].astype(jnp.float32)
        v = v_ref[0, 0, t].astype(jnp.float32)
        w = w_ref[0, 0, t].astype(jnp.float32)
        S = S_ref[...]
        kv = k[:, None] * v[None, :]                   # (hs, hs)
        out = jnp.sum(r[:, None] * (S + u[:, None] * kv), axis=0)
        o_ref[0, 0, t] = out.astype(o_ref.dtype)
        S_ref[...] = w[:, None] * S + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ic == n_chunks - 1)
    def _final():
        sf_ref[0, 0] = S_ref[...].astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "chunk_t"))
def rwkv_scan(r, k, v, w, u, s0, *, interpret: bool = True,
              chunk_t: int = CHUNK_T):
    """r/k/v/w: (B, H, T, hs); u: (H, hs); s0: (B, H, hs, hs).
    Returns (out (B,H,T,hs), s_final (B,H,hs,hs))."""
    B, H, T, hs = r.shape
    chunk = min(chunk_t, T)
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk
    kernel = functools.partial(_rwkv_kernel, chunk=chunk,
                               n_chunks=n_chunks)
    io_spec = pl.BlockSpec((1, 1, chunk, hs), lambda b, h, i: (b, h, i, 0))
    state_spec = pl.BlockSpec((1, 1, hs, hs), lambda b, h, i: (b, h, 0, 0))
    out, s_final = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[io_spec, io_spec, io_spec, io_spec,
                  pl.BlockSpec((1, hs), lambda b, h, i: (h, 0)),
                  state_spec],
        out_specs=[io_spec, state_spec],
        out_shape=[jax.ShapeDtypeStruct((B, H, T, hs), r.dtype),
                   jax.ShapeDtypeStruct((B, H, hs, hs), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out, s_final
