"""ROI crop / uncrop kernels for the hierarchical detection second pass.

The transprecise cascade (``serving/cascade.py``) runs a cheap first
pass over the full frame, then batches the detected regions through the
heavy model (SNIPPETS.md §3, ``inference-region=roi-list``).  The two
halves of that data movement live here:

* ``crop_resize_pallas`` — nearest-neighbor crop+resize of R normalized
  xyxy windows per frame into fixed (C, C) tiles, so ROI crops slot
  straight into the existing micro-batch path.  The gather is expressed
  as two one-hot matmuls (rows then columns): the source-index
  comparison against a ``broadcasted_iota`` builds a (C, H) / (C, W)
  selection matrix, and the contraction runs on the MXU — no serial
  per-pixel gather loop in the kernel body.  Grid (B, R): one program
  per window.
* ``uncrop_boxes_pallas`` — maps second-pass detections from crop pixel
  coordinates back into the parent frame; boxes are carried transposed
  as (4, N) coordinate planes like ``iou.py`` so the box index lands on
  the lane dimension.

Both have an XLA twin (``*_xla``) of the same index math and a pure
oracle in ``ref.py``; the source-pixel formula

    src = clip(floor((r0 + (i + 0.5) / C * (r1 - r0)) * S), 0, S - 1)

is evaluated in float32 with the same operation order in every tier.
The crop is bit-compatible across all three tiers (the floor/clip
quantizes to integer indices, absorbing any excess precision); for the
uncrop, Pallas and the XLA twin are bit-identical to each other, and
both match the numpy oracle to within one float32 ULP of the parent
frame scale — XLA contracts the ``r0 + t * (r1 - r0)`` pattern into an
FMA inside jit, which eager numpy cannot express.  Validated on CPU
with interpret=True against ``ref.crop_resize_ref`` /
``ref.uncrop_boxes_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

UNCROP_BLOCK = 128


def _crop_kernel(img_ref, roi_ref, o_ref, *, H, W, ch, C):
    img = img_ref[0].astype(jnp.float32)         # (H, W*ch)
    roi = roi_ref[...].astype(jnp.float32)       # (1, 1, 4)
    x0, y0 = roi[0, 0, 0], roi[0, 0, 1]
    x1, y1 = roi[0, 0, 2], roi[0, 0, 3]
    # rows: out row i reads src row floor((y0 + (i+.5)/C*(y1-y0)) * H)
    ii = jax.lax.broadcasted_iota(jnp.float32, (C, H), 0)
    hh = jax.lax.broadcasted_iota(jnp.float32, (C, H), 1)
    fy = (ii + 0.5) / C
    ys = jnp.clip(jnp.floor((y0 + fy * (y1 - y0)) * H), 0.0, H - 1.0)
    row_oh = (hh == ys).astype(jnp.float32)      # (C, H) one-hot
    rows = jnp.dot(row_oh, img).reshape(C, W, ch)
    # columns: same selection along x as a second one-hot contraction
    jj = jax.lax.broadcasted_iota(jnp.float32, (C, W), 0)
    ww = jax.lax.broadcasted_iota(jnp.float32, (C, W), 1)
    fx = (jj + 0.5) / C
    xs = jnp.clip(jnp.floor((x0 + fx * (x1 - x0)) * W), 0.0, W - 1.0)
    col_oh = (ww == xs).astype(jnp.float32)      # (C, W) one-hot
    out = jnp.einsum("cwk,dw->cdk", rows, col_oh)
    o_ref[...] = out.reshape(1, 1, C, C * ch)


@functools.partial(jax.jit, static_argnames=("out_size", "interpret"))
def crop_resize_pallas(images, rois, *, out_size: int,
                       interpret: bool = True):
    """images (B, H, W, ch), rois (B, R, 4) normalized xyxy in [0, 1]
    -> crops (B, R, C, C, ch) float32, C = out_size.  Degenerate
    (zero-area) windows produce a constant tile of source pixel (0, 0);
    callers mask invalid windows downstream."""
    B, H, W, ch = images.shape
    R = rois.shape[1]
    C = out_size
    flat = images.reshape(B, H, W * ch)
    out = pl.pallas_call(
        functools.partial(_crop_kernel, H=H, W=W, ch=ch, C=C),
        grid=(B, R),
        in_specs=[
            pl.BlockSpec((1, H, W * ch), lambda b, r: (b, 0, 0)),
            pl.BlockSpec((1, 1, 4), lambda b, r: (b, r, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, C, C * ch),
                               lambda b, r: (b, r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, R, C, C * ch), jnp.float32),
        interpret=interpret,
    )(flat, rois.astype(jnp.float32))
    return out.reshape(B, R, C, C, ch)


@functools.partial(jax.jit, static_argnames=("out_size",))
def crop_resize_xla(images, rois, *, out_size: int):
    """XLA twin of ``crop_resize_pallas``: same float32 index math as a
    vmapped double gather — the production path on non-TPU hosts."""
    B, H, W, ch = images.shape
    C = out_size
    f = (jnp.arange(C, dtype=jnp.float32) + 0.5) / C

    def one(img, roi):
        roi = roi.astype(jnp.float32)
        x0, y0, x1, y1 = roi[0], roi[1], roi[2], roi[3]
        ys = jnp.clip(jnp.floor((y0 + f * (y1 - y0)) * H),
                      0.0, H - 1.0).astype(jnp.int32)
        xs = jnp.clip(jnp.floor((x0 + f * (x1 - x0)) * W),
                      0.0, W - 1.0).astype(jnp.int32)
        return img.astype(jnp.float32)[ys][:, xs]

    return jax.vmap(lambda img, rs:
                    jax.vmap(lambda r: one(img, r))(rs))(images, rois)


def _uncrop_kernel(b_ref, r_ref, o_ref, *, W, H, C):
    b = b_ref[...].astype(jnp.float32)           # (4, BN) crop-space boxes
    r = r_ref[...].astype(jnp.float32)           # (4, BN) normalized rois
    x0, y0, x1, y1 = r[0], r[1], r[2], r[3]
    o_ref[...] = jnp.stack([
        (x0 + b[0] / C * (x1 - x0)) * W,
        (y0 + b[1] / C * (y1 - y0)) * H,
        (x0 + b[2] / C * (x1 - x0)) * W,
        (y0 + b[3] / C * (y1 - y0)) * H,
    ])


@functools.partial(jax.jit, static_argnames=("bounds", "crop_size",
                                             "interpret", "block"))
def uncrop_boxes_pallas(boxes, rois, *, bounds, crop_size: int,
                        interpret: bool = True, block: int = UNCROP_BLOCK):
    """boxes (..., 4) xyxy in crop pixel coordinates [0, crop_size],
    rois (..., 4) normalized parent windows (broadcast against the
    boxes' leading shape) -> boxes in parent-frame pixel coordinates,
    bounds = (W, H)."""
    W, H = bounds
    boxes = jnp.asarray(boxes, jnp.float32)
    rois = jnp.broadcast_to(jnp.asarray(rois, jnp.float32), boxes.shape)
    lead = boxes.shape[:-1]
    N = 1
    for d in lead:
        N *= d
    pad = -N % block
    bt = jnp.pad(boxes.reshape(N, 4), ((0, pad), (0, 0))).T   # (4, Np)
    rt = jnp.pad(rois.reshape(N, 4), ((0, pad), (0, 0))).T
    Np = N + pad
    out = pl.pallas_call(
        functools.partial(_uncrop_kernel, W=float(W), H=float(H),
                          C=crop_size),
        grid=(Np // block,),
        in_specs=[
            pl.BlockSpec((4, block), lambda i: (0, i)),
            pl.BlockSpec((4, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((4, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((4, Np), jnp.float32),
        interpret=interpret,
    )(bt, rt)
    return out.T[:N].reshape(lead + (4,))


@functools.partial(jax.jit, static_argnames=("bounds", "crop_size"))
def uncrop_boxes_xla(boxes, rois, *, bounds, crop_size: int):
    """XLA twin of ``uncrop_boxes_pallas`` (same float32 math,
    elementwise)."""
    W, H = float(bounds[0]), float(bounds[1])
    b = jnp.asarray(boxes, jnp.float32)
    r = jnp.broadcast_to(jnp.asarray(rois, jnp.float32), b.shape)
    scale = jnp.stack([r[..., 2] - r[..., 0], r[..., 3] - r[..., 1],
                       r[..., 2] - r[..., 0], r[..., 3] - r[..., 1]], -1)
    base = jnp.stack([r[..., 0], r[..., 1], r[..., 0], r[..., 1]], -1)
    px = jnp.asarray([W, H, W, H], jnp.float32)
    return (base + b / crop_size * scale) * px
