"""Fused batched NMS — Pallas TPU kernel + an XLA twin of the same
algorithm.

The seed decode path ran greedy NMS as a per-image ``vmap`` of
(full IoU matrix + A-step serial ``fori_loop``): A sequential steps per
frame and an (A, A) IoU matrix materialized in HBM.  This module replaces
it with one launch per micro-batch that is exact (bit-compatible with
``ref.nms_ref``) but does only a handful of serial steps:

 1. **Score threshold** (optional, fused): scores below ``score_thr``
    are zeroed — the same semantics the detector decode applied before
    calling NMS.
 2. **Candidate selection**: a stable descending sort by thresholded
    score; only the top ``num_candidates`` sorted boxes enter
    suppression (default: all of them, which keeps the op exact).
 3. **Tiled suppression**: sorted candidates are processed in tiles of
    ``tile``.  For each tile the IoU of the tile's boxes against all
    later candidates is computed on the fly in VMEM — the full (A, A)
    IoU matrix never exists in HBM.  Inside a tile, greedy NMS is solved
    by a *suppression fixpoint*: ``alive[j] = pre[j] and not any(alive[i]
    and iou[i, j] >= thr for i < j)`` iterated to convergence, which
    takes at most the longest suppression-chain depth (3-5 iterations in
    practice) instead of ``tile`` serial steps.  One vectorized pass then
    suppresses all later candidates.
 4. **Early exit**: once ``max_out`` survivors exist, remaining tiles
    cannot change the output — extra survivors only bump the count past
    the point where ``valid`` saturates and their keep-slots are dropped
    (matching the reference's out-of-bounds-scatter semantics) — so the
    tile loop stops.  With ``stop_at_zero`` the loop also stops at the
    first tile whose best (thresholded) score is 0: zero-score survivors
    can never suppress a positive-score box (they sort after all of
    them) and the detector masks them out of ``valid`` anyway.
 5. **Slot assignment**: survivor i lands in output slot
    ``#survivors-before-i`` — an O(A) exclusive cumsum over the alive
    mask (a dense triangular-matrix product would put an (A, A) operand
    back into VMEM, exactly what the tiling avoids).

Greedy-equivalence of the fixpoint: ``alive[j]`` depends only on
``alive[i]`` for candidates i that precede j in score order, so by
induction each lane stabilizes one Jacobi sweep after its predecessors —
the iteration converges to the unique greedy solution in at most
chain-depth sweeps, and the convergence check makes the result exact.

Layout: boxes are carried transposed as (4, A) coordinate planes per
frame (same trick as ``iou.py``) so the candidate index lands on the
128-wide lane dimension; the grid has a leading batch dimension, one
program per frame, so a whole micro-batch is suppressed in one launch.

On TPU the ``pallas_call`` compiles to Mosaic; on the CPU host it runs
in interpret mode, which validates numerics but interprets the kernel
body per grid step.  ``batched_nms_xla`` is the same algorithm written
as batched XLA ops (tiles unrolled, per-tile early exit via
``lax.cond``) and is the fast path on non-TPU hosts — see
``ops.batched_nms`` for the dispatch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 32


def _plane_iou(tx0, ty0, tx1, ty1, tarea, x0, y0, x1, y1, area):
    """IoU of a (T,) tile of boxes against (A,) boxes -> (T, A)."""
    ix0 = jnp.maximum(tx0[:, None], x0[None, :])
    iy0 = jnp.maximum(ty0[:, None], y0[None, :])
    ix1 = jnp.minimum(tx1[:, None], x1[None, :])
    iy1 = jnp.minimum(ty1[:, None], y1[None, :])
    inter = jnp.clip(ix1 - ix0, 0.0) * jnp.clip(iy1 - iy0, 0.0)
    union = tarea[:, None] + area[None, :] - inter
    # degenerate zero-area boxes (e.g. padding rows): union == inter == 0
    # -> IoU 0, never NaN
    return inter / jnp.maximum(union, 1e-9)


def _intra_tile_fixpoint(intra_sup, pre):
    """Greedy NMS inside one tile: ``intra_sup`` (T, T) is the strictly
    upper-triangular suppression relation in score order, ``pre`` (T,)
    the candidates still alive after earlier tiles."""

    def cond(state):
        alive, prev, it = state
        return (it == 0) | jnp.any(alive != prev)

    def body(state):
        alive, _, it = state
        new = pre & ~jnp.any(intra_sup & alive[:, None], axis=0)
        return new, alive, it + 1

    alive, _, _ = jax.lax.while_loop(cond, body, (pre, pre, 0))
    return alive


def _nms_kernel(boxes_ref, scores_ref, oidx_ref, keep_ref, count_ref, *,
                n_real, iou_thr, score_thr, max_out, tile, num_candidates,
                stop_at_zero):
    """One grid program = one frame of the micro-batch."""
    b = boxes_ref[0].astype(jnp.float32)             # (4, Ap) planes
    x0, y0, x1, y1 = b[0], b[1], b[2], b[3]
    area = (x1 - x0) * (y1 - y0)
    s = scores_ref[0].astype(jnp.float32)            # (Ap,) sorted desc
    if score_thr is not None:
        s = jnp.where(s >= score_thr, s, 0.0)
    Ap = s.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, Ap), 1)[0]

    n_cand = min(n_real, num_candidates)
    n_tiles = pl.cdiv(n_cand, tile)
    alive0 = lane < n_cand                           # padding never alive
    tri = (jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 0) <
           jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 1))

    def tile_cond(state):
        t, alive, found = state
        more = (t < n_tiles) & (found < max_out)
        if stop_at_zero:
            tile_best = jax.lax.dynamic_slice(s, (t * tile,), (1,))[0]
            more &= tile_best > 0.0
        return more

    def tile_body(state):
        t, alive, found = state
        c0 = t * tile
        tx0 = jax.lax.dynamic_slice(x0, (c0,), (tile,))
        ty0 = jax.lax.dynamic_slice(y0, (c0,), (tile,))
        tx1 = jax.lax.dynamic_slice(x1, (c0,), (tile,))
        ty1 = jax.lax.dynamic_slice(y1, (c0,), (tile,))
        ta = jax.lax.dynamic_slice(area, (c0,), (tile,))
        sup = _plane_iou(tx0, ty0, tx1, ty1, ta,
                         x0, y0, x1, y1, area) >= iou_thr      # (T, Ap)
        intra = jax.lax.dynamic_slice(sup, (0, c0), (tile, tile)) & tri
        pre = jax.lax.dynamic_slice(alive, (c0,), (tile,))
        a_c = _intra_tile_fixpoint(intra, pre)
        # one vectorized pass suppresses every later candidate
        later = lane[None, :] >= c0 + tile
        dead_later = jnp.any(sup & later & a_c[:, None], axis=0)
        alive = alive & ~dead_later
        alive = jax.lax.dynamic_update_slice(alive, a_c, (c0,))
        return t + 1, alive, found + jnp.sum(a_c.astype(jnp.int32))

    _, alive, found = jax.lax.while_loop(
        tile_cond, tile_body, (0, alive0, jnp.int32(0)))

    # slot[i] = number of survivors before i (exclusive cumsum; O(A),
    # unlike a dense triangular-matrix product which would put an
    # (Ap, Ap) operand back into VMEM)
    alive_i = alive.astype(jnp.int32)
    slot = jnp.cumsum(alive_i) - alive_i
    mo = keep_ref.shape[1]
    slot_iota = jax.lax.broadcasted_iota(jnp.int32, (Ap, mo), 1)
    onehot = (alive[:, None] & (slot[:, None] == slot_iota))
    oidx = oidx_ref[0].astype(jnp.int32)
    keep_ref[0, :] = jnp.sum(
        jnp.where(onehot, oidx[:, None], 0), axis=0).astype(jnp.int32)
    count_ref[0, 0] = jnp.minimum(found, max_out)


@functools.partial(jax.jit, static_argnames=(
    "iou_thr", "score_thr", "max_out", "tile", "num_candidates",
    "stop_at_zero", "interpret"))
def batched_nms_pallas(boxes, scores, *, iou_thr=0.5, score_thr=None,
                       max_out=64, tile=DEFAULT_TILE, num_candidates=None,
                       stop_at_zero=False, interpret=True):
    """boxes (B, A, 4) xyxy, scores (B, A) -> keep (B, max_out) int32,
    valid (B, max_out) bool.  Exact greedy NMS per frame, one launch for
    the whole micro-batch."""
    B, A = scores.shape
    if num_candidates is None:
        num_candidates = A
    s_key = scores.astype(jnp.float32)
    if score_thr is not None:
        s_key = jnp.where(s_key >= score_thr, s_key, 0.0)
    order = jnp.argsort(-s_key, axis=-1, stable=True)
    bs = jnp.take_along_axis(boxes.astype(jnp.float32),
                             order[..., None], axis=1)
    ss = jnp.take_along_axis(scores.astype(jnp.float32), order, axis=1)

    # pad to a common multiple of the tile and the 8-sublane minimum so
    # the last tile's dynamic_slice never clamps (a clamped start would
    # re-process — and double-count — earlier candidates)
    pad = -A % math.lcm(tile, 8)
    if pad:
        bs = jnp.pad(bs, ((0, 0), (0, pad), (0, 0)))
        ss = jnp.pad(ss, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        order = jnp.pad(order, ((0, 0), (0, pad)))
    Ap = A + pad
    bt = bs.transpose(0, 2, 1)                       # (B, 4, Ap) planes

    kernel = functools.partial(
        _nms_kernel, n_real=A, iou_thr=iou_thr, score_thr=score_thr,
        max_out=max_out, tile=tile, num_candidates=num_candidates,
        stop_at_zero=stop_at_zero)
    keep, count = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, 4, Ap), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Ap), lambda b: (b, 0)),
            pl.BlockSpec((1, Ap), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, max_out), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, max_out), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(bt, ss, order.astype(jnp.int32))
    valid = jnp.arange(max_out)[None, :] < count
    return keep, valid


def _pair_iou(a, b):
    """a (B, T, 4) vs b (B, M, 4) -> (B, T, M)."""
    tl = jnp.maximum(a[:, :, None, :2], b[:, None, :, :2])
    br = jnp.minimum(a[:, :, None, 2:], b[:, None, :, 2:])
    inter = (jnp.clip(br[..., 0] - tl[..., 0], 0.0) *
             jnp.clip(br[..., 1] - tl[..., 1], 0.0))
    aa = (a[:, :, 2] - a[:, :, 0]) * (a[:, :, 3] - a[:, :, 1])
    ab = (b[:, :, 2] - b[:, :, 0]) * (b[:, :, 3] - b[:, :, 1])
    return inter / jnp.maximum(aa[:, :, None] + ab[:, None, :] - inter, 1e-9)


@functools.partial(jax.jit, static_argnames=(
    "iou_thr", "score_thr", "max_out", "tile", "num_candidates",
    "stop_at_zero"))
def batched_nms_xla(boxes, scores, *, iou_thr=0.5, score_thr=None,
                    max_out=64, tile=DEFAULT_TILE, num_candidates=None,
                    stop_at_zero=False):
    """XLA twin of the Pallas kernel — identical algorithm and outputs,
    tiles unrolled with a batch-global ``lax.cond`` early exit.  This is
    the production path on hosts where Pallas runs interpreted."""
    B, A = scores.shape
    K = A if num_candidates is None else min(num_candidates, A)
    s_key = scores.astype(jnp.float32)
    if score_thr is not None:
        s_key = jnp.where(s_key >= score_thr, s_key, 0.0)
    order = jnp.argsort(-s_key, axis=-1, stable=True)[:, :K]
    bs = jnp.take_along_axis(boxes.astype(jnp.float32),
                             order[..., None], axis=1)
    ss = jnp.take_along_axis(s_key, order, axis=1)

    tri = jnp.arange(tile)[:, None] < jnp.arange(tile)[None, :]
    alive_parts = []
    alive_rest = jnp.ones((B, K), bool)
    # per-frame gate, exactly like the kernel's tile_cond: a frame stops
    # contributing survivors once its next tile opens with a zero score
    # (a batch-global gate would let one long frame drag zero-score
    # survivors into the other frames' counts)
    active = jnp.ones((B,), bool)
    if stop_at_zero and K > 0:
        active = ss[:, 0] > 0.0
    found = jnp.zeros((B,), jnp.int32)
    for c0 in range(0, K, tile):
        T = min(tile, K - c0)
        pre = alive_rest[:, c0:c0 + T] & active[:, None]
        rest = alive_rest[:, c0 + T:]
        done = ~jnp.any(active) | jnp.all(found >= max_out)

        def do_tile(args, c0=c0, T=T):
            pre, rest, found = args
            iou = _pair_iou(bs[:, c0:c0 + T], bs[:, c0:])
            sup = iou >= iou_thr
            intra = sup[:, :, :T] & tri[:T, :T][None]

            def cond(st):
                return (st[2] == 0) | jnp.any(st[0] != st[1])

            def body(st):
                a, _, it = st
                return pre & ~jnp.any(intra & a[:, :, None], 1), a, it + 1

            a_c, _, _ = jax.lax.while_loop(cond, body, (pre, pre, 0))
            dead = jnp.any(sup[:, :, T:] & a_c[:, :, None], 1)
            return a_c, rest & ~dead, found + jnp.sum(a_c, -1,
                                                      dtype=jnp.int32)

        a_c, rest, found = jax.lax.cond(
            done, lambda args: (jnp.zeros_like(args[0]),) + args[1:],
            do_tile, (pre, rest, found))
        alive_parts.append(a_c)
        if c0 + T < K:
            alive_rest = jnp.concatenate(
                [jnp.zeros((B, c0 + T), bool), rest], axis=-1)
            if stop_at_zero:
                active = active & (ss[:, c0 + T] > 0.0)

    alive = jnp.concatenate(alive_parts, axis=-1)
    count = jnp.minimum(found, max_out)
    # survivor i -> slot (#survivors before i); dead/overflow slots land in
    # a per-frame spill column that is sliced away (the reference's
    # dropped-out-of-bounds-scatter semantics)
    slot = jnp.where(alive, jnp.cumsum(alive, axis=-1) - 1, max_out)
    slot = jnp.minimum(slot, max_out)
    flat = (jnp.arange(B)[:, None] * (max_out + 1) + slot).reshape(-1)
    keep = jnp.zeros((B * (max_out + 1),), jnp.int32).at[flat].set(
        order.reshape(-1).astype(jnp.int32)
    ).reshape(B, max_out + 1)[:, :max_out]
    valid = jnp.arange(max_out)[None, :] < count[:, None]
    return keep, valid
