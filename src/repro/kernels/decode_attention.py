"""GQA flash-decode — Pallas TPU kernel for single-token decode against a
long KV cache.

Tiling: grid (B, KV).  Each program handles one (batch, kv-head) pair: the
G = H/KV query heads that share this kv-head form a (G, D) tile (so the
GQA "repeat" never materializes), and the (S, D) cache streams through
VMEM in (BLOCK_S, D) tiles with an online softmax.  This is the hot loop
of decode_32k / long_500k serving.

Validated on CPU with interpret=True against ref.decode_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_S = 512
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, seq_k, block_s):
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    g, d = q.shape

    k_all = k_ref[0, :, 0, :]                            # (S, D) in VMEM
    v_all = v_ref[0, :, 0, :]

    def step(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(
            k_all, j * block_s, block_s, 0).astype(jnp.float32)  # (BS, D)
        v = jax.lax.dynamic_slice_in_dim(
            v_all, j * block_s, block_s, 0).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, BS)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return m_new, l_new, acc_new

    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    a0 = jnp.zeros((g, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, seq_k // block_s, step, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_s"))
def decode_attention(q, k, v, *, scale=None, interpret: bool = True,
                     block_s: int = BLOCK_S):
    """q:(B,H,D) one new token; k/v:(B,S,KV,D) cache -> (B,H,D)."""
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, KV, G, D)
    kernel = functools.partial(_decode_kernel, scale=scale, seq_k=S,
                               block_s=block_s)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, kv: (b, kv, 0, 0)),
            pl.BlockSpec((1, S, 1, D), lambda b, kv: (b, 0, kv, 0)),
            pl.BlockSpec((1, S, 1, D), lambda b, kv: (b, 0, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, kv: (b, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(B, H, D)
