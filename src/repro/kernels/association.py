"""Batched track↔detection association — IoU cost matrix + greedy
assignment as one fused kernel (Pallas TPU kernel + an XLA twin).

The tracking subsystem (``repro/tracking``) needs, per frame batch, the
classic data-association step: score every (track, detection) pair by
IoU, then greedily commit the best-scoring pairs until nothing clears
the threshold.  Done naively this is a host-side Hungarian/greedy loop
per frame; here it is one launch per frame batch:

 1. **Cost matrix**: the (T, D) IoU matrix of predicted track boxes vs
    detection boxes is computed on the fly in VMEM from (4, T) / (4, D)
    coordinate planes (same transposed layout as ``iou.py`` /
    ``nms.py`` — the pair index lands on the 128-wide lane dimension).
    Pairs that are masked out (dead track slot, padding detection) or
    class-mismatched are set to cost -1 so they can never win.
 2. **Greedy assignment**: at most ``min(T, D)`` serial steps; each
    step takes the argmax of the remaining cost matrix (row-major tie
    break, exactly like the oracle), commits the pair, and retires its
    row and column with one vectorized mask.  The loop exits as soon as
    the best remaining pair falls below ``iou_thr``, so the serial step
    count is the number of *matches*, not T·D.

Greedy (not Hungarian) is the standard choice for edge trackers — it
is within a fraction of a percent of optimal at IoU-gated costs and is
embarrassingly vectorizable; the oracle in ``ref.greedy_assign_ref``
pins the exact semantics and both paths are bit-compatible with it.

On TPU the ``pallas_call`` compiles to Mosaic (grid = batch, one
program per frame); on the CPU host it runs in interpret mode.
``greedy_assign_xla`` is the same algorithm as batched XLA ops with a
per-frame active gate and is the production path on non-TPU hosts —
see ``ops.greedy_assign`` for the dispatch.  TPU tile tuning (lane-
width padding of T/D, VMEM residency) is a ROADMAP follow-up; only
interpret mode is validated so far.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .nms import _pair_iou


def _plane_cost(tb, db, t_ok, d_ok, t_cls, d_cls):
    """IoU of (4, T) track planes vs (4, D) detection planes, masked to
    -1 where either side is dead/padding or the classes differ."""
    tx0, ty0, tx1, ty1 = tb[0], tb[1], tb[2], tb[3]
    dx0, dy0, dx1, dy1 = db[0], db[1], db[2], db[3]
    ix0 = jnp.maximum(tx0[:, None], dx0[None, :])
    iy0 = jnp.maximum(ty0[:, None], dy0[None, :])
    ix1 = jnp.minimum(tx1[:, None], dx1[None, :])
    iy1 = jnp.minimum(ty1[:, None], dy1[None, :])
    inter = jnp.clip(ix1 - ix0, 0.0) * jnp.clip(iy1 - iy0, 0.0)
    t_area = (tx1 - tx0) * (ty1 - ty0)
    d_area = (dx1 - dx0) * (dy1 - dy0)
    union = t_area[:, None] + d_area[None, :] - inter
    iou = inter / jnp.maximum(union, 1e-9)
    ok = ((t_ok[:, None] > 0) & (d_ok[None, :] > 0) &
          (t_cls[:, None] == d_cls[None, :]))
    return jnp.where(ok, iou, -1.0)


def _greedy_body(n_pairs, iou_thr, Dp, cost0, match0):
    """Shared greedy loop (runs inside the Pallas kernel): commit the
    best remaining pair per step, retire its row+column."""
    row = jax.lax.broadcasted_iota(jnp.int32, (cost0.shape[0], 1), 0)[:, 0]

    def cond(state):
        it, cost, _ = state
        return (it < n_pairs) & (jnp.max(cost) >= iou_thr)

    def body(state):
        it, cost, match = state
        flat = jnp.argmax(cost).astype(jnp.int32)
        i = flat // Dp
        j = flat - i * Dp
        match = jnp.where(row == i, j, match)
        col = jax.lax.broadcasted_iota(jnp.int32, cost.shape, 1)
        rowm = jax.lax.broadcasted_iota(jnp.int32, cost.shape, 0)
        cost = jnp.where((rowm == i) | (col == j), -1.0, cost)
        return it + 1, cost, match

    _, _, match = jax.lax.while_loop(cond, body,
                                     (jnp.int32(0), cost0, match0))
    return match


def _assoc_kernel(tb_ref, tm_ref, tc_ref, db_ref, dm_ref, dc_ref,
                  match_ref, *, n_pairs, iou_thr):
    """One grid program = one frame of the batch."""
    cost = _plane_cost(tb_ref[0].astype(jnp.float32),
                       db_ref[0].astype(jnp.float32),
                       tm_ref[0], dm_ref[0], tc_ref[0], dc_ref[0])
    match_ref[0, :] = _greedy_body(
        n_pairs, iou_thr, cost.shape[1], cost,
        jnp.full((cost.shape[0],), -1, jnp.int32))


@functools.partial(jax.jit, static_argnames=("iou_thr", "interpret"))
def greedy_assign_pallas(t_boxes, d_boxes, t_mask, d_mask, t_cls, d_cls,
                         *, iou_thr=0.3, interpret=True):
    """t_boxes (B, T, 4) xyxy, d_boxes (B, D, 4) xyxy (+ per-slot masks
    and int class ids) -> match (B, T) int32: the detection index
    assigned to each track slot, or -1.  One launch per frame batch."""
    B, T, _ = t_boxes.shape
    D = d_boxes.shape[1]
    t_pad = -T % 8
    d_pad = -D % 8
    tb = jnp.pad(t_boxes.astype(jnp.float32), ((0, 0), (0, t_pad), (0, 0)))
    db = jnp.pad(d_boxes.astype(jnp.float32), ((0, 0), (0, d_pad), (0, 0)))
    tm = jnp.pad(t_mask.astype(jnp.int32), ((0, 0), (0, t_pad)))
    dm = jnp.pad(d_mask.astype(jnp.int32), ((0, 0), (0, d_pad)))
    tc = jnp.pad(t_cls.astype(jnp.int32), ((0, 0), (0, t_pad)))
    dc = jnp.pad(d_cls.astype(jnp.int32), ((0, 0), (0, d_pad)))
    Tp, Dp = T + t_pad, D + d_pad
    tbt = tb.transpose(0, 2, 1)                  # (B, 4, Tp) planes
    dbt = db.transpose(0, 2, 1)                  # (B, 4, Dp) planes

    kernel = functools.partial(_assoc_kernel, n_pairs=min(T, D),
                               iou_thr=iou_thr)
    match = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, 4, Tp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Tp), lambda b: (b, 0)),
            pl.BlockSpec((1, Tp), lambda b: (b, 0)),
            pl.BlockSpec((1, 4, Dp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Dp), lambda b: (b, 0)),
            pl.BlockSpec((1, Dp), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, Tp), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tp), jnp.int32),
        interpret=interpret,
    )(tbt, tm, tc, dbt, dm, dc)
    return match[:, :T]


@functools.partial(jax.jit, static_argnames=("iou_thr",))
def greedy_assign_xla(t_boxes, d_boxes, t_mask, d_mask, t_cls, d_cls,
                      *, iou_thr=0.3):
    """XLA twin of the Pallas kernel — identical algorithm and outputs,
    batched over frames with a per-frame active gate (a frame whose
    best remaining pair falls below ``iou_thr`` stops committing while
    the other frames keep going)."""
    B, T, _ = t_boxes.shape
    D = d_boxes.shape[1]
    iou = _pair_iou(t_boxes.astype(jnp.float32),
                    d_boxes.astype(jnp.float32))        # (B, T, D)
    ok = (t_mask[:, :, None] & d_mask[:, None, :] &
          (t_cls[:, :, None] == d_cls[:, None, :]))
    cost0 = jnp.where(ok, iou, -1.0)
    match0 = jnp.full((B, T), -1, jnp.int32)
    row = jnp.arange(T, dtype=jnp.int32)[None, :]

    def cond(state):
        it, cost, _ = state
        return (it < min(T, D)) & jnp.any(jnp.max(cost, (1, 2)) >= iou_thr)

    def body(state):
        it, cost, match = state
        flat = jnp.argmax(cost.reshape(B, T * D), -1).astype(jnp.int32)
        best = jnp.take_along_axis(cost.reshape(B, T * D), flat[:, None],
                                   -1)[:, 0]
        act = best >= iou_thr                                # (B,)
        i = flat // D
        j = flat - i * D
        match = jnp.where(act[:, None] & (row == i[:, None]),
                          j[:, None], match)
        kill = (act[:, None, None] &
                ((jnp.arange(T)[None, :, None] == i[:, None, None]) |
                 (jnp.arange(D)[None, None, :] == j[:, None, None])))
        cost = jnp.where(kill, -1.0, cost)
        return it + 1, cost, match

    _, _, match = jax.lax.while_loop(cond, body,
                                     (jnp.int32(0), cost0, match0))
    return match
