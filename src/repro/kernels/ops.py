"""Jit'd dispatch layer over the Pallas kernels.

On the CPU host the kernels execute in interpret mode (the kernel body
runs as traced JAX ops — numerics identical to TPU); on a TPU backend the
same pallas_call compiles to Mosaic.  ``use_pallas=False`` falls back to
the pure-jnp oracles in ref.py (the default inside model code, where XLA
fusion already does well; benchmarks compare both paths).
"""
from __future__ import annotations

import jax

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .iou import iou_matrix as _iou_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, scale=None, use_pallas=True):
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    return _flash_pallas(q, k, v, causal=causal, scale=scale,
                         interpret=_interpret())


def decode_attention(q, k, v, *, scale=None, use_pallas=True):
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, scale=scale)
    return _decode_pallas(q, k, v, scale=scale, interpret=_interpret())


def iou_matrix(a, b, *, use_pallas=True):
    if not use_pallas:
        return ref.iou_matrix_ref(a, b)
    return _iou_pallas(a, b, interpret=_interpret())


def nms(boxes, scores, iou_thr=0.5, max_out=64, use_pallas=True):
    """Greedy NMS: IoU matrix from the Pallas kernel + sequential suppress
    loop (inherently serial; stays in jnp)."""
    import jax.numpy as jnp
    iou = iou_matrix(boxes, boxes, use_pallas=use_pallas)
    order = jnp.argsort(-scores)

    def body(i, state):
        keep, kcount, alive = state
        idx = order[i]
        ok = alive[idx]
        keep = keep.at[kcount].set(jnp.where(ok, idx, keep[kcount]))
        kcount = kcount + ok.astype(jnp.int32)
        alive = alive & ~((iou[idx] >= iou_thr) & ok)
        return keep, kcount, alive

    keep0 = jnp.zeros((max_out,), jnp.int32)
    alive0 = jnp.ones((boxes.shape[0],), bool)
    keep, kcount, _ = jax.lax.fori_loop(0, boxes.shape[0], body,
                                        (keep0, 0, alive0))
    valid = jnp.arange(max_out) < kcount
    return keep, valid
