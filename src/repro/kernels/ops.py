"""Jit'd dispatch layer over the Pallas kernels.

On the CPU host the kernels execute in interpret mode (the kernel body
runs as traced JAX ops — numerics identical to TPU); on a TPU backend the
same pallas_call compiles to Mosaic.  ``use_pallas=False`` falls back to
the pure-jnp oracles in ref.py (the default inside model code, where XLA
fusion already does well; benchmarks compare both paths).

NMS is the one exception to the "False means oracle" rule: the fused
batched NMS has an XLA twin of the *same* tiled algorithm
(``nms.batched_nms_xla``) which is the production path on hosts where
Pallas runs interpreted, so ``batched_nms(use_pallas=False)`` routes
there.  The slow oracles stay available as ``ref.nms_ref`` /
``ref.batched_nms_ref`` (tests assert bit-compatibility against them)
and the seed's per-image serial path survives as ``nms_serial`` for
benchmark baselines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .association import greedy_assign_pallas as _assoc_pallas
from .association import greedy_assign_xla as _assoc_xla
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .iou import iou_matrix as _iou_pallas
from .nms import batched_nms_pallas as _nms_pallas
from .nms import batched_nms_xla as _nms_xla
from .roi import crop_resize_pallas as _crop_pallas
from .roi import crop_resize_xla as _crop_xla
from .roi import uncrop_boxes_pallas as _uncrop_pallas
from .roi import uncrop_boxes_xla as _uncrop_xla


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, scale=None, use_pallas=True):
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    return _flash_pallas(q, k, v, causal=causal, scale=scale,
                         interpret=_interpret())


def decode_attention(q, k, v, *, scale=None, use_pallas=True):
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, scale=scale)
    return _decode_pallas(q, k, v, scale=scale, interpret=_interpret())


def iou_matrix(a, b, *, use_pallas=True):
    if not use_pallas:
        return ref.iou_matrix_ref(a, b)
    return _iou_pallas(a, b, interpret=_interpret())


def batched_nms(boxes, scores, *, iou_thr=0.5, score_thr=None, max_out=64,
                tile=None, num_candidates=None, stop_at_zero=False,
                use_pallas=True):
    """Fused batched greedy NMS over a micro-batch of frames.

    boxes (B, A, 4) xyxy, scores (B, A) -> (keep (B, max_out) int32,
    valid (B, max_out) bool).  Exact (bit-compatible with
    ``ref.batched_nms_ref``) when ``num_candidates`` covers all boxes and
    ``stop_at_zero=False``; with ``score_thr`` + ``stop_at_zero=True``
    the valid-masked outputs still match the seed decode path exactly —
    zero-score survivors are simply not enumerated.
    """
    kw = dict(iou_thr=iou_thr, score_thr=score_thr, max_out=max_out,
              num_candidates=num_candidates, stop_at_zero=stop_at_zero)
    if tile is not None:
        kw["tile"] = tile
    if use_pallas:
        return _nms_pallas(boxes, scores, interpret=_interpret(), **kw)
    return _nms_xla(boxes, scores, **kw)


def greedy_assign(t_boxes, d_boxes, *, t_mask=None, d_mask=None,
                  t_cls=None, d_cls=None, iou_thr=0.3, use_pallas=True):
    """Fused IoU cost-matrix + greedy assignment over a frame batch
    (the tracker's association step).

    t_boxes (B, T, 4) xyxy predicted track boxes, d_boxes (B, D, 4)
    detections -> match (B, T) int32 (detection index per track slot or
    -1).  Masks default to all-true, class ids to all-zero (no class
    gate).  Like NMS, the fused batched path has an XLA twin of the
    same algorithm for non-TPU hosts; ``ref.greedy_assign_ref`` is the
    bit-compatibility oracle.
    """
    B, T, _ = t_boxes.shape
    D = d_boxes.shape[1]
    if T == 0 or D == 0:
        return jnp.full((B, T), -1, jnp.int32)
    t_mask = (jnp.ones((B, T), bool) if t_mask is None
              else t_mask.astype(bool))
    d_mask = (jnp.ones((B, D), bool) if d_mask is None
              else d_mask.astype(bool))
    t_cls = (jnp.zeros((B, T), jnp.int32) if t_cls is None
             else t_cls.astype(jnp.int32))
    d_cls = (jnp.zeros((B, D), jnp.int32) if d_cls is None
             else d_cls.astype(jnp.int32))
    if use_pallas:
        return _assoc_pallas(t_boxes, d_boxes, t_mask, d_mask, t_cls,
                             d_cls, iou_thr=iou_thr,
                             interpret=_interpret())
    return _assoc_xla(t_boxes, d_boxes, t_mask, d_mask, t_cls, d_cls,
                      iou_thr=iou_thr)


def crop_resize(images, rois, *, out_size, use_pallas=True):
    """ROI crop+resize for the cascade's hierarchical second pass:
    images (B, H, W, ch), rois (B, R, 4) normalized xyxy ->
    crops (B, R, C, C, ch) float32.  Like NMS, ``use_pallas=False``
    routes to the XLA twin of the same float32 index math (the
    production path on non-TPU hosts); ``ref.crop_resize_ref`` is the
    bit-compatibility oracle."""
    if not use_pallas:
        return _crop_xla(images, rois, out_size=out_size)
    return _crop_pallas(images, rois, out_size=out_size,
                        interpret=_interpret())


def uncrop_boxes(boxes, rois, *, bounds, crop_size, use_pallas=True):
    """Map second-pass detections from crop pixel coordinates back into
    the parent frame.  boxes (..., 4) in [0, crop_size], rois (..., 4)
    normalized windows (broadcast), bounds = (W, H).  XLA twin on
    ``use_pallas=False``; ``ref.uncrop_boxes_ref`` is the oracle."""
    if not use_pallas:
        return _uncrop_xla(boxes, rois, bounds=tuple(bounds),
                           crop_size=crop_size)
    return _uncrop_pallas(boxes, rois, bounds=tuple(bounds),
                          crop_size=crop_size, interpret=_interpret())


def nms(boxes, scores, iou_thr=0.5, max_out=64, use_pallas=True):
    """Single-frame greedy NMS: routed through the fused batched kernel
    (B=1).  Returns (keep_idx (max_out,), valid mask), identical to
    ``ref.nms_ref``."""
    keep, valid = batched_nms(boxes[None], scores[None], iou_thr=iou_thr,
                              max_out=max_out, use_pallas=use_pallas)
    return keep[0], valid[0]


def nms_serial(boxes, scores, iou_thr=0.5, max_out=64, use_pallas=True):
    """The seed's per-image NMS: IoU matrix (Pallas kernel when
    ``use_pallas``) + an A-step sequential suppress loop.  Kept as the
    benchmark baseline for the fused batched path."""
    iou = iou_matrix(boxes, boxes, use_pallas=use_pallas)
    order = jnp.argsort(-scores)

    def body(i, state):
        keep, kcount, alive = state
        idx = order[i]
        ok = alive[idx]
        keep = keep.at[kcount].set(jnp.where(ok, idx, keep[kcount]))
        kcount = kcount + ok.astype(jnp.int32)
        alive = alive & ~((iou[idx] >= iou_thr) & ok)
        return keep, kcount, alive

    keep0 = jnp.zeros((max_out,), jnp.int32)
    alive0 = jnp.ones((boxes.shape[0],), bool)
    keep, kcount, _ = jax.lax.fori_loop(0, boxes.shape[0], body,
                                        (keep0, 0, alive0))
    valid = jnp.arange(max_out) < kcount
    return keep, valid
