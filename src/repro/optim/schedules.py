"""LR schedules: linear-warmup cosine, and MiniCPM's WSD
(Warmup-Stable-Decay, arXiv:2404.06395 §4): linear warmup to peak, a long
stable plateau, then an exponential decay tail."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str, peak_lr: float, total_steps: int,
                  warmup_steps: int = 100, decay_frac: float = 0.1,
                  final_frac: float = 0.1):
    warmup_steps = max(1, min(warmup_steps, total_steps // 2))

    def cosine(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / warmup_steps
        prog = jnp.clip((step - warmup_steps) /
                        jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)

    def wsd(step):
        step = jnp.asarray(step, jnp.float32)
        decay_steps = jnp.maximum(total_steps * decay_frac, 1.0)
        decay_start = total_steps - decay_steps
        warm = peak_lr * step / warmup_steps
        stable = jnp.full_like(step, peak_lr)
        prog = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        decay = peak_lr * (final_frac ** prog)
        out = jnp.where(step < warmup_steps, warm,
                        jnp.where(step < decay_start, stable, decay))
        return out

    return {"cosine": cosine, "wsd": wsd}[kind]
