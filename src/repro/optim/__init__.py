from .adamw import AdamWConfig, adamw_init, adamw_update
from .schedules import make_schedule

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "make_schedule"]
