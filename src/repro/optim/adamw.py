"""Hand-rolled AdamW (optax is not available offline).

Moment dtype is configurable: the 671B-class MoE configs use bfloat16
moments so optimizer state fits the 16 GB/chip v5e HBM budget under full
FSDP sharding (see docs/ARCHITECTURE.md §Sharding model)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr):
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
