"""RWKV6-3B "Finch" [arXiv:2404.05892] — attention-free, data-dependent
per-channel decay.  32L d_model=2560 d_ff=8960 vocab=65536, head_size=64
(40 heads).  O(1) recurrent state ⇒ long_500k runs natively."""
from repro.models.config import (LayerSpec, ModelConfig, RWKVConfig, Stage)


def make_config(preset="full", variant=None):
    if preset == "smoke":
        return ModelConfig(
            name="rwkv6-3b-smoke", d_model=256, d_ff=512, vocab_size=512,
            stages=(Stage((LayerSpec("rwkv", "rwkv_cmix"),), 2),),
            n_heads=0, n_kv_heads=0, rope="none",
            rwkv=RWKVConfig(head_size=32))
    return ModelConfig(
        name="rwkv6-3b", d_model=2560, d_ff=8960, vocab_size=65536,
        stages=(Stage((LayerSpec("rwkv", "rwkv_cmix"),), 32),),
        n_heads=0, n_kv_heads=0, rope="none",
        rwkv=RWKVConfig(head_size=64),
        dtype="bfloat16", param_dtype="bfloat16")
