"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407] — dense, GQA
kv=8, 128k context.  40L d_model=5120 32H d_ff=14336 vocab=131072,
head_dim=128."""
from repro.configs.base import SWA_WINDOW
from repro.models.config import ModelConfig, dense_stages


def make_config(preset="full", variant=None):
    win = SWA_WINDOW if variant == "swa" else None
    if preset == "smoke":
        return ModelConfig(
            name="mistral-nemo-12b-smoke", d_model=256, d_ff=512,
            vocab_size=512, stages=dense_stages(2), n_heads=4, n_kv_heads=2,
            head_dim=64, decode_window=win)
    return ModelConfig(
        name="mistral-nemo-12b", d_model=5120, d_ff=14336, vocab_size=131072,
        stages=dense_stages(40), n_heads=32, n_kv_heads=8, head_dim=128,
        rope_theta=1e6, decode_window=win,
        dtype="bfloat16", param_dtype="bfloat16")
