from .base import (ARCH_IDS, SHAPES, SWA_WINDOW, InputShape, get_config,
                   supported_shapes)

__all__ = ["ARCH_IDS", "SHAPES", "SWA_WINDOW", "InputShape", "get_config",
           "supported_shapes"]
