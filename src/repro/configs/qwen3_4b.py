"""Qwen3-4B [hf:Qwen/Qwen3-8B] — dense, GQA kv=8, per-head qk-RMSNorm.
36L d_model=2560 32H d_ff=9728 vocab=151936, head_dim=128."""
from repro.configs.base import SWA_WINDOW
from repro.models.config import ModelConfig, dense_stages


def make_config(preset="full", variant=None):
    win = SWA_WINDOW if variant == "swa" else None
    if preset == "smoke":
        return ModelConfig(
            name="qwen3-4b-smoke", d_model=256, d_ff=512, vocab_size=512,
            stages=dense_stages(2), n_heads=4, n_kv_heads=2, head_dim=64,
            qk_norm=True, decode_window=win)
    return ModelConfig(
        name="qwen3-4b", d_model=2560, d_ff=9728, vocab_size=151936,
        stages=dense_stages(36), n_heads=32, n_kv_heads=8, head_dim=128,
        qk_norm=True, rope_theta=1e6, decode_window=win,
        dtype="bfloat16", param_dtype="bfloat16")
