"""Grok-1-314B [hf:xai-org/grok-1] — MoE 8 experts top-2, every layer.
64L d_model=6144 48H (kv=8) d_ff=32768 vocab=131072.  8 experts on a
16-way model axis: expert dim is tensor-parallel *within* experts (the
rules engine picks the (None, fsdp, tensor) layout automatically)."""
from repro.configs.base import SWA_WINDOW
from repro.models.config import (LayerSpec, ModelConfig, MoEConfig, Stage)


def make_config(preset="full", variant=None):
    win = SWA_WINDOW if variant == "swa" else None
    if preset == "smoke":
        return ModelConfig(
            name="grok-1-smoke", d_model=256, d_ff=512, vocab_size=512,
            stages=(Stage((LayerSpec("attn", "moe"),), 2),),
            n_heads=4, n_kv_heads=2, head_dim=64,
            moe=MoEConfig(n_experts=4, top_k=2, d_ff=512), decode_window=win)
    return ModelConfig(
        name="grok-1-314b", d_model=6144, d_ff=32768, vocab_size=131072,
        stages=(Stage((LayerSpec("attn", "moe"),), 64),),
        n_heads=48, n_kv_heads=8, head_dim=128,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32768, dispatch="batched"), decode_window=win,
        dtype="bfloat16", param_dtype="bfloat16")
