"""HuBERT-XLarge [arXiv:2106.07447] — audio encoder-only backbone.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-unit prediction
targets).  The mel/conv feature extractor is a stub: ``input_specs`` feeds
precomputed frame embeddings (frontend_dim=512, the wav2vec2 conv output
width).  Positional information: we use RoPE in place of HuBERT's
convolutional relative positional embedding (stub-frontend carve-out;
recorded here).  Encoder-only ⇒ no decode shapes.
"""
from repro.models.config import ModelConfig, dense_stages


def make_config(preset="full", variant=None):
    if preset == "smoke":
        return ModelConfig(
            name="hubert-xlarge-smoke", d_model=256, d_ff=512, vocab_size=504,
            stages=dense_stages(2), n_heads=4, n_kv_heads=4, head_dim=64,
            causal=False, rope="full", modality="audio", frontend_dim=64)
    return ModelConfig(
        name="hubert-xlarge", d_model=1280, d_ff=5120, vocab_size=504,
        stages=dense_stages(48), n_heads=16, n_kv_heads=16, head_dim=80,
        causal=False, rope="full", modality="audio", frontend_dim=512,
        dtype="bfloat16", param_dtype="bfloat16")
