"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409] — VLM: Pixtral-ViT frontend
(STUB: ``input_specs`` provides precomputed patch embeddings, dim 1024)
feeding a Mistral-Nemo-12B language backbone.  40L d_model=5120 32H (kv=8)
d_ff=14336 vocab=131072.  Image patches occupy the first 1024 sequence
positions during train/prefill; decode consumes text tokens only."""
from repro.configs.base import SWA_WINDOW
from repro.models.config import ModelConfig, dense_stages


def make_config(preset="full", variant=None):
    win = SWA_WINDOW if variant == "swa" else None
    if preset == "smoke":
        return ModelConfig(
            name="pixtral-12b-smoke", d_model=256, d_ff=512, vocab_size=512,
            stages=dense_stages(2), n_heads=4, n_kv_heads=2, head_dim=64,
            modality="vlm", frontend_dim=64, n_frontend_tokens=16,
            decode_window=win)
    return ModelConfig(
        name="pixtral-12b", d_model=5120, d_ff=14336, vocab_size=131072,
        stages=dense_stages(40), n_heads=32, n_kv_heads=8, head_dim=128,
        rope_theta=1e6, modality="vlm", frontend_dim=1024,
        n_frontend_tokens=1024, decode_window=win,
        dtype="bfloat16", param_dtype="bfloat16")
