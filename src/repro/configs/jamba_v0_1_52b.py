"""Jamba-v0.1-52B [arXiv:2403.19887] — hybrid Mamba+attention 7:1 with
MoE 16e top-2 every other layer.  32L d_model=4096 32H (kv=8) d_ff=14336
vocab=65536.  Period of 8 layers: attention at index 4, Mamba elsewhere;
MoE FFN on odd indices.  SSM state ⇒ long_500k runs natively (the 4
attention layers keep a full KV cache, sharded over the data axis).
"""
from repro.models.config import (LayerSpec, MambaConfig, ModelConfig,
                                 MoEConfig, Stage)


def _pattern(window=None):
    specs = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        specs.append(LayerSpec(mixer, ffn, window))
    return tuple(specs)


def make_config(preset="full", variant=None):
    if preset == "smoke":
        return ModelConfig(
            name="jamba-v0.1-52b-smoke", d_model=256, d_ff=512,
            vocab_size=512,
            stages=(Stage(pattern=(LayerSpec("mamba", "moe"),
                                   LayerSpec("attn", "dense")), repeats=1),),
            n_heads=4, n_kv_heads=2, head_dim=64, rope="full",
            moe=MoEConfig(n_experts=4, top_k=2, d_ff=512),
            mamba=MambaConfig(d_state=8, d_conv=4, expand=2))
    return ModelConfig(
        name="jamba-v0.1-52b", d_model=4096, d_ff=14336, vocab_size=65536,
        stages=(Stage(pattern=_pattern(), repeats=4),),
        n_heads=32, n_kv_heads=8, head_dim=128, rope="full",
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336, dispatch="batched"),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        dtype="bfloat16", param_dtype="bfloat16")
