"""MiniCPM-2B [arXiv:2404.06395] — llama-like dense, MHA (kv=36), tied
embeddings, trained with the WSD (warmup-stable-decay) schedule, which is
implemented in ``repro.optim.schedules``.  40L d_model=2304 36H d_ff=5760
vocab=122753, head_dim=64.  36 heads do not divide the 16-way model axis —
the rules engine falls back to fsdp-only sharding for attention projections
(padding to 48 heads is a recorded §Perf candidate)."""
from repro.configs.base import SWA_WINDOW
from repro.models.config import ModelConfig, dense_stages


def make_config(preset="full", variant=None):
    win = SWA_WINDOW if variant == "swa" else None
    if preset == "smoke":
        return ModelConfig(
            name="minicpm-2b-smoke", d_model=256, d_ff=512, vocab_size=512,
            stages=dense_stages(2), n_heads=4, n_kv_heads=4, head_dim=64,
            tie_embeddings=True, decode_window=win)
    return ModelConfig(
        name="minicpm-2b", d_model=2304, d_ff=5760, vocab_size=122753,
        stages=dense_stages(40), n_heads=36, n_kv_heads=36, head_dim=64,
        tie_embeddings=True, decode_window=win,
        dtype="bfloat16", param_dtype="bfloat16")
