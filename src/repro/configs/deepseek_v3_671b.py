"""DeepSeek-V3-671B [arXiv:2412.19437] — MLA + 1 shared / 256 routed top-8
MoE + MTP.  61L d_model=7168 128H vocab=129280.  The assigned d_ff=2048 is
the per-expert hidden dim; the first 3 layers are dense FFN (18432, per the
source paper) and layers 4..61 are MoE.  Sigmoid router with normalized
top-8 weights.  The MLA compressed KV cache (kv_lora 512 + rope 64) is what
makes long-context decode shapes small."""
from repro.configs.base import SWA_WINDOW
from repro.models.config import (MLAConfig, ModelConfig, MoEConfig,
                                 dense_stages, LayerSpec, Stage)


def make_config(preset="full", variant=None):
    win = SWA_WINDOW if variant == "swa" else None
    if preset == "smoke":
        return ModelConfig(
            name="deepseek-v3-smoke", d_model=256, d_ff=512, vocab_size=512,
            stages=(Stage((LayerSpec("attn", "dense"),), 1),
                    Stage((LayerSpec("attn", "moe"),), 1)),
            n_heads=4, n_kv_heads=4, head_dim=64,
            mla=MLAConfig(q_lora_rank=128, kv_lora_rank=64, qk_nope_dim=32,
                          qk_rope_dim=16, v_head_dim=32),
            moe=MoEConfig(n_experts=4, top_k=2, d_ff=256,
                          n_shared_experts=1, shared_d_ff=256,
                          router="sigmoid"),
            mtp=True, decode_window=win)
    return ModelConfig(
        name="deepseek-v3-671b", d_model=7168, d_ff=18432, vocab_size=129280,
        stages=(Stage((LayerSpec("attn", "dense"),), 3),
                Stage((LayerSpec("attn", "moe"),), 58)),
        n_heads=128, n_kv_heads=128, head_dim=128,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                      qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048,
                      n_shared_experts=1, shared_d_ff=2048,
                      router="sigmoid", capacity_factor=1.25,
                      dispatch="batched"),
        mtp=True, decode_window=win,
        dtype="bfloat16", param_dtype="bfloat16")
