"""ChatGLM3-6B [arXiv:2406.12793] — dense, RoPE-2d (GLM partial rotary),
GQA with kv=2 (multi-query-ish).  28L d_model=4096 32H d_ff=13696
vocab=65024."""
from repro.configs.base import SWA_WINDOW
from repro.models.config import ModelConfig, dense_stages


def make_config(preset="full", variant=None):
    win = SWA_WINDOW if variant == "swa" else None
    if preset == "smoke":
        return ModelConfig(
            name="chatglm3-6b-smoke", d_model=256, d_ff=512, vocab_size=512,
            stages=dense_stages(2), n_heads=4, n_kv_heads=2, head_dim=64,
            rope="glm", decode_window=win)
    return ModelConfig(
        name="chatglm3-6b", d_model=4096, d_ff=13696, vocab_size=65024,
        stages=dense_stages(28), n_heads=32, n_kv_heads=2, head_dim=128,
        rope="glm", rope_theta=10000.0, decode_window=win,
        dtype="bfloat16", param_dtype="bfloat16")
