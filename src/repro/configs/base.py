"""Config registry + the four assigned input shapes.

Every architecture module exposes ``make_config(preset, variant)``:
  preset  "full"  — the exact assigned configuration (dry-run only)
          "smoke" — reduced same-family variant (≤2 layers-ish, d_model≤512,
                    ≤4 experts) that runs a real step on CPU
  variant None    — paper-faithful full attention
          "swa"   — sliding-window decode variant (window 4096) enabling
                    long_500k for full-attention architectures (beyond-paper)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from ..models.config import ModelConfig

SWA_WINDOW = 4096


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "hubert-xlarge", "chatglm3-6b", "jamba-v0.1-52b", "qwen3-4b",
    "deepseek-v3-671b", "rwkv6-3b", "mistral-nemo-12b", "grok-1-314b",
    "pixtral-12b", "minicpm-2b",
]


def get_config(arch: str, preset: str = "full",
               variant: Optional[str] = None) -> ModelConfig:
    import importlib
    mod = importlib.import_module(
        f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    return mod.make_config(preset=preset, variant=variant)


def supported_shapes(cfg: ModelConfig, variant: Optional[str] = None):
    """Which of the four shapes this (arch, variant) runs — with skips as
    documented on each config module."""
    out = ["train_4k", "prefill_32k"]
    if cfg.encoder_only:
        return out                       # encoder-only: no decode step
    out.append("decode_32k")
    subquadratic = cfg.attn_free or _is_hybrid(cfg) or variant == "swa" \
        or cfg.decode_window is not None
    if subquadratic:
        out.append("long_500k")
    return out


def _is_hybrid(cfg: ModelConfig) -> bool:
    mixers = {l.mixer for s in cfg.stages for l in s.pattern}
    return "mamba" in mixers or "rwkv" in mixers


def smoke_shrink(cfg: ModelConfig, **extra) -> ModelConfig:
    return dataclasses.replace(cfg, **extra)
