"""Post-SPMD HLO analysis: trip-count-aware FLOPs, HBM bytes, and
collective bytes (per device), walking while-loop bodies with their
known trip counts so work inside `lax.scan` layer stacks is counted
repeats-x — XLA-CPU's own HloCostAnalysis counts loop bodies once, which
underestimates a 61-layer scanned model by ~60x.

Operand shapes are resolved through a per-computation symbol table
(this XLA's HLO printer does not inline operand types).

Feeds the roofline terms:
    compute_s    = flops / peak_FLOPs_per_chip
    memory_s     = bytes / HBM_bw
    collective_s = collective_bytes / ICI_link_bw
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_TYPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*")


def _parse_instr(line: str):
    """-> (name, result_type_str, opname) or None.  Handles tuple result
    types with /*index=k*/ comments via balanced-paren scanning."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i >= len(line):
        return None
    if line[i] == "(":                       # tuple type
        depth = 0
        j = i
        for j in range(i, len(line)):
            depth += line[j] == "("
            depth -= line[j] == ")"
            if depth == 0:
                break
        type_str = line[i:j + 1]
        rest = line[j + 1:]
    else:
        mt = re.match(r"(\w+\[[0-9,]*\]\S*)", line[i:])
        if not mt:
            return None
        type_str = mt.group(1)
        rest = line[i + mt.end():]
    mo = re.match(r"\s+([\w\-]+)", rest)
    if not mo:
        return None
    return name, type_str, mo.group(1)
_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_RE = re.compile(r"body=%?([\w\.\-_]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-_]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-_]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_REF_RE = re.compile(r"%([\w\.\-_]+)")
_FGC_RE = re.compile(r"feature_group_count=(\d+)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _TYPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d.strip()]


def _operand_text(line: str) -> str:
    """Text inside the op's argument parens (skipping a tuple result
    type's parens)."""
    mi = _parse_instr(line)
    if mi is None:
        return ""
    # position after "name = <type> <opname>"
    m = _NAME_RE.match(line)
    idx = m.end() + len(mi[1])
    i = line.find(mi[2] + "(", idx)
    if i < 0:
        return ""
    i = line.find("(", i)
    depth = 0
    for j in range(i, len(line)):
        depth += line[j] == "("
        depth -= line[j] == ")"
        if depth == 0:
            return line[i:j + 1]
    return line[i:]


def parse_computations(hlo: str):
    """-> (computations: name -> [instr lines], entry name,
           symbols: name -> {instr name -> result type str})"""
    comps: Dict[str, List[str]] = {}
    symbols: Dict[str, Dict[str, str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        # computation definitions start at column 0 (instructions are
        # indented), contain '->' and open a brace
        if stripped and not line[:1].isspace() and stripped.endswith("{") \
                and "->" in stripped:
            m = _COMP_RE.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                symbols[cur] = {}
                if stripped.startswith("ENTRY"):
                    entry = cur
                continue
        if stripped.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        comps[cur].append(line)
        mi = _parse_instr(line)
        if mi:
            symbols[cur][mi[0]] = mi[1]
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry, symbols


def _operand_types(line: str, table: Dict[str, str]) -> List[str]:
    text = _operand_text(line)
    inline = _TYPE_RE.findall(text)
    if inline:
        return [f"{dt}[{dims}]" for dt, dims in inline]
    return [table[r] for r in _REF_RE.findall(text) if r in table]


def _dot_flops(line: str, table) -> float:
    mi = _parse_instr(line)
    if not mi:
        return 0.0
    rdims = _shape_dims(mi[1])
    ops = _operand_types(line, table)
    if not ops:
        return 0.0
    lhs_dims = _shape_dims(ops[0])
    mc = _CONTRACT_RE.search(line)
    contract = 1
    if mc and mc.group(1).strip():
        for idx in mc.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    n = 1
    for d in rdims:
        n *= d
    return 2.0 * n * contract


def _conv_flops(line: str, table) -> float:
    mi = _parse_instr(line)
    if not mi:
        return 0.0
    rdims = _shape_dims(mi[1])
    ops = _operand_types(line, table)
    if len(ops) < 2:
        return 0.0
    kdims = _shape_dims(ops[1])
    n = 1
    for d in rdims:
        n *= d
    k = 1
    for d in kdims[:-1]:
        k *= d
    mg = _FGC_RE.search(line)
    groups = int(mg.group(1)) if mg else 1
    return 2.0 * n * k / groups


SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "iota", "while", "conditional",
              "call"}


class HloAnalysis:
    def __init__(self, hlo: str):
        self.comps, self.entry, self.symbols = parse_computations(hlo)
        self._memo: Dict[str, Dict[str, float]] = {}
        self._unknown_trips = 0

    def _walk(self, name: str, flops_only: bool) -> Dict[str, float]:
        key = f"{name}#{flops_only}"
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = {}
        table = self.symbols.get(name, {})
        acc: Dict[str, float] = {"flops": 0.0, "bytes": 0.0}
        for k in COLLECTIVES:
            acc[k] = 0.0
        for line in self.comps.get(name, []):
            mi = _parse_instr(line)
            if not mi:
                continue
            opname = mi[2]
            if opname == "dot":
                acc["flops"] += _dot_flops(line, table)
            elif opname == "convolution":
                acc["flops"] += _conv_flops(line, table)
            for ck in COLLECTIVES:
                if opname == ck or opname == ck + "-start":
                    b = sum(_type_bytes(t)
                            for t in _operand_types(line, table))
                    acc[ck] += b
                    break
            if not flops_only and opname not in SKIP_BYTES and \
                    not opname.endswith("-done"):
                dus_slice = None
                if opname == "fusion":
                    dus_slice = self._fusion_dus_slice(line)
                if dus_slice is not None:
                    # in-place stacked-buffer update inside a scan: traffic
                    # = slice read+write, not the whole 40-layer buffer
                    acc["bytes"] += 2 * dus_slice
                elif opname == "dynamic-update-slice":
                    # in-place slice write: traffic = update read + region
                    # write, NOT the whole (e.g. layer-stacked) buffer
                    ops_t = _operand_types(line, table)
                    upd = ops_t[1] if len(ops_t) > 1 else mi[1]
                    acc["bytes"] += 2 * _type_bytes(upd)
                elif opname == "dynamic-slice":
                    # slice read + result write
                    acc["bytes"] += 2 * _type_bytes(mi[1])
                else:
                    acc["bytes"] += sum(_type_bytes(t)
                                        for t in _operand_types(line, table))
                    acc["bytes"] += _type_bytes(mi[1])
            # recurse
            mult, children, f_children = 1.0, [], []
            if opname == "while":
                mt = _TRIP_RE.search(line)
                if mt:
                    mult = float(mt.group(1))
                else:
                    self._unknown_trips += 1
                mb, mc = _BODY_RE.search(line), _COND_RE.search(line)
                children += [c.group(1) for c in (mb, mc) if c]
            elif opname == "fusion":
                mcall = _CALL_RE.search(line)
                if mcall:
                    f_children.append(mcall.group(1))
            else:
                mcall = _CALL_RE.search(line)
                if mcall:
                    children.append(mcall.group(1))
                mbr = _BRANCH_RE.search(line)
                if mbr:
                    children += [c.strip().lstrip("%")
                                 for c in mbr.group(1).split(",")]
            for child in children:
                sub = self._walk(child, flops_only)
                for k_, v in sub.items():
                    acc[k_] = acc.get(k_, 0.0) + mult * v
            for child in f_children:   # fused dots: flops yes, bytes no
                sub = self._walk(child, True)
                acc["flops"] += mult * sub["flops"]
                for ck in COLLECTIVES:
                    acc[ck] += mult * sub.get(ck, 0.0)
        self._memo[key] = acc
        return acc

    def _fusion_dus_slice(self, line: str):
        """If this fusion's root is a dynamic-update-slice, return the
        byte size of the updated slice, else None."""
        mcall = _CALL_RE.search(line)
        if not mcall:
            return None
        comp = mcall.group(1)
        table = self.symbols.get(comp, {})
        for inner in self.comps.get(comp, []):
            if "ROOT" not in inner:
                continue
            mi = _parse_instr(inner)
            if not mi:
                return None
            if mi[2] == "dynamic-update-slice":
                ops = _operand_types(inner, table)
                if len(ops) > 1:
                    return _type_bytes(ops[1])
                return _type_bytes(mi[1])
            return None
        return None

    def totals(self) -> Dict:
        acc = self._walk(self.entry, False) if self.entry else \
            {"flops": 0.0, "bytes": 0.0}
        by_kind = {k: acc.get(k, 0.0) for k in COLLECTIVES}
        return {
            "flops": acc.get("flops", 0.0),
            "bytes": acc.get("bytes", 0.0),
            "by_kind": by_kind,
            "total_bytes": float(sum(by_kind.values())),
            "unknown_trip_counts": self._unknown_trips,
            "n_computations": len(self.comps),
        }


def hlo_cost_from_text(hlo: str) -> Dict:
    t = HloAnalysis(hlo).totals()
    return {"flops": t["flops"], "bytes": t["bytes"]}


def collective_bytes_from_hlo(hlo: str) -> Dict:
    t = HloAnalysis(hlo).totals()
    return {"by_kind": t["by_kind"], "total_bytes": t["total_bytes"],
            "unknown_trip_counts": t["unknown_trip_counts"],
            "n_computations": t["n_computations"]}
