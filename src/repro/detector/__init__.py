from .ssd import (SSDConfig, decode_detections, detector_loss, init_ssd,
                  make_anchors, ssd_forward)

__all__ = ["SSDConfig", "decode_detections", "detector_loss", "init_ssd",
           "make_anchors", "ssd_forward"]
