"""Mini single-shot detector in pure JAX — the paper's executor payload
class (SSD300/YOLOv3 stand-in; pretrained weights are not available
offline, so examples train this on the synthetic benchmark video).

Conv backbone (stride-2 blocks) -> two feature maps -> per-anchor box
regression + objectness + class logits; decode + greedy NMS through the
fused batched Pallas NMS kernel (repro.kernels.nms) — the whole
micro-batch is suppressed in one launch.  Input: (B, 64, 64, 3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..models.layers import truncated_normal


@dataclass(frozen=True)
class SSDConfig:
    image_size: int = 64
    n_classes: int = 3
    channels: Tuple[int, ...] = (16, 32, 64, 64)   # stride-2 conv blocks
    anchor_scales: Tuple[float, ...] = (0.15, 0.35)
    feature_strides: Tuple[int, ...] = (8, 16)     # maps at 8x8 and 4x4


def _conv_init(key, k, c_in, c_out):
    return {
        "w": truncated_normal(key, (k, k, c_in, c_out), jnp.float32,
                              1.0 / np.sqrt(k * k * c_in)),
        "b": jnp.zeros((c_out,), jnp.float32),
    }


def _conv(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def make_anchors(cfg: SSDConfig) -> np.ndarray:
    """(A_total, 4) xyxy in [0,1] image coords."""
    out = []
    for stride, scale in zip(cfg.feature_strides, cfg.anchor_scales):
        g = cfg.image_size // stride
        cs = (np.arange(g) + 0.5) / g
        cx, cy = np.meshgrid(cs, cs)
        for ar in (1.0, 2.0):
            w = scale * np.sqrt(ar)
            h = scale / np.sqrt(ar)
            out.append(np.stack([cx - w / 2, cy - h / 2,
                                 cx + w / 2, cy + h / 2], -1).reshape(-1, 4))
    return np.concatenate(out, 0).astype(np.float32)


def init_ssd(cfg: SSDConfig, key):
    ks = jax.random.split(key, len(cfg.channels) + 2)
    p = {"backbone": []}
    c_in = 3
    for i, c in enumerate(cfg.channels):
        p["backbone"].append(_conv_init(ks[i], 3, c_in, c))
        c_in = c
    n_anchor_kinds = 2
    out_dim = n_anchor_kinds * (4 + 1 + cfg.n_classes)
    p["head8"] = _conv_init(ks[-2], 3, cfg.channels[-2], out_dim)
    p["head16"] = _conv_init(ks[-1], 3, cfg.channels[-1], out_dim)
    return p


def ssd_forward(p, cfg: SSDConfig, images):
    """images: (B, S, S, 3) -> (boxes_delta (B,A,4), obj (B,A),
    cls_logits (B,A,C))."""
    x = images
    feats = []
    for i, blk in enumerate(p["backbone"]):
        x = jax.nn.relu(_conv(blk, x, stride=2))
        feats.append(x)
    f8, f16 = feats[-2], feats[-1]           # (B,8,8,C), (B,4,4,C)
    outs = []
    for f, head in ((f8, p["head8"]), (f16, p["head16"])):
        y = _conv(head, f)                   # (B,g,g,2*(5+C))
        B, g, _, _ = y.shape
        outs.append(y.reshape(B, g * g * 2, 5 + cfg.n_classes))
    y = jnp.concatenate(outs, 1)             # (B, A, 5+C)
    return y[..., :4], y[..., 4], y[..., 5:]


def detector_loss(p, cfg: SSDConfig, images, gt_boxes, gt_classes, gt_mask,
                  anchors):
    """gt_boxes: (B,K,4) in [0,1]; gt_mask: (B,K) valid flags."""
    deltas, obj, cls_logits = ssd_forward(p, cfg, images)
    B, A = obj.shape
    anc = jnp.asarray(anchors)               # (A,4)

    def per_image(gtb, gtc, gtm):
        iou = _iou(anc, gtb)                 # (A,K)
        iou = iou * gtm[None, :]
        best_gt = jnp.argmax(iou, 1)         # (A,)
        best_iou = jnp.max(iou, 1)
        pos = best_iou >= 0.45
        tgt_box = gtb[best_gt]               # (A,4)
        tgt_cls = gtc[best_gt]
        return pos, tgt_box, tgt_cls

    pos, tgt_box, tgt_cls = jax.vmap(per_image)(gt_boxes, gt_classes,
                                                gt_mask)
    anc_wh = anc[:, 2:] - anc[:, :2]
    anc_c = (anc[:, :2] + anc[:, 2:]) / 2
    tgt_c = (tgt_box[..., :2] + tgt_box[..., 2:]) / 2
    tgt_wh = jnp.maximum(tgt_box[..., 2:] - tgt_box[..., :2], 1e-4)
    tgt_delta = jnp.concatenate(
        [(tgt_c - anc_c) / anc_wh, jnp.log(tgt_wh / anc_wh)], -1)

    posf = pos.astype(jnp.float32)
    n_pos = jnp.maximum(jnp.sum(posf), 1.0)
    box_l = jnp.sum(jnp.abs(deltas - tgt_delta).sum(-1) * posf) / n_pos
    obj_t = posf
    obj_l = jnp.mean(
        jnp.maximum(obj, 0) - obj * obj_t + jnp.log1p(jnp.exp(-jnp.abs(obj))))
    logz = jax.scipy.special.logsumexp(cls_logits, -1)
    gold = jnp.take_along_axis(cls_logits, tgt_cls[..., None], -1)[..., 0]
    cls_l = jnp.sum((logz - gold) * posf) / n_pos
    return box_l + obj_l + cls_l, {"box": box_l, "obj": obj_l, "cls": cls_l}


def _iou(a, b):
    tl = jnp.maximum(a[:, None, :2], b[None, :, :2])
    br = jnp.minimum(a[:, None, 2:], b[None, :, 2:])
    inter = jnp.prod(jnp.clip(br - tl, 0.0), -1)
    aa = jnp.prod(a[:, 2:] - a[:, :2], -1)
    ab = jnp.prod(b[:, 2:] - b[:, :2], -1)
    return inter / jnp.maximum(aa[:, None] + ab[None] - inter, 1e-9)


def decode_detections(p, cfg: SSDConfig, images, anchors, score_thr=0.4,
                      iou_thr=0.5, max_out=32, use_pallas=False):
    """Full inference: forward + box decode + fused batched NMS (one
    suppression launch for the whole micro-batch; Pallas kernel when
    use_pallas=True, its XLA twin otherwise).  Returns per-image
    (boxes, scores, classes, valid)."""
    deltas, obj, cls_logits = ssd_forward(p, cfg, images)
    anc = jnp.asarray(anchors)
    anc_wh = anc[:, 2:] - anc[:, :2]
    anc_c = (anc[:, :2] + anc[:, 2:]) / 2
    c = anc_c + deltas[..., :2] * anc_wh
    wh = anc_wh * jnp.exp(jnp.clip(deltas[..., 2:], -4, 4))
    boxes = jnp.concatenate([c - wh / 2, c + wh / 2], -1)   # (B,A,4)
    scores = jax.nn.sigmoid(obj)
    classes = jnp.argmax(cls_logits, -1)

    # score-thresholding and suppression are fused into the batched NMS;
    # stop_at_zero skips the zero-score tail, whose survivors the seed
    # path enumerated only to mask them back out of ``valid``
    keep, valid = kops.batched_nms(boxes, scores, iou_thr=iou_thr,
                                   score_thr=score_thr, max_out=max_out,
                                   stop_at_zero=True, use_pallas=use_pallas)
    sc = jnp.where(scores >= score_thr, scores, 0.0)
    bxk = jnp.take_along_axis(boxes, keep[..., None], axis=1)
    sck = jnp.take_along_axis(sc, keep, axis=1)
    clk = jnp.take_along_axis(classes, keep, axis=1)
    valid = valid & (sck > 0)
    return bxk, sck, clk, valid
