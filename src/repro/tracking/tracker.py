"""Batched multi-object tracker: fixed-capacity track table + masked
lifecycle updates, one fused launch per frame batch.

The track table is a struct-of-arrays ``TrackerState`` with a leading
batch axis (B independent streams tracked in lockstep — the serving
engine uses B=1, a multi-camera NVR deployment raises it).  No Python
object per track ever exists: birth, confirmation, coasting and death
are all masked array updates inside one jitted ``step``:

  predict  — constant-velocity Kalman predict on every slot, age +=1,
             score decay while coasting, kill after ``max_coast``
             frames without a matched detection (the slot's ``active``
             bit drops; its storage is reused by the next birth).
  associate— fused IoU cost + greedy assignment kernel
             (``kernels/association.py``), class-gated.
  update   — Kalman measurement update on matched slots; hit counters
             drive confirmation (``min_hits``).
  birth    — unmatched detections land in free slots via the same
             exclusive-cumsum rank trick the NMS kernel uses for slot
             assignment (k-th unmatched detection -> k-th free slot),
             so birth is O(T·D) vectorized, not a Python scan.  When
             unmatched detections outnumber free slots, the
             lowest-score COASTING tracks are evicted to make room
             (overflow eviction); only a table whose every slot
             matched a detection this frame — nothing safe to evict —
             still drops the overflow birth with ``det_tid = -1``.

``output`` emits the confirmed, alive slots — the boxes a dropped frame
gets instead of nothing.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .association import associate, cxcywh_to_xyxy, xyxy_to_cxcywh
from .kalman import init_cov, kf_predict, kf_update


@dataclass(frozen=True)
class TrackerConfig:
    capacity: int = 64         # track-table slots per stream
    iou_thr: float = 0.3       # association gate
    min_hits: int = 2          # matches before a track is emitted
    max_coast: int = 12        # frames without a match before death
    score_decay: float = 0.95  # per-coasted-frame score multiplier
    birth_score_thr: float = 0.0   # detections below never seed tracks
    q: float = 1.0             # process noise intensity (px^2/frame^4)
    r: float = 9.0             # measurement noise variance (px^2)
    p0_vel: float = 25.0       # fresh-track velocity variance


class TrackerState(NamedTuple):
    pos: jnp.ndarray        # (B, T, 4) cx, cy, w, h
    vel: jnp.ndarray        # (B, T, 4)
    cov: jnp.ndarray        # (B, T, 4, 3) [p_xx, p_xv, p_vv] per coord
    score: jnp.ndarray      # (B, T) last matched detection score, decayed
    cls: jnp.ndarray        # (B, T) int32
    track_id: jnp.ndarray   # (B, T) int32 (globally unique per stream)
    hits: jnp.ndarray       # (B, T) int32 total matches
    tsu: jnp.ndarray        # (B, T) int32 frames since last match
    active: jnp.ndarray     # (B, T) bool
    next_id: jnp.ndarray    # (B,) int32


def init_state(batch: int, cfg: TrackerConfig) -> TrackerState:
    B, T = batch, cfg.capacity
    return TrackerState(
        pos=jnp.zeros((B, T, 4), jnp.float32),
        vel=jnp.zeros((B, T, 4), jnp.float32),
        cov=jnp.zeros((B, T, 4, 3), jnp.float32),
        score=jnp.zeros((B, T), jnp.float32),
        cls=jnp.zeros((B, T), jnp.int32),
        track_id=jnp.full((B, T), -1, jnp.int32),
        hits=jnp.zeros((B, T), jnp.int32),
        tsu=jnp.zeros((B, T), jnp.int32),
        active=jnp.zeros((B, T), bool),
        next_id=jnp.zeros((B,), jnp.int32),
    )


def _tick(state: TrackerState, cfg: TrackerConfig) -> TrackerState:
    """One frame of time passing: Kalman predict + coast bookkeeping."""
    pos, vel, cov = kf_predict(state.pos, state.vel, state.cov, cfg.q)
    tsu = state.tsu + state.active
    score = jnp.where(state.active, state.score * cfg.score_decay,
                      state.score)
    active = state.active & (tsu <= cfg.max_coast)
    return state._replace(pos=pos, vel=vel, cov=cov, tsu=tsu,
                          score=score, active=active)


@functools.partial(jax.jit, static_argnames=("cfg",))
def coast(state: TrackerState, cfg: TrackerConfig) -> TrackerState:
    """Advance the table over a frame with no detections (a frame the
    executors never saw).  Not a miss: lifecycle is clocked in frames,
    so ``max_coast`` bounds the total interpolation span either way."""
    return _tick(state, cfg)


@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas"))
def step(state: TrackerState, boxes, scores, classes, valid,
         cfg: TrackerConfig, use_pallas: bool = False):
    """One detection frame per stream: predict, associate, update,
    birth — all masked array updates, one launch per frame batch.

    boxes (B, D, 4) xyxy, scores (B, D), classes (B, D), valid (B, D).
    Returns (new_state, det_track_id (B, D) int32): the track id each
    detection landed on (matched or newborn), -1 for unused slots.
    """
    B, T = state.active.shape
    D = boxes.shape[1]
    boxes = boxes.astype(jnp.float32)
    scores = scores.astype(jnp.float32)
    classes = classes.astype(jnp.int32)
    valid = valid.astype(bool)

    state = _tick(state, cfg)

    # -------------------------------------------------------- associate
    match = associate(state.pos, state.active, state.cls, boxes, valid,
                      classes, cfg.iou_thr, use_pallas)      # (B, T)
    matched = match >= 0
    mi = jnp.maximum(match, 0)
    z = xyxy_to_cxcywh(jnp.take_along_axis(boxes, mi[..., None], axis=1))

    # ----------------------------------------------------------- update
    pos, vel, cov = kf_update(state.pos, state.vel, state.cov, z, cfg.r,
                              matched[..., None])
    score = jnp.where(matched, jnp.take_along_axis(scores, mi, axis=1),
                      state.score)
    hits = state.hits + matched
    tsu = jnp.where(matched, 0, state.tsu)

    # ------------------------------------------------------------ birth
    darange = jnp.arange(D, dtype=jnp.int32)
    taken = jnp.any((match[..., None] == darange[None, None]) &
                    matched[..., None], axis=1)              # (B, D)
    unmatched = valid & ~taken & (scores >= cfg.birth_score_thr)
    free = ~state.active

    # ---------------------------------------------- overflow eviction
    # When unmatched detections outnumber free slots, births used to be
    # silently dropped (det_tid stayed -1 with no signal).  Instead the
    # lowest-score COASTING tracks (active but unmatched this frame)
    # give up exactly the missing slots; every evicted slot is
    # guaranteed to be reborn below, because the eviction count never
    # exceeds n_unmatched - n_free.  With no overflow ``need`` is 0 and
    # this whole block is the identity.
    need = jnp.maximum(jnp.sum(unmatched, -1) - jnp.sum(free, -1),
                       0)[:, None]                           # (B, 1)
    evictable = state.active & ~matched
    # ascending-score rank among evictable slots (ties -> lower index
    # first): double stable argsort = rank, O(T log T) — non-evictable
    # slots sort last behind +inf keys and are masked out anyway
    key = jnp.where(evictable, state.score, jnp.inf)
    rank = jnp.argsort(jnp.argsort(key, axis=-1), axis=-1)   # (B, T)
    evict = evictable & (rank < need)
    free = free | evict
    d_rank = jnp.cumsum(unmatched, -1) - unmatched           # excl. rank
    t_rank = jnp.cumsum(free, -1) - free
    pair = (free[:, :, None] & unmatched[:, None, :] &
            (t_rank[:, :, None] == d_rank[:, None, :]))      # (B, T, D)
    birth = jnp.any(pair, -1)                                # (B, T)
    bidx = jnp.argmax(pair, -1)                              # det index
    bz = xyxy_to_cxcywh(jnp.take_along_axis(boxes, bidx[..., None],
                                            axis=1))
    b3 = birth[..., None]
    pos = jnp.where(b3, bz, pos)
    vel = jnp.where(b3, 0.0, vel)
    cov = jnp.where(b3[..., None],
                    init_cov((B, T, 4), cfg.r, cfg.p0_vel), cov)
    score = jnp.where(birth, jnp.take_along_axis(scores, bidx, axis=1),
                      score)
    cls = jnp.where(birth, jnp.take_along_axis(classes, bidx, axis=1),
                    state.cls)
    new_id = state.next_id[:, None] + t_rank
    track_id = jnp.where(birth, new_id, state.track_id)
    next_id = state.next_id + jnp.sum(birth, -1, dtype=jnp.int32)
    hits = jnp.where(birth, 1, hits)
    tsu = jnp.where(birth, 0, tsu)
    active = (state.active & ~evict) | birth

    # which track id each detection landed on (matched or newborn)
    m_onehot = (match[..., None] == darange[None, None]) & matched[..., None]
    det_tid = jnp.max(jnp.where(m_onehot | pair, track_id[..., None], -1),
                      axis=1)                                # (B, D)
    det_tid = jnp.where(valid, det_tid, -1)

    return state._replace(pos=pos, vel=vel, cov=cov, score=score,
                          cls=cls, track_id=track_id, hits=hits,
                          tsu=tsu, active=active,
                          next_id=next_id), det_tid


def export_rows(state: TrackerState) -> list:
    """Split the (B, T) table into B portable per-stream rows: plain
    dicts of numpy copies (one entry per ``TrackerState`` field, the
    batch axis stripped).  Rows are serializable and shard-agnostic —
    the currency track identities travel in across segment boundaries,
    stream migration and evacuation.  ``rows_to_state`` rebuilds a
    table from any subset/reordering of them bit-identically."""
    arrs = {f: np.asarray(getattr(state, f))
            for f in TrackerState._fields}
    B = arrs["active"].shape[0]
    return [{f: arrs[f][b].copy() for f in TrackerState._fields}
            for b in range(B)]


def rows_to_state(rows, cfg: TrackerConfig) -> TrackerState:
    """Rebuild a (B, T) table from ``len(rows)`` portable rows; a None
    entry seeds that batch row fresh (== ``init_state``).  All-None
    input returns ``init_state`` itself, so a cold start is
    bit-identical to the pre-portability behavior."""
    fresh = init_state(len(rows), cfg)
    if all(r is None for r in rows):
        return fresh
    cols = {f: np.asarray(getattr(fresh, f)).copy()
            for f in TrackerState._fields}
    for b, r in enumerate(rows):
        if r is None:
            continue
        for f in TrackerState._fields:
            cols[f][b] = r[f]
    return TrackerState(**{f: jnp.asarray(v) for f, v in cols.items()})


@functools.partial(jax.jit, static_argnames=("cfg",))
def output(state: TrackerState, cfg: TrackerConfig):
    """Emit the confirmed, alive tracks: (boxes (B, T, 4) xyxy, scores,
    classes, track ids, valid).  Unconfirmed births (e.g. single-frame
    false positives that never re-matched) stay silent."""
    emit = state.active & (state.hits >= cfg.min_hits)
    return (cxcywh_to_xyxy(state.pos), state.score, state.cls,
            state.track_id, emit)
