"""Dropped-frame interpolation: run the tracker over a simulated run's
processed frames and synthesize tracker-predicted boxes for every frame
the executors never saw.

This is the bridge between the paper's pipeline (stream -> scheduler ->
executors -> synchronizer) and the tracking subsystem: where the
synchronizer's stale-reuse fill replays the *last processed frame's*
boxes verbatim (zero-velocity prediction — the mechanism behind the
paper's mAP collapse), ``fill_stream`` coasts every confirmed track
through the gap, so a dropped frame gets motion-compensated boxes at a
tiny fraction of the detector's cost.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..core.synchronizer import SequenceSynchronizer
from . import tracker as trk
from .tracker import TrackerConfig


@dataclass
class TrackedFrame:
    """Per-arrival-frame output of the tracked stream.  Processed frames
    carry their own (fresh) detections; dropped frames carry the
    tracker-predicted boxes and are tagged ``interpolated``."""
    index: int
    boxes: np.ndarray        # (N, 4) xyxy
    scores: np.ndarray       # (N,)
    classes: np.ndarray      # (N,)
    track_ids: np.ndarray    # (N,) int32, -1 if the detection joined no track
    interpolated: bool


def _detect_all(video, processed: Sequence[int], detector, det_by_frame):
    """Proxy detections for every processed frame, batched per detector
    (one vectorized noise-synthesis call per model)."""
    groups: Dict[int, tuple] = {}
    for i in processed:
        det = (det_by_frame or {}).get(i, detector)
        groups.setdefault(id(det), (det, []))[1].append(i)
    out = {}
    for det, idxs in groups.values():
        if hasattr(det, "detect_many"):
            det.detect_many(video, idxs)
        for i in idxs:
            out[i] = det.detect(video, i)
    return out


def fill_stream(video, result, detector, det_by_frame=None,
                cfg: Optional[TrackerConfig] = None,
                use_pallas: bool = False) -> List[TrackedFrame]:
    """Tracked output stream for a ``SimResult``: every arrival frame
    yields a TrackedFrame, processed frames feeding the tracker and
    dropped frames coasting it.  The sequence synchronizer decides the
    emission order and the interpolated tagging (``order_tracked``);
    this function fills in the boxes."""
    cfg = cfg or TrackerConfig()
    ordered = SequenceSynchronizer().order_tracked(result)
    processed = sorted(sf.index for sf in ordered if not sf.stale)
    dets = _detect_all(video, processed, detector, det_by_frame)
    d_cap = max([len(d.boxes) for d in dets.values()] + [1])
    d_cap += -d_cap % 8          # one jit trace for the whole run
    state = trk.init_state(1, cfg)
    out: List[TrackedFrame] = []
    for sf in ordered:
        i = sf.index
        if not sf.interpolated:
            d = dets[i]
            n = len(d.boxes)
            boxes = np.zeros((1, d_cap, 4), np.float32)
            scores = np.zeros((1, d_cap), np.float32)
            classes = np.zeros((1, d_cap), np.int32)
            valid = np.zeros((1, d_cap), bool)
            boxes[0, :n] = d.boxes
            scores[0, :n] = d.scores
            classes[0, :n] = d.classes
            valid[0, :n] = True
            state, det_tid = trk.step(state, jnp.asarray(boxes),
                                      jnp.asarray(scores),
                                      jnp.asarray(classes),
                                      jnp.asarray(valid), cfg,
                                      use_pallas=use_pallas)
            out.append(TrackedFrame(i, d.boxes, d.scores, d.classes,
                                    np.asarray(det_tid)[0, :n], False))
        else:
            state = trk.coast(state, cfg)
            b, s, c, tid, emit = (np.asarray(a) for a in
                                  trk.output(state, cfg))
            m = emit[0]
            out.append(TrackedFrame(i, b[0][m], s[0][m], c[0][m],
                                    tid[0][m], True))
    return out
