"""Batched multi-object tracking for the parallel detection pipeline.

Paper -> tracker mapping
------------------------
The source paper (*Parallel Detection for Efficient Video Analytics at
the Edge*) runs n detection models in parallel and RANDOMLY DROPS the
frames that arrive while every executor is busy; its quality tables
(IV/V) show mAP collapsing as the drop rate grows, because the
synchronizer fills a dropped frame with the previous processed frame's
detections verbatim — a zero-velocity prediction whose IoU against the
moving ground truth decays frame by frame.  The authors' follow-up line
of work (*TOD*, 2021; *Fast and Resource-Efficient Object Tracking on
Edge Devices*, 2023) closes that gap with a lightweight tracker running
between detections.  This package is that tracker, built JAX-native so
it rides the same fused-kernel substrate as the detection fast path:

* ``kalman``      — constant-velocity Kalman filter vectorized over the
                    whole (B, T) track table (the motion model that
                    replaces "stale reuse" = constant-position).
* ``association`` — box plumbing around the fused IoU cost-matrix +
                    greedy-assignment kernel
                    (``repro/kernels/association.py``: Pallas kernel,
                    XLA twin, ``ref.greedy_assign_ref`` oracle — the
                    same three-tier pattern as the NMS fast path).
* ``tracker``     — fixed-capacity track table with birth / confirm /
                    coast / kill as masked array updates; one jitted
                    launch per frame batch, B independent streams in
                    lockstep (the NVR/multi-camera scenario).
* ``interpolate`` — ``fill_stream``: every frame the scheduler dropped
                    gets tracker-coasted boxes instead of stale ones,
                    tagged ``interpolated`` and emitted in arrival
                    order.

Quality accounting lives in ``repro.core.quality`` (tracked-stream mAP
via ``evaluate_map_dets``, ID switches / continuity via
``track_quality``); the serving integration is
``serving.DetectionEngine(track_and_interpolate=True)``.
"""
from .interpolate import TrackedFrame, fill_stream
from .tracker import (TrackerConfig, TrackerState, coast, export_rows,
                      init_state, output, rows_to_state, step)

__all__ = ["TrackedFrame", "TrackerConfig", "TrackerState", "coast",
           "export_rows", "fill_stream", "init_state", "output",
           "rows_to_state", "step"]
