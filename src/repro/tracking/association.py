"""Association stage of the tracker: box-format plumbing around the
fused cost-matrix + greedy-assignment kernel (``repro.kernels``).

The tracker state carries boxes as (cx, cy, w, h); detections arrive as
xyxy.  This module owns the conversions and the call into
``ops.greedy_assign`` (Pallas kernel / XLA twin dispatch), keeping
``tracker.py`` free of layout detail.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..kernels import ops


def cxcywh_to_xyxy(pos):
    """(..., 4) center boxes -> xyxy, with w/h floored at 1 so long
    coasts can never emit an inverted box."""
    wh = jnp.maximum(pos[..., 2:], 1.0)
    c = pos[..., :2]
    return jnp.concatenate([c - wh / 2.0, c + wh / 2.0], -1)


def xyxy_to_cxcywh(boxes):
    return jnp.concatenate([(boxes[..., :2] + boxes[..., 2:]) / 2.0,
                            boxes[..., 2:] - boxes[..., :2]], -1)


def associate(pos, active, cls, det_boxes, det_valid, det_cls,
              iou_thr: float, use_pallas: bool = False):
    """Match predicted track boxes to detections.

    pos (B, T, 4) cxcywh, active (B, T) bool, det_boxes (B, D, 4) xyxy
    -> match (B, T) int32 (detection index per track slot or -1).
    Class-gated: a track never matches a detection of another class.
    """
    return ops.greedy_assign(
        cxcywh_to_xyxy(pos), det_boxes.astype(jnp.float32),
        t_mask=active, d_mask=det_valid, t_cls=cls, d_cls=det_cls,
        iou_thr=iou_thr, use_pallas=use_pallas)
