"""Batched constant-velocity Kalman filter over the track table.

The motion model is the one the paper's failure mode implies: dropped
frames reuse *stale* detections, i.e. a zero-velocity prediction, and
the mAP collapse in Tables IV/V is exactly the IoU decay of that
prediction against moving objects.  A constant-velocity filter is the
cheapest model that fixes this — per track, each measurement coordinate
z ∈ {cx, cy, w, h} gets an independent (position, velocity) state with
a 2x2 covariance, which is the block-diagonal structure SORT-style edge
trackers use.

Everything is vectorized over the full ``(B, T)`` track table: state is
``pos``/``vel`` arrays of shape (B, T, 4) and the per-coordinate 2x2
symmetric covariance is packed as (B, T, 4, 3) = [p_xx, p_xv, p_vv].
Predict and update are pure jnp functions (jitted by the callers in
``tracker.py``), so one tracker step is one launch regardless of how
many tracks are alive.
"""
from __future__ import annotations

import jax.numpy as jnp


def kf_predict(pos, vel, cov, q: float, dt: float = 1.0):
    """Advance every track one time step under constant velocity.

    ``q`` is the white-noise-acceleration intensity; the discrete
    process noise is Q = q * [[dt^4/4, dt^3/2], [dt^3/2, dt^2]].
    """
    pxx, pxv, pvv = cov[..., 0], cov[..., 1], cov[..., 2]
    pos = pos + vel * dt
    pxx = pxx + dt * (2.0 * pxv + dt * pvv) + q * dt ** 4 / 4.0
    pxv = pxv + dt * pvv + q * dt ** 3 / 2.0
    pvv = pvv + q * dt * dt
    return pos, vel, jnp.stack([pxx, pxv, pvv], -1)


def kf_update(pos, vel, cov, z, r: float, gate):
    """Measurement update with z (B, T, 4); ``gate`` (B, T, 1) selects
    the tracks that actually matched a detection this frame (the rest
    keep their predicted state untouched).

    With H = [1, 0] and scalar measurement noise r per coordinate the
    gain is closed-form: K = [p_xx, p_xv] / (p_xx + r).
    """
    pxx, pxv, pvv = cov[..., 0], cov[..., 1], cov[..., 2]
    s = pxx + r
    k1 = pxx / s
    k2 = pxv / s
    y = z - pos
    pos_u = pos + k1 * y
    vel_u = vel + k2 * y
    cov_u = jnp.stack([(1.0 - k1) * pxx, (1.0 - k1) * pxv,
                       pvv - k2 * pxv], -1)
    pos = jnp.where(gate, pos_u, pos)
    vel = jnp.where(gate, vel_u, vel)
    cov = jnp.where(gate[..., None], cov_u, cov)
    return pos, vel, cov


def init_cov(shape, r: float, p0_vel: float):
    """Fresh-track covariance: position pinned to the measurement
    noise, velocity wide open so the second match locks the velocity."""
    cov = jnp.zeros(shape + (3,), jnp.float32)
    cov = cov.at[..., 0].set(r)
    cov = cov.at[..., 2].set(p0_vel)
    return cov
