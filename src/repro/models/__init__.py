from .config import (LayerSpec, MLAConfig, MambaConfig, ModelConfig,
                     MoEConfig, RWKVConfig, Stage, dense_stages)
from .transformer import (init_cache, init_model, model_apply)

__all__ = [
    "LayerSpec", "MLAConfig", "MambaConfig", "ModelConfig", "MoEConfig",
    "RWKVConfig", "Stage", "dense_stages", "init_cache", "init_model",
    "model_apply",
]
