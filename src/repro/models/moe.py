"""Mixture-of-Experts with capacity-based sort dispatch (TPU-native).

GPU MoE stacks typically use ragged grouped-GEMM CUDA kernels; the
TPU-idiomatic formulation is static-shape capacity dispatch: tokens are
argsorted by expert id, the first ``capacity`` tokens per expert are
gathered into a dense (E, C, d) block, experts run as one batched einsum
(MXU-friendly), and results scatter-add back with router weights.

Two dispatch scopes (MoEConfig.dispatch):
  "global"  — paper-faithful single token pool across the whole global
              batch.  GSPMD implements the cross-shard gather as an
              all-reduce of the full (E*C, d) dispatch buffer per layer —
              19.6e12 collective bytes/device on grok-1 train_4k.
  "batched" — routing + capacity per batch row (vmap over B).  Gathers
              become shard-local (batch dim and gather indices share the
              data sharding), eliminating the dispatch collectives
              entirely; experts compute via the same batched einsum.
              Capacity drops are decided per row instead of globally
              (standard practice; quality-neutral at equal capacity
              factor).

Experts shard over the ``model`` mesh axis ("expert" logical axis) when
the expert count divides it, else tensor-parallel inside each expert
(e.g. Grok-1's 8 experts on a 16-way model axis).

Supports softmax top-k routing (Grok/Jamba/Mixtral-style) and DeepSeek-V3
sigmoid routing with normalized top-k weights + shared experts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from .config import ModelConfig, MoEConfig
from ..sharding import constrain


def init_moe(key, cfg: ModelConfig):
    m: MoEConfig = cfg.moe
    dt = jnp.dtype(cfg.param_dtype)
    k_router, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    E, d, f = m.n_experts, cfg.d_model, m.d_ff
    p = {
        "router": {"w": layers.dense_init(k_router, d, E, jnp.float32)},
        "experts": {
            "w_gate": _stack_init(k_g, E, d, f, dt),
            "w_up": _stack_init(k_u, E, d, f, dt),
            "w_down": _stack_init(k_d, E, f, d, dt),
        },
    }
    if m.n_shared_experts:
        sf = (m.shared_d_ff or m.d_ff) * m.n_shared_experts
        p["shared"] = layers.init_mlp(k_s, d, sf, dt)
    return p


def _stack_init(key, E, d_in, d_out, dt):
    keys = jax.random.split(key, E)
    return jax.vmap(lambda k: layers.dense_init(k, d_in, d_out, dt))(keys)


def capacity(n_tokens: int, m: MoEConfig) -> int:
    c = int(np.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    return max(4, min(n_tokens, -(-c // 4) * 4))  # mult-of-4, >=4, <=T


def route(x_flat, router_w, m: MoEConfig):
    """x_flat: (T, d) -> (weights (T,k), idx (T,k), aux dict)."""
    logits = (x_flat.astype(jnp.float32) @ router_w)      # (T, E)
    if m.router == "sigmoid":                              # DeepSeek-V3
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, m.top_k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(jnp.sum(scores, -1, keepdims=True),
                                     1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, m.top_k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    # load-balance aux (Switch-style): E * sum_i f_i * P_i
    T = x_flat.shape[0]
    f = jnp.zeros((m.n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = f / (T * m.top_k)
    P = jnp.mean(probs, axis=0)
    lb = m.n_experts * jnp.sum(f * P)
    zl = jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    aux = {"load_balance": lb, "router_z": zl,
           "aux_loss": m.aux_loss_weight * lb + m.router_z_weight * zl}
    return w, idx, aux


def _dispatch_tables(w, idx, T: int, E: int, k: int, C: int):
    """Sort-based dispatch tables: slot -> (token id, combine weight)."""
    e_flat = idx.reshape(-1)                               # (T*k,)
    tok_of = jnp.arange(T * k, dtype=jnp.int32) // k       # (T*k,)
    w_flat = w.reshape(-1)
    order = jnp.argsort(e_flat)                            # group by expert
    e_sorted = e_flat[order]
    counts = jnp.bincount(e_flat, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * k, dtype=jnp.int32) - \
        starts[e_sorted].astype(jnp.int32)
    valid = pos < C
    dest = jnp.where(valid, e_sorted * C + pos, E * C)     # overflow slot
    slot_tok = jnp.zeros((E * C + 1,), jnp.int32).at[dest].set(tok_of[order])
    slot_w = jnp.zeros((E * C + 1,), jnp.float32).at[dest].set(
        jnp.where(valid, w_flat[order], 0.0))
    return slot_tok[:-1], slot_w[:-1]


def _expert_ffn(we, x_disp):
    """x_disp: (..., E, C, d) -> (..., E, C, d) via batched MXU einsums."""
    gate = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", x_disp,
                                  we["w_gate"]))
    up = jnp.einsum("...ecd,edf->...ecf", x_disp, we["w_up"])
    return jnp.einsum("...ecf,efd->...ecd", gate * up, we["w_down"])


def _moe_flat(p, m: MoEConfig, x_flat, C):
    """Dispatch+compute+combine over one token pool (T, d)."""
    T, d = x_flat.shape
    E, k = m.n_experts, m.top_k
    w, idx, aux = route(x_flat, p["router"]["w"], m)
    slot_tok, slot_w = _dispatch_tables(w, idx, T, E, k, C)
    x_disp = x_flat[slot_tok].reshape(E, C, d) * (
        slot_w.reshape(E, C, 1) > 0).astype(x_flat.dtype)
    y = _expert_ffn(p["experts"], x_disp)
    y_flat = y.reshape(E * C, d) * slot_w[:, None].astype(y.dtype)
    out = jnp.zeros((T, d), y.dtype).at[slot_tok].add(y_flat)
    return out, aux


def apply_moe(p, cfg: ModelConfig, x):
    """x: (B, S, d) -> (B, S, d), aux."""
    m = cfg.moe
    B, S, d = x.shape
    if m.dispatch == "batched":
        x = constrain(x, "batch", None, None)
        C = capacity(S, m)
        out, aux = jax.vmap(lambda xr: _moe_flat(p, m, xr, C))(x)
        aux = jax.tree.map(jnp.mean, aux)
        out = constrain(out, "batch", None, None)
    else:
        T = B * S
        x_flat = x.reshape(T, d)
        out, aux = _moe_flat(p, m, x_flat, capacity(T, m))
        out = out.reshape(B, S, d)
    if "shared" in p:
        out = out + layers.apply_mlp(p["shared"], x.reshape(B, S, d))
    return out.reshape(B, S, d), aux
