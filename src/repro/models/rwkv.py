"""RWKV-6 ("Finch") mixer — data-dependent per-channel decay, attention-free.

The recurrence per head (k/v head size ``hs``):

    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T        w_t = exp(-exp(w0 + lora(x)))

GPU implementations fuse this into a CUDA kernel; the TPU adaptation runs a
``lax.scan`` over fixed-size time chunks with ``jax.checkpoint`` on the
chunk body, so the backward pass recomputes inside each chunk and only the
per-chunk (B, H, hs, hs) states are saved — bounding HBM residuals without
the numerically-delicate 1/∏w chunk-parallel decomposition (recorded as a
§Perf candidate: GLA-style chunk-parallel Pallas kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig, RWKVConfig

CHUNK = 64


def _dims(cfg: ModelConfig):
    r: RWKVConfig = cfg.rwkv
    H = cfg.d_model // r.head_size
    return r, H, r.head_size


def init_rwkv(key, cfg: ModelConfig):
    r, H, hs = _dims(cfg)
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 10)
    return {
        "mu_r": jnp.full((d,), 0.5, dt), "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt), "mu_w": jnp.full((d,), 0.5, dt),
        "mu_g": jnp.full((d,), 0.5, dt),
        "wr6": layers.dense_init(ks[0], d, d, dt),
        "wk6": layers.dense_init(ks[1], d, d, dt),
        "wv6": layers.dense_init(ks[2], d, d, dt),
        "wg6": layers.dense_init(ks[3], d, d, dt),
        "wo6": layers.dense_init(ks[4], d, d, dt),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x_w @ w1) @ w2))
        "w0": jnp.full((d,), -1.0, jnp.float32),
        "lora_w1": layers.dense_init(ks[5], d, r.decay_lora, dt),
        "lora_w2": layers.dense_init(ks[6], r.decay_lora, d, dt, scale=0.1),
        "u": layers.truncated_normal(ks[7], (H, hs), jnp.float32, 0.5),
        "ln_scale": jnp.ones((d,), dt), "ln_bias": jnp.zeros((d,), dt),
    }


def init_rwkv_cache(cfg: ModelConfig, batch, dtype):
    r, H, hs = _dims(cfg)
    return {
        "tm_shift": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, hs, hs), jnp.float32),
    }


def _shift(x, last=None):
    """x: (B,T,d) -> previous-token tensor, optionally seeded by `last`."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return jnp.concatenate([first, prev[:, 1:]], axis=1)


def _mix(x, x_prev, mu):
    return x + (x_prev - x) * mu


def _decay(p, xw):
    logw = -jnp.exp(p["w0"] + (jnp.tanh(xw @ p["lora_w1"]) @ p["lora_w2"])
                    .astype(jnp.float32))
    return jnp.exp(logw)                                  # in (0, 1)


def _head_norm(p, out, B, T, d):
    out = out.reshape(B, T, d)
    mean = jnp.mean(out, -1, keepdims=True)
    var = jnp.var(out, -1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 1e-5)
    return out * p["ln_scale"] + p["ln_bias"]


def apply_rwkv(p, cfg: ModelConfig, x, mode="train", cache=None):
    r_cfg, H, hs = _dims(cfg)
    B, T, d = x.shape
    if mode == "decode":
        return _decode_step(p, cfg, x, cache)

    x_prev = _shift(x)
    xr = _mix(x, x_prev, p["mu_r"])
    xk = _mix(x, x_prev, p["mu_k"])
    xv = _mix(x, x_prev, p["mu_v"])
    xw = _mix(x, x_prev, p["mu_w"])
    xg = _mix(x, x_prev, p["mu_g"])

    r = (xr @ p["wr6"]).reshape(B, T, H, hs).astype(jnp.float32)
    k = (xk @ p["wk6"]).reshape(B, T, H, hs).astype(jnp.float32)
    v = (xv @ p["wv6"]).reshape(B, T, H, hs).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg6"])
    w = _decay(p, xw).reshape(B, T, H, hs)
    u = p["u"]

    chunk = min(CHUNK, T)
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                          # (B,H,hs)
        kv = k_t[..., :, None] * v_t[..., None, :]        # (B,H,hs,hs)
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, out

    def chunk_body(S, inp):
        # unroll: XLA fuses u consecutive elementwise state updates into
        # one fusion -> the (B,H,hs,hs) state buffer is read/written once
        # per u steps instead of every step (SS§Perf hillclimb #1)
        return jax.lax.scan(step, S, inp, unroll=8)

    chunk_body = jax.checkpoint(chunk_body)

    def to_chunks(a):                                     # (B,T,H,hs)->(nc,chunk,B,H,hs)
        return a.reshape(B, n_chunks, chunk, H, hs).transpose(1, 2, 0, 3, 4)

    S0 = (jnp.zeros((B, H, hs, hs), jnp.float32) if cache is None
          else cache["wkv"])

    def outer(S, inp):
        return chunk_body(S, inp)

    S_last, outs = jax.lax.scan(
        outer, S0, (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(w)))
    out = outs.transpose(2, 0, 1, 3, 4).reshape(B, T, H * hs)

    out = _head_norm(p, out.astype(x.dtype), B, T, d) * g
    y = out @ p["wo6"]

    new_cache = None
    if mode == "prefill":
        new_cache = {"tm_shift": x[:, -1], "wkv": S_last}
    return y, new_cache


def _decode_step(p, cfg, x, cache):
    r_cfg, H, hs = _dims(cfg)
    B, _, d = x.shape
    xt = x[:, 0]
    prev = cache["tm_shift"]
    xr = _mix(xt, prev, p["mu_r"]); xk = _mix(xt, prev, p["mu_k"])
    xv = _mix(xt, prev, p["mu_v"]); xw = _mix(xt, prev, p["mu_w"])
    xg = _mix(xt, prev, p["mu_g"])
    r = (xr @ p["wr6"]).reshape(B, H, hs).astype(jnp.float32)
    k = (xk @ p["wk6"]).reshape(B, H, hs).astype(jnp.float32)
    v = (xv @ p["wv6"]).reshape(B, H, hs).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg6"])
    w = _decay(p, xw).reshape(B, H, hs)
    S = cache["wkv"]
    kv = k[..., :, None] * v[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv", r, S + p["u"][None, :, :, None] * kv)
    S = w[..., :, None] * S + kv
    out = _head_norm(p, out.reshape(B, 1, d).astype(x.dtype), B, 1, d) * g[:, None]
    y = out @ p["wo6"]
    return y, {"tm_shift": xt, "wkv": S}


# ----------------------------------------------------------- channel mix
def init_rwkv_cmix(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_ck": jnp.full((d,), 0.5, dt), "mu_cr": jnp.full((d,), 0.5, dt),
        "wk_c": layers.dense_init(k1, d, f, dt),
        "wv_c": layers.dense_init(k2, f, d, dt),
        "wr_c": layers.dense_init(k3, d, d, dt),
    }


def init_cmix_cache(cfg: ModelConfig, batch, dtype):
    return {"cm_shift": jnp.zeros((batch, cfg.d_model), dtype)}


def apply_rwkv_cmix(p, cfg: ModelConfig, x, mode="train", cache=None):
    B, T, d = x.shape
    last = cache["cm_shift"] if (mode == "decode" and cache) else None
    x_prev = _shift(x, last) if mode != "decode" else (
        cache["cm_shift"][:, None] if cache else jnp.zeros_like(x))
    xk = _mix(x, x_prev, p["mu_ck"])
    xr = _mix(x, x_prev, p["mu_cr"])
    kk = jnp.square(jax.nn.relu(xk @ p["wk_c"]))
    y = jax.nn.sigmoid(xr @ p["wr_c"]) * (kk @ p["wv_c"])
    new_cache = None
    if mode == "prefill":
        new_cache = {"cm_shift": x[:, -1]}
    elif mode == "decode":
        new_cache = {"cm_shift": x[:, 0]}
    return y, new_cache
