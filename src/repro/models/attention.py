"""Attention mixers: GQA (full / sliding-window), optional qk-norm, and
DeepSeek-style MLA (multi-head latent attention with compressed KV cache).

Three execution modes share one code path:
  train   — full sequence, no cache
  prefill — full sequence, returns a populated KV cache
  decode  — single new token against an existing cache

Caches are position-indexed ring buffers of length ``cache_len`` (= the
sliding window for SWA variants, else the max sequence length), so the
long_500k SWA configs keep O(window) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig, LayerSpec, MLAConfig
from .rope import apply_rope

NEG_INF = -1e30


# --------------------------------------------------------------------- core
Q_CHUNK, K_CHUNK = 512, 1024


def sdpa(q, k, v, mask, scale):
    """q:(B,T,H,D) k/v:(B,S,KV,D) mask:(B,1,T,S) bool -> (B,T,H,D).

    Pure-jnp scaled-dot-product attention (reference path; also the oracle
    the Pallas flash kernels in ``repro.kernels`` are validated against).
    """
    B, T, H, D = q.shape
    KV = k.shape[2]
    if KV != H:
        # grouped GQA: never materialize the repeated K/V (a 4x cache-read
        # saving on kv=8 decode; §Perf hillclimb #3)
        G = H // KV
        qg = q.reshape(B, T, KV, G, D)
        s = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32)
        s = jnp.where(mask[:, None], s * scale, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgts,bskd->btkgd", p, v)
        return out.reshape(B, T, H, D)
    scores = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhts,bshd->bthd", probs, v)


def sdpa_masked(q, k, v, q_pos, k_pos, causal, window, k_valid, scale):
    """Dispatch: chunked online-softmax (flash-style; O(chunk^2) temp
    memory instead of O(T*S)) for long sequences, naive reference for short
    sequences and decode.  Masks are built per chunk from positions, never
    materialized at (T, S)."""
    T, S = q.shape[1], k.shape[1]
    if (T >= 2 * Q_CHUNK and S >= 2 * K_CHUNK and T % Q_CHUNK == 0
            and S % K_CHUNK == 0 and k_valid is None):
        return _sdpa_chunked(q, k, v, q_pos, k_pos, causal, window, scale)
    mask = make_mask(q_pos, k_pos, causal, window, k_valid)
    return sdpa(q, k, v, mask, scale)


def _sdpa_chunked(q, k, v, q_pos, k_pos, causal, window, scale):
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]                 # MLA: v head dim != qk head dim
    G = H // KV                      # grouped GQA: K/V never repeated
    nq, nk = T // Q_CHUNK, S // K_CHUNK

    q_c = q.reshape(B, nq, Q_CHUNK, KV, G, D).transpose(1, 0, 2, 3, 4, 5)
    qp_c = q_pos.reshape(B, nq, Q_CHUNK).transpose(1, 0, 2)
    k_c = k.reshape(B, nk, K_CHUNK, KV, D).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(B, nk, K_CHUNK, KV, Dv).transpose(1, 0, 2, 3, 4)
    kp_c = k_pos.reshape(B, nk, K_CHUNK).transpose(1, 0, 2)

    def q_block(_, inp):
        qb, qpb = inp                            # (B,Tq,KV,G,D), (B,Tq)

        @jax.checkpoint  # recompute score chunks in backward: O(chunk^2)
        def kv_step(carry, kv_in):
            m, l, acc = carry
            kb, vb, kpb = kv_in
            s = jnp.einsum("btkgd,bskd->bkgts", qb, kb).astype(jnp.float32)
            s = s * scale
            msk = make_mask(qpb, kpb, causal, window)  # (B,1,Tq,Tk)
            s = jnp.where(msk[:, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgts,bskd->bkgtd", p, vb.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, Q_CHUNK), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, Q_CHUNK), jnp.float32)
        a0 = jnp.zeros((B, KV, G, Q_CHUNK, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (k_c, v_c, kp_c))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B,KV,G,Tq,Dv) -> (B,Tq,KV,G,Dv)
        return None, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, (q_c, qp_c))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, H, Dv)


def make_mask(q_pos, k_pos, causal, window, k_valid=None):
    """q_pos:(B,T) k_pos:(B,S) -> bool (B,1,T,S)."""
    q = q_pos[:, None, :, None]
    k = k_pos[:, None, None, :]
    m = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        m &= k <= q
    if window is not None:
        m &= (q - k) < window
    if k_valid is not None:
        m &= k_valid[:, None, None, :]
    return m


# ------------------------------------------------------------------ GQA
def init_gqa(key, cfg: ModelConfig):
    H, KV, D, dm = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": layers.dense_init(ks[0], dm, H * D, dt),
        "wk": layers.dense_init(ks[1], dm, KV * D, dt),
        "wv": layers.dense_init(ks[2], dm, KV * D, dt),
        "wo": layers.dense_init(ks[3], H * D, dm, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rms_norm(D, dt)
        p["k_norm"] = layers.init_rms_norm(D, dt)
    return p


def init_gqa_cache(cfg: ModelConfig, spec: LayerSpec, batch, cache_len, dtype):
    KV, D = cfg.n_kv_heads, cfg.head_dim
    win = spec.window or cfg.decode_window
    L = min(cache_len, win) if win else cache_len
    return {
        "k": jnp.zeros((batch, L, KV, D), dtype),
        "v": jnp.zeros((batch, L, KV, D), dtype),
    }


def _ring_positions(cache_len, next_pos):
    """Positions stored at each ring slot after ``next_pos`` tokens have been
    written (token i lives at slot i % cache_len).  Slot s holds the largest
    position p < next_pos with p ≡ s (mod cache_len)."""
    slots = jnp.arange(cache_len, dtype=jnp.int32)
    last = next_pos - 1
    k_pos = last - jnp.mod(last - slots, cache_len)
    valid = k_pos >= 0
    return k_pos.astype(jnp.int32), valid


def apply_gqa(p, cfg: ModelConfig, spec: LayerSpec, x, positions,
              mode="train", cache=None, decode_pos=None):
    B, T, _ = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, T, H, D)
    k = (x @ p["wk"]).reshape(B, T, KV, D)
    v = (x @ p["wv"]).reshape(B, T, KV, D)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = layers.rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope)
    scale = D ** -0.5
    window = spec.window or (cfg.decode_window if mode != "train" else spec.window)

    new_cache = None
    if mode in ("train", "prefill"):
        out = sdpa_masked(q, k, v, positions, positions, cfg.causal,
                          window, None, scale)
        if mode == "prefill":
            new_cache = _fill_cache(cache, k, v, T)
    else:  # decode: T == 1, append at decode_pos then attend over the ring
        L = cache["k"].shape[1]
        slot = jnp.mod(decode_pos, L)
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k_pos, valid = _ring_positions(L, decode_pos + 1)
        k_pos = jnp.broadcast_to(k_pos[None], (B, L))
        valid = jnp.broadcast_to(valid[None], (B, L))
        out = sdpa_masked(q, ck, cv, positions, k_pos, cfg.causal, window,
                          valid, scale)

    y = out.reshape(B, T, H * D) @ p["wo"]
    return y, new_cache


def _fill_cache(cache, k, v, T):
    """Write the last ``cache_len`` of the prefill K/V into the ring so that
    token i sits at slot i %% cache_len (matching decode's ring indexing)."""
    L = cache["k"].shape[1]
    if T <= L:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
        return {"k": ck, "v": cv}
    # keep the trailing window, placed at its ring slots
    tail_k, tail_v = k[:, T - L:], v[:, T - L:]
    shift = jnp.mod(T - L, L)
    ck = jnp.roll(tail_k, shift, axis=1)
    cv = jnp.roll(tail_v, shift, axis=1)
    return {"k": ck, "v": cv}


# ------------------------------------------------------------------ MLA
def init_mla(key, cfg: ModelConfig):
    m: MLAConfig = cfg.mla
    H, dm = cfg.n_heads, cfg.d_model
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": layers.dense_init(ks[0], dm, m.q_lora_rank, dt),
        "q_norm": layers.init_rms_norm(m.q_lora_rank, dt),
        "wq_b": layers.dense_init(ks[1], m.q_lora_rank, H * qk_dim, dt),
        "wkv_a": layers.dense_init(ks[2], dm, m.kv_lora_rank + m.qk_rope_dim, dt),
        "kv_norm": layers.init_rms_norm(m.kv_lora_rank, dt),
        "wk_b": layers.dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_dim, dt),
        "wv_b": layers.dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim, dt),
        "wo": layers.dense_init(ks[5], H * m.v_head_dim, dm, dt),
    }


def init_mla_cache(cfg: ModelConfig, spec: LayerSpec, batch, cache_len, dtype):
    m = cfg.mla
    win = spec.window or cfg.decode_window
    L = min(cache_len, win) if win else cache_len
    return {
        "ckv": jnp.zeros((batch, L, m.kv_lora_rank), dtype),
        "kpe": jnp.zeros((batch, L, m.qk_rope_dim), dtype),
    }


def _mla_expand(p, cfg, ckv):
    """ckv:(B,S,r) -> k_nope:(B,S,H,nope), v:(B,S,H,v_dim)."""
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = ckv.shape
    k_nope = (ckv @ p["wk_b"]).reshape(B, S, H, m.qk_nope_dim)
    v = (ckv @ p["wv_b"]).reshape(B, S, H, m.v_head_dim)
    return k_nope, v


def apply_mla(p, cfg: ModelConfig, spec: LayerSpec, x, positions,
              mode="train", cache=None, decode_pos=None):
    m, H = cfg.mla, cfg.n_heads
    B, T, _ = x.shape
    qk_dim = m.qk_nope_dim + m.qk_rope_dim

    q_lat = layers.rms_norm(x @ p["wq_a"], p["q_norm"]["scale"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(B, T, H, qk_dim)
    q_nope, q_pe = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta, "full")
    q = jnp.concatenate([q_nope, q_pe], axis=-1)

    kv = x @ p["wkv_a"]
    ckv = layers.rms_norm(kv[..., :m.kv_lora_rank], p["kv_norm"]["scale"],
                          cfg.norm_eps)
    kpe = kv[..., m.kv_lora_rank:][:, :, None, :]       # single shared head
    kpe = apply_rope(kpe, positions, cfg.rope_theta, "full")[:, :, 0]

    scale = qk_dim ** -0.5
    window = spec.window or (cfg.decode_window if mode != "train" else None)

    new_cache = None
    if mode in ("train", "prefill"):
        k_nope, v = _mla_expand(p, cfg, ckv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe[:, :, None], (B, T, H, m.qk_rope_dim))],
            axis=-1)
        out = sdpa_masked(q, k, v, positions, positions, cfg.causal,
                          window, None, scale)
        if mode == "prefill":
            new_cache = _fill_mla_cache(cache, ckv, kpe, T)
    else:
        # weight-absorbed MLA decode (DeepSeek-V2/V3): attention runs in
        # the compressed kv_lora space — W_kb is absorbed into the query
        # and W_vb into the output, so the (L, H, nope+v) expansion of the
        # cache never materializes.  Exact algebra; ~1000x fewer decode
        # FLOPs at L=32k.
        L = cache["ckv"].shape[1]
        slot = jnp.mod(decode_pos, L)
        cckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, slot, 0))
        ckpe = jax.lax.dynamic_update_slice(cache["kpe"], kpe, (0, slot, 0))
        new_cache = {"ckv": cckv, "kpe": ckpe}
        wk_b = p["wk_b"].reshape(m.kv_lora_rank, H, m.qk_nope_dim)
        wv_b = p["wv_b"].reshape(m.kv_lora_rank, H, m.v_head_dim)
        q_abs = jnp.einsum("bthn,rhn->bthr", q_nope, wk_b)    # (B,1,H,r)
        s = jnp.einsum("bthr,bsr->bhts", q_abs.astype(jnp.float32),
                       cckv.astype(jnp.float32))
        s = s + jnp.einsum("bthp,bsp->bhts", q_pe.astype(jnp.float32),
                           ckpe.astype(jnp.float32))
        s = s * scale
        k_pos, valid = _ring_positions(L, decode_pos + 1)
        k_pos = jnp.broadcast_to(k_pos[None], (B, L))
        valid = jnp.broadcast_to(valid[None], (B, L))
        mask = make_mask(positions, k_pos, cfg.causal, window, valid)
        s = jnp.where(mask, s, NEG_INF)
        prob = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhts,bsr->bthr", prob,
                         cckv.astype(jnp.float32))            # (B,1,H,r)
        out = jnp.einsum("bthr,rhv->bthv", ctx,
                         wv_b.astype(jnp.float32)).astype(x.dtype)

    y = out.reshape(B, T, H * m.v_head_dim) @ p["wo"]
    return y, new_cache


def _fill_mla_cache(cache, ckv, kpe, T):
    L = cache["ckv"].shape[1]
    if T <= L:
        return {
            "ckv": jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, 0, 0)),
            "kpe": jax.lax.dynamic_update_slice(cache["kpe"], kpe, (0, 0, 0)),
        }
    shift = jnp.mod(T - L, L)
    return {
        "ckv": jnp.roll(ckv[:, T - L:], shift, axis=1),
        "kpe": jnp.roll(kpe[:, T - L:], shift, axis=1),
    }


# ------------------------------------------------------------------ facade
def init_attention(key, cfg: ModelConfig):
    return init_mla(key, cfg) if cfg.mla else init_gqa(key, cfg)


def init_attention_cache(cfg, spec, batch, cache_len, dtype):
    if cfg.mla:
        return init_mla_cache(cfg, spec, batch, cache_len, dtype)
    return init_gqa_cache(cfg, spec, batch, cache_len, dtype)


def apply_attention(p, cfg, spec, x, positions, mode="train", cache=None,
                    decode_pos=None):
    fn = apply_mla if cfg.mla else apply_gqa
    return fn(p, cfg, spec, x, positions, mode=mode, cache=cache,
              decode_pos=decode_pos)
