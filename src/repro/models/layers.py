"""Shared building blocks: norms, projections, gated MLP, embeddings.

Parameters are plain nested dicts of jnp arrays.  Sharding is applied by
path-based rules in ``repro.sharding`` so the model code stays mesh-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, dtype, stddev):
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale: float = 1.0):
    """Fan-in scaled init for a (d_in, d_out) projection."""
    stddev = scale / np.sqrt(d_in)
    return truncated_normal(key, (d_in, d_out), dtype, stddev)


def rms_norm(x, weight, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dt)


def init_rms_norm(d, dtype):
    # stored as zero-centred so (1 + w) is the effective gain
    return {"scale": jnp.zeros((d,), dtype)}


def apply_rms_norm(params, x, eps):
    return rms_norm(x, params["scale"], eps)


# ---------------------------------------------------------------- gated MLP
def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff, dtype),
        "wi_up": dense_init(k2, d_model, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }


def apply_mlp(params, x):
    gate = jax.nn.silu(x @ params["wi_gate"])
    up = x @ params["wi_up"]
    return (gate * up) @ params["wo"]


# ------------------------------------------------------------- embeddings
def pad_vocab(vocab: int, multiple: int = 256) -> int:
    """Pad vocab to a tensor-parallel-friendly multiple (122753 -> 122880):
    lets the unembed/vocab dim shard over the model axis so CE logits don't
    replicate (a 16x per-device temp-memory win on minicpm/qwen3)."""
    return -(-vocab // multiple) * multiple


def init_embedding(key, vocab, d_model, dtype):
    # 1/sqrt(d) keeps tied-embedding logits O(1) at init
    return {"table": truncated_normal(key, (pad_vocab(vocab), d_model),
                                      dtype, d_model ** -0.5)}


def apply_embedding(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def init_unembed(key, d_model, vocab, dtype):
    return {"w": dense_init(key, d_model, pad_vocab(vocab), dtype)}


def apply_unembed(params, x):
    return x @ params["w"]


def cross_entropy(logits, labels, mask=None):
    """Mean token cross-entropy in f32. labels: int ids, mask: optional 0/1."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
