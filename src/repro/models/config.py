"""Unified model configuration covering every assigned architecture family.

A model is a stack of *stages*; each stage repeats a *pattern* (period) of
layers, and each layer is a (mixer, ffn) pair:

  mixer ∈ {"attn", "mamba", "rwkv"}      ffn ∈ {"dense", "moe", "rwkv_cmix"}

Homogeneous models are one stage with a single-layer pattern; Jamba is one
stage whose pattern is the 8-layer Mamba/attention period; DeepSeek-V3 is a
3-layer dense-FFN stage followed by a 58-layer MoE stage.  Stages are
executed with ``jax.lax.scan`` over the stacked period parameters so the
lowered HLO stays compact for 61-layer models on 512 devices.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

MIXERS = ("attn", "mamba", "rwkv")
FFNS = ("dense", "moe", "rwkv_cmix", "none")


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"
    ffn: str = "dense"
    # Sliding-window attention (None = full). Per-layer so hybrids can mix.
    window: Optional[int] = None

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ffn in FFNS, self.ffn


@dataclass(frozen=True)
class Stage:
    pattern: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    n_shared_experts: int = 0      # DeepSeek-style always-on shared experts
    shared_d_ff: int = 0           # hidden dim of the shared expert(s)
    router: str = "softmax"        # "softmax" | "sigmoid" (DeepSeek-V3)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3
    dispatch: str = "global"       # "global" (paper-faithful pool) |
    #                                "batched" (per-row; shard-local gather)


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64           # rank of the data-dependent decay LoRA
    mix_lora: int = 32             # rank of the token-shift mix LoRA


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    d_ff: int
    vocab_size: int
    stages: Tuple[Stage, ...]
    # --- attention ---
    n_heads: int = 0               # 0 for attention-free models
    n_kv_heads: int = 0
    head_dim: int = 128
    qk_norm: bool = False
    causal: bool = True            # False => encoder-only (no decode path)
    rope: str = "full"             # "none" | "full" | "glm" (partial/2d)
    rope_theta: float = 10000.0
    mla: Optional[MLAConfig] = None
    # --- mixture of experts ---
    moe: Optional[MoEConfig] = None
    # --- SSM families ---
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # --- io / heads ---
    modality: str = "text"         # "text" | "audio" | "vlm"
    frontend_dim: int = 0          # stub-frontend embedding dim (audio/vlm)
    n_frontend_tokens: int = 0     # patches/frames occupying the seq prefix
    tie_embeddings: bool = False
    mtp: bool = False              # DeepSeek multi-token-prediction head
    mtp_loss_weight: float = 0.3
    # --- numerics ---
    norm_eps: float = 1e-5
    dtype: str = "float32"         # activation/compute dtype
    param_dtype: str = "float32"
    # --- serving ---
    decode_window: Optional[int] = None  # SWA variant window for long-context

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.stages)

    @property
    def attn_free(self) -> bool:
        return all(l.mixer != "attn" for s in self.stages for l in s.pattern)

    @property
    def encoder_only(self) -> bool:
        return not self.causal

    def layer_specs(self):
        """Flat list of LayerSpec in execution order."""
        out = []
        for s in self.stages:
            out.extend(list(s.pattern) * s.repeats)
        return out

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def dense_stages(n_layers: int, window: Optional[int] = None,
                 ffn: str = "dense") -> Tuple[Stage, ...]:
    return (Stage(pattern=(LayerSpec("attn", ffn, window),), repeats=n_layers),)
