"""Composable model: stages of scanned layer periods over any mixer/ffn mix.

One code path serves all ten assigned architectures and all three execution
modes (train / prefill / decode).  Layer stacks run under ``jax.lax.scan``
over stacked period parameters so the lowered HLO is O(pattern) rather than
O(n_layers) — essential for compiling 61-layer models on 512 host devices.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention, layers, mamba, moe, rwkv
from .config import LayerSpec, ModelConfig, Stage
from ..sharding import constrain

ZERO_AUX = {"aux_loss": 0.0, "load_balance": 0.0, "router_z": 0.0}


# ------------------------------------------------------------------ layers
def init_layer(key, cfg: ModelConfig, spec: LayerSpec):
    k_mix, k_ffn = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    p: Dict[str, Any] = {"mixer_norm": layers.init_rms_norm(cfg.d_model, dt)}
    if spec.mixer == "attn":
        p["mixer"] = attention.init_attention(k_mix, cfg)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba.init_mamba(k_mix, cfg)
    elif spec.mixer == "rwkv":
        p["mixer"] = rwkv.init_rwkv(k_mix, cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["ffn_norm"] = layers.init_rms_norm(cfg.d_model, dt)
    if spec.ffn == "dense":
        p["ffn"] = layers.init_mlp(k_ffn, cfg.d_model, cfg.d_ff, dt)
    elif spec.ffn == "moe":
        p["ffn"] = moe.init_moe(k_ffn, cfg)
    elif spec.ffn == "rwkv_cmix":
        p["ffn"] = rwkv.init_rwkv_cmix(k_ffn, cfg)
    return p


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch, cache_len,
                     dtype):
    c: Dict[str, Any] = {}
    if spec.mixer == "attn":
        c["mixer"] = attention.init_attention_cache(cfg, spec, batch,
                                                    cache_len, dtype)
    elif spec.mixer == "mamba":
        c["mixer"] = mamba.init_mamba_cache(cfg, batch, dtype)
    elif spec.mixer == "rwkv":
        c["mixer"] = rwkv.init_rwkv_cache(cfg, batch, dtype)
    c["ffn"] = (rwkv.init_cmix_cache(cfg, batch, dtype)
                if spec.ffn == "rwkv_cmix" else {})
    return c


def apply_layer(p, cfg: ModelConfig, spec: LayerSpec, h, positions,
                mode="train", cache=None, decode_pos=None):
    cache = cache or {}
    h_norm = layers.apply_rms_norm(p["mixer_norm"], h, cfg.norm_eps)
    if spec.mixer == "attn":
        y, mc = attention.apply_attention(p["mixer"], cfg, spec, h_norm,
                                          positions, mode=mode,
                                          cache=cache.get("mixer"),
                                          decode_pos=decode_pos)
    elif spec.mixer == "mamba":
        y, mc = mamba.apply_mamba(p["mixer"], cfg, h_norm, mode=mode,
                                  cache=cache.get("mixer"))
    else:
        y, mc = rwkv.apply_rwkv(p["mixer"], cfg, h_norm, mode=mode,
                                cache=cache.get("mixer"))
    h = h + y
    h = constrain(h, "batch", "seq", None)

    aux = dict(ZERO_AUX)
    fc: Any = {}
    if spec.ffn != "none":
        f_norm = layers.apply_rms_norm(p["ffn_norm"], h, cfg.norm_eps)
        if spec.ffn == "dense":
            f = layers.apply_mlp(p["ffn"], f_norm)
        elif spec.ffn == "moe":
            f, moe_aux = moe.apply_moe(p["ffn"], cfg, f_norm)
            aux.update(moe_aux)
        else:
            f, fc = rwkv.apply_rwkv_cmix(p["ffn"], cfg, f_norm, mode=mode,
                                         cache=cache.get("ffn"))
            fc = fc or {}
        h = h + f
        h = constrain(h, "batch", "seq", None)
    new_cache = {"mixer": mc if mc is not None else {}, "ffn": fc}
    return h, new_cache, aux


# ------------------------------------------------------------------ stages
def init_stage(key, cfg: ModelConfig, stage: Stage):
    layer_stacks = []
    for j, spec in enumerate(stage.pattern):
        keys = jax.random.split(jax.random.fold_in(key, j), stage.repeats)
        layer_stacks.append(
            jax.vmap(lambda k, s=spec: init_layer(k, cfg, s))(keys))
    return {"layers": layer_stacks}


def init_stage_cache(cfg, stage: Stage, batch, cache_len, dtype):
    stacks = []
    for spec in stage.pattern:
        proto = init_layer_cache(cfg, spec, batch, cache_len, dtype)
        stacks.append(jax.tree.map(
            lambda a: jnp.zeros((stage.repeats,) + a.shape, a.dtype), proto))
    return {"caches": stacks}


def run_stage(stage_p, cfg: ModelConfig, stage: Stage, h, positions,
              mode="train", stage_cache=None, decode_pos=None, remat=False):
    pattern = stage.pattern
    with_cache = stage_cache is not None

    def body(carry, xs):
        hh = carry
        if with_cache:
            layer_ps, caches = xs
        else:
            layer_ps, caches = xs, [None] * len(pattern)
        new_caches, aux_tot = [], dict(ZERO_AUX)
        for j, spec in enumerate(pattern):
            hh, nc, aux = apply_layer(layer_ps[j], cfg, spec, hh, positions,
                                      mode=mode, cache=caches[j],
                                      decode_pos=decode_pos)
            new_caches.append(nc)
            aux_tot = {k: aux_tot[k] + aux[k] for k in aux_tot}
        ys = (new_caches, aux_tot) if with_cache else aux_tot
        return hh, ys

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    xs = ((stage_p["layers"], stage_cache["caches"]) if with_cache
          else stage_p["layers"])
    h, ys = jax.lax.scan(body, h, xs)
    if with_cache:
        new_caches, auxs = ys
        new_cache = {"caches": new_caches}
    else:
        new_caches, auxs = None, ys
        new_cache = None
    aux = {k: jnp.sum(auxs[k]) for k in ZERO_AUX}
    return h, new_cache, aux


# ------------------------------------------------------------------ model
def init_model(cfg: ModelConfig, rng):
    ks = jax.random.split(rng, 8)
    dt = jnp.dtype(cfg.param_dtype)
    p: Dict[str, Any] = {}
    if cfg.modality != "audio":
        p["embed"] = layers.init_embedding(ks[0], cfg.vocab_size,
                                           cfg.d_model, dt)
    if cfg.modality in ("audio", "vlm"):
        p["frontend"] = {"w": layers.dense_init(ks[1], cfg.frontend_dim,
                                                cfg.d_model, dt)}
    p["stages"] = [init_stage(jax.random.fold_in(ks[2], i), cfg, s)
                   for i, s in enumerate(cfg.stages)]
    p["final_norm"] = layers.init_rms_norm(cfg.d_model, dt)
    if not cfg.tie_embeddings:
        p["unembed"] = layers.init_unembed(ks[3], cfg.d_model, cfg.vocab_size,
                                           dt)
    if cfg.mtp:
        p["mtp"] = {
            "proj": layers.dense_init(ks[4], 2 * cfg.d_model, cfg.d_model, dt),
            "norm_h": layers.init_rms_norm(cfg.d_model, dt),
            "norm_e": layers.init_rms_norm(cfg.d_model, dt),
            "layer": init_layer(ks[5], cfg, LayerSpec("attn", "dense")),
            "final_norm": layers.init_rms_norm(cfg.d_model, dt),
        }
    return p


def init_cache(cfg: ModelConfig, batch, cache_len, dtype=None):
    dtype = jnp.dtype(dtype or cfg.dtype)
    return [init_stage_cache(cfg, s, batch, cache_len, dtype)
            for s in cfg.stages]


def _embed_inputs(p, cfg: ModelConfig, batch_in):
    if cfg.modality == "audio":
        h = batch_in["features"] @ p["frontend"]["w"]
    elif cfg.modality == "vlm" and "image_embeds" in batch_in:
        img = batch_in["image_embeds"] @ p["frontend"]["w"]
        txt = layers.apply_embedding(p["embed"], batch_in["tokens"])
        h = jnp.concatenate([img, txt], axis=1)
    else:
        h = layers.apply_embedding(p["embed"], batch_in["tokens"])
    return h.astype(jnp.dtype(cfg.dtype))


def _unembed(p, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        logits = h @ p["embed"]["table"].T
    else:
        logits = layers.apply_unembed(p["unembed"], h)
    padded = logits.shape[-1]
    if padded != cfg.vocab_size:  # mask pad slots out of the softmax
        neg = jnp.asarray(jnp.finfo(logits.dtype).min, logits.dtype)
        valid = jnp.arange(padded) < cfg.vocab_size
        logits = jnp.where(valid, logits, neg)
    return logits


def logits_fn(p, cfg: ModelConfig, h):
    h = layers.apply_rms_norm(p["final_norm"], h, cfg.norm_eps)
    return _unembed(p, cfg, h)


def model_apply(p, cfg: ModelConfig, batch_in: Dict[str, Any],
                mode: str = "train", cache: Optional[List] = None,
                decode_pos=None, remat: bool = False):
    """Returns (logits, new_cache, aux)."""
    h = _embed_inputs(p, cfg, batch_in)
    B, S, _ = h.shape
    h = constrain(h, "batch", "seq", None)
    if mode == "decode":
        positions = jnp.broadcast_to(decode_pos, (B, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    new_caches, aux_tot = [], dict(ZERO_AUX)
    for i, stage in enumerate(cfg.stages):
        sc = cache[i] if cache is not None else None
        h, nc, aux = run_stage(p["stages"][i], cfg, stage, h, positions,
                               mode=mode, stage_cache=sc,
                               decode_pos=decode_pos, remat=remat)
        new_caches.append(nc)
        aux_tot = {k: aux_tot[k] + aux[k] for k in aux_tot}

    logits = logits_fn(p, cfg, h)
    logits = constrain(logits, "batch", "seq", "tensor")

    if cfg.mtp and mode == "train":
        aux_tot["mtp_logits"] = _mtp_logits(p, cfg, h, batch_in, positions)
    return logits, (new_caches if cache is not None else None), aux_tot


def _mtp_logits(p, cfg, h, batch_in, positions):
    """DeepSeek-V3 multi-token prediction: one extra block predicting t+2
    from (h_t, emb(token_{t+1}))."""
    mp = p["mtp"]
    tokens = batch_in["tokens"]
    nxt = jnp.roll(tokens, -1, axis=1)
    emb = layers.apply_embedding(p["embed"], nxt).astype(h.dtype)
    hn = layers.apply_rms_norm(mp["norm_h"], h, cfg.norm_eps)
    en = layers.apply_rms_norm(mp["norm_e"], emb, cfg.norm_eps)
    x = jnp.concatenate([hn, en], axis=-1) @ mp["proj"]
    x, _, _ = apply_layer(mp["layer"], cfg, LayerSpec("attn", "dense"), x,
                          positions, mode="train")
    x = layers.apply_rms_norm(mp["final_norm"], x, cfg.norm_eps)
    return _unembed(p, cfg, x)
