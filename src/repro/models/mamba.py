"""Mamba (S6) mixer for the Jamba hybrid — TPU-native selective scan.

The reference GPU implementation is a fused CUDA "selective scan" with
shared-memory staging.  On TPU we instead express the recurrence
``h_t = Ā_t h_{t-1} + B̄_t x_t`` as a *chunked associative scan*:
``jax.lax.associative_scan`` (log-depth, vectorizes on the VPU) inside
fixed-size time chunks, with an ``lax.scan`` carrying the SSM state across
chunks.  Chunking bounds the (B, chunk, d_inner, d_state) working set that
a monolithic associative scan would materialize across the full sequence —
this is the HBM→VMEM-aware adaptation of the paper-adjacent GPU kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from .config import ModelConfig, MambaConfig

CHUNK = 256


def _dims(cfg: ModelConfig):
    m: MambaConfig = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return m, d_inner, dt_rank


def init_mamba(key, cfg: ModelConfig):
    m, di, dtr = _dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, m.d_state + 1, dtype=jnp.float32)[None, :],
                 (di, 1))
    dt_init = jax.random.uniform(ks[5], (di,), jnp.float32,
                                 minval=1e-3, maxval=1e-1)
    return {
        "in_proj": layers.dense_init(ks[0], cfg.d_model, 2 * di, dt),
        "conv_w": layers.truncated_normal(ks[1], (m.d_conv, di), dt,
                                          1.0 / np.sqrt(m.d_conv)),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": layers.dense_init(ks[2], di, dtr + 2 * m.d_state, dt),
        "dt_w": layers.dense_init(ks[3], dtr, di, dt),
        "dt_b": jnp.log(jnp.expm1(dt_init)).astype(dt),
        "A_log": jnp.log(A),
        "Dskip": jnp.ones((di,), jnp.float32),
        "out_proj": layers.dense_init(ks[4], di, cfg.d_model, dt),
    }


def init_mamba_cache(cfg: ModelConfig, batch, dtype):
    m, di, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, m.d_state), jnp.float32),
    }


def _causal_conv(x, w, b, d_conv):
    """x: (B,T,di) depthwise causal conv along T."""
    di = x.shape[-1]
    kernel = w.reshape(d_conv, 1, di)
    y = jax.lax.conv_general_dilated(
        x, kernel.astype(x.dtype), window_strides=(1,),
        padding=[(d_conv - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=di)
    return y + b.astype(y.dtype)


def _ssm_inputs(p, cfg, x_c):
    """x_c: (..., di) -> Ā, Bx, C  (f32)."""
    m, di, dtr = _dims(cfg)
    proj = x_c @ p["x_proj"]
    dt_in, B, C = jnp.split(proj, [dtr, dtr + m.d_state], axis=-1)
    dt = jax.nn.softplus((dt_in @ p["dt_w"]).astype(jnp.float32)
                         + p["dt_b"].astype(jnp.float32))       # (..., di)
    A = -jnp.exp(p["A_log"])                                     # (di, ds)
    A_bar = jnp.exp(dt[..., None] * A)                           # (..., di, ds)
    Bx = (dt * x_c.astype(jnp.float32))[..., None] * \
        B.astype(jnp.float32)[..., None, :]                      # (..., di, ds)
    return A_bar, Bx, C.astype(jnp.float32)


def _scan_chunked(A_bar, Bx, h0):
    """Associative scan within the chunk given entry state h0."""
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2
    a_cum, b_cum = jax.lax.associative_scan(combine, (A_bar, Bx), axis=1)
    h = a_cum * h0[:, None] + b_cum                     # (B, chunk, di, ds)
    return h, h[:, -1]


def apply_mamba(p, cfg: ModelConfig, x, mode="train", cache=None):
    """x: (B,T,d). Returns (y, new_cache)."""
    m, di, _ = _dims(cfg)
    B, T, _ = x.shape
    if mode == "decode":
        return _decode_step(p, cfg, x, cache)

    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"], m.d_conv))

    chunk = min(CHUNK, T)
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk

    @jax.checkpoint  # backward recomputes the (B,chunk,di,ds) working set
    def body(h, xc_chunk):
        A_bar, Bx, C = _ssm_inputs(p, cfg, xc_chunk)
        h_seq, h_last = _scan_chunked(A_bar, Bx, h)
        y = jnp.einsum("btds,bts->btd", h_seq, C)
        return h_last, y.astype(x.dtype)

    xc_chunks = x_c.reshape(B, n_chunks, chunk, di).swapaxes(0, 1)
    h0 = jnp.zeros((B, di, m.d_state), jnp.float32)
    h_last, ys = jax.lax.scan(body, h0, xc_chunks)
    y = ys.swapaxes(0, 1).reshape(B, T, di)
    y = y + p["Dskip"].astype(x.dtype) * x_c
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]

    new_cache = None
    if mode == "prefill":
        pad = jnp.zeros((B, max(0, m.d_conv - 1 - T), di), x_in.dtype)
        conv_tail = jnp.concatenate([pad, x_in[:, -(m.d_conv - 1):]], axis=1)
        new_cache = {"conv": conv_tail, "ssm": h_last}
    return out, new_cache


def _decode_step(p, cfg, x, cache):
    m, di, _ = _dims(cfg)
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]                          # (B, 2di)
    x_in, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([cache["conv"], x_in[:, None]], axis=1)
    conv = jnp.einsum("btd,td->bd", window.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    x_c = jax.nn.silu(conv).astype(x.dtype)              # (B, di)
    A_bar, Bx, C = _ssm_inputs(p, cfg, x_c)              # (B, di, ds)
    h = A_bar * cache["ssm"] + Bx
    y = jnp.einsum("bds,bs->bd", h, C).astype(x.dtype)
    y = y + p["Dskip"].astype(x.dtype) * x_c
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    return out, {"conv": window[:, 1:], "ssm": h}
