"""Rotary position embeddings: standard ("full"), GLM partial-2d ("glm"),
and none.  All functions take explicit integer positions so the same code
serves train, prefill, and single-token decode.
"""
from __future__ import annotations

import jax.numpy as jnp


def _rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def apply_rope(x, positions, theta: float, variant: str = "full"):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32.

    variant:
      "none" -> identity
      "full" -> rotary over the whole head_dim (non-interleaved halves)
      "glm"  -> ChatGLM-style: rotary over the first half of head_dim only
                (the "2d" scheme degenerates to 1d positions for standard
                causal LM usage; the second half carries no rotation).
    """
    if variant == "none":
        return x
    head_dim = x.shape[-1]
    if variant == "glm":
        rot_dim = head_dim // 2
        x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
        x_rot = _apply(x_rot, positions, theta)
        return jnp.concatenate([x_rot, x_pass], axis=-1)
    if variant == "full":
        return _apply(x, positions, theta)
    raise ValueError(f"unknown rope variant {variant!r}")


def _apply(x, positions, theta):
    dt = x.dtype
    dim = x.shape[-1]
    freqs = _rope_freqs(dim, theta)                      # (dim/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, dim/2)
    angles = jnp.concatenate([angles, angles], axis=-1)  # (..., seq, dim)
    # broadcast over the heads axis: x is (..., seq, heads, dim)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    return (x32 * cos + _rotate_half(x32) * sin).astype(dt)
