"""Multi-camera NVR serving demo: several cameras multiplexed onto one
shared detector pool (the paper's parallel detection generalized from
one video stream to an NVR deployment).

Each camera paces its own synthetic stream; all frames interleave into
the SAME micro-batches and replicas, and ONE batched tracker (B =
number of cameras, lockstep, one launch per tick) fills every frame
the overloaded pool drops — so each camera still gets full-coverage
output with per-camera accuracy accounting.

  PYTHONPATH=src python examples/nvr_serving.py [--cameras 4]
      [--frames 48] [--rate 2.0] [--replicas 2]
"""
from __future__ import annotations

import argparse

from repro.core import evaluate_streams, proxy_detect_fn_streams
from repro.serving import DetectionEngine, make_nvr_streams


def serve(n_cameras, n_frames, rate, n_replicas, **kw):
    frames, frame_of, videos, dets = make_nvr_streams(n_cameras,
                                                      n_frames, rate)
    eng = DetectionEngine(
        detect_fn=proxy_detect_fn_streams(videos, dets, frame_of),
        n_replicas=n_replicas, service_time=0.4, **kw)
    out = eng.serve(frames)
    return out, evaluate_streams(videos, out["streams"], n_frames)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cameras", type=int, default=4)
    ap.add_argument("--frames", type=int, default=48)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--replicas", type=int, default=2)
    args = ap.parse_args()

    lam = args.cameras * args.rate
    mu = args.replicas / 0.4
    print(f"== NVR: {args.cameras} cameras x {args.rate} FPS = "
          f"{lam:.1f} FPS onto a {mu:.1f} FPS pool "
          f"({args.replicas} replicas) ==")

    print("-- drop-when-busy (the paper's behaviour, per camera) --")
    out_d, q_d = serve(args.cameras, args.frames, args.rate,
                       args.replicas, drop_when_busy=True)
    print("-- track-and-interpolate (one batched tracker, "
          f"B={args.cameras}) --")
    out_t, q_t = serve(args.cameras, args.frames, args.rate,
                       args.replicas, track_and_interpolate=True)
    assert out_t["tracker_launches"] == out_t["tracker_ticks"]

    print(f"  {'cam':>4s} {'frames':>6s} {'drop':>5s} {'interp':>6s} "
          f"{'cover%':>6s} {'FPS':>6s} {'mAP%':>6s} {'dropmAP%':>8s} "
          f"{'IDsw':>4s}")
    for s in sorted(out_t["per_stream"]):
        v = out_t["per_stream"][s]
        qt = q_t["per_stream"][s]
        qd = q_d["per_stream"].get(s, {"map": 0.0})
        print(f"  {s:4d} {v['frames']:6d} "
              f"{out_d['per_stream'][s]['dropped']:5d} "
              f"{v['interpolated']:6d} {v['coverage']*100:6.1f} "
              f"{v['throughput_fps']:6.2f} {qt['map']*100:6.1f} "
              f"{qd['map']*100:8.1f} {qt['id_switches']:4.0f}")
    print(f"  mean tracked mAP {q_t['map_mean']*100:.1f}% vs dropped "
          f"{q_d['map_mean']*100:.1f}%  |  "
          f"{out_t['tracker_launches']} tracker launches for "
          f"{out_t['tracker_ticks']} ticks x {args.cameras} cameras")

    print("== scaling: cameras sharing the same pool ==")
    print(f"  {'cams':>5s} {'dropcov%':>8s} {'trk mAP%':>8s} "
          f"{'drop mAP%':>9s}")
    for n in (1, 2, 4, 8):
        o_d, s_d = serve(n, args.frames, args.rate, args.replicas,
                         drop_when_busy=True)
        o_t, s_t = serve(n, args.frames, args.rate, args.replicas,
                         track_and_interpolate=True)
        print(f"  {n:5d} {o_d['coverage']*100:8.1f} "
              f"{s_t['map_mean']*100:8.1f} {s_d['map_mean']*100:9.1f}")


if __name__ == "__main__":
    main()
