"""Quickstart: the paper's multi-model parallel detection in ~20 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import ParallelDetector, choose_n

LAMBDA, MU = 14.0, 2.5          # ETH-Sunnyday stream rate; NCS2 YOLOv3 rate

# 1. The problem: one accelerator is 5.6x too slow -> random frame drops
single = ParallelDetector("ETH-Sunnyday", "yolov3", ["ncs2"]).run()
print(f"single NCS2:  sigma={single.sigma:.1f} FPS  "
      f"mAP={single.map_score*100:.1f}%  "
      f"(~{single.drops_per_processed:.0f} drops per processed frame)")

# 2. The paper's fix: n = ceil(lambda/mu) parallel detection models
n = choose_n(LAMBDA, MU, "conservative")
parallel = ParallelDetector("ETH-Sunnyday", "yolov3", ["ncs2"] * n,
                            scheduler="fcfs").run()
print(f"{n} parallel:   sigma={parallel.sigma:.1f} FPS  "
      f"mAP={parallel.map_score*100:.1f}%  (near real-time, near-zero "
      f"drops)")

# 3. Heterogeneous devices: FCFS vs the round-robin baseline
for sched in ("rr", "fcfs"):
    r = ParallelDetector("ETH-Sunnyday", "yolov3",
                         ["fast_cpu"] + ["ncs2"] * 3, sched).run(
        with_map=False)
    print(f"fast CPU + 3 NCS2, {sched:4s}: sigma={r.sigma:.1f} FPS")
