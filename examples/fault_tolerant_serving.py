"""Fault-tolerant NVR serving demo: deterministic chaos, supervised
recovery.

Three legs, all driven by a ``FaultSchedule`` of virtual-time events
(so every run replays bit-identically — re-run with the same seed and
watch the same failures and the same recoveries):

1. **Replica death** on a single host: the scheduler's timeout rule
   detects the dead replica (a dispatcher never sees "dead", only "no
   completion within k x the expected service"), fails the in-flight
   frame over, and the lockstep tracker coasts whatever the shrunken
   pool drops — full per-stream coverage, quality degrading gracefully.
2. **Whole-shard death** on a 2-shard epoch-loop deployment: frames
   arriving while the shard is down are lost (accounted as drops,
   never a silent gap); the ``Watchdog`` notices the missed heartbeat
   at the next epoch boundary, restarts the shard, and evacuates its
   cameras to live shards — every stream back at full coverage within
   one epoch.
3. **Replica lending**: ONE 30 fps camera overloads shard 0 while
   shard 1 idles.  Stream migration refuses to act (moving the only
   stream would just relocate the overload), so the watchdog lends
   shard 1's tail replica to shard 0 and takes it back once the
   pressure clears — strictly fewer drops, pools restored by serve end.

  PYTHONPATH=src python examples/fault_tolerant_serving.py
      [--cameras 4] [--frames 48] [--seed 0]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import evaluate_streams, proxy_detect_fn_streams
from repro.serving import (DetectionEngine, FaultSchedule, FrameRequest,
                           ShardedDetectionEngine, Watchdog,
                           make_nvr_streams)


def leg_replica_death(n_cameras, n_frames):
    frames, frame_of, videos, dets = make_nvr_streams(n_cameras,
                                                      n_frames, rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(detect_fn=oracle, n_replicas=2, service_time=0.05,
              track_and_interpolate=True)
    horizon = n_frames / 4.0
    sched = FaultSchedule.replica_kill(horizon / 3, replica=1)
    print(f"== leg 1: replica 1 of 2 dies at t={horizon / 3:.1f}s "
          f"(never revives) ==")
    print(f"  {'run':>10s} {'cover%':>6s} {'interp':>6s} {'mAP%':>6s} "
          f"{'retries':>7s} {'failovers':>9s}")
    for name, faults in (("fault-free", None), ("replica-kill", sched)):
        rep = DetectionEngine(faults=faults, **kw).serve(frames)
        q = evaluate_streams(videos, rep["streams"], n_frames)
        print(f"  {name:>10s} {rep['coverage'] * 100:6.1f} "
              f"{rep['interpolated']:6d} {q['map_mean'] * 100:6.1f} "
              f"{sum(rep['retries'].values()):7d} "
              f"{sum(rep['failovers'].values()):9d}")
        assert rep["coverage"] == 1.0   # the tracker coasts the losses


def leg_shard_death(n_cameras, n_frames):
    frames, frame_of, videos, dets = make_nvr_streams(n_cameras,
                                                      n_frames, rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(detect_fn=oracle, n_replicas=2, service_time=0.02,
              n_shards=2, rebalance=True, epoch_s=2.0,
              track_and_interpolate=True)
    sched = FaultSchedule.shard_kill(2.5, shard=0)
    print("== leg 2: shard 0 of 2 dies at t=2.5s (epoch_s=2.0) ==")
    print(f"  {'run':>12s} {'drops':>5s} {'lost':>4s} {'recov_cov':>9s} "
          f"{'restarts':>8s} {'evacuations':>11s}")
    for name, sup in (("unsupervised", None), ("watchdog", Watchdog())):
        rep = ShardedDetectionEngine(faults=sched, supervisor=sup,
                                     **kw).serve(frames)
        fl = rep["faults"]
        evac = [m for m in rep["migrations"] if m["src"] == 0]
        print(f"  {name:>12s} {len(rep['dropped']):5d} "
              f"{fl['frames_lost_shard']:4d} "
              f"{rep['recovered_coverage']:9.2f} "
              f"{len(fl['restarts']):8d} {len(evac):11d}")
    for r in fl["restarts"]:
        print(f"  watchdog: restarted shard {r['shard']} at boundary "
              f"t={r['t']:.1f} (epoch {r['epoch']}, ok={r['ok']})")
    for m in evac:
        print(f"  watchdog: evacuated camera {m['stream']} "
              f"{m['src']}->{m['dst']} at epoch {m['epoch']}")
    assert rep["recovered_coverage"] == 1.0


def leg_lending():
    def stub(images, rids=None):
        b = len(images)
        return (np.zeros((b, 4, 4), np.float32),
                np.zeros((b, 4), np.float32),
                np.zeros((b, 4), np.int32), np.zeros((b, 4), bool))

    events = [(k / 30.0, 0, k) for k in range(240)]
    events += [(k + 0.5, 1, k) for k in range(8)]
    events.sort()
    frames = [FrameRequest(rid, np.zeros((4, 4, 3), np.float32), t,
                           stream_id=s)
              for rid, (t, s, k) in enumerate(events)]
    kw = dict(detect_fn=stub, n_replicas=2, service_time=0.1,
              drop_when_busy=True, micro_batch=1, max_micro_batch=1,
              n_shards=2, rebalance=True, epoch_s=2.0)
    print("== leg 3: one 30 FPS camera on shard 0, shard 1 idle "
          "(drop mode) ==")
    print(f"  {'run':>12s} {'drops':>5s} {'cover%':>6s} "
          f"{'migrations':>10s} {'loans':>5s}")
    for name, sup in (("unsupervised", None),
                      ("lending", Watchdog(idle_backlog_s=0.5))):
        rep = ShardedDetectionEngine(supervisor=sup, **kw).serve(frames)
        loans = rep.get("faults", {}).get("loans", [])
        print(f"  {name:>12s} {len(rep['dropped']):5d} "
              f"{rep['coverage'] * 100:6.1f} "
              f"{len(rep['migrations']):10d} {len(loans):5d}")
    for ln in loans:
        print(f"  watchdog: shard {ln['lender']} lent a replica to "
              f"shard {ln['borrower']} at epoch {ln['epoch']}, "
              f"returned at epoch {ln['returned_epoch']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cameras", type=int, default=4)
    ap.add_argument("--frames", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the bonus random-chaos leg")
    args = ap.parse_args()

    leg_replica_death(args.cameras, args.frames)
    leg_shard_death(args.cameras, args.frames)
    leg_lending()

    # bonus: seeded random chaos — same seed, same failures, same
    # recoveries, bit-identical report (run it twice to check)
    frames, frame_of, videos, dets = make_nvr_streams(
        args.cameras, args.frames, rate=4.0)
    sched = FaultSchedule.random(args.seed, args.frames / 4.0,
                                 n_shards=2, n_replicas=2,
                                 n_replica_events=2, n_shard_events=1)
    eng = ShardedDetectionEngine(
        detect_fn=proxy_detect_fn_streams(videos, dets, frame_of),
        n_replicas=2, service_time=0.02, n_shards=2, rebalance=True,
        epoch_s=2.0, track_and_interpolate=True, faults=sched,
        supervisor=Watchdog())
    r1, r2 = eng.serve(frames), eng.serve(frames)
    assert r1["faults"] == r2["faults"]
    print(f"== bonus: seeded chaos (seed={args.seed}) — "
          f"{len(sched)} events, {len(r1['faults']['restarts'])} "
          f"restarts, {len(r1['faults']['loans'])} loans, "
          f"recovered_coverage={r1['recovered_coverage']:.2f}, "
          "replays bit-identically ==")


if __name__ == "__main__":
    main()
