"""End-to-end edge video analytics driver (the paper's full pipeline):

  1. Train the pure-JAX mini-SSD detector on the synthetic benchmark video
     (real conv training on this host — no pretrained weights offline).
  2. Use REAL measured inference wall-times as executor service times.
  3. Stream the video through the parallel detection pipeline
     (scheduler -> n executors -> sequence synchronizer).
  4. Report the FPS/mAP table across n (the paper's Table IV shape),
     with the track-and-interpolate columns: mAP of the tracked output
     stream (dropped frames filled with tracker-coasted boxes instead
     of stale reuse), track coverage of object-frames, and ID switches.

  PYTHONPATH=src python examples/video_analytics.py [--steps 150]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DEVICE_PROFILES, MODEL_PROFILES, DetectorExecutor,
                        FrameStream, ParallelDetector, SyntheticVideo,
                        choose_n)
from repro.core.stream import ETH_SUNNYDAY
from repro.detector import (SSDConfig, decode_detections, detector_loss,
                            init_ssd, make_anchors, ssd_forward)


def train_detector(video: SyntheticVideo, steps: int, batch: int = 8):
    cfg = SSDConfig()
    anchors = make_anchors(cfg)
    params = init_ssd(cfg, jax.random.PRNGKey(0))
    spec = video.spec
    K = spec.n_objects

    def make_batch(rng):
        idx = rng.integers(0, spec.n_frames, batch)
        imgs = np.stack([video.pixels(i, cfg.image_size) for i in idx])
        boxes = np.stack([video.boxes_at(i) for i in idx])
        boxes = boxes / np.array([spec.width, spec.height] * 2)
        cls = np.tile(video.classes[None], (batch, 1))
        mask = np.ones((batch, K), np.float32)
        return (jnp.asarray(imgs), jnp.asarray(boxes, jnp.float32),
                jnp.asarray(cls, jnp.int32), jnp.asarray(mask))

    @jax.jit
    def step(params, imgs, boxes, cls, mask):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: detector_loss(p, cfg, imgs, boxes, cls, mask,
                                    anchors), has_aux=True)(params)
        params = jax.tree.map(lambda p, g: p - 3e-3 * g, params, grads)
        return params, loss, parts

    rng = np.random.default_rng(0)
    for i in range(steps):
        params, loss, parts = step(params, *make_batch(rng))
        if i % max(1, steps // 6) == 0 or i == steps - 1:
            print(f"  detector step {i:4d} loss={float(loss):.3f} "
                  f"(box={float(parts['box']):.3f} "
                  f"obj={float(parts['obj']):.3f} "
                  f"cls={float(parts['cls']):.3f})")
    return cfg, params, anchors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    video = SyntheticVideo(ETH_SUNNYDAY)
    print("== 1. training mini-SSD on synthetic ETH-Sunnyday ==")
    cfg, params, anchors = train_detector(video, args.steps)

    print("== 2. measuring real per-frame inference service time ==")
    infer = jax.jit(lambda img: decode_detections(params, cfg, img, anchors))
    img0 = jnp.asarray(video.pixels(0)[None])
    jax.block_until_ready(infer(img0))            # compile
    t0 = time.perf_counter()
    for i in range(10):
        out = infer(jnp.asarray(video.pixels(i)[None]))
    jax.block_until_ready(out)
    per_frame = (time.perf_counter() - t0) / 10
    print(f"  measured {per_frame*1e3:.1f} ms/frame on this host "
          f"({1/per_frame:.1f} FPS) — NCS2 profile stays at 2.5 FPS for "
          f"the virtual-clock runs below")

    print("== 3. parallel detection pipeline across n (Table IV shape) ==")
    lam = video.spec.fps
    print(f"  lambda={lam} FPS, mu=2.5 FPS -> paper rule: n in "
          f"[{choose_n(lam, 2.5)}, {choose_n(lam, 2.5, 'conservative')}]")
    print(f"  {'n':>3s} {'sigma(FPS)':>10s} {'mAP%':>6s} {'trk mAP%':>8s} "
          f"{'cover%':>6s} {'IDsw':>4s} {'drops/proc':>10s}")
    off = ParallelDetector(video.spec, "yolov3", ["ncs2"]).run(offline=True)
    print(f"  off {off.sigma:10.2f} {off.map_score*100:6.1f} "
          f"{'—':>8s} {'—':>6s} {'—':>4s} {'(zero-drop ref)':>10s}")
    for n in range(1, 8):
        r = ParallelDetector(video.spec, "yolov3", ["ncs2"] * n,
                             "fcfs").run(track=True)
        print(f"  {n:3d} {r.sigma:10.2f} {r.map_score*100:6.1f} "
              f"{r.map_tracked*100:8.1f} {r.track_coverage*100:6.1f} "
              f"{r.id_switches:4.0f} {r.drops_per_processed:10.1f}")


if __name__ == "__main__":
    main()
