"""The paper's multi-model parallelism as an LLM serving feature: batched
requests over n model replicas of an assigned architecture, FCFS vs RR,
homogeneous vs heterogeneous replicas — real jitted prefill/decode compute.

  PYTHONPATH=src python examples/llm_serving.py [--arch qwen3-4b]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.serving import Request, ServingEngine


def burst(cfg, n, rate, prompt_len=16, new_tokens=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size - 1, prompt_len)
                    .astype(np.int32), new_tokens, i / rate)
            for i in range(n)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, preset="smoke")
    if cfg.encoder_only:
        raise SystemExit("encoder-only arch: no decode serving")

    print(f"== serving {args.arch} (smoke config), {args.requests} "
          f"requests ==")
    print("-- homogeneous: 1 vs 4 replicas (the paper's n-scaling) --")
    for n in (1, 4):
        eng = ServingEngine(cfg, n_replicas=n, scheduler="fcfs",
                            cache_len=64)
        out = eng.serve(burst(cfg, args.requests, rate=400.0))
        print(f"  n={n}: throughput={out['throughput_rps']:6.2f} req/s  "
              f"p50={out['p50_latency']*1e3:6.1f} ms  "
              f"per-replica={out['per_replica']}")

    print("-- heterogeneous (replica 0 is 5x slower): RR vs FCFS --")
    speeds = [5.0, 1.0, 1.0, 1.0]
    for sched in ("rr", "fcfs"):
        eng = ServingEngine(cfg, n_replicas=4, scheduler=sched,
                            cache_len=64, replica_speeds=speeds)
        out = eng.serve(burst(cfg, args.requests, rate=400.0))
        print(f"  {sched:4s}: throughput={out['throughput_rps']:6.2f} "
              f"req/s  per-replica={out['per_replica']}")
    print("(FCFS routes around the slow replica; lockstep RR is dragged "
          "to n x min-rate — the paper's Table VII effect)")


if __name__ == "__main__":
    main()
