"""Sharded NVR serving demo: one camera set, 1..N mesh shards.

The single-host NVR demo (``nvr_serving.py``) multiplexes every camera
onto one replica pool; this one spreads the SAME camera set over mesh
shards — each shard its own ``DetectionEngine`` (replica pool +
lockstep ``B = cameras-per-shard`` tracker), per-shard reports merged
into one global report.  Forces a fake multi-device host mesh (the
XLA_FLAGS below, set before the first jax import) so the SPMD
detect+NMS program really spans shards on this CPU host.

  PYTHONPATH=src python examples/sharded_serving.py [--cameras 8]
      [--frames 24] [--rate 2.0] [--replicas 2]
"""
from __future__ import annotations

import argparse
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")

from repro.core import evaluate_streams, proxy_detect_fn_streams  # noqa: E402
from repro.serving import ShardedDetectionEngine, make_nvr_streams  # noqa: E402


def serve(n_shards, n_cameras, n_frames, rate, n_replicas):
    frames, frame_of, videos, dets = make_nvr_streams(n_cameras,
                                                      n_frames, rate)
    eng = ShardedDetectionEngine(
        n_shards=n_shards,
        detect_fn=proxy_detect_fn_streams(videos, dets, frame_of),
        n_replicas=n_replicas, service_time=0.4,
        track_and_interpolate=True)
    out = eng.serve(frames)
    return out, evaluate_streams(videos, out["streams"], n_frames)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cameras", type=int, default=8)
    ap.add_argument("--frames", type=int, default=24)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--replicas", type=int, default=2,
                    help="replicas PER SHARD")
    args = ap.parse_args()

    lam = args.cameras * args.rate
    print(f"== sharded NVR: {args.cameras} cameras x {args.rate} FPS = "
          f"{lam:.1f} FPS, {args.replicas} replicas/shard ==")
    print(f"  {'shards':>6s} {'cams/shard':>10s} {'interp':>6s} "
          f"{'cover%':>6s} {'mAP%':>6s} {'minmAP%':>7s} {'IDsw':>4s}")
    for n in (1, 2, 4):
        out, q = serve(n, args.cameras, args.frames, args.rate,
                       args.replicas)
        cams = max(len(s["streams"]) for s in out["per_shard"])
        assert out["coverage"] == 1.0
        print(f"  {n:6d} {cams:10d} {out['interpolated']:6d} "
              f"{out['coverage']*100:6.1f} {q['map_mean']*100:6.1f} "
              f"{q['map_min']*100:7.1f} {q['id_switches_total']:4.0f}")

    out, q = serve(4, args.cameras, args.frames, args.rate, args.replicas)
    print("== shard view (4 shards) ==")
    for h, shard in enumerate(out["per_shard"]):
        print(f"  shard {h}: cameras={shard['streams']} "
              f"frames={shard['frames']} dropped={shard['dropped']} "
              f"interpolated={shard['interpolated']} "
              f"tracker_launches={shard['tracker_launches']}")
    print(f"  merged report: {out['n_streams']} streams, "
          f"{len(out['responses'])} responses, "
          f"{len(out['per_replica'])} replicas across "
          f"{out['n_shards']} shards")

    # cross-shard work stealing: skew the load (the cameras the static
    # partition puts on shard 0 run at 2x rate) and compare the static
    # partition against epoch-based rebalancing in drop mode — the rate
    # mismatch the paper diagnoses, fixed at runtime by migrating one
    # hot camera to an idle shard
    from repro.serving import make_skewed_streams

    print("== cross-shard work stealing (shard-0 cameras at 2x rate, "
          "drop mode) ==")
    print(f"  {'policy':>9s} {'drops':>5s} {'cov_min%':>8s} "
          f"{'migrations':>10s}")
    sk_frames, sk_of, sk_videos, sk_dets = make_skewed_streams(
        6, args.frames, 1.0, 2)
    sk_oracle = proxy_detect_fn_streams(sk_videos, sk_dets, sk_of)
    for policy, extra in (("static", {}),
                          ("stealing", {"rebalance": True,
                                        "epoch_s": args.frames / 3})):
        eng = ShardedDetectionEngine(
            n_shards=2, detect_fn=sk_oracle, n_replicas=args.replicas,
            service_time=0.36, drop_when_busy=True, **extra)
        r = eng.serve(sk_frames)
        cov = min(v["coverage"] for v in r["per_stream"].values())
        moves = ", ".join(
            f"cam{m['stream']}:{m['src']}->{m['dst']}@e{m['epoch']}"
            for m in r.get("migrations", [])) or "-"
        print(f"  {policy:>9s} {len(r['dropped']):5d} {cov*100:8.1f} "
              f"{moves:>10s}")

    # the SPMD leg: the same engine with mesh= runs detection as ONE
    # jitted program spanning the (forced) 4-device mesh — this is
    # what the XLA_FLAGS line at the top is for
    import jax
    import numpy as np

    from repro.launch.mesh import make_serving_mesh
    from repro.serving import FrameRequest

    n_dev = min(4, len(jax.devices()))
    mesh = make_serving_mesh(n_dev)
    rng = np.random.default_rng(0)
    spmd_frames = [FrameRequest(i, rng.random((64, 64, 3))
                                .astype(np.float32), i / 40.0,
                                stream_id=i % n_dev)
                   for i in range(8 * n_dev)]
    eng = ShardedDetectionEngine(n_shards=n_dev, mesh=mesh,
                                 n_replicas=args.replicas,
                                 service_time=0.05,
                                 track_and_interpolate=True)
    spmd = eng.serve(spmd_frames)
    print(f"== SPMD mesh leg: one compiled detect+NMS program over "
          f"{n_dev} devices ==")
    print(f"  {spmd['n_streams']} cameras / {n_dev} shards, "
          f"coverage={spmd['coverage']:.2f}, "
          f"{len(spmd['responses'])} mini-SSD responses")


if __name__ == "__main__":
    main()
