"""Benchmark harness: one function per paper table + kernel micro-benches
+ the roofline summary.  Prints ``name,us_per_call,derived`` CSV rows (and
detailed per-table CSV blocks as comments).

  PYTHONPATH=src python -m benchmarks.run [--only table_iv,...]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks import tables  # noqa: E402


def _run_table(name, fn):
    t0 = time.perf_counter()
    rows, derived = fn()
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{derived:.3f}")
    if rows:
        cols = list(rows[0].keys())
        print(f"# {name}: " + ",".join(cols))
        for r in rows:
            print("#   " + ",".join(_fmt(r.get(c, "")) for c in cols))
    return rows


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.2f}"
    return str(v)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    benches = {
        "drop_analysis": tables.drop_analysis,     # §II / Fig 2-3
        "table_iv": tables.table_iv,               # ETH-Sunnyday FPS+mAP
        "table_v": tables.table_v,                 # ADL-Rundle-6 FPS+mAP
        "table_vi": tables.table_vi,               # energy FPS/W
        "table_vii": tables.table_vii,             # RR vs FCFS
        "table_ix": tables.table_ix,               # USB 2.0 vs 3.0
        "table_x": tables.table_x,                 # Python vs C++
        "hetero_models": tables.hetero_models,     # beyond-paper (§V)
    }
    names = (args.only.split(",") if args.only else
             list(benches) + ["kernels", "roofline"])

    print("name,us_per_call,derived")
    for name in names:
        if name in benches:
            _run_table(name, benches[name])

    if "kernels" in names:
        from benchmarks.kernel_bench import bench_kernels
        for name, us, derived in bench_kernels():
            print(f"{name},{us:.0f},{derived}")

    if "roofline" in names:
        try:
            from benchmarks import roofline
            rows = roofline.table("single")
            if rows:
                worst = min(rows, key=lambda r: r["useful_ratio"])
                print(f"roofline_summary,0,{len(rows)}")
                print(f"# worst useful-FLOP ratio: {worst['arch']} x "
                      f"{worst['shape']} = {worst['useful_ratio']:.3f} "
                      f"({worst['dominant']}-bound)")
        except Exception as e:  # noqa: BLE001 — roofline needs dry-run data
            print(f"# roofline skipped: {e}")


if __name__ == "__main__":
    main()
