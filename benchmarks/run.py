"""Benchmark harness: one function per paper table + kernel micro-benches
+ the detection fast-path (fused NMS) and tracking-subsystem
trajectories + the roofline summary, so the paper tables and the kernel
perf trajectory land in ONE report.  Prints ``name,us_per_call,derived``
CSV rows (and detailed per-table CSV blocks as comments).

  PYTHONPATH=src python -m benchmarks.run [--only table_iv,nms,tracking,...]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks import tables  # noqa: E402


def _run_table(name, fn):
    t0 = time.perf_counter()
    rows, derived = fn()
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{derived:.3f}")
    if rows:
        cols = list(rows[0].keys())
        print(f"# {name}: " + ",".join(cols))
        for r in rows:
            print("#   " + ",".join(_fmt(r.get(c, "")) for c in cols))
    return rows


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.2f}"
    return str(v)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    benches = {
        "drop_analysis": tables.drop_analysis,     # §II / Fig 2-3
        "table_iv": tables.table_iv,               # ETH-Sunnyday FPS+mAP
        "table_v": tables.table_v,                 # ADL-Rundle-6 FPS+mAP
        "table_vi": tables.table_vi,               # energy FPS/W
        "table_vii": tables.table_vii,             # RR vs FCFS
        "table_ix": tables.table_ix,               # USB 2.0 vs 3.0
        "table_x": tables.table_x,                 # Python vs C++
        "hetero_models": tables.hetero_models,     # beyond-paper (§V)
    }
    names = (args.only.split(",") if args.only else
             list(benches) + ["kernels", "nms", "tracking", "tick",
                              "nvr", "sharded", "faults", "obs",
                              "daemon", "cascade", "roofline"])

    print("name,us_per_call,derived")
    for name in names:
        if name in benches:
            _run_table(name, benches[name])

    if "kernels" in names:
        from benchmarks.kernel_bench import bench_kernels
        for name, us, derived in bench_kernels():
            print(f"{name},{us:.0f},{derived}")

    if "nms" in names:
        # the detection fast path at the decode shape (smoke iterations):
        # derived = speedup of the fused batched launch over the seed's
        # per-image vmap + serial-loop path
        from benchmarks.nms_bench import bench_nms_decode, bench_nms_random
        d = bench_nms_decode(8, 160, 32, iters=3, reps=2)
        print(f"nms_decode_fused_xla,{d['fused_xla_ms']*1e3:.0f},"
              f"{d['loop_ms'] / d['fused_xla_ms']:.2f}")
        r = bench_nms_random(8, 160, 32, iters=3, reps=2)
        print(f"nms_random_fused_xla,{r['fused_xla_ms']*1e3:.0f},"
              f"{r['loop_ms'] / r['fused_xla_ms']:.2f}")

    if "tracking" in names:
        # tracker step latency + the mAP the tracker recovers from
        # dropped frames (derived = recovered mAP points at n=2)
        from benchmarks.tracking_bench import bench_recovered_map, \
            bench_step
        s = bench_step(1, 32, iters=3, reps=2)
        row = bench_recovered_map((2,), smoke=True)[0]
        print(f"tracking_step,{s['step_ms']*1e3:.0f},"
              f"{row['map_recovered']:.4f}")
        print(f"# tracking n={row['n']}: drop_rate={row['drop_rate']:.2f} "
              f"map_stale={row['map_stale']:.4f} "
              f"map_tracked={row['map_tracked']:.4f} "
              f"coverage={row['coverage']:.3f} "
              f"id_switches={row['id_switches']:.0f}")

    if "tick" in names:
        # the tick-pipeline launch chain: staged step+output vs the
        # one-launch-per-window scan (derived = window speedup; the
        # >= 1.2x gate and bit-identity run in tick_bench.py's main)
        from benchmarks.tick_bench import bench as bench_tick
        from repro.tracking import TrackerConfig
        r = bench_tick(B=2, D=8, K=20, reps=3,
                       cfg=TrackerConfig(capacity=16))
        print(f"tick_fused_window,"
              f"{r['fused_window']['tracker_step_ms']*1e3:.0f},"
              f"{r['speedup']:.2f}")
        print(f"# tick: staged={r['staged']['tracker_step_ms']:.3f}ms "
              f"fused={r['fused']['tracker_step_ms']:.3f}ms "
              f"window={r['fused_window']['tracker_step_ms']:.3f}ms "
              f"identical={r['bit_identical']}")

    if "nvr" in names:
        # multi-camera serving: 8 cameras multiplexed onto a 2-replica
        # pool; derived = mean per-camera tracked mAP (coverage 1.0 and
        # one tracker launch per tick asserted inside)
        from benchmarks.nvr_bench import bench_nvr_row
        r = bench_nvr_row(8, 24, rate=2.0, step_iters=3, step_reps=1)
        print(f"nvr_8cam_serve,{r['serve_ms']*1e3:.0f},"
              f"{r['map_mean']:.4f}")
        print(f"# nvr n=8: interp={r['interpolated']} "
              f"drop_cov={r['drop_coverage']:.3f} "
              f"map_drop={r['map_drop_mean']:.4f} "
              f"step_ms={r['step_ms']:.2f}")

    if "sharded" in names:
        # sharded NVR serving: 4 cameras split over 2 shards; derived =
        # mean per-camera tracked mAP after the shard merge (coverage
        # 1.0 asserted inside).  sharded_bench's forced host-device
        # count only applies before the first jax init, so in this
        # process the SPMD micro-bench clamps to the visible devices;
        # run sharded_bench.py standalone for the real multi-device mesh.
        from benchmarks.sharded_bench import (bench_shard_row,
                                              bench_stealing_row)
        r = bench_shard_row(2, 4, 16, rate=2.0, iters=3, reps=1)
        print(f"sharded_2shard_serve,{r['serve_ms']*1e3:.0f},"
              f"{r['map_mean']:.4f}")
        print(f"# sharded n=2: cams/shard={r['cameras_per_shard']} "
              f"step_ms={r['tracker_step_ms']:.2f} "
              f"spmd_ms={r['spmd_detect_ms']:.2f} "
              f"interp={r['interpolated']}")
        # cross-shard work stealing on the skewed (2x shard-0) trace:
        # derived = drops recovered by stealing vs the static partition
        w = bench_stealing_row(2, 12, rate=1.0, iters=3, reps=1)
        print(f"sharded_2shard_stealing,{w['serve_ms_stealing']*1e3:.0f},"
              f"{w['drops_static'] - w['drops_stealing']}")
        print(f"# stealing n=2: drops {w['drops_static']}->"
              f"{w['drops_stealing']} cov_min "
              f"{w['coverage_min_static']:.3f}->"
              f"{w['coverage_min_stealing']:.3f} "
              f"migrations={len(w['migrations'])} "
              f"step_ms {w['tracker_step_ms_static']:.2f}->"
              f"{w['tracker_step_ms_stealing']:.2f}")

    if "faults" in names:
        # fault-injected serving: a whole shard dies mid-epoch and the
        # watchdog restarts + evacuates it; derived = frames the kill
        # lost (recovered_coverage 1.0 asserted inside).  Second row:
        # replica lending on the single-hot-stream overload; derived =
        # drops the loan recovered vs the unsupervised run.
        from benchmarks.faults_bench import (scenario_lending,
                                             scenario_shard_kill)
        t0 = time.perf_counter()
        # 24 frames @4fps = a 6 s horizon: the kill epoch ([2,4)) needs
        # at least one later epoch for the boundary recovery to land in
        sk, ok_sk = scenario_shard_kill(4, 24)
        assert ok_sk and sk["recovered_coverage"] == 1.0
        print(f"faults_shard_kill,{(time.perf_counter() - t0) * 1e6:.0f},"
              f"{sk['frames_lost_shard']}")
        print(f"# shard kill @t={sk['kill_t']}: restart "
              f"epoch={sk['restarts'][0]['epoch']} "
              f"evacuations={len(sk['evacuations'])} "
              f"cov={sk['coverage']:.3f} recovered="
              f"{sk['recovered_coverage']:.1f}")
        t0 = time.perf_counter()
        ld, ok_ld = scenario_lending()
        assert ok_ld
        print(f"faults_lending,{(time.perf_counter() - t0) * 1e6:.0f},"
              f"{ld['drops_unsupervised'] - ld['drops_with_lending']}")
        print(f"# lending: drops {ld['drops_unsupervised']}->"
              f"{ld['drops_with_lending']} loans={len(ld['loans'])} "
              f"cov {ld['coverage_unsupervised']:.3f}->"
              f"{ld['coverage_with_lending']:.3f}")

    if "obs" in names:
        # frame-lifecycle tracing: derived = traced/untraced wall ratio
        # on the 8-cam sharded serve (budget 1.05), with the recorded
        # chaos trace audited against the serving invariants
        from benchmarks.obs_bench import (scenario_audit_chaos,
                                          scenario_overhead)
        t0 = time.perf_counter()
        ovh, ok_ovh = scenario_overhead(24, blocks=4)
        assert ok_ovh, f"tracing overhead {ovh['overhead_ratio']} > 1.05"
        print(f"obs_overhead,{(time.perf_counter() - t0) * 1e6:.0f},"
              f"{ovh['overhead_ratio']:.4f}")
        print(f"# obs: {ovh['events_recorded']} events/serve "
              f"untraced={ovh['untraced_ms']:.1f}ms "
              f"traced={ovh['traced_ms']:.1f}ms")
        t0 = time.perf_counter()
        ch, ok_ch, _rec = scenario_audit_chaos(4, 16, seeds=(0, 1))
        assert ok_ch, "chaos trace failed the invariant audit"
        print(f"obs_audit_chaos,{(time.perf_counter() - t0) * 1e6:.0f},"
              f"{len(ch['per_seed'])}")
        print("# obs audit: " + " ".join(
            f"seed{p['seed']}={p['events']}ev/"
            f"{'ok' if p['ok'] else 'FAIL'}" for p in ch["per_seed"]))

    if "daemon" in names:
        # incremental serving core: derived = incremental/batch wall
        # ratio on the 8-cam sharded serve with per-frame ingest
        # (budget 1.05), plus the daemon drain (audit-clean, nothing
        # pending after shutdown)
        from benchmarks.daemon_bench import (scenario_daemon,
                                             scenario_overhead as
                                             daemon_overhead)
        t0 = time.perf_counter()
        ovh, ok_ovh = daemon_overhead(24, blocks=4)
        assert ok_ovh, \
            f"incremental overhead {ovh['overhead_ratio']} > 1.05"
        print(f"daemon_overhead,{(time.perf_counter() - t0) * 1e6:.0f},"
              f"{ovh['overhead_ratio']:.4f}")
        print(f"# daemon: batch={ovh['batch_ms']:.1f}ms "
              f"incremental={ovh['incremental_ms']:.1f}ms "
              f"chunk={ovh['ingest_chunk']}")
        t0 = time.perf_counter()
        dm, ok_dm = scenario_daemon(16)
        assert ok_dm, "daemon drain failed audit/conservation"
        print(f"daemon_drain,{(time.perf_counter() - t0) * 1e6:.0f},"
              f"{dm['events_published']}")
        print(f"# daemon drain: ingested={dm['ingested']} "
              f"pending={dm['pending_after_drain']} "
              f"cov={dm['coverage']:.3f} "
              f"audit={'ok' if dm['audit_ok'] else 'FAIL'}")

    if "cascade" in names:
        # transprecise cascade: per-micro-batch model selection on the
        # sinusoidal overload cycle; derived = cascade tracked mAP
        # minus the best fixed-model baseline's (strictly > 0 asserted
        # inside).  Second row: the fast+heavy ROI second pass; derived
        # = pixel reduction vs full-frame re-detection (> 0.5 gated).
        from benchmarks.cascade_bench import (scenario_cascade_overload,
                                              scenario_roi_sparse)
        t0 = time.perf_counter()
        ov, ok_ov = scenario_cascade_overload(192, 96)
        assert ok_ov, "cascade lost to a fixed-model baseline"
        best_fixed = max(f["map_mean"] for f in ov["fixed"].values())
        print(f"cascade_overload,{(time.perf_counter() - t0) * 1e6:.0f},"
              f"{ov['cascade']['map_mean'] - best_fixed:.4f}")
        print(f"# cascade: map={ov['cascade']['map_mean']:.4f} "
              f"best_fixed={best_fixed:.4f} "
              f"models={ov['cascade']['models']} "
              f"switches={ov['cascade']['switches']} "
              f"drops={ov['cascade']['dropped']}")
        t0 = time.perf_counter()
        roi, ok_roi = scenario_roi_sparse(24)
        assert ok_roi, "ROI pass below the 50% pixel-reduction gate"
        print(f"cascade_roi,{(time.perf_counter() - t0) * 1e6:.0f},"
              f"{roi['pixel_reduction']:.4f}")
        print(f"# roi: passes={roi['roi_passes']} "
              f"px {roi['px_full']:.0f}->{roi['px_roi']:.0f} "
              f"audit={'ok' if roi['audit_ok'] else 'FAIL'}")

    if "roofline" in names:
        try:
            from benchmarks import roofline
            rows = roofline.table("single")
            if rows:
                worst = min(rows, key=lambda r: r["useful_ratio"])
                print(f"roofline_summary,0,{len(rows)}")
                print(f"# worst useful-FLOP ratio: {worst['arch']} x "
                      f"{worst['shape']} = {worst['useful_ratio']:.3f} "
                      f"({worst['dominant']}-bound)")
        except Exception as e:  # noqa: BLE001 — roofline needs dry-run data
            print(f"# roofline skipped: {e}")


if __name__ == "__main__":
    main()
