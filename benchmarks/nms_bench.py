"""Perf trajectory for the detection fast path: fused batched NMS and
vectorized mAP vs the seed's per-image / Python-loop implementations.

  PYTHONPATH=src python benchmarks/nms_bench.py [--smoke] [--out PATH]

Emits ``BENCH_nms.json`` with wall-clock timings (best of N) for

* ``nms_random``  — dense random scores, exact mode: every path is
  bit-compatible with ``ref.batched_nms_ref``;
* ``nms_decode``  — the ETH-Sunnyday decode shape (160 anchors, ~20
  boxes past the 0.4 score threshold, the detector's ``stop_at_zero``
  fast path) timed through the full post-NMS decode section, with
  valid-masked outputs asserted identical to the seed path;
* ``map_eth``     — ``evaluate_map`` vectorized vs the seed loop on an
  ETH-Sunnyday paced run (identical mAP asserted, warm detection memo
  so the scorers — the thing this PR vectorizes — dominate).

Baselines: "loop" is the seed's per-image ``vmap`` + serial
``fori_loop`` NMS (jnp IoU); "pallas_unfused" is the same loop over the
Pallas IoU kernel; "fused_xla"/"fused_pallas" are the batched fused
suppression (ops.batched_nms dispatch targets).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def best_of(fn, *args, iters=20, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) / iters * 1e3)
    return min(times)


def seed_post(boxes, scores, classes, score_thr, iou_thr, max_out,
              use_pallas):
    """The seed decode post-processing: per-image vmap + serial NMS."""
    def per_image(bx, sc, cl):
        sc = jnp.where(sc >= score_thr, sc, 0.0)
        keep, valid = ops.nms_serial(bx, sc, iou_thr=iou_thr,
                                     max_out=max_out, use_pallas=use_pallas)
        valid &= sc[keep] > 0
        return bx[keep], sc[keep], cl[keep], valid
    return jax.vmap(per_image)(boxes, scores, classes)


def fused_post(boxes, scores, classes, score_thr, iou_thr, max_out,
               use_pallas):
    """The new decode post-processing: one fused batched NMS launch."""
    keep, valid = ops.batched_nms(boxes, scores, iou_thr=iou_thr,
                                  score_thr=score_thr, max_out=max_out,
                                  stop_at_zero=True, use_pallas=use_pallas)
    sc = jnp.where(scores >= score_thr, scores, 0.0)
    sck = jnp.take_along_axis(sc, keep, axis=1)
    return (jnp.take_along_axis(boxes, keep[..., None], axis=1), sck,
            jnp.take_along_axis(classes, keep, axis=1), valid & (sck > 0))


def _rand_boxes(rng, B, A):
    tl = rng.uniform(0, 1, (B, A, 2))
    wh = rng.uniform(0.02, 0.3, (B, A, 2))
    return jnp.asarray(np.concatenate([tl, tl + wh], -1), jnp.float32)


def _masked_equal(o1, o2):
    v1, v2 = np.asarray(o1[3]), np.asarray(o2[3])
    return bool(np.array_equal(v1, v2) and all(
        np.array_equal(np.asarray(a)[v1], np.asarray(b)[v2])
        for a, b in zip(o1[:3], o2[:3])))


def bench_nms_random(B, A, max_out, iters, reps):
    rng = np.random.default_rng(0)
    boxes = _rand_boxes(rng, B, A)
    scores = jnp.asarray(rng.random((B, A)), jnp.float32)

    loop = jax.jit(jax.vmap(
        lambda b, s: ops.nms_serial(b, s, 0.5, max_out, use_pallas=False)))
    loop_pl = jax.jit(jax.vmap(
        lambda b, s: ops.nms_serial(b, s, 0.5, max_out, use_pallas=True)))
    fused_x = jax.jit(lambda b, s: ops.batched_nms(
        b, s, max_out=max_out, use_pallas=False))
    fused_p = jax.jit(lambda b, s: ops.batched_nms(
        b, s, max_out=max_out, use_pallas=True))

    kr, vr = ref.batched_nms_ref(boxes, scores, 0.5, max_out)
    for f in (fused_x, fused_p, loop, loop_pl):
        k, v = f(boxes, scores)
        assert np.array_equal(np.asarray(k), np.asarray(kr))
        assert np.array_equal(np.asarray(v), np.asarray(vr))
    return {
        "shape": [B, A, max_out],
        "loop_ms": best_of(loop, boxes, scores, iters=iters, reps=reps),
        "pallas_unfused_ms": best_of(loop_pl, boxes, scores, iters=iters,
                                     reps=reps),
        "fused_xla_ms": best_of(fused_x, boxes, scores, iters=iters,
                                reps=reps),
        "fused_pallas_ms": best_of(fused_p, boxes, scores, iters=iters,
                                   reps=reps),
        "bit_compatible": True,
    }


def bench_nms_decode(B, A, max_out, iters, reps):
    """ETH-Sunnyday decode shape: 8 objects x 2-3 matching anchors clear
    the 0.4 objectness threshold; the rest fall below it."""
    rng = np.random.default_rng(1)
    boxes = _rand_boxes(rng, B, A)
    sc = rng.uniform(0.0, 0.39, (B, A))
    n_pos = max(4, min(20, A // 8))
    for b in range(B):
        pos = rng.choice(A, n_pos, replace=False)
        sc[b, pos] = rng.uniform(0.4, 1.0, n_pos)
    scores = jnp.asarray(sc, jnp.float32)
    classes = jnp.asarray(rng.integers(0, 3, (B, A)), jnp.int32)
    args = (boxes, scores, classes, 0.4, 0.5, max_out)

    f_loop = jax.jit(lambda b, s, c: seed_post(b, s, c, 0.4, 0.5, max_out,
                                               False))
    f_xla = jax.jit(lambda b, s, c: fused_post(b, s, c, 0.4, 0.5, max_out,
                                               False))
    f_pl = jax.jit(lambda b, s, c: fused_post(b, s, c, 0.4, 0.5, max_out,
                                              True))
    o_loop = f_loop(boxes, scores, classes)
    assert _masked_equal(o_loop, f_xla(boxes, scores, classes))
    assert _masked_equal(o_loop, f_pl(boxes, scores, classes))
    return {
        "shape": [B, A, max_out],
        "n_positive_per_frame": n_pos,
        "loop_ms": best_of(f_loop, boxes, scores, classes, iters=iters,
                           reps=reps),
        "fused_xla_ms": best_of(f_xla, boxes, scores, classes, iters=iters,
                                reps=reps),
        "fused_pallas_ms": best_of(f_pl, boxes, scores, classes,
                                   iters=iters, reps=reps),
        "outputs_identical": True,
    }


def bench_map(n_sticks, reps):
    from repro.core import (ParallelDetector, SequenceSynchronizer,
                            evaluate_map, evaluate_map_loop)
    from repro.core.simulator import simulate
    from repro.core.stream import FrameStream
    det = ParallelDetector("ETH-Sunnyday", "yolov3", ["ncs2"] * n_sticks)
    result = simulate(FrameStream(det.video), det.scheduler)
    synced = SequenceSynchronizer().order(result)
    m_vec = evaluate_map(det.video, synced, det.detector)
    m_loop = evaluate_map_loop(det.video, synced, det.detector)
    assert abs(m_vec - m_loop) < 1e-9, (m_vec, m_loop)

    def t(fn):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn(det.video, synced, det.detector)
            ts.append((time.perf_counter() - t0) * 1e3)
        return min(ts)

    return {
        "video": "ETH-Sunnyday", "n": n_sticks, "map": m_vec,
        "frames_scored": sum(1 for s in synced if s.source_index >= 0),
        "loop_ms": t(evaluate_map_loop),
        "vectorized_ms": t(evaluate_map),
        "map_identical": True,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / single rep (CI)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_nms.json"))
    args = ap.parse_args()

    if args.smoke:
        iters, reps = 3, 1
        nms_random = bench_nms_random(4, 64, 16, iters, reps)
        nms_decode = bench_nms_decode(4, 64, 16, iters, reps)
        map_eth = bench_map(2, reps=2)
    else:
        iters, reps = 20, 5
        nms_random = bench_nms_random(32, 160, 32, iters, reps)
        nms_decode = bench_nms_decode(32, 160, 32, iters, reps)
        map_eth = bench_map(4, reps=5)

    out = {
        "bench": "nms_fused_fast_path",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "nms_random": nms_random,
        "nms_decode": nms_decode,
        "map_eth": map_eth,
        # headline: the detection path as dispatched on this host (fused
        # batched suppression) vs the seed per-image vmap+fori_loop path
        "speedup_batched_vs_loop": round(
            nms_decode["loop_ms"] / nms_decode["fused_xla_ms"], 2),
        "speedup_batched_vs_loop_random": round(
            nms_random["loop_ms"] / nms_random["fused_xla_ms"], 2),
        "speedup_map_vectorized": round(
            map_eth["loop_ms"] / map_eth["vectorized_ms"], 2),
    }
    out["acceptance"] = {
        "nms_5x": out["speedup_batched_vs_loop"] >= 5.0,
        "map_3x": out["speedup_map_vectorized"] >= 3.0,
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
