"""Multi-camera (NVR) serving trajectory: how tracked mAP and tracker
step latency scale as 1..8 cameras multiplex onto the same detector
replicas.

  PYTHONPATH=src python benchmarks/nvr_bench.py [--smoke] [--out PATH]

Emits ``BENCH_nvr.json`` with one row per camera count:

* ``coverage``          — MIN per-stream frame coverage under
  ``track_and_interpolate`` (measured; asserted 1.0 for every camera);
* ``tracker_launches``  — trk.step/trk.coast calls counted at the call
  sites (measured, not engine bookkeeping); asserted equal to the
  frames-per-stream tick count (ONE batched launch advances all B
  streams per tick);
* ``map_mean``/``map_min`` — per-stream tracked mAP aggregated across
  cameras (vs the drop-frames baseline's ``map_drop_mean``);
* ``step_ms``           — tracker step latency at batch B = n_streams
  (the lockstep launch the serve loop issues every tick).

The pool is FIXED (2 replicas at the NCS2-calibrated 2.5 FPS) while
the camera count grows, so the per-camera detection budget shrinks
with n — the measurement-study regime where per-stream tracking cost
caps multi-camera scale.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import numpy as np


def bench_nvr_row(n_streams, n_frames, rate, step_iters, step_reps):
    from benchmarks.tracking_bench import bench_step
    import repro.tracking as trk
    from repro.core import evaluate_streams, proxy_detect_fn_streams
    from repro.serving import DetectionEngine, make_nvr_streams

    frames, frame_of, videos, dets = make_nvr_streams(n_streams,
                                                      n_frames, rate)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)

    def run(**kw):
        eng = DetectionEngine(detect_fn=oracle, n_replicas=2,
                              service_time=0.4, **kw)
        t0 = time.perf_counter()
        out = eng.serve(frames)
        return out, (time.perf_counter() - t0) * 1e3

    out_d, _ = run(drop_when_busy=True)
    # count the ACTUAL tracker launches (trk.step/trk.coast calls),
    # not the engine's own bookkeeping — the one-launch-per-tick claim
    # is measured, not trusted
    launches = {"n": 0}
    orig_step, orig_coast = trk.step, trk.coast

    def spy_step(*a, **kw):
        launches["n"] += 1
        return orig_step(*a, **kw)

    def spy_coast(*a, **kw):
        launches["n"] += 1
        return orig_coast(*a, **kw)

    trk.step, trk.coast = spy_step, spy_coast
    try:
        out_t, serve_ms = run(track_and_interpolate=True)
    finally:
        trk.step, trk.coast = orig_step, orig_coast
    # acceptance: full per-stream coverage (measured), one tracker
    # launch per tick (ticks == frames_per_stream: equal-length streams)
    cov_min = min(v["coverage"] for v in out_t["per_stream"].values())
    assert cov_min == 1.0, cov_min
    assert launches["n"] == n_frames, (launches["n"], n_frames)
    assert out_t["tracker_ticks"] == n_frames
    q_t = evaluate_streams(videos, out_t["streams"], n_frames)
    q_d = evaluate_streams(videos, out_d["streams"], n_frames)
    step = bench_step(n_streams, 24, step_iters, step_reps)
    return {
        "n_streams": n_streams,
        "frames_per_stream": n_frames,
        "stream_rate_fps": rate,
        "coverage": cov_min,
        "tracker_launches": launches["n"],
        "tracker_ticks": out_t["tracker_ticks"],
        "interpolated": out_t["interpolated"],
        "drop_coverage": round(out_d["coverage"], 4),
        "map_mean": round(q_t["map_mean"], 4),
        "map_min": round(q_t["map_min"], 4),
        "map_drop_mean": round(q_d["map_mean"], 4),
        "id_switches_total": q_t["id_switches_total"],
        "step_ms": step["step_ms"],
        "serve_ms": round(serve_ms, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream lengths / single rep (CI)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_nvr.json"))
    args = ap.parse_args()

    if args.smoke:
        ns, n_frames, iters, reps = (1, 4, 8), 24, 3, 1
    else:
        ns, n_frames, iters, reps = (1, 2, 4, 8), 96, 20, 5

    rows = [bench_nvr_row(n, n_frames, rate=2.0, step_iters=iters,
                          step_reps=reps) for n in ns]
    out = {
        "bench": "nvr_multi_camera_serving",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "pool": {"n_replicas": 2, "service_time_s": 0.4},
        "rows": rows,
        "acceptance": {
            # both measured per row: coverage is the min over streams,
            # launches are counted at the trk.step/trk.coast call sites
            "per_stream_coverage_all_one": all(
                r["coverage"] == 1.0 for r in rows),
            "one_tracker_launch_per_tick": all(
                r["tracker_launches"] == r["frames_per_stream"]
                for r in rows),
            "eight_camera_run_completes": any(r["n_streams"] == 8
                                              for r in rows),
            # strict win wherever the pool actually dropped frames
            # (n=1 at 2 FPS fits the 5 FPS pool: nothing to recover)
            "tracked_beats_drop_when_overloaded": all(
                r["map_mean"] > r["map_drop_mean"]
                for r in rows if r["interpolated"] > 0),
        },
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
