"""Fused serve-tick benchmark: the one-jit donated-buffer tracker tick
(``serving.pipeline._fused_tick``) and the one-launch-per-window scan
(``serving.pipeline.fused_window``) vs the staged ``step`` + ``output``
launch chain, with bit-identity asserted and the >= 1.2x speedup gate
on ``tracker_step_ms`` enforced.

  PYTHONPATH=src python benchmarks/tick_bench.py [--smoke] [--out PATH]

Emits ``BENCH_tick.json`` with

* ``staged``       — per-tick latency of the pre-refactor two-dispatch
  chain (``trk.step``, det_tid sync, ``trk.output``, outputs
  materialized — the interpolation replay's drop-bearing tick);
* ``fused``        — the ONE-launch-per-tick program (associate ->
  Kalman update/birth -> output, track table donated), same
  materialization;
* ``fused_window`` — the whole K-tick window as ONE ``lax.scan``
  launch (the replay knows every tick's detections up front), stacked
  det_tid/outputs materialized once at the end.  This is the regime
  the >= 1.2x gate runs against: it amortizes the entire dispatch
  chain, so the margin is structural, not timer jitter;
* ``identity``     — all three regimes replayed over the same K random
  detection ticks (including detection-free ticks, which the fused
  programs run as all-invalid rows): every ``TrackerState`` field,
  ``det_tid`` and the output tuple must match bit for bit;
* ``roofline``     — the fused tick program's ``cost_analysis``
  FLOPs/bytes against the v5e-class peaks from
  ``benchmarks/roofline.py``: the compute/memory bounds in ms, the
  bound-side verdict, and the measured-over-bound ratio (on XLA-CPU
  the measured time is dispatch-dominated — exactly the overhead
  fusion removes).

Timing method: staged / fused / window reps are interleaved tick by
tick (shared-runner drift hits every regime equally) and the per-tick
MINIMUM across reps is summed — noise only ever adds time, so the sum
of per-tick floors is the stable latency estimate.

Acceptance (CI-gated): ``fused_bit_identical`` and
``fused_speedup_ge_1_2`` (staged vs ``fused_window``) must both be
true; the process exits nonzero otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.pipeline import _fused_tick, fused_window
from repro.tracking import (TrackerConfig, coast, export_rows, init_state,
                            output, rows_to_state, step)

try:
    from benchmarks.roofline import HBM_BW, PEAK_FLOPS
except ImportError:   # standalone run: benchmarks/ itself is on sys.path
    from roofline import HBM_BW, PEAK_FLOPS


def make_ticks(rng, B, D, K):
    """K random detection ticks; every 5th is detection-free (the
    interpolation path's coast tick — fused runs it as an all-invalid
    row)."""
    ticks = []
    for k in range(K):
        if k % 5 == 4:
            ticks.append((jnp.zeros((B, D, 4), jnp.float32),
                          jnp.zeros((B, D), jnp.float32),
                          jnp.zeros((B, D), jnp.int32),
                          jnp.zeros((B, D), bool)))
            continue
        tl = rng.uniform(0, 400, (B, D, 2))
        wh = rng.uniform(10, 60, (B, D, 2))
        ticks.append((
            jnp.asarray(np.concatenate([tl, tl + wh], -1), jnp.float32),
            jnp.asarray(rng.uniform(0.5, 1.0, (B, D)), jnp.float32),
            jnp.asarray(rng.integers(0, 3, (B, D)), jnp.int32),
            jnp.asarray(rng.random((B, D)) > 0.2)))
    return ticks


def warm_rows(cfg, ticks, B):
    """Portable rows of a table warmed over the first ticks — each
    timing rep rebuilds fresh buffers from them (the fused programs
    DONATE their input state; reps must never share buffers)."""
    state = init_state(B, cfg)
    for t in ticks[:3]:
        state, _ = step(state, *t, cfg)
    return export_rows(state)


def time_regimes(cfg, rows, ticks, reps):
    """Interleaved per-tick-min timing of the three regimes.  Each rep
    threads fresh states (donation safety) through the same K ticks;
    staged and fused alternate within every tick so runner drift is
    shared, and the window launch is timed around the same rep.
    Returns per-tick ms floors ``(staged, fused, window)``."""
    K = len(ticks)
    stacked = tuple(jnp.stack([t[i] for t in ticks]) for i in range(4))
    smin = [float("inf")] * K
    fmin = [float("inf")] * K
    wmin = float("inf")
    for r in range(reps + 1):          # rep 0 warms the compile caches
        s_st = rows_to_state(rows, cfg)
        s_fu = rows_to_state(rows, cfg)
        s_wd = rows_to_state(rows, cfg)
        jax.block_until_ready((s_st, s_fu, s_wd))
        for k, t in enumerate(ticks):
            t0 = time.perf_counter()
            s_st, tid = step(s_st, *t, cfg)
            tid = np.asarray(tid)                  # per-tick det_tid sync
            out = tuple(np.asarray(a) for a in output(s_st, cfg))
            t1 = time.perf_counter()
            s_fu, tid, out = _fused_tick(s_fu, *t, cfg, False)
            tid = np.asarray(tid)
            out = tuple(np.asarray(a) for a in out)
            t2 = time.perf_counter()
            if r:
                smin[k] = min(smin[k], t1 - t0)
                fmin[k] = min(fmin[k], t2 - t1)
        t0 = time.perf_counter()
        s_wd, wtid, wout = fused_window(s_wd, *stacked, cfg)
        wtid = np.asarray(wtid)
        wout = tuple(np.asarray(a) for a in wout)
        t1 = time.perf_counter()
        if r:
            wmin = min(wmin, (t1 - t0) / K)
    return (sum(smin) / K * 1e3, sum(fmin) / K * 1e3, wmin * 1e3)


def check_identity(cfg, rows, ticks):
    """Replay all three regimes over the same ticks: every state field,
    the det_tid assignment and the output tuple must match bit for bit,
    and a detection-free fused tick must equal ``coast``."""
    s1 = rows_to_state(rows, cfg)
    s2 = rows_to_state(rows, cfg)
    tids, outs = [], []
    for k, t in enumerate(ticks):
        empty = not bool(np.asarray(t[3]).any())
        if empty:
            s1, tid1 = coast(s1, cfg), None
        else:
            s1, tid1 = step(s1, *t, cfg)
        o1 = output(s1, cfg)
        tids.append(None if tid1 is None else np.asarray(tid1))
        outs.append([np.asarray(a) for a in o1])
        s2, tid2, o2 = _fused_tick(s2, *t, cfg, False)
        if not empty and not np.array_equal(np.asarray(tid1),
                                            np.asarray(tid2)):
            return False
        for a, b in zip(o1, o2):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                return False
        for f in type(s1)._fields:
            if not np.array_equal(np.asarray(getattr(s1, f)),
                                  np.asarray(getattr(s2, f))):
                return False
    stacked = tuple(jnp.stack([t[i] for t in ticks]) for i in range(4))
    s3, wtid, wout = fused_window(rows_to_state(rows, cfg), *stacked, cfg)
    for f in type(s1)._fields:
        if not np.array_equal(np.asarray(getattr(s1, f)),
                              np.asarray(getattr(s3, f))):
            return False
    for k in range(len(ticks)):
        if tids[k] is not None and not np.array_equal(
                np.asarray(wtid)[k], tids[k]):
            return False
        for i, a in enumerate(wout):
            if not np.array_equal(np.asarray(a)[k], outs[k][i]):
                return False
    return True


def roofline_row(cfg, rows, tick, fused_ms):
    """Analytical bound of ONE fused tick vs the measured time."""
    state = rows_to_state(rows, cfg)
    compiled = jax.jit(
        lambda s, b, sc, c, v: _fused_tick(s, b, sc, c, v, cfg, False)
    ).lower(state, *tick).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):      # older jax: one dict per device
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    compute_ms = flops / PEAK_FLOPS * 1e3
    memory_ms = byts / HBM_BW * 1e3
    bound_ms = max(compute_ms, memory_ms)
    return {
        "flops": flops, "bytes": byts,
        "compute_ms": compute_ms, "memory_ms": memory_ms,
        "bound": "compute" if compute_ms >= memory_ms else "memory",
        "measured_fused_ms": fused_ms,
        # >> 1 on CPU: the tick is dispatch-overhead-bound, which is
        # the regime where collapsing the launch chain pays
        "measured_over_bound": (fused_ms / bound_ms if bound_ms
                                else float("inf")),
    }


def bench(B, D, K, reps, cfg):
    rng = np.random.default_rng(0)
    ticks = make_ticks(rng, B, D, K)
    rows = warm_rows(cfg, ticks, B)
    staged_ms, fused_ms, window_ms = time_regimes(cfg, rows, ticks, reps)
    return {
        "batch_streams": B, "det_capacity": D,
        "track_capacity": cfg.capacity, "ticks": K,
        "staged": {"launches_per_tick": 2, "tracker_step_ms": staged_ms},
        "fused": {"launches_per_tick": 1, "tracker_step_ms": fused_ms,
                  "speedup_vs_staged": staged_ms / fused_ms},
        "fused_window": {"launches_per_window": 1,
                         "tracker_step_ms": window_ms},
        "speedup": staged_ms / window_ms,
        "bit_identical": check_identity(cfg, rows, ticks),
        "roofline": roofline_row(cfg, rows, ticks[0], fused_ms),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / fewer reps (CI)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_tick.json"))
    args = ap.parse_args()

    if args.smoke:
        row = bench(B=2, D=8, K=20, reps=4, cfg=TrackerConfig(capacity=16))
    else:
        row = bench(B=4, D=16, K=40, reps=8, cfg=TrackerConfig(capacity=32))

    out = {
        "bench": "fused_serve_tick",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        **row,
        "acceptance": {
            "fused_bit_identical": row["bit_identical"],
            "fused_speedup_ge_1_2": row["speedup"] >= 1.2,
        },
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))
    if not all(out["acceptance"].values()):
        failed = [k for k, v in out["acceptance"].items() if not v]
        print(f"ACCEPTANCE FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
