"""Fault-injected serving trajectory: deterministic replica/shard chaos
through the NVR serving stack, measuring what the failure machinery
costs and what the supervision recovers.

  PYTHONPATH=src python benchmarks/faults_bench.py [--smoke] [--out PATH]

Four scenarios, each a pure function of ``(trace, FaultSchedule)`` so
every number replays bit-identically:

* **no-fault** — an EMPTY schedule (and an idle watchdog) must leave the
  fault-free serve bit-identical: same response rids/clocks, same
  drops, same migrations.  The fault machinery may cost nothing when
  nothing fails.
* **replica kill** — one replica of a single-host pool dies mid-serve
  (no revive).  The scheduler's timeout rule detects it, fails the
  in-flight frame over, and the tracker coasts whatever the shrunken
  pool drops; per-stream coverage must hold at 1.0 and the tracked mAP
  must stay within 20% of the fault-free run.
* **shard kill** — a whole shard of a 2-shard epoch-loop deployment dies
  mid-epoch.  The watchdog restarts it at the next boundary and
  evacuates its cameras; every stream must be back at full coverage
  from the first post-recovery boundary on (``recovered_coverage`` 1.0)
  — recovery within one epoch.
* **replica lending** — a single 30 fps camera overloads shard 0 while
  shard 1 idles: the one load stream migration refuses to move (it
  would just relocate the overload).  The watchdog lends shard 1's tail
  replica instead; drops must STRICTLY fall versus the unsupervised
  run, and every loan must be returned by serve end.

Emits ``BENCH_faults.json``; exits nonzero unless every acceptance key
holds (CI gates on this).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np


def canonical(report):
    """The bit-identity fingerprint of a serve report: response ids,
    replicas and clocks, drop list, migrations."""
    return {
        "responses": [(r.rid, r.replica, r.t_start, r.t_done)
                      for r in report["responses"]],
        "dropped": list(report["dropped"]),
        "migrations": report.get("migrations"),
        "per_replica": report["per_replica"],
    }


def scenario_no_fault(n_streams, n_frames):
    """Empty schedule + idle watchdog vs the plain engine, on the epoch
    loop (the path every fault hook lives on)."""
    from repro.core import proxy_detect_fn_streams
    from repro.serving import (FaultSchedule, ShardedDetectionEngine,
                               Watchdog, make_nvr_streams)

    frames, frame_of, videos, dets = make_nvr_streams(n_streams,
                                                      n_frames, rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(detect_fn=oracle, n_replicas=2, service_time=0.02,
              n_shards=2, rebalance=True, epoch_s=2.0,
              track_and_interpolate=True)
    plain = ShardedDetectionEngine(**kw).serve(frames)
    empty = ShardedDetectionEngine(faults=FaultSchedule(),
                                   **kw).serve(frames)
    idle_sup = ShardedDetectionEngine(supervisor=Watchdog(),
                                      **kw).serve(frames)
    identical = (canonical(plain) == canonical(empty)
                 == canonical(idle_sup))
    return {
        "frames": len(frames),
        "coverage": plain["coverage"],
        "bit_identical": identical,
        "idle_watchdog_actions": (idle_sup["faults"]["restarts"]
                                  + idle_sup["faults"]["loans"]),
    }, identical


def scenario_replica_kill(n_streams, n_frames):
    """One replica dies mid-serve on a single host; tracker coasts the
    lost capacity and quality must hold within 20% of fault-free."""
    from repro.core import evaluate_streams, proxy_detect_fn_streams
    from repro.serving import (DetectionEngine, FaultSchedule,
                               make_nvr_streams)

    frames, frame_of, videos, dets = make_nvr_streams(n_streams,
                                                      n_frames, rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(detect_fn=oracle, n_replicas=2, service_time=0.05,
              track_and_interpolate=True)
    horizon = n_frames / 4.0
    sched = FaultSchedule.replica_kill(horizon / 3, replica=1)
    clean = DetectionEngine(**kw).serve(frames)
    faulty = DetectionEngine(faults=sched, **kw).serve(frames)
    q_clean = evaluate_streams(videos, clean["streams"], n_frames)
    q_faulty = evaluate_streams(videos, faulty["streams"], n_frames)
    cov = min(v["coverage"] for v in faulty["per_stream"].values())
    ok = (cov == 1.0
          and q_faulty["map_mean"] >= 0.8 * q_clean["map_mean"]
          and sum(faulty["retries"].values()) >= 1)
    return {
        "kill_t": round(horizon / 3, 3),
        "coverage_min": cov,
        "interpolated": faulty["interpolated"],
        "retries": faulty["retries"],
        "failovers": faulty["failovers"],
        "frames_lost": faulty["frames_lost"],
        "map_mean_clean": round(q_clean["map_mean"], 4),
        "map_mean_faulty": round(q_faulty["map_mean"], 4),
        "map_ratio": round(q_faulty["map_mean"]
                           / max(q_clean["map_mean"], 1e-9), 4),
    }, ok


def scenario_shard_kill(n_streams, n_frames):
    """A whole shard dies mid-epoch; the watchdog restarts it at the
    next boundary and evacuates its cameras — full per-stream coverage
    from the first post-recovery boundary on."""
    from repro.core import proxy_detect_fn_streams
    from repro.serving import (FaultSchedule, ShardedDetectionEngine,
                               Watchdog, make_nvr_streams)

    frames, frame_of, videos, dets = make_nvr_streams(n_streams,
                                                      n_frames, rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(detect_fn=oracle, n_replicas=2, service_time=0.02,
              n_shards=2, rebalance=True, epoch_s=2.0,
              track_and_interpolate=True)
    sched = FaultSchedule.shard_kill(2.5, shard=0)
    rep = ShardedDetectionEngine(faults=sched, supervisor=Watchdog(),
                                 **kw).serve(frames)
    restarts = rep["faults"]["restarts"]
    # killed at t=2.5 inside epoch 1 ([2,4)) -> restart must land at the
    # epoch-1 boundary (t=4.0): recovery within ONE epoch
    within_epoch = (len(restarts) == 1 and restarts[0]["shard"] == 0
                    and restarts[0]["ok"] and restarts[0]["t"] == 4.0)
    ok = (within_epoch and rep["recovered_coverage"] == 1.0
          and rep["faults"]["frames_lost_shard"] > 0
          and any(m["src"] == 0 for m in rep["migrations"]))
    return {
        "kill_t": 2.5,
        "epoch_s": 2.0,
        "frames_lost_shard": rep["faults"]["frames_lost_shard"],
        "restarts": restarts,
        "evacuations": [m for m in rep["migrations"]
                        if m["src"] == 0 and m["epoch"] == 1],
        "coverage": round(rep["coverage"], 4),
        "recovered_coverage": rep["recovered_coverage"],
    }, ok


def hot_stream_trace():
    """One 30 fps camera (shard 0) + one 1 fps camera (shard 1) over an
    8 s horizon: the single-hot-stream overload stream migration
    refuses to touch (rule 3: moving the only stream just relocates
    the overload) — the case replica lending exists for."""
    from repro.serving import FrameRequest
    events = [(k / 30.0, 0, k) for k in range(240)]
    events += [(k + 0.5, 1, k) for k in range(8)]
    events.sort()
    return [FrameRequest(rid, np.zeros((4, 4, 3), np.float32), t,
                         stream_id=s)
            for rid, (t, s, k) in enumerate(events)]


def scenario_lending():
    from repro.serving import ShardedDetectionEngine, Watchdog

    def stub(images, rids=None):
        b = len(images)
        return (np.zeros((b, 4, 4), np.float32),
                np.zeros((b, 4), np.float32),
                np.zeros((b, 4), np.int32), np.zeros((b, 4), bool))

    frames = hot_stream_trace()
    kw = dict(detect_fn=stub, n_replicas=2, service_time=0.1,
              drop_when_busy=True, micro_batch=1, max_micro_batch=1,
              n_shards=2, rebalance=True, epoch_s=2.0)
    rep_no = ShardedDetectionEngine(**kw).serve(frames)
    eng = ShardedDetectionEngine(
        supervisor=Watchdog(idle_backlog_s=0.5), **kw)
    rep_ln = eng.serve(frames)
    loans = rep_ln["faults"]["loans"]
    ok = (not rep_no["migrations"]                 # migration refused...
          and bool(loans)                          # ...lending acted
          and len(rep_ln["dropped"]) < len(rep_no["dropped"])
          and all(ln["returned_epoch"] is not None for ln in loans)
          and all(len(e.replicas) == 2 for e in eng.engines))
    return {
        "frames": len(frames),
        "drops_unsupervised": len(rep_no["dropped"]),
        "drops_with_lending": len(rep_ln["dropped"]),
        "migrations_unsupervised": rep_no["migrations"],
        "loans": loans,
        "coverage_unsupervised": round(rep_no["coverage"], 4),
        "coverage_with_lending": round(rep_ln["coverage"], 4),
    }, ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream lengths (CI)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_faults.json"))
    args = ap.parse_args()

    import jax

    n_streams, n_frames = (4, 24) if args.smoke else (6, 48)
    t0 = time.perf_counter()
    no_fault, ok_nf = scenario_no_fault(n_streams, n_frames)
    rk, ok_rk = scenario_replica_kill(n_streams, n_frames)
    sk, ok_sk = scenario_shard_kill(n_streams, n_frames)
    ld, ok_ld = scenario_lending()

    out = {
        "bench": "fault_injected_serving",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "pool": {"cameras": n_streams, "frames_per_stream": n_frames,
                 "stream_rate_fps": 4.0, "n_replicas_per_shard": 2},
        "no_fault": no_fault,
        "replica_kill": rk,
        "shard_kill": sk,
        "lending": ld,
        "wall_s": round(time.perf_counter() - t0, 2),
        "acceptance": {
            # an empty schedule and an idle watchdog cost NOTHING: the
            # fault-free serve is bit-identical with or without them
            "no_fault_bit_identical": ok_nf,
            # one replica dead -> tracker coasts the lost capacity:
            # full per-stream coverage, mAP within 20% of fault-free
            "replica_kill_coverage_1": ok_rk,
            # whole-shard kill -> watchdog restart + evacuation brings
            # every stream back by the first boundary after the kill
            "shard_kill_recovers_within_epoch": ok_sk,
            # the single-hot-stream overload migration refuses: lending
            # a replica strictly reduces drops, and every loan returns
            "lending_strictly_reduces_drops": ok_ld,
        },
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))
    if not all(out["acceptance"].values()):
        failed = [k for k, v in out["acceptance"].items() if not v]
        print(f"ACCEPTANCE FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
