"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the artifacts
in experiments/dryrun/.

  PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks import roofline  # noqa: E402

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
HBM_GB = 16.0   # v5e


def dryrun_table():
    print("| arch | shape | mesh | params | args/dev | temp/dev | "
          "flops/dev | coll B/dev | fits 16G |")
    print("|---|---|---|---|---|---|---|---|---|")
    for f in sorted(DRYRUN.glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("skipped"):
            print(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — | — "
                  f"| — | — | skip: {d['reason'][:32]} |")
            continue
        if "error" in d:
            print(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | "
                  f"FAIL: {d['error'][:40]} |")
            continue
        mem = d["memory"]
        args = mem["argument_size_in_bytes"] / 1e9
        temp = mem["temp_size_in_bytes"] / 1e9
        hc = d.get("hlo_cost", {})
        fits = "✅" if args + temp <= HBM_GB else f"{args+temp:.0f} GB ⚠️"
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} "
              f"| {d['n_params']/1e9:.1f}B | {args:.2f} GB | {temp:.2f} GB "
              f"| {hc.get('flops', 0):.2e} "
              f"| {d['collectives']['total_bytes']:.2e} | {fits} |")


def main():
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        dryrun_table()
        print()
    if which in ("all", "roofline"):
        print("### Roofline (single-pod 16x16)\n")
        rows = roofline.table("single")
        print(roofline.render(rows))
        print()
        print("### Roofline (multi-pod 2x16x16)\n")
        rows = roofline.table("multi")
        print(roofline.render(rows))


if __name__ == "__main__":
    main()
