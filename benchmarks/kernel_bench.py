"""Pallas-kernel micro-benchmarks (interpret mode on CPU: numerics + shape
validation; wall times are meaningful relatively, not as TPU projections).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready()          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def bench_kernels():
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.decode_attention import decode_attention
    from repro.kernels.iou import iou_matrix
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)

    B, H, T, D = 1, 4, 512, 64
    q = jax.random.normal(ks[0], (B, H, T, D))
    k = jax.random.normal(ks[1], (B, H, T, D))
    v = jax.random.normal(ks[2], (B, H, T, D))
    rows.append(("flash_attention_pallas",
                 _time(lambda a, b, c: flash_attention(a, b, c,
                                                       interpret=True),
                       q, k, v), f"{B}x{H}x{T}x{D}"))
    rows.append(("flash_attention_ref",
                 _time(jax.jit(ref.flash_attention_ref), q, k, v),
                 f"{B}x{H}x{T}x{D}"))

    B, H, KV, S, D = 2, 16, 4, 2048, 64
    q1 = jax.random.normal(ks[0], (B, H, D))
    k1 = jax.random.normal(ks[1], (B, S, KV, D))
    v1 = jax.random.normal(ks[2], (B, S, KV, D))
    rows.append(("decode_attention_pallas",
                 _time(lambda a, b, c: decode_attention(a, b, c,
                                                        interpret=True),
                       q1, k1, v1), f"cache={S}"))
    rows.append(("decode_attention_ref",
                 _time(jax.jit(ref.decode_attention_ref), q1, k1, v1),
                 f"cache={S}"))

    bx = jnp.asarray(np.random.default_rng(0).uniform(0, 100, (256, 4)),
                     jnp.float32)
    rows.append(("iou_matrix_pallas",
                 _time(lambda a: iou_matrix(a, a, interpret=True), bx),
                 "256x256"))
    rows.append(("iou_matrix_ref",
                 _time(jax.jit(ref.iou_matrix_ref), bx, bx), "256x256"))
    return rows
