"""Perf + quality trajectory for the tracking subsystem: tracker step
latency, association-kernel bit-compatibility, and the mAP the tracker
recovers from dropped frames at each drop rate.

  PYTHONPATH=src python benchmarks/tracking_bench.py [--smoke] [--out PATH]

Emits ``BENCH_tracking.json`` with

* ``assoc``        — greedy-assignment kernel timings (Pallas /
  XLA twin) with all paths asserted bit-identical to
  ``ref.greedy_assign_ref``;
* ``step``         — full tracker-step latency (predict + associate +
  update + birth, one fused launch) at the serving shape and a
  multi-stream (NVR) shape;
* ``recovered_map``— for each executor count n on ETH-Sunnyday: the
  paced run's drop rate, the stale-reuse mAP (the paper's fill), the
  tracked/interpolated mAP, track coverage and ID switches — asserting
  the tracked stream beats stale reuse at every drop rate;
* ``engine``       — the serving acceptance row: a stream paced at 2x
  the single-replica detection rate, drop-mode coverage vs
  track-and-interpolate coverage (must be 100%) and the mAP win.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def best_of(fn, *args, iters=20, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) / iters * 1e3)
    return min(times)


def _rand_assoc(rng, B, T, D):
    def boxes(n):
        tl = rng.uniform(0, 400, (B, n, 2))
        wh = rng.uniform(10, 80, (B, n, 2))
        return jnp.asarray(np.concatenate([tl, tl + wh], -1), jnp.float32)
    return (boxes(T), boxes(D),
            jnp.asarray(rng.random((B, T)) > 0.3),
            jnp.asarray(rng.random((B, D)) > 0.3),
            jnp.asarray(rng.integers(0, 3, (B, T)), jnp.int32),
            jnp.asarray(rng.integers(0, 3, (B, D)), jnp.int32))


def bench_assoc(B, T, D, iters, reps):
    rng = np.random.default_rng(0)
    tb, db, tm, dm, tc, dc = _rand_assoc(rng, B, T, D)
    kw = dict(t_mask=tm, d_mask=dm, t_cls=tc, d_cls=dc, iou_thr=0.3)
    r = np.asarray(ref.greedy_assign_ref(tb, db, tm, dm, tc, dc, 0.3))
    x = np.asarray(ops.greedy_assign(tb, db, use_pallas=False, **kw))
    p = np.asarray(ops.greedy_assign(tb, db, use_pallas=True, **kw))
    assert np.array_equal(x, r) and np.array_equal(p, r)
    f_x = jax.jit(lambda a, b: ops.greedy_assign(a, b, use_pallas=False,
                                                 **kw))
    f_p = jax.jit(lambda a, b: ops.greedy_assign(a, b, use_pallas=True,
                                                 **kw))
    return {
        "shape": [B, T, D],
        "xla_ms": best_of(f_x, tb, db, iters=iters, reps=reps),
        "pallas_ms": best_of(f_p, tb, db, iters=iters, reps=reps),
        "bit_compatible": True,
    }


def bench_step(B, D, iters, reps):
    from repro.tracking import TrackerConfig, init_state, step
    cfg = TrackerConfig()
    rng = np.random.default_rng(1)
    state = init_state(B, cfg)
    tl = rng.uniform(0, 400, (B, D, 2))
    wh = rng.uniform(10, 60, (B, D, 2))
    boxes = jnp.asarray(np.concatenate([tl, tl + wh], -1), jnp.float32)
    scores = jnp.asarray(rng.uniform(0.5, 1.0, (B, D)), jnp.float32)
    classes = jnp.asarray(rng.integers(0, 3, (B, D)), jnp.int32)
    valid = jnp.asarray(rng.random((B, D)) > 0.2)
    # warm the table so the timed step exercises match+coast+birth
    state, _ = step(state, boxes, scores, classes, valid, cfg)
    f = lambda s: step(s, boxes, scores, classes, valid, cfg)[0]
    return {
        "batch_streams": B, "det_capacity": D,
        "track_capacity": cfg.capacity,
        "step_ms": best_of(f, state, iters=iters, reps=reps),
    }


def bench_recovered_map(ns, smoke):
    from dataclasses import replace
    from repro.core import (ParallelDetector, SequenceSynchronizer,
                            evaluate_map, evaluate_map_dets,
                            track_quality)
    from repro.core.simulator import simulate
    from repro.core.stream import ETH_SUNNYDAY, FrameStream
    from repro.tracking import fill_stream
    # smoke: a 120-frame prefix of the stream (same λ/μ, same drop
    # dynamics) keeps the CI job short
    spec = replace(ETH_SUNNYDAY, n_frames=120) if smoke else ETH_SUNNYDAY
    rows = []
    for n in ns:
        det = ParallelDetector(spec, "yolov3", ["ncs2"] * n)
        paced = simulate(FrameStream(det.video), det.scheduler)
        synced = SequenceSynchronizer().order(paced)
        stale = evaluate_map(det.video, synced, det.detector)
        t0 = time.perf_counter()
        tracked = fill_stream(det.video, paced, det.detector)
        fill_ms = (time.perf_counter() - t0) * 1e3
        tmap = evaluate_map_dets(det.video, tracked)
        tq = track_quality(det.video, tracked)
        assert tmap > stale, (n, tmap, stale)
        rows.append({
            "n": n, "drop_rate": round(paced.drop_rate, 4),
            "map_stale": round(stale, 4),
            "map_tracked": round(tmap, 4),
            "map_recovered": round(tmap - stale, 4),
            "coverage": round(tq["coverage"], 4),
            "id_switches": tq["id_switches"],
            "fill_stream_ms": round(fill_ms, 1),
        })
    return rows


def bench_engine(n_frames):
    """The acceptance row: stream paced at 2x the single-replica
    detection rate; track-and-interpolate must cover every arrival
    frame and beat the drop-frames baseline on full-stream mAP."""
    from repro.core import ProxyDetector, SyntheticVideo
    from repro.core.quality import (evaluate_map_dets, proxy_detect_fn,
                                    responses_to_detections)
    from repro.core.stream import ETH_SUNNYDAY
    from repro.serving import DetectionEngine, FrameRequest

    video = SyntheticVideo(ETH_SUNNYDAY)
    oracle = proxy_detect_fn(video, ProxyDetector("yolov3",
                                                  "ETH-Sunnyday"))
    mu = 2.5
    frames = [FrameRequest(i, np.zeros((4, 4, 3), np.float32),
                           i / (2.0 * mu)) for i in range(n_frames)]

    def run(**kw):
        eng = DetectionEngine(n_replicas=1, detect_fn=oracle,
                              service_time=1.0 / mu, **kw)
        out = eng.serve(frames)
        dets = responses_to_detections(out["responses"], n_frames)
        return out, evaluate_map_dets(video, dets)

    out_d, map_d = run(drop_when_busy=True)
    out_t, map_t = run(track_and_interpolate=True)
    assert out_t["coverage"] == 1.0, out_t["coverage"]
    assert map_t > map_d, (map_t, map_d)
    return {
        "stream_rate_over_mu": 2.0, "n_frames": n_frames,
        "drop_coverage": round(out_d["coverage"], 4),
        "tracked_coverage": out_t["coverage"],
        "interpolated_frames": out_t["interpolated"],
        "map_dropped": round(map_d, 4),
        "map_tracked": round(map_t, 4),
        "full_coverage_and_map_win": True,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / single rep (CI)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_tracking.json"))
    args = ap.parse_args()

    if args.smoke:
        iters, reps = 3, 1
        assoc = bench_assoc(4, 16, 8, iters, reps)
        step1 = bench_step(1, 16, iters, reps)
        stepN = bench_step(4, 16, iters, reps)
        recovered = bench_recovered_map((2,), smoke=True)
        engine = bench_engine(60)
    else:
        iters, reps = 20, 5
        assoc = bench_assoc(8, 64, 32, iters, reps)
        step1 = bench_step(1, 32, iters, reps)
        stepN = bench_step(8, 32, iters, reps)
        recovered = bench_recovered_map((1, 2, 4), smoke=False)
        engine = bench_engine(120)

    out = {
        "bench": "tracking_subsystem",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "assoc": assoc,
        "step_single_stream": step1,
        "step_multi_stream": stepN,
        "recovered_map": recovered,
        "engine": engine,
        "acceptance": {
            "assoc_bit_compatible": assoc["bit_compatible"],
            "tracked_beats_stale_all_rates": True,   # asserted above
            "engine_full_coverage_and_map_win":
                engine["full_coverage_and_map_win"],
        },
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
