"""Sharded multi-host NVR serving trajectory: a fixed camera set spread
over 1..N mesh shards, each shard its own replica pool + lockstep
tracker, detection running as ONE SPMD program on the host mesh.

  PYTHONPATH=src python benchmarks/sharded_bench.py [--smoke] [--out PATH]

Forces ``xla_force_host_platform_device_count`` BEFORE the first jax
import so the host exposes a real multi-device mesh (CPU smoke stand-in
for multi-host; interpret the step latencies as trajectory, not TPU
projections).  Emits ``BENCH_sharded.json`` with one row per shard
count:

* ``coverage``          — MIN per-stream coverage under
  ``track_and_interpolate`` (asserted 1.0 for every row);
* ``tracker_step_ms``   — lockstep tracker step at
  ``B = cameras-per-shard`` (the per-tick launch each shard issues;
  sharding shrinks B, which is where the step-latency win comes from);
* ``spmd_detect_ms``    — the shared detect+NMS program on an
  ``n_shards``-device mesh at the engine's micro-batch size;
* ``map_mean``/``map_min`` — per-stream tracked mAP after the merge
  (scored by ``core.quality.evaluate_streams``, unchanged);
* ``serve_ms``          — wall time of the whole sharded serve call.

Acceptance (all measured here, not trusted): every row full coverage,
single-shard report bit-identical to ``DetectionEngine``, SPMD detect
bit-compatible with the plain jitted path, and the per-shard tracker
step at the largest shard count beating the unsharded one.

Work-stealing section (``work_stealing`` key): a SKEWED trace — the
cameras the static partition puts on shard 0 run at 2x rate — served
static vs ``rebalance=True`` at each shard count, in drop mode so the
rate mismatch is visible as drops.  Per row: total drops, min
per-stream coverage, executed migrations, serve wall time, and the
lockstep tracker step at the max cameras-per-shard each policy ends up
with.  Gated: stealing must STRICTLY reduce total drops at every
multi-shard row while no stream's coverage falls below its static
value, the single-shard row must be unchanged by the flag, and
``rebalance=False`` must stay bit-identical to the per-shard
DetectionEngine + ``merge_shard_reports`` composition.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

N_DEVICES = 8
if __name__ == "__main__":
    # standalone invocation only: must precede the first jax import to
    # take effect, and must NOT leak into processes that merely import
    # bench_shard_row (benchmarks/run.py — jax already initialized
    # there, so the flag could only confuse child processes)
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_DEVICES}")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import numpy as np


def best_of(f, iters, reps):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            f()
        best = min(best, (time.perf_counter() - t0) / iters)
    return best * 1e3


def bench_spmd_detect(n_shards, mb, iters, reps):
    """The shared SPMD detect+NMS program on an n-shard mesh, plus a
    bit-compat check against the engine's own meshless jit path."""
    import jax.numpy as jnp

    from repro.detector import (SSDConfig, decode_detections, init_ssd,
                                make_anchors)
    from repro.launch.mesh import make_serving_mesh
    from repro.serving import make_spmd_detect

    cfg = SSDConfig()
    params = init_ssd(cfg, jax.random.PRNGKey(0))
    # clamp to the visible devices: when jax was initialized before our
    # XLA_FLAGS took effect (benchmarks/run.py importing this module),
    # the micro-bench degrades to the 1-device mesh instead of failing
    n_shards = min(n_shards, len(jax.devices()))
    mesh = make_serving_mesh(n_shards)
    detect = make_spmd_detect(cfg, params, mesh)
    anchors = jnp.asarray(make_anchors(cfg))
    plain = jax.jit(lambda im: decode_detections(params, cfg, im, anchors))
    imgs = np.random.default_rng(0).random((mb, 64, 64, 3)) \
        .astype(np.float32)
    spmd_out = [np.asarray(a) for a in detect(imgs)]   # compile + warm
    plain_out = [np.asarray(a) for a in
                 jax.block_until_ready(plain(jnp.asarray(imgs)))]
    # partitioned convs may differ from the meshless program by a ulp
    # in box coords (different XLA fusion per shard); the DECISIONS —
    # classes, suppression survivors — must be identical, and a
    # 1-device mesh must be bit-exact (the constraints are no-ops)
    max_diff = max(float(np.max(np.abs(
        a.astype(np.float64) - b.astype(np.float64))))
        for a, b in zip(spmd_out[:2], plain_out[:2]))
    decisions = (np.array_equal(spmd_out[2], plain_out[2])
                 and np.array_equal(spmd_out[3], plain_out[3]))
    matches = decisions and (max_diff == 0.0 if n_shards == 1
                             else max_diff < 1e-6)
    ms = best_of(lambda: detect(imgs), iters, reps)
    return ms, matches, max_diff


def single_shard_bit_identical(frames, oracle, **kw):
    from repro.serving import DetectionEngine, ShardedDetectionEngine
    base = DetectionEngine(detect_fn=oracle, **kw).serve(frames)
    sh = ShardedDetectionEngine(n_shards=1, detect_fn=oracle,
                                **kw).serve(frames)
    same = len(base["responses"]) == len(sh["responses"]) and all(
        ra.rid == rb.rid and ra.t_done == rb.t_done
        and np.array_equal(ra.boxes, rb.boxes)
        and np.array_equal(ra.valid, rb.valid)
        for ra, rb in zip(base["responses"], sh["responses"]))
    scalars = all(base[k] == sh[k] for k in
                  ("coverage", "interpolated", "throughput_fps",
                   "dropped", "per_replica", "tracker_launches"))
    return same and scalars


def bench_shard_row(n_shards, n_streams, n_frames, rate, iters, reps):
    from benchmarks.tracking_bench import bench_step
    from repro.core import evaluate_streams, proxy_detect_fn_streams
    from repro.serving import ShardedDetectionEngine, make_nvr_streams

    frames, frame_of, videos, dets = make_nvr_streams(n_streams,
                                                      n_frames, rate)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    eng = ShardedDetectionEngine(
        n_shards=n_shards, detect_fn=oracle, n_replicas=2,
        service_time=0.4, track_and_interpolate=True)
    t0 = time.perf_counter()
    out = eng.serve(frames)
    serve_ms = (time.perf_counter() - t0) * 1e3
    cov_min = min(v["coverage"] for v in out["per_stream"].values())
    assert cov_min == 1.0, cov_min
    assert out["n_shards"] == n_shards
    q = evaluate_streams(videos, out["streams"], n_frames)
    cams_per_shard = max(len(s["streams"]) for s in out["per_shard"])
    step = bench_step(cams_per_shard, 24, iters, reps)
    mb = eng.engines[0].max_micro_batch
    spmd_ms, spmd_ok, spmd_diff = bench_spmd_detect(n_shards, mb,
                                                    iters, reps)
    return {
        "n_shards": n_shards,
        "cameras": n_streams,
        "cameras_per_shard": cams_per_shard,
        "frames_per_stream": n_frames,
        "coverage": cov_min,
        "interpolated": out["interpolated"],
        "tracker_launches": out["tracker_launches"],
        "map_mean": round(q["map_mean"], 4),
        "map_min": round(q["map_min"], 4),
        "id_switches_total": q["id_switches_total"],
        "tracker_step_ms": step["step_ms"],
        "spmd_detect_ms": round(spmd_ms, 3),
        "spmd_matches_plain": spmd_ok,
        "spmd_max_abs_diff": spmd_diff,
        "serve_ms": round(serve_ms, 1),
    }


def bench_stealing_row(n_shards, n_frames, rate, iters, reps):
    """Static partition vs cross-shard work stealing on the skewed
    trace, drop mode (the rate mismatch shows up as drops, the paper's
    §III pathology).  Coverage below is per-stream served fraction."""
    from benchmarks.tracking_bench import bench_step
    from repro.core import proxy_detect_fn_streams
    from repro.serving import make_skewed_streams, ShardedDetectionEngine

    n_streams = 3 * n_shards
    frames, frame_of, videos, dets = make_skewed_streams(
        n_streams, n_frames, rate, n_shards)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(n_shards=n_shards, detect_fn=oracle, n_replicas=2,
              service_time=0.36, drop_when_busy=True)
    outs, serve_ms = {}, {}
    for name, extra in (("static", {}),
                        ("stealing", {"rebalance": True,
                                      "epoch_s": 4.0 * n_frames / 12})):
        eng = ShardedDetectionEngine(**kw, **extra)
        t0 = time.perf_counter()
        outs[name] = eng.serve(frames)
        serve_ms[name] = round((time.perf_counter() - t0) * 1e3, 1)
    static, steal = outs["static"], outs["stealing"]
    cov = {name: {sid: v["coverage"]
                  for sid, v in outs[name]["per_stream"].items()}
           for name in outs}
    # lockstep tracker step at the max cameras-per-shard each policy
    # ends up with (stealing can RAISE the receiver's B — honest cost)
    cams = {"static": max(len(s["streams"]) for s in static["per_shard"]),
            "stealing": max(len(s["streams"])
                            for s in steal["per_shard"])}
    step = {name: bench_step(b, 24, iters, reps)["step_ms"]
            for name, b in cams.items()}
    return {
        "n_shards": n_shards,
        "cameras": n_streams,
        "frames": len(frames),
        "drops_static": len(static["dropped"]),
        "drops_stealing": len(steal["dropped"]),
        "coverage_min_static": round(min(cov["static"].values()), 4),
        "coverage_min_stealing": round(min(cov["stealing"].values()), 4),
        "coverage_ge_static_all_streams": all(
            cov["stealing"][sid] >= c for sid, c in cov["static"].items()),
        "migrations": steal.get("migrations", []),
        "n_epochs": steal.get("n_epochs", 1),
        "cams_per_shard_static": cams["static"],
        "cams_per_shard_stealing": cams["stealing"],
        "tracker_step_ms_static": step["static"],
        "tracker_step_ms_stealing": step["stealing"],
        "serve_ms_static": serve_ms["static"],
        "serve_ms_stealing": serve_ms["stealing"],
    }


def rebalance_off_bit_identical(n_frames, rate):
    """``rebalance=False`` vs the hand-rolled pre-stealing composition
    (per-shard DetectionEngine under the static partition +
    merge_shard_reports): every shared key must match bit-for-bit."""
    from repro.core import proxy_detect_fn_streams
    from repro.serving import (DetectionEngine, ShardedDetectionEngine,
                               make_skewed_streams, merge_shard_reports)
    from repro.sharding import shard_streams

    frames, frame_of, videos, dets = make_skewed_streams(
        6, n_frames, rate, 2)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(detect_fn=oracle, n_replicas=2, service_time=0.36,
              drop_when_busy=True)
    sh = ShardedDetectionEngine(n_shards=2, rebalance=False,
                                **kw).serve(frames)
    part = shard_streams(range(6), 2)
    subs = [[f for f in frames if part[f.stream_id] == h]
            for h in range(2)]
    reports = [DetectionEngine(**kw).serve(s) for s in subs]
    manual = merge_shard_reports(frames, reports, [2, 2])
    same = all(
        ra.rid == rb.rid and ra.replica == rb.replica
        and ra.t_done == rb.t_done
        and np.array_equal(ra.boxes, rb.boxes)
        for ra, rb in zip(manual["responses"], sh["responses"]))
    scalars = all(manual[k] == sh[k] for k in
                  ("coverage", "dropped", "per_replica", "per_stream",
                   "throughput_fps", "tracker_launches"))
    return same and scalars and "migrations" not in sh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream lengths / single rep (CI)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_sharded.json"))
    args = ap.parse_args()

    from repro.core import proxy_detect_fn_streams
    from repro.serving import make_nvr_streams

    if args.smoke:
        # the step-timing acceptance gate compares two sub-ms
        # measurements, so even smoke keeps enough best-of reps to
        # ride out shared-runner scheduling noise (30 calls ~ tens of
        # ms; the B=4 vs B=2 gap is ~1.7x, far above best-of jitter)
        shard_counts, n_streams, n_frames, iters, reps = \
            (1, 2), 4, 16, 10, 3
    else:
        shard_counts, n_streams, n_frames, iters, reps = \
            (1, 2, 4), 8, 48, 20, 5

    rows = [bench_shard_row(n, n_streams, n_frames, rate=2.0,
                            iters=iters, reps=reps)
            for n in shard_counts]

    skew_frames = max(n_frames // 2, 12)
    steal_rows = [bench_stealing_row(n, skew_frames, rate=1.0,
                                     iters=iters, reps=reps)
                  for n in shard_counts]
    rebalance_off_ok = rebalance_off_bit_identical(skew_frames, rate=1.0)

    frames, frame_of, videos, dets = make_nvr_streams(n_streams,
                                                      n_frames, rate=2.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    bit_identical = single_shard_bit_identical(
        frames, oracle, n_replicas=2, service_time=0.4,
        track_and_interpolate=True)

    out = {
        "bench": "sharded_nvr_serving",
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "smoke": bool(args.smoke),
        "pool": {"cameras": n_streams, "frames_per_stream": n_frames,
                 "stream_rate_fps": 2.0, "n_replicas_per_shard": 2,
                 "service_time_s": 0.4},
        "rows": rows,
        # NOTE: the skewed runs use their own operating point (slower
        # streams, tighter service time) so the static partition really
        # drops — the top-level ``pool`` config does NOT apply here
        "work_stealing": {
            "skew": 2.0,
            "frames_per_slow_stream": skew_frames,
            "epoch_s": 4.0 * skew_frames / 12,
            "slow_stream_rate_fps": 1.0,
            "service_time_s": 0.36,
            "n_replicas_per_shard": 2,
            "rows": steal_rows,
        },
        "acceptance": {
            # skewed trace: stealing strictly reduces total drops at
            # every multi-shard row (where the static partition really
            # drops), never costs any stream coverage, and is a no-op
            # at one shard (no peer to steal from)
            "stealing_strictly_reduces_drops": all(
                r["drops_stealing"] < r["drops_static"]
                and r["drops_static"] > 0
                for r in steal_rows if r["n_shards"] >= 2),
            "stealing_coverage_ge_static_all_streams": all(
                r["coverage_ge_static_all_streams"] for r in steal_rows),
            "single_shard_stealing_is_static": all(
                r["drops_stealing"] == r["drops_static"]
                and not r["migrations"]
                for r in steal_rows if r["n_shards"] == 1),
            "rebalance_off_bit_identical": rebalance_off_ok,
            "per_stream_coverage_all_one": all(
                r["coverage"] == 1.0 for r in rows),
            "single_shard_bit_identical_to_detection_engine":
                bit_identical,
            # bit-exact on the 1-device mesh, decision-exact (classes /
            # survivors) and <1e-6 box drift on multi-device meshes
            "spmd_detect_matches_plain_path": all(
                r["spmd_matches_plain"] for r in rows),
            "mesh_spans_multiple_shards": any(
                r["n_shards"] >= 2 for r in rows)
                and len(jax.devices()) >= 2,
            # sharding shrinks the per-shard tracker batch B, so the
            # per-tick lockstep launch gets cheaper with shard count
            "tracker_step_scales_with_sharding":
                rows[-1]["tracker_step_ms"] < rows[0]["tracker_step_ms"],
        },
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))
    if not all(out["acceptance"].values()):
        failed = [k for k, v in out["acceptance"].items() if not v]
        print(f"ACCEPTANCE FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
