"""Observability trajectory: what frame-lifecycle tracing costs, that
it costs NOTHING when off, and that every recorded trace passes the
serving invariants (``repro.obs.audit``).

  PYTHONPATH=src python benchmarks/obs_bench.py [--smoke] [--out PATH]

Four scenarios, each deterministic (virtual clock) so every number
replays bit-identically:

* **overhead** — an 8-camera NVR trace served twice, with and without
  a live ``TraceRecorder``; the traced wall time (min over reps) must
  stay within 5% of the untraced one.  Recording is dict appends
  behind one ``enabled`` check — the hot path may not notice it.
* **disabled bit-identity** — the default engine, an engine given an
  explicit ``NullRecorder``, and an engine given a LIVE recorder must
  all produce the same report bits (responses, drops, clocks, and the
  full latency block): tracing observes the serve, never steers it.
* **audit** — three traced deployments replayed through the invariant
  checker: a fault-free sharded serve, a work-stealing serve on the
  skewed trace (migrations under load), and a seeded-chaos serve
  (``FaultSchedule.random`` + ``Watchdog`` restarts/loans/steals).
  Frame conservation, emit monotonicity, dead-replica dispatch and
  loan LIFO discipline must hold on ALL of them.
* **export** — the Perfetto/Chrome export of the chaos trace must
  carry exactly one duration span per completed frame, and the raw
  events must round-trip back out of the Chrome doc.

Emits ``BENCH_obs.json``; exits nonzero unless every acceptance key
holds (CI gates on this).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np


def canonical(report):
    """The bit-identity fingerprint of a serve report: response ids,
    replicas and clocks, drop list, and the new latency block."""
    return {
        "responses": [(r.rid, r.replica, r.t_start, r.t_done)
                      for r in report["responses"]],
        "dropped": list(report["dropped"]),
        "migrations": report.get("migrations"),
        "per_replica": report["per_replica"],
        "p50_latency": report["p50_latency"],
        "p95_latency": report["p95_latency"],
        "p99_latency": report["p99_latency"],
        "latency_hist": report["latency_hist"],
    }


def _nvr_engine_kw(n_streams, n_frames, **extra):
    from repro.core import proxy_detect_fn_streams
    from repro.serving import make_nvr_streams

    frames, frame_of, videos, dets = make_nvr_streams(n_streams,
                                                      n_frames, rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(detect_fn=oracle, n_replicas=2, service_time=0.02,
              track_and_interpolate=True, **extra)
    return frames, kw


def scenario_overhead(n_frames, blocks=7, serves_per_block=4):
    """8-camera NVR trace through the sharded epoch loop, with and
    without a live recorder: wall-time ratio must stay <= 1.05.

    Measurement design, because the delta is ~1 ms on a noisy shared
    box: each timing sample is a BLOCK of several whole serves (long
    enough to average across scheduler/frequency noise phases), the
    traced/untraced blocks alternate so drift hits both sides, GC is
    paused, and the statistic is min-of-blocks on each side — the
    closest observable to the true floor on both."""
    import gc

    from repro.obs import TraceRecorder
    from repro.serving import ShardedDetectionEngine

    frames, kw = _nvr_engine_kw(8, n_frames, n_shards=2,
                                rebalance=True, epoch_s=2.0)

    def block(recorder_of):
        t0 = time.perf_counter()
        for _ in range(serves_per_block):
            eng = ShardedDetectionEngine(recorder=recorder_of(), **kw)
            eng.serve(frames)
        return time.perf_counter() - t0

    def round_ratio():
        offs, ons = [], []
        gc.collect()
        gc.disable()
        try:
            for k in range(blocks):
                # alternate which side goes first so clock drift and
                # cache-warmth order effects cancel across blocks
                if k % 2 == 0:
                    ons.append(block(TraceRecorder))
                    offs.append(block(lambda: None))
                else:
                    offs.append(block(lambda: None))
                    ons.append(block(TraceRecorder))
        finally:
            gc.enable()
        return min(ons), min(offs)

    block(lambda: None), block(TraceRecorder)   # warm every lazy path
    # a scheduler stall landing inside one round can poison either side
    # by far more than the ~2% signal, so take the best of up to three
    # rounds (noise inflates the ratio; the floor is the measurement)
    on = off = ratio = None
    rounds = 0
    for _ in range(3):
        rounds += 1
        on_r, off_r = round_ratio()
        if ratio is None or on_r / off_r < ratio:
            on, off, ratio = on_r, off_r, on_r / off_r
        if ratio <= 1.05:
            break
    rec = TraceRecorder()
    ShardedDetectionEngine(recorder=rec, **kw).serve(frames)
    ok = ratio <= 1.05
    per_serve = 1e3 / serves_per_block
    return {
        "cameras": 8,
        "frames": len(frames),
        "events_recorded": len(rec.events),
        "untraced_ms": round(off * per_serve, 2),
        "traced_ms": round(on * per_serve, 2),
        "overhead_ratio": round(ratio, 4),
        "budget_ratio": 1.05,
        "blocks": blocks,
        "serves_per_block": serves_per_block,
        "rounds": rounds,
    }, ok


def scenario_disabled_identity(n_frames):
    """Default vs explicit NullRecorder vs LIVE TraceRecorder: one
    report, three recorder settings, identical bits."""
    from repro.obs import NullRecorder, TraceRecorder
    from repro.serving import DetectionEngine

    frames, kw = _nvr_engine_kw(4, n_frames)
    default = DetectionEngine(**kw).serve(frames)
    null = DetectionEngine(recorder=NullRecorder(), **kw).serve(frames)
    live = DetectionEngine(recorder=TraceRecorder(), **kw).serve(frames)
    identical = (canonical(default) == canonical(null)
                 == canonical(live))
    return {
        "frames": len(frames),
        "bit_identical": identical,
        "p95_latency": default["p95_latency"],
    }, identical


def _audit_one(recorder, report):
    from repro.obs import audit_recorder
    res = audit_recorder(recorder)
    return {
        "events": len(recorder.events),
        "arrived": res.stats["arrive"],
        "emitted": res.stats["emitted"],
        "dropped": res.stats["dropped_final"],
        "shard_lost": res.stats["shard_lost"],
        "dropped_report": len(report["dropped"]),
        "violations": res.violations[:5],
        "ok": res.ok,
    }, res.ok


def scenario_audit_no_fault(n_streams, n_frames):
    """Fault-free 2-shard epoch-loop serve: the trace must conserve
    every frame and keep per-stream emits monotone."""
    from repro.obs import TraceRecorder
    from repro.serving import ShardedDetectionEngine

    frames, kw = _nvr_engine_kw(n_streams, n_frames, n_shards=2,
                                rebalance=True, epoch_s=2.0)
    rec = TraceRecorder()
    rep = ShardedDetectionEngine(recorder=rec, **kw).serve(frames)
    return _audit_one(rec, rep)


def scenario_audit_stealing(n_frames):
    """Work-stealing serve on the skewed trace: shard 0's overload
    migrates mid-run, and the trace must stay invariant-clean across
    the migration epochs."""
    from repro.core import proxy_detect_fn_streams
    from repro.obs import TraceRecorder
    from repro.serving import ShardedDetectionEngine, make_skewed_streams

    frames, frame_of, videos, dets = make_skewed_streams(
        6, n_frames, rate=4.0, n_shards=2, skew=3.0)
    rec = TraceRecorder()
    rep = ShardedDetectionEngine(
        detect_fn=proxy_detect_fn_streams(videos, dets, frame_of),
        n_replicas=2, service_time=0.05, n_shards=2, rebalance=True,
        epoch_s=2.0, track_and_interpolate=True,
        recorder=rec).serve(frames)
    out, ok = _audit_one(rec, rep)
    out["migrations"] = rep["migrations"]
    return out, ok and bool(rep["migrations"])


def scenario_audit_chaos(n_streams, n_frames, seeds=(0, 1, 2, 3)):
    """Seeded random chaos (replica+shard kills) under a Watchdog: the
    trace must stay clean through restarts, failovers and loans —
    every seed."""
    from repro.obs import TraceRecorder, audit_recorder
    from repro.serving import (FaultSchedule, ShardedDetectionEngine,
                               Watchdog)

    frames, kw = _nvr_engine_kw(n_streams, n_frames, n_shards=2,
                                rebalance=True, epoch_s=2.0)
    horizon = n_frames / 4.0
    per_seed, all_ok = [], True
    last = None
    for seed in seeds:
        rec = TraceRecorder()
        rep = ShardedDetectionEngine(
            faults=FaultSchedule.random(seed=seed, horizon_s=horizon,
                                        n_shards=2, n_replicas=2,
                                        n_shard_events=1),
            supervisor=Watchdog(), recorder=rec, **kw).serve(frames)
        res = audit_recorder(rec)
        per_seed.append({
            "seed": seed, "events": len(rec.events),
            "restarts": len(rep["faults"]["restarts"]),
            "loans": len(rep["faults"]["loans"]),
            "frames_lost_shard": rep["faults"]["frames_lost_shard"],
            "ok": res.ok,
            "violations": res.violations[:3],
        })
        all_ok = all_ok and res.ok
        last = rec
    return {"seeds": list(seeds), "per_seed": per_seed}, all_ok, last


def scenario_export(recorder):
    """Chrome export of the last chaos trace: one 'X' span per
    ``complete`` event, and the raw events round-trip out of args."""
    from repro.obs import events_from_chrome, to_chrome_trace

    doc = to_chrome_trace(recorder.events, recorder.series)
    json.dumps(doc, default=float)        # must be serializable
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    completes = [e for e in recorder.events if e["kind"] == "complete"]
    back = events_from_chrome(doc)
    ok = (len(spans) == len(completes)
          and len(back) == len(recorder.events))
    return {
        "trace_events": len(doc["traceEvents"]),
        "spans": len(spans),
        "completes": len(completes),
        "round_trip_events": len(back),
        "raw_events": len(recorder.events),
    }, ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream lengths (CI)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_obs.json"))
    args = ap.parse_args()

    import jax

    n_streams, n_frames = (4, 16) if args.smoke else (6, 40)
    seeds = (0, 1) if args.smoke else (0, 1, 2, 3)
    t0 = time.perf_counter()
    ovh, ok_ovh = scenario_overhead(24, blocks=6 if args.smoke else 8)
    ident, ok_id = scenario_disabled_identity(n_frames)
    nf, ok_nf = scenario_audit_no_fault(n_streams, n_frames)
    st, ok_st = scenario_audit_stealing(n_frames)
    ch, ok_ch, chaos_rec = scenario_audit_chaos(n_streams, n_frames,
                                                seeds)
    ex, ok_ex = scenario_export(chaos_rec)

    out = {
        "bench": "serving_observability",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "overhead": ovh,
        "disabled_identity": ident,
        "audit_no_fault": nf,
        "audit_stealing": st,
        "audit_chaos": ch,
        "export": ex,
        "wall_s": round(time.perf_counter() - t0, 2),
        "acceptance": {
            # a live recorder costs <= 5% wall time on the 8-cam trace
            "overhead_within_5pct": ok_ovh,
            # recorder off (default or NullRecorder) or on: report bits
            # are identical — observation never steers the serve
            "disabled_bit_identical": ok_id,
            # every traced deployment passes the four invariants:
            "audit_no_fault_clean": ok_nf,
            # ...including across work-stealing migrations...
            "audit_stealing_clean": ok_st,
            # ...and under seeded chaos with watchdog supervision
            "audit_chaos_clean": ok_ch,
            # the Perfetto export is lossless: one span per completed
            # frame, raw events recoverable from the Chrome doc
            "export_span_per_complete": ok_ex,
        },
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))
    if not all(out["acceptance"].values()):
        failed = [k for k, v in out["acceptance"].items() if not v]
        print(f"ACCEPTANCE FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
