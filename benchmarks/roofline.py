"""Roofline analysis over the dry-run artifacts (experiments/dryrun/).

Per (arch x shape x mesh):
    compute_s    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory_s     = HLO_bytes_per_device / HBM_bw
    collective_s = collective_bytes_per_device / ICI_link_bw

(cost_analysis / the partitioned HLO report per-device quantities, so the
per-chip denominators apply directly — equivalent to the global/chips
formulation.)  MODEL_FLOPS uses 6·N_active·D for training and 2·N_active·D
for inference, with N_active discounting inactive experts for MoE.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

HINTS = {
    "compute": ("compute-bound: raise per-chip utilization — larger "
                "per-device token batch, fuse elementwise chains, MXU-"
                "aligned tile shapes"),
    "memory": ("memory-bound: cut HBM traffic — remat policy tuning, "
               "fused attention (no score materialization), bf16 "
               "activations, larger scan chunks"),
    "collective": ("collective-bound: reshard to shrink cross-chip bytes "
                   "— overlap collectives with compute, reduce-scatter "
                   "instead of all-reduce, keep weights resident"),
}


def active_params(arch: str, kind: str) -> float:
    """N (dense) or N_active (MoE: only top-k + shared experts count)."""
    from repro.configs import get_config
    from repro.models import init_model
    cfg = get_config(arch, "full")
    struct = jax.eval_shape(lambda k: init_model(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    flat = jax.tree_util.tree_flatten_with_path(struct)[0]
    total = 0.0
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        n = 1
        for s in leaf.shape:
            n *= s
        if "experts" in path and cfg.moe:
            n *= (cfg.moe.top_k / cfg.moe.n_experts)
        total += n
    return total


def tokens_for(shape: str) -> float:
    from repro.configs import SHAPES
    sh = SHAPES[shape]
    if sh.kind == "decode":
        return sh.global_batch            # one new token per sequence
    return sh.global_batch * sh.seq_len


def analyze(record: dict) -> dict:
    cost, coll = record["cost"], record["collectives"]
    # prefer the trip-count-aware HLO walk (repro.hlo); XLA-CPU's own
    # cost_analysis counts scan bodies once (see EXPERIMENTS.md §Roofline)
    hc = record.get("hlo_cost", {})
    flops = hc.get("flops") or cost.get("flops", 0.0)
    byts = hc.get("bytes") or cost.get("bytes accessed", 0.0)
    chips = 1
    for v in record["mesh"].values():
        chips *= v
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll["total_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    factor = 6.0 if record["kind"] == "train" else 2.0
    model_flops = factor * active_params(record["arch"],
                                         record["kind"]) \
        * tokens_for(record["shape"])
    hlo_total = flops * chips
    return {
        **{f"{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_total": hlo_total,
        "useful_ratio": model_flops / hlo_total if hlo_total else 0.0,
        "chips": chips,
        "hint": HINTS[dominant],
    }


def load_records(mesh: str = "single"):
    out = []
    for f in sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")):
        d = json.loads(f.read_text())
        if "error" in d or d.get("skipped"):
            continue
        out.append(d)
    return out


def table(mesh: str = "single"):
    rows = []
    for rec in load_records(mesh):
        a = analyze(rec)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "variant": rec.get("variant"), **a,
        })
    return rows


def render(rows) -> str:
    hdr = (f"| {'arch':24s} | {'shape':11s} | compute_s | memory_s | "
           f"collect_s | dominant | useful |")
    sep = "|" + "-" * 26 + "|" + "-" * 13 + "|" + "-" * 11 + "|" + "-" * 10 \
        + "|" + "-" * 11 + "|" + "-" * 10 + "|" + "-" * 8 + "|"
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']:24s} | {r['shape']:11s} | {r['compute_s']:9.2e} "
            f"| {r['memory_s']:8.2e} | {r['collective_s']:9.2e} "
            f"| {r['dominant']:8s} | {r['useful_ratio']:6.2f} |")
    return "\n".join(lines)


def main():
    rows = table("single")
    print(render(rows))
    out = DRYRUN_DIR.parent / "roofline_single.json"
    out.write_text(json.dumps(rows, indent=1))
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
