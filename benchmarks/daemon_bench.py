"""Incremental serving core trajectory: the always-on runtime must be
FREE — bit-identical reports to the batch path on every engine
configuration, and within 5% of its wall time on the 8-camera sharded
serve — and the daemon must drain cleanly.

  PYTHONPATH=src python benchmarks/daemon_bench.py [--smoke] [--out PATH]

Four scenarios, each deterministic (virtual clock):

* **overhead** — the 8-camera rebalancing sharded trace served as a
  batch (``eng.serve(frames)``) vs incrementally (``ServingRuntime``
  ingest per arrival + ``advance`` + ``drain``); the incremental wall
  time (min over alternating blocks, GC paused) must stay within 5%.
* **batch bit-identity** — one-shot ingest+drain through the runtime
  reproduces ``serve()`` byte-for-byte on DetectionEngine AND
  ShardedDetectionEngine across the static, rebalancing and
  seeded-fault+watchdog paths; back-to-back serves (the unified
  ``reset``) stay identical too.
* **chunked ingest** — chunk sizes {1, 3, 7} drain to the same bits as
  the one-shot serve on both engine kinds.
* **daemon drain** — the virtual-clock daemon replays the trace through
  the event pipeline: zero frames pending after shutdown, every
  recorded event published exactly once, and the tapped trace passes
  the ``obs.audit`` invariants (frame conservation, emit monotonicity).

Emits ``BENCH_daemon.json``; exits nonzero unless every acceptance key
holds (CI gates on this).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def canonical(report):
    """The bit-identity fingerprint of a serve report: response ids,
    replicas and clocks, drop list, and the latency block."""
    return {
        "responses": [(r.rid, r.replica, r.t_start, r.t_done)
                      for r in report["responses"]],
        "dropped": list(report["dropped"]),
        "migrations": report.get("migrations"),
        "per_replica": report["per_replica"],
        "p50_latency": report["p50_latency"],
        "p95_latency": report["p95_latency"],
        "p99_latency": report["p99_latency"],
        "latency_hist": report["latency_hist"],
    }


def _nvr_engine_kw(n_streams, n_frames, **extra):
    from repro.core import proxy_detect_fn_streams
    from repro.serving import make_nvr_streams

    frames, frame_of, videos, dets = make_nvr_streams(n_streams,
                                                      n_frames, rate=4.0)
    oracle = proxy_detect_fn_streams(videos, dets, frame_of)
    kw = dict(detect_fn=oracle, n_replicas=2, service_time=0.02,
              track_and_interpolate=True, **extra)
    return frames, kw


def _drain_chunked(engine, frames, chunk, streams=None):
    from repro.serving import ServingRuntime
    rt = ServingRuntime(engine, streams=streams)
    step = chunk or len(frames)
    for i in range(0, len(frames), step):
        rt.ingest(frames[i:i + step])
        rt.advance()
    return rt.drain()


def scenario_overhead(n_frames, blocks=7, serves_per_block=4, chunk=1):
    """Batch ``serve`` vs per-frame incremental ingest on the 8-camera
    rebalancing sharded trace: wall-time ratio must stay <= 1.05.

    Same measurement design as the tracing-overhead bench: each sample
    is a block of whole serves, batch/incremental blocks alternate so
    drift hits both sides, GC is paused, and the statistic is
    min-of-blocks per side — with up to three rounds because a single
    scheduler stall is far larger than the signal."""
    import gc

    from repro.serving import ShardedDetectionEngine

    frames, kw = _nvr_engine_kw(8, n_frames, n_shards=2,
                                rebalance=True, epoch_s=2.0)
    streams = sorted({f.stream_id for f in frames})

    def block_batch():
        t0 = time.perf_counter()
        for _ in range(serves_per_block):
            ShardedDetectionEngine(**kw).serve(frames)
        return time.perf_counter() - t0

    def block_incr():
        t0 = time.perf_counter()
        for _ in range(serves_per_block):
            _drain_chunked(ShardedDetectionEngine(**kw), frames, chunk,
                           streams=streams)
        return time.perf_counter() - t0

    def round_ratio():
        batch, incr = [], []
        gc.collect()
        gc.disable()
        try:
            for k in range(blocks):
                if k % 2 == 0:
                    incr.append(block_incr())
                    batch.append(block_batch())
                else:
                    batch.append(block_batch())
                    incr.append(block_incr())
        finally:
            gc.enable()
        return min(incr), min(batch)

    block_batch(), block_incr()            # warm every lazy path
    on = off = ratio = None
    rounds = 0
    for _ in range(3):
        rounds += 1
        on_r, off_r = round_ratio()
        if ratio is None or on_r / off_r < ratio:
            on, off, ratio = on_r, off_r, on_r / off_r
        if ratio <= 1.05:
            break
    ok = ratio <= 1.05
    per_serve = 1e3 / serves_per_block
    return {
        "cameras": 8,
        "frames": len(frames),
        "ingest_chunk": chunk,
        "batch_ms": round(off * per_serve, 2),
        "incremental_ms": round(on * per_serve, 2),
        "overhead_ratio": round(ratio, 4),
        "budget_ratio": 1.05,
        "blocks": blocks,
        "serves_per_block": serves_per_block,
        "rounds": rounds,
    }, ok


def scenario_bit_identity(n_frames):
    """serve() == one-shot runtime drain on every engine path, and
    back-to-back serves stay identical (unified reset)."""
    from repro.serving import (DetectionEngine, FaultSchedule,
                               ShardedDetectionEngine, Watchdog)

    results, oks = {}, {}

    frames, kw = _nvr_engine_kw(4, n_frames)
    base = DetectionEngine(**kw).serve(frames)
    again = DetectionEngine(**kw).serve(frames)
    incr = _drain_chunked(DetectionEngine(**kw), frames, None)
    oks["detection"] = (canonical(base) == canonical(incr)
                        == canonical(again))
    results["detection"] = {"frames": len(frames),
                            "identical": oks["detection"]}

    sframes, skw = _nvr_engine_kw(8, n_frames, n_shards=2)
    streams = sorted({f.stream_id for f in sframes})
    base = ShardedDetectionEngine(**skw).serve(sframes)
    incr = _drain_chunked(ShardedDetectionEngine(**skw), sframes, None,
                          streams=streams)
    oks["sharded_static"] = canonical(base) == canonical(incr)
    results["sharded_static"] = {"identical": oks["sharded_static"]}

    rkw = dict(skw, rebalance=True, epoch_s=2.0)
    base = ShardedDetectionEngine(**rkw).serve(sframes)
    eng = ShardedDetectionEngine(**rkw)
    r1 = eng.serve(sframes)
    eng.reset()
    r2 = eng.serve(sframes)
    incr = _drain_chunked(ShardedDetectionEngine(**rkw), sframes, None,
                          streams=streams)
    oks["sharded_rebalance"] = (canonical(base) == canonical(incr)
                                == canonical(r1) == canonical(r2))
    results["sharded_rebalance"] = {
        "identical": oks["sharded_rebalance"]}

    def chaos():
        return FaultSchedule.random(seed=1, horizon_s=n_frames / 4.0,
                                    n_shards=2, n_replicas=2,
                                    n_shard_events=1)

    fkw = dict(rkw)
    base = ShardedDetectionEngine(faults=chaos(), supervisor=Watchdog(),
                                  **fkw).serve(sframes)
    incr = _drain_chunked(
        ShardedDetectionEngine(faults=chaos(), supervisor=Watchdog(),
                               **fkw), sframes, None, streams=streams)
    oks["sharded_faults"] = canonical(base) == canonical(incr)
    results["sharded_faults"] = {
        "identical": oks["sharded_faults"],
        "frames_lost_shard": base["faults"]["frames_lost_shard"],
        "restarts": len(base["faults"]["restarts"]),
    }
    return results, oks


def scenario_chunked(n_frames, chunks=(1, 3, 7)):
    """Chunked ingest {1,3,7} == one-shot, on the plain engine and the
    rebalancing sharded engine."""
    from repro.serving import DetectionEngine, ShardedDetectionEngine

    frames, kw = _nvr_engine_kw(4, n_frames)
    ref = canonical(_drain_chunked(DetectionEngine(**kw), frames, None))
    det_ok = all(
        canonical(_drain_chunked(DetectionEngine(**kw), frames, c)) == ref
        for c in chunks)

    sframes, skw = _nvr_engine_kw(8, n_frames, n_shards=2,
                                  rebalance=True, epoch_s=2.0)
    streams = sorted({f.stream_id for f in sframes})
    sref = canonical(_drain_chunked(ShardedDetectionEngine(**skw),
                                    sframes, None, streams=streams))
    sh_ok = all(
        canonical(_drain_chunked(ShardedDetectionEngine(**skw), sframes,
                                 c, streams=streams)) == sref
        for c in chunks)
    ok = det_ok and sh_ok
    return {"chunks": list(chunks), "detection_identical": det_ok,
            "sharded_identical": sh_ok}, ok


def scenario_daemon(n_frames):
    """Virtual-clock daemon end to end: drain leaves nothing pending,
    the bus published every recorded event, and the tapped trace is
    audit-clean."""
    from repro.launch.daemon import ServingDaemon, VirtualClock
    from repro.obs import audit_recorder
    from repro.serving import (EventBus, ServingRuntime,
                               ShardedDetectionEngine)

    frames, kw = _nvr_engine_kw(8, n_frames, n_shards=2,
                                rebalance=True, epoch_s=2.0)
    frames = sorted(frames, key=lambda f: f.t_arrival)
    bus = EventBus()
    rec = bus.recorder()
    eng = ShardedDetectionEngine(recorder=rec, **kw)
    rt = ServingRuntime(eng, streams=sorted({f.stream_id
                                             for f in frames}))
    daemon = ServingDaemon(rt, clock=VirtualClock(), chunk=4)
    out = daemon.run(frames)
    res = audit_recorder(rec)
    published = sum(bus.counts.values())
    ok = (rt.frames_pending == 0
          and daemon.frames_ingested == len(frames)
          and published == len(rec.events)
          and res.ok)
    return {
        "frames": len(frames),
        "ingested": daemon.frames_ingested,
        "pending_after_drain": rt.frames_pending,
        "events_recorded": len(rec.events),
        "events_published": published,
        "topic_counts": dict(sorted(bus.counts.items())),
        "coverage": out["coverage"],
        "audit_ok": res.ok,
        "violations": res.violations[:5],
    }, ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream lengths (CI)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_daemon.json"))
    args = ap.parse_args()

    import jax

    n_frames = 16 if args.smoke else 32
    t0 = time.perf_counter()
    ovh, ok_ovh = scenario_overhead(24, blocks=6 if args.smoke else 8)
    ident, oks = scenario_bit_identity(n_frames)
    chunked, ok_ch = scenario_chunked(n_frames)
    daemon, ok_dm = scenario_daemon(n_frames)

    out = {
        "bench": "serving_daemon",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "overhead": ovh,
        "bit_identity": ident,
        "chunked": chunked,
        "daemon": daemon,
        "wall_s": round(time.perf_counter() - t0, 2),
        "acceptance": {
            # the incremental core costs <= 5% wall time vs batch serve
            "overhead_within_5pct": ok_ovh,
            # batch serve() through the refactored core is bit-identical
            # on every engine path (incl. back-to-back reset serves):
            "batch_bit_identical_detection": oks["detection"],
            "batch_bit_identical_sharded_static": oks["sharded_static"],
            "batch_bit_identical_sharded_rebalance":
                oks["sharded_rebalance"],
            "batch_bit_identical_sharded_faults": oks["sharded_faults"],
            # any ingest chunking drains to the one-shot bits
            "chunked_matches_one_shot": ok_ch,
            # the daemon drains in-flight frames and the tapped trace
            # conserves every frame (obs.audit)
            "daemon_drain_clean": ok_dm,
        },
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))
    if not all(out["acceptance"].values()):
        failed = [k for k, v in out["acceptance"].items() if not v]
        print(f"ACCEPTANCE FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
